//! End-to-end driver (DESIGN.md `e2e`): graph-neural-network feature
//! propagation — the paper's motivating workload (§2.1: "in graph based
//! machine learning, matrix B represents the node properties and matrix A
//! represents the graph, so SpMM performs the graph propagation").
//!
//! Runs a 2-layer GCN-style propagation `H' = Â H` on a power-law graph
//! **through the full three-layer stack**: the rust coordinator streams
//! scheduled windows into the AOT-compiled Pallas kernels via PJRT
//! (`Engine::spmm`), the functional simulator provides the oracle, and the
//! cycle simulator reports what the U280 would do.
//!
//! Requires artifacts: `make artifacts` first.
//!
//! ```bash
//! cargo run --release --example gnn_layer
//! ```

use std::time::Instant;

use sextans::arch::{simulate, AcceleratorConfig};
use sextans::arch::functional;
use sextans::runtime::Engine;
use sextans::sched::preprocess;
use sextans::sparse::{gen, rng::Rng, Coo};

/// Row-normalize the adjacency (mean aggregation: Â = D⁻¹(A + I)).
fn normalize_adjacency(a: &Coo) -> Coo {
    let n = a.m;
    let mut rows = a.rows.clone();
    let mut cols = a.cols.clone();
    let mut vals = a.vals.clone();
    for i in 0..n {
        rows.push(i as u32);
        cols.push(i as u32);
        vals.push(1.0); // self-loop
    }
    let mut deg = vec![0f32; n];
    for &r in &rows {
        deg[r as usize] += 1.0;
    }
    for (i, v) in vals.iter_mut().enumerate() {
        *v = 1.0 / deg[rows[i] as usize];
    }
    Coo { m: n, k: n, rows, cols, vals }
}

fn main() -> anyhow::Result<()> {
    let nodes = 3000usize;
    let feat = 16usize; // feature width (N in SpMM terms)
    let pes = 8usize; // XLA-path PE count (each PE tile must fit the variant)

    let mut rng = Rng::new(2024);
    let graph = gen::rmat(nodes, nodes * 8, 0.57, 0.19, 0.19, &mut rng);
    let adj = normalize_adjacency(&graph);
    println!(
        "graph: {} nodes, {} edges (nnz {}, max degree {})",
        nodes,
        graph.nnz(),
        adj.nnz(),
        adj.max_row_nnz()
    );

    // --- Load the AOT artifacts and plan execution (variant selection).
    let t0 = Instant::now();
    let engine = Engine::load_default()?;
    println!(
        "engine: loaded + compiled artifacts in {:.2} s (variants: {:?})",
        t0.elapsed().as_secs_f64(),
        engine.variants().iter().map(|v| v.m_tile).collect::<Vec<_>>()
    );
    let d = AcceleratorConfig::sextans_u280().d;
    let (variant, image) = engine.plan(&adj, pes, d)?;
    println!(
        "plan: variant k0={} m_tile={} nnz_cap={}, image {} windows, II {:.4}",
        variant.k0,
        variant.m_tile,
        variant.nnz_cap,
        image.num_windows,
        image.effective_ii()
    );

    // --- Initial features.
    let mut h: Vec<f32> = (0..nodes * feat).map(|_| rng.normal()).collect();

    // --- Two propagation layers through the PJRT kernels.
    let zeros = vec![0f32; nodes * feat];
    let mut xla_total = 0.0;
    for layer in 0..2 {
        let t = Instant::now();
        let h_next = engine.spmm(variant, &image, &h, &zeros, feat, 1.0, 0.0)?;
        let dt = t.elapsed().as_secs_f64();
        xla_total += dt;

        // Oracle: the functional simulator (identical slot order).
        let mut want = zeros.clone();
        functional::execute(&image, &h, &mut want, feat, 1.0, 0.0);
        let max_err = h_next
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        println!(
            "layer {layer}: XLA/PJRT {dt:.3} s, max |err| vs functional sim = {max_err:.2e}"
        );
        assert!(max_err < 1e-3, "PJRT path diverged");
        h = h_next;
    }

    // --- What the real accelerator would do (cycle model, U280 config).
    let cfg = AcceleratorConfig::sextans_u280();
    let u280_image = preprocess(&adj, cfg.p(), cfg.k0, cfg.d);
    let rep = simulate(&u280_image, &cfg, feat);
    println!(
        "\nU280 projection per layer: {} cycles = {:.3} ms, {:.2} GFLOP/s",
        rep.cycles,
        rep.seconds * 1e3,
        rep.gflops
    );
    println!(
        "host XLA-interpret path ran {:.1}x slower than the projected silicon \
         (expected: interpret-mode Pallas on CPU vs a 189 MHz pipeline)",
        (xla_total / 2.0) / rep.seconds
    );
    println!("\ngnn_layer OK — 2 layers propagated through rust -> PJRT -> Pallas HLO");
    Ok(())
}
