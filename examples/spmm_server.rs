//! Serving demo: the L3 coordinator as an SpMM inference service.
//!
//! Registers two preprocessed matrices ("models"), fires a mixed workload
//! of requests at the server, and reports batching effectiveness and
//! latency percentiles. Demonstrates the vLLM-router-style dynamic batcher:
//! requests against the same matrix with matching (α, β) are column-merged
//! into one SpMM.
//!
//! ```bash
//! cargo run --release --example spmm_server
//! ```

use std::sync::Arc;
use std::time::Instant;

use sextans::arch::AcceleratorConfig;
use sextans::coordinator::{BatchPolicy, Server, SpmmRequest};
use sextans::sched::preprocess;
use sextans::sparse::{gen, rng::Rng};

fn main() {
    let cfg = AcceleratorConfig::sextans_u280();
    let mut rng = Rng::new(11);

    // Two "models": a social graph and an FEM matrix.
    let social = gen::rmat(8192, 80_000, 0.57, 0.19, 0.19, &mut rng);
    let fem = gen::banded(6000, 24, 16, &mut rng);
    println!(
        "models: social {}x{} nnz {}, fem {}x{} nnz {}",
        social.m, social.k, social.nnz(),
        fem.m, fem.k, fem.nnz()
    );

    let t0 = Instant::now();
    let social_img = Arc::new(preprocess(&social, cfg.p(), cfg.k0, cfg.d));
    let fem_img = Arc::new(preprocess(&fem, cfg.p(), cfg.k0, cfg.d));
    println!("preprocessing (both): {:.2} s", t0.elapsed().as_secs_f64());

    // Workers pick their engine by registry name; swap "native" for
    // "functional" or "pjrt" to change the execution path.
    let server = Server::start_backend(
        2,
        BatchPolicy {
            max_columns: 256,
            window: std::time::Duration::from_millis(3),
            route_columns: 8,
        },
        "native",
    )
    .expect("backend spec");
    let h_social = server.register(social_img);
    let h_fem = server.register(fem_img);

    // Mixed workload: 200 requests across both models and several widths.
    let t1 = Instant::now();
    let mut rxs = Vec::new();
    let mut total_flops = 0u64;
    for i in 0..200 {
        let (handle, k, m) = if i % 3 == 0 {
            (h_fem.clone(), fem.k, fem.m)
        } else {
            (h_social.clone(), social.k, social.m)
        };
        let n = [4usize, 8, 16, 32][i % 4];
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        total_flops += 2 * (if i % 3 == 0 { fem.nnz() } else { social.nnz() } as u64) * n as u64;
        rxs.push(server.submit(SpmmRequest {
            image: handle,
            b,
            c: vec![0.0; m * n],
            n,
            alpha: 1.0,
            beta: 0.0,
        }));
    }
    for rx in rxs {
        rx.recv().expect("response");
    }
    let wall = t1.elapsed().as_secs_f64();
    let s = server.shutdown();

    println!("\nserved {} requests in {:.2} s ({:.1} req/s, {:.2} GFLOP/s functional)",
        s.requests, wall, s.requests as f64 / wall, total_flops as f64 / wall / 1e9);
    println!(
        "batching: {} batches, mean {:.1} requests/batch",
        s.batches, s.mean_batch
    );
    for (name, count) in &s.backends {
        println!("backend {name}: {count} requests");
    }
    println!(
        "latency: p50 {:.2} ms, p95 {:.2} ms, p99 {:.2} ms",
        s.p50_s * 1e3,
        s.p95_s * 1e3,
        s.p99_s * 1e3
    );
    assert!(s.mean_batch > 1.0, "batcher should have merged something");
    println!("\nspmm_server OK");
}
