//! Sharded serving quickstart: what `sextans serve --shards 4` does, as a
//! library consumer.
//!
//! One power-law "model" matrix is registered with the coordinator, whose
//! workers execute through the `sharded:4:native` composite backend — each
//! SpMM is row-partitioned across 4 nnz-balanced shards running in
//! parallel, and the serving summary reports shard-level load balance and
//! makespan alongside the usual latency percentiles.
//!
//! ```bash
//! cargo run --release --example sharded_serve
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use sextans::arch::AcceleratorConfig;
use sextans::coordinator::{BatchPolicy, Server, SpmmRequest};
use sextans::sched::preprocess;
use sextans::shard::plan_shards;
use sextans::sparse::{gen, rng::Rng};

fn main() {
    let cfg = AcceleratorConfig::sextans_u280();
    let mut rng = Rng::new(42);

    // A recommender-style matrix: Zipf row degrees, uniform columns — the
    // skew that makes nnz-balanced sharding worthwhile.
    let model = gen::power_law_rows(16_384, 8_192, 600_000, 1.1, &mut rng);
    println!(
        "model: {}x{} nnz {} (max row {} nnz)",
        model.m,
        model.k,
        model.nnz(),
        model.max_row_nnz()
    );
    // Peek at the plan the sharded backend will build internally.
    let plan = plan_shards(&model, 4);
    println!(
        "shard plan: nnz per shard {:?}, imbalance {:.3}",
        plan.shard_nnz,
        plan.imbalance()
    );

    let image = Arc::new(preprocess(&model, cfg.p(), cfg.k0, cfg.d));

    // `sharded:4:native` — the coordinator divides its thread budget per
    // worker, the composite divides the worker's share per shard.
    let server = Server::start_backend(
        2,
        BatchPolicy { max_columns: 256, window: Duration::from_millis(3), route_columns: 8 },
        "sharded:4:native",
    )
    .expect("backend spec");
    let handle = server.register(image);

    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for i in 0..48 {
        let n = [8usize, 16, 32][i % 3];
        let b: Vec<f32> = (0..model.k * n).map(|_| rng.normal()).collect();
        rxs.push(server.submit(SpmmRequest {
            image: handle.clone(),
            b,
            c: vec![0.0; model.m * n],
            n,
            alpha: 1.0,
            beta: 0.0,
        }));
    }
    for rx in rxs {
        let resp = rx.recv().expect("response");
        assert!(resp.error.is_none(), "shard failure: {:?}", resp.error);
    }
    let wall = t0.elapsed().as_secs_f64();
    let s = server.shutdown();

    println!(
        "\nserved {} requests in {wall:.2} s ({} batches, mean {:.1} req/batch)",
        s.requests, s.batches, s.mean_batch
    );
    println!(
        "latency: p50 {:.2} ms, p95 {:.2} ms, p99 {:.2} ms",
        s.p50_s * 1e3,
        s.p95_s * 1e3,
        s.p99_s * 1e3
    );
    println!(
        "shards: {} executions, mean {:.1} shards, imbalance mean {:.3} / max {:.3}, \
         mean makespan {:.2} ms",
        s.shard_execs,
        s.mean_shards,
        s.mean_shard_imbalance,
        s.max_shard_imbalance,
        s.mean_shard_makespan_s * 1e3
    );
    println!(
        "prepare: {} shard-pool builds ({} cache hits, hit rate {:.0}%), mean {:.2} ms, \
         {:.2} MiB resident",
        s.prepares,
        s.prepare_hits,
        s.prepare_hit_rate * 100.0,
        s.mean_prepare_s * 1e3,
        s.prepared_bytes as f64 / (1024.0 * 1024.0)
    );
    assert!(s.shard_execs > 0, "sharded backend must report shard stats");
    assert!(s.prepares <= 2, "one registered matrix: at most one prepare per worker");
    println!("\nsharded_serve OK");
}
