//! Power iteration (PageRank-style) on the HFlex accelerator.
//!
//! SpMV is SpMM with N = 1; the paper's N0 = 8 lanes mean an SpMV only
//! uses 1/8 of each PU — so we run EIGHT chained power iterations at once
//! (one per lane) on shifted starting vectors, which is both a real trick
//! (block power iteration) and a demonstration of why the N/N0 loop
//! structure makes small-N problems bandwidth-friendly.
//!
//! ```bash
//! cargo run --release --example spmv_power_iteration
//! ```

use sextans::arch::AcceleratorConfig;
use sextans::hflex::{HFlexAccelerator, SpmmProblem};
use sextans::sparse::{gen, rng::Rng, Coo};

/// Column-stochastic transition matrix of a random graph.
fn transition_matrix(n: usize, rng: &mut Rng) -> Coo {
    let g = gen::rmat(n, n * 6, 0.57, 0.19, 0.19, rng);
    // Column sums for normalization (dangling columns get a self loop).
    let mut colsum = vec![0f32; n];
    for i in 0..g.nnz() {
        colsum[g.cols[i] as usize] += g.vals[i].abs();
    }
    let mut rows = Vec::new();
    let mut cols = Vec::new();
    let mut vals = Vec::new();
    for i in 0..g.nnz() {
        rows.push(g.rows[i]);
        cols.push(g.cols[i]);
        vals.push(g.vals[i].abs() / colsum[g.cols[i] as usize]);
    }
    for (j, &s) in colsum.iter().enumerate() {
        if s == 0.0 {
            rows.push(j as u32);
            cols.push(j as u32);
            vals.push(1.0);
        }
    }
    Coo { m: n, k: n, rows, cols, vals }
}

fn main() -> anyhow::Result<()> {
    let n_nodes = 4096usize;
    let lanes = 8usize; // N0: eight simultaneous iterations
    let damping = 0.85f32;
    let iters = 30usize;

    let mut rng = Rng::new(99);
    let p = transition_matrix(n_nodes, &mut rng);
    println!("transition matrix: {}x{}, nnz {}", p.m, p.k, p.nnz());

    let accel = HFlexAccelerator::synthesize(AcceleratorConfig::sextans_u280());
    // Load once: every iteration below reuses the same resident handle —
    // the prepare/execute contract is exactly the power-iteration shape.
    let image = accel.load(&p)?;

    // x: n_nodes x lanes block of rank vectors, uniformly initialized with
    // per-lane perturbations.
    let mut x = vec![1.0f32 / n_nodes as f32; n_nodes * lanes];
    for (i, v) in x.iter_mut().enumerate() {
        *v *= 1.0 + 0.01 * ((i % lanes) as f32);
    }
    let teleport = (1.0 - damping) / n_nodes as f32;

    let mut total_cycles = 0u64;
    let mut delta = f32::MAX;
    for it in 0..iters {
        // x' = damping * P x + teleport  (SpMM with alpha=damping, beta=0,
        // then the teleport constant folded in on the host).
        let b = x.clone();
        let mut c = vec![0f32; n_nodes * lanes];
        let report = accel.invoke(SpmmProblem {
            a: &image,
            b: &b,
            c: &mut c,
            n: lanes,
            alpha: damping,
            beta: 0.0,
        })?;
        total_cycles += report.sim.cycles;
        for v in c.iter_mut() {
            *v += teleport;
        }
        delta = x
            .iter()
            .zip(&c)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        x = c;
        if it % 5 == 0 || delta < 1e-7 {
            println!("iter {it:>3}: max delta = {delta:.3e}");
        }
        if delta < 1e-7 {
            break;
        }
    }

    // All lanes converged to the same dominant eigenvector.
    let lane = |q: usize| -> Vec<f32> { (0..n_nodes).map(|i| x[i * lanes + q]).collect() };
    let l0 = lane(0);
    for q in 1..lanes {
        let lq = lane(q);
        let dmax = l0
            .iter()
            .zip(&lq)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(dmax < 1e-4, "lane {q} disagreed by {dmax}");
    }
    // Rank sums to 1 per lane (stochastic fixed point).
    let sum: f32 = l0.iter().sum();
    assert!((sum - 1.0).abs() < 1e-2, "rank mass = {sum}");

    let cfg = accel.config();
    println!(
        "\nconverged (delta {delta:.2e}); {} accelerator invocations, \
         {total_cycles} total cycles = {:.2} ms on U280",
        iters,
        cfg.seconds(total_cycles) * 1e3
    );
    println!("top-5 ranked nodes: {:?}", top_k(&l0, 5));
    println!("\nspmv_power_iteration OK");
    Ok(())
}

fn top_k(x: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..x.len()).collect();
    idx.sort_by(|&a, &b| x[b].partial_cmp(&x[a]).unwrap());
    idx.truncate(k);
    idx
}
