//! Quickstart: synthesize a Sextans accelerator once, run several SpMMs of
//! different shapes on it (the HFlex contract), and read the reports.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use sextans::arch::AcceleratorConfig;
use sextans::hflex::{HFlexAccelerator, SpmmProblem};
use sextans::sparse::{gen, rng::Rng};

fn main() -> anyhow::Result<()> {
    // 1. "Synthesize" the accelerator: one config, fixed forever (the paper
    //    ships one U280 bitstream; we ship one simulator config).
    let accel = HFlexAccelerator::synthesize(AcceleratorConfig::sextans_u280());
    println!(
        "synthesized Sextans: {} PEs x {} PUs, K0 = {}, {} MHz",
        accel.config().p(),
        accel.config().n0,
        accel.config().k0,
        accel.config().freq_mhz
    );

    let mut rng = Rng::new(42);

    // 2. Run three very differently shaped SpMMs on the SAME accelerator.
    for (label, m, k, density, n) in [
        ("social-graph-ish", 8192usize, 8192usize, 0.002f64, 64usize),
        ("fem-ish (wide B)", 2048, 2048, 0.01, 512),
        ("tall skinny", 50_000, 512, 0.01, 8),
    ] {
        let a = gen::random_uniform(m, k, density, &mut rng);
        // Load (once per matrix): partition + OoO schedule + make the
        // image resident on the execution backend.
        let loaded = accel.load(&a)?;

        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let mut c: Vec<f32> = vec![0.0; m * n];
        let report = accel.invoke(SpmmProblem {
            a: &loaded,
            b: &b,
            c: &mut c,
            n,
            alpha: 1.0,
            beta: 0.0,
        })?;

        let sim = &report.sim;
        println!(
            "\n[{label}] {}x{} nnz={} N={n}",
            m,
            k,
            a.nnz()
        );
        let image = loaded.image();
        println!(
            "  schedule: II = {:.4}, {} bubbles / {} slots; loaded in {:.2} ms",
            image.effective_ii(),
            image.total_bubbles(),
            image.total_slots(),
            loaded.prepare_cost().wall.as_secs_f64() * 1e3
        );
        println!(
            "  simulated: {:.3} ms, {:.2} GFLOP/s (roof {:.1})",
            sim.seconds * 1e3,
            sim.gflops,
            accel.config().datapath_roof_gflops()
        );
        // The functional result is in `c`; spot check against the naive oracle.
        let mut want = vec![0.0f32; m * n];
        a.spmm_reference(&b, &mut want, n, 1.0, 0.0);
        let max_err = c
            .iter()
            .zip(&want)
            .map(|(x, y)| (x - y).abs())
            .fold(0f32, f32::max);
        println!("  numerics: max |err| vs oracle = {max_err:.2e}");
        assert!(max_err < 1e-2, "functional mismatch");
    }

    println!("\nquickstart OK — same accelerator, three problem shapes, zero re-synthesis");
    Ok(())
}
