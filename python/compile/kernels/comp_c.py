"""L1 Pallas kernel: the Comp-C stage, C_out = alpha * C_AB + beta * C_in.

Paper §3.1.1: "A Comp C module performs the element-wise computation of
C_out = C_alphaAB + beta * C_in". The paper processes it with a parallel
factor of F_C x N0 = 16 x 8 = 128 lanes; here the whole tile is one VPU
vector op, and the F_C factor enters the cycle model (perfmodel), not the
numerics.

alpha and beta are passed as (1,1) arrays so ONE compiled artifact serves
every (alpha, beta) pair — the HFlex contract (scalars are runtime inputs,
never compile-time constants).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _comp_c_kernel(c_ab_ref, c_in_ref, alpha_ref, beta_ref, o_ref):
    alpha = alpha_ref[0, 0]
    beta = beta_ref[0, 0]
    o_ref[...] = alpha * c_ab_ref[...] + beta * c_in_ref[...]


@jax.jit
def comp_c(c_ab, c_in, alpha, beta):
    """Element-wise combine.

    Args:
      c_ab: float32[M_TILE, N0] accumulated A@B tile.
      c_in: float32[M_TILE, N0] streamed-in original C tile.
      alpha, beta: float32[1, 1] runtime scalars.

    Returns:
      float32[M_TILE, N0] output tile.
    """
    return pl.pallas_call(
        _comp_c_kernel,
        out_shape=jax.ShapeDtypeStruct(c_ab.shape, jnp.float32),
        interpret=True,
    )(c_ab, c_in, alpha, beta)
