"""L1 Pallas kernels (interpret=True) + pure-jnp reference oracles."""

from .comp_c import comp_c
from .dense_tile import dense_tile
from .spmm_window import spmm_window

__all__ = ["comp_c", "dense_tile", "spmm_window"]
