"""L1 Pallas kernel: dense tile matmul — the MXU-path baseline.

Two roles:
  1. the "decompose into fixed-size dense kernels" baseline the paper argues
     against in §2.4 (the 4096x4096 AutoSA-style kernel with 0.15 ms launch
     overhead per tile) — our perfmodel uses its cycle count;
  2. the MXU half of the hardware-adaptation story: dense tiles DO map to
     the systolic array, so this kernel is written MXU-style
     (jnp.dot with preferred_element_type) while spmm_window uses VPU lanes.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dense_tile_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@jax.jit
def dense_tile(a_tile, b_tile):
    """o = a_tile @ b_tile for fixed-shape dense tiles.

    Args:
      a_tile: float32[M_T, K_T]
      b_tile: float32[K_T, N_T]

    Returns:
      float32[M_T, N_T]
    """
    m_t, _ = a_tile.shape
    _, n_t = b_tile.shape
    return pl.pallas_call(
        _dense_tile_kernel,
        out_shape=jax.ShapeDtypeStruct((m_t, n_t), jnp.float32),
        interpret=True,
    )(a_tile, b_tile)
