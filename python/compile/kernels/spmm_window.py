"""L1 Pallas kernel: the Sextans PE inner loop over one scheduled window.

One grid step consumes the whole scheduled non-zero list of a window
(NNZ_CAP slots, zero-padded) and updates the C-tile scratchpad:

    for t in 0..NNZ_CAP:                  # one non-zero per "cycle" (II=1)
        r, c, v = rows[t], cols[t], vals[t]
        C[r, 0:N0] += v * B[c, 0:N0]      # N0 lanes = the paper's 8 PUs

Hardware adaptation (paper §FPGA -> TPU, see DESIGN.md §Hardware-Adaptation):
  * the B window lives in VMEM (BRAM analogue) — `pallas_call` copies it
    HBM->VMEM once per window, which *is* the paper's "stream a B window,
    then compute" schedule (paper §3.5 (1));
  * the C tile is an output-stationary VMEM accumulator (URAM analogue);
  * the N0-wide vector update uses VPU lanes in place of the 8 PUs;
  * the MXU is deliberately NOT used here: scheduled gather/scatter SpMM is
    not a systolic fit (it is used in dense_tile.py instead).

The kernel is sequential over non-zeros by construction — exactly like the
paper's II=1 pipeline, where inter-nonzero parallelism exists only across
PEs (grid/batch dimension handled by the rust coordinator). The out-of-order
schedule produced by `sextans::sched` guarantees that consecutive slots never
target the same row within the RAW distance D, which is what makes the
sequential loop legal to pipeline on real hardware.

MUST be lowered with interpret=True: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmm_window_kernel(rows_ref, cols_ref, vals_ref, b_ref, c_ref, o_ref):
    """Pallas kernel body. o_ref aliases the updated C tile."""
    # Load the incoming accumulator once (URAM preload).
    o_ref[...] = c_ref[...]

    nnz_cap = rows_ref.shape[0]
    n0 = b_ref.shape[1]

    def body(t, _):
        r = rows_ref[t]
        c = cols_ref[t]
        v = vals_ref[t]
        # Gather N0 B elements (step 2 in paper Fig. 4): one BRAM read,
        # broadcast to the N0 PUs.
        b_row = pl.load(b_ref, (pl.dslice(c, 1), pl.dslice(0, n0)))
        # Read-modify-write the C scratchpad row (steps 3-6 in Fig. 4).
        c_row = pl.load(o_ref, (pl.dslice(r, 1), pl.dslice(0, n0)))
        pl.store(o_ref, (pl.dslice(r, 1), pl.dslice(0, n0)), c_row + v * b_row)
        return 0

    jax.lax.fori_loop(0, nnz_cap, body, 0)


@functools.partial(jax.jit, static_argnames=("m_tile",))
def spmm_window(rows, cols, vals, b_win, c_acc, *, m_tile=None):
    """Run one scheduled window through the PE datapath.

    Args:
      rows: int32[NNZ_CAP] compressed row indices (padding: val == 0).
      cols: int32[NNZ_CAP] compressed col indices into the B window.
      vals: float32[NNZ_CAP] values.
      b_win: float32[K0, N0] dense B window (VMEM/BRAM analogue).
      c_acc: float32[M_TILE, N0] C scratchpad tile.
      m_tile: unused static hint (shapes carry all information).

    Returns:
      float32[M_TILE, N0] updated C tile.
    """
    del m_tile
    return pl.pallas_call(
        _spmm_window_kernel,
        out_shape=jax.ShapeDtypeStruct(c_acc.shape, jnp.float32),
        interpret=True,
    )(rows, cols, vals, b_win, c_acc)
