"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth every Pallas kernel (and, transitively, every HLO
artifact executed from rust) is validated against. They use only dense jnp
ops / scatter-adds, no Pallas, so a bug cannot be shared between kernel and
oracle.
"""

import jax.numpy as jnp


def ref_spmm_window(rows, cols, vals, b_win, c_acc):
    """Accumulate one scheduled-nonzero window into the C tile.

    Mirrors the Sextans PE inner loop (paper Eq. 5): for each non-zero
    a[r, c] = v, do  C[r, 0:N0] += v * B[c, 0:N0].

    Padding contract: padded slots carry val == 0.0 (row/col arbitrary but
    in-range), so they contribute exactly 0.

    Args:
      rows: int32[NNZ]   compressed row indices into the C tile.
      cols: int32[NNZ]   compressed column indices into the B window.
      vals: float32[NNZ] non-zero values (0.0 for padding).
      b_win: float32[K0, N0] dense B window.
      c_acc: float32[M_TILE, N0] accumulator (C scratchpad analogue).

    Returns:
      float32[M_TILE, N0] updated accumulator.
    """
    contrib = vals[:, None] * b_win[cols]
    return c_acc.at[rows].add(contrib)


def ref_comp_c(c_ab, c_in, alpha, beta):
    """The Comp-C stage: C_out = alpha * C_AB + beta * C_in (element-wise)."""
    return alpha * c_ab + beta * c_in


def ref_dense_tile(a_tile, b_tile):
    """Dense tile matmul (MXU analogue) used by the dense baseline path."""
    return jnp.dot(a_tile, b_tile, preferred_element_type=jnp.float32)


def ref_spmm_full(rows, cols, vals, m, b, c, alpha, beta):
    """Full SpMM oracle: C = alpha * A @ B + beta * C with COO A.

    Used by pytest to validate window-decomposed execution end-to-end.
    """
    ab = jnp.zeros((m, b.shape[1]), dtype=jnp.float32)
    ab = ab.at[rows].add(vals[:, None] * b[cols])
    return alpha * ab + beta * c
