"""L2: the window-level SpMM compute graph in JAX, composing the L1 kernels.

The Sextans dataflow (paper Eq. 1-4) decomposes C = alpha*A@B + beta*C into
(i, j, p) windows. The rust coordinator (L3) owns the outer i/j/p loops,
scheduling, and streaming; this module owns the per-tile compute graph:

  * `make_window_fn`  — one (p, j) window: scheduled non-zeros x B window
                        accumulated into the C-tile scratchpad (L1 kernel).
  * `make_comp_fn`    — the Comp-C combine C_out = alpha*C_AB + beta*C_in.
  * `make_fused_fn`   — one (i, p) C tile end-to-end: lax.scan over NWIN
                        K-windows calling the L1 kernel, then Comp-C. This
                        is the artifact the hot path prefers (one PJRT call
                        per C tile instead of K/K0 + 1 calls).
  * `make_dense_fn`   — dense tile matmul (MXU path / fixed-size-kernel
                        baseline of paper §2.4).

Every function here is shape-monomorphic per `Variant` — the AOT analogue of
a synthesized bitstream. HFlex holds because the *contents* (non-zeros, Q,
alpha, beta) are runtime inputs.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels.comp_c import _comp_c_kernel
from .kernels.spmm_window import _spmm_window_kernel
from .kernels.dense_tile import _dense_tile_kernel
from jax.experimental import pallas as pl


@dataclasses.dataclass(frozen=True)
class Variant:
    """A fixed-capacity hardware variant (one AOT artifact family).

    Attributes:
      name: short id used in artifact filenames and the rust variant cache.
      nnz_cap: scheduled-slot capacity per window (padded with val=0.0).
      k0: B window depth (paper: 4096; scaled for CPU-interpret artifacts).
      m_tile: C scratchpad rows per PE tile (paper URAM depth: 12,288).
      n0: PU lane count (paper: 8).
    """

    name: str
    nnz_cap: int
    k0: int
    m_tile: int
    n0: int


def _window_call(variant, rows, cols, vals, b_win, c_acc):
    return pl.pallas_call(
        _spmm_window_kernel,
        out_shape=jax.ShapeDtypeStruct((variant.m_tile, variant.n0), jnp.float32),
        interpret=True,
    )(rows, cols, vals, b_win, c_acc)


def _comp_call(c_ab, c_in, alpha, beta):
    return pl.pallas_call(
        _comp_c_kernel,
        out_shape=jax.ShapeDtypeStruct(c_ab.shape, jnp.float32),
        interpret=True,
    )(c_ab, c_in, alpha, beta)


def make_window_fn(variant):
    """One scheduled window through the PE datapath. Returns a 1-tuple."""

    def fn(rows, cols, vals, b_win, c_acc):
        return (_window_call(variant, rows, cols, vals, b_win, c_acc),)

    return fn


def make_comp_fn(variant):
    """Comp-C combine for one tile. Returns a 1-tuple."""
    del variant

    def fn(c_ab, c_in, alpha, beta):
        return (_comp_call(c_ab, c_in, alpha, beta),)

    return fn


def make_fused_fn(variant, nwin):
    """One (i, p) C tile: scan over `nwin` K-windows + Comp-C.

    The scan carry is the C scratchpad — output-stationary, exactly the
    paper's URAM accumulator that persists across the j loop (Eq. 3).
    Surplus windows must be padded with val=0.0 slots (harmless adds),
    mirroring how the real accelerator idles PEs on short windows.
    """

    def fn(rows, cols, vals, b_wins, c_in, alpha, beta):
        # rows/cols: i32[nwin, nnz_cap]; vals: f32[nwin, nnz_cap]
        # b_wins: f32[nwin, k0, n0]; c_in: f32[m_tile, n0]
        c0 = jnp.zeros((variant.m_tile, variant.n0), dtype=jnp.float32)

        def step(c_acc, xs):
            r, c, v, b = xs
            return _window_call(variant, r, c, v, b, c_acc), None

        c_ab, _ = jax.lax.scan(step, c0, (rows, cols, vals, b_wins), length=nwin)
        return (_comp_call(c_ab, c_in, alpha, beta),)

    return fn


def make_dense_fn(m_t, k_t, n_t):
    """Dense tile matmul (MXU path). Returns a 1-tuple."""

    def fn(a_tile, b_tile):
        return (
            pl.pallas_call(
                _dense_tile_kernel,
                out_shape=jax.ShapeDtypeStruct(
                    (a_tile.shape[0], b_tile.shape[1]), jnp.float32
                ),
                interpret=True,
            )(a_tile, b_tile),
        )

    return fn


def window_specs(variant):
    """ShapeDtypeStructs for make_window_fn inputs."""
    i32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
    f32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.float32)
    return (
        i32((variant.nnz_cap,)),
        i32((variant.nnz_cap,)),
        f32((variant.nnz_cap,)),
        f32((variant.k0, variant.n0)),
        f32((variant.m_tile, variant.n0)),
    )


def comp_specs(variant):
    """ShapeDtypeStructs for make_comp_fn inputs."""
    f32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.float32)
    return (
        f32((variant.m_tile, variant.n0)),
        f32((variant.m_tile, variant.n0)),
        f32((1, 1)),
        f32((1, 1)),
    )


def fused_specs(variant, nwin):
    """ShapeDtypeStructs for make_fused_fn inputs."""
    i32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
    f32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.float32)
    return (
        i32((nwin, variant.nnz_cap)),
        i32((nwin, variant.nnz_cap)),
        f32((nwin, variant.nnz_cap)),
        f32((nwin, variant.k0, variant.n0)),
        f32((variant.m_tile, variant.n0)),
        f32((1, 1)),
        f32((1, 1)),
    )


def dense_specs(m_t, k_t, n_t):
    f32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.float32)
    return (f32((m_t, k_t)), f32((k_t, n_t)))
