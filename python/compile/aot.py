"""AOT pipeline: lower the L2 model (with L1 Pallas kernels inside) to HLO
text artifacts consumed by the rust runtime.

Interchange format is HLO *text*, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  python -m compile.aot --out ../artifacts
Emits:  <out>/<name>.hlo.txt per artifact + <out>/manifest.tsv

The manifest is the contract with rust (`runtime::manifest`): one line per
artifact, tab-separated `kind  name  file  key=value ...`.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


# The "synthesized bitstreams": fixed-capacity variants. win_m is the default
# hot-path variant; win_s keeps tests fast; win_l exercises capacity
# selection. Paper values (K0=4096, URAM depth 12,288) are scaled down for
# CPU-interpret artifact size; the cycle model uses the paper values.
WINDOW_VARIANTS = [
    model.Variant("win_s", nnz_cap=256, k0=128, m_tile=128, n0=8),
    model.Variant("win_m", nnz_cap=2048, k0=512, m_tile=512, n0=8),
    model.Variant("win_l", nnz_cap=8192, k0=1024, m_tile=1024, n0=8),
]

FUSED_NWIN = 8
DENSE_TILE = (128, 128, 8)  # (M_T, K_T, N_T)


def to_hlo_text(lowered) -> str:
    """jax lowered -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(fn, specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*specs))


def build_all(out_dir: str) -> list[str]:
    """Lower every artifact, write HLO text + manifest. Returns manifest lines."""
    os.makedirs(out_dir, exist_ok=True)
    manifest = []

    def emit(kind, name, fn, specs, **params):
        fname = f"{name}.hlo.txt"
        text = lower_artifact(fn, specs)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        kv = "\t".join(f"{k}={v}" for k, v in sorted(params.items()))
        manifest.append(f"{kind}\t{name}\t{fname}\t{kv}")
        print(f"  [aot] {kind:12s} {name:12s} -> {fname} ({len(text)} chars)")

    for v in WINDOW_VARIANTS:
        emit(
            "spmm_window",
            v.name,
            model.make_window_fn(v),
            model.window_specs(v),
            nnz_cap=v.nnz_cap,
            k0=v.k0,
            m_tile=v.m_tile,
            n0=v.n0,
        )
        emit(
            "comp_c",
            f"comp_{v.name}",
            model.make_comp_fn(v),
            model.comp_specs(v),
            m_tile=v.m_tile,
            n0=v.n0,
        )

    # Fused tile artifact on the default variant (hot path: 1 PJRT call/tile).
    vm = WINDOW_VARIANTS[1]
    emit(
        "spmm_fused",
        f"fused_{vm.name}",
        model.make_fused_fn(vm, FUSED_NWIN),
        model.fused_specs(vm, FUSED_NWIN),
        nnz_cap=vm.nnz_cap,
        k0=vm.k0,
        m_tile=vm.m_tile,
        n0=vm.n0,
        nwin=FUSED_NWIN,
    )

    m_t, k_t, n_t = DENSE_TILE
    emit(
        "dense_tile",
        "dense_128",
        model.make_dense_fn(m_t, k_t, n_t),
        model.dense_specs(m_t, k_t, n_t),
        m_t=m_t,
        k_t=k_t,
        n_t=n_t,
    )

    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    return manifest


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="artifact dir")
    args = parser.parse_args()
    lines = build_all(args.out)
    print(f"[aot] wrote {len(lines)} artifacts + manifest to {args.out}")


if __name__ == "__main__":
    main()
