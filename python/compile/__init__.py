"""Build-time compile path: JAX/Pallas model + AOT lowering to HLO text.

Nothing in this package is imported at runtime — the rust coordinator only
consumes the HLO artifacts this package emits (`make artifacts`).
"""
