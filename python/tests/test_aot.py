"""AOT pipeline tests: artifact emission, manifest contract, HLO sanity."""

import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    lines = aot.build_all(out)
    return out, lines


def test_manifest_written(built):
    out, lines = built
    assert os.path.exists(os.path.join(out, "manifest.tsv"))
    with open(os.path.join(out, "manifest.tsv")) as f:
        disk = f.read().strip().split("\n")
    assert disk == lines


def test_every_artifact_file_exists_and_is_hlo(built):
    out, lines = built
    assert len(lines) == 2 * len(aot.WINDOW_VARIANTS) + 2
    for line in lines:
        kind, name, fname, *_ = line.split("\t")
        path = os.path.join(out, fname)
        assert os.path.exists(path), path
        text = open(path).read()
        # HLO text sanity: has an entry computation and real instructions.
        assert "ENTRY" in text
        assert "f32" in text


def test_manifest_params_match_variants(built):
    _, lines = built
    by_name = {}
    for line in lines:
        kind, name, fname, *kvs = line.split("\t")
        by_name[name] = (kind, dict(kv.split("=") for kv in kvs))
    for v in aot.WINDOW_VARIANTS:
        kind, params = by_name[v.name]
        assert kind == "spmm_window"
        assert int(params["nnz_cap"]) == v.nnz_cap
        assert int(params["k0"]) == v.k0
        assert int(params["m_tile"]) == v.m_tile
        assert int(params["n0"]) == v.n0
        ckind, cparams = by_name[f"comp_{v.name}"]
        assert ckind == "comp_c"
        assert int(cparams["m_tile"]) == v.m_tile


def test_fused_artifact_params(built):
    _, lines = built
    fused = [l for l in lines if l.startswith("spmm_fused")]
    assert len(fused) == 1
    kvs = dict(kv.split("=") for kv in fused[0].split("\t")[3:])
    assert int(kvs["nwin"]) == aot.FUSED_NWIN


def test_window_hlo_contains_while_loop(built):
    """The PE inner loop must lower to a single HLO while (II=1 pipeline
    analogue) — not an unrolled body, which would blow up artifact size."""
    out, _ = built
    text = open(os.path.join(out, "win_s.hlo.txt")).read()
    assert "while" in text


def test_variants_are_distinct():
    names = [v.name for v in aot.WINDOW_VARIANTS]
    assert len(set(names)) == len(names)
    caps = [(v.nnz_cap, v.k0, v.m_tile) for v in aot.WINDOW_VARIANTS]
    assert len(set(caps)) == len(caps)


def test_variant_dataclass_frozen():
    v = model.Variant("x", 1, 2, 3, 4)
    with pytest.raises(Exception):
        v.nnz_cap = 5  # type: ignore[misc]
