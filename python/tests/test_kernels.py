"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

This is the CORE numeric signal for the whole stack: the rust runtime
executes exactly these kernels (AOT-lowered), so allclose here + HLO
round-trip integration tests on the rust side = end-to-end correctness.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import comp_c, dense_tile, spmm_window
from compile.kernels.ref import (
    ref_comp_c,
    ref_dense_tile,
    ref_spmm_window,
)

RNG = np.random.default_rng(1234)


def random_window(nnz, k0, m, n0, pad_from=None, seed=0):
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, m, nnz).astype(np.int32)
    cols = rng.integers(0, k0, nnz).astype(np.int32)
    vals = rng.standard_normal(nnz).astype(np.float32)
    if pad_from is not None:
        vals[pad_from:] = 0.0
    b = rng.standard_normal((k0, n0)).astype(np.float32)
    c = rng.standard_normal((m, n0)).astype(np.float32)
    return (
        jnp.array(rows),
        jnp.array(cols),
        jnp.array(vals),
        jnp.array(b),
        jnp.array(c),
    )


def assert_window_matches(rows, cols, vals, b, c, rtol=1e-4, atol=1e-4):
    out = spmm_window(rows, cols, vals, b, c)
    ref = ref_spmm_window(rows, cols, vals, b, c)
    np.testing.assert_allclose(out, ref, rtol=rtol, atol=atol)


# ---------------------------------------------------------------- spmm_window


@pytest.mark.parametrize(
    "nnz,k0,m,n0",
    [
        (1, 1, 1, 8),
        (16, 8, 4, 8),
        (64, 32, 16, 8),
        (256, 128, 128, 8),
        (100, 64, 32, 4),
        (32, 16, 8, 16),
    ],
)
def test_window_matches_ref(nnz, k0, m, n0):
    assert_window_matches(*random_window(nnz, k0, m, n0, seed=nnz))


def test_window_all_padding():
    rows, cols, vals, b, c = random_window(32, 16, 8, 8, pad_from=0, seed=7)
    out = spmm_window(rows, cols, vals, b, c)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(c))


def test_window_padding_invariance():
    """Appending zero-valued slots never changes the result."""
    rows, cols, vals, b, c = random_window(48, 32, 16, 8, seed=11)
    base = spmm_window(rows, cols, vals, b, c)
    pad = 16
    rows_p = jnp.concatenate([rows, jnp.zeros(pad, jnp.int32)])
    cols_p = jnp.concatenate([cols, jnp.zeros(pad, jnp.int32)])
    vals_p = jnp.concatenate([vals, jnp.zeros(pad, jnp.float32)])
    padded = spmm_window(rows_p, cols_p, vals_p, b, c)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(padded))


def test_window_raw_conflict_same_row():
    """Every non-zero hits the SAME C row — the worst RAW case the paper's
    OoO scheduler exists to handle. Numerics must still be exact-ish."""
    nnz, k0, m, n0 = 64, 32, 8, 8
    rng = np.random.default_rng(3)
    rows = jnp.full((nnz,), 5, jnp.int32)
    cols = jnp.array(rng.integers(0, k0, nnz), dtype=jnp.int32)
    vals = jnp.array(rng.standard_normal(nnz), dtype=jnp.float32)
    b = jnp.array(rng.standard_normal((k0, n0)), dtype=jnp.float32)
    c = jnp.zeros((m, n0), jnp.float32)
    out = spmm_window(rows, cols, vals, b, c)
    # Sequential accumulation: row 5 = sum of val_t * B[col_t].
    expect = np.zeros((m, n0), np.float32)
    for t in range(nnz):
        expect[5] += float(vals[t]) * np.asarray(b[int(cols[t])])
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-4, atol=1e-5)


def test_window_permutation_invariance_allclose():
    """Out-of-order scheduling permutes the non-zero stream; results must
    agree up to FP reassociation."""
    rows, cols, vals, b, c = random_window(96, 32, 16, 8, seed=13)
    perm = np.random.default_rng(5).permutation(96)
    base = spmm_window(rows, cols, vals, b, c)
    shuf = spmm_window(rows[perm], cols[perm], vals[perm], b, c)
    np.testing.assert_allclose(np.asarray(base), np.asarray(shuf), rtol=1e-4, atol=1e-4)


def test_window_accumulates_into_nonzero_c():
    rows, cols, vals, b, c = random_window(32, 16, 8, 8, seed=17)
    out = spmm_window(rows, cols, vals, b, c)
    out_zero = spmm_window(rows, cols, vals, b, jnp.zeros_like(c))
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(out_zero) + np.asarray(c), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=25, deadline=None)
@given(
    nnz=st.integers(1, 128),
    k0=st.integers(1, 64),
    m=st.integers(1, 64),
    n0=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_window_hypothesis(nnz, k0, m, n0, seed):
    assert_window_matches(*random_window(nnz, k0, m, n0, seed=seed))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), dup_row=st.integers(0, 7))
def test_window_hypothesis_heavy_duplicates(seed, dup_row):
    """Skewed row distribution (power-law-ish worst case)."""
    nnz, k0, m, n0 = 64, 16, 8, 8
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, m, nnz).astype(np.int32)
    rows[rng.random(nnz) < 0.7] = dup_row
    cols = rng.integers(0, k0, nnz).astype(np.int32)
    vals = rng.standard_normal(nnz).astype(np.float32)
    b = rng.standard_normal((k0, n0)).astype(np.float32)
    c = rng.standard_normal((m, n0)).astype(np.float32)
    assert_window_matches(
        jnp.array(rows), jnp.array(cols), jnp.array(vals), jnp.array(b), jnp.array(c)
    )


# --------------------------------------------------------------------- comp_c


@pytest.mark.parametrize(
    "alpha,beta",
    [(1.0, 0.0), (0.0, 1.0), (2.5, -0.5), (0.0, 0.0), (-1.0, 3.0)],
)
def test_comp_c_matches_ref(alpha, beta):
    rng = np.random.default_rng(21)
    c_ab = jnp.array(rng.standard_normal((32, 8)), dtype=jnp.float32)
    c_in = jnp.array(rng.standard_normal((32, 8)), dtype=jnp.float32)
    out = comp_c(c_ab, c_in, jnp.full((1, 1), alpha), jnp.full((1, 1), beta))
    np.testing.assert_allclose(
        np.asarray(out), ref_comp_c(np.asarray(c_ab), np.asarray(c_in), alpha, beta),
        rtol=1e-5, atol=1e-7,  # XLA may contract a*x+b*y into FMAs
    )


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 128),
    n0=st.sampled_from([1, 4, 8]),
    alpha=st.floats(-1e3, 1e3, width=32),
    beta=st.floats(-1e3, 1e3, width=32),
    seed=st.integers(0, 2**31 - 1),
)
def test_comp_c_hypothesis(m, n0, alpha, beta, seed):
    rng = np.random.default_rng(seed)
    c_ab = jnp.array(rng.standard_normal((m, n0)), dtype=jnp.float32)
    c_in = jnp.array(rng.standard_normal((m, n0)), dtype=jnp.float32)
    out = comp_c(c_ab, c_in, jnp.full((1, 1), alpha), jnp.full((1, 1), beta))
    ref = ref_comp_c(np.asarray(c_ab), np.asarray(c_in), np.float32(alpha), np.float32(beta))
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5, atol=1e-3)


# ----------------------------------------------------------------- dense_tile


@pytest.mark.parametrize("m,k,n", [(1, 1, 1), (8, 16, 8), (64, 128, 8), (128, 128, 8)])
def test_dense_tile_matches_ref(m, k, n):
    rng = np.random.default_rng(m * 1000 + k)
    a = jnp.array(rng.standard_normal((m, k)), dtype=jnp.float32)
    b = jnp.array(rng.standard_normal((k, n)), dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(dense_tile(a, b)), np.asarray(ref_dense_tile(a, b)),
        rtol=1e-4, atol=1e-4,
    )


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 64),
    k=st.integers(1, 64),
    n=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_tile_hypothesis(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.array(rng.standard_normal((m, k)), dtype=jnp.float32)
    b = jnp.array(rng.standard_normal((k, n)), dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(dense_tile(a, b)), np.asarray(ref_dense_tile(a, b)),
        rtol=1e-3, atol=1e-3,
    )
