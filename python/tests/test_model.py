"""L2 model tests: the fused tile graph vs the full-SpMM oracle.

Builds the same window decomposition the rust coordinator performs (partition
B rows into K0 windows, compress indices, pad to NNZ_CAP) in numpy, runs the
fused scan artifact function, and checks against ref_spmm_full.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import ref_spmm_full

V = model.Variant("test", nnz_cap=64, k0=16, m_tile=32, n0=8)
NWIN = 4  # K = NWIN * k0


def decompose(rows, cols, vals, variant, nwin):
    """Window decomposition mirroring sextans::sched::partition (rust)."""
    w_rows = np.zeros((nwin, variant.nnz_cap), np.int32)
    w_cols = np.zeros((nwin, variant.nnz_cap), np.int32)
    w_vals = np.zeros((nwin, variant.nnz_cap), np.float32)
    fill = [0] * nwin
    for r, c, v in zip(rows, cols, vals):
        j = c // variant.k0
        t = fill[j]
        assert t < variant.nnz_cap, "window overflow in test data"
        w_rows[j, t] = r
        w_cols[j, t] = c % variant.k0  # compressed column index
        w_vals[j, t] = v
        fill[j] += 1
    return w_rows, w_cols, w_vals


def run_fused(rows, cols, vals, b, c_in, alpha, beta):
    w_rows, w_cols, w_vals = decompose(rows, cols, vals, V, NWIN)
    b_wins = b.reshape(NWIN, V.k0, V.n0)
    fn = model.make_fused_fn(V, NWIN)
    (out,) = jax.jit(fn)(
        jnp.array(w_rows),
        jnp.array(w_cols),
        jnp.array(w_vals),
        jnp.array(b_wins),
        jnp.array(c_in),
        jnp.full((1, 1), alpha, jnp.float32),
        jnp.full((1, 1), beta, jnp.float32),
    )
    return np.asarray(out)


def random_problem(nnz, seed):
    rng = np.random.default_rng(seed)
    k = NWIN * V.k0
    rows = rng.integers(0, V.m_tile, nnz).astype(np.int32)
    cols = rng.integers(0, k, nnz).astype(np.int32)
    vals = rng.standard_normal(nnz).astype(np.float32)
    b = rng.standard_normal((k, V.n0)).astype(np.float32)
    c = rng.standard_normal((V.m_tile, V.n0)).astype(np.float32)
    return rows, cols, vals, b, c


@pytest.mark.parametrize("nnz,alpha,beta", [(50, 1.0, 0.0), (120, 2.0, -1.5), (8, 0.5, 1.0)])
def test_fused_matches_full_oracle(nnz, alpha, beta):
    rows, cols, vals, b, c = random_problem(nnz, seed=nnz)
    got = run_fused(rows, cols, vals, b, c, alpha, beta)
    ref = ref_spmm_full(
        jnp.array(rows), jnp.array(cols), jnp.array(vals), V.m_tile,
        jnp.array(b), jnp.array(c), alpha, beta,
    )
    np.testing.assert_allclose(got, np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_fused_equals_sequential_windows():
    """The scan composition must equal window-by-window calls + comp_c."""
    rows, cols, vals, b, c = random_problem(80, seed=99)
    w_rows, w_cols, w_vals = decompose(rows, cols, vals, V, NWIN)
    b_wins = b.reshape(NWIN, V.k0, V.n0)

    win_fn = jax.jit(model.make_window_fn(V))
    comp_fn = jax.jit(model.make_comp_fn(V))
    acc = jnp.zeros((V.m_tile, V.n0), jnp.float32)
    for j in range(NWIN):
        (acc,) = win_fn(
            jnp.array(w_rows[j]), jnp.array(w_cols[j]), jnp.array(w_vals[j]),
            jnp.array(b_wins[j]), acc,
        )
    (seq,) = comp_fn(
        acc, jnp.array(c), jnp.full((1, 1), 2.0, jnp.float32),
        jnp.full((1, 1), 0.5, jnp.float32),
    )
    fused = run_fused(rows, cols, vals, b, c, 2.0, 0.5)
    np.testing.assert_allclose(fused, np.asarray(seq), rtol=1e-5, atol=1e-5)


def test_empty_problem_is_beta_c():
    rows, cols, vals, b, c = random_problem(1, seed=5)
    vals[:] = 0.0
    got = run_fused(rows, cols, vals, b, c, 3.0, 0.25)
    np.testing.assert_allclose(got, 0.25 * c, rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(nnz=st.integers(1, 200), seed=st.integers(0, 2**31 - 1),
       alpha=st.floats(-4, 4, width=32), beta=st.floats(-4, 4, width=32))
def test_fused_hypothesis(nnz, seed, alpha, beta):
    from hypothesis import assume

    rows, cols, vals, b, c = random_problem(nnz, seed=seed)
    # Skip draws where one window would exceed the variant's slot capacity
    # (the rust coordinator chunks in that case; the test kernel does not).
    counts = np.bincount(cols // V.k0, minlength=NWIN)
    assume(int(counts.max()) <= V.nnz_cap)
    got = run_fused(rows, cols, vals, b, c, alpha, beta)
    ref = ref_spmm_full(
        jnp.array(rows), jnp.array(cols), jnp.array(vals), V.m_tile,
        jnp.array(b), jnp.array(c), np.float32(alpha), np.float32(beta),
    )
    np.testing.assert_allclose(got, np.asarray(ref), rtol=1e-3, atol=1e-3)


def test_specs_match_variant_shapes():
    specs = model.window_specs(V)
    assert specs[0].shape == (V.nnz_cap,)
    assert specs[3].shape == (V.k0, V.n0)
    assert specs[4].shape == (V.m_tile, V.n0)
    fspecs = model.fused_specs(V, NWIN)
    assert fspecs[0].shape == (NWIN, V.nnz_cap)
    assert fspecs[3].shape == (NWIN, V.k0, V.n0)
    cspecs = model.comp_specs(V)
    assert cspecs[2].shape == (1, 1)
