#!/usr/bin/env bash
# Record the SIMD perf trajectory as a before/after snapshot pair:
#
#   BENCH_simd_before.json  — native:8 with SEXTANS_SIMD=scalar (the
#                             portable fallback every host can run)
#   BENCH_simd_after.json   — native:8 with runtime SIMD dispatch (AVX2
#                             on hosts that have it)
#
# then checks the geomean speedup across matched measurement cells
# against the acceptance floor (default 1.5x; override with
# SIMD_TRAJECTORY_MIN, set it to 0 to record without gating — e.g. on a
# host without AVX2, where before == after by construction), and finally
# refreshes BENCH_baseline.json from a full-catalog run so the committed
# baseline is anchored at this revision.
#
# Usage: scripts/record_simd_trajectory.sh [out_dir]   (default: repo root)
set -euo pipefail
cd "$(dirname "$0")/.."

OUT="${1:-.}"
STAMP="${BENCH_TIMESTAMP:-$(git rev-parse --short HEAD 2>/dev/null || echo unknown)}"
MIN="${SIMD_TRAJECTORY_MIN:-1.5}"
RUN=(cargo run --release -p sextans --)

echo "== before: scalar fallback (SEXTANS_SIMD=scalar, native:8) =="
SEXTANS_SIMD=scalar "${RUN[@]}" bench \
  --backend native:8 --name simd_before --out "$OUT" --timestamp "$STAMP"

echo
echo "== after: runtime-dispatched SIMD (native:8) =="
"${RUN[@]}" bench \
  --backend native:8 --name simd_after --out "$OUT" --timestamp "$STAMP" \
  --baseline "$OUT/BENCH_simd_before.json"

# Geomean of after/before across measurement cells. The two snapshots
# run the identical command, so the pretty-JSON "gflops" lines pair up
# positionally.
gf() { grep -oE '"gflops": *[0-9.eE+-]+' "$1" | grep -oE '[0-9.eE+-]+$'; }
GEOMEAN=$(paste <(gf "$OUT/BENCH_simd_after.json") <(gf "$OUT/BENCH_simd_before.json") |
  awk '$2 > 0 { s += log($1 / $2); n++ } END { if (n) printf "%.3f", exp(s / n); else print "nan" }')
echo
echo "simd-vs-scalar geomean speedup: ${GEOMEAN}x (floor ${MIN}x)"
awk -v g="$GEOMEAN" -v m="$MIN" 'BEGIN { exit !(g >= m) }' || {
  echo "FAIL: geomean ${GEOMEAN}x below the ${MIN}x acceptance floor" >&2
  exit 1
}

echo
echo "== refresh BENCH_baseline.json (full catalog) =="
"${RUN[@]}" bench --full --write-baseline --out "$OUT" --timestamp "$STAMP"
