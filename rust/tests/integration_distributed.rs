//! Multi-process integration: the distributed worker fleet end to end.
//!
//! These tests spawn real `sextans worker` processes on loopback (via
//! `CARGO_BIN_EXE_sextans`) and drive them through the `remote:<addr>`
//! backend — the same process topology a production fleet would run, not
//! the in-process worker threads the `net` module tests use. The
//! acceptance contract:
//!
//! - `remote` over ≥ 2 worker processes is **bit-identical** to the
//!   `functional` reference on a schedule-invariant matrix (exactly one
//!   non-zero per row per K0 window, so every schedule accumulates each
//!   row in the same floating-point order), and allclose on general
//!   random matrices across alpha/beta.
//! - Killing a worker process mid-stream triggers re-place + retry: the
//!   answer stays correct (no zeroed rows) and the execution report
//!   carries `retries > 0` / `replaced > 0`.
//! - With `replicas=2`, a kill is absorbed by the surviving replica.

use std::io::{BufRead, BufReader};
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use sextans::backend::{self, PreparedSpmm, SpmmBackend};
use sextans::net::{worker::rpc, Op};
use sextans::prop::assert_allclose;
use sextans::sched::preprocess;
use sextans::sparse::{gen, rng::Rng, Coo};

/// Bound on any single child-process readiness wait.
const READY_TIMEOUT: Duration = Duration::from_secs(20);

/// Read the child's stdout until a line starting with `prefix` appears,
/// bounded by [`READY_TIMEOUT`]. On timeout or stdout EOF (the child
/// died or never became ready) the child is killed and the test panics
/// with whatever it wrote to stderr — a wedged spawn can never strand
/// the suite in a silent infinite wait. Returns the first whitespace
/// token after the prefix plus the live line channel (keep draining it
/// so the child can never block on a full pipe).
fn await_readiness(
    child: &mut Child,
    prefix: &str,
) -> (String, std::sync::mpsc::Receiver<String>) {
    let stdout = child.stdout.take().expect("child stdout is piped");
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        for line in BufReader::new(stdout).lines() {
            let Ok(line) = line else { break };
            if tx.send(line).is_err() {
                break;
            }
        }
    });
    let deadline = Instant::now() + READY_TIMEOUT;
    loop {
        let left = deadline.saturating_duration_since(Instant::now());
        match rx.recv_timeout(left) {
            Ok(line) => {
                if let Some(rest) = line.strip_prefix(prefix) {
                    let token = rest
                        .split_whitespace()
                        .next()
                        .expect("token after the readiness prefix")
                        .to_string();
                    return (token, rx);
                }
            }
            Err(_) => {
                // Timeout, or the child exited before its readiness line.
                let _ = child.kill();
                let mut err = String::new();
                if let Some(stderr) = child.stderr.take() {
                    use std::io::Read;
                    let _ = std::io::BufReader::new(stderr).read_to_string(&mut err);
                }
                let _ = child.wait();
                panic!(
                    "child never printed a {prefix:?} line within {READY_TIMEOUT:?}; \
                     stderr:\n{err}"
                );
            }
        }
    }
}

/// One `sextans worker` child process, killed on drop so a failing test
/// never leaks listeners.
struct WorkerProc {
    child: Child,
    addr: String,
}

impl WorkerProc {
    /// Spawn `sextans worker --addr 127.0.0.1:0 --backend <spec>` and
    /// block (bounded) until it prints its readiness line, returning the
    /// bound address scraped from it.
    fn spawn(backend_spec: &str) -> WorkerProc {
        let mut child = Command::new(env!("CARGO_BIN_EXE_sextans"))
            .args(["worker", "--addr", "127.0.0.1:0", "--backend", backend_spec])
            .stdout(Stdio::piped())
            .stderr(Stdio::piped())
            .spawn()
            .expect("spawn sextans worker");
        let (addr, lines) = await_readiness(&mut child, "worker listening on ");
        // Keep draining stdout and stderr so the worker can never block
        // on a full pipe once the test stops reading.
        std::thread::spawn(move || for _line in lines {});
        if let Some(stderr) = child.stderr.take() {
            std::thread::spawn(move || for _line in BufReader::new(stderr).lines() {});
        }
        WorkerProc { child, addr }
    }

    /// Hard-kill the process — the "host died mid-stream" failure mode.
    fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }

    /// Graceful stop: shutdown RPC, bounded wait, then kill as a last
    /// resort so the test never hangs on a wedged worker.
    fn shutdown(&mut self) {
        if let Ok(mut s) = TcpStream::connect(&self.addr) {
            let _ = s.set_read_timeout(Some(Duration::from_secs(5)));
            let _ = s.set_write_timeout(Some(Duration::from_secs(5)));
            let _ = rpc(&mut s, Op::Shutdown, &[]);
        }
        let deadline = Instant::now() + Duration::from_secs(10);
        while Instant::now() < deadline {
            match self.child.try_wait() {
                Ok(Some(_)) => return,
                Ok(None) => std::thread::sleep(Duration::from_millis(20)),
                Err(_) => break,
            }
        }
        self.kill();
    }
}

impl Drop for WorkerProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// A matrix whose SpMM result is schedule-invariant: exactly one
/// non-zero per row per K0 window, so each row accumulates one product
/// per window in window-ascending order no matter how slots are
/// scheduled or rows are sharded — local and distributed execution are
/// bit-identical, not merely allclose.
fn schedule_invariant(m: usize, k: usize, k0: usize, seed: u64) -> Coo {
    let mut rng = Rng::new(seed);
    let windows = k.div_ceil(k0);
    let mut rows = Vec::with_capacity(m * windows);
    let mut cols = Vec::with_capacity(m * windows);
    let mut vals = Vec::with_capacity(m * windows);
    for r in 0..m {
        for w in 0..windows {
            let lo = w * k0;
            let hi = k.min(lo + k0);
            rows.push(r as u32);
            cols.push((lo + rng.index(hi - lo)) as u32);
            vals.push(rng.normal());
        }
    }
    Coo::new(m, k, rows, cols, vals).unwrap()
}

#[test]
fn remote_over_two_worker_processes_matches_functional_bit_for_bit() {
    let mut w1 = WorkerProc::spawn("functional");
    let mut w2 = WorkerProc::spawn("functional");
    let spec = format!("remote:{},{}", w1.addr, w2.addr);

    // Bit-identity on the schedule-invariant construction.
    let k0 = 8;
    let coo = schedule_invariant(48, 32, k0, 0xD157);
    let image = Arc::new(preprocess(&coo, 4, k0, 4));
    let n = 5;
    let mut rng = Rng::new(0xD157 ^ 0xB0B);
    let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
    let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();

    let functional =
        backend::create("functional").unwrap().prepare(Arc::clone(&image)).unwrap();
    let remote = backend::create(&spec).unwrap().prepare(Arc::clone(&image)).unwrap();
    for (alpha, beta) in [(1.0f32, 0.0f32), (2.5, -0.5)] {
        let mut want = c0.clone();
        functional.execute(&b, &mut want, n, alpha, beta).unwrap();
        let mut got = c0.clone();
        let report = remote.execute_with_report(&b, &mut got, n, alpha, beta).unwrap();
        assert_eq!(
            got, want,
            "remote must be bit-identical to functional at alpha={alpha}, beta={beta}"
        );
        let stats = report.remote.expect("remote handle reports fleet stats");
        assert_eq!(stats.workers, 2);
        assert_eq!(stats.live_workers, 2);
        assert_eq!(stats.retries, 0, "healthy fleet must not retry");
        assert_eq!(stats.replaced, 0);
        assert!(stats.placements >= 2, "both shards placed: {stats:?}");
    }

    // Allclose on a general random matrix (schedules may differ).
    let coo = gen::random_uniform(60, 44, 0.15, &mut rng);
    let image = Arc::new(preprocess(&coo, 4, 12, 4));
    let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
    let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
    let functional =
        backend::create("functional").unwrap().prepare(Arc::clone(&image)).unwrap();
    let remote = backend::create(&spec).unwrap().prepare(Arc::clone(&image)).unwrap();
    let mut want = c0.clone();
    functional.execute(&b, &mut want, n, 1.5, -0.25).unwrap();
    let mut got = c0.clone();
    remote.execute(&b, &mut got, n, 1.5, -0.25).unwrap();
    assert_allclose(&got, &want, 2e-4, 2e-4).unwrap();

    w1.shutdown();
    w2.shutdown();
}

#[test]
fn killing_a_worker_mid_stream_replaces_the_shard_and_keeps_the_answer() {
    let mut survivor = WorkerProc::spawn("functional");
    let mut doomed = WorkerProc::spawn("functional");
    // A long heartbeat keeps the background supervisor out of this test:
    // the kill must be discovered by the execute itself (retry +
    // re-place), not raced by a heartbeat-driven rebalance.
    let spec = format!("remote:{},{},heartbeat_ms=60000", survivor.addr, doomed.addr);

    let mut rng = Rng::new(0xFA11);
    let coo = gen::random_uniform(64, 40, 0.2, &mut rng);
    let image = Arc::new(preprocess(&coo, 4, 12, 4));
    let n = 4;
    let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();

    let functional =
        backend::create("functional").unwrap().prepare(Arc::clone(&image)).unwrap();
    let mut want = vec![0.0f32; coo.m * n];
    functional.execute(&b, &mut want, n, 1.0, 0.0).unwrap();

    let remote = backend::create(&spec).unwrap().prepare(Arc::clone(&image)).unwrap();
    // Healthy first call: both workers hold a shard, nothing retries.
    let mut c = vec![0.0f32; coo.m * n];
    let report = remote.execute_with_report(&b, &mut c, n, 1.0, 0.0).unwrap();
    let stats = report.remote.expect("remote stats");
    assert_eq!((stats.retries, stats.replaced), (0, 0), "{stats:?}");
    assert_allclose(&c, &want, 2e-4, 2e-4).unwrap();

    // Kill one worker process outright: its pooled connections die, the
    // next execute must mark it dead, re-place its shard on the
    // survivor (re-preparing it there), retry, and still be right.
    doomed.kill();
    let mut c = vec![0.0f32; coo.m * n];
    let report = remote.execute_with_report(&b, &mut c, n, 1.0, 0.0).unwrap();
    let stats = report.remote.expect("remote stats");
    assert!(stats.retries > 0, "a killed worker must surface as retries: {stats:?}");
    assert!(stats.replaced > 0, "its shard must be re-placed: {stats:?}");
    assert_eq!(stats.live_workers, 1, "{stats:?}");
    assert_allclose(&c, &want, 2e-4, 2e-4)
        .expect("failover answer must be complete — no zeroed rows");

    // The healed placement serves follow-ups without further retries.
    let mut c = vec![0.0f32; coo.m * n];
    let report = remote.execute_with_report(&b, &mut c, n, 1.0, 0.0).unwrap();
    let stats = report.remote.expect("remote stats");
    assert_eq!((stats.retries, stats.replaced), (0, 0), "healed: {stats:?}");
    assert_allclose(&c, &want, 2e-4, 2e-4).unwrap();

    survivor.shutdown();
}

#[test]
fn replicated_placement_absorbs_a_kill_without_replacing() {
    let mut w1 = WorkerProc::spawn("functional");
    let mut w2 = WorkerProc::spawn("functional");
    // heartbeat_ms=60000: see the kill test above — the execute, not the
    // background heartbeat, must absorb the kill deterministically.
    let spec = format!("remote:{},{},replicas=2,heartbeat_ms=60000", w1.addr, w2.addr);

    let mut rng = Rng::new(0x2E91);
    let coo = gen::random_uniform(52, 36, 0.18, &mut rng);
    let image = Arc::new(preprocess(&coo, 4, 12, 4));
    let n = 3;
    let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();

    let functional =
        backend::create("functional").unwrap().prepare(Arc::clone(&image)).unwrap();
    let mut want = vec![0.0f32; coo.m * n];
    functional.execute(&b, &mut want, n, 1.0, 0.0).unwrap();

    let remote = backend::create(&spec).unwrap().prepare(Arc::clone(&image)).unwrap();
    let mut c = vec![0.0f32; coo.m * n];
    let report = remote.execute_with_report(&b, &mut c, n, 1.0, 0.0).unwrap();
    let stats = report.remote.expect("remote stats");
    assert_eq!(stats.replicas, 2);
    assert_allclose(&c, &want, 2e-4, 2e-4).unwrap();

    // Every shard already has a live replica, so a kill costs retries
    // but the answer never needs a fresh placement to be correct.
    w2.kill();
    let mut c = vec![0.0f32; coo.m * n];
    let report = remote.execute_with_report(&b, &mut c, n, 1.0, 0.0).unwrap();
    let stats = report.remote.expect("remote stats");
    assert!(stats.retries > 0, "{stats:?}");
    assert_eq!(stats.live_workers, 1, "{stats:?}");
    assert_allclose(&c, &want, 2e-4, 2e-4).unwrap();

    w1.shutdown();
}
