//! Integration: the SIMD kernel layer's bit-identity contract.
//!
//! The native engines dispatch between an AVX2 path and a portable scalar
//! fallback at runtime; the whole design rests on the two producing the
//! *same bits* (mul + add per contribution, never FMA — see
//! `backend::simd`). This suite pins that contract at the kernel level,
//! across the widths the satellite spec calls out (N ∈ {1, LANES−1,
//! LANES, LANES+1, 3·LANES+7}), degenerate alpha/beta, empty rows, and
//! NaN/inf propagation — and it runs the scalar path explicitly on every
//! host, so both dispatch arms are exercised regardless of the machine's
//! ISA (CI additionally re-runs the whole suite under
//! `SEXTANS_SIMD=scalar` to pin the engine-level toggle).

use std::sync::Arc;

use sextans::arch::functional;
use sextans::backend::simd::{self, Isa, LANES};
use sextans::backend::{NativeBackend, PreparedSpmm, SpmmBackend};
use sextans::prop;
use sextans::sched::preprocess;
use sextans::sparse::{gen, rng::Rng};

/// The satellite's width set: 1, LANES−1, LANES, LANES+1, 3·LANES+7.
const WIDTHS: [usize; 5] = [1, LANES - 1, LANES, LANES + 1, 3 * LANES + 7];

/// Scalar/vector coefficient pairs the spec calls out.
const COEFFS: [(f32, f32); 4] = [(0.0, 1.0), (1.0, 0.0), (-2.5, 1.0), (-2.5, -2.5)];

/// Every ISA this host can actually execute. Scalar is always present, so
/// the fallback arm is exercised on every machine; the AVX2 arm joins in
/// whenever the CPU has it (all of CI's fleet).
fn isas() -> Vec<Isa> {
    let mut v = vec![Isa::Scalar];
    if simd::avx2_available() {
        v.push(Isa::Avx2);
    }
    v
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn axpy_and_comp_c_bit_identical_across_isas_property() {
    prop::check("simd_axpy_comp_c_bit_identity", 0x51D0_0001, 40, |rng| {
        let len = rng.index(4 * LANES + 8);
        let x: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
        let y0: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
        let a = rng.range_f32(-3.0, 3.0);
        let mut want = y0.clone();
        simd::axpy(Isa::Scalar, &mut want, &x, a);
        for isa in isas() {
            let mut got = y0.clone();
            simd::axpy(isa, &mut got, &x, a);
            if bits(&got) != bits(&want) {
                return Err(format!("axpy diverged on {} at len {len}", isa.name()));
            }
        }
        for (alpha, beta) in COEFFS {
            let mut want = y0.clone();
            simd::comp_c(Isa::Scalar, &mut want, &x, alpha, beta);
            for isa in isas() {
                let mut got = y0.clone();
                simd::comp_c(isa, &mut got, &x, alpha, beta);
                if bits(&got) != bits(&want) {
                    return Err(format!(
                        "comp_c diverged on {} at len {len}, alpha {alpha}, beta {beta}",
                        isa.name()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn row_kernels_bit_identical_across_isas_property() {
    prop::check("simd_row_kernel_bit_identity", 0x51D0_0002, 30, |rng| {
        let b_rows = 1 + rng.index(40);
        let nnz = rng.index(60); // 0 = the empty-row case
        let cols: Vec<u32> = (0..nnz).map(|_| rng.index(b_rows) as u32).collect();
        let vals: Vec<f32> = (0..nnz).map(|_| rng.normal()).collect();
        for n in WIDTHS {
            let b: Vec<f32> = (0..b_rows * n).map(|_| rng.normal()).collect();
            for (alpha, beta) in COEFFS {
                if n <= LANES {
                    let c0: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
                    let mut want = c0.clone();
                    simd::row_narrow(Isa::Scalar, &cols, &vals, &b, n, &mut want, alpha, beta);
                    for isa in isas() {
                        let mut got = c0.clone();
                        simd::row_narrow(isa, &cols, &vals, &b, n, &mut got, alpha, beta);
                        if bits(&got) != bits(&want) {
                            return Err(format!(
                                "row_narrow diverged on {} at n {n}, nnz {nnz}",
                                isa.name()
                            ));
                        }
                    }
                }
                // Blocked path: accumulate a random slice, then Comp-C it.
                let col0 = rng.index(n);
                let w = 1 + rng.index(n - col0);
                let mut want_acc = vec![0f32; w];
                simd::row_block(Isa::Scalar, &cols, &vals, &b, n, col0, &mut want_acc);
                for isa in isas() {
                    let mut acc = vec![f32::NAN; w]; // kernel must overwrite
                    simd::row_block(isa, &cols, &vals, &b, n, col0, &mut acc);
                    if bits(&acc) != bits(&want_acc) {
                        return Err(format!(
                            "row_block diverged on {} at n {n}, col0 {col0}, w {w}",
                            isa.name()
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn nan_and_inf_propagate_identically() {
    // Specials must flow through both paths to the same bit patterns —
    // packed and scalar x86 mul/add agree on NaN/inf semantics, and
    // nothing in the kernels may short-circuit them away.
    let n = LANES - 1; // masked narrow path
    let mut b = vec![1.0f32; 4 * n];
    b[0] = f32::NAN;
    b[n] = f32::INFINITY;
    b[2 * n] = f32::NEG_INFINITY;
    let cols = [0u32, 1, 2, 3];
    let vals = [2.0f32, -1.0, 0.5, 3.0];
    let c0: Vec<f32> = (0..n).map(|i| i as f32 - 2.0).collect();
    for (alpha, beta) in [(1.0f32, 1.0f32), (0.0, 1.0), (-2.5, 0.0)] {
        let mut want = c0.clone();
        simd::row_narrow(Isa::Scalar, &cols, &vals, &b, n, &mut want, alpha, beta);
        for isa in isas() {
            let mut got = c0.clone();
            simd::row_narrow(isa, &cols, &vals, &b, n, &mut got, alpha, beta);
            assert_eq!(
                bits(&got),
                bits(&want),
                "row_narrow specials diverged on {} (alpha {alpha}, beta {beta})",
                isa.name()
            );
        }
        let mut want_acc = vec![0f32; n];
        simd::row_block(Isa::Scalar, &cols, &vals, &b, n, 0, &mut want_acc);
        for isa in isas() {
            let mut acc = vec![0f32; n];
            simd::row_block(isa, &cols, &vals, &b, n, 0, &mut acc);
            assert_eq!(
                bits(&acc),
                bits(&want_acc),
                "row_block specials diverged on {}",
                isa.name()
            );
        }
    }
}

#[test]
fn native_engine_matches_functional_bitwise_across_satellite_widths() {
    // End to end through whatever ISA `simd::active()` resolved — under
    // the CI scalar leg this pins the fallback engine, on AVX2 hosts the
    // vector engine; functional is the ISA-independent reference either
    // way.
    let mut rng = Rng::new(0x51D3);
    let a = gen::power_law_rows(140, 110, 2_200, 1.0, &mut rng);
    let sm = Arc::new(preprocess(&a, 8, 32, 6));
    for n in WIDTHS {
        let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..a.m * n).map(|_| rng.normal()).collect();
        for (alpha, beta) in COEFFS {
            let mut want = c0.clone();
            functional::execute(&sm, &b, &mut want, n, alpha, beta);
            for backend in [NativeBackend::new(3), NativeBackend::blocked(3)] {
                let handle = backend.build(Arc::clone(&sm));
                let mut got = c0.clone();
                handle.execute(&b, &mut got, n, alpha, beta).unwrap();
                assert_eq!(
                    bits(&got),
                    bits(&want),
                    "{} != functional at n {n}, alpha {alpha}, beta {beta} (isa {})",
                    backend.name(),
                    simd::active().name()
                );
            }
        }
    }
}
