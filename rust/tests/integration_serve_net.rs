//! Network front door end to end: real sockets, real processes.
//!
//! The acceptance contract for the serving edge:
//!
//! - A result fetched through the loopback front door is **bit-identical**
//!   to the `functional` reference executing the same scheduled image
//!   locally (chunked register, chunked panels, streamed result chunks —
//!   transport must never touch the numbers).
//! - An over-quota burst against one hot image sheds through the
//!   pipeline's admission stage as typed `Shed` frames, surfaces as
//!   `rejected > 0` / `image_sheds > 0` in the metrics summary, and other
//!   images keep completing.
//! - A client killed mid-stream (half a header, half an image upload,
//!   half a panel) costs its own connection only; the server keeps
//!   serving.
//! - Each request's `net.frontend` span parents the pipeline's `request`
//!   span tree, and the `exec` span's duration equals the wire-reported
//!   `exec_ns` exactly.
//! - `sextans loadgen` against a spawned `sextans serve --listen` emits a
//!   parseable schema-v1 `BENCH_serve_*.json`, and `--drain-server`
//!   drains and stops the server cleanly.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use sextans::backend::{self, SpmmBackend};
use sextans::coordinator::metrics::Summary;
use sextans::coordinator::{AdmissionPolicy, BatchPolicy, PipelineConfig};
use sextans::net::wire::{self, Op};
use sextans::sched::preprocess;
use sextans::serve_net::{
    loadgen, proto, FrontClient, FrontDoor, FrontDoorConfig, LoadgenOptions, Mix, ShedReason,
};
use sextans::sparse::{rng::Rng, Coo};
use sextans::telemetry::bench_record::BenchRecord;
use sextans::telemetry::trace::{TelemetrySink, TraceCollector};

const TIMEOUT: Duration = Duration::from_secs(20);

/// Read the child's stdout until a line starting with `prefix` appears,
/// bounded by [`TIMEOUT`]. On timeout or stdout EOF (the child died or
/// never became ready) the child is killed and the test panics with
/// whatever it wrote to stderr — a wedged spawn can never strand the
/// suite in a silent infinite wait. Returns the first whitespace token
/// after the prefix plus the live line channel (keep draining it so the
/// child can never block on a full pipe).
fn await_readiness(
    child: &mut Child,
    prefix: &str,
) -> (String, std::sync::mpsc::Receiver<String>) {
    let stdout = child.stdout.take().expect("child stdout is piped");
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        for line in BufReader::new(stdout).lines() {
            let Ok(line) = line else { break };
            if tx.send(line).is_err() {
                break;
            }
        }
    });
    let deadline = Instant::now() + TIMEOUT;
    loop {
        let left = deadline.saturating_duration_since(Instant::now());
        match rx.recv_timeout(left) {
            Ok(line) => {
                if let Some(rest) = line.strip_prefix(prefix) {
                    let token = rest
                        .split_whitespace()
                        .next()
                        .expect("token after the readiness prefix")
                        .to_string();
                    return (token, rx);
                }
            }
            Err(_) => {
                // Timeout, or the child exited before its readiness line.
                let _ = child.kill();
                let mut err = String::new();
                if let Some(stderr) = child.stderr.take() {
                    use std::io::Read;
                    let _ = std::io::BufReader::new(stderr).read_to_string(&mut err);
                }
                let _ = child.wait();
                panic!(
                    "child never printed a {prefix:?} line within {TIMEOUT:?}; stderr:\n{err}"
                );
            }
        }
    }
}

/// Start an in-process front door on a free loopback port; returns the
/// bound address and the join handle carrying the serving summary.
fn start_door(config: FrontDoorConfig) -> (String, std::thread::JoinHandle<Summary>) {
    let door = FrontDoor::bind("127.0.0.1:0", &config).expect("bind front door");
    let addr = door.local_addr().expect("bound address").to_string();
    let handle = std::thread::spawn(move || door.run(&config).expect("front door run"));
    (addr, handle)
}

/// One `sextans serve --listen` child process, killed on drop so a
/// failing test never leaks listeners.
struct ServeProc {
    child: Child,
    addr: String,
}

impl ServeProc {
    /// Spawn on port 0 and scrape the bound address from the readiness
    /// line.
    fn spawn(extra: &[&str]) -> ServeProc {
        let mut args =
            vec!["serve", "--listen", "127.0.0.1:0", "--backend", "functional", "--workers", "2"];
        args.extend_from_slice(extra);
        let mut child = Command::new(env!("CARGO_BIN_EXE_sextans"))
            .args(&args)
            .stdout(Stdio::piped())
            .stderr(Stdio::piped())
            .spawn()
            .expect("spawn sextans serve");
        let (addr, lines) = await_readiness(&mut child, "serve listening on ");
        // Keep draining stdout and stderr so the server can never block
        // on a full pipe once the test stops reading.
        std::thread::spawn(move || for _line in lines {});
        if let Some(stderr) = child.stderr.take() {
            std::thread::spawn(move || for _line in BufReader::new(stderr).lines() {});
        }
        ServeProc { child, addr }
    }

    /// Bounded wait for a clean exit (the graceful-drain assertion).
    fn wait_for_exit(&mut self) -> std::process::ExitStatus {
        let deadline = Instant::now() + TIMEOUT;
        while Instant::now() < deadline {
            match self.child.try_wait() {
                Ok(Some(status)) => return status,
                Ok(None) => std::thread::sleep(Duration::from_millis(20)),
                Err(e) => panic!("wait on serve process: {e}"),
            }
        }
        panic!("serve process did not exit within {TIMEOUT:?}");
    }
}

impl Drop for ServeProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// A matrix whose SpMM result is schedule-invariant: exactly one
/// non-zero per row per K0 window, so every schedule accumulates each
/// row in the same floating-point order — local and networked execution
/// are bit-identical, not merely allclose.
fn schedule_invariant(m: usize, k: usize, k0: usize, seed: u64) -> Coo {
    let mut rng = Rng::new(seed);
    let windows = k.div_ceil(k0);
    let mut rows = Vec::with_capacity(m * windows);
    let mut cols = Vec::with_capacity(m * windows);
    let mut vals = Vec::with_capacity(m * windows);
    for r in 0..m {
        for w in 0..windows {
            let lo = w * k0;
            let hi = k.min(lo + k0);
            rows.push(r as u32);
            cols.push((lo + rng.index(hi - lo)) as u32);
            vals.push(rng.normal());
        }
    }
    Coo::new(m, k, rows, cols, vals).unwrap()
}

#[test]
fn loopback_front_door_is_bit_identical_to_functional() {
    let mut serve = ServeProc::spawn(&[]);
    let k0 = 8;
    let coo = schedule_invariant(48, 32, k0, 0xF40D);
    let image = Arc::new(preprocess(&coo, 4, k0, 4));
    let n = 5;
    let mut rng = Rng::new(0xF40D ^ 0xB0B);
    let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
    let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();

    let functional =
        backend::create("functional").unwrap().prepare(Arc::clone(&image)).unwrap();

    let mut client = FrontClient::connect(&serve.addr, TIMEOUT).expect("connect front door");
    // 512-byte chunks force a genuinely multi-frame image upload.
    let info = client.register_image(&image, 512).expect("register image");
    assert_eq!((info.m as usize, info.k as usize), (coo.m, coo.k));

    for (alpha, beta) in [(1.0f32, 0.0f32), (2.5, -0.5)] {
        let mut want = c0.clone();
        functional.execute(&b, &mut want, n, alpha, beta).unwrap();
        // col_block 2 streams B/C up and C_out down in [2, 2, 1] blocks.
        let resp = client.call(&info, n, alpha, beta, &b, &c0, 2).expect("front-door call");
        assert!(resp.timing.error.is_none(), "{:?}", resp.timing.error);
        assert!(resp.timing.flops > 0);
        assert_eq!(
            resp.c, want,
            "front door must be bit-identical to functional at alpha={alpha}, beta={beta}"
        );
    }

    client.shutdown_server().expect("shutdown");
    assert!(serve.wait_for_exit().success(), "serve must exit cleanly after Shutdown");
}

#[test]
fn hot_image_burst_sheds_via_admission_while_others_complete() {
    let config = FrontDoorConfig {
        backend_spec: "functional".to_string(),
        workers: 2,
        pipeline: PipelineConfig {
            // One in-flight request per image, and a batch window long
            // enough that a hot image's next arrival always finds its
            // quota taken.
            admission: AdmissionPolicy { max_in_flight: 4096, per_image_quota: 1 },
            batch: BatchPolicy {
                window: Duration::from_millis(30),
                ..PipelineConfig::default().batch
            },
            ..PipelineConfig::default()
        },
        ..FrontDoorConfig::default()
    };
    let (addr, door) = start_door(config);

    let opts = LoadgenOptions {
        addr: addr.clone(),
        rate: 400.0,
        duration: Duration::from_millis(500),
        mix: Mix::Uniform,
        images: 3,
        hot: 0.7,
        m: 64,
        k: 64,
        n: 4,
        nnz: 512,
        seed: 0x407,
        senders: 8,
        timeout: TIMEOUT,
        ..LoadgenOptions::default()
    };
    let report = loadgen::run(&opts).expect("loadgen run");

    assert!(report.completed > 0, "some requests must complete: {report:?}");
    assert!(
        report.sheds[ShedReason::ImageQuota as usize] > 0,
        "the hot image must trip its quota: {report:?}"
    );
    assert!(
        report.completed_by_image.iter().filter(|&&(_, count)| count > 0).count() >= 2,
        "images besides the hot one must keep completing: {:?}",
        report.completed_by_image
    );

    // The server's own metrics agree with the client-observed sheds.
    let mut client = FrontClient::connect(&addr, TIMEOUT).expect("connect for metrics");
    let metrics = client.metrics_json().expect("metrics json");
    assert!(metrics.contains("image_sheds"), "summary JSON carries image_sheds: {metrics}");
    client.shutdown_server().expect("shutdown");
    let summary = door.join().expect("front door thread");
    assert!(summary.rejected > 0, "pipeline admission must have shed: {summary:?}");
    let shed_max =
        summary.image_sheds.iter().map(|&(_, count)| count).max().unwrap_or(0);
    assert!(shed_max > 0, "per-image quota sheds must be attributed: {:?}", summary.image_sheds);
}

#[test]
fn hostile_submit_n_is_refused_without_allocating() {
    let config = FrontDoorConfig {
        backend_spec: "functional".to_string(),
        ..FrontDoorConfig::default()
    };
    let (addr, door) = start_door(config);

    let coo = schedule_invariant(24, 16, 8, 0xB16);
    let image = Arc::new(preprocess(&coo, 4, 8, 4));
    let mut client = FrontClient::connect(&addr, TIMEOUT).expect("connect");
    let info = client.register_image(&image, 4096).expect("register");

    // One small Submit frame asking for ~2^44-element staging panels: if
    // the server tried to honor it, the allocation (tens of TiB) would
    // abort the process — the contract is a typed refusal instead.
    let mut s = TcpStream::connect(&addr).expect("connect raw");
    wire::write_frame(&mut s, Op::Submit, &proto::encode_submit(info.id, 1 << 40, 1.0, 0.0, 0))
        .expect("hostile submit");
    let (op, payload) = wire::read_frame(&mut s).expect("refusal reply");
    assert_eq!(op, Op::Err, "hostile n must be refused, not served");
    let msg = String::from_utf8_lossy(&payload);
    assert!(msg.contains("exceeds"), "refusal names the cap: {msg}");
    drop(s);

    // The refusal cost nothing: the same server still serves real work.
    let n = 3;
    let mut rng = Rng::new(0xB16 ^ 0xB0B);
    let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
    let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
    let resp = client.call(&info, n, 1.0, 0.0, &b, &c0, 0).expect("call after hostile submit");
    assert!(resp.timing.error.is_none(), "{:?}", resp.timing.error);

    client.shutdown_server().expect("shutdown");
    let _ = door.join().expect("front door thread");
}

#[test]
fn killing_a_client_mid_stream_leaves_the_server_serving() {
    let config = FrontDoorConfig {
        backend_spec: "functional".to_string(),
        ..FrontDoorConfig::default()
    };
    let (addr, door) = start_door(config);

    // (a) Die inside the frame header.
    let mut s = TcpStream::connect(&addr).expect("connect");
    s.write_all(b"SX").expect("write half a magic");
    drop(s);

    // (b) Die mid image upload: begin + one chunk, never end.
    let mut s = TcpStream::connect(&addr).expect("connect");
    wire::write_frame(&mut s, Op::RegisterBegin, &proto::encode_register_begin(1_000_000))
        .expect("register begin");
    let (op, payload) = wire::read_frame(&mut s).expect("token reply");
    assert_eq!(op, Op::Ok);
    let token = proto::decode_u64(&payload).expect("token");
    wire::write_frame(
        &mut s,
        Op::RegisterChunk,
        &proto::encode_register_chunk(token, 0, &[0u8; 128]),
    )
    .expect("one chunk");
    let _ = wire::read_frame(&mut s);
    drop(s);

    // (c) Die mid panel upload: a registered image, a submit, one column
    // block, never SubmitEnd.
    let coo = schedule_invariant(24, 16, 8, 0xDEAD);
    let image = Arc::new(preprocess(&coo, 4, 8, 4));
    let n = 4;
    let mut client = FrontClient::connect(&addr, TIMEOUT).expect("connect");
    let info = client.register_image(&image, 4096).expect("register");
    let mut s = TcpStream::connect(&addr).expect("connect");
    wire::write_frame(&mut s, Op::Submit, &proto::encode_submit(info.id, n, 1.0, 0.0, 0))
        .expect("submit");
    let (op, payload) = wire::read_frame(&mut s).expect("ticket reply");
    assert_eq!(op, Op::Ok);
    let ticket = proto::decode_u64(&payload).expect("ticket");
    let b_block = vec![0.25f32; coo.k];
    let c_block = vec![0.5f32; coo.m];
    wire::write_frame(
        &mut s,
        Op::SubmitChunk,
        &proto::encode_submit_chunk(ticket, 0, 1, &b_block, &c_block),
    )
    .expect("one column");
    let _ = wire::read_frame(&mut s);
    drop(s);

    // The server is unbothered: a full register + call still works and
    // is still exact.
    let mut rng = Rng::new(0xDEAD ^ 0xB0B);
    let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
    let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
    let functional =
        backend::create("functional").unwrap().prepare(Arc::clone(&image)).unwrap();
    let mut want = c0.clone();
    functional.execute(&b, &mut want, n, 1.0, 0.0).unwrap();
    let resp = client.call(&info, n, 1.0, 0.0, &b, &c0, 0).expect("call after dead clients");
    assert_eq!(resp.c, want, "survivor requests stay bit-identical");

    client.shutdown_server().expect("shutdown");
    let summary = door.join().expect("front door thread");
    assert!(summary.requests >= 1, "{summary:?}");
}

#[test]
fn frontend_span_parents_the_pipeline_trace_and_reconciles_with_timing() {
    let collector = Arc::new(TraceCollector::new());
    let config = FrontDoorConfig {
        backend_spec: "functional".to_string(),
        pipeline: PipelineConfig {
            sink: Some(Arc::clone(&collector) as Arc<dyn TelemetrySink>),
            ..PipelineConfig::default()
        },
        ..FrontDoorConfig::default()
    };
    let (addr, door) = start_door(config);

    let coo = schedule_invariant(32, 16, 8, 0x59A2);
    let image = Arc::new(preprocess(&coo, 4, 8, 4));
    let n = 3;
    let mut rng = Rng::new(0x59A2 ^ 0xB0B);
    let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
    let c0 = vec![0.0f32; coo.m * n];

    let mut client = FrontClient::connect(&addr, TIMEOUT).expect("connect");
    let info = client.register_image(&image, 4096).expect("register");
    let resp = client.call(&info, n, 1.0, 0.0, &b, &c0, 0).expect("call");
    assert!(resp.timing.error.is_none());

    // The net.frontend span is emitted just after the reply frame is
    // written, so the client can observe the response first — wait.
    let deadline = Instant::now() + TIMEOUT;
    let spans = loop {
        let spans = collector.spans();
        if spans.iter().any(|s| s.name == "net.frontend")
            && spans.iter().any(|s| s.name == "request")
        {
            break spans;
        }
        assert!(Instant::now() < deadline, "spans never arrived: {:?}", collector.spans());
        std::thread::sleep(Duration::from_millis(10));
    };

    let front = spans.iter().find(|s| s.name == "net.frontend").expect("frontend span");
    let request = spans
        .iter()
        .find(|s| s.name == "request" && s.trace_id == front.trace_id)
        .expect("pipeline request root in the same trace");
    assert_eq!(
        request.parent_id,
        Some(front.span_id),
        "the pipeline root must parent under the network edge"
    );
    assert!(
        front.start_ns <= request.start_ns && request.end_ns <= front.end_ns,
        "the frontend span must cover the pipeline: {front:?} vs {request:?}"
    );
    for name in ["admission", "queue", "batch", "prepare", "exec"] {
        let stage = spans
            .iter()
            .find(|s| s.name == name && s.trace_id == front.trace_id)
            .unwrap_or_else(|| panic!("stage span {name} missing from the trace"));
        if name != "admission" {
            assert_eq!(stage.parent_id, Some(request.span_id), "{name} parents the root");
        }
    }
    // Spans and the wire-reported timing are stamped from the same
    // Instants, so they reconcile exactly, not approximately.
    let exec = spans
        .iter()
        .find(|s| s.name == "exec" && s.trace_id == front.trace_id)
        .expect("exec span");
    assert_eq!(
        exec.end_ns - exec.start_ns,
        resp.timing.exec_ns,
        "exec span duration must equal the AwaitOk exec_ns"
    );

    client.shutdown_server().expect("shutdown");
    let _ = door.join().expect("front door thread");
}

#[test]
fn loadgen_cli_emits_schema_v1_bench_and_drains_the_server() {
    let mut serve = ServeProc::spawn(&[]);
    let out_dir = std::env::temp_dir().join(format!("sextans-serve-itest-{}", std::process::id()));
    std::fs::create_dir_all(&out_dir).expect("create out dir");
    let metrics_path = out_dir.join("serve_metrics.json");

    let output = Command::new(env!("CARGO_BIN_EXE_sextans"))
        .args([
            "loadgen",
            "--addr",
            &serve.addr,
            "--rate",
            "40",
            "--duration",
            "0.5",
            "--mix",
            "banded",
            "--images",
            "2",
            "--m",
            "64",
            "--k",
            "64",
            "--n",
            "4",
            "--nnz",
            "512",
            "--name",
            "itest",
            "--out",
            out_dir.to_str().unwrap(),
            "--metrics-json",
            metrics_path.to_str().unwrap(),
            "--drain-server",
        ])
        .output()
        .expect("run sextans loadgen");
    assert!(
        output.status.success(),
        "loadgen failed:\n{}\n{}",
        String::from_utf8_lossy(&output.stdout),
        String::from_utf8_lossy(&output.stderr)
    );

    // The persisted snapshot parses as schema v1 and is a real
    // measurement, not a zeroed placeholder.
    let bench_path = out_dir.join("BENCH_serve_itest.json");
    let record = BenchRecord::read(&bench_path).expect("parse BENCH_serve_itest.json");
    assert_eq!(record.name, "serve_itest");
    assert_eq!(record.results.len(), 5, "one row per stage: {:?}", record.results);
    assert!(!record.is_zeroed(), "a completed run must not look like a placeholder");
    assert!(
        record.results.iter().any(|r| r.bench == "serve/e2e" && r.gflops > 0.0),
        "{:?}",
        record.results
    );
    let metrics = std::fs::read_to_string(&metrics_path).expect("metrics json written");
    assert!(metrics.contains("\"requests\""), "{metrics}");

    // --drain-server shut the server down; the process must exit cleanly.
    assert!(serve.wait_for_exit().success(), "serve must exit cleanly after drain + shutdown");
    let _ = std::fs::remove_dir_all(&out_dir);
}
