//! Integration: HFlex accelerator + serving coordinator working together —
//! one synthesized accelerator serving a heterogeneous request mix, with
//! failure injection (bad shapes, foreign images) leaving the service
//! healthy.

use std::sync::Arc;
use std::time::Duration;

use sextans::arch::AcceleratorConfig;
use sextans::backend::FunctionalBackend;
use sextans::coordinator::{BatchPolicy, Server, SpmmRequest};
use sextans::hflex::{HFlexAccelerator, HFlexError, SpmmProblem};
use sextans::prop::assert_allclose;
use sextans::sched::preprocess;
use sextans::sparse::{gen, rng::Rng};

#[test]
fn hflex_end_to_end_mixed_shapes_and_scalars() {
    let accel = HFlexAccelerator::synthesize(AcceleratorConfig::sextans_u280());
    let mut rng = Rng::new(100);
    // Mixed structures: uniform, banded, power-law, rmat — all on the same
    // accelerator, with varied (alpha, beta).
    let cases: Vec<(sextans::sparse::Coo, usize, f32, f32)> = vec![
        (gen::random_uniform(128, 256, 0.05, &mut rng), 8, 1.0, 0.0),
        (gen::banded(300, 6, 5, &mut rng), 16, 2.0, -1.0),
        (gen::power_law_rows(200, 150, 2_000, 0.8, &mut rng), 4, 0.5, 0.5),
        (gen::rmat(256, 2_048, 0.45, 0.2, 0.2, &mut rng), 32, -1.0, 2.0),
    ];
    for (coo, n, alpha, beta) in cases {
        let loaded = accel.load(&coo).unwrap();
        let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
        let mut c: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
        let mut want = c.clone();
        coo.spmm_reference(&b, &mut want, n, alpha, beta);
        let rep = accel
            .invoke(SpmmProblem { a: &loaded, b: &b, c: &mut c, n, alpha, beta })
            .unwrap();
        assert_allclose(&c, &want, 2e-4, 2e-4).unwrap();
        assert!(rep.sim.cycles > 0);
        assert!(rep.sim.gflops > 0.0);
    }
}

#[test]
fn server_survives_heterogeneous_load() {
    let cfg = AcceleratorConfig::sextans_u280();
    let mut rng = Rng::new(200);
    let m1 = gen::random_uniform(100, 80, 0.1, &mut rng);
    let m2 = gen::banded(150, 4, 3, &mut rng);
    let i1 = Arc::new(preprocess(&m1, cfg.p(), cfg.k0, cfg.d));
    let i2 = Arc::new(preprocess(&m2, cfg.p(), cfg.k0, cfg.d));

    let server = Server::start(
        2,
        BatchPolicy { max_columns: 64, window: Duration::from_millis(2), route_columns: 8 },
        |_| Box::new(FunctionalBackend),
    );
    let h1 = server.register(i1);
    let h2 = server.register(i2);

    let mut expected = Vec::new();
    let mut rxs = Vec::new();
    for i in 0..30 {
        let (h, coo) = if i % 2 == 0 { (h1.clone(), &m1) } else { (h2.clone(), &m2) };
        let n = 1 + (i % 5);
        let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
        let c: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
        let mut want = c.clone();
        coo.spmm_reference(&b, &mut want, n, 1.0, 1.0);
        expected.push(want);
        rxs.push(server.submit(SpmmRequest {
            image: h,
            b,
            c,
            n,
            alpha: 1.0,
            beta: 1.0,
            deadline: None,
        }));
    }
    for (rx, want) in rxs.into_iter().zip(expected) {
        let resp = rx.recv().unwrap();
        assert_allclose(&resp.c, &want, 2e-4, 2e-4).unwrap();
    }
    let s = server.shutdown();
    assert_eq!(s.requests, 30);
}

#[test]
fn failure_injection_wrong_config_is_rejected_cleanly() {
    let accel = HFlexAccelerator::synthesize(AcceleratorConfig::sextans_u280());
    let mut rng = Rng::new(300);
    let coo = gen::random_uniform(64, 64, 0.1, &mut rng);
    // Image for a hypothetical different accelerator generation: refused
    // at load, before any backend residency is built.
    let foreign = Arc::new(preprocess(&coo, 32, 2048, 6));
    let err = accel.load_image(foreign).map(|_| ()).unwrap_err();
    assert!(matches!(err, HFlexError::WrongConfiguration { .. }));
    // The accelerator still works afterwards.
    let b = vec![0f32; 64 * 8];
    let mut c = vec![0f32; 64 * 8];
    let good = accel.load(&coo).unwrap();
    accel
        .invoke(SpmmProblem { a: &good, b: &b, c: &mut c, n: 8, alpha: 1.0, beta: 0.0 })
        .unwrap();
}

#[test]
fn simulated_timing_is_monotone_in_n() {
    let accel = HFlexAccelerator::synthesize(AcceleratorConfig::sextans_u280());
    let mut rng = Rng::new(400);
    let coo = gen::random_uniform(2048, 2048, 0.01, &mut rng);
    let loaded = accel.load(&coo).unwrap();
    let mut prev = 0u64;
    for n in [8usize, 64, 512] {
        let b = vec![0f32; coo.k * n];
        let mut c = vec![0f32; coo.m * n];
        let rep = accel
            .invoke(SpmmProblem { a: &loaded, b: &b, c: &mut c, n, alpha: 1.0, beta: 0.0 })
            .unwrap();
        assert!(rep.sim.cycles > prev, "cycles must grow with N");
        prev = rep.sim.cycles;
    }
}
