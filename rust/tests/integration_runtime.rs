//! Integration: the PJRT runtime executing AOT Pallas kernels vs the
//! functional simulator and the naive oracle — the end-to-end numeric
//! contract of the three-layer stack.
//!
//! Requires `artifacts/` (`make artifacts`). PJRT handles are not Send, so
//! each test thread builds its own Engine (a few hundred ms of compiles).

use sextans::arch::functional;
use sextans::prop::assert_allclose;
use sextans::runtime::{manifest, Engine};
use sextans::sparse::{gen, rng::Rng, Coo};

fn engine() -> Option<Engine> {
    if manifest::default_dir().join("manifest.tsv").exists() {
        Some(Engine::load_default().expect("engine load"))
    } else {
        eprintln!("artifacts/ missing — run `make artifacts`");
        None
    }
}

macro_rules! require_engine {
    () => {
        match engine() {
            Some(e) => e,
            None => return, // environment without artifacts: skip
        }
    };
}

#[test]
fn manifest_lists_expected_artifact_kinds() {
    let e = &require_engine!();
    assert!(e.variants().len() >= 3, "expected >= 3 window variants");
    assert!(e.fused_variant().is_some());
}

#[test]
fn window_kernel_matches_functional_scatter() {
    let e = &require_engine!();
    let v = e.variants()[0];
    let mut rng = Rng::new(1);
    let rows: Vec<i32> = (0..v.nnz_cap).map(|_| rng.index(v.m_tile) as i32).collect();
    let cols: Vec<i32> = (0..v.nnz_cap).map(|_| rng.index(v.k0) as i32).collect();
    let mut vals: Vec<f32> = (0..v.nnz_cap).map(|_| rng.normal()).collect();
    // Pad the tail: padding contract is val == 0.
    for t in v.nnz_cap - 32..v.nnz_cap {
        vals[t] = 0.0;
    }
    let b: Vec<f32> = (0..v.k0 * v.n0).map(|_| rng.normal()).collect();
    let c: Vec<f32> = (0..v.m_tile * v.n0).map(|_| rng.normal()).collect();

    let got = e.run_window(v, &rows, &cols, &vals, &b, &c).unwrap();

    // Host-side sequential scatter in identical order.
    let mut want = c.clone();
    for t in 0..v.nnz_cap {
        let (r, cl, val) = (rows[t] as usize, cols[t] as usize, vals[t]);
        for q in 0..v.n0 {
            want[r * v.n0 + q] += val * b[cl * v.n0 + q];
        }
    }
    assert_allclose(&got, &want, 1e-4, 1e-4).unwrap();
}

#[test]
fn comp_kernel_is_axpby() {
    let e = &require_engine!();
    let v = e.variants()[0];
    let mut rng = Rng::new(2);
    let c_ab: Vec<f32> = (0..v.m_tile * v.n0).map(|_| rng.normal()).collect();
    let c_in: Vec<f32> = (0..v.m_tile * v.n0).map(|_| rng.normal()).collect();
    let got = e.run_comp(v.m_tile, v.n0, &c_ab, &c_in, 2.5, -0.5).unwrap();
    let want: Vec<f32> = c_ab
        .iter()
        .zip(&c_in)
        .map(|(a, b)| 2.5 * a - 0.5 * b)
        .collect();
    assert_allclose(&got, &want, 1e-5, 1e-5).unwrap();
}

#[test]
fn full_spmm_matches_functional_simulator() {
    let e = &require_engine!();
    let mut rng = Rng::new(3);
    let coo = gen::random_uniform(300, 900, 0.02, &mut rng);
    let (v, image) = e.plan(&coo, 4, 10).unwrap();
    let n = 11; // deliberately not a multiple of N0
    let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
    let c_in: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();

    let got = e.spmm(v, &image, &b, &c_in, n, 1.5, -0.25).unwrap();

    let mut want = c_in.clone();
    functional::execute(&image, &b, &mut want, n, 1.5, -0.25);
    assert_allclose(&got, &want, 1e-4, 1e-4).unwrap();

    // And against the naive COO oracle (independent of the image).
    let mut oracle = c_in;
    coo.spmm_reference(&b, &mut oracle, n, 1.5, -0.25);
    assert_allclose(&got, &oracle, 1e-3, 1e-3).unwrap();
}

#[test]
fn spmm_hflex_contract_same_engine_many_shapes() {
    let e = &require_engine!();
    let mut rng = Rng::new(4);
    for (m, k, n) in [(64usize, 64usize, 8usize), (200, 500, 4), (500, 120, 24)] {
        let coo = gen::random_uniform(m, k, 0.05, &mut rng);
        let (v, image) = e.plan(&coo, 4, 10).unwrap();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let c_in = vec![0f32; m * n];
        let got = e.spmm(v, &image, &b, &c_in, n, 1.0, 0.0).unwrap();
        let mut want = vec![0f32; m * n];
        coo.spmm_reference(&b, &mut want, n, 1.0, 0.0);
        assert_allclose(&got, &want, 1e-3, 1e-3).unwrap();
    }
}

#[test]
fn spmm_rejects_mismatched_image() {
    let e = &require_engine!();
    let coo = Coo::empty(64, 64);
    let (v, _) = e.plan(&coo, 4, 10).unwrap();
    // Preprocess with a non-variant window size.
    let bad = sextans::sched::preprocess(&coo, 4, v.k0 + 1, 10);
    let b = vec![0f32; 64 * 8];
    let c = vec![0f32; 64 * 8];
    assert!(e.spmm(v, &bad, &b, &c, 8, 1.0, 0.0).is_err());
}

#[test]
fn fused_artifact_matches_window_composition() {
    let e = &require_engine!();
    let Some((v, nwin)) = e.fused_variant() else { return };
    let mut rng = Rng::new(5);
    let nnz = 600usize;
    let mut rows = vec![0i32; nwin * v.nnz_cap];
    let mut cols = vec![0i32; nwin * v.nnz_cap];
    let mut vals = vec![0f32; nwin * v.nnz_cap];
    let mut fill = vec![0usize; nwin];
    for _ in 0..nnz {
        let w = rng.index(nwin);
        if fill[w] >= v.nnz_cap {
            continue;
        }
        let t = w * v.nnz_cap + fill[w];
        rows[t] = rng.index(v.m_tile) as i32;
        cols[t] = rng.index(v.k0) as i32;
        vals[t] = rng.normal();
        fill[w] += 1;
    }
    let b_wins: Vec<f32> = (0..nwin * v.k0 * v.n0).map(|_| rng.normal()).collect();
    let c_in: Vec<f32> = (0..v.m_tile * v.n0).map(|_| rng.normal()).collect();
    let (alpha, beta) = (1.25f32, 0.75f32);

    let fused = e
        .run_fused(&rows, &cols, &vals, &b_wins, &c_in, alpha, beta)
        .unwrap();

    // Window-by-window + comp composition.
    let mut acc = vec![0f32; v.m_tile * v.n0];
    for w in 0..nwin {
        let s = w * v.nnz_cap;
        acc = e
            .run_window(
                v,
                &rows[s..s + v.nnz_cap],
                &cols[s..s + v.nnz_cap],
                &vals[s..s + v.nnz_cap],
                &b_wins[w * v.k0 * v.n0..(w + 1) * v.k0 * v.n0],
                &acc,
            )
            .unwrap();
    }
    let want = e.run_comp(v.m_tile, v.n0, &acc, &c_in, alpha, beta).unwrap();
    assert_allclose(&fused, &want, 1e-4, 1e-4).unwrap();
}

#[test]
fn dense_tile_matches_host_matmul() {
    let e = &require_engine!();
    let mut rng = Rng::new(6);
    let (m_t, k_t, n_t) = (128usize, 128usize, 8usize);
    let a: Vec<f32> = (0..m_t * k_t).map(|_| rng.normal()).collect();
    let b: Vec<f32> = (0..k_t * n_t).map(|_| rng.normal()).collect();
    let got = e.run_dense(&a, &b).unwrap();
    let mut want = vec![0f32; m_t * n_t];
    for i in 0..m_t {
        for l in 0..k_t {
            let av = a[i * k_t + l];
            for j in 0..n_t {
                want[i * n_t + j] += av * b[l * n_t + j];
            }
        }
    }
    assert_allclose(&got, &want, 1e-3, 1e-3).unwrap();
}
