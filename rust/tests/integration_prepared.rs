//! Integration: the prepare/execute contract itself.
//!
//! * Reuse property: ONE `PreparedSpmm` handle per engine, driven with many
//!   (B, alpha, beta) — **including n changing across calls** — stays equal
//!   to the CSR reference for `native`, `native-blocked`, `functional`, and
//!   `sharded:{1,3,8}:native`.
//! * `execute_batch` equals repeated `execute` on every engine.
//! * Serving e2e: repeated requests against one registered matrix hit the
//!   per-worker prepared-handle cache (hit rate > 0) — the acceptance bar
//!   for prepare-once/execute-many in the coordinator.

use std::sync::Arc;

use sextans::backend::{self, PreparedSpmm, SpmmBackend};
use sextans::coordinator::{BatchPolicy, Server, SpmmRequest};
use sextans::prop::{self, assert_allclose};
use sextans::sched::preprocess;
use sextans::sparse::{gen, rng::Rng, Csr};

const ENGINES: [&str; 6] = [
    "native",
    "native-blocked",
    "functional",
    "sharded:1:native:1",
    "sharded:3:native:1",
    "sharded:8:native:1",
];

#[test]
fn one_prepared_handle_many_calls_matches_reference_property() {
    prop::check("prepared_reuse_vs_reference", 0x9E0A, 10, |rng| {
        let m = 1 + rng.index(80);
        let k = 1 + rng.index(100);
        let a = if rng.chance(0.5) {
            gen::random_uniform(m, k, rng.f64() * 0.25, rng)
        } else {
            gen::power_law_rows(m, k, 1 + rng.index(4 * m), 1.1, rng)
        };
        let p = 1 + rng.index(8);
        let k0 = 1 + rng.index(24);
        let d = 1 + rng.index(8);
        let sm = Arc::new(preprocess(&a, p, k0, d));
        let csr = Csr::from_coo(&a);
        // A shared request schedule: n varies call to call, which is the
        // part per-call engines never had to survive.
        let calls: Vec<(usize, f32, f32)> = (0..5)
            .map(|_| {
                (
                    1 + rng.index(12),
                    rng.range_f32(-2.0, 2.0),
                    rng.range_f32(-2.0, 2.0),
                )
            })
            .collect();
        let inputs: Vec<(Vec<f32>, Vec<f32>)> = calls
            .iter()
            .map(|&(n, _, _)| {
                (
                    (0..k * n).map(|_| rng.normal()).collect(),
                    (0..m * n).map(|_| rng.normal()).collect(),
                )
            })
            .collect();
        for spec in ENGINES {
            let handle = backend::create(spec)
                .map_err(|e| e.to_string())?
                .prepare(Arc::clone(&sm))
                .map_err(|e| format!("{spec}: prepare: {e}"))?;
            for (&(n, alpha, beta), (b, c0)) in calls.iter().zip(&inputs) {
                let mut got = c0.clone();
                handle
                    .execute(b, &mut got, n, alpha, beta)
                    .map_err(|e| format!("{spec} at n={n}: {e}"))?;
                let mut want = c0.clone();
                csr.spmm_reference(b, &mut want, n, alpha, beta);
                assert_allclose(&got, &want, 3e-4, 3e-4)
                    .map_err(|e| format!("{spec} at n={n}, alpha={alpha}, beta={beta}: {e}"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn execute_batch_equals_repeated_execute() {
    let mut rng = Rng::new(0xBA7C);
    let a = gen::power_law_rows(70, 60, 800, 1.0, &mut rng);
    let sm = Arc::new(preprocess(&a, 4, 16, 5));
    let n = 4;
    let bs: Vec<Vec<f32>> =
        (0..3).map(|_| (0..a.k * n).map(|_| rng.normal()).collect()).collect();
    let c0s: Vec<Vec<f32>> =
        (0..3).map(|_| (0..a.m * n).map(|_| rng.normal()).collect()).collect();
    for spec in ENGINES {
        let factory = backend::create(spec).unwrap();
        // Sequential singles on one handle...
        let single = factory.prepare(Arc::clone(&sm)).unwrap();
        let mut want: Vec<Vec<f32>> = c0s.clone();
        for (b, c) in bs.iter().zip(want.iter_mut()) {
            single.execute(b, c, n, 1.5, -0.5).unwrap();
        }
        // ...must equal one execute_batch on a fresh handle.
        let batched = factory.prepare(Arc::clone(&sm)).unwrap();
        let mut got: Vec<Vec<f32>> = c0s.clone();
        {
            let mut jobs: Vec<(&[f32], &mut [f32])> = bs
                .iter()
                .map(|b| b.as_slice())
                .zip(got.iter_mut().map(|c| c.as_mut_slice()))
                .collect();
            batched.execute_batch(&mut jobs, n, 1.5, -0.5).unwrap();
        }
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g, w, "{spec}: batch entry {i} diverged from sequential");
        }
    }
}

#[test]
fn prepare_cost_is_reported_per_engine() {
    let mut rng = Rng::new(0xC057);
    let a = gen::random_uniform(64, 64, 0.1, &mut rng);
    let sm = Arc::new(preprocess(&a, 4, 16, 5));
    // Native keeps decoded triples resident; sharded keeps shard images +
    // inner residency; functional keeps nothing extra.
    let native = backend::create("native:2").unwrap().prepare(Arc::clone(&sm)).unwrap();
    assert!(native.prepare_cost().resident_bytes >= 12 * a.nnz() as u64);
    let sharded =
        backend::create("sharded:2:native:1").unwrap().prepare(Arc::clone(&sm)).unwrap();
    assert!(sharded.prepare_cost().resident_bytes > 0);
    let functional = backend::create("functional").unwrap().prepare(Arc::clone(&sm)).unwrap();
    assert_eq!(functional.prepare_cost().resident_bytes, 0);
}

#[test]
fn serving_e2e_prepared_cache_hit_rate_is_positive() {
    // The acceptance bar: N sequential requests against one registered
    // matrix on one worker — the matrix is sharded/prepared once, and the
    // server's hit-rate metric proves every later request found it
    // resident.
    let mut rng = Rng::new(0x417);
    let coo = gen::power_law_rows(160, 120, 2_500, 1.1, &mut rng);
    let image = Arc::new(preprocess(&coo, 8, 32, 10));
    let server =
        Server::start_backend(1, BatchPolicy::default(), "sharded:3:native:1").unwrap();
    let handle = server.register(image);
    let n = 4;
    for _ in 0..6 {
        let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
        let mut want = c0.clone();
        coo.spmm_reference(&b, &mut want, n, 1.5, 0.5);
        // call() waits per request, so batches never merge and each request
        // is its own cache lookup.
        let resp = server.call(SpmmRequest {
            image: handle.clone(),
            b,
            c: c0,
            n,
            alpha: 1.5,
            beta: 0.5,
            deadline: None,
        });
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_allclose(&resp.c, &want, 2e-4, 2e-4).unwrap();
    }
    let summary = server.shutdown();
    assert_eq!(summary.requests, 6);
    assert_eq!(summary.prepares, 1, "one matrix, one worker: exactly one shard build");
    assert_eq!(summary.prepare_hits, 5);
    assert!(
        summary.prepare_hit_rate > 0.0,
        "hit rate must be positive, got {}",
        summary.prepare_hit_rate
    );
    assert!(summary.shard_execs >= 1);
}
