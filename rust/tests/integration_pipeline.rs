//! Integration: the adaptive serving pipeline end to end — shard-aware
//! routed batching (small-N requests execute on a shard subset with
//! results identical to the functional backend), re-shard-on-skew (a
//! skewed workload triggers exactly one rebuild and results stay
//! deterministic afterwards), the per-stage latency breakdown, admission
//! backpressure, and the shared-handle concurrency contract (N threads ×
//! one `Arc<dyn PreparedSpmm>` handle, bit-identical to the functional
//! reference, with the scratch pool bounded by the thread count).

use std::sync::Arc;
use std::time::Duration;

use sextans::backend::{FunctionalBackend, PreparedSpmm, SpmmBackend};
use sextans::coordinator::{
    AdmissionPolicy, BatchPolicy, PipelineConfig, ReshardPolicy, Server, SpmmRequest,
};
use sextans::prop::assert_allclose;
use sextans::sched::preprocess;
use sextans::sparse::{rng::Rng, Coo};

/// A matrix whose non-zeros live in only 4 of 40 rows: over 8 shards the
/// LPT planner gives each non-empty row its own shard, leaving 4 shards
/// with nothing to compute. Each row holds exactly one non-zero per
/// K0 = 8 window, so every schedule accumulates a row's contributions in
/// the same (window-ascending) order — results are bit-identical across
/// sharded, routed, and whole-image execution.
fn sparse_rows_matrix() -> Coo {
    let (m, k) = (40usize, 24usize);
    let mut rows = Vec::new();
    let mut cols = Vec::new();
    let mut vals = Vec::new();
    for r in 0..4u32 {
        for w in 0..3u32 {
            rows.push(r);
            cols.push(w * 8 + r);
            vals.push(0.5 + r as f32 - 0.25 * w as f32);
        }
    }
    Coo::new(m, k, rows, cols, vals).unwrap()
}

/// One extreme row plus 70 light rows: nnz imbalance 4.0 at S = 8 (one
/// shard holds half the work), 2.0 at S = 4 — so a threshold of 2.5
/// triggers exactly one halving.
fn skewed_matrix() -> Coo {
    let k = 800usize;
    let mut rows = Vec::new();
    let mut cols = Vec::new();
    let mut vals = Vec::new();
    for j in 0..700u32 {
        rows.push(0);
        cols.push(j);
        vals.push(0.01 + (j % 7) as f32 * 0.1);
    }
    for r in 1..=70u32 {
        for j in 0..10u32 {
            rows.push(r);
            cols.push((r * 7 + j * 13) % k as u32);
            vals.push(0.2 + (r % 5) as f32 * 0.05);
        }
    }
    Coo::new(71, k, rows, cols, vals).unwrap()
}

fn vecs(coo: &Coo, n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
    let c: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
    (b, c)
}

#[test]
fn small_n_requests_execute_on_a_shard_subset() {
    let coo = sparse_rows_matrix();
    let image = Arc::new(preprocess(&coo, 4, 8, 4));

    // Reference: the functional backend on the unsharded image.
    let reference = FunctionalBackend.prepare(Arc::clone(&image)).unwrap();

    let config = PipelineConfig {
        batch: BatchPolicy {
            max_columns: 512,
            window: Duration::from_millis(2),
            route_columns: 4,
        },
        ..PipelineConfig::default()
    };
    let server = Server::start_backend_with(1, config, "sharded:8:functional").unwrap();
    let handle = server.register(Arc::clone(&image));

    let n = 2; // <= route_columns: dispatched through the routed path
    let requests = 3;
    for i in 0..requests {
        let (b, c0) = vecs(&coo, n, 100 + i);
        let mut want = c0.clone();
        reference.execute(&b, &mut want, n, 1.5, -0.5).unwrap();
        let resp = server.call(SpmmRequest {
            image: handle.clone(),
            b,
            c: c0,
            n,
            alpha: 1.5,
            beta: -0.5,
            deadline: None,
        });
        assert!(resp.error.is_none(), "{:?}", resp.error);
        // Same engine per shard, complete rows per shard: the routed
        // sharded result must equal the functional reference exactly.
        assert_eq!(resp.c, want, "routed subset must match the functional backend");
        let mut coo_want = vecs(&coo, n, 100 + i).1;
        coo.spmm_reference(&vecs(&coo, n, 100 + i).0, &mut coo_want, n, 1.5, -0.5);
        assert_allclose(&resp.c, &coo_want, 2e-4, 2e-4).unwrap();
    }
    // A wide request stays on the unrouted path.
    let n_wide = 16;
    let (b, c0) = vecs(&coo, n_wide, 999);
    let resp = server.call(SpmmRequest {
        image: handle.clone(),
        b,
        c: c0,
        n: n_wide,
        alpha: 1.5,
        beta: -0.5,
        deadline: None,
    });
    assert!(resp.error.is_none());

    let summary = server.shutdown();
    assert_eq!(summary.requests, requests as usize + 1);
    assert_eq!(summary.routed_jobs, requests as usize, "small-N jobs route");
    // 4 non-empty rows over 8 shards: every routed execution skips the 4
    // shards that own no non-zeros.
    assert_eq!(summary.shards_skipped, 4 * requests as usize);
    assert_eq!(summary.prepares, 1, "routing reuses the one resident pool");
}

#[test]
fn routed_and_unrouted_paths_are_bit_identical() {
    let coo = sparse_rows_matrix();
    let image = Arc::new(preprocess(&coo, 4, 8, 4));
    let n = 2;
    let (b, c0) = vecs(&coo, n, 7);
    let mut results = Vec::new();
    for route_columns in [4usize, 0] {
        let config = PipelineConfig {
            batch: BatchPolicy {
                max_columns: 512,
                window: Duration::from_millis(2),
                route_columns,
            },
            ..PipelineConfig::default()
        };
        let server = Server::start_backend_with(1, config, "sharded:8:native:1").unwrap();
        let handle = server.register(Arc::clone(&image));
        let resp = server.call(SpmmRequest {
            image: handle,
            b: b.clone(),
            c: c0.clone(),
            n,
            alpha: 2.0,
            beta: 0.75,
            deadline: None,
        });
        assert!(resp.error.is_none(), "{:?}", resp.error);
        let summary = server.shutdown();
        if route_columns > 0 {
            assert_eq!(summary.routed_jobs, 1);
            assert_eq!(summary.shards_skipped, 4);
        } else {
            assert_eq!(summary.routed_jobs, 0);
        }
        results.push(resp.c);
    }
    assert_eq!(
        results[0], results[1],
        "skipping empty shards must not change a single bit"
    );
}

#[test]
fn skewed_workload_triggers_exactly_one_reshard() {
    let coo = skewed_matrix();
    let image = Arc::new(preprocess(&coo, 4, 64, 4));
    let config = PipelineConfig {
        batch: BatchPolicy {
            max_columns: 512,
            window: Duration::from_millis(2),
            route_columns: 0, // isolate resharding from routing
        },
        reshard: ReshardPolicy { imbalance_threshold: 2.5, window: 4 },
        ..PipelineConfig::default()
    };
    let server = Server::start_backend_with(1, config, "sharded:8:native:1").unwrap();
    let handle = server.register(Arc::clone(&image));

    let n = 3;
    let (b, c0) = vecs(&coo, n, 21);
    let mut want = c0.clone();
    coo.spmm_reference(&b, &mut want, n, 1.25, 0.5);

    // 12 identical sequential requests: executions 1-4 run at S=8 (mean
    // imbalance 4.0 > 2.5 -> rebuild after the 4th), 5-12 at S=4 (mean
    // 2.0 < 2.5 -> no second rebuild).
    let mut responses = Vec::new();
    for _ in 0..12 {
        let resp = server.call(SpmmRequest {
            image: handle.clone(),
            b: b.clone(),
            c: c0.clone(),
            n,
            alpha: 1.25,
            beta: 0.5,
            deadline: None,
        });
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_allclose(&resp.c, &want, 2e-4, 2e-4).unwrap();
        responses.push(resp.c);
    }
    let summary = server.shutdown();
    assert_eq!(summary.reshards, 1, "exactly one rebuild");
    assert_eq!(summary.last_reshard, Some((8, 4)));
    assert_eq!(summary.requests, 12);
    // The rebuild happened mid-stream: mean shard count sits strictly
    // between the old and new S.
    assert!(
        summary.mean_shards > 4.0 && summary.mean_shards < 8.0,
        "mean shards {} must reflect 8-shard and 4-shard executions",
        summary.mean_shards
    );
    // Determinism around the rebuild: identical requests produce
    // bit-identical results within each residency generation.
    for c in &responses[1..4] {
        assert_eq!(responses[0], *c, "pre-rebuild responses must be bit-identical");
    }
    for c in &responses[5..] {
        assert_eq!(responses[4], *c, "post-rebuild responses must be bit-identical");
    }
}

#[test]
fn stage_breakdown_decomposes_request_latency() {
    let coo = sparse_rows_matrix();
    let image = Arc::new(preprocess(&coo, 4, 8, 4));
    let server = Server::start(2, BatchPolicy::default(), |_| Box::new(FunctionalBackend));
    let handle = server.register(image);
    let n = 4;
    for i in 0..5 {
        let (b, c0) = vecs(&coo, n, 300 + i);
        let resp = server.call(SpmmRequest {
            image: handle.clone(),
            b,
            c: c0,
            n,
            alpha: 1.0,
            beta: 0.0,
            deadline: None,
        });
        assert!(resp.error.is_none());
        // The four stages decompose each request's end-to-end latency.
        let t = resp.timing;
        assert_eq!(t.total(), t.queue + t.batch + t.prepare + t.exec);
    }
    let summary = server.shutdown();
    assert_eq!(summary.requests, 5);
    for (name, v) in [
        ("queue", summary.stage_queue_s),
        ("batch", summary.stage_batch_s),
        ("prepare", summary.stage_prepare_s),
        ("exec", summary.stage_exec_s),
    ] {
        assert!(v.is_finite() && v >= 0.0, "stage {name} = {v}");
    }
    assert!(summary.stage_exec_s > 0.0, "execution must take measurable time");
    let stage_sum = summary.stage_queue_s
        + summary.stage_batch_s
        + summary.stage_prepare_s
        + summary.stage_exec_s;
    let mean_latency = summary.sum_latency_s / summary.requests as f64;
    assert!(
        (stage_sum - mean_latency).abs() <= 1e-9 + 1e-6 * mean_latency,
        "stage means ({stage_sum}) must sum to the mean latency ({mean_latency})"
    );
}

/// The tentpole's acceptance test: N threads share ONE prepared handle
/// (`Arc<dyn PreparedSpmm + Send + Sync>`, no mutex) across every
/// shareable engine, each thread running many executes with varying
/// inputs; every result must be bit-identical to the functional reference
/// on the same image. Any data race in the &self execution path (scratch
/// aliasing, stream corruption, pool mix-ups) shows up as a wrong bit
/// here.
#[test]
fn n_threads_one_shared_handle_bit_identical_to_functional() {
    let mut rng = Rng::new(0x5EED);
    let coo = {
        // A power-law-ish matrix with empty rows mixed in.
        let (m, k) = (96usize, 72usize);
        let mut rows = Vec::new();
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        for i in 0..1_400u32 {
            let r = (i * i * 37 + i * 11) % (m as u32);
            if r % 5 == 4 {
                continue; // leave some rows empty
            }
            rows.push(r);
            cols.push((i * 53 + 7) % (k as u32));
            vals.push(0.1 + ((i % 13) as f32) * 0.17 - ((i % 7) as f32) * 0.09);
        }
        Coo::new(m, k, rows, cols, vals).unwrap()
    };
    let image = Arc::new(preprocess(&coo, 4, 16, 6));
    let functional = FunctionalBackend.prepare(Arc::clone(&image)).unwrap();

    // Shared request schedule: every thread replays the same calls.
    let calls: Vec<(usize, f32, f32)> =
        vec![(3, 1.5, -0.5), (1, 2.0, 0.0), (7, -0.75, 1.25), (3, 1.5, -0.5)];
    let inputs: Vec<(Vec<f32>, Vec<f32>)> = calls
        .iter()
        .map(|&(n, _, _)| {
            (
                (0..coo.k * n).map(|_| rng.normal()).collect(),
                (0..coo.m * n).map(|_| rng.normal()).collect(),
            )
        })
        .collect();
    let functional_wants: Vec<Vec<f32>> = calls
        .iter()
        .zip(&inputs)
        .map(|(&(n, alpha, beta), (b, c0))| {
            let mut want = c0.clone();
            functional.execute(b, &mut want, n, alpha, beta).unwrap();
            want
        })
        .collect();

    let threads = 6;
    for spec in ["native:2", "native-blocked:2", "functional", "sharded:3:native:1"] {
        let shared: Arc<dyn PreparedSpmm + Send + Sync> = Arc::from(
            sextans::backend::create(spec).unwrap().prepare_send(Arc::clone(&image)).unwrap(),
        );
        // The engine's own serial answers, computed on the SAME handle
        // before any concurrency: every concurrent result must match
        // these bitwise — the determinism half of the contract.
        let serial_wants: Vec<Vec<f32>> = calls
            .iter()
            .zip(&inputs)
            .map(|(&(n, alpha, beta), (b, c0))| {
                let mut want = c0.clone();
                shared.execute(b, &mut want, n, alpha, beta).unwrap();
                want
            })
            .collect();
        // Correctness half: native and native-blocked are documented
        // bit-identical to the functional reference on the same image;
        // sharded reschedules rows per shard, so it matches within FP
        // tolerance instead.
        for (i, (serial, func)) in serial_wants.iter().zip(&functional_wants).enumerate() {
            if spec.starts_with("sharded") {
                assert_allclose(serial, func, 3e-4, 3e-4)
                    .unwrap_or_else(|e| panic!("{spec} call {i}: {e}"));
            } else {
                assert_eq!(serial, func, "{spec} call {i} must match functional bitwise");
            }
        }
        std::thread::scope(|s| {
            for t in 0..threads {
                let shared = Arc::clone(&shared);
                let calls = &calls;
                let inputs = &inputs;
                let serial_wants = &serial_wants;
                s.spawn(move || {
                    for round in 0..10 {
                        // Threads walk the schedule at different offsets so
                        // different (n, alpha, beta) genuinely overlap.
                        let i = (t + round) % calls.len();
                        let (n, alpha, beta) = calls[i];
                        let (b, c0) = &inputs[i];
                        let mut c = c0.clone();
                        shared.execute(b, &mut c, n, alpha, beta).unwrap();
                        assert_eq!(
                            c, serial_wants[i],
                            "{spec}: thread {t} round {round} diverged under concurrency"
                        );
                    }
                });
            }
        });
    }
}

/// Sizing contract of the pooled scratch: W concurrent executors against
/// one shared handle leave at most W scratch sets in its pool — residency
/// never balloons past the realized concurrency.
#[test]
fn shared_handle_scratch_pool_is_bounded_by_worker_count() {
    use sextans::backend::NativeBackend;
    let mut rng = Rng::new(0xB0BB);
    let coo = {
        let (m, k) = (64usize, 48usize);
        let mut rows = Vec::new();
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        for i in 0..900u32 {
            rows.push((i * 31 + 3) % (m as u32));
            cols.push((i * 17 + 5) % (k as u32));
            vals.push(1.0 + (i % 9) as f32 * 0.25);
        }
        Coo::new(m, k, rows, cols, vals).unwrap()
    };
    let image = Arc::new(preprocess(&coo, 4, 16, 4));
    let handle = NativeBackend::new(2).build(Arc::clone(&image));
    let n = 5;
    let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
    let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
    let workers = 4;
    std::thread::scope(|s| {
        for _ in 0..workers {
            let b = &b;
            let c0 = &c0;
            let handle = &handle;
            s.spawn(move || {
                for _ in 0..50 {
                    let mut c = c0.clone();
                    handle.execute(b, &mut c, n, 1.0, 0.5).unwrap();
                }
            });
        }
    });
    let sets = handle.scratch_sets();
    assert!(
        (1..=workers).contains(&sets),
        "pool holds {sets} sets for {workers} concurrent executors"
    );
}

#[test]
fn admission_backpressure_sheds_and_recovers() {
    let coo = sparse_rows_matrix();
    let image = Arc::new(preprocess(&coo, 4, 8, 4));
    let config = PipelineConfig {
        admission: AdmissionPolicy { max_in_flight: 0, ..AdmissionPolicy::default() },
        ..PipelineConfig::default()
    };
    let server = Server::start_with(1, config, |_| Box::new(FunctionalBackend));
    let handle = server.register(image);
    let n = 2;
    let (b, c0) = vecs(&coo, n, 55);
    let resp = server.call(SpmmRequest {
        image: handle.clone(),
        b,
        c: c0,
        n,
        alpha: 1.0,
        beta: 0.0,
        deadline: None,
    });
    let err = resp.error.expect("a zero-depth gate rejects everything");
    assert!(err.contains("admission rejected"), "{err}");
    let summary = server.shutdown();
    assert_eq!(summary.rejected, 1);
    assert_eq!(summary.requests, 0);
}
