//! Integration: the pluggable execution-backend subsystem under the
//! two-phase prepare/execute contract.
//!
//! The core contract — native backend == functional simulator == CSR
//! reference on arbitrary COO matrices, driven through prepared handles —
//! plus registry selection and the coordinator serving correct results
//! through a named backend with no artifacts directory present (the HFlex
//! §3.4 promise held by pure-rust execution).

use std::sync::Arc;
use std::time::Duration;

use sextans::backend::{
    self, BackendError, FunctionalBackend, NativeBackend, PreparedSpmm, SpmmBackend,
};
use sextans::coordinator::{BatchPolicy, Server, SpmmRequest};
use sextans::prop::{self, assert_allclose};
use sextans::sched::{preprocess, ScheduledMatrix};
use sextans::sparse::{gen, rng::Rng, Coo, Csr};

/// One-shot a backend over a fresh copy of `c0` and return the result.
fn run(
    backend: &dyn SpmmBackend,
    sm: &Arc<ScheduledMatrix>,
    b: &[f32],
    c0: &[f32],
    n: usize,
    alpha: f32,
    beta: f32,
) -> Vec<f32> {
    let mut c = c0.to_vec();
    backend.execute_once(sm, b, &mut c, n, alpha, beta).unwrap();
    c
}

#[test]
fn native_equals_functional_equals_csr_reference_property() {
    prop::check("backend_three_way_agreement", 0xBAC4E7D, 20, |rng| {
        // Small K0 so most matrices span several B windows; occasional
        // zero-density draws give fully empty rows.
        let m = 1 + rng.index(90);
        let k = 1 + rng.index(120);
        let n = 1 + rng.index(10);
        let density = rng.f64() * 0.25;
        let a = gen::random_uniform(m, k, density, rng);
        let p = 1 + rng.index(8);
        let k0 = 1 + rng.index(24);
        let d = 1 + rng.index(10);
        let sm = Arc::new(preprocess(&a, p, k0, d));
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let threads = 1 + rng.index(6);
        let csr = Csr::from_coo(&a);
        // One prepared handle per engine, driven across every scalar pair —
        // the reuse contract is part of what's under test.
        let native = NativeBackend::new(threads).prepare(Arc::clone(&sm)).unwrap();
        let functional = FunctionalBackend.prepare(Arc::clone(&sm)).unwrap();
        for (alpha, beta) in [(0.0f32, 1.0f32), (1.0, 0.0), (2.5, 2.5), (1.0, 2.5)] {
            let mut got_native = c0.clone();
            native.execute(&b, &mut got_native, n, alpha, beta).unwrap();
            let mut got_functional = c0.clone();
            functional.execute(&b, &mut got_functional, n, alpha, beta).unwrap();
            if got_native != got_functional {
                return Err(format!(
                    "native (threads={threads}) != functional bitwise at alpha={alpha}, \
                     beta={beta}"
                ));
            }
            let mut reference = c0.clone();
            csr.spmm_reference(&b, &mut reference, n, alpha, beta);
            assert_allclose(&got_native, &reference, 2e-4, 2e-4)
                .map_err(|e| format!("native vs CSR at alpha={alpha}, beta={beta}: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn agreement_with_empty_rows_and_multi_window_matrix() {
    // Explicit construction: K spans 4 B windows (k0 = 16, k = 60), rows
    // 1, 3 and the whole tail beyond row 5 are empty.
    let rows = vec![0u32, 0, 2, 2, 2, 4, 5, 5];
    let cols = vec![0u32, 17, 3, 33, 59, 48, 16, 31];
    let vals = vec![1.5f32, -2.0, 0.5, 3.0, -1.0, 2.5, -0.5, 1.0];
    let a = Coo::new(9, 60, rows, cols, vals).unwrap();
    let sm = Arc::new(preprocess(&a, 4, 16, 6));
    assert!(sm.num_windows >= 4, "test matrix must span several windows");

    let mut rng = Rng::new(7);
    let n = 5;
    let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
    let c0: Vec<f32> = (0..a.m * n).map(|_| rng.normal()).collect();
    let csr = Csr::from_coo(&a);
    for (alpha, beta) in [(0.0f32, 0.0f32), (0.0, 1.0), (1.0, 1.0), (2.5, 0.0), (2.5, 2.5)] {
        let native = run(&NativeBackend::new(4), &sm, &b, &c0, n, alpha, beta);
        let functional = run(&FunctionalBackend, &sm, &b, &c0, n, alpha, beta);
        assert_eq!(native, functional, "alpha={alpha} beta={beta}");
        let mut reference = c0.clone();
        csr.spmm_reference(&b, &mut reference, n, alpha, beta);
        assert_allclose(&native, &reference, 1e-4, 1e-4).unwrap();
    }
}

#[test]
fn registry_constructs_all_backends_by_name() {
    let names: Vec<&str> = backend::registry().iter().map(|b| b.name).collect();
    assert_eq!(
        names,
        ["native", "native-blocked", "functional", "pjrt", "sharded"]
    );
    for name in names {
        assert_eq!(backend::create(name).unwrap().name(), name);
    }
    assert!(matches!(backend::create("verilog"), Err(BackendError::Unknown(_))));
}

#[test]
fn coordinator_serves_native_backend_without_artifacts() {
    // The acceptance headline: a clean checkout (no artifacts/) serves
    // correct SpMMs through the name-selected native backend. The registry
    // must advertise native as executable in every build; the request below
    // proves it end to end.
    let native_info = backend::registry()
        .into_iter()
        .find(|b| b.name == "native")
        .expect("native must be registered");
    assert!(native_info.available, "native must execute in every build");
    let mut rng = Rng::new(11);
    let coo = gen::random_uniform(120, 90, 0.1, &mut rng);
    let image = Arc::new(preprocess(&coo, 8, 32, 10));
    let server = Server::start_backend(
        2,
        BatchPolicy { max_columns: 64, window: Duration::from_millis(2), route_columns: 8 },
        "native:2",
    )
    .unwrap();
    let handle = server.register(image);
    let n = 6;
    let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
    let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
    let mut want = c0.clone();
    coo.spmm_reference(&b, &mut want, n, 1.25, -0.75);
    let resp = server.call(SpmmRequest {
        image: handle,
        b,
        c: c0,
        n,
        alpha: 1.25,
        beta: -0.75,
        deadline: None,
    });
    assert!(resp.error.is_none());
    assert_allclose(&resp.c, &want, 2e-4, 2e-4).unwrap();
    assert_eq!(resp.timing.backend, "native");
    let summary = server.shutdown();
    assert_eq!(summary.requests, 1);
    assert_eq!(summary.backends, vec![("native", 1)]);
    assert_eq!(summary.prepares, 1, "the image became resident exactly once");
}

#[test]
fn server_refuses_unavailable_backend_at_startup() {
    // Without the real PJRT engine the registry marks pjrt unavailable,
    // and the server must refuse at startup instead of zero-filling
    // responses.
    if backend::registry().iter().any(|b| b.name == "pjrt" && b.available) {
        return; // real-engine build: nothing to assert here
    }
    let err = Server::start_backend(1, BatchPolicy::default(), "pjrt")
        .map(|_| ())
        .unwrap_err();
    assert!(matches!(err, BackendError::Unavailable(_)), "{err}");
    // Wrapping the unavailable engine in a sharded composite must not
    // smuggle it past the startup gate.
    let err = Server::start_backend(1, BatchPolicy::default(), "sharded:2:pjrt")
        .map(|_| ())
        .unwrap_err();
    assert!(matches!(err, BackendError::Unavailable(_)), "{err}");
}

#[test]
fn server_rejects_unknown_backend_spec() {
    let err = Server::start_backend(1, BatchPolicy::default(), "asic")
        .map(|_| ())
        .unwrap_err();
    assert!(matches!(err, BackendError::Unknown(_)));
}

#[test]
fn capabilities_identify_the_engines() {
    let native = NativeBackend::new(3);
    assert_eq!(native.capability().threads, 3);
    assert_eq!(native.capability().simd_lanes, 8);
    assert!(!native.capability().requires_artifacts);
    let functional = FunctionalBackend;
    assert_eq!(functional.capability().threads, 1);
    let pjrt = backend::create("pjrt").unwrap();
    assert!(pjrt.capability().requires_artifacts);
}

#[test]
fn prepare_reports_cost_and_handles_survive_dropping_the_factory() {
    let mut rng = Rng::new(13);
    let coo = gen::random_uniform(60, 50, 0.15, &mut rng);
    let sm = Arc::new(preprocess(&coo, 4, 16, 6));
    let handle = {
        // The factory can go away; the handle owns its residency.
        let factory = backend::create("native:2").unwrap();
        factory.prepare(Arc::clone(&sm)).unwrap()
    };
    let cost = handle.prepare_cost();
    assert!(cost.resident_bytes > 0);
    let n = 4;
    let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
    let mut c = vec![0f32; coo.m * n];
    handle.execute(&b, &mut c, n, 1.0, 0.0).unwrap();
    let mut want = vec![0f32; coo.m * n];
    coo.spmm_reference(&b, &mut want, n, 1.0, 0.0);
    assert_allclose(&c, &want, 2e-4, 2e-4).unwrap();
}
