//! Integration: the sharded multi-accelerator execution subsystem under
//! the prepare/execute contract.
//!
//! The acceptance contract — `sharded:<S>:native` == `functional` == CSR
//! reference for random COO matrices (empty rows, skewed rows, multi-window
//! K) across alpha/beta and S ∈ {1, 2, 3, 8}, with **one prepared handle
//! per (matrix, S) driven across every scalar pair**; greedy shard planning
//! stays within a 1.25 nnz-imbalance bound on power-law matrices; and the
//! serving coordinator carries shard metrics end to end.

use std::sync::Arc;
use std::time::Duration;

use sextans::backend::{self, FunctionalBackend, PreparedSpmm, SpmmBackend};
use sextans::coordinator::{BatchPolicy, Server, SpmmRequest};
use sextans::prop::{self, assert_allclose};
use sextans::sched::preprocess;
use sextans::shard::{plan_shards, ShardedMatrix};
use sextans::sparse::{gen, rng::Rng, Coo, Csr};

#[test]
fn sharded_equals_functional_equals_csr_reference_property() {
    prop::check("sharded_three_way_agreement", 0x5AD0, 12, |rng| {
        // Small K0 so most matrices span several B windows; the skewed
        // generator half the time gives heavy-tailed rows; zero-density
        // draws give fully empty rows.
        let m = 1 + rng.index(90);
        let k = 1 + rng.index(120);
        let n = 1 + rng.index(10);
        let a = if rng.chance(0.5) {
            gen::random_uniform(m, k, rng.f64() * 0.25, rng)
        } else {
            gen::power_law_rows(m, k, 1 + rng.index(4 * m), 1.1, rng)
        };
        let p = 1 + rng.index(8);
        let k0 = 1 + rng.index(24);
        let d = 1 + rng.index(10);
        let sm = Arc::new(preprocess(&a, p, k0, d));
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let csr = Csr::from_coo(&a);
        let functional = FunctionalBackend.prepare(Arc::clone(&sm)).unwrap();
        for s in [1usize, 2, 3, 8] {
            // Prepare once per (matrix, S): sharding happens here, not per
            // execute.
            let sharded = backend::create(&format!("sharded:{s}:native:1"))
                .unwrap()
                .prepare(Arc::clone(&sm))
                .unwrap();
            for (alpha, beta) in [(0.0f32, 1.0f32), (1.0, 0.0), (2.5, 2.5), (1.0, -0.5)] {
                let mut got = c0.clone();
                sharded.execute(&b, &mut got, n, alpha, beta).unwrap();
                let mut reference_fn = c0.clone();
                functional.execute(&b, &mut reference_fn, n, alpha, beta).unwrap();
                assert_allclose(&got, &reference_fn, 2e-4, 2e-4).map_err(|e| {
                    format!("sharded:{s} vs functional at alpha={alpha}, beta={beta}: {e}")
                })?;
                let mut reference = c0.clone();
                csr.spmm_reference(&b, &mut reference, n, alpha, beta);
                assert_allclose(&got, &reference, 2e-4, 2e-4).map_err(|e| {
                    format!("sharded:{s} vs CSR at alpha={alpha}, beta={beta}: {e}")
                })?;
            }
        }
        Ok(())
    });
}

#[test]
fn greedy_planning_beats_imbalance_bound_on_power_law() {
    // Acceptance bar: max-shard / mean-shard nnz <= 1.25 on power-law rows.
    let mut rng = Rng::new(0xBA1);
    for (m, k, nnz, zipf) in
        [(2048usize, 1024usize, 32_768usize, 1.1f64), (1024, 2048, 16_384, 1.3), (4096, 512, 65_536, 1.0)]
    {
        let a = gen::power_law_rows(m, k, nnz, zipf, &mut rng);
        for s in [2usize, 3, 4, 8] {
            let plan = plan_shards(&a, s);
            let imb = plan.imbalance();
            assert!(
                imb <= 1.25,
                "m={m} nnz={nnz} zipf={zipf} S={s}: imbalance {imb:.3}"
            );
        }
    }
}

#[test]
fn sharded_matrix_partitions_rows_and_nnz_exactly() {
    let mut rng = Rng::new(0x51AB);
    let a = gen::power_law_rows(300, 200, 5_000, 1.2, &mut rng);
    let sharded = ShardedMatrix::build(&a, 4, 8, 32, 8);
    assert_eq!(sharded.num_shards(), 4);
    assert_eq!(sharded.nnz(), a.nnz());
    let mut seen = vec![false; a.m];
    for shard in &sharded.shards {
        for &gr in &shard.global_rows {
            assert!(!seen[gr as usize], "row {gr} in two shards");
            seen[gr as usize] = true;
        }
        assert_eq!(shard.image.m, shard.global_rows.len());
        assert_eq!(shard.image.k, a.k);
    }
    assert!(seen.into_iter().all(|x| x), "every row must land in a shard");
}

#[test]
fn coordinator_serves_sharded_backend_with_metrics() {
    let mut rng = Rng::new(0xC0DE);
    let coo = gen::power_law_rows(200, 150, 4_000, 1.1, &mut rng);
    let image = Arc::new(preprocess(&coo, 8, 32, 10));
    let server = Server::start_backend(
        2,
        BatchPolicy { max_columns: 64, window: Duration::from_millis(2), route_columns: 8 },
        "sharded:4:native:1",
    )
    .unwrap();
    let handle = server.register(image);
    let n = 6;
    let mut rxs = Vec::new();
    let mut wants = Vec::new();
    for _ in 0..6 {
        let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
        let mut want = c0.clone();
        coo.spmm_reference(&b, &mut want, n, 1.25, -0.75);
        wants.push(want);
        rxs.push(server.submit(SpmmRequest {
            image: handle.clone(),
            b,
            c: c0,
            n,
            alpha: 1.25,
            beta: -0.75,
            deadline: None,
        }));
    }
    for (rx, want) in rxs.into_iter().zip(wants) {
        let resp = rx.recv().unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_allclose(&resp.c, &want, 2e-4, 2e-4).unwrap();
        assert_eq!(resp.timing.backend, "sharded");
    }
    let summary = server.shutdown();
    assert_eq!(summary.requests, 6);
    assert!(summary.shard_execs >= 1, "shard metrics must flow into the summary");
    assert!((summary.mean_shards - 4.0).abs() < 1e-12);
    assert!(summary.mean_shard_imbalance >= 1.0);
    assert!(summary.max_shard_imbalance >= summary.mean_shard_imbalance);
    assert_eq!(summary.backends, vec![("sharded", 6)]);
    // Sharding is per prepared matrix, never per request: one registered
    // image on two workers can be sharded at most twice.
    assert!(summary.prepares <= 2, "prepares = {}", summary.prepares);
    assert!(summary.prepared_bytes > 0);
}

#[test]
fn sharded_handles_degenerate_shapes() {
    // More shards than rows, a single row, and an empty matrix — through
    // the composite backend.
    for (m, k, nnz_rows) in [(3usize, 5usize, vec![0u32, 1, 2]), (1, 4, vec![0]), (5, 5, vec![])] {
        let cols: Vec<u32> = nnz_rows.iter().map(|&r| r % k as u32).collect();
        let vals = vec![2.0f32; nnz_rows.len()];
        let a = Coo::new(m, k, nnz_rows, cols, vals).unwrap();
        let sm = Arc::new(preprocess(&a, 2, 4, 3));
        let n = 3;
        let b = vec![1.0f32; k * n];
        let c0 = vec![1.0f32; m * n];
        let mut want = c0.clone();
        a.spmm_reference(&b, &mut want, n, 1.0, 2.0);
        let be = backend::create("sharded:8:native:1").unwrap();
        let mut c = c0;
        be.execute_once(&sm, &b, &mut c, n, 1.0, 2.0).unwrap();
        assert_allclose(&c, &want, 1e-5, 1e-5).unwrap();
    }
}
