//! Integration: the paper's evaluation *shape* must hold on a reduced
//! sweep. These are the claims DESIGN.md §4 commits to reproducing — who
//! wins, by roughly what factor, and where the crossovers fall.

use sextans::metrics::{geomean_speedup, summarize};
use sextans::perfmodel::Platform;
use sextans::report::{run_sweep, SweepOptions};
use sextans::sparse::catalog::Scale;

fn sweep() -> &'static [sextans::metrics::SweepPoint] {
    use std::sync::OnceLock;
    static PTS: OnceLock<Vec<sextans::metrics::SweepPoint>> = OnceLock::new();
    PTS.get_or_init(|| {
        run_sweep(&SweepOptions {
            scale: Scale::Ci,
            n_values: vec![8, 64, 512],
            max_matrices: None,
            stride: 3, // ~67 matrices spread over all six families
            verbose: false,
        })
    })
}

#[test]
fn sextans_beats_k80_geomean() {
    // Paper headline: 2.50x geomean. Accept the 1.5-4x band on the
    // reduced sweep.
    let s = geomean_speedup(sweep(), Platform::Sextans, Platform::K80);
    assert!((1.5..4.0).contains(&s), "Sextans/K80 geomean = {s}");
}

#[test]
fn sextans_p_beats_v100_geomean() {
    // Paper: 1.14x. Accept 1.0-2.0.
    let s = geomean_speedup(sweep(), Platform::SextansP, Platform::V100);
    assert!((1.0..2.0).contains(&s), "Sextans-P/V100 geomean = {s}");
}

#[test]
fn v100_beats_sextans_geomean_but_not_sextans_p() {
    let v100 = geomean_speedup(sweep(), Platform::V100, Platform::K80);
    let sx = geomean_speedup(sweep(), Platform::Sextans, Platform::K80);
    let sxp = geomean_speedup(sweep(), Platform::SextansP, Platform::K80);
    assert!(v100 > sx, "V100 ({v100}) must beat Sextans ({sx}) overall");
    assert!(sxp > v100 * 0.95, "Sextans-P ({sxp}) must match/beat V100 ({v100})");
}

#[test]
fn v100_wins_at_large_problems() {
    // Paper Fig. 7: "the saturated throughput of V100 is higher than that
    // of Sextans-P" — at the largest problems V100 must win.
    let pts = sweep();
    let mut big: Vec<&sextans::metrics::SweepPoint> =
        pts.iter().filter(|p| p.n == 512).collect();
    big.sort_by_key(|p| std::cmp::Reverse(p.flops));
    let top_flops = big.first().map(|p| p.flops).unwrap();
    let at_top = |platform| {
        big.iter()
            .find(|p| p.platform == platform && p.flops >= top_flops / 2)
            .map(|p| p.gflops)
            .unwrap()
    };
    assert!(at_top(Platform::V100) > at_top(Platform::SextansP));
}

#[test]
fn sextans_wins_at_small_problems() {
    // Paper §4.2.1: "for problem size less than 1e6 FLOP, Sextans performs
    // better than both K80 and V100" (runtime overhead amplification).
    let pts = sweep();
    let small: Vec<&sextans::metrics::SweepPoint> =
        pts.iter().filter(|p| p.flops < 1_000_000).collect();
    assert!(!small.is_empty(), "reduced sweep must include small problems");
    let geo = |platform| {
        let xs: Vec<f64> = small
            .iter()
            .filter(|p| p.platform == platform)
            .map(|p| p.gflops)
            .collect();
        sextans::metrics::geomean(&xs)
    };
    let sx = geo(Platform::Sextans);
    assert!(sx > geo(Platform::K80), "small problems: Sextans must beat K80");
    assert!(sx > geo(Platform::V100), "small problems: Sextans must beat V100");
}

#[test]
fn peak_throughput_ordering_matches_table3() {
    // V100 > Sextans-P > Sextans > K80 at the peak (Table 3).
    let peaks: Vec<f64> = [Platform::K80, Platform::Sextans, Platform::SextansP, Platform::V100]
        .iter()
        .map(|p| summarize(*p, sweep()).peak_gflops)
        .collect();
    assert!(peaks[1] > peaks[0], "Sextans peak must beat K80: {peaks:?}");
    assert!(peaks[2] > peaks[1], "Sextans-P peak must beat Sextans: {peaks:?}");
    assert!(peaks[3] > peaks[2], "V100 peak must beat Sextans-P: {peaks:?}");
}

#[test]
fn sextans_saturates_earlier_than_v100() {
    // Paper Fig. 8a: Sextans reaches its peak at ~8e7 FLOP, GPUs at ~1e9.
    // On the CI-scale catalog the K80's curve is truncated (its compute
    // roof is low enough to saturate in-range), so the robust comparison
    // is against V100, whose saturation point is far beyond CI scale.
    let pts = sweep();
    let saturation_size = |platform| {
        let series: Vec<(f64, f64)> = pts
            .iter()
            .filter(|p| p.platform == platform)
            .map(|p| (p.flops as f64, p.gflops))
            .collect();
        let peaks = sextans::metrics::running_peak(&series);
        let final_peak = peaks.last().unwrap().1;
        peaks
            .iter()
            .find(|(_, v)| *v >= 0.9 * final_peak)
            .map(|(s, _)| *s)
            .unwrap()
    };
    let sx = saturation_size(Platform::Sextans);
    let v100 = saturation_size(Platform::V100);
    assert!(sx < v100, "Sextans saturates at {sx:.2e}, V100 at {v100:.2e}");
}

#[test]
fn energy_efficiency_shape() {
    // Paper Fig. 10: normalized to K80, Sextans ~6.25x, V100 ~1.95x,
    // Sextans-P ~6.70x. Check ordering + rough bands.
    let pts = sweep();
    let k80 = summarize(Platform::K80, pts).geomean_flop_per_joule;
    let sx = summarize(Platform::Sextans, pts).geomean_flop_per_joule / k80;
    let v100 = summarize(Platform::V100, pts).geomean_flop_per_joule / k80;
    let sxp = summarize(Platform::SextansP, pts).geomean_flop_per_joule / k80;
    assert!(sx > v100, "Sextans ({sx:.2}) must be greener than V100 ({v100:.2})");
    assert!(sxp > v100, "Sextans-P must be greener than V100");
    assert!((3.0..12.0).contains(&sx), "Sextans/K80 energy = {sx:.2} (paper 6.25)");
    assert!((1.0..4.0).contains(&v100), "V100/K80 energy = {v100:.2} (paper 1.95)");
}

#[test]
fn bandwidth_utilization_bands() {
    // Paper Fig. 9 geomeans: K80 1.47%, Sextans 3.85%, V100 3.39%,
    // Sextans-P 3.88%. Check Sextans > K80 by ~2-4x and all in the
    // few-percent regime.
    let pts = sweep();
    let k80 = summarize(Platform::K80, pts).geomean_bw_util;
    let sx = summarize(Platform::Sextans, pts).geomean_bw_util;
    assert!(sx / k80 > 1.5, "Sextans bw-util must beat K80: {} vs {}", sx, k80);
    for p in [Platform::K80, Platform::Sextans, Platform::V100, Platform::SextansP] {
        let u = summarize(p, pts).geomean_bw_util;
        assert!((0.001..0.25).contains(&u), "{:?} geomean bw util = {u}", p);
    }
}
