//! Integration: the end-to-end telemetry subsystem — per-request span
//! trees that reconcile with the metrics pipeline, admission-only traces
//! for rejected requests, the `--metrics-json` summary payload, and the
//! committed `BENCH_baseline.json` perf-trajectory snapshot.
//!
//! The reconciliation test is the subsystem's acceptance bar: stage spans
//! are stamped from the *same* `Instant`s that populate
//! [`sextans::coordinator::metrics::RequestTiming`], so queue/batch/
//! prepare/exec span durations must equal the reported timings to the
//! nanosecond — not approximately, exactly. Only the root `request` span,
//! which closes after the response is sent, gets a clock-tolerance bound.

use std::path::Path;
use std::sync::Arc;

use sextans::coordinator::{AdmissionPolicy, PipelineConfig, Server, SpmmRequest};
use sextans::sched::preprocess;
use sextans::sparse::{rng::Rng, Coo};
use sextans::telemetry::bench_record::{compare, BenchRecord, SCHEMA_VERSION};
use sextans::telemetry::trace::{build_tree, SpanNode, TelemetrySink, TraceCollector};

/// Root close is bounded by real work (splitting C per segment) plus
/// scheduling noise; 100 ms is orders of magnitude above both on any CI
/// box while still catching a clock-domain mixup (which would be off by
/// the process uptime).
const ROOT_CLOSE_TOLERANCE_NS: u128 = 100_000_000;

fn test_matrix() -> Coo {
    let (m, k) = (48usize, 32usize);
    let mut rows = Vec::new();
    let mut cols = Vec::new();
    let mut vals = Vec::new();
    for i in 0..600u32 {
        rows.push((i * 13 + 1) % (m as u32));
        cols.push((i * 29 + 3) % (k as u32));
        vals.push(0.25 + (i % 11) as f32 * 0.125);
    }
    Coo::new(m, k, rows, cols, vals).unwrap()
}

fn vecs(coo: &Coo, n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
    let c: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
    (b, c)
}

fn traced_config(collector: &Arc<TraceCollector>) -> PipelineConfig {
    PipelineConfig {
        sink: Some(Arc::clone(collector) as Arc<dyn TelemetrySink>),
        ..PipelineConfig::default()
    }
}

fn child<'a>(root: &'a SpanNode, name: &str) -> &'a SpanNode {
    root.children
        .iter()
        .find(|c| c.span.name == name)
        .unwrap_or_else(|| panic!("span tree is missing a '{name}' child"))
}

#[test]
fn span_tree_reconciles_with_request_timing() {
    let coo = test_matrix();
    let image = Arc::new(preprocess(&coo, 4, 8, 4));
    let collector = Arc::new(TraceCollector::new());
    let server =
        Server::start_backend_with(2, traced_config(&collector), "native:1").unwrap();
    let handle = server.register(Arc::clone(&image));

    let mut timings = Vec::new();
    for i in 0..4u64 {
        let n = 2 + i as usize;
        let (b, c0) = vecs(&coo, n, 40 + i);
        let resp = server.call(SpmmRequest {
            image: handle.clone(),
            b,
            c: c0,
            n,
            alpha: 1.0,
            beta: 0.5,
            deadline: None,
        });
        assert!(resp.error.is_none(), "{:?}", resp.error);
        timings.push(resp.timing);
    }
    // Shutdown joins the workers, so every span (including roots, emitted
    // after the response send) is in the collector by now.
    server.shutdown();

    // Sequential submission allocates strictly increasing trace ids, so
    // ascending trace ids line up with the recorded timings.
    let ids = collector.trace_ids();
    assert_eq!(ids.len(), timings.len(), "one trace per request");
    for (trace_idx, (tid, t)) in ids.iter().zip(&timings).enumerate() {
        let spans = collector.trace(*tid);
        let roots = build_tree(&spans);
        assert_eq!(roots.len(), 1, "trace {tid} must have exactly one root");
        let root = &roots[0];
        assert_eq!(root.span.name, "request");
        assert!(root.span.parent_id.is_none());

        // Exact integer-nanosecond reconciliation: span and timing were
        // built from the same Instants.
        assert_eq!(child(root, "queue").span.duration_ns() as u128, t.queue.as_nanos());
        assert_eq!(child(root, "batch").span.duration_ns() as u128, t.batch.as_nanos());
        assert_eq!(
            child(root, "prepare").span.duration_ns() as u128,
            t.prepare.as_nanos()
        );
        assert_eq!(child(root, "exec").span.duration_ns() as u128, t.exec.as_nanos());

        let admission = child(root, "admission");
        assert!(
            admission.span.tags.iter().any(|(k, v)| *k == "outcome" && v == "admitted"),
            "admission span must record the outcome"
        );

        // The first request misses residency: its prepare span carries the
        // backend build as a child span.
        if trace_idx == 0 {
            let backend_prepare = child(child(root, "prepare"), "backend.prepare");
            assert!(backend_prepare
                .span
                .tags
                .iter()
                .any(|(k, v)| *k == "outcome" && v == "built"));
        }

        // The root interval covers the whole stage breakdown and closes
        // within clock tolerance of the reported end-to-end latency.
        let total_ns = t.total().as_nanos();
        let root_ns = root.span.duration_ns() as u128;
        assert!(
            root_ns >= total_ns,
            "trace {tid}: root {root_ns} ns shorter than stage sum {total_ns} ns"
        );
        assert!(
            root_ns - total_ns < ROOT_CLOSE_TOLERANCE_NS,
            "trace {tid}: root closes {} ns after the stage sum",
            root_ns - total_ns
        );
    }
}

#[test]
fn rejected_requests_trace_as_a_lone_admission_span() {
    let coo = test_matrix();
    let image = Arc::new(preprocess(&coo, 4, 8, 4));
    let collector = Arc::new(TraceCollector::new());
    let config = PipelineConfig {
        admission: AdmissionPolicy { max_in_flight: 0, ..AdmissionPolicy::default() },
        ..traced_config(&collector)
    };
    let server = Server::start_backend_with(1, config, "functional").unwrap();
    let handle = server.register(image);
    let n = 2;
    let (b, c0) = vecs(&coo, n, 9);
    let resp = server.call(SpmmRequest {
        image: handle,
        b,
        c: c0,
        n,
        alpha: 1.0,
        beta: 0.0,
        deadline: None,
    });
    assert!(resp.error.is_some(), "zero-depth gate must reject");
    server.shutdown();

    let ids = collector.trace_ids();
    assert_eq!(ids.len(), 1);
    let spans = collector.trace(ids[0]);
    assert_eq!(spans.len(), 1, "a shed request gets exactly one span");
    // No `request` root exists; build_tree promotes the orphan admission
    // span so the partial trace still renders.
    let roots = build_tree(&spans);
    assert_eq!(roots.len(), 1);
    assert_eq!(roots[0].span.name, "admission");
    assert!(roots[0]
        .span
        .tags
        .iter()
        .any(|(k, v)| *k == "outcome" && v == "shed_full"));
}

#[test]
fn metrics_summary_json_carries_stage_percentiles() {
    let coo = test_matrix();
    let image = Arc::new(preprocess(&coo, 4, 8, 4));
    let server = Server::start_backend_with(1, PipelineConfig::default(), "native:1").unwrap();
    let handle = server.register(image);
    for i in 0..6u64 {
        let n = 3;
        let (b, c0) = vecs(&coo, n, 70 + i);
        let resp = server.call(SpmmRequest {
            image: handle.clone(),
            b,
            c: c0,
            n,
            alpha: 1.0,
            beta: 0.0,
            deadline: None,
        });
        assert!(resp.error.is_none());
    }
    let summary = server.shutdown();
    let v = summary.to_value();
    assert_eq!(v.get("requests").and_then(|r| r.as_u64()), Some(6));
    let stages = v.get("stages").expect("stages object");
    for stage in ["queue", "batch", "prepare", "exec"] {
        let s = stages.get(stage).unwrap_or_else(|| panic!("missing stage {stage}"));
        for key in ["mean_s", "p50_s", "p95_s", "p99_s"] {
            let val = s
                .get(key)
                .and_then(|x| x.as_f64())
                .unwrap_or_else(|| panic!("stage {stage} missing {key}"));
            assert!(val.is_finite() && val >= 0.0, "{stage}.{key} = {val}");
        }
        // Percentiles are monotone by construction.
        let p50 = s.get("p50_s").unwrap().as_f64().unwrap();
        let p99 = s.get("p99_s").unwrap().as_f64().unwrap();
        assert!(p99 >= p50, "{stage}: p99 {p99} < p50 {p50}");
    }
    // Exec takes measurable time, so its percentiles are strictly positive.
    let exec_p50 =
        stages.get("exec").unwrap().get("p50_s").unwrap().as_f64().unwrap();
    assert!(exec_p50 > 0.0);
    // Per-backend and per-image latency tables ride along.
    let backends = v.get("backends").and_then(|b| b.as_arr()).expect("backends array");
    assert!(!backends.is_empty());
    assert!(backends[0].get("p95_s").and_then(|x| x.as_f64()).is_some());
    let images = v.get("images").and_then(|b| b.as_arr()).expect("images array");
    assert_eq!(images.len(), 1, "one registered image served every request");
    assert_eq!(images[0].get("requests").and_then(|x| x.as_u64()), Some(6));
}

/// The committed perf-trajectory baseline at the repo root must always
/// parse under the current schema and never flag regressions against
/// itself — this is what keeps the `BENCH_*.json` contract honest across
/// PRs (CI also validates a freshly generated smoke snapshot).
#[test]
fn committed_bench_baseline_parses_and_self_compares_clean() {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_baseline.json");
    let baseline = BenchRecord::read(&path).expect("committed baseline must parse");
    assert_eq!(baseline.name, "baseline");
    assert!(!baseline.git_rev.is_empty());
    // Matrices recorded in the snapshot are rebuildable catalog specs.
    for spec in &baseline.matrices {
        assert!(spec.m > 0 && spec.nnz > 0, "{}: degenerate spec", spec.name);
    }
    assert!(compare(&baseline, &baseline, 0.0).is_empty(), "self-compare must be clean");
    // The schema version in the file matches the library's.
    let text = std::fs::read_to_string(&path).unwrap();
    let v = sextans::telemetry::json::parse(&text).unwrap();
    assert_eq!(v.get("schema").and_then(|s| s.as_u64()), Some(SCHEMA_VERSION));
}
