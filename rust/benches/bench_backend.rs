//! Backend benchmarks: native engine (1/2/4/8 threads) and the adaptive
//! column-blocked variant vs the functional simulator on synthetic catalog
//! shapes, in GFLOP/s of served SpMM, plus a microbench of the SIMD
//! kernel layer itself per available ISA.
//!
//! All engines run through the prepare/execute contract: one prepared
//! handle per (engine, matrix), timed over repeated executes — the
//! steady-state serving shape. The acceptance bar for the native engine is
//! to beat the functional backend at >= 4 threads on every shape (it
//! should already win at 1 thread thanks to the 8-lane vectorized inner
//! loop). Run with `SEXTANS_SIMD=scalar` to measure the scalar fallback —
//! the before/after pair in `BENCH_simd_*.json` is exactly that toggle.

//! Set `BENCH_OUT=<file>` to additionally write the measurements as a
//! `BENCH_*.json` snapshot (schema: `sextans::telemetry::bench_record`);
//! `BENCH_TIMESTAMP` stamps it (defaults to `unknown`).

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use sextans::arch::simulator::problem_flops;
use sextans::backend::simd::{self, Isa};
use sextans::backend::{FunctionalBackend, NativeBackend, PreparedSpmm, SpmmBackend};
use sextans::bench_util::{bench, black_box, section};
use sextans::sched::preprocess;
use sextans::sparse::catalog::{catalog, crystm03_like, MatrixSpec, Scale};
use sextans::sparse::rng::Rng;
use sextans::telemetry::bench_record::{git_rev, BenchMeasurement, BenchRecord};

fn pick(specs: &[MatrixSpec], name_prefix: &str) -> Option<MatrixSpec> {
    specs.iter().find(|s| s.name.starts_with(name_prefix)).cloned()
}

fn main() {
    println!(
        "simd isa: {} (avx2 {}, L2 {} KiB)",
        simd::active().name(),
        if simd::avx2_available() { "available" } else { "absent" },
        simd::l2_cache_bytes() / 1024
    );
    let specs = catalog(Scale::Ci);
    // A graph, a banded FEM matrix, and the Table 1 crystm03 stand-in.
    let shapes: Vec<MatrixSpec> = [
        pick(&specs, "snap_rmat_25"),
        pick(&specs, "ss_banded_15"),
        Some(crystm03_like()),
    ]
    .into_iter()
    .flatten()
    .collect();

    let n = 16usize;
    let mut rng = Rng::new(0xBE);
    let mut results: Vec<BenchMeasurement> = Vec::new();
    for spec in &shapes {
        let coo = spec.build();
        // Paper-shaped image: 64 PEs, K0 = 4096, D = 10.
        let sm = Arc::new(preprocess(&coo, 64, 4096, 10));
        let flops = problem_flops(coo.nnz(), coo.m, n) as f64;
        let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
        let mut c = c0.clone();

        section(&format!(
            "{} ({}x{}, nnz {}, N={n})",
            spec.name,
            coo.m,
            coo.k,
            coo.nnz()
        ));

        let functional = FunctionalBackend.prepare(Arc::clone(&sm)).unwrap();
        let r = bench("backend/functional", 1, 6, Duration::from_millis(400), || {
            c.copy_from_slice(&c0);
            functional.execute(&b, &mut c, n, 1.0, 0.5).unwrap();
            black_box(&c);
        });
        let base_gflops = r.throughput(flops) / 1e9;
        println!("    -> {base_gflops:.2} GFLOP/s");
        results.push(BenchMeasurement {
            bench: "backend/functional".into(),
            matrix: spec.name.clone(),
            n,
            gflops: base_gflops,
            median_ns: r.median_ns,
            p50_ns: r.p50_ns,
            p95_ns: r.p95_ns,
            p99_ns: r.p99_ns,
        });

        for threads in [1usize, 2, 4, 8] {
            let native = NativeBackend::new(threads).prepare(Arc::clone(&sm)).unwrap();
            let r = bench(
                &format!("backend/native:{threads}"),
                1,
                6,
                Duration::from_millis(400),
                || {
                    c.copy_from_slice(&c0);
                    native.execute(&b, &mut c, n, 1.0, 0.5).unwrap();
                    black_box(&c);
                },
            );
            let gflops = r.throughput(flops) / 1e9;
            println!(
                "    -> {gflops:.2} GFLOP/s ({:.2}x vs functional)",
                gflops / base_gflops
            );
            results.push(BenchMeasurement {
                bench: format!("backend/native:{threads}"),
                matrix: spec.name.clone(),
                n,
                gflops,
                median_ns: r.median_ns,
                p50_ns: r.p50_ns,
                p95_ns: r.p95_ns,
                p99_ns: r.p99_ns,
            });
        }

        // The adaptive column-blocked variant: its width resolves per
        // matrix from the distinct-B-row count and the detected L2.
        let blocked = NativeBackend::blocked(8).build(Arc::clone(&sm));
        let width = blocked.col_block();
        let r = bench(
            "backend/native-blocked:8",
            1,
            6,
            Duration::from_millis(400),
            || {
                c.copy_from_slice(&c0);
                blocked.execute(&b, &mut c, n, 1.0, 0.5).unwrap();
                black_box(&c);
            },
        );
        let gflops = r.throughput(flops) / 1e9;
        println!("    -> {gflops:.2} GFLOP/s (adaptive block width {width})");
        results.push(BenchMeasurement {
            bench: "backend/native-blocked:8".into(),
            matrix: spec.name.clone(),
            n,
            gflops,
            median_ns: r.median_ns,
            p50_ns: r.p50_ns,
            p95_ns: r.p95_ns,
            p99_ns: r.p99_ns,
        });
    }

    // SIMD kernel layer in isolation: the N-wide AXPY inner step on a
    // resident working set, per ISA the host can run — the dispatch-level
    // speedup the engine numbers above are built from.
    section("simd kernels (axpy over 64Ki f32, per ISA)");
    let len = 65_536usize;
    let x: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
    let mut y = vec![0f32; len];
    let mut kernel_isas = vec![Isa::Scalar];
    if simd::avx2_available() {
        kernel_isas.push(Isa::Avx2);
    }
    for isa in kernel_isas {
        let r = bench(
            &format!("kernel/axpy:{}", isa.name()),
            1,
            6,
            Duration::from_millis(200),
            || {
                simd::axpy(isa, &mut y, &x, 1.000001);
                black_box(&y);
            },
        );
        let gflops = r.throughput(2.0 * len as f64) / 1e9;
        println!("    -> {gflops:.2} GFLOP/s");
        results.push(BenchMeasurement {
            bench: format!("kernel/axpy:{}", isa.name()),
            matrix: format!("dense_{len}"),
            n: 1,
            gflops,
            median_ns: r.median_ns,
            p50_ns: r.p50_ns,
            p95_ns: r.p95_ns,
            p99_ns: r.p99_ns,
        });
    }

    if let Ok(path) = std::env::var("BENCH_OUT") {
        let record = BenchRecord {
            name: "backend".into(),
            git_rev: git_rev(),
            timestamp: std::env::var("BENCH_TIMESTAMP").unwrap_or_else(|_| "unknown".into()),
            host_threads: std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1),
            matrices: shapes,
            results,
            scaling: Vec::new(),
        };
        record.write(Path::new(&path)).expect("write BENCH_OUT");
        println!("\nwrote {path}");
    }
}
