//! Table 1 bench: wall-clock of generating the breakdown (preprocessing in
//! all three schedule modes on crystm03 + the four simulator configs), and
//! the report itself for inspection.

use std::time::Duration;

use sextans::arch::AcceleratorConfig;
use sextans::bench_util::{bench, black_box, section};
use sextans::report::experiments;
use sextans::sched::preprocess::{preprocess_mode, ScheduleMode};
use sextans::sparse::catalog;

fn main() {
    let coo = catalog::crystm03_like().build();
    let cfg = AcceleratorConfig::sextans_u280();
    println!(
        "crystm03-like: {}x{}, nnz {}",
        coo.m,
        coo.k,
        coo.nnz()
    );

    section("preprocessing per schedule mode");
    for (label, mode) in [
        ("ooo", ScheduleMode::OutOfOrder),
        ("inorder-colmajor", ScheduleMode::InOrderColMajor),
        ("inorder-rowmajor", ScheduleMode::InOrderRowMajor),
    ] {
        bench(
            &format!("preprocess/crystm03/{label}"),
            1,
            3,
            Duration::from_millis(500),
            || {
                black_box(preprocess_mode(
                    black_box(&coo),
                    cfg.p(),
                    cfg.k0,
                    cfg.d,
                    mode,
                ));
            },
        );
    }

    section("table 1 end-to-end");
    bench("experiments::table1", 0, 2, Duration::from_millis(100), || {
        black_box(experiments::table1());
    });
    println!("\n{}", experiments::table1());
}
