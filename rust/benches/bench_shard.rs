//! Shard benchmarks: GFLOP/s of the resident shard pool at S = 1/2/4/8
//! shards vs the unsharded native backend, on a skewed (power-law rows)
//! matrix — the workload where nnz-balanced sharding has to prove itself.
//! Also reports the greedy planner's shard imbalance ratio per S.
//!
//! Pools are prepared once per S ([`ShardExecutor::prepare`]); the timed
//! loop is pure execute, i.e. the steady-state of the prepare/execute
//! contract.

use std::sync::Arc;
use std::time::Duration;

use sextans::arch::simulator::problem_flops;
use sextans::backend::{NativeBackend, PreparedSpmm, SpmmBackend};
use sextans::bench_util::{bench, black_box, section};
use sextans::sched::preprocess;
use sextans::shard::{ShardExecutor, ShardedMatrix};
use sextans::sparse::{gen, rng::Rng};

fn main() {
    let mut rng = Rng::new(0x5A);
    // Power-law rows: the head rows carry orders of magnitude more work
    // than the tail — exactly what greedy nnz bin-packing must flatten.
    let coo = gen::power_law_rows(8192, 8192, 400_000, 1.1, &mut rng);
    let (p, k0, d) = (64usize, 4096usize, 10usize);
    let n = 64usize;
    let flops = problem_flops(coo.nnz(), coo.m, n) as f64;
    let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
    let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
    let mut c = c0.clone();

    section(&format!(
        "shard sweep ({}x{}, nnz {}, N={n}, power-law rows)",
        coo.m,
        coo.k,
        coo.nnz()
    ));

    // Baseline: the unsharded native backend, auto-threaded, prepared once.
    let sm = Arc::new(preprocess(&coo, p, k0, d));
    let native = NativeBackend::new(0).prepare(Arc::clone(&sm)).expect("native prepare");
    let r = bench("shard/unsharded-native", 1, 6, Duration::from_millis(400), || {
        c.copy_from_slice(&c0);
        native.execute(&b, &mut c, n, 1.0, 0.5).unwrap();
        black_box(&c);
    });
    let base_gflops = r.throughput(flops) / 1e9;
    println!("    -> {base_gflops:.2} GFLOP/s (baseline)");

    for s in [1usize, 2, 4, 8] {
        let sharded = ShardedMatrix::build(&coo, s, p, k0, d);
        let exec = ShardExecutor::prepare(&sharded, "native").expect("native pool");
        let pcost = exec.prepare_cost();
        let r = bench(
            &format!("shard/sharded:{s}:native"),
            1,
            6,
            Duration::from_millis(400),
            || {
                c.copy_from_slice(&c0);
                exec.execute(&b, &mut c, n, 1.0, 0.5).unwrap();
                black_box(&c);
            },
        );
        let gflops = r.throughput(flops) / 1e9;
        println!(
            "    -> {gflops:.2} GFLOP/s ({:.2}x vs unsharded), nnz imbalance {:.3}, \
             pool prepare {:.1} ms / {:.1} MiB resident",
            gflops / base_gflops,
            sharded.imbalance(),
            pcost.wall.as_secs_f64() * 1e3,
            pcost.resident_bytes as f64 / (1024.0 * 1024.0)
        );
    }
}
