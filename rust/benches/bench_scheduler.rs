//! Scheduler benchmarks: preprocessing throughput (nnz/s) across matrix
//! structures — the host-side cost the paper amortizes offline. Target
//! (DESIGN.md §6): ≥ 10M nnz/s end-to-end preprocessing.

use std::time::Duration;

use sextans::arch::AcceleratorConfig;
use sextans::bench_util::{bench, black_box, section};
use sextans::sched::ooo::{cycles_inorder, schedule_ooo, Scratch};
use sextans::sched::{partition, preprocess};
use sextans::sparse::{gen, rng::Rng};

fn main() {
    let cfg = AcceleratorConfig::sextans_u280();
    let mut rng = Rng::new(0xBE7C);

    section("ooo scheduler core (single window list)");
    for (label, rows, nnz) in [
        ("uniform 4k rows, 64k nnz", 4096usize, 65_536usize),
        ("hot 256 rows, 64k nnz", 256, 65_536),
        ("tiny 16 rows, 4k nnz", 16, 4096),
    ] {
        let bin: Vec<_> = (0..nnz)
            .map(|i| sextans::sched::Nz {
                row: rng.index(rows) as u32,
                col: (i % 4096) as u16,
                val: 1.0,
            })
            .collect();
        let mut scratch = Scratch::default();
        let r = bench(
            &format!("schedule_ooo/{label}"),
            2,
            8,
            Duration::from_millis(400),
            || {
                black_box(schedule_ooo(black_box(&bin), cfg.d, rows, &mut scratch));
            },
        );
        println!("    -> {:.2} Mnnz/s", r.throughput(nnz as f64) / 1e6);
        bench(
            &format!("cycles_inorder/{label}"),
            2,
            8,
            Duration::from_millis(200),
            || {
                black_box(cycles_inorder(black_box(&bin), cfg.d, rows));
            },
        );
    }

    section("partition (Eq. 2-4)");
    let coo = gen::random_uniform(65_536, 65_536, 0.001, &mut rng);
    let nnz = coo.nnz();
    let r = bench(
        "partition/64k x 64k, 4.3M nnz",
        1,
        4,
        Duration::from_millis(500),
        || {
            black_box(partition(black_box(&coo), cfg.p(), cfg.k0));
        },
    );
    println!("    -> {:.2} Mnnz/s", r.throughput(nnz as f64) / 1e6);

    section("end-to-end preprocessing (partition + schedule + encode + Q)");
    for (label, m, density) in [
        ("8k^2 uniform 0.01", 8192usize, 0.01f64),
        ("64k^2 uniform 0.001", 65_536, 0.001),
    ] {
        let coo = gen::random_uniform(m, m, density, &mut rng);
        let nnz = coo.nnz();
        let r = bench(
            &format!("preprocess/{label} ({nnz} nnz)"),
            1,
            4,
            Duration::from_millis(800),
            || {
                black_box(preprocess(black_box(&coo), cfg.p(), cfg.k0, cfg.d));
            },
        );
        println!("    -> {:.2} Mnnz/s", r.throughput(nnz as f64) / 1e6);
    }

    let coo = gen::rmat(32_768, 1 << 18, 0.45, 0.2, 0.2, &mut rng);
    let nnz = coo.nnz();
    let r = bench(
        &format!("preprocess/rmat 32k ({nnz} nnz)"),
        1,
        4,
        Duration::from_millis(800),
        || {
            black_box(preprocess(black_box(&coo), cfg.p(), cfg.k0, cfg.d));
        },
    );
    println!("    -> {:.2} Mnnz/s", r.throughput(nnz as f64) / 1e6);
}
