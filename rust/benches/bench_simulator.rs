//! Simulator benchmarks: cycle-model evaluation and functional-execution
//! throughput. The cycle model must be fast enough that the 1,400-SpMM
//! sweep is dominated by preprocessing, not simulation.

use std::time::Duration;

use sextans::arch::{functional, simulate, AcceleratorConfig};
use sextans::bench_util::{bench, black_box, section};
use sextans::sched::preprocess;
use sextans::sparse::{gen, rng::Rng};

fn main() {
    let cfg = AcceleratorConfig::sextans_u280();
    let mut rng = Rng::new(0x51A1);

    section("cycle-level simulate()");
    for (label, m, density, n) in [
        ("8k^2 1%, N=8", 8192usize, 0.01f64, 8usize),
        ("8k^2 1%, N=512", 8192, 0.01, 512),
        ("64k^2 0.1%, N=64", 65_536, 0.001, 64),
    ] {
        let coo = gen::random_uniform(m, m, density, &mut rng);
        let sm = preprocess(&coo, cfg.p(), cfg.k0, cfg.d);
        bench(
            &format!("simulate/{label}"),
            2,
            16,
            Duration::from_millis(300),
            || {
                black_box(simulate(black_box(&sm), &cfg, n));
            },
        );
    }

    section("functional execute() (exact FP32 datapath)");
    for (label, m, density, n) in [
        ("2k^2 1%, N=8", 2048usize, 0.01f64, 8usize),
        ("8k^2 0.5%, N=8", 8192, 0.005, 8),
        ("8k^2 0.5%, N=64", 8192, 0.005, 64),
    ] {
        let coo = gen::random_uniform(m, m, density, &mut rng);
        let sm = preprocess(&coo, cfg.p(), cfg.k0, cfg.d);
        let b: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let mut c = vec![0f32; m * n];
        let nnz = coo.nnz();
        let r = bench(
            &format!("functional/{label} ({nnz} nnz)"),
            1,
            8,
            Duration::from_millis(400),
            || {
                functional::execute(black_box(&sm), black_box(&b), &mut c, n, 1.0, 0.0);
                black_box(&c);
            },
        );
        println!(
            "    -> {:.2} Mnnz/s, {:.2} GFLOP/s host-functional",
            r.throughput(nnz as f64) / 1e6,
            r.throughput((2 * nnz * n) as f64) / 1e9
        );
    }
}
