//! Shared-handle concurrency scaling: W ∈ {1, 2, 4, 8} worker threads all
//! executing against ONE prepared matrix (`Arc<dyn PreparedSpmm>`, no
//! mutex), reporting aggregate GFLOP/s and scaling efficiency vs the
//! W = 1 baseline — the number the `&self` execution redesign exists to
//! improve. Under the old `Arc<Mutex<..>>` residency, this workload ran
//! exactly one execute at a time regardless of W (efficiency ~ 1/W);
//! with pooled scratch it should scale near-linearly until the memory
//! bus saturates.
//!
//! The inner engine is pinned to one thread (`native:1`) so the scaling
//! measured is *concurrency across requests*, not the engine's own
//! fan-out; a second section repeats W = 4 on `sharded:2:native:1` to
//! show the composite's gather/scatter path also concurrency-scales.

//! Set `BENCH_OUT=<file>` to additionally write the scaling points as a
//! `BENCH_*.json` snapshot (schema: `sextans::telemetry::bench_record`);
//! `BENCH_TIMESTAMP` stamps it (defaults to `unknown`).

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use sextans::arch::simulator::problem_flops;
use sextans::backend::{self, PreparedSpmm, SpmmBackend};
use sextans::bench_util::{black_box, section};
use sextans::sched::preprocess;
use sextans::sparse::{gen, rng::Rng};
use sextans::telemetry::bench_record::{git_rev, BenchRecord, ScalingPoint};

/// Aggregate seconds for `iters` executes spread evenly over `w` threads
/// sharing `handle`.
fn run_shared(
    handle: &Arc<dyn PreparedSpmm + Send + Sync>,
    w: usize,
    iters: usize,
    b: &[f32],
    c0: &[f32],
    n: usize,
) -> f64 {
    let per_thread = iters.div_ceil(w);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..w {
            let handle = Arc::clone(handle);
            s.spawn(move || {
                let mut c = c0.to_vec();
                for _ in 0..per_thread {
                    handle.execute(b, &mut c, n, 1.0, 0.5).unwrap();
                    black_box(&c);
                }
            });
        }
    });
    t0.elapsed().as_secs_f64() / (per_thread * w) as f64
}

fn main() {
    let mut rng = Rng::new(0xC0C0);
    // One serving-shaped hot matrix; N modest so a single execute is far
    // from saturating the machine on its own.
    let coo = gen::power_law_rows(4096, 4096, 250_000, 1.1, &mut rng);
    let (p, k0, d) = (64usize, 4096usize, 10usize);
    let n = 16usize;
    let sm = Arc::new(preprocess(&coo, p, k0, d));
    let flops = problem_flops(coo.nnz(), coo.m, n) as f64;
    let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
    let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();

    section(&format!(
        "shared-handle concurrency ({}x{}, nnz {}, N={n}, engine native:1, isa {})",
        coo.m,
        coo.k,
        coo.nnz(),
        sextans::backend::simd::active().name()
    ));

    let handle: Arc<dyn PreparedSpmm + Send + Sync> = Arc::from(
        backend::create("native:1").unwrap().prepare_send(Arc::clone(&sm)).unwrap(),
    );
    // Warm the scratch pool at the highest W so allocation never lands in
    // a timed region.
    run_shared(&handle, 8, 8, &b, &c0, n);

    let iters = 24usize;
    let mut base_gflops = 0.0f64;
    let mut scaling: Vec<ScalingPoint> = Vec::new();
    for w in [1usize, 2, 4, 8] {
        let per_exec_s = run_shared(&handle, w, iters, &b, &c0, n);
        // Aggregate throughput across the W concurrent streams
        // (per_exec_s already amortizes the wall clock over every execute
        // issued by every thread).
        let agg_gflops = flops / per_exec_s / 1e9;
        if w == 1 {
            base_gflops = agg_gflops;
        }
        let efficiency = agg_gflops / (base_gflops * w as f64);
        println!(
            "W={w}: {:.3} ms/execute, aggregate {:.2} GFLOP/s, scaling efficiency \
             {:.0}% of linear",
            per_exec_s * 1e3,
            agg_gflops,
            efficiency * 100.0
        );
        scaling.push(ScalingPoint {
            bench: "concurrency/native:1".into(),
            workers: w,
            gflops: agg_gflops,
            efficiency,
        });
    }

    if let Ok(path) = std::env::var("BENCH_OUT") {
        let record = BenchRecord {
            name: "concurrency".into(),
            git_rev: git_rev(),
            timestamp: std::env::var("BENCH_TIMESTAMP").unwrap_or_else(|_| "unknown".into()),
            host_threads: std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1),
            matrices: Vec::new(),
            results: Vec::new(),
            scaling,
        };
        record.write(Path::new(&path)).expect("write BENCH_OUT");
        println!("wrote {path}");
    }

    section("shared sharded handle (W=4, sharded:2:native:1)");
    let sharded: Arc<dyn PreparedSpmm + Send + Sync> = Arc::from(
        backend::create("sharded:2:native:1")
            .unwrap()
            .prepare_send(Arc::clone(&sm))
            .unwrap(),
    );
    run_shared(&sharded, 4, 4, &b, &c0, n); // warm gather blocks
    for w in [1usize, 4] {
        let per_exec_s = run_shared(&sharded, w, iters, &b, &c0, n);
        println!(
            "W={w}: {:.3} ms/execute, aggregate {:.2} GFLOP/s",
            per_exec_s * 1e3,
            flops / per_exec_s / 1e9
        );
    }
}
