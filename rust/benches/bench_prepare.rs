//! Prepare amortization curve: prepare-once-execute-k vs the old per-call
//! path (prepare + execute on every request, which is what the stateless
//! `execute` contract forced — for the sharded composite that meant a full
//! re-shard per call) for k ∈ {1, 4, 16, 64} at S ∈ {1, 4}.
//!
//! Reports the one-time prepare cost (ms, resident MiB), the steady-state
//! execute GFLOP/s of the resident handle, and the end-to-end speedup of
//! the prepared path over per-call at each k — the curve should start near
//! the prepare/execute cost ratio at k = 1 and asymptote to 1x of
//! steady-state as k grows.
//!
//! The second section measures **re-shard-on-skew** on a skewed power-law
//! matrix: the cost of the trigger itself (drop the resident pool +
//! re-prepare at the halved S), the nnz imbalance before/after, the
//! steady-state execute at both shard counts, and the number of executes
//! needed to amortize the rebuild — so the serving policy's threshold is
//! informed by measurement, not guesswork.

//! Set `BENCH_OUT=<file>` to additionally write the steady-state execute
//! measurements as a `BENCH_*.json` snapshot (schema:
//! `sextans::telemetry::bench_record`); `BENCH_TIMESTAMP` stamps it.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use sextans::arch::simulator::problem_flops;
use sextans::backend::{self, PreparedSpmm, SpmmBackend};
use sextans::bench_util::{black_box, percentile_sorted, section};
use sextans::sched::preprocess;
use sextans::sparse::{gen, rng::Rng};
use sextans::telemetry::bench_record::{git_rev, BenchMeasurement, BenchRecord};

fn main() {
    let mut rng = Rng::new(0xA3);
    // A serving-shaped matrix: power-law rows, moderate size so the
    // per-call path (which re-prepares every request) stays benchable.
    let coo = gen::power_law_rows(4096, 4096, 200_000, 1.1, &mut rng);
    let (p, k0, d) = (64usize, 4096usize, 10usize);
    let n = 32usize;
    let sm = Arc::new(preprocess(&coo, p, k0, d));
    let flops = problem_flops(coo.nnz(), coo.m, n) as f64;
    let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
    let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
    let mut c = c0.clone();

    section(&format!(
        "prepare amortization ({}x{}, nnz {}, N={n})",
        coo.m,
        coo.k,
        coo.nnz()
    ));

    let mut results: Vec<BenchMeasurement> = Vec::new();
    for s in [1usize, 4] {
        // sharded:1 still pays the full plan/re-shard on the old per-call
        // path, so the S=1 row isolates the contract change itself.
        let spec = format!("sharded:{s}:native");
        let factory = backend::create(&spec).expect("spec");

        // One-time prepare cost of the resident handle.
        let t0 = Instant::now();
        let handle = factory.prepare(Arc::clone(&sm)).expect("prepare");
        let prepare_s = t0.elapsed().as_secs_f64();
        let cost = handle.prepare_cost();
        // Warm up scratch, then measure steady-state execute per-iteration
        // (sampled so the BENCH snapshot gets real percentiles).
        handle.execute(&b, &mut c, n, 1.0, 0.5).unwrap();
        const STEADY_ITERS: usize = 5;
        let mut samples: Vec<f64> = Vec::with_capacity(STEADY_ITERS);
        for _ in 0..STEADY_ITERS {
            c.copy_from_slice(&c0);
            let t1 = Instant::now();
            handle.execute(&b, &mut c, n, 1.0, 0.5).unwrap();
            samples.push(t1.elapsed().as_nanos() as f64);
            black_box(&c);
        }
        let exec_s = samples.iter().sum::<f64>() / samples.len() as f64 / 1e9;
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        results.push(BenchMeasurement {
            bench: format!("prepare/{spec}"),
            matrix: "power_law_4096".into(),
            n,
            gflops: flops / exec_s / 1e9,
            median_ns: percentile_sorted(&samples, 0.5),
            p50_ns: percentile_sorted(&samples, 0.5),
            p95_ns: percentile_sorted(&samples, 0.95),
            p99_ns: percentile_sorted(&samples, 0.99),
        });
        println!(
            "{spec}: prepare {:.2} ms ({:.2} MiB resident), steady-state execute \
             {:.2} ms = {:.2} GFLOP/s",
            prepare_s * 1e3,
            cost.resident_bytes as f64 / (1024.0 * 1024.0),
            exec_s * 1e3,
            flops / exec_s / 1e9
        );

        for k in [1usize, 4, 16, 64] {
            // Old per-call path: every request pays prepare + execute
            // (execute_once), exactly what the stateless contract did.
            let t0 = Instant::now();
            for _ in 0..k {
                c.copy_from_slice(&c0);
                factory.execute_once(&sm, &b, &mut c, n, 1.0, 0.5).unwrap();
                black_box(&c);
            }
            let percall_s = t0.elapsed().as_secs_f64();

            // New path: the handle is already resident; k pure executes.
            let t0 = Instant::now();
            for _ in 0..k {
                c.copy_from_slice(&c0);
                handle.execute(&b, &mut c, n, 1.0, 0.5).unwrap();
                black_box(&c);
            }
            let prepared_s = t0.elapsed().as_secs_f64();
            // Amortized view charges the one-time prepare against the run.
            let amortized_s = prepare_s + prepared_s;
            println!(
                "  k={k:>3}: per-call {:>8.2} ms | prepared {:>8.2} ms \
                 (+{:.2} ms prepare, amortized {:.2}x faster) | steady {:.2} GFLOP/s",
                percall_s * 1e3,
                prepared_s * 1e3,
                prepare_s * 1e3,
                percall_s / amortized_s,
                (k as f64 * flops) / prepared_s / 1e9
            );
        }
    }

    if let Ok(path) = std::env::var("BENCH_OUT") {
        let record = BenchRecord {
            name: "prepare".into(),
            git_rev: git_rev(),
            timestamp: std::env::var("BENCH_TIMESTAMP").unwrap_or_else(|_| "unknown".into()),
            host_threads: std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1),
            matrices: Vec::new(),
            results,
            scaling: Vec::new(),
        };
        record.write(Path::new(&path)).expect("write BENCH_OUT");
        println!("wrote {path}");
    }

    // ---- Re-shard-on-skew: the cost of drop + re-prepare at a new S ----
    // A heavily skewed power-law matrix — the shape whose imbalance trips
    // the serving trigger (one dominant row keeps the largest shard hot
    // regardless of S, so halving S improves max/mean balance).
    let skewed = gen::power_law_rows(4096, 4096, 200_000, 2.0, &mut rng);
    let skewed_sm = Arc::new(preprocess(&skewed, p, k0, d));
    let skewed_flops = problem_flops(skewed.nnz(), skewed.m, n) as f64;
    section(&format!(
        "re-shard-on-skew cost (skewed power-law {}x{}, nnz {}, N={n})",
        skewed.m,
        skewed.k,
        skewed.nnz()
    ));
    const RESHARD_ITERS: usize = 5;
    for (s_from, s_to) in [(8usize, 4usize), (4, 2)] {
        let steady = |handle: &dyn PreparedSpmm, c: &mut [f32]| -> f64 {
            handle.execute(&b, c, n, 1.0, 0.5).unwrap(); // warm scratch
            let t0 = Instant::now();
            for _ in 0..RESHARD_ITERS {
                c.copy_from_slice(&c0);
                handle.execute(&b, c, n, 1.0, 0.5).unwrap();
                black_box(&c);
            }
            t0.elapsed().as_secs_f64() / RESHARD_ITERS as f64
        };
        let from = backend::create(&format!("sharded:{s_from}:native")).unwrap();
        let handle = from.prepare(Arc::clone(&skewed_sm)).unwrap();
        let imb_from = sextans::shard::plan_shards(&skewed, s_from).imbalance();
        let exec_from = steady(&*handle, &mut c);

        // The trigger's cost: drop the resident pool, re-prepare at s_to.
        let to = backend::create(&format!("sharded:{s_to}:native")).unwrap();
        let t0 = Instant::now();
        drop(handle);
        let handle = to.prepare(Arc::clone(&skewed_sm)).unwrap();
        let reshard_s = t0.elapsed().as_secs_f64();
        let imb_to = sextans::shard::plan_shards(&skewed, s_to).imbalance();
        let exec_to = steady(&*handle, &mut c);

        let break_even = if exec_from > exec_to {
            format!("{:.0} executes", (reshard_s / (exec_from - exec_to)).ceil())
        } else {
            "never (old S faster here)".to_string()
        };
        println!(
            "S {s_from} -> {s_to}: rebuild {:.2} ms ({:.2} MiB resident), imbalance \
             {imb_from:.3} -> {imb_to:.3}, steady execute {:.2} -> {:.2} ms \
             ({:.2} -> {:.2} GFLOP/s), break-even after {break_even}",
            reshard_s * 1e3,
            handle.prepare_cost().resident_bytes as f64 / (1024.0 * 1024.0),
            exec_from * 1e3,
            exec_to * 1e3,
            skewed_flops / exec_from / 1e9,
            skewed_flops / exec_to / 1e9
        );
    }
}
