//! Sweep bench: wall-clock of the per-figure pipeline on a catalog slice,
//! plus the headline geomeans it produces (Fig. 7-10 content check).

use std::time::Duration;

use sextans::bench_util::{bench, black_box, section};
use sextans::report::experiments;
use sextans::report::{run_sweep, SweepOptions};
use sextans::sparse::catalog::Scale;

fn main() {
    section("sweep slices");
    for (label, max) in [("20 matrices", 20usize), ("60 matrices", 60)] {
        bench(
            &format!("run_sweep/{label} x 7 N x 4 platforms"),
            0,
            2,
            Duration::from_millis(100),
            || {
                black_box(run_sweep(&SweepOptions {
                    scale: Scale::Ci,
                    max_matrices: Some(max),
                    ..Default::default()
                }));
            },
        );
    }

    section("figure transforms");
    // Stride 3 samples all six families (a plain prefix would be
    // SNAP-only and skew the headline geomeans printed below).
    let points = run_sweep(&SweepOptions {
        scale: Scale::Ci,
        stride: 3,
        ..Default::default()
    });
    bench("fig7+headline", 1, 4, Duration::from_millis(200), || {
        black_box(experiments::fig7(black_box(&points)));
    });
    bench("fig8 (peak+cdf)", 1, 4, Duration::from_millis(200), || {
        black_box(experiments::fig8(black_box(&points)));
    });
    bench("fig9+fig10", 1, 4, Duration::from_millis(200), || {
        black_box(experiments::fig9(black_box(&points)));
        black_box(experiments::fig10(black_box(&points)));
    });

    println!("\n{}", experiments::headline(&points));
}
