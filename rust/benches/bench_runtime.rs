//! PJRT runtime benchmarks: artifact compile time, per-kernel dispatch
//! latency, and the full windowed SpMM through XLA executables.
//!
//! Skips (exit 0 with a notice) if `artifacts/` is missing — run
//! `make artifacts` first.

use std::time::{Duration, Instant};

use sextans::bench_util::{bench, black_box, section};
use sextans::runtime::{manifest, Engine};
use sextans::sparse::{gen, rng::Rng};

fn main() {
    if !manifest::default_dir().join("manifest.tsv").exists() {
        println!("bench_runtime: artifacts/ missing — run `make artifacts`; skipping");
        return;
    }

    section("engine load + compile (all artifacts)");
    let t0 = Instant::now();
    let engine = Engine::load_default().expect("engine load");
    println!(
        "engine::load (compile {} window variants + comp/fused/dense): {:.2} s",
        engine.variants().len(),
        t0.elapsed().as_secs_f64()
    );

    let mut rng = Rng::new(0x9A);
    let variants = engine.variants();
    let v = variants[0]; // smallest (win_s)

    section("single-kernel dispatch");
    let rows: Vec<i32> = (0..v.nnz_cap).map(|_| rng.index(v.m_tile) as i32).collect();
    let cols: Vec<i32> = (0..v.nnz_cap).map(|_| rng.index(v.k0) as i32).collect();
    let vals: Vec<f32> = (0..v.nnz_cap).map(|_| rng.normal()).collect();
    let b: Vec<f32> = (0..v.k0 * v.n0).map(|_| rng.normal()).collect();
    let c: Vec<f32> = vec![0.0; v.m_tile * v.n0];
    let r = bench(
        &format!("run_window/{}nnz k0={}", v.nnz_cap, v.k0),
        2,
        8,
        Duration::from_millis(500),
        || {
            black_box(engine.run_window(v, &rows, &cols, &vals, &b, &c).unwrap());
        },
    );
    println!(
        "    -> {:.2} Mnnz/s through the XLA interpret pipeline",
        r.throughput(v.nnz_cap as f64) / 1e6
    );

    bench("run_comp/m_tile", 2, 8, Duration::from_millis(300), || {
        black_box(
            engine
                .run_comp(v.m_tile, v.n0, &c, &c, 2.0, 0.5)
                .unwrap(),
        );
    });

    section("full SpMM via Engine::spmm");
    let coo = gen::random_uniform(512, 1024, 0.02, &mut rng);
    let (pv, image) = engine.plan(&coo, 8, 10).expect("plan");
    let n = 16;
    let bb: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
    let cc: Vec<f32> = vec![0.0; coo.m * n];
    let r = bench(
        &format!("spmm/512x1024 nnz={} N={n} (variant k0={})", coo.nnz(), pv.k0),
        0,
        3,
        Duration::from_millis(100),
        || {
            black_box(engine.spmm(pv, &image, &bb, &cc, n, 1.0, 0.0).unwrap());
        },
    );
    println!(
        "    -> {:.3} Mnnz/s end-to-end (interpret-mode HLO; the silicon\n       projection for the same image comes from `sextans run`)",
        r.throughput(coo.nnz() as f64) / 1e6
    );
}
