//! PJRT backend adapter: [`crate::runtime::Engine`] (AOT Pallas kernels
//! executed by the PJRT CPU client) behind the prepare/execute contract.
//!
//! The engine loads — and the kernel variant matching the image's (K0,
//! rows/PE) is selected — at **prepare** time: the [`PreparedPjrt`] handle
//! is where device residency lives (today the compiled executables + chosen
//! variant; staged HBM operand buffers land here next). Constructing the
//! factory itself never touches artifacts, so registry listings and server
//! startup stay artifact-free.
//!
//! Without the real engine (the `pjrt` + `xla` cargo features),
//! `Engine::load` is a stub and every prepare reports
//! [`BackendError::Unavailable`] — the serving stack stays buildable and
//! testable on a clean checkout.
//!
//! Contract: the image must have been preprocessed with a window size K0
//! matching one of the engine's compiled variants whose `m_tile` fits the
//! image's rows/PE (i.e. via [`crate::runtime::Engine::plan`]).

use std::sync::Arc;
use std::time::Instant;

use super::{check_shapes, BackendError, Capability, PrepareCost, PreparedSpmm, SpmmBackend};
use crate::runtime::{Engine, Variant};
use crate::sched::ScheduledMatrix;

/// PJRT/XLA backend factory. Stateless; the engine loads per prepared
/// matrix, inside the preparing thread (PJRT client handles are
/// thread-local).
pub struct PjrtBackend;

impl PjrtBackend {
    /// Construct without loading anything; the engine loads (and compiles
    /// all artifacts) at [`SpmmBackend::prepare`].
    pub fn new() -> PjrtBackend {
        PjrtBackend
    }
}

impl Default for PjrtBackend {
    fn default() -> Self {
        Self::new()
    }
}

fn build_prepared(image: Arc<ScheduledMatrix>) -> Result<PreparedPjrt, BackendError> {
    let t0 = Instant::now();
    let engine =
        Engine::load_default().map_err(|e| BackendError::Unavailable(format!("{e:#}")))?;
    let rows_per_pe = image.rows_per_pe();
    let variant = engine
        .variants()
        .into_iter()
        .find(|v| v.k0 == image.k0 && v.m_tile >= rows_per_pe)
        .ok_or_else(|| {
            BackendError::Unavailable(format!(
                "no compiled variant with k0 = {} and m_tile >= {rows_per_pe}; \
                 preprocess via Engine::plan",
                image.k0
            ))
        })?;
    // Residency today is the A stream staged for the kernels; device
    // buffers for B/C land here when the HBM path arrives.
    let resident_bytes = image.a_stream_bytes();
    Ok(PreparedPjrt {
        image,
        engine,
        variant,
        cost: PrepareCost { wall: t0.elapsed(), resident_bytes },
    })
}

impl SpmmBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn capability(&self) -> Capability {
        Capability {
            threads: 1,
            simd_lanes: 8,
            requires_artifacts: true,
            deterministic: true,
        }
    }

    fn prepare(&self, image: Arc<ScheduledMatrix>) -> Result<Box<dyn PreparedSpmm>, BackendError> {
        Ok(Box::new(build_prepared(image)?))
    }

    /// Without the real engine the stub `Engine` holds no client handles,
    /// so the (never-constructible) prepared handle is trivially
    /// `Send + Sync`. With `pjrt` + `xla` the default refusal stands:
    /// prepare inside the executing thread.
    #[cfg(not(all(feature = "pjrt", feature = "xla")))]
    fn prepare_send(
        &self,
        image: Arc<ScheduledMatrix>,
    ) -> Result<Box<dyn PreparedSpmm + Send + Sync>, BackendError> {
        Ok(Box::new(build_prepared(image)?))
    }
}

/// A matrix resident on the PJRT engine: the loaded engine plus the
/// selected kernel variant for this image.
pub struct PreparedPjrt {
    image: Arc<ScheduledMatrix>,
    engine: Engine,
    variant: Variant,
    cost: PrepareCost,
}

impl PreparedSpmm for PreparedPjrt {
    fn backend_name(&self) -> &'static str {
        "pjrt"
    }

    fn prepare_cost(&self) -> PrepareCost {
        self.cost
    }

    fn execute(
        &self,
        b: &[f32],
        c: &mut [f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<(), BackendError> {
        check_shapes(&self.image, b, c, n)?;
        // `Engine::spmm` takes `&self` and stages its host buffers
        // per call, so the handle carries no per-call mutable state of its
        // own: `&self` execution is direct. (Concurrency across one
        // *real* PJRT handle is still bounded by the engine's thread-local
        // client — those handles never cross threads in the first place.)
        let out = self
            .engine
            .spmm(self.variant, &self.image, b, &*c, n, alpha, beta)
            .map_err(|e| BackendError::Execution(format!("{e:#}")))?;
        c.copy_from_slice(&out);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::preprocess;
    use crate::sparse::Coo;

    #[test]
    fn constructs_without_artifacts() {
        let backend = PjrtBackend::new();
        assert_eq!(backend.name(), "pjrt");
        assert!(backend.capability().requires_artifacts);
    }

    #[test]
    fn prepare_errors_cleanly_when_unavailable() {
        // On a clean checkout (no artifacts dir, real engine off) prepare
        // must refuse with an error, not panic.
        if std::path::Path::new("artifacts/manifest.tsv").exists() && super::super::PJRT_REAL {
            return; // environment actually has a runtime: nothing to assert
        }
        let a = Coo::empty(4, 4);
        let sm = Arc::new(preprocess(&a, 2, 2, 2));
        let err = PjrtBackend::new().prepare(sm).map(|_| ()).unwrap_err();
        assert!(matches!(err, BackendError::Unavailable(_)), "{err}");
    }
}
