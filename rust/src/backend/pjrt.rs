//! PJRT backend adapter: [`crate::runtime::Engine`] (AOT Pallas kernels
//! executed by the PJRT CPU client) behind the [`SpmmBackend`] trait.
//!
//! The engine is loaded lazily on first execution so that constructing the
//! backend (registry listing, server startup) never requires artifacts.
//! Without the `pjrt` cargo feature, `Engine::load` is a stub and every
//! execution reports [`BackendError::Unavailable`] — the serving stack
//! stays buildable and testable on a clean checkout.
//!
//! Contract: the image must have been preprocessed with a window size K0
//! matching one of the engine's compiled variants whose `m_tile` fits the
//! image's rows/PE (i.e. via [`crate::runtime::Engine::plan`]).

use super::{check_shapes, BackendError, Capability, SpmmBackend};
use crate::runtime::Engine;
use crate::sched::ScheduledMatrix;

/// Lazy-loading PJRT/XLA backend.
pub struct PjrtBackend {
    engine: Option<Engine>,
}

impl PjrtBackend {
    /// Construct without loading anything; the engine loads (and compiles
    /// all artifacts) on first [`SpmmBackend::execute`].
    pub fn new() -> PjrtBackend {
        PjrtBackend { engine: None }
    }

    fn engine(&mut self) -> Result<&Engine, BackendError> {
        if self.engine.is_none() {
            let engine = Engine::load_default()
                .map_err(|e| BackendError::Unavailable(format!("{e:#}")))?;
            self.engine = Some(engine);
        }
        Ok(self.engine.as_ref().unwrap())
    }
}

impl Default for PjrtBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl SpmmBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn capability(&self) -> Capability {
        Capability {
            threads: 1,
            simd_lanes: 8,
            requires_artifacts: true,
            deterministic: true,
        }
    }

    fn execute(
        &mut self,
        sm: &ScheduledMatrix,
        b: &[f32],
        c: &mut [f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<(), BackendError> {
        check_shapes(sm, b, c, n)?;
        let rows_per_pe = sm.rows_per_pe();
        let engine = self.engine()?;
        let variant = engine
            .variants()
            .into_iter()
            .find(|v| v.k0 == sm.k0 && v.m_tile >= rows_per_pe)
            .ok_or_else(|| {
                BackendError::Unavailable(format!(
                    "no compiled variant with k0 = {} and m_tile >= {rows_per_pe}; \
                     preprocess via Engine::plan",
                    sm.k0
                ))
            })?;
        let out = engine
            .spmm(variant, sm, b, &*c, n, alpha, beta)
            .map_err(|e| BackendError::Execution(format!("{e:#}")))?;
        c.copy_from_slice(&out);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::preprocess;
    use crate::sparse::Coo;

    #[test]
    fn constructs_without_artifacts() {
        let backend = PjrtBackend::new();
        assert_eq!(backend.name(), "pjrt");
        assert!(backend.capability().requires_artifacts);
    }

    #[test]
    fn execute_errors_cleanly_when_unavailable() {
        // On a clean checkout (no artifacts dir, `pjrt` feature off) the
        // backend must refuse with an error, not panic.
        if std::path::Path::new("artifacts/manifest.tsv").exists() && cfg!(feature = "pjrt") {
            return; // environment actually has a runtime: nothing to assert
        }
        let a = Coo::empty(4, 4);
        let sm = preprocess(&a, 2, 2, 2);
        let b = vec![0.0; 8];
        let mut c = vec![0.0; 8];
        let err = PjrtBackend::new().execute(&sm, &b, &mut c, 2, 1.0, 0.0).unwrap_err();
        assert!(matches!(
            err,
            BackendError::Unavailable(_) | BackendError::Execution(_)
        ));
    }
}
