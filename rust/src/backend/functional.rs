//! The functional-simulator backend: [`crate::arch::functional::execute`]
//! behind the prepare/execute contract — serial, dependency-free, and the
//! reference semantics every other backend is tested against.
//!
//! The prepared handle keeps nothing resident beyond the shared image
//! (`resident_bytes = 0`): the simulator consumes the encoded streams
//! directly, so prepare is effectively free. That makes this backend the
//! baseline for amortization measurements too — and, with no per-call
//! state at all, trivially `&self`-executable: concurrent callers share
//! one handle with zero coordination.

use std::sync::Arc;
use std::time::Instant;

use super::{check_shapes, BackendError, Capability, PrepareCost, PreparedSpmm, SpmmBackend};
use crate::arch::functional;
use crate::sched::ScheduledMatrix;

/// Serial functional-simulator backend (exact FP32 datapath numerics).
pub struct FunctionalBackend;

impl SpmmBackend for FunctionalBackend {
    fn name(&self) -> &'static str {
        "functional"
    }

    fn capability(&self) -> Capability {
        Capability {
            threads: 1,
            simd_lanes: 1,
            requires_artifacts: false,
            deterministic: true,
        }
    }

    fn prepare(&self, image: Arc<ScheduledMatrix>) -> Result<Box<dyn PreparedSpmm>, BackendError> {
        Ok(Box::new(PreparedFunctional::new(image)))
    }

    fn prepare_send(
        &self,
        image: Arc<ScheduledMatrix>,
    ) -> Result<Box<dyn PreparedSpmm + Send + Sync>, BackendError> {
        Ok(Box::new(PreparedFunctional::new(image)))
    }
}

/// A matrix "resident" on the functional simulator — just the shared image.
pub struct PreparedFunctional {
    image: Arc<ScheduledMatrix>,
    cost: PrepareCost,
}

impl PreparedFunctional {
    fn new(image: Arc<ScheduledMatrix>) -> PreparedFunctional {
        let t0 = Instant::now();
        PreparedFunctional {
            image,
            cost: PrepareCost { wall: t0.elapsed(), resident_bytes: 0 },
        }
    }
}

impl PreparedSpmm for PreparedFunctional {
    fn backend_name(&self) -> &'static str {
        "functional"
    }

    fn prepare_cost(&self) -> PrepareCost {
        self.cost
    }

    fn execute(
        &self,
        b: &[f32],
        c: &mut [f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<(), BackendError> {
        check_shapes(&self.image, b, c, n)?;
        functional::execute(&self.image, b, c, n, alpha, beta);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::sched::preprocess;
    use crate::sparse::{gen, rng::Rng};

    #[test]
    fn adapter_matches_direct_call() {
        let mut rng = Rng::new(1);
        let a = gen::random_uniform(30, 25, 0.2, &mut rng);
        let sm = Arc::new(preprocess(&a, 4, 8, 5));
        let n = 3;
        let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..a.m * n).map(|_| rng.normal()).collect();
        let mut got = c0.clone();
        let handle = FunctionalBackend.prepare(Arc::clone(&sm)).unwrap();
        handle.execute(&b, &mut got, n, 1.5, 0.5).unwrap();
        let mut want = c0;
        functional::execute(&sm, &b, &mut want, n, 1.5, 0.5);
        assert_eq!(got, want);
        assert_eq!(handle.backend_name(), "functional");
        assert_eq!(handle.prepare_cost().resident_bytes, 0);
    }

    #[test]
    fn rejects_bad_shapes_instead_of_panicking() {
        let mut rng = Rng::new(2);
        let a = gen::random_uniform(8, 8, 0.3, &mut rng);
        let sm = Arc::new(preprocess(&a, 2, 4, 3));
        let b = vec![0.0; 5];
        let mut c = vec![0.0; 16];
        let err = FunctionalBackend
            .prepare(sm)
            .unwrap()
            .execute(&b, &mut c, 2, 1.0, 0.0)
            .unwrap_err();
        assert!(matches!(err, BackendError::Shape(_)));
        prop::assert_allclose(&c, &vec![0.0; 16], 0.0, 0.0).unwrap();
    }
}
