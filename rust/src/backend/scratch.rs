//! A tiny checkout/return scratch pool — the mechanism that lets prepared
//! handles execute through `&self`.
//!
//! Every engine needs per-call mutable state (C-accumulation tiles,
//! per-shard gather blocks, staging buffers). With `&mut self` execution
//! that state lived in the handle and forced callers to serialize; the
//! pool inverts the ownership: the handle keeps a [`ScratchPool`], each
//! execution checks a scratch set out for the duration of the call, and
//! the set returns automatically when the call finishes. The pool's lock
//! guards only the push/pop of the slot vector — a few nanoseconds — never
//! the multiply itself, so W concurrent executions proceed with W
//! independent scratch sets and zero contention on the hot path.
//!
//! Sizing invariant: a slot exists only while checked out or parked in the
//! pool, and a checkout always drains the pool before allocating, so the
//! pool never holds more sets than the peak number of *concurrent*
//! executions — W workers hammering one handle grow it to at most W sets
//! (asserted by the unit tests below and the backend integration tests).
//!
//! Accounting: [`crate::backend::PrepareCost::resident_bytes`] is captured
//! at prepare time with one (seed) scratch set; a pool that has grown
//! under concurrency holds up to W−1 additional sets beyond that estimate.
//! [`ScratchPool::measure`] sums a caller-supplied byte function over the
//! parked slots, and engines surface the live total through
//! [`crate::backend::PreparedSpmm::resident_bytes_now`] — the serving
//! residency stage refreshes its byte-budgeted accounting from that after
//! each execution, so hot handles are charged for their real footprint.

use std::ops::{Deref, DerefMut};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Guaranteed alignment (bytes) of [`AlignedVec`] storage — one full
/// 256-bit AVX2 register, so vector loads/stores on scratch tiles are
/// never split across cache lines by an unlucky allocator.
pub const SCRATCH_ALIGN: usize = 32;

/// A grow-only f32 buffer whose storage is always [`SCRATCH_ALIGN`]-byte
/// aligned — the scratch currency of the SIMD-era native engine.
/// `Vec<f32>` only guarantees 4-byte alignment, which splits 256-bit
/// accumulator loads across cache lines often enough to show up in
/// `bench_backend`; this keeps the hot C_AB tiles register-friendly.
///
/// Deliberately minimal: it derefs to `[f32]` of its current logical
/// length, and [`AlignedVec::ensure_len`] grows (never shrinks) the
/// buffer, zero-filling any newly exposed region. Contents are otherwise
/// scratch — callers overwrite them per use.
#[derive(Debug, Default)]
pub struct AlignedVec {
    ptr: Option<std::ptr::NonNull<f32>>,
    /// Current logical (and allocated) length in f32 elements.
    len: usize,
}

// SAFETY: AlignedVec uniquely owns its allocation; it is a plain buffer
// of f32 with no interior mutability or thread affinity.
unsafe impl Send for AlignedVec {}
unsafe impl Sync for AlignedVec {}

impl AlignedVec {
    /// An empty buffer; storage is allocated by [`AlignedVec::ensure_len`].
    pub fn new() -> AlignedVec {
        AlignedVec { ptr: None, len: 0 }
    }

    /// A zero-filled buffer of `len` elements, allocated up front (the
    /// prepare-time seeding path).
    pub fn zeroed(len: usize) -> AlignedVec {
        let mut v = AlignedVec::new();
        v.ensure_len(len);
        v
    }

    /// Current length in f32 elements.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the buffer holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn layout(len: usize) -> std::alloc::Layout {
        std::alloc::Layout::from_size_align(len * 4, SCRATCH_ALIGN)
            .expect("scratch tile layout within address-space bounds")
    }

    /// Grow to at least `len` elements (no-op when already large enough);
    /// new storage is zero-filled and the old contents are preserved.
    pub fn ensure_len(&mut self, len: usize) {
        if len <= self.len {
            return;
        }
        // SAFETY: the layout is non-zero-sized (len > self.len >= 0), the
        // old pointer (when present) came from the same allocator with
        // its own length's layout, and the copy stays within both
        // allocations.
        unsafe {
            let raw = std::alloc::alloc_zeroed(Self::layout(len)) as *mut f32;
            let ptr = std::ptr::NonNull::new(raw)
                .unwrap_or_else(|| std::alloc::handle_alloc_error(Self::layout(len)));
            if let Some(old) = self.ptr.take() {
                std::ptr::copy_nonoverlapping(old.as_ptr(), ptr.as_ptr(), self.len);
                std::alloc::dealloc(old.as_ptr() as *mut u8, Self::layout(self.len));
            }
            self.ptr = Some(ptr);
            self.len = len;
        }
    }
}

impl Drop for AlignedVec {
    fn drop(&mut self) {
        if let Some(ptr) = self.ptr.take() {
            // SAFETY: allocated by ensure_len with exactly this layout.
            unsafe { std::alloc::dealloc(ptr.as_ptr() as *mut u8, Self::layout(self.len)) }
        }
    }
}

impl Deref for AlignedVec {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        match self.ptr {
            // SAFETY: `len` elements were allocated and zero-initialized.
            Some(ptr) => unsafe { std::slice::from_raw_parts(ptr.as_ptr(), self.len) },
            None => &[],
        }
    }
}

impl DerefMut for AlignedVec {
    fn deref_mut(&mut self) -> &mut [f32] {
        match self.ptr {
            // SAFETY: `len` elements were allocated and zero-initialized;
            // `&mut self` guarantees exclusive access.
            Some(ptr) => unsafe { std::slice::from_raw_parts_mut(ptr.as_ptr(), self.len) },
            None => &mut [],
        }
    }
}

/// One parked slot plus the moment it was returned — the idle clock
/// [`ScratchPool::trim_idle`] reads.
#[derive(Debug)]
struct Parked<T> {
    value: T,
    since: Instant,
}

/// A checkout/return pool of reusable scratch values. Cheap to construct;
/// `Sync` whenever `T: Send`, which is what lets handles holding one be
/// shared across threads.
#[derive(Debug, Default)]
pub struct ScratchPool<T> {
    slots: Mutex<Vec<Parked<T>>>,
}

impl<T> ScratchPool<T> {
    /// An empty pool; slots are created lazily by [`ScratchPool::checkout`].
    pub fn new() -> ScratchPool<T> {
        ScratchPool { slots: Mutex::new(Vec::new()) }
    }

    /// A pool seeded with one ready slot — engines pre-size their scratch
    /// at prepare time so the first execution allocates nothing.
    pub fn with_seed(seed: T) -> ScratchPool<T> {
        ScratchPool {
            slots: Mutex::new(vec![Parked { value: seed, since: Instant::now() }]),
        }
    }

    /// Check a slot out, building a fresh one with `make` only when every
    /// parked slot is already in use. The returned guard derefs to `T` and
    /// parks the slot back on drop (including on panic/unwind).
    pub fn checkout(&self, make: impl FnOnce() -> T) -> Scratch<'_, T> {
        let recycled = self.slots.lock().unwrap().pop().map(|p| p.value);
        Scratch { pool: self, item: Some(recycled.unwrap_or_else(make)) }
    }

    /// Slots currently parked in the pool (none checked out ⇒ the pool's
    /// total footprint). Exposed so tests can assert the sizing invariant.
    pub fn idle(&self) -> usize {
        self.slots.lock().unwrap().len()
    }

    /// Sum `bytes_of` over the parked slots — the pool's current resident
    /// footprint (checked-out slots are transient call state and excluded
    /// on purpose). Engines use this to implement
    /// [`crate::backend::PreparedSpmm::resident_bytes_now`].
    pub fn measure(&self, bytes_of: impl Fn(&T) -> u64) -> u64 {
        self.slots.lock().unwrap().iter().map(|p| bytes_of(&p.value)).sum()
    }

    /// Drop every slot parked for longer than `max_idle` and return the
    /// bytes reclaimed (per `bytes_of`). A pool sized by a concurrency
    /// burst otherwise holds its high-water footprint forever; engines
    /// expose this through
    /// [`crate::backend::PreparedSpmm::trim_resident`] so the serving
    /// residency stage can shrink cold handles — the reclaim shows up in
    /// the next [`crate::backend::PreparedSpmm::resident_bytes_now`]
    /// measurement. Checkout order is LIFO, so under steady load the
    /// stale tail is exactly the burst surplus.
    pub fn trim_idle(&self, max_idle: Duration, bytes_of: impl Fn(&T) -> u64) -> u64 {
        let mut slots = self.slots.lock().unwrap();
        let mut reclaimed = 0;
        slots.retain(|p| {
            if p.since.elapsed() > max_idle {
                reclaimed += bytes_of(&p.value);
                false
            } else {
                true
            }
        });
        reclaimed
    }
}

/// RAII checkout from a [`ScratchPool`]: deref to the scratch value, return
/// it to the pool on drop.
pub struct Scratch<'p, T> {
    pool: &'p ScratchPool<T>,
    item: Option<T>,
}

impl<T> Deref for Scratch<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        self.item.as_ref().expect("scratch present until drop")
    }
}

impl<T> DerefMut for Scratch<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.item.as_mut().expect("scratch present until drop")
    }
}

impl<T> Drop for Scratch<'_, T> {
    fn drop(&mut self) {
        if let Some(item) = self.item.take() {
            self.pool
                .slots
                .lock()
                .unwrap()
                .push(Parked { value: item, since: Instant::now() });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn checkout_reuses_parked_slots() {
        let made = AtomicUsize::new(0);
        let pool: ScratchPool<Vec<f32>> = ScratchPool::new();
        for _ in 0..10 {
            let mut s = pool.checkout(|| {
                made.fetch_add(1, Ordering::Relaxed);
                vec![0.0; 8]
            });
            s[0] = 1.0;
        }
        assert_eq!(made.load(Ordering::Relaxed), 1, "sequential reuse allocates once");
        assert_eq!(pool.idle(), 1);
    }

    #[test]
    fn seeded_pool_starts_with_one_ready_slot() {
        let pool = ScratchPool::with_seed(vec![0.0f32; 16]);
        assert_eq!(pool.idle(), 1);
        {
            let s = pool.checkout(|| panic!("the seed must satisfy the first checkout"));
            assert_eq!(s.len(), 16);
            assert_eq!(pool.idle(), 0, "checked-out slots leave the pool");
        }
        assert_eq!(pool.idle(), 1, "drop parks the slot back");
    }

    #[test]
    fn pool_never_grows_beyond_peak_concurrency() {
        // W threads × many checkouts each: the pool ends with at most W
        // slots — the sizing invariant the &self execution path relies on.
        let pool: ScratchPool<Vec<u8>> = ScratchPool::new();
        let workers = 4;
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| {
                    for _ in 0..200 {
                        let mut s = pool.checkout(|| vec![0u8; 64]);
                        s[0] = s[0].wrapping_add(1);
                    }
                });
            }
        });
        assert!(
            pool.idle() <= workers,
            "pool grew to {} slots with only {workers} concurrent users",
            pool.idle()
        );
        assert!(pool.idle() >= 1, "at least one slot survives for reuse");
    }

    #[test]
    fn measure_sums_parked_slots_only() {
        let pool: ScratchPool<Vec<u8>> = ScratchPool::new();
        assert_eq!(pool.measure(|s| s.len() as u64), 0, "empty pool holds no bytes");
        let a = pool.checkout(|| vec![0u8; 100]);
        let b = pool.checkout(|| vec![0u8; 28]);
        assert_eq!(
            pool.measure(|s| s.len() as u64),
            0,
            "checked-out slots are call state, not resident footprint"
        );
        drop(a);
        drop(b);
        assert_eq!(pool.measure(|s| s.len() as u64), 128);
    }

    #[test]
    fn trim_idle_reclaims_stale_slots_and_reports_bytes() {
        let pool: ScratchPool<Vec<u8>> = ScratchPool::new();
        {
            let a = pool.checkout(|| vec![0u8; 100]);
            let b = pool.checkout(|| vec![0u8; 28]);
            drop(a);
            drop(b);
        }
        assert_eq!(pool.idle(), 2);
        // Nothing is older than an hour: nothing reclaimed.
        let reclaimed =
            pool.trim_idle(std::time::Duration::from_secs(3600), |s| s.len() as u64);
        assert_eq!(reclaimed, 0);
        assert_eq!(pool.idle(), 2);
        // Zero high-water timeout: everything parked is stale.
        std::thread::sleep(std::time::Duration::from_millis(2));
        let reclaimed = pool.trim_idle(std::time::Duration::ZERO, |s| s.len() as u64);
        assert_eq!(reclaimed, 128, "reclaim reports the bytes of dropped slots");
        assert_eq!(pool.idle(), 0);
        assert_eq!(pool.measure(|s| s.len() as u64), 0, "footprint reflects the trim");
    }

    #[test]
    fn trim_spares_recently_used_slots() {
        let pool: ScratchPool<Vec<u8>> = ScratchPool::new();
        {
            let a = pool.checkout(|| vec![0u8; 64]);
            drop(a);
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
        {
            // Touch one slot now; it was just returned so it must survive a
            // 10ms high-water trim while nothing else does.
            let b = pool.checkout(|| vec![0u8; 16]);
            let c = pool.checkout(|| vec![0u8; 256]);
            drop(c);
            std::thread::sleep(std::time::Duration::from_millis(20));
            drop(b);
        }
        let reclaimed =
            pool.trim_idle(std::time::Duration::from_millis(10), |s| s.len() as u64);
        assert_eq!(reclaimed, 256, "only the stale slot is reclaimed");
        assert_eq!(pool.idle(), 1);
    }

    #[test]
    fn aligned_vec_guarantees_alignment_across_growth() {
        let mut v = AlignedVec::new();
        assert!(v.is_empty());
        for len in [1usize, 7, 8, 64, 1000] {
            v.ensure_len(len);
            assert_eq!(v.len(), len);
            assert_eq!(
                v.as_ptr() as usize % SCRATCH_ALIGN,
                0,
                "storage unaligned at len {len}"
            );
        }
        // Grow-only: a smaller request keeps the larger buffer.
        v.ensure_len(3);
        assert_eq!(v.len(), 1000);
    }

    #[test]
    fn aligned_vec_zero_fills_and_preserves_contents() {
        let mut v = AlignedVec::zeroed(4);
        assert_eq!(&v[..], &[0.0; 4]);
        v[0] = 1.5;
        v[3] = -2.0;
        v.ensure_len(10);
        assert_eq!(v[0], 1.5);
        assert_eq!(v[3], -2.0);
        assert_eq!(&v[4..], &[0.0; 6], "newly exposed region must be zeroed");
    }

    #[test]
    fn aligned_vec_pools_like_any_scratch() {
        let pool: ScratchPool<AlignedVec> = ScratchPool::with_seed(AlignedVec::zeroed(16));
        {
            let mut s = pool.checkout(AlignedVec::new);
            assert_eq!(s.len(), 16);
            s.ensure_len(32);
        }
        assert_eq!(pool.measure(|v| v.len() as u64 * 4), 128, "grown tile parked back");
    }

    #[test]
    fn nested_checkouts_get_distinct_slots() {
        let pool: ScratchPool<Vec<u8>> = ScratchPool::new();
        let mut a = pool.checkout(|| vec![1u8]);
        let b = pool.checkout(|| vec![2u8]);
        a[0] = 9;
        assert_eq!(b[0], 2, "overlapping checkouts must not alias");
        drop(a);
        drop(b);
        assert_eq!(pool.idle(), 2);
    }
}
