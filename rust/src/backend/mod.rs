//! Pluggable SpMM execution backends with a **two-phase prepare/execute
//! contract** — the HFlex promise (§3.4) made explicit in the API:
//! preprocess A once, then run arbitrarily many SpMMs against it.
//!
//! A [`SpmmBackend`] is a stateless *factory* selected by registry name; it
//! does no work per request. All per-matrix state lives in the
//! [`PreparedSpmm`] handle returned by [`SpmmBackend::prepare`]:
//!
//! * [`native::NativeBackend`] — multi-threaded host engine. Its handle
//!   pre-decodes every PE stream (bubbles dropped, window-local columns
//!   resolved to global), condenses it into per-output-row SoA segments,
//!   and pre-sizes the per-worker aligned accumulators, so steady-state
//!   execution is pure vectorized axpy + Comp-C through the [`simd`]
//!   kernel layer (runtime-dispatched AVX2 with a bit-identical scalar
//!   fallback; `SEXTANS_SIMD=scalar` forces the portable path).
//! * [`functional::FunctionalBackend`] — the functional simulator
//!   ([`crate::arch::functional`]); the always-available reference
//!   semantics.
//! * [`pjrt::PjrtBackend`] — adapter over [`crate::runtime::Engine`] (AOT
//!   Pallas kernels via PJRT). The engine loads and the kernel variant is
//!   selected at *prepare* time — the handle is where device residency
//!   lives. Needs the `pjrt` + `xla` cargo features and compiled artifacts.
//! * [`crate::shard::ShardedBackend`] — composite (`"sharded:<S>:<inner>"`):
//!   its handle owns the shard plan, one preprocessed image per shard, and
//!   one *prepared inner handle* per shard. Sharding happens exactly once
//!   per prepared matrix — never per request.
//!
//! One-shot callers use the provided [`SpmmBackend::execute_once`] shim;
//! everything that serves more than one request against the same A (the
//! coordinator, the HFlex accelerator, the benches) holds a handle.
//!
//! Execution is **shared-read**: every `execute*` method takes `&self`, so
//! one handle sustains arbitrarily many *concurrent* multiplications — the
//! Sextans serving shape (one scheduled A, a stream of dense operands)
//! without a per-matrix lock. All per-call mutable state (C-accumulation
//! tiles, per-shard gather blocks) is drawn from an internal
//! [`ScratchPool`], whose lock guards only the tiny checkout/return — never
//! the multiply.
//!
//! Backends are selected by name through [`create`] (`"native"`,
//! `"native:4"`, `"native-blocked"`, `"functional"`, `"pjrt"`,
//! `"sharded:4:native"`), so servers and CLIs stay backend-agnostic.
//! [`apply_thread_budget`] rewrites auto-threaded specs to fit a global
//! core budget, so stacked parallelism (server workers × shards × engine
//! threads) never oversubscribes the machine.

pub mod functional;
pub mod native;
pub mod pjrt;
pub mod scratch;
pub mod simd;

pub use functional::FunctionalBackend;
pub use native::NativeBackend;
pub use pjrt::PjrtBackend;
pub use scratch::{AlignedVec, Scratch, ScratchPool, SCRATCH_ALIGN};

use std::sync::Arc;
use std::time::Duration;

use crate::sched::ScheduledMatrix;

/// True when the real PJRT engine is compiled in (`pjrt` + `xla` features;
/// see `runtime`). With `pjrt` alone the engine is the API-identical stub,
/// so that feature combination stays buildable in artifact-free
/// environments (CI exercises it).
pub const PJRT_REAL: bool = cfg!(all(feature = "pjrt", feature = "xla"));

/// Why a backend refused or failed a prepare or an execution.
#[derive(Debug, PartialEq)]
pub enum BackendError {
    /// No backend registered under the requested name.
    Unknown(String),
    /// The spec string parsed, but its argument is invalid.
    InvalidSpec(String),
    /// The backend cannot run in this environment (missing feature,
    /// missing artifacts, ...).
    Unavailable(String),
    /// B/C buffer shapes do not match the image and N.
    Shape(String),
    /// The backend started but failed mid-execution.
    Execution(String),
}

impl std::fmt::Display for BackendError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BackendError::Unknown(s) => write!(
                f,
                "unknown backend {s:?} (expected one of: {})",
                names().join(", ")
            ),
            BackendError::InvalidSpec(s) => write!(f, "invalid backend spec: {s}"),
            BackendError::Unavailable(s) => write!(f, "backend unavailable: {s}"),
            BackendError::Shape(s) => write!(f, "shape mismatch: {s}"),
            BackendError::Execution(s) => write!(f, "execution failed: {s}"),
        }
    }
}

impl std::error::Error for BackendError {}

/// What a backend can do — reported, not probed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Capability {
    /// Worker threads used on the hot path (1 = serial).
    pub threads: usize,
    /// Inner-loop vector width the implementation is shaped around.
    pub simd_lanes: usize,
    /// Needs AOT artifacts / external runtime to execute.
    pub requires_artifacts: bool,
    /// Same image + inputs always produce bit-identical output.
    pub deterministic: bool,
}

/// What one [`SpmmBackend::prepare`] cost and what the handle keeps
/// resident — the amortization report serving stacks aggregate (prepare
/// once, execute many).
#[derive(Clone, Copy, Debug, Default)]
pub struct PrepareCost {
    /// Wall-clock time spent preparing the matrix.
    pub wall: Duration,
    /// Bytes of per-matrix state the handle keeps resident beyond the
    /// shared [`ScheduledMatrix`] (decoded streams, shard images, scratch,
    /// device buffers).
    pub resident_bytes: u64,
}

/// What one `execute*_with_report` call did, returned *by value* so the
/// facts belong to the caller that ran the job. The older
/// [`PreparedSpmm::shard_stats`] poll reads a last-run cell that concurrent
/// executions overwrite (last-finisher-wins); the report path has no such
/// race — the serving dispatch uses it to attribute shard metrics to the
/// exact request that produced them.
#[derive(Clone, Debug, Default)]
pub struct ExecutionReport {
    /// Internal units skipped by routed execution (0 on the plain path and
    /// for single-unit engines).
    pub skipped: usize,
    /// Shard-level statistics of *this* call, for handles that shard
    /// internally; `None` for single-unit engines.
    pub shard_stats: Option<crate::shard::ShardRunStats>,
    /// Distributed-fleet statistics of *this* call; `None` for local
    /// engines. Set by the `remote:<addr>` backend so the serving
    /// dispatch can attribute placement/retry/re-place counters to the
    /// exact request that incurred them.
    pub remote: Option<RemoteStats>,
}

/// What one distributed execution did across the worker fleet — the
/// per-call facts behind the `remote_*` counters in
/// [`crate::coordinator::metrics::Summary`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RemoteStats {
    /// Workers in the fleet (reachable or not).
    pub workers: usize,
    /// Workers whose supervised liveness is Live after this call
    /// (Suspect and Dead workers are excluded).
    pub live_workers: usize,
    /// Shard placements currently live across the fleet (replicas
    /// included).
    pub placements: usize,
    /// Effective replication factor (requested R clamped to fleet size).
    pub replicas: usize,
    /// Failed RPC attempts that were retried on another replica during
    /// this call.
    pub retries: usize,
    /// Shards re-placed (re-prepared on a fresh worker) during this call.
    pub replaced: usize,
    /// Circuit-breaker trips (closed → open edges) since the handle was
    /// prepared.
    pub breaker_trips: usize,
    /// Liveness transitions (any direction) observed by the heartbeat
    /// supervisor since the handle was prepared.
    pub transitions: usize,
    /// Placements proactively re-placed by membership-driven rebalancing
    /// since the handle was prepared.
    pub rebalanced: usize,
}

/// A matrix-resident execution handle: one preprocessed A, arbitrarily many
/// SpMMs. Handles own all per-matrix state (scratch pools, shard plans,
/// device buffers), so nothing is rebuilt between calls — N and the scalars
/// may change freely per call.
///
/// Execution takes `&self`: the resident image and decoded streams are
/// read-only, and per-call mutable state comes from an internal
/// [`ScratchPool`], so any number of threads may execute against one
/// handle concurrently (share the handle via `Arc`, no mutex).
///
/// Handles are not required to be `Send` (the real PJRT engine's client is
/// thread-local); use [`SpmmBackend::prepare_send`] when the handle must
/// cross threads — its handles are additionally `Sync`, the shared
/// concurrent-execution contract.
pub trait PreparedSpmm {
    /// Registry name of the engine that prepared this handle.
    fn backend_name(&self) -> &'static str;

    /// What prepare cost and what stays resident.
    fn prepare_cost(&self) -> PrepareCost;

    /// Execute `C = alpha * A @ B + beta * C` against the resident matrix,
    /// where `b` is row-major `k x n` and `c` is row-major `m x n`.
    fn execute(
        &self,
        b: &[f32],
        c: &mut [f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<(), BackendError>;

    /// Execute several (B, C) pairs against the same resident matrix, all
    /// with the same `n`, `alpha`, `beta` — the multi-B serving shape (one
    /// sparse A, a stream of dense operands). The default runs the pairs
    /// sequentially; engines may override to amortize further.
    fn execute_batch(
        &self,
        jobs: &mut [(&[f32], &mut [f32])],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<(), BackendError> {
        for (b, c) in jobs.iter_mut() {
            self.execute(b, c, n, alpha, beta)?;
        }
        Ok(())
    }

    /// Shard-level statistics of the most recent successful [`execute`]
    /// (see [`crate::shard`]). Non-sharding engines keep the default
    /// `None`. With concurrent executions the "most recent" run is
    /// whichever finished last — callers that need the stats of *their*
    /// call use [`execute_with_report`] /
    /// [`execute_routed_with_report`] instead (the serving dispatch does);
    /// this poll remains for diagnostics and compatibility.
    ///
    /// [`execute`]: PreparedSpmm::execute
    /// [`execute_with_report`]: PreparedSpmm::execute_with_report
    /// [`execute_routed_with_report`]: PreparedSpmm::execute_routed_with_report
    fn shard_stats(&self) -> Option<crate::shard::ShardRunStats> {
        None
    }

    /// Number of internal shard units this handle partitions its matrix
    /// across (`None` for single-unit engines). The serving residency
    /// stage tracks this to drive re-shard-on-skew rebuilds.
    fn resident_shards(&self) -> Option<usize> {
        None
    }

    /// Routing hook for shard-aware batching: like [`execute`], but a
    /// composite handle may skip internal units that own no non-zeros —
    /// their rows receive exactly the `beta * C` update the engine would
    /// have computed, so results stay bit-identical. Returns the number of
    /// units skipped; single-unit engines keep this default (a plain
    /// execute, 0 skipped). The serving batcher dispatches small-N merged
    /// jobs through this path, where per-unit fan-out overhead rivals the
    /// useful work.
    ///
    /// [`execute`]: PreparedSpmm::execute
    fn execute_routed(
        &self,
        b: &[f32],
        c: &mut [f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<usize, BackendError> {
        self.execute(b, c, n, alpha, beta)?;
        Ok(0)
    }

    /// [`execute`] returning a per-call [`ExecutionReport`]. Unlike the
    /// [`shard_stats`] poll, the report cannot be clobbered by a concurrent
    /// execution finishing later — sharding handles override this to return
    /// the stats of exactly this call. The default wraps a plain execute
    /// (no units, no stats).
    ///
    /// [`execute`]: PreparedSpmm::execute
    /// [`shard_stats`]: PreparedSpmm::shard_stats
    fn execute_with_report(
        &self,
        b: &[f32],
        c: &mut [f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<ExecutionReport, BackendError> {
        self.execute(b, c, n, alpha, beta)?;
        Ok(ExecutionReport::default())
    }

    /// [`execute_routed`] returning a per-call [`ExecutionReport`] — the
    /// routed counterpart of [`execute_with_report`], same race-free
    /// attribution. The default wraps `execute_routed` so composites that
    /// only override the older method still report their skip count.
    ///
    /// [`execute_routed`]: PreparedSpmm::execute_routed
    /// [`execute_with_report`]: PreparedSpmm::execute_with_report
    fn execute_routed_with_report(
        &self,
        b: &[f32],
        c: &mut [f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<ExecutionReport, BackendError> {
        let skipped = self.execute_routed(b, c, n, alpha, beta)?;
        Ok(ExecutionReport { skipped, ..ExecutionReport::default() })
    }

    /// Bytes this handle keeps resident *right now*, including per-call
    /// scratch that has accumulated in internal pools since prepare. The
    /// default repeats [`prepare_cost`]'s static estimate; engines with
    /// growing pools override it so the residency stage's byte-budgeted
    /// eviction sees the true cost of a hot handle.
    ///
    /// [`prepare_cost`]: PreparedSpmm::prepare_cost
    fn resident_bytes_now(&self) -> u64 {
        self.prepare_cost().resident_bytes
    }

    /// Release internal scratch that has sat idle longer than `max_idle`,
    /// returning the bytes reclaimed. Scratch pools grow to the peak
    /// concurrency a handle ever saw and otherwise hold that high-water
    /// footprint forever; the serving residency stage calls this on cold
    /// handles so the reclaim shows up in the next
    /// [`resident_bytes_now`] measurement. Engines without trimmable
    /// state keep this default no-op.
    ///
    /// [`resident_bytes_now`]: PreparedSpmm::resident_bytes_now
    fn trim_resident(&self, max_idle: Duration) -> u64 {
        let _ = max_idle;
        0
    }
}

impl std::fmt::Debug for dyn PreparedSpmm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PreparedSpmm({})", self.backend_name())
    }
}

impl std::fmt::Debug for dyn PreparedSpmm + Send + Sync {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PreparedSpmm({})", self.backend_name())
    }
}

/// One SpMM execution engine: a stateless, shareable factory that turns
/// preprocessed images into matrix-resident [`PreparedSpmm`] handles.
///
/// Factories are `Send + Sync` (they hold configuration, never client
/// handles or scratch); per-thread affinity concerns live entirely in the
/// handles, which is why [`prepare`] and [`prepare_send`] are distinct.
///
/// [`prepare`]: SpmmBackend::prepare
/// [`prepare_send`]: SpmmBackend::prepare_send
pub trait SpmmBackend: Send + Sync {
    /// Stable registry name (also recorded in serving metrics).
    fn name(&self) -> &'static str;

    /// Capability / identity report.
    fn capability(&self) -> Capability;

    /// Build a matrix-resident handle for `image`. This is the build path:
    /// everything per-matrix (stream decoding, shard planning, engine
    /// loading, scratch sizing) happens here, exactly once.
    fn prepare(&self, image: Arc<ScheduledMatrix>) -> Result<Box<dyn PreparedSpmm>, BackendError>;

    /// Like [`prepare`], but the handle may cross threads *and* be shared
    /// between them (`Send + Sync`): wrap it in an `Arc` and any number of
    /// workers execute against it concurrently. Engines whose handles are
    /// thread-local (the real PJRT engine) keep this default refusal —
    /// prepare inside the executing thread instead (the serving
    /// coordinator's workers do).
    ///
    /// [`prepare`]: SpmmBackend::prepare
    fn prepare_send(
        &self,
        image: Arc<ScheduledMatrix>,
    ) -> Result<Box<dyn PreparedSpmm + Send + Sync>, BackendError> {
        let _ = image;
        Err(BackendError::Unavailable(format!(
            "backend {:?} prepares thread-local handles; call prepare() inside the \
             executing thread",
            self.name()
        )))
    }

    /// One-shot shim: prepare + execute + drop, for callers that genuinely
    /// run a single SpMM per matrix. Anything serving repeated requests
    /// should hold the [`PreparedSpmm`] handle instead — that is the whole
    /// point of the two-phase contract.
    fn execute_once(
        &self,
        image: &Arc<ScheduledMatrix>,
        b: &[f32],
        c: &mut [f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<(), BackendError> {
        self.prepare(Arc::clone(image))?.execute(b, c, n, alpha, beta)
    }
}

impl std::fmt::Debug for dyn SpmmBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SpmmBackend({})", self.name())
    }
}

/// Validate B/C buffer shapes against the image (shared by backends).
pub(crate) fn check_shapes(
    sm: &ScheduledMatrix,
    b: &[f32],
    c: &[f32],
    n: usize,
) -> Result<(), BackendError> {
    if b.len() != sm.k * n {
        return Err(BackendError::Shape(format!(
            "B has {} elements, expected K*N = {}",
            b.len(),
            sm.k * n
        )));
    }
    if c.len() != sm.m * n {
        return Err(BackendError::Shape(format!(
            "C has {} elements, expected M*N = {}",
            c.len(),
            sm.m * n
        )));
    }
    Ok(())
}

/// A registry row: name, availability in this build, one-line description.
#[derive(Clone, Copy, Debug)]
pub struct BackendInfo {
    /// Registry name accepted by [`create`].
    pub name: &'static str,
    /// Whether [`create`]d instances can actually execute in this build.
    pub available: bool,
    /// Human-readable summary.
    pub description: &'static str,
}

/// The registered backends, in preference order.
pub fn registry() -> Vec<BackendInfo> {
    vec![
        BackendInfo {
            name: "native",
            available: true,
            description: "multi-threaded host engine over scheduled images (default; \
                          accepts native:<threads>)",
        },
        BackendInfo {
            name: "native-blocked",
            available: true,
            description: "native engine with an adaptive (L2-sized) column-blocked sweep \
                          for wide N (accepts native-blocked:<threads>)",
        },
        BackendInfo {
            name: "functional",
            available: true,
            description: "serial functional simulator (reference semantics)",
        },
        BackendInfo {
            name: "pjrt",
            available: PJRT_REAL,
            description: "AOT Pallas kernels via PJRT/XLA (needs `pjrt`+`xla` features + \
                          artifacts)",
        },
        BackendInfo {
            name: "sharded",
            available: true,
            description: "row-sharded composite running S shards in parallel over an \
                          inner backend (sharded:<S>:<inner>, default sharded:2:native)",
        },
        BackendInfo {
            name: "remote",
            available: true,
            description: "distributed composite proxying shards to `sextans worker` \
                          processes (remote:<addr>[,addr...][,replicas=R]); \
                          availability = at least one worker answers a ping",
        },
    ]
}

/// Registered backend names.
pub fn names() -> Vec<&'static str> {
    registry().iter().map(|b| b.name).collect()
}

fn split_spec(spec: &str) -> (&str, Option<&str>) {
    match spec.split_once(':') {
        Some((n, a)) => (n, Some(a)),
        None => (spec, None),
    }
}

fn parse_native_threads(arg: Option<&str>) -> Result<usize, BackendError> {
    match arg {
        None => Ok(0),
        Some(a) => a.parse::<usize>().map_err(|_| {
            BackendError::InvalidSpec(format!("native:<threads> needs an integer, got {a:?}"))
        }),
    }
}

fn no_arg(name: &str, arg: Option<&str>) -> Result<(), BackendError> {
    match arg {
        None => Ok(()),
        Some(a) => Err(BackendError::InvalidSpec(format!(
            "{name} takes no argument, got {a:?}"
        ))),
    }
}

/// Parse a `sharded` argument: `<S>` or `<S>:<inner spec>` (inner defaults
/// to `"native"`; a bare `"sharded"` means 2 shards).
fn parse_sharded(arg: Option<&str>) -> Result<(usize, String), BackendError> {
    let Some(arg) = arg else {
        return Ok((2, "native".to_string()));
    };
    let (s_str, inner) = match arg.split_once(':') {
        Some((s, i)) => (s, i.to_string()),
        None => (arg, "native".to_string()),
    };
    let s = s_str.parse::<usize>().map_err(|_| {
        BackendError::InvalidSpec(format!(
            "sharded:<S>[:<inner>] needs an integer shard count, got {s_str:?}"
        ))
    })?;
    if s == 0 {
        return Err(BackendError::InvalidSpec("sharded:<S> needs S >= 1".into()));
    }
    Ok((s, inner))
}

/// Split a `sharded:<S>:<inner>` spec into its shard count and raw inner
/// spec (`None` for non-sharded or malformed specs — [`create`] rejects
/// the latter with a better error). The serving coordinator uses this to
/// wire re-shard-on-skew: rebuilds need the *un-budgeted* inner spec so
/// thread budgets can be re-derived for the new S.
pub fn sharded_parts(spec: &str) -> Option<(usize, String)> {
    let (name, arg) = split_spec(spec);
    if name != "sharded" {
        return None;
    }
    parse_sharded(arg).ok()
}

/// Check that the spec's engine can execute in this build. For `sharded`
/// the *inner* engine is what executes, so the check recurses into it —
/// `"sharded:2:pjrt"` is refused in a pjrt-less build just like `"pjrt"`.
/// Unknown or malformed specs pass: [`create`] rejects those with a better
/// error.
pub fn check_available(spec: &str) -> Result<(), BackendError> {
    let (name, arg) = split_spec(spec);
    if name == "sharded" {
        return match parse_sharded(arg) {
            Ok((_, inner)) => check_available(&inner),
            Err(_) => Ok(()),
        };
    }
    if name == "remote" {
        // Availability is a live property of the fleet, not the build:
        // probe the workers (at least one must answer a ping). Malformed
        // specs pass — create() rejects them with a better error.
        return match crate::net::RemoteBackend::from_spec(arg) {
            Ok(be) => be.probe(),
            Err(_) => Ok(()),
        };
    }
    match registry().iter().find(|b| b.name == name) {
        Some(info) if !info.available => Err(BackendError::Unavailable(format!(
            "backend {name:?} cannot execute in this build ({})",
            info.description
        ))),
        _ => Ok(()),
    }
}

/// Rewrite a spec so its total worker-thread appetite fits `budget` cores.
/// Only *auto-sized* specs are touched (`"native"` / `"native-blocked"`
/// without an explicit thread count, recursively inside `"sharded"`);
/// explicit thread counts are an operator decision and pass through. This
/// is what keeps server workers × shards × engine lanes from
/// oversubscribing the machine: the coordinator divides cores per worker,
/// the sharded composite divides its share per shard.
pub fn apply_thread_budget(spec: &str, budget: usize) -> String {
    let budget = budget.max(1);
    let (name, arg) = split_spec(spec);
    match name {
        "native" | "native-blocked" if arg.is_none() => format!("{name}:{budget}"),
        "sharded" => {
            let Ok((s, inner)) = parse_sharded(arg) else {
                return spec.to_string();
            };
            format!("sharded:{s}:{}", apply_thread_budget(&inner, (budget / s).max(1)))
        }
        _ => spec.to_string(),
    }
}

/// Construct a backend factory from a spec string: `"native"`,
/// `"native:<threads>"`, `"native-blocked"`, `"functional"`, `"pjrt"`, or
/// `"sharded:<S>:<inner>"`. Factories are cheap, stateless, and
/// `Send + Sync`; the expensive per-matrix work happens in
/// [`SpmmBackend::prepare`].
pub fn create(spec: &str) -> Result<Box<dyn SpmmBackend>, BackendError> {
    let (name, arg) = split_spec(spec);
    match name {
        "native" => Ok(Box::new(NativeBackend::new(parse_native_threads(arg)?))),
        "native-blocked" => {
            Ok(Box::new(NativeBackend::blocked(parse_native_threads(arg)?)))
        }
        "functional" => {
            no_arg("functional", arg)?;
            Ok(Box::new(FunctionalBackend))
        }
        "pjrt" => {
            no_arg("pjrt", arg)?;
            Ok(Box::new(PjrtBackend::new()))
        }
        "sharded" => {
            let (s, inner) = parse_sharded(arg)?;
            Ok(Box::new(crate::shard::ShardedBackend::from_spec(s, &inner)?))
        }
        "remote" => Ok(Box::new(crate::net::RemoteBackend::from_spec(arg)?)),
        other => Err(BackendError::Unknown(other.to_string())),
    }
}

/// Prepare a `Send` handle directly from a spec string — the one-call path
/// for thread-mobile consumers ([`crate::hflex::HFlexAccelerator::load`]).
pub fn prepare_send(
    spec: &str,
    image: Arc<ScheduledMatrix>,
) -> Result<Box<dyn PreparedSpmm + Send + Sync>, BackendError> {
    create(spec)?.prepare_send(image)
}

/// The default backend: native, auto-sized thread pool.
pub fn default_backend() -> Box<dyn SpmmBackend> {
    Box::new(NativeBackend::new(0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::preprocess;
    use crate::sparse::{gen, rng::Rng, Coo};

    #[test]
    fn registry_lists_all_backends() {
        let names: Vec<_> = registry().iter().map(|b| b.name).collect();
        assert_eq!(
            names,
            vec!["native", "native-blocked", "functional", "pjrt", "sharded", "remote"]
        );
        // Everything but pjrt executes in every build; pjrt tracks the
        // real-engine feature pair.
        for info in registry() {
            if info.name == "pjrt" {
                assert_eq!(info.available, PJRT_REAL);
            } else {
                assert!(info.available, "{} must be available", info.name);
            }
        }
    }

    #[test]
    fn create_by_name() {
        assert_eq!(create("native").unwrap().name(), "native");
        assert_eq!(create("native:4").unwrap().name(), "native");
        assert_eq!(create("native-blocked").unwrap().name(), "native-blocked");
        assert_eq!(create("native-blocked:2").unwrap().name(), "native-blocked");
        assert_eq!(create("functional").unwrap().name(), "functional");
        assert_eq!(create("pjrt").unwrap().name(), "pjrt");
        assert_eq!(create("sharded").unwrap().name(), "sharded");
        assert_eq!(create("sharded:3").unwrap().name(), "sharded");
        assert_eq!(create("sharded:2:functional").unwrap().name(), "sharded");
        assert_eq!(create("sharded:2:native:1").unwrap().name(), "sharded");
        assert_eq!(create("remote:127.0.0.1:7070").unwrap().name(), "remote");
        assert_eq!(
            create("remote:127.0.0.1:7070,127.0.0.1:7071,replicas=2").unwrap().name(),
            "remote"
        );
    }

    #[test]
    fn create_rejects_bad_specs() {
        assert!(matches!(create("fpga"), Err(BackendError::Unknown(_))));
        assert!(matches!(create("native:x"), Err(BackendError::InvalidSpec(_))));
        assert!(matches!(create("functional:2"), Err(BackendError::InvalidSpec(_))));
        assert!(matches!(create("sharded:0"), Err(BackendError::InvalidSpec(_))));
        assert!(matches!(create("sharded:x:native"), Err(BackendError::InvalidSpec(_))));
        assert!(matches!(
            create("sharded:2:sharded:2:native"),
            Err(BackendError::InvalidSpec(_))
        ));
        assert!(matches!(create("remote"), Err(BackendError::InvalidSpec(_))));
        assert!(matches!(create("remote:"), Err(BackendError::InvalidSpec(_))));
        assert!(matches!(
            create("remote:replicas=2"),
            Err(BackendError::InvalidSpec(_))
        ));
        assert!(matches!(
            create("remote:127.0.0.1:7070,replicas=x"),
            Err(BackendError::InvalidSpec(_))
        ));
        let msg = create("fpga").unwrap_err().to_string();
        assert!(msg.contains("native") && msg.contains("pjrt"), "{msg}");
        assert!(msg.contains("sharded"), "{msg}");
    }

    #[test]
    fn thread_budget_rewrites_auto_specs_only() {
        assert_eq!(apply_thread_budget("native", 8), "native:8");
        assert_eq!(apply_thread_budget("native-blocked", 6), "native-blocked:6");
        assert_eq!(apply_thread_budget("native:3", 8), "native:3");
        assert_eq!(apply_thread_budget("functional", 8), "functional");
        assert_eq!(apply_thread_budget("pjrt", 8), "pjrt");
        // Sharded divides its budget across shards, floored at 1 thread.
        assert_eq!(apply_thread_budget("sharded:4:native", 8), "sharded:4:native:2");
        assert_eq!(apply_thread_budget("sharded:8:native", 4), "sharded:8:native:1");
        assert_eq!(apply_thread_budget("sharded:2:native:5", 8), "sharded:2:native:5");
        assert_eq!(apply_thread_budget("sharded:2", 8), "sharded:2:native:4");
        assert_eq!(apply_thread_budget("sharded", 8), "sharded:2:native:4");
        // Remote threads are another machine's problem: pass through.
        assert_eq!(
            apply_thread_budget("remote:127.0.0.1:7070", 8),
            "remote:127.0.0.1:7070"
        );
        // Budget is clamped to at least one core.
        assert_eq!(apply_thread_budget("native", 0), "native:1");
        // Malformed specs pass through untouched (create() rejects them).
        assert_eq!(apply_thread_budget("sharded:x:native", 8), "sharded:x:native");
    }

    #[test]
    fn availability_check_sees_through_sharded() {
        assert!(check_available("native").is_ok());
        assert!(check_available("sharded:4:native:2").is_ok());
        assert!(check_available("sharded").is_ok()); // default inner = native
        // Malformed / unknown specs defer to create()'s richer errors.
        assert!(check_available("sharded:x:native").is_ok());
        assert!(check_available("warpdrive").is_ok());
        assert_eq!(check_available("pjrt").is_ok(), PJRT_REAL);
        assert_eq!(check_available("sharded:2:pjrt").is_ok(), PJRT_REAL);
        // Remote availability is a live probe: nothing listens on the
        // discard port, so the fleet is unreachable.
        assert!(check_available("remote:127.0.0.1:9").is_err());
        // Malformed remote specs defer to create()'s richer errors.
        assert!(check_available("remote:no-port-here").is_ok());
    }

    #[test]
    fn backends_are_send_sync_factories() {
        fn assert_send_sync<T: Send + Sync + ?Sized>() {}
        assert_send_sync::<dyn SpmmBackend>();
        let b: Box<dyn SpmmBackend> = create("native:2").unwrap();
        assert_eq!(b.name(), "native");
    }

    #[test]
    fn default_backend_is_native() {
        let b = default_backend();
        assert_eq!(b.name(), "native");
        assert!(b.capability().threads >= 1);
        assert_eq!(b.capability().simd_lanes, 8);
    }

    #[test]
    fn execute_once_shim_matches_prepared_path() {
        let mut rng = Rng::new(77);
        let a = gen::random_uniform(40, 30, 0.2, &mut rng);
        let image = Arc::new(preprocess(&a, 4, 16, 5));
        let n = 3;
        let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..a.m * n).map(|_| rng.normal()).collect();
        let be = create("native:2").unwrap();
        let mut once = c0.clone();
        be.execute_once(&image, &b, &mut once, n, 1.5, -0.5).unwrap();
        let handle = be.prepare(Arc::clone(&image)).unwrap();
        let mut held = c0.clone();
        handle.execute(&b, &mut held, n, 1.5, -0.5).unwrap();
        assert_eq!(once, held);
    }

    #[test]
    fn prepare_send_default_refuses_with_name() {
        // A backend that keeps the default prepare_send must name itself in
        // the refusal.
        struct Local;
        impl SpmmBackend for Local {
            fn name(&self) -> &'static str {
                "local-only"
            }
            fn capability(&self) -> Capability {
                Capability {
                    threads: 1,
                    simd_lanes: 1,
                    requires_artifacts: false,
                    deterministic: true,
                }
            }
            fn prepare(
                &self,
                _image: Arc<ScheduledMatrix>,
            ) -> Result<Box<dyn PreparedSpmm>, BackendError> {
                Err(BackendError::Unavailable("stub".into()))
            }
        }
        let sm = Arc::new(preprocess(&Coo::empty(2, 2), 1, 2, 1));
        let err = Local.prepare_send(sm).unwrap_err();
        assert!(err.to_string().contains("local-only"), "{err}");
    }

    #[test]
    fn sharded_parts_splits_composite_specs_only() {
        assert_eq!(sharded_parts("sharded:4:native"), Some((4, "native".to_string())));
        assert_eq!(
            sharded_parts("sharded:2:native:3"),
            Some((2, "native:3".to_string()))
        );
        assert_eq!(sharded_parts("sharded:3"), Some((3, "native".to_string())));
        assert_eq!(sharded_parts("sharded"), Some((2, "native".to_string())));
        assert_eq!(sharded_parts("native"), None);
        assert_eq!(sharded_parts("native:4"), None);
        assert_eq!(sharded_parts("sharded:x:native"), None);
    }

    #[test]
    fn execute_routed_default_matches_execute_and_skips_nothing() {
        let mut rng = Rng::new(31);
        let a = gen::random_uniform(32, 24, 0.2, &mut rng);
        let image = Arc::new(preprocess(&a, 2, 8, 4));
        let n = 2;
        let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..a.m * n).map(|_| rng.normal()).collect();
        let handle = create("native:1").unwrap().prepare(Arc::clone(&image)).unwrap();
        assert_eq!(handle.resident_shards(), None, "native is single-unit");
        let mut plain = c0.clone();
        handle.execute(&b, &mut plain, n, 1.5, -0.5).unwrap();
        let mut routed = c0.clone();
        let skipped = handle.execute_routed(&b, &mut routed, n, 1.5, -0.5).unwrap();
        assert_eq!(skipped, 0);
        assert_eq!(plain, routed, "default routing is a plain execute");
    }

    #[test]
    fn execute_batch_default_loops_pairs() {
        let mut rng = Rng::new(5);
        let a = gen::random_uniform(24, 20, 0.25, &mut rng);
        let image = Arc::new(preprocess(&a, 2, 8, 4));
        let n = 2;
        let handle = create("native:1").unwrap().prepare(Arc::clone(&image)).unwrap();
        let bs: Vec<Vec<f32>> =
            (0..3).map(|_| (0..a.k * n).map(|_| rng.normal()).collect()).collect();
        let mut cs: Vec<Vec<f32>> = (0..3).map(|_| vec![0.0; a.m * n]).collect();
        {
            let mut jobs: Vec<(&[f32], &mut [f32])> = bs
                .iter()
                .map(|b| b.as_slice())
                .zip(cs.iter_mut().map(|c| c.as_mut_slice()))
                .collect();
            handle.execute_batch(&mut jobs, n, 1.0, 0.0).unwrap();
        }
        for (b, c) in bs.iter().zip(&cs) {
            let mut want = vec![0.0; a.m * n];
            a.spmm_reference(b, &mut want, n, 1.0, 0.0);
            crate::prop::assert_allclose(c, &want, 2e-4, 2e-4).unwrap();
        }
    }
}
