//! Pluggable SpMM execution backends — the HFlex contract (§3.4) made
//! portable: a preprocessed [`ScheduledMatrix`] image is itself the
//! executable format, and anything that can consume it (a native CPU
//! engine, the functional simulator, the PJRT/XLA kernel path, one day a
//! real bitstream) is interchangeable behind [`SpmmBackend`].
//!
//! * [`native::NativeBackend`] — multi-threaded host engine, PE-parallel
//!   across the image's P streams with an 8-lane (N0-shaped) inner loop.
//!   The default: correct, fast, and dependency-free.
//! * [`functional::FunctionalBackend`] — the cycle-exact functional
//!   simulator ([`crate::arch::functional`]); the always-available
//!   reference semantics.
//! * [`pjrt::PjrtBackend`] — adapter over [`crate::runtime::Engine`]
//!   (AOT Pallas kernels via PJRT); requires the `pjrt` cargo feature and
//!   compiled artifacts, and reports unavailability otherwise.
//!
//! Backends are selected by name through [`create`] (`"native"`,
//! `"native:4"`, `"functional"`, `"pjrt"`), so servers and CLIs stay
//! backend-agnostic.

pub mod functional;
pub mod native;
pub mod pjrt;

pub use functional::FunctionalBackend;
pub use native::NativeBackend;
pub use pjrt::PjrtBackend;

use crate::sched::ScheduledMatrix;

/// Why a backend refused or failed an execution.
#[derive(Debug, PartialEq)]
pub enum BackendError {
    /// No backend registered under the requested name.
    Unknown(String),
    /// The spec string parsed, but its argument is invalid.
    InvalidSpec(String),
    /// The backend cannot run in this environment (missing feature,
    /// missing artifacts, ...).
    Unavailable(String),
    /// B/C buffer shapes do not match the image and N.
    Shape(String),
    /// The backend started but failed mid-execution.
    Execution(String),
}

impl std::fmt::Display for BackendError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BackendError::Unknown(s) => write!(
                f,
                "unknown backend {s:?} (expected one of: {})",
                names().join(", ")
            ),
            BackendError::InvalidSpec(s) => write!(f, "invalid backend spec: {s}"),
            BackendError::Unavailable(s) => write!(f, "backend unavailable: {s}"),
            BackendError::Shape(s) => write!(f, "shape mismatch: {s}"),
            BackendError::Execution(s) => write!(f, "execution failed: {s}"),
        }
    }
}

impl std::error::Error for BackendError {}

/// What a backend can do — reported, not probed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Capability {
    /// Worker threads used on the hot path (1 = serial).
    pub threads: usize,
    /// Inner-loop vector width the implementation is shaped around.
    pub simd_lanes: usize,
    /// Needs AOT artifacts / external runtime to execute.
    pub requires_artifacts: bool,
    /// Same image + inputs always produce bit-identical output.
    pub deterministic: bool,
}

/// One SpMM execution engine consuming scheduled images.
///
/// Implementations are constructed per worker thread (see
/// [`crate::coordinator::Server::start`]); the trait deliberately has no
/// `Send` bound because PJRT client handles are thread-local.
pub trait SpmmBackend {
    /// Stable registry name (also recorded in serving metrics).
    fn name(&self) -> &'static str;

    /// Capability / identity report.
    fn capability(&self) -> Capability;

    /// Execute `C = alpha * A @ B + beta * C` where A is the scheduled
    /// image, `b` is row-major `k x n` and `c` is row-major `m x n`.
    fn execute(
        &mut self,
        image: &ScheduledMatrix,
        b: &[f32],
        c: &mut [f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<(), BackendError>;
}

impl std::fmt::Debug for dyn SpmmBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SpmmBackend({})", self.name())
    }
}

impl std::fmt::Debug for dyn SpmmBackend + Send {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SpmmBackend({})", self.name())
    }
}

/// Validate B/C buffer shapes against the image (shared by backends).
pub(crate) fn check_shapes(
    sm: &ScheduledMatrix,
    b: &[f32],
    c: &[f32],
    n: usize,
) -> Result<(), BackendError> {
    if b.len() != sm.k * n {
        return Err(BackendError::Shape(format!(
            "B has {} elements, expected K*N = {}",
            b.len(),
            sm.k * n
        )));
    }
    if c.len() != sm.m * n {
        return Err(BackendError::Shape(format!(
            "C has {} elements, expected M*N = {}",
            c.len(),
            sm.m * n
        )));
    }
    Ok(())
}

/// A registry row: name, availability in this build, one-line description.
#[derive(Clone, Copy, Debug)]
pub struct BackendInfo {
    /// Registry name accepted by [`create`].
    pub name: &'static str,
    /// Whether [`create`]d instances can actually execute in this build.
    pub available: bool,
    /// Human-readable summary.
    pub description: &'static str,
}

/// The registered backends, in preference order.
pub fn registry() -> Vec<BackendInfo> {
    vec![
        BackendInfo {
            name: "native",
            available: true,
            description: "multi-threaded host engine over scheduled images (default; \
                          accepts native:<threads>)",
        },
        BackendInfo {
            name: "functional",
            available: true,
            description: "serial functional simulator (reference semantics)",
        },
        BackendInfo {
            name: "pjrt",
            available: cfg!(feature = "pjrt"),
            description: "AOT Pallas kernels via PJRT/XLA (needs `pjrt` feature + artifacts)",
        },
    ]
}

/// Registered backend names.
pub fn names() -> Vec<&'static str> {
    registry().iter().map(|b| b.name).collect()
}

fn split_spec(spec: &str) -> (&str, Option<&str>) {
    match spec.split_once(':') {
        Some((n, a)) => (n, Some(a)),
        None => (spec, None),
    }
}

fn parse_native_threads(arg: Option<&str>) -> Result<usize, BackendError> {
    match arg {
        None => Ok(0),
        Some(a) => a.parse::<usize>().map_err(|_| {
            BackendError::InvalidSpec(format!("native:<threads> needs an integer, got {a:?}"))
        }),
    }
}

fn no_arg(name: &str, arg: Option<&str>) -> Result<(), BackendError> {
    match arg {
        None => Ok(()),
        Some(a) => Err(BackendError::InvalidSpec(format!(
            "{name} takes no argument, got {a:?}"
        ))),
    }
}

/// Construct a backend from a spec string: `"native"`, `"native:<threads>"`,
/// `"functional"`, or `"pjrt"`.
pub fn create(spec: &str) -> Result<Box<dyn SpmmBackend>, BackendError> {
    let (name, arg) = split_spec(spec);
    match name {
        "native" => Ok(Box::new(NativeBackend::new(parse_native_threads(arg)?))),
        "functional" => {
            no_arg("functional", arg)?;
            Ok(Box::new(FunctionalBackend))
        }
        "pjrt" => {
            no_arg("pjrt", arg)?;
            Ok(Box::new(PjrtBackend::new()))
        }
        other => Err(BackendError::Unknown(other.to_string())),
    }
}

/// Like [`create`], but returns a `Send` backend, suitable for owning
/// inside thread-mobile structures ([`crate::hflex::HFlexAccelerator`]).
/// With the `pjrt` feature enabled the PJRT engine's handles are
/// thread-local, so `"pjrt"` is refused here — construct it inside its
/// executing thread instead (the coordinator's worker factories do).
pub fn create_send(spec: &str) -> Result<Box<dyn SpmmBackend + Send>, BackendError> {
    let (name, arg) = split_spec(spec);
    match name {
        "native" => Ok(Box::new(NativeBackend::new(parse_native_threads(arg)?))),
        "functional" => {
            no_arg("functional", arg)?;
            Ok(Box::new(FunctionalBackend))
        }
        "pjrt" => {
            no_arg("pjrt", arg)?;
            create_send_pjrt()
        }
        other => Err(BackendError::Unknown(other.to_string())),
    }
}

#[cfg(not(feature = "pjrt"))]
fn create_send_pjrt() -> Result<Box<dyn SpmmBackend + Send>, BackendError> {
    // Without the feature the adapter holds no client handles and is Send.
    Ok(Box::new(PjrtBackend::new()))
}

#[cfg(feature = "pjrt")]
fn create_send_pjrt() -> Result<Box<dyn SpmmBackend + Send>, BackendError> {
    Err(BackendError::Unavailable(
        "pjrt engine handles are thread-local; construct PjrtBackend inside its executing \
         thread (Server::start_backend does)"
            .into(),
    ))
}

/// The default backend: native, auto-sized thread pool.
pub fn default_backend() -> Box<dyn SpmmBackend + Send> {
    Box::new(NativeBackend::new(0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_three_backends() {
        let names: Vec<_> = registry().iter().map(|b| b.name).collect();
        assert_eq!(names, vec!["native", "functional", "pjrt"]);
        // native and functional always execute; pjrt tracks the feature.
        assert!(registry()[0].available && registry()[1].available);
        assert_eq!(registry()[2].available, cfg!(feature = "pjrt"));
    }

    #[test]
    fn create_by_name() {
        assert_eq!(create("native").unwrap().name(), "native");
        assert_eq!(create("native:4").unwrap().name(), "native");
        assert_eq!(create("functional").unwrap().name(), "functional");
        assert_eq!(create("pjrt").unwrap().name(), "pjrt");
    }

    #[test]
    fn create_rejects_bad_specs() {
        assert!(matches!(create("fpga"), Err(BackendError::Unknown(_))));
        assert!(matches!(create("native:x"), Err(BackendError::InvalidSpec(_))));
        assert!(matches!(create("functional:2"), Err(BackendError::InvalidSpec(_))));
        let msg = create("fpga").unwrap_err().to_string();
        assert!(msg.contains("native") && msg.contains("pjrt"), "{msg}");
    }

    #[test]
    fn create_send_constructs_send_backends() {
        assert_eq!(create_send("native:2").unwrap().name(), "native");
        assert_eq!(create_send("functional").unwrap().name(), "functional");
        if cfg!(feature = "pjrt") {
            assert!(matches!(create_send("pjrt"), Err(BackendError::Unavailable(_))));
        } else {
            assert_eq!(create_send("pjrt").unwrap().name(), "pjrt");
        }
    }

    #[test]
    fn default_backend_is_native() {
        let b = default_backend();
        assert_eq!(b.name(), "native");
        assert!(b.capability().threads >= 1);
        assert_eq!(b.capability().simd_lanes, 8);
    }
}
