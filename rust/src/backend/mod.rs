//! Pluggable SpMM execution backends — the HFlex contract (§3.4) made
//! portable: a preprocessed [`ScheduledMatrix`] image is itself the
//! executable format, and anything that can consume it (a native CPU
//! engine, the functional simulator, the PJRT/XLA kernel path, one day a
//! real bitstream) is interchangeable behind [`SpmmBackend`].
//!
//! * [`native::NativeBackend`] — multi-threaded host engine, PE-parallel
//!   across the image's P streams with an 8-lane (N0-shaped) inner loop.
//!   The default: correct, fast, and dependency-free.
//! * [`functional::FunctionalBackend`] — the cycle-exact functional
//!   simulator ([`crate::arch::functional`]); the always-available
//!   reference semantics.
//! * [`pjrt::PjrtBackend`] — adapter over [`crate::runtime::Engine`]
//!   (AOT Pallas kernels via PJRT); requires the `pjrt` cargo feature and
//!   compiled artifacts, and reports unavailability otherwise.
//! * [`crate::shard::ShardedBackend`] — composite: row-shards the matrix
//!   across S parallel instances of any inner backend
//!   (`"sharded:<S>:<inner>"`, e.g. `"sharded:4:native"`).
//!
//! Backends are selected by name through [`create`] (`"native"`,
//! `"native:4"`, `"native-blocked"`, `"functional"`, `"pjrt"`,
//! `"sharded:4:native"`), so servers and CLIs stay backend-agnostic.
//! [`apply_thread_budget`] rewrites auto-threaded specs to fit a global
//! core budget, so stacked parallelism (server workers × shards × engine
//! threads) never oversubscribes the machine.

pub mod functional;
pub mod native;
pub mod pjrt;

pub use functional::FunctionalBackend;
pub use native::NativeBackend;
pub use pjrt::PjrtBackend;

use crate::sched::ScheduledMatrix;

/// Why a backend refused or failed an execution.
#[derive(Debug, PartialEq)]
pub enum BackendError {
    /// No backend registered under the requested name.
    Unknown(String),
    /// The spec string parsed, but its argument is invalid.
    InvalidSpec(String),
    /// The backend cannot run in this environment (missing feature,
    /// missing artifacts, ...).
    Unavailable(String),
    /// B/C buffer shapes do not match the image and N.
    Shape(String),
    /// The backend started but failed mid-execution.
    Execution(String),
}

impl std::fmt::Display for BackendError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BackendError::Unknown(s) => write!(
                f,
                "unknown backend {s:?} (expected one of: {})",
                names().join(", ")
            ),
            BackendError::InvalidSpec(s) => write!(f, "invalid backend spec: {s}"),
            BackendError::Unavailable(s) => write!(f, "backend unavailable: {s}"),
            BackendError::Shape(s) => write!(f, "shape mismatch: {s}"),
            BackendError::Execution(s) => write!(f, "execution failed: {s}"),
        }
    }
}

impl std::error::Error for BackendError {}

/// What a backend can do — reported, not probed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Capability {
    /// Worker threads used on the hot path (1 = serial).
    pub threads: usize,
    /// Inner-loop vector width the implementation is shaped around.
    pub simd_lanes: usize,
    /// Needs AOT artifacts / external runtime to execute.
    pub requires_artifacts: bool,
    /// Same image + inputs always produce bit-identical output.
    pub deterministic: bool,
}

/// One SpMM execution engine consuming scheduled images.
///
/// Implementations are constructed per worker thread (see
/// [`crate::coordinator::Server::start`]); the trait deliberately has no
/// `Send` bound because PJRT client handles are thread-local.
pub trait SpmmBackend {
    /// Stable registry name (also recorded in serving metrics).
    fn name(&self) -> &'static str;

    /// Capability / identity report.
    fn capability(&self) -> Capability;

    /// Execute `C = alpha * A @ B + beta * C` where A is the scheduled
    /// image, `b` is row-major `k x n` and `c` is row-major `m x n`.
    fn execute(
        &mut self,
        image: &ScheduledMatrix,
        b: &[f32],
        c: &mut [f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<(), BackendError>;

    /// Shard-level statistics of the most recent successful `execute`, for
    /// backends that shard (see [`crate::shard`]). Non-sharding engines
    /// keep the default `None`; the serving coordinator polls this after
    /// every job to feed shard metrics into its summary.
    fn shard_stats(&self) -> Option<crate::shard::ShardRunStats> {
        None
    }
}

impl std::fmt::Debug for dyn SpmmBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SpmmBackend({})", self.name())
    }
}

impl std::fmt::Debug for dyn SpmmBackend + Send {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SpmmBackend({})", self.name())
    }
}

/// Validate B/C buffer shapes against the image (shared by backends).
pub(crate) fn check_shapes(
    sm: &ScheduledMatrix,
    b: &[f32],
    c: &[f32],
    n: usize,
) -> Result<(), BackendError> {
    if b.len() != sm.k * n {
        return Err(BackendError::Shape(format!(
            "B has {} elements, expected K*N = {}",
            b.len(),
            sm.k * n
        )));
    }
    if c.len() != sm.m * n {
        return Err(BackendError::Shape(format!(
            "C has {} elements, expected M*N = {}",
            c.len(),
            sm.m * n
        )));
    }
    Ok(())
}

/// A registry row: name, availability in this build, one-line description.
#[derive(Clone, Copy, Debug)]
pub struct BackendInfo {
    /// Registry name accepted by [`create`].
    pub name: &'static str,
    /// Whether [`create`]d instances can actually execute in this build.
    pub available: bool,
    /// Human-readable summary.
    pub description: &'static str,
}

/// The registered backends, in preference order.
pub fn registry() -> Vec<BackendInfo> {
    vec![
        BackendInfo {
            name: "native",
            available: true,
            description: "multi-threaded host engine over scheduled images (default; \
                          accepts native:<threads>)",
        },
        BackendInfo {
            name: "native-blocked",
            available: true,
            description: "native engine with a column-blocked inner loop for wide N \
                          (accepts native-blocked:<threads>)",
        },
        BackendInfo {
            name: "functional",
            available: true,
            description: "serial functional simulator (reference semantics)",
        },
        BackendInfo {
            name: "pjrt",
            available: cfg!(feature = "pjrt"),
            description: "AOT Pallas kernels via PJRT/XLA (needs `pjrt` feature + artifacts)",
        },
        BackendInfo {
            name: "sharded",
            available: true,
            description: "row-sharded composite running S shards in parallel over an \
                          inner backend (sharded:<S>:<inner>, default sharded:2:native)",
        },
    ]
}

/// Registered backend names.
pub fn names() -> Vec<&'static str> {
    registry().iter().map(|b| b.name).collect()
}

fn split_spec(spec: &str) -> (&str, Option<&str>) {
    match spec.split_once(':') {
        Some((n, a)) => (n, Some(a)),
        None => (spec, None),
    }
}

fn parse_native_threads(arg: Option<&str>) -> Result<usize, BackendError> {
    match arg {
        None => Ok(0),
        Some(a) => a.parse::<usize>().map_err(|_| {
            BackendError::InvalidSpec(format!("native:<threads> needs an integer, got {a:?}"))
        }),
    }
}

fn no_arg(name: &str, arg: Option<&str>) -> Result<(), BackendError> {
    match arg {
        None => Ok(()),
        Some(a) => Err(BackendError::InvalidSpec(format!(
            "{name} takes no argument, got {a:?}"
        ))),
    }
}

/// Parse a `sharded` argument: `<S>` or `<S>:<inner spec>` (inner defaults
/// to `"native"`; a bare `"sharded"` means 2 shards).
fn parse_sharded(arg: Option<&str>) -> Result<(usize, String), BackendError> {
    let Some(arg) = arg else {
        return Ok((2, "native".to_string()));
    };
    let (s_str, inner) = match arg.split_once(':') {
        Some((s, i)) => (s, i.to_string()),
        None => (arg, "native".to_string()),
    };
    let s = s_str.parse::<usize>().map_err(|_| {
        BackendError::InvalidSpec(format!(
            "sharded:<S>[:<inner>] needs an integer shard count, got {s_str:?}"
        ))
    })?;
    if s == 0 {
        return Err(BackendError::InvalidSpec("sharded:<S> needs S >= 1".into()));
    }
    Ok((s, inner))
}

/// Check that the spec's engine can execute in this build. For `sharded`
/// the *inner* engine is what executes, so the check recurses into it —
/// `"sharded:2:pjrt"` is refused in a pjrt-less build just like `"pjrt"`.
/// Unknown or malformed specs pass: [`create`] rejects those with a better
/// error.
pub fn check_available(spec: &str) -> Result<(), BackendError> {
    let (name, arg) = split_spec(spec);
    if name == "sharded" {
        return match parse_sharded(arg) {
            Ok((_, inner)) => check_available(&inner),
            Err(_) => Ok(()),
        };
    }
    match registry().iter().find(|b| b.name == name) {
        Some(info) if !info.available => Err(BackendError::Unavailable(format!(
            "backend {name:?} cannot execute in this build ({})",
            info.description
        ))),
        _ => Ok(()),
    }
}

/// Rewrite a spec so its total worker-thread appetite fits `budget` cores.
/// Only *auto-sized* specs are touched (`"native"` / `"native-blocked"`
/// without an explicit thread count, recursively inside `"sharded"`);
/// explicit thread counts are an operator decision and pass through. This
/// is what keeps server workers × shards × engine lanes from
/// oversubscribing the machine: the coordinator divides cores per worker,
/// the sharded composite divides its share per shard.
pub fn apply_thread_budget(spec: &str, budget: usize) -> String {
    let budget = budget.max(1);
    let (name, arg) = split_spec(spec);
    match name {
        "native" | "native-blocked" if arg.is_none() => format!("{name}:{budget}"),
        "sharded" => {
            let Ok((s, inner)) = parse_sharded(arg) else {
                return spec.to_string();
            };
            format!("sharded:{s}:{}", apply_thread_budget(&inner, (budget / s).max(1)))
        }
        _ => spec.to_string(),
    }
}

/// Construct a backend from a spec string: `"native"`, `"native:<threads>"`,
/// `"native-blocked"`, `"functional"`, `"pjrt"`, or `"sharded:<S>:<inner>"`.
pub fn create(spec: &str) -> Result<Box<dyn SpmmBackend>, BackendError> {
    let (name, arg) = split_spec(spec);
    match name {
        "native" => Ok(Box::new(NativeBackend::new(parse_native_threads(arg)?))),
        "native-blocked" => {
            Ok(Box::new(NativeBackend::blocked(parse_native_threads(arg)?)))
        }
        "functional" => {
            no_arg("functional", arg)?;
            Ok(Box::new(FunctionalBackend))
        }
        "pjrt" => {
            no_arg("pjrt", arg)?;
            Ok(Box::new(PjrtBackend::new()))
        }
        "sharded" => {
            let (s, inner) = parse_sharded(arg)?;
            Ok(Box::new(crate::shard::ShardedBackend::from_spec(s, &inner)?))
        }
        other => Err(BackendError::Unknown(other.to_string())),
    }
}

/// Like [`create`], but returns a `Send` backend, suitable for owning
/// inside thread-mobile structures ([`crate::hflex::HFlexAccelerator`]).
/// With the `pjrt` feature enabled the PJRT engine's handles are
/// thread-local, so `"pjrt"` is refused here — construct it inside its
/// executing thread instead (the coordinator's worker factories do). The
/// same restriction applies to `"sharded:<S>:pjrt"`, whose inner engines
/// are built through this function.
pub fn create_send(spec: &str) -> Result<Box<dyn SpmmBackend + Send>, BackendError> {
    let (name, arg) = split_spec(spec);
    match name {
        "native" => Ok(Box::new(NativeBackend::new(parse_native_threads(arg)?))),
        "native-blocked" => {
            Ok(Box::new(NativeBackend::blocked(parse_native_threads(arg)?)))
        }
        "functional" => {
            no_arg("functional", arg)?;
            Ok(Box::new(FunctionalBackend))
        }
        "pjrt" => {
            no_arg("pjrt", arg)?;
            create_send_pjrt()
        }
        "sharded" => {
            let (s, inner) = parse_sharded(arg)?;
            Ok(Box::new(crate::shard::ShardedBackend::from_spec(s, &inner)?))
        }
        other => Err(BackendError::Unknown(other.to_string())),
    }
}

#[cfg(not(feature = "pjrt"))]
fn create_send_pjrt() -> Result<Box<dyn SpmmBackend + Send>, BackendError> {
    // Without the feature the adapter holds no client handles and is Send.
    Ok(Box::new(PjrtBackend::new()))
}

#[cfg(feature = "pjrt")]
fn create_send_pjrt() -> Result<Box<dyn SpmmBackend + Send>, BackendError> {
    Err(BackendError::Unavailable(
        "pjrt engine handles are thread-local; construct PjrtBackend inside its executing \
         thread (Server::start_backend does)"
            .into(),
    ))
}

/// The default backend: native, auto-sized thread pool.
pub fn default_backend() -> Box<dyn SpmmBackend + Send> {
    Box::new(NativeBackend::new(0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_lists_all_backends() {
        let names: Vec<_> = registry().iter().map(|b| b.name).collect();
        assert_eq!(
            names,
            vec!["native", "native-blocked", "functional", "pjrt", "sharded"]
        );
        // Everything but pjrt executes in every build; pjrt tracks the feature.
        for info in registry() {
            if info.name == "pjrt" {
                assert_eq!(info.available, cfg!(feature = "pjrt"));
            } else {
                assert!(info.available, "{} must be available", info.name);
            }
        }
    }

    #[test]
    fn create_by_name() {
        assert_eq!(create("native").unwrap().name(), "native");
        assert_eq!(create("native:4").unwrap().name(), "native");
        assert_eq!(create("native-blocked").unwrap().name(), "native-blocked");
        assert_eq!(create("native-blocked:2").unwrap().name(), "native-blocked");
        assert_eq!(create("functional").unwrap().name(), "functional");
        assert_eq!(create("pjrt").unwrap().name(), "pjrt");
        assert_eq!(create("sharded").unwrap().name(), "sharded");
        assert_eq!(create("sharded:3").unwrap().name(), "sharded");
        assert_eq!(create("sharded:2:functional").unwrap().name(), "sharded");
        assert_eq!(create("sharded:2:native:1").unwrap().name(), "sharded");
    }

    #[test]
    fn create_rejects_bad_specs() {
        assert!(matches!(create("fpga"), Err(BackendError::Unknown(_))));
        assert!(matches!(create("native:x"), Err(BackendError::InvalidSpec(_))));
        assert!(matches!(create("functional:2"), Err(BackendError::InvalidSpec(_))));
        assert!(matches!(create("sharded:0"), Err(BackendError::InvalidSpec(_))));
        assert!(matches!(create("sharded:x:native"), Err(BackendError::InvalidSpec(_))));
        assert!(matches!(
            create("sharded:2:sharded:2:native"),
            Err(BackendError::InvalidSpec(_))
        ));
        let msg = create("fpga").unwrap_err().to_string();
        assert!(msg.contains("native") && msg.contains("pjrt"), "{msg}");
        assert!(msg.contains("sharded"), "{msg}");
    }

    #[test]
    fn thread_budget_rewrites_auto_specs_only() {
        assert_eq!(apply_thread_budget("native", 8), "native:8");
        assert_eq!(apply_thread_budget("native-blocked", 6), "native-blocked:6");
        assert_eq!(apply_thread_budget("native:3", 8), "native:3");
        assert_eq!(apply_thread_budget("functional", 8), "functional");
        assert_eq!(apply_thread_budget("pjrt", 8), "pjrt");
        // Sharded divides its budget across shards, floored at 1 thread.
        assert_eq!(apply_thread_budget("sharded:4:native", 8), "sharded:4:native:2");
        assert_eq!(apply_thread_budget("sharded:8:native", 4), "sharded:8:native:1");
        assert_eq!(apply_thread_budget("sharded:2:native:5", 8), "sharded:2:native:5");
        assert_eq!(apply_thread_budget("sharded:2", 8), "sharded:2:native:4");
        assert_eq!(apply_thread_budget("sharded", 8), "sharded:2:native:4");
        // Budget is clamped to at least one core.
        assert_eq!(apply_thread_budget("native", 0), "native:1");
        // Malformed specs pass through untouched (create() rejects them).
        assert_eq!(apply_thread_budget("sharded:x:native", 8), "sharded:x:native");
    }

    #[test]
    fn availability_check_sees_through_sharded() {
        assert!(check_available("native").is_ok());
        assert!(check_available("sharded:4:native:2").is_ok());
        assert!(check_available("sharded").is_ok()); // default inner = native
        // Malformed / unknown specs defer to create()'s richer errors.
        assert!(check_available("sharded:x:native").is_ok());
        assert!(check_available("warpdrive").is_ok());
        let pjrt_ok = cfg!(feature = "pjrt");
        assert_eq!(check_available("pjrt").is_ok(), pjrt_ok);
        assert_eq!(check_available("sharded:2:pjrt").is_ok(), pjrt_ok);
    }

    #[test]
    fn create_send_constructs_send_backends() {
        assert_eq!(create_send("native:2").unwrap().name(), "native");
        assert_eq!(create_send("functional").unwrap().name(), "functional");
        if cfg!(feature = "pjrt") {
            assert!(matches!(create_send("pjrt"), Err(BackendError::Unavailable(_))));
        } else {
            assert_eq!(create_send("pjrt").unwrap().name(), "pjrt");
        }
    }

    #[test]
    fn default_backend_is_native() {
        let b = default_backend();
        assert_eq!(b.name(), "native");
        assert!(b.capability().threads >= 1);
        assert_eq!(b.capability().simd_lanes, 8);
    }
}
