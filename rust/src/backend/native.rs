//! Native multi-threaded SpMM engine over scheduled images, in two-phase
//! prepare/execute form — now vectorized end to end via the
//! [`super::simd`] kernel layer.
//!
//! The paper's hardware runs P PEs in parallel, each consuming its own
//! scheduled slot stream and owning the output rows `r ≡ pe (mod P)` in its
//! C scratchpad. That row partition is exactly what makes a host
//! parallelization safe: the prepared handle assigns the P streams
//! round-robin to worker threads (`std::thread::scope`), each worker
//! accumulates one output row at a time into a private accumulator (the
//! scratchpad analogue), and the Comp-C stage writes each PE's disjoint
//! row set straight into C.
//!
//! **Prepare** ([`SpmmBackend::prepare`]) decodes every PE stream once —
//! bubbles are dropped and window-local columns resolve to global B rows —
//! then **condenses** it (SpArch-style): a stable counting sort groups the
//! stream into per-output-row segments in an 8-byte/non-zero SoA layout
//! (`row_ptr` / `cols` / `vals`). Within each row the slot-issue order is
//! preserved, so per output element the accumulation order is untouched;
//! across rows the engine gains sequential segment scans, one-row
//! accumulator locality, and a natural place for software prefetch of the
//! upcoming B rows. Steady-state execution never touches the 64-bit
//! encoding again.
//!
//! Numerics are bit-identical to [`crate::arch::functional::execute`]: per
//! output element, the accumulation order is the PE's slot issue order in
//! both implementations (dropping bubbles removes only zero
//! contributions), and the final `alpha * C_AB + beta * C_in` is the same
//! expression. The [`super::simd`] kernels keep that contract on every
//! ISA — mul + add per contribution, never FMA — so `SEXTANS_SIMD=scalar`
//! and the AVX2 path produce the same bits (see the kernel module docs).
//!
//! Hot-path allocation is zero after warm-up: the handle keeps a
//! [`ScratchPool`] of per-call scratch *sets* (one 32-byte-aligned
//! accumulator per worker, [`super::scratch::AlignedVec`]), each execution
//! checks one set out, and buffers only grow across requests. Because the
//! condensed streams are read-only and all mutable state is pooled,
//! `execute` takes `&self` — any number of threads may drive one handle
//! concurrently, each on its own scratch set.
//!
//! **Column blocking** ([`NativeBackend::blocked`], registry name
//! `"native-blocked"`): for wide N the B rows and C row of one request
//! stop fitting in cache, so the blocked variant sweeps the same streams
//! once per column slice, re-reading the condensed segments (8 B/nnz,
//! streams linearly) in exchange for keeping the random-access B working
//! set cache-resident — the host mirror of the paper's N/N0 outer loop
//! (Eq. 2). The width is no longer a constant: [`adaptive_col_block`]
//! sizes it at prepare time from the matrix's distinct B-row count and the
//! detected L2 ([`super::simd::l2_cache_bytes`]), and **narrow requests
//! (N ≤ [`LANES`]) skip blocking entirely** — each output row lives in one
//! masked vector register start to finish. Per output element the
//! accumulation order is unchanged, so `native-blocked` stays bit-identical
//! to `native`.

use std::sync::Arc;
use std::time::Instant;

use super::scratch::AlignedVec;
use super::simd::{self, Isa};
use super::{
    check_shapes, BackendError, Capability, PrepareCost, PreparedSpmm, ScratchPool, SpmmBackend,
};
use crate::sched::{decode, ScheduledMatrix};

pub use super::simd::LANES;

/// The pre-adaptive fixed column-block width, kept as a reference point
/// for tuning experiments and the fixed-width tests
/// ([`NativeBackend::with_block`] still accepts any width).
pub const COL_BLOCK: usize = 64;

/// Upper clamp on [`adaptive_col_block`]: beyond this width the per-slice
/// segment re-scan overhead is already negligible and wider slices only
/// grow the accumulator.
pub const MAX_COL_BLOCK: usize = 512;

/// Choose a column-block width from the matrix's distinct B-row count and
/// the L2 budget: the largest multiple of [`LANES`] such that the touched
/// B rows of one slice (`distinct_b_rows × width × 4` bytes) fill at most
/// half the L2 (the other half is left for C rows, the streams, and the
/// other hyperthread), clamped to `[LANES, MAX_COL_BLOCK]`.
pub fn adaptive_col_block(distinct_b_rows: usize, l2_bytes: usize) -> usize {
    let budget = l2_bytes / 2;
    let per_col_bytes = 4 * distinct_b_rows.max(1);
    let w = (budget / per_col_bytes) / LANES * LANES;
    w.clamp(LANES, MAX_COL_BLOCK)
}

/// How a backend instance chooses its column-block width at prepare time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum BlockPolicy {
    /// Unblocked: one full-width sweep (the plain `native` engine).
    Off,
    /// A caller-fixed width (tuning experiments, tests).
    Fixed(usize),
    /// Resolve per matrix via [`adaptive_col_block`] at prepare time.
    Adaptive,
}

/// Multi-threaded native backend factory. Stateless: per-matrix state
/// (condensed streams, scratch) lives in the [`PreparedNative`] handles it
/// produces.
pub struct NativeBackend {
    /// Resolved worker-thread count (>= 1).
    threads: usize,
    /// Column-blocking policy, resolved to a width at prepare time.
    block: BlockPolicy,
}

impl NativeBackend {
    /// `threads == 0` auto-sizes to the machine's available parallelism.
    pub fn new(threads: usize) -> NativeBackend {
        let threads = Self::resolve_threads(threads);
        NativeBackend { threads, block: BlockPolicy::Off }
    }

    /// The `native-blocked` variant: sweeps columns in cache-sized slices
    /// for wide-N workloads, with the width chosen per matrix at prepare
    /// time ([`adaptive_col_block`]). Same numerics, different cache story.
    pub fn blocked(threads: usize) -> NativeBackend {
        let threads = Self::resolve_threads(threads);
        NativeBackend { threads, block: BlockPolicy::Adaptive }
    }

    /// Explicit column-block width (`0` = unblocked); exposed for tuning
    /// experiments and the bench harness.
    pub fn with_block(threads: usize, block_n: usize) -> NativeBackend {
        let threads = Self::resolve_threads(threads);
        let block = if block_n == 0 { BlockPolicy::Off } else { BlockPolicy::Fixed(block_n) };
        NativeBackend { threads, block }
    }

    fn resolve_threads(threads: usize) -> usize {
        if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        }
    }

    /// The resolved worker-thread count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Configured fixed column-block width; `0` both for the unblocked
    /// engine and for the adaptive variant, whose width only exists per
    /// prepared matrix ([`PreparedNative::col_block`]).
    pub fn block_width(&self) -> usize {
        match self.block {
            BlockPolicy::Fixed(w) => w,
            BlockPolicy::Off | BlockPolicy::Adaptive => 0,
        }
    }

    /// Concrete-typed prepare: identical to [`SpmmBackend::prepare`] but
    /// returns [`PreparedNative`] directly, for callers that need its
    /// inherent accessors (the scratch-pool sizing tests, benches).
    pub fn build(&self, image: Arc<ScheduledMatrix>) -> PreparedNative {
        let t0 = Instant::now();
        let rows_per_pe = image.rows_per_pe();
        // Decode every PE stream once (drop bubbles, resolve window-local
        // columns to global B rows, keep slot-issue order), counting the
        // distinct B rows for the adaptive block width, then condense into
        // per-output-row segments.
        let mut touched = vec![false; image.k];
        let mut distinct_b_rows = 0usize;
        let streams: Vec<CondensedStream> = image
            .streams
            .iter()
            .map(|stream| {
                let mut rows = Vec::with_capacity(stream.nnz);
                let mut cols = Vec::with_capacity(stream.nnz);
                let mut vals = Vec::with_capacity(stream.nnz);
                for j in 0..image.num_windows {
                    let col_base = (j * image.k0) as u32;
                    for &word in &stream.encoded[stream.q.window_range(j)] {
                        let nz = decode(word);
                        if nz.val == 0.0 {
                            continue; // bubble (or explicit zero: same arithmetic)
                        }
                        let gc = col_base + nz.col;
                        if !touched[gc as usize] {
                            touched[gc as usize] = true;
                            distinct_b_rows += 1;
                        }
                        rows.push(nz.row);
                        cols.push(gc);
                        vals.push(nz.val);
                    }
                }
                CondensedStream::condense(rows_per_pe, &rows, &cols, &vals)
            })
            .collect();
        let workers = self.threads.min(image.p).max(1);
        let block = match self.block {
            BlockPolicy::Off => 0,
            BlockPolicy::Fixed(w) => w,
            BlockPolicy::Adaptive => adaptive_col_block(distinct_b_rows, simd::l2_cache_bytes()),
        };
        // Seed the scratch pool with one per-call set (one aligned
        // accumulator per worker). Blocked accumulators are fully
        // pre-sized here; unblocked ones size themselves to N on first
        // execute and are grow-only afterwards. Additional sets are
        // created only by *concurrent* executions, one per simultaneous
        // caller. Narrow requests (N <= LANES) never touch them.
        let seed: Vec<AlignedVec> = if block > 0 {
            (0..workers).map(|_| AlignedVec::zeroed(block)).collect()
        } else {
            (0..workers).map(|_| AlignedVec::new()).collect()
        };
        let resident_bytes = streams.iter().map(CondensedStream::resident_bytes).sum::<u64>()
            + seed.iter().map(|t| t.len() as u64 * 4).sum::<u64>();
        PreparedNative {
            image,
            block,
            workers,
            streams,
            scratch: ScratchPool::with_seed(seed),
            cost: PrepareCost { wall: t0.elapsed(), resident_bytes },
        }
    }
}

impl SpmmBackend for NativeBackend {
    fn name(&self) -> &'static str {
        match self.block {
            BlockPolicy::Off => "native",
            BlockPolicy::Fixed(_) | BlockPolicy::Adaptive => "native-blocked",
        }
    }

    fn capability(&self) -> Capability {
        Capability {
            threads: self.threads,
            simd_lanes: LANES,
            requires_artifacts: false,
            deterministic: true,
        }
    }

    fn prepare(&self, image: Arc<ScheduledMatrix>) -> Result<Box<dyn PreparedSpmm>, BackendError> {
        Ok(Box::new(self.build(image)))
    }

    fn prepare_send(
        &self,
        image: Arc<ScheduledMatrix>,
    ) -> Result<Box<dyn PreparedSpmm + Send + Sync>, BackendError> {
        Ok(Box::new(self.build(image)))
    }
}

/// One PE's decoded stream, condensed at prepare time: CSR-like
/// per-output-row segments in an SoA layout (8 bytes per non-zero vs 12
/// for the old `(row, col, val)` triples). Built by a *stable* counting
/// sort, so within each output row the slot-issue order — the
/// accumulation-order half of the bit-identity contract — is preserved
/// exactly.
struct CondensedStream {
    /// Segment bounds per local output row: row `t`'s non-zeros are
    /// `cols[row_ptr[t] as usize..row_ptr[t + 1] as usize]` (and the same
    /// range of `vals`), in slot-issue order. Length `rows_per_pe + 1`.
    row_ptr: Vec<u32>,
    /// Global B-row index of each non-zero, grouped by local output row.
    cols: Vec<u32>,
    /// Non-zero values, parallel to `cols`.
    vals: Vec<f32>,
}

impl CondensedStream {
    /// Stable counting sort of issue-order triples by local output row.
    fn condense(rows_per_pe: usize, rows: &[u32], cols: &[u32], vals: &[f32]) -> CondensedStream {
        debug_assert!(rows.len() < u32::MAX as usize, "per-PE stream exceeds u32 indexing");
        let mut row_ptr = vec![0u32; rows_per_pe + 1];
        for &r in rows {
            row_ptr[r as usize + 1] += 1;
        }
        for t in 0..rows_per_pe {
            row_ptr[t + 1] += row_ptr[t];
        }
        let mut out_cols = vec![0u32; cols.len()];
        let mut out_vals = vec![0f32; vals.len()];
        let mut cursor: Vec<u32> = row_ptr[..rows_per_pe].to_vec();
        for ((&r, &gc), &v) in rows.iter().zip(cols).zip(vals) {
            let slot = cursor[r as usize] as usize;
            out_cols[slot] = gc;
            out_vals[slot] = v;
            cursor[r as usize] += 1;
        }
        CondensedStream { row_ptr, cols: out_cols, vals: out_vals }
    }

    fn resident_bytes(&self) -> u64 {
        (self.row_ptr.len() as u64 + self.cols.len() as u64 + self.vals.len() as u64) * 4
    }
}

/// A matrix resident on the native engine: condensed per-PE streams
/// (shared, read-only) plus a pool of per-call scratch sets, ready for any
/// number of — including concurrent — (B, n, alpha, beta).
pub struct PreparedNative {
    image: Arc<ScheduledMatrix>,
    /// Resolved column-block width; 0 = unblocked.
    block: usize,
    /// Worker-thread count (<= P, >= 1), fixed at prepare.
    workers: usize,
    /// Per-PE condensed streams (per-output-row segments in issue order,
    /// bubbles dropped). Read-only after prepare — the shared half of the
    /// `&self` execution contract.
    streams: Vec<CondensedStream>,
    /// Pool of per-call scratch sets — one 32-byte-aligned block-width
    /// accumulator per worker, reused across requests and across the PEs
    /// a worker owns. One set is checked out per execution, so the pool
    /// holds at most as many sets as there are concurrent callers.
    scratch: ScratchPool<Vec<AlignedVec>>,
    cost: PrepareCost,
}

impl PreparedNative {
    /// The resident image.
    pub fn image(&self) -> &Arc<ScheduledMatrix> {
        &self.image
    }

    /// Scratch sets currently parked in the internal pool (none checked
    /// out ⇒ the handle's whole scratch footprint). The pool holds at most
    /// one set per peak *concurrent* execution — exposed so tests can
    /// assert that bound.
    pub fn scratch_sets(&self) -> usize {
        self.scratch.idle()
    }

    /// The column-block width this matrix resolved to at prepare time
    /// (0 = unblocked). For [`NativeBackend::blocked`] this is the
    /// [`adaptive_col_block`] choice; narrow requests (N ≤ [`LANES`])
    /// bypass it at execute time.
    pub fn col_block(&self) -> usize {
        self.block
    }
}

/// Raw C pointer wrapper so scoped workers can write disjoint rows of the
/// shared output. Safety rests on the PE row partition: global row
/// `t * P + pe` is touched only by the worker owning `pe`, and each `pe`
/// is owned by exactly one worker.
#[derive(Clone, Copy)]
struct CPtr(*mut f32);

unsafe impl Send for CPtr {}
unsafe impl Sync for CPtr {}

/// Process every PE in `pe0, pe0 + stride, ...` for the column slice
/// `[col0, col0 + cols)` of B/C, one output row at a time: accumulate the
/// row's condensed segment into `acc` (narrow requests: straight into a
/// masked vector register) and Comp-C it into the shared C buffer. The
/// unblocked engine passes one full-width slice; the blocked engine calls
/// once per block-wide slice.
#[allow(clippy::too_many_arguments)]
fn run_pes(
    sm: &ScheduledMatrix,
    streams: &[CondensedStream],
    b: &[f32],
    c: CPtr,
    n: usize,
    alpha: f32,
    beta: f32,
    isa: Isa,
    acc: &mut [f32],
    pe0: usize,
    stride: usize,
    col0: usize,
    cols: usize,
) {
    let rows_per_pe = sm.rows_per_pe();
    let narrow = n <= LANES;
    debug_assert!(col0 + cols <= n);
    debug_assert!(if narrow { col0 == 0 && cols == n } else { acc.len() == cols });
    let mut pe = pe0;
    while pe < sm.p {
        let cs = &streams[pe];
        for t in 0..rows_per_pe {
            let gr = t * sm.p + pe;
            if gr >= sm.m {
                break;
            }
            let lo = cs.row_ptr[t] as usize;
            let hi = cs.row_ptr[t + 1] as usize;
            // SAFETY: rows `gr ≡ pe (mod P)` are written only by the
            // worker owning `pe` (see CPtr), `gr < m` and
            // `col0 + cols <= n`, so this row slice is in bounds of the
            // `m * n` buffer and disjoint from every other worker's
            // slices.
            let c_row = unsafe { std::slice::from_raw_parts_mut(c.0.add(gr * n + col0), cols) };
            let seg_cols = &cs.cols[lo..hi];
            let seg_vals = &cs.vals[lo..hi];
            if narrow {
                simd::row_narrow(isa, seg_cols, seg_vals, b, n, c_row, alpha, beta);
            } else {
                simd::row_block(isa, seg_cols, seg_vals, b, n, col0, acc);
                simd::comp_c(isa, c_row, acc, alpha, beta);
            }
        }
        pe += stride;
    }
}

impl PreparedSpmm for PreparedNative {
    fn backend_name(&self) -> &'static str {
        if self.block == 0 {
            "native"
        } else {
            "native-blocked"
        }
    }

    fn prepare_cost(&self) -> PrepareCost {
        self.cost
    }

    fn resident_bytes_now(&self) -> u64 {
        // Condensed streams are fixed at prepare; the scratch pool grows
        // with request width (accumulators are grow-only) and with peak
        // concurrency (one set per simultaneous caller), so it is
        // measured live.
        let streams: u64 = self.streams.iter().map(CondensedStream::resident_bytes).sum();
        let pooled = self.scratch.measure(|set| set.iter().map(|tile| tile.len() as u64 * 4).sum());
        streams + pooled
    }

    fn trim_resident(&self, max_idle: std::time::Duration) -> u64 {
        // The condensed streams are the handle's reason to exist; only the
        // pooled scratch sets (sized by peak concurrency and request
        // width) are reclaimable.
        self.scratch.trim_idle(max_idle, |set| set.iter().map(|tile| tile.len() as u64 * 4).sum())
    }

    fn execute(
        &self,
        b: &[f32],
        c: &mut [f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<(), BackendError> {
        let sm: &ScheduledMatrix = &self.image;
        check_shapes(sm, b, c, n)?;
        if sm.p == 0 || sm.m == 0 || n == 0 {
            return Ok(());
        }
        let workers = self.workers;
        let isa = simd::active();
        // Narrow requests keep each output row in one masked register:
        // no blocking, no scratch. Otherwise: full width when unblocked,
        // else the prepared block width.
        let narrow = n <= LANES;
        let block = if narrow || self.block == 0 { n } else { self.block.min(n) };
        // Per-call mutable state: check one scratch set out of the pool
        // (concurrent callers each get their own; the lock covers only
        // this checkout and the drop at the end, never the multiply).
        let mut set = self.scratch.checkout(|| (0..workers).map(|_| AlignedVec::new()).collect());
        if !narrow {
            for buf in &mut set[..workers] {
                buf.ensure_len(block);
            }
        }
        let streams: &[CondensedStream] = &self.streams;
        let cptr = CPtr(c.as_mut_ptr());
        if workers == 1 {
            let buf = &mut set[0];
            let mut col0 = 0;
            while col0 < n {
                let cols = block.min(n - col0);
                let acc_len = if narrow { 0 } else { cols };
                run_pes(
                    sm,
                    streams,
                    b,
                    cptr,
                    n,
                    alpha,
                    beta,
                    isa,
                    &mut buf[..acc_len],
                    0,
                    1,
                    col0,
                    cols,
                );
                col0 += cols;
            }
            return Ok(());
        }
        std::thread::scope(|s| {
            for (w, buf) in set[..workers].iter_mut().enumerate() {
                let worker_c = cptr;
                s.spawn(move || {
                    let mut col0 = 0;
                    while col0 < n {
                        let cols = block.min(n - col0);
                        let acc_len = if narrow { 0 } else { cols };
                        run_pes(
                            sm,
                            streams,
                            b,
                            worker_c,
                            n,
                            alpha,
                            beta,
                            isa,
                            &mut buf[..acc_len],
                            w,
                            workers,
                            col0,
                            cols,
                        );
                        col0 += cols;
                    }
                });
            }
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::functional;
    use crate::prop;
    use crate::sched::preprocess;
    use crate::sparse::{gen, rng::Rng, Coo};

    fn run_native(
        threads: usize,
        sm: &Arc<ScheduledMatrix>,
        b: &[f32],
        c0: &[f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Vec<f32> {
        let handle = NativeBackend::new(threads).build(Arc::clone(sm));
        let mut c = c0.to_vec();
        handle.execute(b, &mut c, n, alpha, beta).unwrap();
        c
    }

    #[test]
    fn matches_functional_bitwise() {
        let mut rng = Rng::new(1);
        let a = gen::random_uniform(96, 80, 0.12, &mut rng);
        let sm = Arc::new(preprocess(&a, 8, 16, 6));
        let n = 11; // deliberately not a multiple of LANES
        let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..a.m * n).map(|_| rng.normal()).collect();
        let mut want = c0.clone();
        functional::execute(&sm, &b, &mut want, n, 1.5, -0.25);
        for threads in [1, 2, 4, 8] {
            let got = run_native(threads, &sm, &b, &c0, n, 1.5, -0.25);
            assert_eq!(got, want, "threads = {threads}");
        }
    }

    #[test]
    fn narrow_n_fast_path_matches_functional_bitwise() {
        // Every N on the register-resident path (N <= LANES), including
        // the masked widths, must still match the reference bit for bit.
        let mut rng = Rng::new(17);
        let a = gen::power_law_rows(100, 90, 1_500, 1.0, &mut rng);
        let sm = Arc::new(preprocess(&a, 8, 16, 6));
        for n in 1..=LANES {
            let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
            let c0: Vec<f32> = (0..a.m * n).map(|_| rng.normal()).collect();
            let mut want = c0.clone();
            functional::execute(&sm, &b, &mut want, n, -0.75, 1.25);
            for threads in [1, 3] {
                let got = run_native(threads, &sm, &b, &c0, n, -0.75, 1.25);
                assert_eq!(got, want, "n = {n}, threads = {threads}");
            }
        }
    }

    #[test]
    fn thread_count_does_not_change_bits() {
        let mut rng = Rng::new(2);
        let a = gen::power_law_rows(150, 120, 2_000, 1.0, &mut rng);
        let sm = Arc::new(preprocess(&a, 16, 32, 10));
        let n = 8;
        let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..a.m * n).map(|_| rng.normal()).collect();
        let base = run_native(1, &sm, &b, &c0, n, 2.0, 0.5);
        for threads in [2, 3, 5, 16, 64] {
            assert_eq!(run_native(threads, &sm, &b, &c0, n, 2.0, 0.5), base);
        }
    }

    #[test]
    fn one_handle_many_requests_reuses_scratch() {
        let mut rng = Rng::new(3);
        let a = gen::random_uniform(40, 40, 0.2, &mut rng);
        let sm = Arc::new(preprocess(&a, 4, 16, 4));
        let n = 4;
        let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
        let handle = NativeBackend::new(2).build(Arc::clone(&sm));
        let mut first = vec![0f32; a.m * n];
        handle.execute(&b, &mut first, n, 1.0, 0.0).unwrap();
        // Second request with dirty scratch must produce identical output.
        let mut second = vec![0f32; a.m * n];
        handle.execute(&b, &mut second, n, 1.0, 0.0).unwrap();
        assert_eq!(first, second);
        // N may change across calls against the same handle.
        let n2 = 9;
        let b2: Vec<f32> = (0..a.k * n2).map(|_| rng.normal()).collect();
        let mut wide = vec![0f32; a.m * n2];
        handle.execute(&b2, &mut wide, n2, 1.0, 0.0).unwrap();
        let mut want = vec![0f32; a.m * n2];
        a.spmm_reference(&b2, &mut want, n2, 1.0, 0.0);
        prop::assert_allclose(&wide, &want, 2e-4, 2e-4).unwrap();
    }

    #[test]
    fn prepare_cost_reports_resident_streams() {
        let mut rng = Rng::new(8);
        let a = gen::random_uniform(60, 60, 0.1, &mut rng);
        let sm = Arc::new(preprocess(&a, 4, 16, 4));
        let handle = NativeBackend::new(2).build(Arc::clone(&sm));
        let cost = handle.prepare_cost();
        // 8 bytes per condensed non-zero at minimum (SoA cols + vals).
        assert!(cost.resident_bytes >= 8 * a.nnz() as u64, "{cost:?}");
        // Blocked variant additionally pre-sizes its accumulators.
        let blocked = NativeBackend::blocked(2).build(Arc::clone(&sm));
        assert!(blocked.prepare_cost().resident_bytes > cost.resident_bytes);
    }

    #[test]
    fn resident_bytes_now_tracks_grown_scratch() {
        let mut rng = Rng::new(9);
        let a = gen::random_uniform(60, 60, 0.1, &mut rng);
        let sm = Arc::new(preprocess(&a, 4, 16, 4));
        let handle = NativeBackend::new(2).build(Arc::clone(&sm));
        let at_prepare = handle.prepare_cost().resident_bytes;
        assert_eq!(
            handle.resident_bytes_now(),
            at_prepare,
            "before any execution the live footprint is the prepare estimate"
        );
        // A wide request grows the (unblocked) accumulators well past the
        // empty seed; the live measurement must see it, the static one
        // cannot.
        let n = 200;
        let b = vec![1.0f32; a.k * n];
        let mut c = vec![0.0f32; a.m * n];
        handle.execute(&b, &mut c, n, 1.0, 0.0).unwrap();
        assert!(
            handle.resident_bytes_now() > at_prepare,
            "grown scratch missing from the live footprint: {} <= {at_prepare}",
            handle.resident_bytes_now()
        );
        assert_eq!(handle.prepare_cost().resident_bytes, at_prepare);
    }

    #[test]
    fn empty_matrix_is_pure_comp_c() {
        let a = Coo::empty(6, 6);
        let sm = Arc::new(preprocess(&a, 4, 4, 2));
        let b = vec![1.0; 12];
        let mut c = vec![2.0; 12];
        NativeBackend::new(4).build(sm).execute(&b, &mut c, 2, 9.0, 0.5).unwrap();
        assert_eq!(c, vec![1.0; 12]);
    }

    #[test]
    fn rejects_shape_mismatch() {
        let a = Coo::empty(4, 4);
        let sm = Arc::new(preprocess(&a, 2, 2, 2));
        let b = vec![0.0; 7]; // not k * n
        let mut c = vec![0.0; 8];
        let err = NativeBackend::new(1).build(sm).execute(&b, &mut c, 2, 1.0, 0.0).unwrap_err();
        assert!(matches!(err, BackendError::Shape(_)));
    }

    #[test]
    fn more_threads_than_pes_is_fine() {
        let mut rng = Rng::new(4);
        let a = gen::random_uniform(10, 10, 0.3, &mut rng);
        let sm = Arc::new(preprocess(&a, 2, 4, 3));
        let n = 3;
        let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
        let c0 = vec![0f32; a.m * n];
        let got = run_native(32, &sm, &b, &c0, n, 1.0, 0.0);
        let mut want = vec![0f32; a.m * n];
        a.spmm_reference(&b, &mut want, n, 1.0, 0.0);
        prop::assert_allclose(&got, &want, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn adaptive_width_is_clamped_and_lane_aligned() {
        // A tiny working set saturates at the upper clamp.
        assert_eq!(adaptive_col_block(1, 2 * 1024 * 1024), MAX_COL_BLOCK);
        // A huge working set floors at one vector register.
        assert_eq!(adaptive_col_block(10_000_000, 256 * 1024), LANES);
        // In between: lane-aligned and monotone in the L2 budget.
        let narrow_l2 = adaptive_col_block(2_000, 256 * 1024);
        let wide_l2 = adaptive_col_block(2_000, 4 * 1024 * 1024);
        assert_eq!(narrow_l2 % LANES, 0);
        assert_eq!(wide_l2 % LANES, 0);
        assert!(narrow_l2 <= wide_l2);
        assert!((LANES..=MAX_COL_BLOCK).contains(&narrow_l2));
        // distinct_b_rows = 0 (empty matrix) must not divide by zero.
        assert!(adaptive_col_block(0, 1024 * 1024) >= LANES);
    }

    #[test]
    fn blocked_is_bit_identical_to_native() {
        // Column blocking reorders nothing per output element, so the
        // blocked engine — adaptive or any fixed width — must match the
        // plain one bitwise, including N below, at, and far beyond the
        // width, and N not a multiple of it.
        let mut rng = Rng::new(11);
        let a = gen::power_law_rows(120, 100, 1_800, 1.0, &mut rng);
        let sm = Arc::new(preprocess(&a, 8, 32, 6));
        for n in [1usize, 11, COL_BLOCK, COL_BLOCK + 1, 3 * COL_BLOCK + 7] {
            let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
            let c0: Vec<f32> = (0..a.m * n).map(|_| rng.normal()).collect();
            for threads in [1usize, 4] {
                let plain = run_native(threads, &sm, &b, &c0, n, 1.5, -0.25);
                let adaptive = NativeBackend::blocked(threads).build(Arc::clone(&sm));
                let mut c = c0.clone();
                adaptive.execute(&b, &mut c, n, 1.5, -0.25).unwrap();
                assert_eq!(c, plain, "adaptive: n = {n}, threads = {threads}");
                for width in [LANES, COL_BLOCK, 100] {
                    let fixed = NativeBackend::with_block(threads, width).build(Arc::clone(&sm));
                    let mut c = c0.clone();
                    fixed.execute(&b, &mut c, n, 1.5, -0.25).unwrap();
                    assert_eq!(c, plain, "width = {width}, n = {n}, threads = {threads}");
                }
            }
        }
    }

    #[test]
    fn blocked_identity_and_scratch_reuse() {
        let mut rng = Rng::new(12);
        let a = gen::random_uniform(50, 40, 0.15, &mut rng);
        let sm = Arc::new(preprocess(&a, 4, 16, 5));
        let n = 150; // several blocks
        let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
        let backend = NativeBackend::blocked(2);
        assert_eq!(backend.name(), "native-blocked");
        assert_eq!(backend.block_width(), 0, "adaptive width resolves per matrix at prepare");
        assert_eq!(NativeBackend::with_block(2, COL_BLOCK).block_width(), COL_BLOCK);
        let handle = backend.build(Arc::clone(&sm));
        assert_eq!(handle.backend_name(), "native-blocked");
        let width = handle.col_block();
        assert!((LANES..=MAX_COL_BLOCK).contains(&width), "resolved width {width}");
        assert_eq!(width % LANES, 0, "resolved width {width} not lane-aligned");
        let mut first = vec![0f32; a.m * n];
        handle.execute(&b, &mut first, n, 1.0, 0.0).unwrap();
        // Dirty scratch from the first request must not leak into the next.
        let mut second = vec![0f32; a.m * n];
        handle.execute(&b, &mut second, n, 1.0, 0.0).unwrap();
        assert_eq!(first, second);
        let mut want = vec![0f32; a.m * n];
        a.spmm_reference(&b, &mut want, n, 1.0, 0.0);
        prop::assert_allclose(&first, &want, 2e-4, 2e-4).unwrap();
    }

    #[test]
    fn concurrent_executions_share_one_handle_bit_identically() {
        // The &self contract: W threads hammer ONE prepared handle with no
        // external lock; every result matches the serial run bitwise, and
        // the internal scratch pool never grows beyond the number of
        // concurrent callers.
        let mut rng = Rng::new(21);
        let a = gen::power_law_rows(120, 90, 1_500, 1.0, &mut rng);
        let sm = Arc::new(preprocess(&a, 8, 16, 6));
        let n = 6;
        let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..a.m * n).map(|_| rng.normal()).collect();
        let handle = NativeBackend::new(2).build(Arc::clone(&sm));
        let mut serial = c0.clone();
        handle.execute(&b, &mut serial, n, 1.5, -0.25).unwrap();
        let callers = 4;
        let results: Vec<Vec<f32>> = std::thread::scope(|s| {
            (0..callers)
                .map(|_| {
                    s.spawn(|| {
                        let mut c = c0.clone();
                        for _ in 0..8 {
                            handle.execute(&b, &mut c, n, 1.5, -0.25).unwrap();
                            c.copy_from_slice(&c0);
                        }
                        handle.execute(&b, &mut c, n, 1.5, -0.25).unwrap();
                        c
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        for c in &results {
            assert_eq!(c, &serial, "concurrent result diverged from serial");
        }
        let sets = handle.scratch_sets();
        assert!(
            (1..=callers).contains(&sets),
            "scratch pool holds {sets} sets for {callers} concurrent callers"
        );
    }

    #[test]
    fn native_matches_reference_property() {
        prop::check("native_vs_reference", 0x7A71, 24, |rng| {
            let m = 1 + rng.index(80);
            let k = 1 + rng.index(80);
            let n = 1 + rng.index(12);
            let a = gen::random_uniform(m, k, 0.05 + rng.f64() * 0.2, rng);
            let p = 1 + rng.index(8);
            let k0 = 1 + rng.index(32);
            let d = 1 + rng.index(10);
            let sm = Arc::new(preprocess(&a, p, k0, d));
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
            let c0: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
            let alpha = rng.range_f32(-2.0, 2.0);
            let beta = rng.range_f32(-2.0, 2.0);
            let threads = 1 + rng.index(6);
            let mut want = c0.clone();
            a.spmm_reference(&b, &mut want, n, alpha, beta);
            let got = run_native(threads, &sm, &b, &c0, n, alpha, beta);
            prop::assert_allclose(&got, &want, 2e-4, 2e-4)
        });
    }
}
