//! Native multi-threaded SpMM engine over scheduled images, in two-phase
//! prepare/execute form.
//!
//! The paper's hardware runs P PEs in parallel, each consuming its own
//! scheduled slot stream and owning the output rows `r ≡ pe (mod P)` in its
//! C scratchpad. That row partition is exactly what makes a host
//! parallelization safe: the prepared handle assigns the P streams
//! round-robin to worker threads (`std::thread::scope`), each worker
//! accumulates a PE's rows into a reusable private scratch tile (the
//! scratchpad analogue), and the Comp-C stage writes each PE's disjoint row
//! set straight into C.
//!
//! **Prepare** ([`SpmmBackend::prepare`]) decodes every PE stream once:
//! bubbles are dropped, window-local columns are resolved to global B rows,
//! and the result is stored as flat `(row, col, val)` triples in slot-issue
//! order. Steady-state execution therefore never touches the 64-bit
//! encoding again — it is pure axpy + Comp-C over pre-sized scratch, which
//! is the point of the A-resident serving contract.
//!
//! Numerics are bit-identical to [`crate::arch::functional::execute`]: per
//! output element, the accumulation order is the PE's slot issue order in
//! both implementations (dropping bubbles removes only zero contributions),
//! and the final `alpha * C_AB + beta * C_in` is the same expression. The
//! inner loop is chunked to [`LANES`] = 8 columns — the paper's N0 = 8 SIMD
//! float lanes — which vectorizes cleanly without changing the per-element
//! order of adds.
//!
//! Hot-path allocation is zero after warm-up: the handle keeps a
//! [`ScratchPool`] of per-call scratch *sets* (one tile per worker), each
//! execution checks one set out, and tiles only grow (never shrink) across
//! requests; the blocked variant seeds a fully pre-sized set at prepare
//! time. Because the decoded streams are read-only and all mutable state
//! is pooled, `execute` takes `&self` — any number of threads may drive
//! one handle concurrently, each on its own scratch set.
//!
//! **Column blocking** ([`NativeBackend::blocked`], registry name
//! `"native-blocked"`): for N well beyond [`COL_BLOCK`], the B window rows
//! and C tile of one request stop fitting in cache, so the blocked variant
//! sweeps the same streams once per [`COL_BLOCK`]-wide column slice. It
//! re-reads the decoded A triples per slice (12 B/nnz, streams linearly) in
//! exchange for keeping the random-access B/C working set cache-resident —
//! the host mirror of the paper's N/N0 outer loop (Eq. 2). Per output
//! element the accumulation order is unchanged, so `native-blocked` is
//! bit-identical to `native`.

use std::sync::Arc;
use std::time::Instant;

use super::{
    check_shapes, BackendError, Capability, PrepareCost, PreparedSpmm, ScratchPool, SpmmBackend,
};
use crate::sched::{decode, ScheduledMatrix};

/// Inner-loop chunk width — the paper's N0 (8 PUs per PE).
pub const LANES: usize = 8;

/// Column-block width of the `native-blocked` variant (8 LANES-wide
/// chunks; sized so one B window row slice + C tile stays L1/L2-resident).
pub const COL_BLOCK: usize = 64;

/// Multi-threaded native backend factory. Stateless: per-matrix state
/// (decoded streams, scratch) lives in the [`PreparedNative`] handles it
/// produces.
pub struct NativeBackend {
    /// Resolved worker-thread count (>= 1).
    threads: usize,
    /// Column-block width; 0 = unblocked (the plain `native` engine).
    block_n: usize,
}

impl NativeBackend {
    /// `threads == 0` auto-sizes to the machine's available parallelism.
    pub fn new(threads: usize) -> NativeBackend {
        Self::with_block(threads, 0)
    }

    /// The `native-blocked` variant: sweeps columns in [`COL_BLOCK`]-wide
    /// slices for wide-N workloads. Same numerics, different cache story.
    pub fn blocked(threads: usize) -> NativeBackend {
        Self::with_block(threads, COL_BLOCK)
    }

    /// Explicit column-block width (`0` = unblocked); exposed for tuning
    /// experiments and the bench harness.
    pub fn with_block(threads: usize, block_n: usize) -> NativeBackend {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        NativeBackend { threads, block_n }
    }

    /// The resolved worker-thread count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Column-block width (0 = unblocked).
    pub fn block_width(&self) -> usize {
        self.block_n
    }

    /// Concrete-typed prepare: identical to [`SpmmBackend::prepare`] but
    /// returns [`PreparedNative`] directly, for callers that need its
    /// inherent accessors (the scratch-pool sizing tests, benches).
    pub fn build(&self, image: Arc<ScheduledMatrix>) -> PreparedNative {
        let t0 = Instant::now();
        // Decode every PE stream once: drop bubbles, resolve window-local
        // columns to global B rows, keep slot-issue order (the accumulation
        // order contract).
        let streams: Vec<Vec<(u32, u32, f32)>> = image
            .streams
            .iter()
            .map(|stream| {
                let mut out = Vec::with_capacity(stream.nnz);
                for j in 0..image.num_windows {
                    let col_base = (j * image.k0) as u32;
                    for &word in &stream.encoded[stream.q.window_range(j)] {
                        let nz = decode(word);
                        if nz.val == 0.0 {
                            continue; // bubble (or explicit zero: same arithmetic)
                        }
                        out.push((nz.row, col_base + nz.col, nz.val));
                    }
                }
                out
            })
            .collect();
        let workers = self.threads.min(image.p).max(1);
        // Seed the scratch pool with one per-call set (one tile per
        // worker). Blocked tiles are fully pre-sized here (their width is
        // fixed); unblocked tiles size themselves to N on first execute
        // and are grow-only afterwards. Additional sets are created only
        // by *concurrent* executions, one per simultaneous caller.
        let seed: Vec<Vec<f32>> = if self.block_n > 0 {
            (0..workers).map(|_| vec![0.0; image.rows_per_pe() * self.block_n]).collect()
        } else {
            (0..workers).map(|_| Vec::new()).collect()
        };
        let triple_bytes = std::mem::size_of::<(u32, u32, f32)>() as u64;
        let resident_bytes = streams.iter().map(|s| s.len() as u64 * triple_bytes).sum::<u64>()
            + seed.iter().map(|s| s.len() as u64 * 4).sum::<u64>();
        PreparedNative {
            image,
            block_n: self.block_n,
            workers,
            streams,
            scratch: ScratchPool::with_seed(seed),
            cost: PrepareCost { wall: t0.elapsed(), resident_bytes },
        }
    }
}

impl SpmmBackend for NativeBackend {
    fn name(&self) -> &'static str {
        if self.block_n == 0 {
            "native"
        } else {
            "native-blocked"
        }
    }

    fn capability(&self) -> Capability {
        Capability {
            threads: self.threads,
            simd_lanes: LANES,
            requires_artifacts: false,
            deterministic: true,
        }
    }

    fn prepare(&self, image: Arc<ScheduledMatrix>) -> Result<Box<dyn PreparedSpmm>, BackendError> {
        Ok(Box::new(self.build(image)))
    }

    fn prepare_send(
        &self,
        image: Arc<ScheduledMatrix>,
    ) -> Result<Box<dyn PreparedSpmm + Send + Sync>, BackendError> {
        Ok(Box::new(self.build(image)))
    }
}

/// A matrix resident on the native engine: decoded per-PE streams (shared,
/// read-only) plus a pool of per-call scratch sets, ready for any number
/// of — including concurrent — (B, n, alpha, beta).
pub struct PreparedNative {
    image: Arc<ScheduledMatrix>,
    /// Column-block width; 0 = unblocked.
    block_n: usize,
    /// Worker-thread count (<= P, >= 1), fixed at prepare.
    workers: usize,
    /// Per-PE decoded slot streams in issue order: (local row, global col,
    /// value); bubbles dropped. Read-only after prepare — the shared half
    /// of the `&self` execution contract.
    streams: Vec<Vec<(u32, u32, f32)>>,
    /// Pool of per-call scratch sets — one C_AB tile per worker
    /// (`rows_per_pe * block width`), tiles reused across requests and
    /// across the PEs a worker owns. One set is checked out per execution,
    /// so the pool holds at most as many sets as there are concurrent
    /// callers.
    scratch: ScratchPool<Vec<Vec<f32>>>,
    cost: PrepareCost,
}

impl PreparedNative {
    /// The resident image.
    pub fn image(&self) -> &Arc<ScheduledMatrix> {
        &self.image
    }

    /// Scratch sets currently parked in the internal pool (none checked
    /// out ⇒ the handle's whole scratch footprint). The pool holds at most
    /// one set per peak *concurrent* execution — exposed so tests can
    /// assert that bound.
    pub fn scratch_sets(&self) -> usize {
        self.scratch.idle()
    }
}

/// `y[..] += a * x[..]`, chunked to [`LANES`] so LLVM vectorizes the body.
/// Element order is unchanged (each output lane is independent).
#[inline]
fn axpy(y: &mut [f32], x: &[f32], a: f32) {
    debug_assert_eq!(y.len(), x.len());
    let mut yc = y.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (yl, xl) in (&mut yc).zip(&mut xc) {
        for l in 0..LANES {
            yl[l] += a * xl[l];
        }
    }
    for (yl, xl) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yl += a * xl;
    }
}

/// Raw C pointer wrapper so scoped workers can write disjoint rows of the
/// shared output. Safety rests on the PE row partition: global row
/// `t * P + pe` is touched only by the worker owning `pe`, and each `pe`
/// is owned by exactly one worker.
#[derive(Clone, Copy)]
struct CPtr(*mut f32);

unsafe impl Send for CPtr {}
unsafe impl Sync for CPtr {}

/// Process every PE in `pe0, pe0 + stride, ...` for the column slice
/// `[col0, col0 + cols)` of B/C: accumulate the PE's decoded stream into
/// `ab` (a `rows_per_pe x cols` tile, cleared per PE), then Comp-C its rows
/// of the shared C buffer. The unblocked engine passes one full-width
/// slice; the blocked engine calls once per [`COL_BLOCK`]-wide slice.
#[allow(clippy::too_many_arguments)]
fn run_pes(
    sm: &ScheduledMatrix,
    streams: &[Vec<(u32, u32, f32)>],
    b: &[f32],
    c: CPtr,
    n: usize,
    alpha: f32,
    beta: f32,
    ab: &mut [f32],
    pe0: usize,
    stride: usize,
    col0: usize,
    cols: usize,
) {
    let rows_per_pe = sm.rows_per_pe();
    debug_assert_eq!(ab.len(), rows_per_pe * cols);
    debug_assert!(col0 + cols <= n);
    let mut pe = pe0;
    while pe < sm.p {
        ab.fill(0.0);
        for &(r, gc, val) in &streams[pe] {
            let r = r as usize;
            let gc = gc as usize;
            debug_assert!(r < rows_per_pe && gc < sm.k);
            axpy(
                &mut ab[r * cols..(r + 1) * cols],
                &b[gc * n + col0..gc * n + col0 + cols],
                val,
            );
        }
        // Comp-C for this PE's (disjoint) rows of the shared C.
        for t in 0..rows_per_pe {
            let gr = t * sm.p + pe;
            if gr >= sm.m {
                break;
            }
            let ab_row = &ab[t * cols..(t + 1) * cols];
            for (q, &v) in ab_row.iter().enumerate() {
                // SAFETY: rows `gr ≡ pe (mod P)` are written only by the
                // worker owning `pe` (see CPtr), and `gr < m`,
                // `col0 + q < n`, so the index is in bounds of the `m * n`
                // buffer.
                unsafe {
                    let slot = c.0.add(gr * n + col0 + q);
                    *slot = alpha * v + beta * *slot;
                }
            }
        }
        pe += stride;
    }
}

impl PreparedSpmm for PreparedNative {
    fn backend_name(&self) -> &'static str {
        if self.block_n == 0 {
            "native"
        } else {
            "native-blocked"
        }
    }

    fn prepare_cost(&self) -> PrepareCost {
        self.cost
    }

    fn resident_bytes_now(&self) -> u64 {
        // Decoded streams are fixed at prepare; the scratch pool grows with
        // request width (tiles are grow-only) and with peak concurrency
        // (one set per simultaneous caller), so it is measured live.
        let triple_bytes = std::mem::size_of::<(u32, u32, f32)>() as u64;
        let streams: u64 =
            self.streams.iter().map(|s| s.len() as u64 * triple_bytes).sum();
        let pooled = self
            .scratch
            .measure(|set| set.iter().map(|tile| tile.len() as u64 * 4).sum());
        streams + pooled
    }

    fn trim_resident(&self, max_idle: std::time::Duration) -> u64 {
        // The decoded streams are the handle's reason to exist; only the
        // pooled scratch sets (sized by peak concurrency and request
        // width) are reclaimable.
        self.scratch
            .trim_idle(max_idle, |set| set.iter().map(|tile| tile.len() as u64 * 4).sum())
    }

    fn execute(
        &self,
        b: &[f32],
        c: &mut [f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<(), BackendError> {
        let sm: &ScheduledMatrix = &self.image;
        check_shapes(sm, b, c, n)?;
        if sm.p == 0 || sm.m == 0 || n == 0 {
            return Ok(());
        }
        let workers = self.workers;
        // Block width: full N when unblocked, else COL_BLOCK-capped slices.
        let block = if self.block_n == 0 { n } else { self.block_n.min(n) };
        let rows_per_pe = sm.rows_per_pe();
        let tile = rows_per_pe * block;
        // Per-call mutable state: check one scratch set out of the pool
        // (concurrent callers each get their own; the lock covers only
        // this checkout and the drop at the end, never the multiply).
        let mut set = self.scratch.checkout(|| vec![Vec::new(); workers]);
        for buf in &mut set[..workers] {
            if buf.len() < tile {
                buf.resize(tile, 0.0);
            }
        }
        let streams: &[Vec<(u32, u32, f32)>] = &self.streams;
        let cptr = CPtr(c.as_mut_ptr());
        if workers == 1 {
            let buf = &mut set[0];
            let mut col0 = 0;
            while col0 < n {
                let cols = block.min(n - col0);
                run_pes(
                    sm, streams, b, cptr, n, alpha, beta,
                    &mut buf[..rows_per_pe * cols],
                    0, 1, col0, cols,
                );
                col0 += cols;
            }
            return Ok(());
        }
        std::thread::scope(|s| {
            for (w, buf) in set[..workers].iter_mut().enumerate() {
                let worker_c = cptr;
                s.spawn(move || {
                    let mut col0 = 0;
                    while col0 < n {
                        let cols = block.min(n - col0);
                        run_pes(
                            sm, streams, b, worker_c, n, alpha, beta,
                            &mut buf[..rows_per_pe * cols],
                            w, workers, col0, cols,
                        );
                        col0 += cols;
                    }
                });
            }
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::functional;
    use crate::prop;
    use crate::sched::preprocess;
    use crate::sparse::{gen, rng::Rng, Coo};

    fn run_native(
        threads: usize,
        sm: &Arc<ScheduledMatrix>,
        b: &[f32],
        c0: &[f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Vec<f32> {
        let handle = NativeBackend::new(threads).build(Arc::clone(sm));
        let mut c = c0.to_vec();
        handle.execute(b, &mut c, n, alpha, beta).unwrap();
        c
    }

    #[test]
    fn matches_functional_bitwise() {
        let mut rng = Rng::new(1);
        let a = gen::random_uniform(96, 80, 0.12, &mut rng);
        let sm = Arc::new(preprocess(&a, 8, 16, 6));
        let n = 11; // deliberately not a multiple of LANES
        let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..a.m * n).map(|_| rng.normal()).collect();
        let mut want = c0.clone();
        functional::execute(&sm, &b, &mut want, n, 1.5, -0.25);
        for threads in [1, 2, 4, 8] {
            let got = run_native(threads, &sm, &b, &c0, n, 1.5, -0.25);
            assert_eq!(got, want, "threads = {threads}");
        }
    }

    #[test]
    fn thread_count_does_not_change_bits() {
        let mut rng = Rng::new(2);
        let a = gen::power_law_rows(150, 120, 2_000, 1.0, &mut rng);
        let sm = Arc::new(preprocess(&a, 16, 32, 10));
        let n = 8;
        let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..a.m * n).map(|_| rng.normal()).collect();
        let base = run_native(1, &sm, &b, &c0, n, 2.0, 0.5);
        for threads in [2, 3, 5, 16, 64] {
            assert_eq!(run_native(threads, &sm, &b, &c0, n, 2.0, 0.5), base);
        }
    }

    #[test]
    fn one_handle_many_requests_reuses_scratch() {
        let mut rng = Rng::new(3);
        let a = gen::random_uniform(40, 40, 0.2, &mut rng);
        let sm = Arc::new(preprocess(&a, 4, 16, 4));
        let n = 4;
        let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
        let handle = NativeBackend::new(2).build(Arc::clone(&sm));
        let mut first = vec![0f32; a.m * n];
        handle.execute(&b, &mut first, n, 1.0, 0.0).unwrap();
        // Second request with dirty scratch must produce identical output.
        let mut second = vec![0f32; a.m * n];
        handle.execute(&b, &mut second, n, 1.0, 0.0).unwrap();
        assert_eq!(first, second);
        // N may change across calls against the same handle.
        let n2 = 9;
        let b2: Vec<f32> = (0..a.k * n2).map(|_| rng.normal()).collect();
        let mut wide = vec![0f32; a.m * n2];
        handle.execute(&b2, &mut wide, n2, 1.0, 0.0).unwrap();
        let mut want = vec![0f32; a.m * n2];
        a.spmm_reference(&b2, &mut want, n2, 1.0, 0.0);
        prop::assert_allclose(&wide, &want, 2e-4, 2e-4).unwrap();
    }

    #[test]
    fn prepare_cost_reports_resident_streams() {
        let mut rng = Rng::new(8);
        let a = gen::random_uniform(60, 60, 0.1, &mut rng);
        let sm = Arc::new(preprocess(&a, 4, 16, 4));
        let handle = NativeBackend::new(2).build(Arc::clone(&sm));
        let cost = handle.prepare_cost();
        // 12 bytes per decoded non-zero at minimum.
        assert!(cost.resident_bytes >= 12 * a.nnz() as u64, "{cost:?}");
        // Blocked variant additionally pre-sizes its tiles.
        let blocked = NativeBackend::blocked(2).build(Arc::clone(&sm));
        assert!(blocked.prepare_cost().resident_bytes > cost.resident_bytes);
    }

    #[test]
    fn resident_bytes_now_tracks_grown_scratch() {
        let mut rng = Rng::new(9);
        let a = gen::random_uniform(60, 60, 0.1, &mut rng);
        let sm = Arc::new(preprocess(&a, 4, 16, 4));
        let handle = NativeBackend::new(2).build(Arc::clone(&sm));
        let at_prepare = handle.prepare_cost().resident_bytes;
        assert_eq!(
            handle.resident_bytes_now(),
            at_prepare,
            "before any execution the live footprint is the prepare estimate"
        );
        // A wide request grows the (unblocked) tiles well past the empty
        // seed; the live measurement must see it, the static one cannot.
        let n = 200;
        let b = vec![1.0f32; a.k * n];
        let mut c = vec![0.0f32; a.m * n];
        handle.execute(&b, &mut c, n, 1.0, 0.0).unwrap();
        assert!(
            handle.resident_bytes_now() > at_prepare,
            "grown scratch tiles missing from the live footprint: {} <= {at_prepare}",
            handle.resident_bytes_now()
        );
        assert_eq!(handle.prepare_cost().resident_bytes, at_prepare);
    }

    #[test]
    fn empty_matrix_is_pure_comp_c() {
        let a = Coo::empty(6, 6);
        let sm = Arc::new(preprocess(&a, 4, 4, 2));
        let b = vec![1.0; 12];
        let mut c = vec![2.0; 12];
        NativeBackend::new(4).build(sm).execute(&b, &mut c, 2, 9.0, 0.5).unwrap();
        assert_eq!(c, vec![1.0; 12]);
    }

    #[test]
    fn rejects_shape_mismatch() {
        let a = Coo::empty(4, 4);
        let sm = Arc::new(preprocess(&a, 2, 2, 2));
        let b = vec![0.0; 7]; // not k * n
        let mut c = vec![0.0; 8];
        let err =
            NativeBackend::new(1).build(sm).execute(&b, &mut c, 2, 1.0, 0.0).unwrap_err();
        assert!(matches!(err, BackendError::Shape(_)));
    }

    #[test]
    fn more_threads_than_pes_is_fine() {
        let mut rng = Rng::new(4);
        let a = gen::random_uniform(10, 10, 0.3, &mut rng);
        let sm = Arc::new(preprocess(&a, 2, 4, 3));
        let n = 3;
        let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
        let c0 = vec![0f32; a.m * n];
        let got = run_native(32, &sm, &b, &c0, n, 1.0, 0.0);
        let mut want = vec![0f32; a.m * n];
        a.spmm_reference(&b, &mut want, n, 1.0, 0.0);
        prop::assert_allclose(&got, &want, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn blocked_is_bit_identical_to_native() {
        // Column blocking reorders nothing per output element, so the
        // blocked engine must match the plain one bitwise — including N
        // that is smaller than, equal to, and far beyond COL_BLOCK, and N
        // not a multiple of the block width.
        let mut rng = Rng::new(11);
        let a = gen::power_law_rows(120, 100, 1_800, 1.0, &mut rng);
        let sm = Arc::new(preprocess(&a, 8, 32, 6));
        for n in [1usize, 11, COL_BLOCK, COL_BLOCK + 1, 3 * COL_BLOCK + 7] {
            let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
            let c0: Vec<f32> = (0..a.m * n).map(|_| rng.normal()).collect();
            for threads in [1usize, 4] {
                let plain = run_native(threads, &sm, &b, &c0, n, 1.5, -0.25);
                let blocked = NativeBackend::blocked(threads).build(Arc::clone(&sm));
                let mut c = c0.clone();
                blocked.execute(&b, &mut c, n, 1.5, -0.25).unwrap();
                assert_eq!(c, plain, "n = {n}, threads = {threads}");
            }
        }
    }

    #[test]
    fn blocked_identity_and_scratch_reuse() {
        let mut rng = Rng::new(12);
        let a = gen::random_uniform(50, 40, 0.15, &mut rng);
        let sm = Arc::new(preprocess(&a, 4, 16, 5));
        let n = 150; // several blocks
        let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
        let backend = NativeBackend::blocked(2);
        assert_eq!(backend.name(), "native-blocked");
        assert_eq!(backend.block_width(), COL_BLOCK);
        let handle = backend.build(Arc::clone(&sm));
        assert_eq!(handle.backend_name(), "native-blocked");
        let mut first = vec![0f32; a.m * n];
        handle.execute(&b, &mut first, n, 1.0, 0.0).unwrap();
        // Dirty scratch from the first request must not leak into the next.
        let mut second = vec![0f32; a.m * n];
        handle.execute(&b, &mut second, n, 1.0, 0.0).unwrap();
        assert_eq!(first, second);
        let mut want = vec![0f32; a.m * n];
        a.spmm_reference(&b, &mut want, n, 1.0, 0.0);
        prop::assert_allclose(&first, &want, 2e-4, 2e-4).unwrap();
    }

    #[test]
    fn concurrent_executions_share_one_handle_bit_identically() {
        // The &self contract: W threads hammer ONE prepared handle with no
        // external lock; every result matches the serial run bitwise, and
        // the internal scratch pool never grows beyond the number of
        // concurrent callers.
        let mut rng = Rng::new(21);
        let a = gen::power_law_rows(120, 90, 1_500, 1.0, &mut rng);
        let sm = Arc::new(preprocess(&a, 8, 16, 6));
        let n = 6;
        let b: Vec<f32> = (0..a.k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..a.m * n).map(|_| rng.normal()).collect();
        let handle = NativeBackend::new(2).build(Arc::clone(&sm));
        let mut serial = c0.clone();
        handle.execute(&b, &mut serial, n, 1.5, -0.25).unwrap();
        let callers = 4;
        let results: Vec<Vec<f32>> = std::thread::scope(|s| {
            (0..callers)
                .map(|_| {
                    s.spawn(|| {
                        let mut c = c0.clone();
                        for _ in 0..8 {
                            handle.execute(&b, &mut c, n, 1.5, -0.25).unwrap();
                            c.copy_from_slice(&c0);
                        }
                        handle.execute(&b, &mut c, n, 1.5, -0.25).unwrap();
                        c
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        for c in &results {
            assert_eq!(c, &serial, "concurrent result diverged from serial");
        }
        let sets = handle.scratch_sets();
        assert!(
            (1..=callers).contains(&sets),
            "scratch pool holds {sets} sets for {callers} concurrent callers"
        );
    }

    #[test]
    fn native_matches_reference_property() {
        prop::check("native_vs_reference", 0x7A71, 24, |rng| {
            let m = 1 + rng.index(80);
            let k = 1 + rng.index(80);
            let n = 1 + rng.index(12);
            let a = gen::random_uniform(m, k, 0.05 + rng.f64() * 0.2, rng);
            let p = 1 + rng.index(8);
            let k0 = 1 + rng.index(32);
            let d = 1 + rng.index(10);
            let sm = Arc::new(preprocess(&a, p, k0, d));
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
            let c0: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
            let alpha = rng.range_f32(-2.0, 2.0);
            let beta = rng.range_f32(-2.0, 2.0);
            let threads = 1 + rng.index(6);
            let mut want = c0.clone();
            a.spmm_reference(&b, &mut want, n, alpha, beta);
            let got = run_native(threads, &sm, &b, &c0, n, alpha, beta);
            prop::assert_allclose(&got, &want, 2e-4, 2e-4)
        });
    }
}
