//! Runtime-dispatched SIMD kernel layer for the native engine family — the
//! CPU analogue of the paper's 8-lane II=1 PE datapath (§4).
//!
//! Every kernel comes in two implementations behind one safe entry point:
//! a portable scalar loop (shaped so LLVM can autovectorize it) and an
//! explicit AVX2 path using 256-bit `std::arch` intrinsics. Callers pass
//! the [`Isa`] to use; [`active`] resolves the process-wide choice once
//! from `is_x86_feature_detected!("avx2")` and the `SEXTANS_SIMD`
//! environment override. Passing [`Isa::Avx2`] on a host without AVX2 is
//! safe — dispatch re-checks feature support and falls back to scalar, so
//! the unsafe intrinsics never run unguarded.
//!
//! ## Numerics contract (bit-identity)
//!
//! The native engines are pinned **bitwise** to
//! [`crate::arch::functional::execute`], so both implementations of every
//! kernel must perform, per output element, the *same sequence of
//! roundings in the same order*:
//!
//! * accumulation is `acc[l] += val * b[l]` — one f32 multiply rounding
//!   then one add rounding per contribution, in slot-issue order;
//! * Comp-C is `c = alpha * ab + beta * c` — two multiply roundings and
//!   one add rounding.
//!
//! That is why the AVX2 paths use `_mm256_mul_ps` + `_mm256_add_ps` and
//! **never FMA**: a fused multiply-add rounds once where the scalar
//! reference rounds twice, which would break the bit-identity tests. SIMD
//! here buys *width* (8 independent output columns per instruction), not
//! reassociation — each lane is an independent output element, so the
//! per-element operation order is untouched.
//!
//! ## Prefetch
//!
//! The condensed streams built at prepare time
//! ([`crate::backend::NativeBackend`]) touch B rows in a data-dependent
//! order. The AVX2 row kernels issue `_mm_prefetch` (T0) for the B row
//! [`PREFETCH_DISTANCE`] non-zeros ahead — far enough to cover DRAM
//! latency at the observed per-non-zero cost, near enough not to thrash
//! L1. On non-x86 targets prefetch compiles to nothing.

use std::sync::OnceLock;

/// Vector width in f32 lanes — the paper's N0 (8 PUs per PE), which is
/// also exactly one 256-bit AVX2 register.
pub const LANES: usize = 8;

/// How many non-zeros ahead the row kernels prefetch the B row of — one
/// pipelined L2/DRAM fetch roughly every [`LANES`] accumulations.
pub const PREFETCH_DISTANCE: usize = 8;

/// Fallback L2 size when neither `SEXTANS_L2_KB` nor sysfs yields one.
const DEFAULT_L2_BYTES: usize = 1024 * 1024;

/// Instruction set a kernel call executes with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Portable scalar loops (still shaped for autovectorization).
    Scalar,
    /// Explicit 256-bit AVX2 intrinsics (x86_64 only).
    Avx2,
}

impl Isa {
    /// Short stable name for logs, bench records, and test labels.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
        }
    }
}

/// True when the running CPU supports the AVX2 kernels.
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Pure dispatch policy: resolve the [`Isa`] from an optional
/// `SEXTANS_SIMD` preference string and the detected AVX2 support.
/// `"scalar"`, `"off"`, `"0"`, and `"false"` force the scalar fallback;
/// anything else (including unset) auto-detects. Split out from [`active`]
/// so the policy is unit-testable without touching process environment.
pub fn detect_with(pref: Option<&str>, avx2: bool) -> Isa {
    if let Some(p) = pref {
        let p = p.trim().to_ascii_lowercase();
        if p == "scalar" || p == "off" || p == "0" || p == "false" {
            return Isa::Scalar;
        }
    }
    if avx2 {
        Isa::Avx2
    } else {
        Isa::Scalar
    }
}

/// The process-wide kernel [`Isa`]: AVX2 when the CPU supports it, unless
/// the `SEXTANS_SIMD` environment variable (`scalar`/`off`/`0`/`false`)
/// forces the scalar fallback — the toggle CI uses to keep the portable
/// path green on AVX2 hosts. Resolved once and cached.
pub fn active() -> Isa {
    static ACTIVE: OnceLock<Isa> = OnceLock::new();
    *ACTIVE.get_or_init(|| {
        let pref = std::env::var("SEXTANS_SIMD").ok();
        detect_with(pref.as_deref(), avx2_available())
    })
}

/// Parse a sysfs cache size string (`"2048K"`, `"2M"`, `"512"`) to bytes.
fn parse_cache_size(s: &str) -> Option<usize> {
    let s = s.trim();
    let (digits, mult) = match s.as_bytes().last()? {
        b'K' | b'k' => (&s[..s.len() - 1], 1024),
        b'M' | b'm' => (&s[..s.len() - 1], 1024 * 1024),
        b'G' | b'g' => (&s[..s.len() - 1], 1024 * 1024 * 1024),
        _ => (s, 1),
    };
    digits.trim().parse::<usize>().ok().map(|v| v * mult)
}

/// Read cpu0's unified/data L2 size from sysfs, if the platform has one.
fn sysfs_l2_bytes() -> Option<usize> {
    let base = std::path::Path::new("/sys/devices/system/cpu/cpu0/cache");
    for entry in std::fs::read_dir(base).ok()?.flatten() {
        let dir = entry.path();
        let level = match std::fs::read_to_string(dir.join("level")) {
            Ok(s) => s,
            Err(_) => continue,
        };
        if level.trim() != "2" {
            continue;
        }
        let kind = std::fs::read_to_string(dir.join("type")).unwrap_or_default();
        let kind = kind.trim();
        if kind != "Unified" && kind != "Data" {
            continue;
        }
        if let Ok(size) = std::fs::read_to_string(dir.join("size")) {
            if let Some(bytes) = parse_cache_size(&size) {
                return Some(bytes);
            }
        }
    }
    None
}

/// Per-core L2 cache size in bytes — the budget the adaptive column
/// blocking sizes its B working set against. `SEXTANS_L2_KB` (kibibytes)
/// overrides detection; otherwise cpu0's sysfs cache topology is read,
/// with a 1 MiB fallback on platforms that expose neither. Resolved once
/// and cached.
pub fn l2_cache_bytes() -> usize {
    static BYTES: OnceLock<usize> = OnceLock::new();
    *BYTES.get_or_init(|| {
        if let Ok(kb) = std::env::var("SEXTANS_L2_KB") {
            if let Ok(kb) = kb.trim().parse::<usize>() {
                if kb > 0 {
                    return kb * 1024;
                }
            }
        }
        sysfs_l2_bytes().unwrap_or(DEFAULT_L2_BYTES)
    })
}

/// `y[..] += a * x[..]` — the N-wide AXPY inner step. Each lane is an
/// independent output element: per element the operation is one multiply
/// rounding then one add rounding on both ISAs.
pub fn axpy(isa: Isa, y: &mut [f32], x: &[f32], a: f32) {
    debug_assert_eq!(y.len(), x.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 if avx2_available() => unsafe { axpy_avx2(y, x, a) },
        _ => axpy_scalar(y, x, a),
    }
}

/// `c[..] = alpha * ab[..] + beta * c[..]` — the Comp-C stage, two
/// multiply roundings and one add rounding per element on both ISAs.
pub fn comp_c(isa: Isa, c: &mut [f32], ab: &[f32], alpha: f32, beta: f32) {
    debug_assert_eq!(c.len(), ab.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 if avx2_available() => unsafe { comp_c_avx2(c, ab, alpha, beta) },
        _ => comp_c_scalar(c, ab, alpha, beta),
    }
}

/// Accumulate one output row's condensed non-zero segment into a zeroed
/// column-block accumulator: `acc[q] += vals[i] * B[cols[i], col0 + q]`
/// for every segment entry in order, over the slice `[col0, col0 +
/// acc.len())` of B's `n` columns. The AVX2 path prefetches the B row
/// [`PREFETCH_DISTANCE`] entries ahead.
pub fn row_block(
    isa: Isa,
    cols: &[u32],
    vals: &[f32],
    b: &[f32],
    n: usize,
    col0: usize,
    acc: &mut [f32],
) {
    debug_assert_eq!(cols.len(), vals.len());
    debug_assert!(col0 + acc.len() <= n);
    acc.fill(0.0);
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 if avx2_available() => unsafe { row_block_avx2(cols, vals, b, n, col0, acc) },
        _ => {
            let w = acc.len();
            for (&gc, &val) in cols.iter().zip(vals) {
                let base = gc as usize * n + col0;
                axpy_scalar(acc, &b[base..base + w], val);
            }
        }
    }
}

/// Narrow-N fast path (`n <= LANES`): one output row start to finish with
/// the accumulator held in registers — `c_row[q] = alpha * sum_i(vals[i] *
/// B[cols[i], q]) + beta * c_row[q]`. No scratch, no blocking; the AVX2
/// path keeps the whole row in one masked 256-bit register. `c_row` must
/// be exactly `n` long.
#[allow(clippy::too_many_arguments)]
pub fn row_narrow(
    isa: Isa,
    cols: &[u32],
    vals: &[f32],
    b: &[f32],
    n: usize,
    c_row: &mut [f32],
    alpha: f32,
    beta: f32,
) {
    debug_assert!(n <= LANES);
    debug_assert_eq!(c_row.len(), n);
    debug_assert_eq!(cols.len(), vals.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 if avx2_available() => unsafe {
            row_narrow_avx2(cols, vals, b, n, c_row, alpha, beta)
        },
        _ => row_narrow_scalar(cols, vals, b, n, c_row, alpha, beta),
    }
}

fn axpy_scalar(y: &mut [f32], x: &[f32], a: f32) {
    // Chunked to LANES so LLVM vectorizes the body; element order is
    // unchanged (each lane is independent).
    let mut yc = y.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (yl, xl) in (&mut yc).zip(&mut xc) {
        for l in 0..LANES {
            yl[l] += a * xl[l];
        }
    }
    for (yl, xl) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yl += a * xl;
    }
}

fn comp_c_scalar(c: &mut [f32], ab: &[f32], alpha: f32, beta: f32) {
    let mut cc = c.chunks_exact_mut(LANES);
    let mut ac = ab.chunks_exact(LANES);
    for (cl, al) in (&mut cc).zip(&mut ac) {
        for l in 0..LANES {
            cl[l] = alpha * al[l] + beta * cl[l];
        }
    }
    for (cl, al) in cc.into_remainder().iter_mut().zip(ac.remainder()) {
        *cl = alpha * al + beta * *cl;
    }
}

fn row_narrow_scalar(
    cols: &[u32],
    vals: &[f32],
    b: &[f32],
    n: usize,
    c_row: &mut [f32],
    alpha: f32,
    beta: f32,
) {
    let mut acc = [0f32; LANES];
    for (&gc, &val) in cols.iter().zip(vals) {
        let base = gc as usize * n;
        let x = &b[base..base + n];
        for (a, &xv) in acc[..n].iter_mut().zip(x) {
            *a += val * xv;
        }
    }
    for (cv, &av) in c_row.iter_mut().zip(acc[..n].iter()) {
        *cv = alpha * av + beta * *cv;
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{LANES, PREFETCH_DISTANCE};
    use std::arch::x86_64::*;

    /// Lane mask with the low `n` lanes active (for masked loads/stores).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn lane_mask(n: usize) -> __m256i {
        let mut lanes = [0i32; LANES];
        for (l, slot) in lanes.iter_mut().enumerate() {
            if l < n {
                *slot = -1;
            }
        }
        _mm256_loadu_si256(lanes.as_ptr() as *const __m256i)
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_avx2(y: &mut [f32], x: &[f32], a: f32) {
        let n = y.len();
        let va = _mm256_set1_ps(a);
        let mut i = 0;
        while i + LANES <= n {
            let vy = _mm256_loadu_ps(y.as_ptr().add(i));
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            // mul + add, never FMA: see the module-level numerics contract.
            _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_add_ps(vy, _mm256_mul_ps(va, vx)));
            i += LANES;
        }
        while i < n {
            *y.get_unchecked_mut(i) += a * *x.get_unchecked(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn comp_c_avx2(c: &mut [f32], ab: &[f32], alpha: f32, beta: f32) {
        let n = c.len();
        let valpha = _mm256_set1_ps(alpha);
        let vbeta = _mm256_set1_ps(beta);
        let mut i = 0;
        while i + LANES <= n {
            let vab = _mm256_loadu_ps(ab.as_ptr().add(i));
            let vc = _mm256_loadu_ps(c.as_ptr().add(i));
            let out = _mm256_add_ps(_mm256_mul_ps(valpha, vab), _mm256_mul_ps(vbeta, vc));
            _mm256_storeu_ps(c.as_mut_ptr().add(i), out);
            i += LANES;
        }
        while i < n {
            let slot = c.get_unchecked_mut(i);
            *slot = alpha * *ab.get_unchecked(i) + beta * *slot;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn row_block_avx2(
        cols: &[u32],
        vals: &[f32],
        b: &[f32],
        n: usize,
        col0: usize,
        acc: &mut [f32],
    ) {
        let w = acc.len();
        let len = cols.len();
        for idx in 0..len {
            if idx + PREFETCH_DISTANCE < len {
                let pbase = *cols.get_unchecked(idx + PREFETCH_DISTANCE) as usize * n + col0;
                if pbase < b.len() {
                    _mm_prefetch::<_MM_HINT_T0>(b.as_ptr().add(pbase) as *const i8);
                }
            }
            let val = *vals.get_unchecked(idx);
            let base = *cols.get_unchecked(idx) as usize * n + col0;
            // Bounds-checked slice: the soundness gate for the raw loads.
            let x = &b[base..base + w];
            let va = _mm256_set1_ps(val);
            let mut i = 0;
            while i + LANES <= w {
                let vacc = _mm256_loadu_ps(acc.as_ptr().add(i));
                let vx = _mm256_loadu_ps(x.as_ptr().add(i));
                _mm256_storeu_ps(
                    acc.as_mut_ptr().add(i),
                    _mm256_add_ps(vacc, _mm256_mul_ps(va, vx)),
                );
                i += LANES;
            }
            while i < w {
                *acc.get_unchecked_mut(i) += val * *x.get_unchecked(i);
                i += 1;
            }
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn row_narrow_avx2(
        cols: &[u32],
        vals: &[f32],
        b: &[f32],
        n: usize,
        c_row: &mut [f32],
        alpha: f32,
        beta: f32,
    ) {
        let mask = lane_mask(n);
        let mut acc = _mm256_setzero_ps();
        let len = cols.len();
        for idx in 0..len {
            if idx + PREFETCH_DISTANCE < len {
                let pbase = *cols.get_unchecked(idx + PREFETCH_DISTANCE) as usize * n;
                if pbase < b.len() {
                    _mm_prefetch::<_MM_HINT_T0>(b.as_ptr().add(pbase) as *const i8);
                }
            }
            let base = *cols.get_unchecked(idx) as usize * n;
            // Bounds-checked slice; the masked load reads only its first
            // `n` lanes, which the slice guarantees are in bounds.
            let x = &b[base..base + n];
            let vx = _mm256_maskload_ps(x.as_ptr(), mask);
            let vv = _mm256_set1_ps(*vals.get_unchecked(idx));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(vv, vx));
        }
        let vc = _mm256_maskload_ps(c_row.as_ptr(), mask);
        let out = _mm256_add_ps(
            _mm256_mul_ps(_mm256_set1_ps(alpha), acc),
            _mm256_mul_ps(_mm256_set1_ps(beta), vc),
        );
        _mm256_maskstore_ps(c_row.as_mut_ptr(), mask, out);
    }
}

#[cfg(target_arch = "x86_64")]
use avx2::{axpy_avx2, comp_c_avx2, row_block_avx2, row_narrow_avx2};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detect_policy_honors_override_and_detection() {
        assert_eq!(detect_with(None, true), Isa::Avx2);
        assert_eq!(detect_with(None, false), Isa::Scalar);
        for force in ["scalar", "off", "0", "false", " SCALAR ", "Off"] {
            assert_eq!(detect_with(Some(force), true), Isa::Scalar, "{force:?}");
        }
        // Unknown / affirmative values fall through to detection.
        for pass in ["", "auto", "avx2", "on", "1"] {
            assert_eq!(detect_with(Some(pass), true), Isa::Avx2, "{pass:?}");
            assert_eq!(detect_with(Some(pass), false), Isa::Scalar, "{pass:?}");
        }
    }

    #[test]
    fn isa_names_are_stable() {
        assert_eq!(Isa::Scalar.name(), "scalar");
        assert_eq!(Isa::Avx2.name(), "avx2");
    }

    #[test]
    fn cache_size_strings_parse() {
        assert_eq!(parse_cache_size("2048K"), Some(2048 * 1024));
        assert_eq!(parse_cache_size(" 512K\n"), Some(512 * 1024));
        assert_eq!(parse_cache_size("2M"), Some(2 * 1024 * 1024));
        assert_eq!(parse_cache_size("1G"), Some(1024 * 1024 * 1024));
        assert_eq!(parse_cache_size("65536"), Some(65536));
        assert_eq!(parse_cache_size(""), None);
        assert_eq!(parse_cache_size("lots"), None);
    }

    #[test]
    fn l2_detection_yields_a_sane_budget() {
        let bytes = l2_cache_bytes();
        assert!(
            (64 * 1024..=1024 * 1024 * 1024).contains(&bytes),
            "implausible L2 size {bytes}"
        );
    }

    /// Every ISA the host can actually run.
    fn isas() -> Vec<Isa> {
        let mut v = vec![Isa::Scalar];
        if avx2_available() {
            v.push(Isa::Avx2);
        }
        v
    }

    fn pattern(len: usize, seed: u32) -> Vec<f32> {
        (0..len).map(|i| ((i as f32 + seed as f32) * 0.37).sin() * 3.0).collect()
    }

    #[test]
    fn axpy_isas_are_bit_identical() {
        for len in [0usize, 1, 7, 8, 9, 31, 100] {
            let x = pattern(len, 1);
            let y0 = pattern(len, 2);
            let mut want = y0.clone();
            axpy(Isa::Scalar, &mut want, &x, -1.75);
            for isa in isas() {
                let mut got = y0.clone();
                axpy(isa, &mut got, &x, -1.75);
                assert_eq!(got, want, "len = {len}, isa = {}", isa.name());
            }
        }
    }

    #[test]
    fn comp_c_isas_are_bit_identical_including_nan() {
        for len in [0usize, 1, 8, 13, 40] {
            let mut ab = pattern(len, 3);
            let c0 = pattern(len, 4);
            if len > 2 {
                ab[1] = f32::NAN;
                ab[2] = f32::INFINITY;
            }
            for (alpha, beta) in [(0.0f32, 1.0f32), (1.0, 0.0), (-2.5, 0.75)] {
                let mut want = c0.clone();
                comp_c(Isa::Scalar, &mut want, &ab, alpha, beta);
                for isa in isas() {
                    let mut got = c0.clone();
                    comp_c(isa, &mut got, &ab, alpha, beta);
                    assert_eq!(
                        got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        "len = {len}, alpha = {alpha}, beta = {beta}, isa = {}",
                        isa.name()
                    );
                }
            }
        }
    }

    #[test]
    fn row_kernels_match_scalar_on_short_segments() {
        // 4 B rows, segment touching them out of order with repeats.
        let n = 5usize;
        let b = pattern(4 * n, 7);
        let cols = [2u32, 0, 3, 2, 1, 3];
        let vals = [1.5f32, -0.25, 2.0, 0.5, -1.0, 3.0];
        let c0 = pattern(n, 9);
        let mut want = c0.clone();
        row_narrow(Isa::Scalar, &cols, &vals, &b, n, &mut want, 1.5, -0.25);
        for isa in isas() {
            let mut got = c0.clone();
            row_narrow(isa, &cols, &vals, &b, n, &mut got, 1.5, -0.25);
            assert_eq!(got, want, "isa = {}", isa.name());
        }
        // Empty segment: pure alpha*0 + beta*c.
        for isa in isas() {
            let mut got = c0.clone();
            row_narrow(isa, &[], &[], &b, n, &mut got, 2.0, 0.5);
            let want: Vec<f32> = c0.iter().map(|&c| 2.0f32 * 0.0 + 0.5 * c).collect();
            assert_eq!(got, want, "isa = {}", isa.name());
        }
    }

    #[test]
    fn row_block_slices_compose_to_full_width() {
        let n = 13usize;
        let b = pattern(6 * n, 11);
        let cols = [5u32, 1, 4, 1, 0];
        let vals = [0.5f32, 2.0, -1.5, 1.0, -0.75];
        let mut full = vec![0f32; n];
        row_block(Isa::Scalar, &cols, &vals, &b, n, 0, &mut full);
        for isa in isas() {
            let mut stitched = vec![0f32; n];
            let mut col0 = 0;
            while col0 < n {
                let w = 4.min(n - col0);
                let mut acc = vec![0f32; w];
                row_block(isa, &cols, &vals, &b, n, col0, &mut acc);
                stitched[col0..col0 + w].copy_from_slice(&acc);
                col0 += w;
            }
            assert_eq!(stitched, full, "isa = {}", isa.name());
        }
    }
}
