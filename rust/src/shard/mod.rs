//! Sharded multi-accelerator execution: one SpMM spread across a *pool*
//! of accelerator instances.
//!
//! Sextans balances load **within** one accelerator by interleaving rows
//! `r mod P` across PEs (§3.3); Serpens (arXiv:2111.12555) scales the same
//! idea **across** HBM channels. This module lifts it one level further:
//! the A matrix is row-partitioned into `S` nnz-balanced shards (greedy
//! bin-packing over row non-zero counts — [`plan_shards`]), each shard is
//! preprocessed into its own [`crate::sched::ScheduledMatrix`], and all
//! shards execute in parallel over any registered
//! [`crate::backend::SpmmBackend`], one instance per shard. Because the
//! shards partition the rows of C, the gather step is a disjoint row
//! scatter — exact, no reduction needed (B is broadcast to every shard,
//! exactly how a multi-card deployment would replicate the dense operand).
//!
//! Sharding follows the crate-wide **prepare/execute** contract: the plan,
//! the per-shard images, and one *prepared* inner handle per shard are all
//! built once per matrix; every request afterwards is gather → parallel
//! shards → scatter. Three entry points:
//!
//! * [`ShardedMatrix`] + [`ShardExecutor::prepare`] — the direct API:
//!   prepare the resident pool once, execute many times, get
//!   [`ShardRunStats`] per run.
//! * The `"sharded:<S>:<inner>"` composite backend
//!   ([`ShardedBackend`], registered in [`crate::backend::registry`]) — any
//!   consumer of the registry (the HFlex accelerator, the serving
//!   coordinator) gains sharding by spec string alone; its
//!   [`PreparedSharded`] handle owns the pool.
//! * `--shards S` on `sextans run` / `sextans serve`.
//!
//! Failure of any shard surfaces as [`ShardError::ShardFailed`] naming the
//! shard — never as silently zeroed rows of C.

pub mod backend;
pub mod executor;
pub mod plan;

pub use backend::{PreparedSharded, ShardedBackend};
pub use executor::ShardExecutor;
pub use plan::{plan_shards, reconstruct_coo, Shard, ShardPlan, ShardedMatrix};

use std::time::Duration;

/// Why a sharded execution was refused or failed.
#[derive(Debug)]
pub enum ShardError {
    /// B/C buffer shapes (or executor/shard-count pairing) are inconsistent.
    Shape(String),
    /// One shard's inner backend failed; the others' results are discarded
    /// so a partial failure can never masquerade as zero rows.
    ShardFailed {
        /// Index of the failing shard (0-based).
        shard: usize,
        /// Total shard count.
        shards: usize,
        /// The inner backend's error message.
        message: String,
    },
}

impl std::fmt::Display for ShardError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShardError::Shape(s) => write!(f, "shard shape mismatch: {s}"),
            ShardError::ShardFailed { shard, shards, message } => {
                write!(f, "shard {shard} of {shards} failed: {message}")
            }
        }
    }
}

impl std::error::Error for ShardError {}

/// Sharded failures map onto the backend error surface without
/// re-stringifying: a shape error stays a shape error (same inner text),
/// and a shard failure keeps its "shard i of S failed" message as an
/// execution error. The one mapping point for every sharded entry path.
impl From<ShardError> for crate::backend::BackendError {
    fn from(e: ShardError) -> Self {
        match e {
            ShardError::Shape(s) => crate::backend::BackendError::Shape(s),
            err @ ShardError::ShardFailed { .. } => {
                crate::backend::BackendError::Execution(err.to_string())
            }
        }
    }
}

/// Shard-level statistics from one sharded execution — the inter-shard
/// analogue of the paper's per-PE load-balance metrics.
#[derive(Clone, Debug)]
pub struct ShardRunStats {
    /// Number of shards executed.
    pub shards: usize,
    /// Real non-zeros per shard.
    pub shard_nnz: Vec<usize>,
    /// Wall-clock execution time per shard (parallel, so the slowest shard
    /// is the makespan).
    pub shard_latency: Vec<Duration>,
    /// max-shard / mean-shard nnz ratio (1.0 = perfectly balanced).
    pub imbalance: f64,
}

impl ShardRunStats {
    /// The makespan: latency of the slowest shard.
    pub fn slowest(&self) -> Duration {
        self.shard_latency.iter().copied().max().unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_error_names_the_failing_shard() {
        let e = ShardError::ShardFailed {
            shard: 2,
            shards: 4,
            message: "execution failed: boom".into(),
        };
        let msg = e.to_string();
        assert!(msg.contains("shard 2 of 4"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn run_stats_slowest_is_max() {
        let stats = ShardRunStats {
            shards: 3,
            shard_nnz: vec![10, 20, 30],
            shard_latency: vec![
                Duration::from_millis(3),
                Duration::from_millis(9),
                Duration::from_millis(1),
            ],
            imbalance: 1.5,
        };
        assert_eq!(stats.slowest(), Duration::from_millis(9));
    }

    #[test]
    fn empty_stats_slowest_is_zero() {
        let stats = ShardRunStats {
            shards: 0,
            shard_nnz: vec![],
            shard_latency: vec![],
            imbalance: 1.0,
        };
        assert_eq!(stats.slowest(), Duration::ZERO);
    }
}
