//! Shard planning: greedy nnz-balanced row partitioning, plus the
//! preprocessed [`ShardedMatrix`] the executor consumes.
//!
//! The partitioning is the inter-accelerator analogue of the paper's Eq. 4
//! `row mod P` PE interleave: where mod-P balances *statistically* (cheap
//! enough for hardware), the host-side shard planner can afford an explicit
//! greedy bin-packing (longest-processing-time order) over per-row non-zero
//! counts, which bounds the heaviest shard at `mean + max_row_nnz` — tight
//! even on power-law matrices. Empty rows carry no work but do occupy
//! C-scratchpad capacity, so they are leveled across shards by row count.

use std::cmp::Reverse;
use std::sync::Arc;

use crate::sched::partition::{global_col, global_row};
use crate::sched::{decode, preprocess, ScheduledMatrix};
use crate::sparse::Coo;

/// A row-to-shard assignment with its load statistics.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    /// Number of shards S.
    pub shards: usize,
    /// `assignment[row]` = shard owning that global row.
    pub assignment: Vec<u32>,
    /// Global rows of each shard, ascending.
    pub shard_rows: Vec<Vec<u32>>,
    /// Non-zeros per shard.
    pub shard_nnz: Vec<usize>,
}

impl ShardPlan {
    /// max-shard / mean-shard nnz ratio (1.0 = perfect balance; defined as
    /// 1.0 for an empty matrix).
    pub fn imbalance(&self) -> f64 {
        let total: usize = self.shard_nnz.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let mean = total as f64 / self.shards as f64;
        let max = *self.shard_nnz.iter().max().unwrap() as f64;
        max / mean
    }
}

/// Partition the rows of `coo` into `s` nnz-balanced shards.
///
/// Non-empty rows are placed in longest-processing-time order (heaviest row
/// first, onto the currently lightest shard); empty rows are then leveled
/// across shards by row count so every shard's C block (and scratchpad
/// footprint) stays comparable. Deterministic: ties break on the lowest row
/// index and lowest shard index.
pub fn plan_shards(coo: &Coo, s: usize) -> ShardPlan {
    assert!(s > 0, "shard count must be >= 1");
    let counts = coo.row_counts();
    let mut assignment = vec![0u32; coo.m];
    let mut shard_nnz = vec![0usize; s];
    let mut shard_rows_len = vec![0usize; s];

    let mut heavy: Vec<usize> = (0..coo.m).filter(|&r| counts[r] > 0).collect();
    heavy.sort_by_key(|&r| (Reverse(counts[r]), r));
    for &r in &heavy {
        // O(S) min scan; S is small (a pool of accelerators, not of PEs).
        let dest = (0..s)
            .min_by_key(|&i| (shard_nnz[i], shard_rows_len[i]))
            .unwrap();
        assignment[r] = dest as u32;
        shard_nnz[dest] += counts[r];
        shard_rows_len[dest] += 1;
    }
    for (r, &cnt) in counts.iter().enumerate() {
        if cnt > 0 {
            continue;
        }
        let dest = (0..s).min_by_key(|&i| shard_rows_len[i]).unwrap();
        assignment[r] = dest as u32;
        shard_rows_len[dest] += 1;
    }

    let mut shard_rows: Vec<Vec<u32>> =
        shard_rows_len.iter().map(|&l| Vec::with_capacity(l)).collect();
    for (r, &sh) in assignment.iter().enumerate() {
        shard_rows[sh as usize].push(r as u32);
    }
    ShardPlan { shards: s, assignment, shard_rows, shard_nnz }
}

/// One shard: the global rows it owns (ascending — local row `i` of the
/// shard is global row `global_rows[i]`) and its preprocessed image. The
/// image is `Arc`-shared so prepared execution handles (one inner
/// [`crate::backend::PreparedSpmm`] per shard) can hold it without copies.
#[derive(Clone, Debug)]
pub struct Shard {
    /// Ascending global row indices of this shard.
    pub global_rows: Vec<u32>,
    /// The shard's scheduled image (local row space, full K).
    pub image: Arc<ScheduledMatrix>,
}

/// A matrix row-partitioned into S shards, each preprocessed for the same
/// accelerator configuration (P, K0, D) — ready for [`super::ShardExecutor`].
/// The plan's row lists are moved into the shards (not duplicated); the
/// plan-level load statistic survives as [`ShardedMatrix::imbalance`].
#[derive(Clone, Debug)]
pub struct ShardedMatrix {
    /// Total rows (M) across shards.
    pub m: usize,
    /// Columns (K) — every shard sees the full K (B is broadcast).
    pub k: usize,
    /// max-shard / mean-shard nnz ratio of the build-time plan.
    imbalance: f64,
    /// The preprocessed shards, one per planned shard.
    pub shards: Vec<Shard>,
}

impl ShardedMatrix {
    /// Plan + preprocess: partition `coo` into `s` shards and schedule each
    /// for a (p, k0, d) accelerator. Build-path cost, paid once per matrix.
    pub fn build(coo: &Coo, s: usize, p: usize, k0: usize, d: usize) -> ShardedMatrix {
        let mut plan = plan_shards(coo, s);
        let imbalance = plan.imbalance();
        // Local row index of each global row = its rank within the shard
        // (shard_rows is ascending, so ranks follow enumeration order).
        let mut local_of = vec![0u32; coo.m];
        for rows in &plan.shard_rows {
            for (local, &gr) in rows.iter().enumerate() {
                local_of[gr as usize] = local as u32;
            }
        }
        let mut rows_v: Vec<Vec<u32>> = vec![Vec::new(); s];
        let mut cols_v: Vec<Vec<u32>> = vec![Vec::new(); s];
        let mut vals_v: Vec<Vec<f32>> = vec![Vec::new(); s];
        for i in 0..coo.nnz() {
            let gr = coo.rows[i] as usize;
            let sh = plan.assignment[gr] as usize;
            rows_v[sh].push(local_of[gr]);
            cols_v[sh].push(coo.cols[i]);
            vals_v[sh].push(coo.vals[i]);
        }
        let shards = (0..s)
            .map(|sh| {
                // Move (not clone) the plan's row list into the shard — one
                // source of truth for the row mapping.
                let global_rows = std::mem::take(&mut plan.shard_rows[sh]);
                let local = Coo {
                    m: global_rows.len(),
                    k: coo.k,
                    rows: std::mem::take(&mut rows_v[sh]),
                    cols: std::mem::take(&mut cols_v[sh]),
                    vals: std::mem::take(&mut vals_v[sh]),
                };
                Shard { global_rows, image: Arc::new(preprocess(&local, p, k0, d)) }
            })
            .collect();
        ShardedMatrix { m: coo.m, k: coo.k, imbalance, shards }
    }

    /// Re-shard a *preprocessed image*: invert preprocessing once
    /// ([`reconstruct_coo`]) and build shard images for the same
    /// (P, K0, D). This is the prepare-path entry for the
    /// `"sharded:<S>:<inner>"` composite backend, whose contract hands over
    /// images rather than raw COO — paid exactly once per prepared matrix.
    pub fn from_image(sm: &ScheduledMatrix, s: usize) -> ShardedMatrix {
        let coo = reconstruct_coo(sm);
        ShardedMatrix::build(&coo, s, sm.p, sm.k0, sm.d)
    }

    /// Bytes this sharded form keeps resident: the shard images' A streams
    /// plus the global-row maps.
    pub fn resident_bytes(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.image.a_stream_bytes() + 4 * s.global_rows.len() as u64)
            .sum()
    }

    /// Number of shards.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Total real non-zeros across shards.
    pub fn nnz(&self) -> usize {
        self.shards.iter().map(|s| s.image.nnz).sum()
    }

    /// max-shard / mean-shard nnz imbalance ratio of the build-time plan.
    pub fn imbalance(&self) -> f64 {
        self.imbalance
    }
}

/// Invert preprocessing: recover the COO triplets from a scheduled image
/// (bubbles — and explicit zeros, which are arithmetically inert — are
/// dropped). This is what lets the `"sharded:<S>:<inner>"` composite
/// backend re-shard an image it receives through the [`crate::backend`]
/// contract, which hands over preprocessed images rather than raw COO.
pub fn reconstruct_coo(sm: &ScheduledMatrix) -> Coo {
    let mut rows = Vec::with_capacity(sm.nnz);
    let mut cols = Vec::with_capacity(sm.nnz);
    let mut vals = Vec::with_capacity(sm.nnz);
    for (pe, stream) in sm.streams.iter().enumerate() {
        for j in 0..sm.num_windows {
            for &word in &stream.encoded[stream.q.window_range(j)] {
                let nz = decode(word);
                if nz.val == 0.0 {
                    continue;
                }
                rows.push(global_row(&nz, pe, sm.p) as u32);
                cols.push(global_col(&nz, j, sm.k0) as u32);
                vals.push(nz.val);
            }
        }
    }
    Coo { m: sm.m, k: sm.k, rows, cols, vals }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::sparse::{gen, rng::Rng};

    #[test]
    fn plan_partitions_every_row_exactly_once() {
        let mut rng = Rng::new(1);
        let coo = gen::random_uniform(100, 50, 0.1, &mut rng);
        for s in [1usize, 2, 3, 7] {
            let plan = plan_shards(&coo, s);
            let total_rows: usize = plan.shard_rows.iter().map(|r| r.len()).sum();
            assert_eq!(total_rows, coo.m);
            for (sh, rows) in plan.shard_rows.iter().enumerate() {
                for &r in rows {
                    assert_eq!(plan.assignment[r as usize] as usize, sh);
                }
                assert!(rows.windows(2).all(|w| w[0] < w[1]), "rows must ascend");
            }
            let total_nnz: usize = plan.shard_nnz.iter().sum();
            assert_eq!(total_nnz, coo.nnz());
        }
    }

    #[test]
    fn greedy_balances_power_law_within_bound() {
        // The acceptance bar: <= 1.25 imbalance on power-law row skew.
        let mut rng = Rng::new(2);
        let coo = gen::power_law_rows(2048, 1024, 32_768, 1.1, &mut rng);
        for s in [2usize, 4, 8] {
            let plan = plan_shards(&coo, s);
            let imb = plan.imbalance();
            assert!(imb <= 1.25, "S={s}: imbalance {imb}");
        }
    }

    #[test]
    fn empty_rows_are_leveled_by_row_count() {
        // 1 non-empty row, 99 empty ones, 4 shards: every shard ends up
        // with 25 rows even though one holds all the non-zeros.
        let coo = Coo::new(100, 10, vec![7, 7, 7], vec![0, 1, 2], vec![1.0; 3]).unwrap();
        let plan = plan_shards(&coo, 4);
        for rows in &plan.shard_rows {
            assert_eq!(rows.len(), 25);
        }
        assert_eq!(plan.shard_nnz.iter().sum::<usize>(), 3);
    }

    #[test]
    fn single_shard_is_identity_partition() {
        let mut rng = Rng::new(3);
        let coo = gen::random_uniform(40, 40, 0.2, &mut rng);
        let plan = plan_shards(&coo, 1);
        assert_eq!(plan.shard_rows[0], (0..40u32).collect::<Vec<_>>());
        assert_eq!(plan.shard_nnz[0], coo.nnz());
        assert!((plan.imbalance() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn more_shards_than_rows_leaves_some_empty() {
        let coo = Coo::new(3, 4, vec![0, 1, 2], vec![0, 1, 2], vec![1.0; 3]).unwrap();
        let sharded = ShardedMatrix::build(&coo, 8, 2, 4, 2);
        assert_eq!(sharded.num_shards(), 8);
        assert_eq!(sharded.nnz(), 3);
        let total_rows: usize = sharded.shards.iter().map(|s| s.global_rows.len()).sum();
        assert_eq!(total_rows, 3);
        // Empty shards have empty images but stay executable (m = 0).
        assert!(sharded.shards.iter().any(|s| s.image.m == 0));
    }

    #[test]
    fn empty_matrix_plans_cleanly() {
        let coo = Coo::empty(10, 10);
        let plan = plan_shards(&coo, 3);
        assert!((plan.imbalance() - 1.0).abs() < 1e-12);
        let sharded = ShardedMatrix::build(&coo, 3, 2, 4, 2);
        assert_eq!(sharded.nnz(), 0);
        assert_eq!(sharded.m, 10);
    }

    #[test]
    fn build_covers_every_nonzero_exactly_once() {
        prop::check("sharded_build_covers", 0x5A4D, 24, |rng| {
            let m = 1 + rng.index(120);
            let k = 1 + rng.index(80);
            let coo = gen::random_uniform(m, k, rng.f64() * 0.2, rng);
            let s = 1 + rng.index(8);
            let sharded = ShardedMatrix::build(&coo, s, 1 + rng.index(4), 1 + rng.index(32), 1 + rng.index(8));
            if sharded.nnz() != coo.nnz() {
                return Err(format!("{} of {} nnz covered", sharded.nnz(), coo.nnz()));
            }
            // Round-trip each shard's entries to global coordinates and
            // compare with the input as multisets.
            let mut got: Vec<(u32, u32, u32)> = Vec::new();
            for shard in &sharded.shards {
                let local = reconstruct_coo(&shard.image);
                for i in 0..local.nnz() {
                    let gr = shard.global_rows[local.rows[i] as usize];
                    got.push((gr, local.cols[i], local.vals[i].to_bits()));
                }
            }
            got.sort_unstable();
            let mut want: Vec<(u32, u32, u32)> = (0..coo.nnz())
                .map(|i| (coo.rows[i], coo.cols[i], coo.vals[i].to_bits()))
                .collect();
            want.sort_unstable();
            if got != want {
                return Err("shard round-trip mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn from_image_matches_build_from_coo() {
        let mut rng = Rng::new(17);
        let coo = gen::power_law_rows(140, 90, 1_600, 1.0, &mut rng);
        let sm = preprocess(&coo, 4, 16, 6);
        let via_image = ShardedMatrix::from_image(&sm, 3);
        let via_coo = ShardedMatrix::build(&coo, 3, 4, 16, 6);
        assert_eq!(via_image.num_shards(), via_coo.num_shards());
        assert_eq!(via_image.nnz(), via_coo.nnz());
        assert_eq!(via_image.m, via_coo.m);
        for (a, b) in via_image.shards.iter().zip(&via_coo.shards) {
            assert_eq!(a.global_rows, b.global_rows);
            assert_eq!(a.image.nnz, b.image.nnz);
        }
        assert!(via_image.resident_bytes() > 0);
    }

    #[test]
    fn reconstruct_inverts_preprocess() {
        let mut rng = Rng::new(9);
        let coo = gen::power_law_rows(90, 70, 900, 1.0, &mut rng);
        let sm = preprocess(&coo, 4, 16, 6);
        let rt = reconstruct_coo(&sm);
        assert_eq!((rt.m, rt.k, rt.nnz()), (coo.m, coo.k, coo.nnz()));
        let key = |c: &Coo| {
            let mut v: Vec<(u32, u32, u32)> = (0..c.nnz())
                .map(|i| (c.rows[i], c.cols[i], c.vals[i].to_bits()))
                .collect();
            v.sort_unstable();
            v
        };
        assert_eq!(key(&rt), key(&coo));
    }
}
