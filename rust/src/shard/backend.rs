//! The `"sharded:<S>:<inner>"` composite backend: sharding behind the
//! plain [`SpmmBackend`] prepare/execute contract, so every registry
//! consumer (the HFlex accelerator, the serving coordinator, the CLI)
//! gains multi-accelerator execution from a spec string alone.
//!
//! The two-phase contract puts all the sharding work where it belongs:
//! [`SpmmBackend::prepare`] inverts preprocessing once, row-partitions into
//! S nnz-balanced shards ([`ShardedMatrix::from_image`]), and prepares one
//! inner handle per shard ([`ShardExecutor::prepare`]). The returned
//! [`PreparedSharded`] handle is the resident pool — every execute is pure
//! gather → parallel shards → scatter, with no per-request re-shard, no
//! image content hashing, nothing to invalidate. Shard-level timings of the
//! latest run are exposed through [`PreparedSpmm::shard_stats`] so serving
//! metrics can aggregate them.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::executor::ShardExecutor;
use super::plan::ShardedMatrix;
use super::ShardRunStats;
use crate::backend::{
    self, BackendError, Capability, ExecutionReport, PrepareCost, PreparedSpmm, SpmmBackend,
};
use crate::sched::ScheduledMatrix;

/// Composite backend factory: prepares S row-shards over inner engines.
/// Stateless — the shard plan and the inner handles live in the
/// [`PreparedSharded`] handles it produces.
pub struct ShardedBackend {
    shards: usize,
    /// Inner registry spec, as given (thread budgeting happens per prepare,
    /// inside [`ShardExecutor::prepare`]).
    inner_spec: String,
    /// Aggregate capability, computed once from a probe of the budgeted
    /// inner spec.
    cap: Capability,
}

impl ShardedBackend {
    /// Build from a shard count and an inner registry spec. The inner spec
    /// is validated (and nested `sharded` refused) here, so bad specs fail
    /// at construction rather than at first prepare.
    pub fn from_spec(shards: usize, inner_spec: &str) -> Result<ShardedBackend, BackendError> {
        if shards == 0 {
            return Err(BackendError::InvalidSpec(
                "sharded:<S> needs S >= 1".into(),
            ));
        }
        if inner_spec == "sharded" || inner_spec.starts_with("sharded:") {
            return Err(BackendError::InvalidSpec(
                "sharded cannot nest inside sharded".into(),
            ));
        }
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let budgeted = backend::apply_thread_budget(inner_spec, (cores / shards).max(1));
        let inner_cap = backend::create(&budgeted)?.capability();
        Ok(ShardedBackend {
            shards,
            inner_spec: inner_spec.to_string(),
            cap: Capability {
                threads: (inner_cap.threads * shards).max(1),
                simd_lanes: inner_cap.simd_lanes,
                requires_artifacts: inner_cap.requires_artifacts,
                deterministic: inner_cap.deterministic,
            },
        })
    }

    /// Configured shard count.
    pub fn num_shards(&self) -> usize {
        self.shards
    }

    /// The same composite at a different shard count — the programmatic
    /// rebuild-at-S hook for direct API consumers: drop the old handle,
    /// prepare through this factory at the new S (thread budgets are
    /// re-derived inside prepare for the new shard count). The serving
    /// coordinator's re-shard-on-skew takes the equivalent registry-spec
    /// route instead ([`crate::coordinator::residency::reshard_spec`]) so
    /// it can re-apply the per-worker core budget.
    pub fn with_shards(&self, shards: usize) -> Result<ShardedBackend, BackendError> {
        ShardedBackend::from_spec(shards, &self.inner_spec)
    }

    fn build(&self, image: Arc<ScheduledMatrix>) -> Result<PreparedSharded, BackendError> {
        let t0 = Instant::now();
        // The build path, paid exactly once per prepared matrix: invert
        // preprocessing, plan + preprocess S shards, prepare each inner.
        let sharded = ShardedMatrix::from_image(&image, self.shards);
        let executor = ShardExecutor::prepare(&sharded, &self.inner_spec)?;
        let resident_bytes = executor.prepare_cost().resident_bytes;
        Ok(PreparedSharded {
            image,
            executor,
            last_stats: Mutex::new(None),
            cost: PrepareCost { wall: t0.elapsed(), resident_bytes },
        })
    }
}

impl SpmmBackend for ShardedBackend {
    fn name(&self) -> &'static str {
        "sharded"
    }

    fn capability(&self) -> Capability {
        self.cap
    }

    fn prepare(&self, image: Arc<ScheduledMatrix>) -> Result<Box<dyn PreparedSpmm>, BackendError> {
        Ok(Box::new(self.build(image)?))
    }

    fn prepare_send(
        &self,
        image: Arc<ScheduledMatrix>,
    ) -> Result<Box<dyn PreparedSpmm + Send + Sync>, BackendError> {
        Ok(Box::new(self.build(image)?))
    }
}

/// A matrix resident across a shard pool: the shard plan, one preprocessed
/// image per shard, and one prepared inner handle per shard. Executes
/// through `&self` — the executor pools its gather blocks, so concurrent
/// requests stream against one resident pool.
pub struct PreparedSharded {
    /// The unsharded source image (kept so the handle reports the matrix it
    /// is resident for and the Arc stays alive for the caller's bookkeeping).
    image: Arc<ScheduledMatrix>,
    executor: ShardExecutor,
    /// Stats of the most recent *successful* execution. The lock guards
    /// only this tiny report, never the execution itself; with concurrent
    /// executions "most recent" is whichever run finished last. Failed
    /// calls leave it untouched — clearing here would let a failing
    /// request racing a successful one wipe the winner's report before
    /// the serving dispatcher reads it (a failed run never reports stats
    /// through that path anyway).
    last_stats: Mutex<Option<ShardRunStats>>,
    cost: PrepareCost,
}

impl PreparedSharded {
    /// Wrap an explicitly assembled executor (tests, heterogeneous pools).
    pub fn from_executor(image: Arc<ScheduledMatrix>, executor: ShardExecutor) -> PreparedSharded {
        let cost = executor.prepare_cost();
        PreparedSharded { image, executor, last_stats: Mutex::new(None), cost }
    }

    /// Number of resident shards.
    pub fn num_shards(&self) -> usize {
        self.executor.num_shards()
    }

    /// Global row sets of the resident shards (ascending per shard).
    /// Today's routed execution skips shards by their nnz counts
    /// ([`ShardExecutor::execute_active`]); these row sets are the basis
    /// for the finer per-request row-mask routing the ROADMAP defers.
    pub fn shard_row_sets(&self) -> &[Vec<u32>] {
        self.executor.shard_rows()
    }

    /// The source image this pool is resident for.
    pub fn image(&self) -> &Arc<ScheduledMatrix> {
        &self.image
    }
}

impl PreparedSpmm for PreparedSharded {
    fn backend_name(&self) -> &'static str {
        "sharded"
    }

    fn prepare_cost(&self) -> PrepareCost {
        self.cost
    }

    fn execute(
        &self,
        b: &[f32],
        c: &mut [f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<(), BackendError> {
        let stats = self.executor.execute(b, c, n, alpha, beta)?;
        *self.last_stats.lock().unwrap() = Some(stats);
        Ok(())
    }

    fn shard_stats(&self) -> Option<ShardRunStats> {
        self.last_stats.lock().unwrap().clone()
    }

    fn resident_shards(&self) -> Option<usize> {
        Some(self.executor.num_shards())
    }

    fn execute_routed(
        &self,
        b: &[f32],
        c: &mut [f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<usize, BackendError> {
        let (stats, skipped) = self.executor.execute_active(b, c, n, alpha, beta)?;
        *self.last_stats.lock().unwrap() = Some(stats);
        Ok(skipped)
    }

    fn execute_with_report(
        &self,
        b: &[f32],
        c: &mut [f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<ExecutionReport, BackendError> {
        let stats = self.executor.execute(b, c, n, alpha, beta)?;
        *self.last_stats.lock().unwrap() = Some(stats.clone());
        Ok(ExecutionReport { skipped: 0, shard_stats: Some(stats), remote: None })
    }

    fn execute_routed_with_report(
        &self,
        b: &[f32],
        c: &mut [f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<ExecutionReport, BackendError> {
        let (stats, skipped) = self.executor.execute_active(b, c, n, alpha, beta)?;
        *self.last_stats.lock().unwrap() = Some(stats.clone());
        Ok(ExecutionReport { skipped, shard_stats: Some(stats), remote: None })
    }

    fn resident_bytes_now(&self) -> u64 {
        self.executor.resident_bytes_now()
    }

    fn trim_resident(&self, max_idle: std::time::Duration) -> u64 {
        self.executor.trim_scratch(max_idle)
            + self
                .executor
                .prepared()
                .iter()
                .map(|h| h.trim_resident(max_idle))
                .sum::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::FunctionalBackend;
    use crate::prop;
    use crate::sched::preprocess;
    use crate::sparse::{gen, rng::Rng};

    fn image(seed: u64) -> (crate::sparse::Coo, Arc<ScheduledMatrix>) {
        let mut rng = Rng::new(seed);
        let coo = gen::power_law_rows(120, 90, 1_500, 1.0, &mut rng);
        let sm = Arc::new(preprocess(&coo, 4, 32, 6));
        (coo, sm)
    }

    #[test]
    fn composite_matches_functional() {
        let (coo, sm) = image(1);
        let n = 5;
        let mut rng = Rng::new(2);
        let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
        let mut want = c0.clone();
        FunctionalBackend
            .prepare(Arc::clone(&sm))
            .unwrap()
            .execute(&b, &mut want, n, 2.0, -0.5)
            .unwrap();
        for s in [1usize, 3, 8] {
            let be = ShardedBackend::from_spec(s, "native:1").unwrap();
            let handle = be.prepare(Arc::clone(&sm)).unwrap();
            let mut c = c0.clone();
            handle.execute(&b, &mut c, n, 2.0, -0.5).unwrap();
            prop::assert_allclose(&c, &want, 2e-4, 2e-4).unwrap();
            let stats = handle.shard_stats().expect("stats after success");
            assert_eq!(stats.shards, s);
        }
    }

    #[test]
    fn one_handle_shards_once_and_serves_many() {
        let (coo, sm) = image(3);
        let be = ShardedBackend::from_spec(3, "functional").unwrap();
        let handle = be.prepare(Arc::clone(&sm)).unwrap();
        // Prepare did the sharding: resident bytes cover the shard images,
        // and the wall time is nonzero-able (not asserted — clocks).
        assert!(handle.prepare_cost().resident_bytes > 0);
        let mut rng = Rng::new(4);
        // Many requests, n varying across calls, against the one handle.
        for n in [2usize, 6, 1, 4] {
            let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
            let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
            let mut want = c0.clone();
            coo.spmm_reference(&b, &mut want, n, 1.5, -0.25);
            let mut c = c0;
            handle.execute(&b, &mut c, n, 1.5, -0.25).unwrap();
            prop::assert_allclose(&c, &want, 2e-4, 2e-4).unwrap();
            assert_eq!(handle.shard_stats().unwrap().shards, 3);
        }
    }

    #[test]
    fn registry_roundtrip() {
        let be = backend::create("sharded:3:native:1").unwrap();
        assert_eq!(be.name(), "sharded");
        assert!(be.capability().threads >= 3);
        let (_, sm) = image(5);
        let handle = be.prepare_send(Arc::clone(&sm)).unwrap();
        assert_eq!(handle.backend_name(), "sharded");
    }

    #[test]
    fn from_spec_rejects_bad_specs_eagerly() {
        assert!(matches!(
            ShardedBackend::from_spec(0, "native"),
            Err(BackendError::InvalidSpec(_))
        ));
        assert!(matches!(
            ShardedBackend::from_spec(2, "sharded:2:native"),
            Err(BackendError::InvalidSpec(_))
        ));
        assert!(matches!(
            ShardedBackend::from_spec(2, "warpdrive"),
            Err(BackendError::Unknown(_))
        ));
    }

    #[test]
    fn handle_exposes_row_sets_and_shard_count() {
        let (coo, sm) = image(7);
        let be = ShardedBackend::from_spec(3, "functional").unwrap();
        assert_eq!(be.with_shards(5).unwrap().num_shards(), 5, "rebuild-at-S hook");
        let handle = be.build(Arc::clone(&sm)).unwrap();
        assert_eq!(PreparedSpmm::resident_shards(&handle), Some(3));
        let rows: usize = handle.shard_row_sets().iter().map(|r| r.len()).sum();
        assert_eq!(rows, coo.m, "row sets partition the matrix");
    }

    #[test]
    fn routed_execute_matches_plain_on_dense_pools() {
        let (coo, sm) = image(8);
        let be = ShardedBackend::from_spec(4, "native:1").unwrap();
        let handle = be.prepare(Arc::clone(&sm)).unwrap();
        let n = 2;
        let mut rng = Rng::new(9);
        let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
        let mut plain = c0.clone();
        handle.execute(&b, &mut plain, n, 1.5, -0.5).unwrap();
        let mut routed = c0.clone();
        let skipped = handle.execute_routed(&b, &mut routed, n, 1.5, -0.5).unwrap();
        assert_eq!(skipped, 0, "every shard owns non-zeros on a power-law image");
        assert_eq!(plain, routed);
        assert_eq!(handle.shard_stats().unwrap().shards, 4);
    }

    #[test]
    fn report_path_returns_this_calls_stats_by_value() {
        let (coo, sm) = image(10);
        let be = ShardedBackend::from_spec(3, "functional").unwrap();
        let handle = be.prepare(Arc::clone(&sm)).unwrap();
        let n = 2;
        let b = vec![1.0f32; coo.k * n];
        let mut c = vec![0.0f32; coo.m * n];
        let report = handle.execute_with_report(&b, &mut c, n, 1.0, 0.0).unwrap();
        assert_eq!(report.skipped, 0);
        let stats = report.shard_stats.expect("sharded handles report per-call stats");
        assert_eq!(stats.shards, 3);
        assert_eq!(stats.shard_nnz.iter().sum::<usize>(), coo.nnz());
        let routed = handle.execute_routed_with_report(&b, &mut c, n, 1.0, 0.0).unwrap();
        assert!(routed.shard_stats.is_some(), "routed report carries stats too");
        // The legacy poll still reflects the latest run for compatibility.
        assert_eq!(handle.shard_stats().unwrap().shards, 3);
    }

    #[test]
    fn failed_execute_keeps_last_successful_stats() {
        let (coo, sm) = image(6);
        let be = ShardedBackend::from_spec(2, "functional").unwrap();
        let handle = be.prepare(Arc::clone(&sm)).unwrap();
        let n = 2;
        let b = vec![1.0f32; coo.k * n];
        let mut c = vec![0.0f32; coo.m * n];
        handle.execute(&b, &mut c, n, 1.0, 0.0).unwrap();
        assert!(handle.shard_stats().is_some());
        // A failed call reports its error but must NOT clear the report of
        // the last successful run: under concurrent `&self` execution a
        // failure racing a success would otherwise wipe the winner's stats
        // before the serving dispatcher reads them (failed runs never
        // report stats through that path regardless).
        let err = handle.execute(&b[..3], &mut c, n, 1.0, 0.0).unwrap_err();
        assert!(matches!(err, BackendError::Shape(_)));
        assert_eq!(
            handle.shard_stats().expect("stats survive failed calls").shards,
            2
        );
    }
}
