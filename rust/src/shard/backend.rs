//! The `"sharded:<S>:<inner>"` composite backend: sharding behind the
//! plain [`SpmmBackend`] contract, so every registry consumer (the HFlex
//! accelerator, the serving coordinator, the CLI) gains multi-accelerator
//! execution from a spec string alone.
//!
//! The backend contract hands over a *preprocessed image*, not raw COO, so
//! the composite inverts preprocessing once ([`reconstruct_coo`]), builds a
//! [`ShardedMatrix`] for the same (P, K0, D), and caches it keyed by a
//! content fingerprint of the image. The cache holds the
//! [`CACHE_ENTRIES`] most recently used matrices, so a worker serving
//! several registered models (the coordinator's normal multi-model case)
//! still pays only an O(slots) hash per request, not a re-shard.
//! Shard-level timings of the latest run are exposed through
//! [`SpmmBackend::shard_stats`] so serving metrics can aggregate them.

use super::executor::ShardExecutor;
use super::plan::{reconstruct_coo, ShardedMatrix};
use super::{ShardError, ShardRunStats};
use crate::backend::{check_shapes, BackendError, Capability, SpmmBackend};
use crate::sched::ScheduledMatrix;

/// Sharded images kept per backend instance, most recently used first.
/// Sized for a worker serving a handful of registered matrices; beyond
/// this the oldest re-shard is rebuilt on next use.
pub const CACHE_ENTRIES: usize = 8;

/// Composite backend running S row-shards in parallel over inner engines.
pub struct ShardedBackend {
    shards: usize,
    executor: ShardExecutor,
    /// Recently sharded images, MRU-first, keyed by content fingerprint.
    cache: Vec<(u64, ShardedMatrix)>,
    /// Stats of the most recent successful execution.
    last_stats: Option<ShardRunStats>,
}

impl ShardedBackend {
    /// Build from a shard count and an inner registry spec (see
    /// [`ShardExecutor::from_spec`] for thread budgeting and nesting rules).
    pub fn from_spec(shards: usize, inner_spec: &str) -> Result<ShardedBackend, BackendError> {
        if shards == 0 {
            return Err(BackendError::InvalidSpec(
                "sharded:<S> needs S >= 1".into(),
            ));
        }
        let executor = ShardExecutor::from_spec(inner_spec, shards)?;
        Ok(ShardedBackend { shards, executor, cache: Vec::new(), last_stats: None })
    }

    /// Build around an explicit executor (tests, heterogeneous pools). The
    /// shard count is the executor's backend count.
    pub fn from_executor(executor: ShardExecutor) -> ShardedBackend {
        ShardedBackend {
            shards: executor.num_shards(),
            executor,
            cache: Vec::new(),
            last_stats: None,
        }
    }

    /// Configured shard count.
    pub fn num_shards(&self) -> usize {
        self.shards
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

#[inline]
fn fnv(h: u64, x: u64) -> u64 {
    (h ^ x).wrapping_mul(FNV_PRIME)
}

/// Content fingerprint of a scheduled image: dimensions, every stream's Q
/// pointer list, and every encoded word (FNV-1a over u64s). Q matters: the
/// encoded words store *window-local* columns, so the same word sequence
/// under different window boundaries is a different matrix. One linear
/// pass per request — a deliberate correctness-over-speed trade (pointer
/// identity could be recycled across deregistered models); if the hash
/// ever shows up in profiles, precompute it once on `ScheduledMatrix` at
/// preprocess time and compare stored values here.
fn fingerprint(sm: &ScheduledMatrix) -> u64 {
    let mut h = FNV_OFFSET;
    for dim in [sm.m, sm.k, sm.p, sm.k0, sm.d, sm.num_windows, sm.nnz] {
        h = fnv(h, dim as u64);
    }
    for stream in &sm.streams {
        h = fnv(h, stream.encoded.len() as u64);
        for &start in stream.q.entries() {
            h = fnv(h, start as u64);
        }
        for &word in &stream.encoded {
            h = fnv(h, word);
        }
    }
    h
}

impl SpmmBackend for ShardedBackend {
    fn name(&self) -> &'static str {
        "sharded"
    }

    fn capability(&self) -> Capability {
        let inners = self.executor.backends();
        Capability {
            threads: inners.iter().map(|b| b.capability().threads).sum::<usize>().max(1),
            simd_lanes: inners.first().map(|b| b.capability().simd_lanes).unwrap_or(1),
            requires_artifacts: inners.iter().any(|b| b.capability().requires_artifacts),
            deterministic: inners.iter().all(|b| b.capability().deterministic),
        }
    }

    fn execute(
        &mut self,
        sm: &ScheduledMatrix,
        b: &[f32],
        c: &mut [f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<(), BackendError> {
        check_shapes(sm, b, c, n)?;
        self.last_stats = None;
        let fp = fingerprint(sm);
        match self.cache.iter().position(|(cached, _)| *cached == fp) {
            Some(0) => {}
            Some(i) => {
                // MRU: bubble the hit to the front.
                let entry = self.cache.remove(i);
                self.cache.insert(0, entry);
            }
            None => {
                let coo = reconstruct_coo(sm);
                let sharded = ShardedMatrix::build(&coo, self.shards, sm.p, sm.k0, sm.d);
                self.cache.insert(0, (fp, sharded));
                self.cache.truncate(CACHE_ENTRIES);
            }
        }
        let sharded = &self.cache[0].1;
        let stats = self
            .executor
            .execute(sharded, b, c, n, alpha, beta)
            .map_err(|e| match e {
                ShardError::Shape(s) => BackendError::Shape(s),
                err @ ShardError::ShardFailed { .. } => BackendError::Execution(err.to_string()),
            })?;
        self.last_stats = Some(stats);
        Ok(())
    }

    fn shard_stats(&self) -> Option<ShardRunStats> {
        self.last_stats.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{self, FunctionalBackend};
    use crate::prop;
    use crate::sched::preprocess;
    use crate::sparse::{gen, rng::Rng};

    fn image(seed: u64) -> (crate::sparse::Coo, ScheduledMatrix) {
        let mut rng = Rng::new(seed);
        let coo = gen::power_law_rows(120, 90, 1_500, 1.0, &mut rng);
        let sm = preprocess(&coo, 4, 32, 6);
        (coo, sm)
    }

    #[test]
    fn composite_matches_functional() {
        let (coo, sm) = image(1);
        let n = 5;
        let mut rng = Rng::new(2);
        let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
        let mut want = c0.clone();
        FunctionalBackend.execute(&sm, &b, &mut want, n, 2.0, -0.5).unwrap();
        for s in [1usize, 3, 8] {
            let mut be = ShardedBackend::from_spec(s, "native:1").unwrap();
            let mut c = c0.clone();
            be.execute(&sm, &b, &mut c, n, 2.0, -0.5).unwrap();
            prop::assert_allclose(&c, &want, 2e-4, 2e-4).unwrap();
            let stats = be.shard_stats().expect("stats after success");
            assert_eq!(stats.shards, s);
        }
    }

    #[test]
    fn cache_keeps_multiple_images_mru_first() {
        let (coo, sm) = image(3);
        let (_, sm2) = image(4);
        let mut be = ShardedBackend::from_spec(2, "functional").unwrap();
        let n = 2;
        let b = vec![1.0f32; coo.k * n];
        let mut c = vec![0.0f32; coo.m * n];
        be.execute(&sm, &b, &mut c, n, 1.0, 0.0).unwrap();
        assert_eq!(be.cache.len(), 1);
        let fp1 = be.cache[0].0;
        be.execute(&sm, &b, &mut c, n, 1.0, 0.0).unwrap();
        assert_eq!(be.cache.len(), 1, "repeat must hit, not append");
        // A second image is cached alongside the first (multi-model
        // serving must not thrash), and becomes the MRU entry.
        let b2 = vec![1.0f32; sm2.k * n];
        let mut c2 = vec![0.0f32; sm2.m * n];
        be.execute(&sm2, &b2, &mut c2, n, 1.0, 0.0).unwrap();
        assert_eq!(be.cache.len(), 2);
        assert_ne!(be.cache[0].0, fp1, "new image must be MRU");
        // Re-running the first image bubbles it back to the front without
        // evicting the second.
        be.execute(&sm, &b, &mut c, n, 1.0, 0.0).unwrap();
        assert_eq!(be.cache.len(), 2);
        assert_eq!(be.cache[0].0, fp1);
    }

    #[test]
    fn registry_roundtrip() {
        let be = backend::create("sharded:3:native:1").unwrap();
        assert_eq!(be.name(), "sharded");
        assert!(be.capability().threads >= 3);
        let send = backend::create_send("sharded:2:functional").unwrap();
        assert_eq!(send.name(), "sharded");
    }

    #[test]
    fn fingerprints_differ_across_images() {
        let (_, a) = image(5);
        let (_, b) = image(6);
        assert_ne!(fingerprint(&a), fingerprint(&b));
        assert_eq!(fingerprint(&a), fingerprint(&a.clone()));
    }

    #[test]
    fn fingerprint_distinguishes_window_boundaries() {
        // Same encoded words, different Q: a non-zero at global col 3
        // (window 0) vs col 11 (window 1, local col 3 under k0 = 8)
        // produces identical slot words whose meaning differs only through
        // the pointer list. The fingerprint must tell them apart or the
        // cache would silently serve the wrong matrix.
        use crate::sparse::Coo;
        let a = Coo::new(1, 16, vec![0], vec![3], vec![2.5]).unwrap();
        let b = Coo::new(1, 16, vec![0], vec![11], vec![2.5]).unwrap();
        let ia = preprocess(&a, 1, 8, 1);
        let ib = preprocess(&b, 1, 8, 1);
        assert_eq!(ia.streams[0].encoded, ib.streams[0].encoded);
        assert_ne!(ia.streams[0].q, ib.streams[0].q);
        assert_ne!(fingerprint(&ia), fingerprint(&ib));
    }
}
