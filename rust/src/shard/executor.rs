//! Parallel shard execution: one inner [`SpmmBackend`] instance per shard,
//! all shards running concurrently, row-disjoint C blocks gathered back.
//!
//! Each shard stands in for one accelerator card of a pool: it receives the
//! full B (broadcast), computes its own rows of C into a private block, and
//! the host scatters the blocks back — exact, because the shard plan
//! partitions rows. The scoped-thread fan-out mirrors the deployment the
//! ROADMAP aims at (S independent accelerators), so per-shard wall-clock
//! latencies in [`ShardRunStats`] are the real makespan decomposition.

use std::time::Instant;

use super::{ShardError, ShardRunStats, ShardedMatrix};
use crate::backend::{self, BackendError, SpmmBackend};

/// Executes a [`ShardedMatrix`] over a pool of inner backends (one per
/// shard, so shards never serialize behind a shared engine).
pub struct ShardExecutor {
    inners: Vec<Box<dyn SpmmBackend + Send>>,
    /// Per-shard C gather blocks, grow-only across calls (hot-path
    /// allocation stays zero after warm-up, matching the native engine's
    /// scratch discipline).
    locals: Vec<Vec<f32>>,
}

impl std::fmt::Debug for ShardExecutor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ShardExecutor({} x ", self.inners.len())?;
        match self.inners.first() {
            Some(b) => write!(f, "{})", b.name()),
            None => write!(f, "none)"),
        }
    }
}

impl ShardExecutor {
    /// Build `s` inner backends from a registry spec (`"native"`,
    /// `"native:2"`, `"functional"`, ...). A bare auto-threaded spec is
    /// first divided by `s` through [`backend::apply_thread_budget`] so the
    /// pool as a whole never oversubscribes the machine. Nested `"sharded"`
    /// inners are refused.
    pub fn from_spec(inner_spec: &str, s: usize) -> Result<ShardExecutor, BackendError> {
        if s == 0 {
            return Err(BackendError::InvalidSpec("shard count must be >= 1".into()));
        }
        if inner_spec == "sharded" || inner_spec.starts_with("sharded:") {
            return Err(BackendError::InvalidSpec(
                "sharded cannot nest inside sharded".into(),
            ));
        }
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let spec = backend::apply_thread_budget(inner_spec, (cores / s).max(1));
        let inners = (0..s)
            .map(|_| backend::create_send(&spec))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(ShardExecutor { inners, locals: Vec::new() })
    }

    /// Build from explicit backends (tests, heterogeneous pools).
    pub fn from_backends(inners: Vec<Box<dyn SpmmBackend + Send>>) -> ShardExecutor {
        ShardExecutor { inners, locals: Vec::new() }
    }

    /// Number of shards this executor can run (= inner backend count).
    pub fn num_shards(&self) -> usize {
        self.inners.len()
    }

    /// The inner backends (capability inspection).
    pub fn backends(&self) -> &[Box<dyn SpmmBackend + Send>] {
        &self.inners
    }

    /// Execute `C = alpha * A @ B + beta * C` across all shards in
    /// parallel. On success C holds every row; on failure C is untouched
    /// and the error names the failing shard.
    pub fn execute(
        &mut self,
        sm: &ShardedMatrix,
        b: &[f32],
        c: &mut [f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<ShardRunStats, ShardError> {
        if self.inners.len() != sm.shards.len() {
            return Err(ShardError::Shape(format!(
                "executor has {} backends but the matrix has {} shards",
                self.inners.len(),
                sm.shards.len()
            )));
        }
        if b.len() != sm.k * n {
            return Err(ShardError::Shape(format!(
                "B has {} elements, expected K*N = {}",
                b.len(),
                sm.k * n
            )));
        }
        if c.len() != sm.m * n {
            return Err(ShardError::Shape(format!(
                "C has {} elements, expected M*N = {}",
                c.len(),
                sm.m * n
            )));
        }

        // Gather: seed each shard's private C block with its global rows
        // (the beta * C_in term lives in the block). Blocks are grow-only
        // executor scratch; every element is overwritten by the gather, so
        // stale contents from earlier calls cannot leak.
        if self.locals.len() < sm.shards.len() {
            self.locals.resize_with(sm.shards.len(), Vec::new);
        }
        for (shard, buf) in sm.shards.iter().zip(self.locals.iter_mut()) {
            let need = shard.global_rows.len() * n;
            if buf.len() < need {
                buf.resize(need, 0.0);
            }
            for (li, &gr) in shard.global_rows.iter().enumerate() {
                let gr = gr as usize;
                buf[li * n..(li + 1) * n].copy_from_slice(&c[gr * n..(gr + 1) * n]);
            }
        }

        // Parallel shard execution: one scoped thread per shard, each
        // driving its own inner backend on its own C block.
        let inners = &mut self.inners;
        let locals = &mut self.locals;
        let outcomes: Vec<(Result<(), BackendError>, std::time::Duration)> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = inners
                    .iter_mut()
                    .zip(sm.shards.iter())
                    .zip(locals.iter_mut())
                    .map(|((inner, shard), buf)| {
                        scope.spawn(move || {
                            let need = shard.global_rows.len() * n;
                            let t0 = Instant::now();
                            let r =
                                inner.execute(&shard.image, b, &mut buf[..need], n, alpha, beta);
                            (r, t0.elapsed())
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("shard worker panicked"))
                    .collect()
            });

        for (shard, (outcome, _)) in outcomes.iter().enumerate() {
            if let Err(e) = outcome {
                return Err(ShardError::ShardFailed {
                    shard,
                    shards: outcomes.len(),
                    message: e.to_string(),
                });
            }
        }

        // Scatter: every shard succeeded, so write the row-disjoint blocks
        // back (partial results never reach C).
        for (shard, buf) in sm.shards.iter().zip(self.locals.iter()) {
            for (li, &gr) in shard.global_rows.iter().enumerate() {
                let gr = gr as usize;
                c[gr * n..(gr + 1) * n].copy_from_slice(&buf[li * n..(li + 1) * n]);
            }
        }

        Ok(ShardRunStats {
            shards: sm.shards.len(),
            shard_nnz: sm.shards.iter().map(|s| s.image.nnz).collect(),
            shard_latency: outcomes.into_iter().map(|(_, d)| d).collect(),
            imbalance: sm.imbalance(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{Capability, FunctionalBackend};
    use crate::prop;
    use crate::sched::ScheduledMatrix;
    use crate::sparse::{gen, rng::Rng, Coo};

    /// Fails every execution — for partial-failure surfacing tests.
    struct FailingBackend;

    impl SpmmBackend for FailingBackend {
        fn name(&self) -> &'static str {
            "failing"
        }

        fn capability(&self) -> Capability {
            Capability {
                threads: 1,
                simd_lanes: 1,
                requires_artifacts: false,
                deterministic: true,
            }
        }

        fn execute(
            &mut self,
            _image: &ScheduledMatrix,
            _b: &[f32],
            _c: &mut [f32],
            _n: usize,
            _alpha: f32,
            _beta: f32,
        ) -> Result<(), BackendError> {
            Err(BackendError::Execution("injected shard failure".into()))
        }
    }

    fn functional_pool(s: usize) -> ShardExecutor {
        ShardExecutor::from_backends(
            (0..s).map(|_| Box::new(FunctionalBackend) as Box<dyn SpmmBackend + Send>).collect(),
        )
    }

    #[test]
    fn sharded_matches_reference() {
        let mut rng = Rng::new(1);
        let coo = gen::power_law_rows(150, 80, 2_000, 1.1, &mut rng);
        let n = 7;
        let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
        let mut want = c0.clone();
        coo.spmm_reference(&b, &mut want, n, 1.5, -0.5);
        for s in [1usize, 2, 5] {
            let sharded = ShardedMatrix::build(&coo, s, 4, 16, 6);
            let mut exec = functional_pool(s);
            let mut c = c0.clone();
            let stats = exec.execute(&sharded, &b, &mut c, n, 1.5, -0.5).unwrap();
            assert_eq!(stats.shards, s);
            assert_eq!(stats.shard_nnz.iter().sum::<usize>(), coo.nnz());
            prop::assert_allclose(&c, &want, 2e-4, 2e-4).unwrap();
        }
    }

    #[test]
    fn failing_shard_is_identified_and_c_untouched() {
        let mut rng = Rng::new(2);
        let coo = gen::random_uniform(40, 30, 0.2, &mut rng);
        let sharded = ShardedMatrix::build(&coo, 3, 2, 8, 4);
        let mut exec = ShardExecutor::from_backends(vec![
            Box::new(FunctionalBackend),
            Box::new(FailingBackend),
            Box::new(FunctionalBackend),
        ]);
        let n = 3;
        let b = vec![1.0f32; coo.k * n];
        let c0: Vec<f32> = (0..coo.m * n).map(|i| i as f32).collect();
        let mut c = c0.clone();
        let err = exec.execute(&sharded, &b, &mut c, n, 1.0, 0.0).unwrap_err();
        match err {
            ShardError::ShardFailed { shard, shards, ref message } => {
                assert_eq!(shard, 1);
                assert_eq!(shards, 3);
                assert!(message.contains("injected shard failure"), "{message}");
            }
            other => panic!("wrong error: {other:?}"),
        }
        // No partial scatter: C must be exactly the input.
        assert_eq!(c, c0);
    }

    #[test]
    fn shape_mismatches_are_rejected() {
        let coo = Coo::empty(4, 4);
        let sharded = ShardedMatrix::build(&coo, 2, 2, 4, 2);
        let mut exec = functional_pool(2);
        let mut c = vec![0f32; 8];
        // Wrong B length.
        assert!(matches!(
            exec.execute(&sharded, &[0.0; 7], &mut c, 2, 1.0, 0.0),
            Err(ShardError::Shape(_))
        ));
        // Executor / shard count mismatch.
        let mut small = functional_pool(3);
        assert!(matches!(
            small.execute(&sharded, &[0.0; 8], &mut c, 2, 1.0, 0.0),
            Err(ShardError::Shape(_))
        ));
    }

    #[test]
    fn empty_rows_still_get_beta_scaling() {
        // Rows with no non-zeros must still compute C = beta * C.
        let coo = Coo::new(6, 4, vec![2], vec![1], vec![3.0]).unwrap();
        let sharded = ShardedMatrix::build(&coo, 3, 2, 4, 2);
        let mut exec = functional_pool(3);
        let n = 2;
        let b = vec![1.0f32; coo.k * n];
        let mut c = vec![2.0f32; coo.m * n];
        exec.execute(&sharded, &b, &mut c, n, 1.0, 0.5).unwrap();
        for (i, &v) in c.iter().enumerate() {
            let row = i / n;
            let want = if row == 2 { 3.0 + 1.0 } else { 1.0 };
            assert!((v - want).abs() < 1e-6, "row {row}: {v} != {want}");
        }
    }

    #[test]
    fn from_spec_builds_budgeted_pool() {
        let exec = ShardExecutor::from_spec("native", 4).unwrap();
        assert_eq!(exec.num_shards(), 4);
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let per_shard = (cores / 4).max(1);
        for be in exec.backends() {
            assert_eq!(be.capability().threads, per_shard);
        }
    }

    #[test]
    fn from_spec_rejects_nesting_and_zero_shards() {
        assert!(matches!(
            ShardExecutor::from_spec("sharded:2:native", 2),
            Err(BackendError::InvalidSpec(_))
        ));
        assert!(matches!(
            ShardExecutor::from_spec("native", 0),
            Err(BackendError::InvalidSpec(_))
        ));
    }
}
