//! Parallel shard execution over *prepared* inner handles: one
//! [`PreparedSpmm`] per shard, resident on that shard's image, all shards
//! running concurrently, row-disjoint C blocks gathered back.
//!
//! Each shard stands in for one accelerator card of a pool: its inner
//! handle is prepared once on the shard's image
//! ([`ShardExecutor::prepare`] — the build path), then every request
//! broadcasts the full B, computes the shard's rows of C into a private
//! block, and the host scatters the blocks back — exact, because the shard
//! plan partitions rows. The scoped-thread fan-out mirrors the deployment
//! the ROADMAP aims at (S independent accelerators), so per-shard
//! wall-clock latencies in [`ShardRunStats`] are the real makespan
//! decomposition — and because the executor now *owns* the resident
//! shards, the cross-process deployment only has to move the handles.
//!
//! Execution takes `&self`: the inner handles themselves execute through
//! `&self` (see [`PreparedSpmm`]), and the per-call C gather blocks come
//! from a [`ScratchPool`] of per-call block sets, so concurrent requests
//! stream against one resident pool without serializing. Exact-failure
//! semantics and the scatter order are unchanged — blocks are written back
//! shard-ascending only after every active shard succeeded, so results
//! stay bit-identical to the serial path and a failed run leaves C
//! untouched.

use std::sync::Arc;
use std::time::Instant;

use super::{ShardError, ShardRunStats, ShardedMatrix};
use crate::backend::{self, BackendError, PrepareCost, PreparedSpmm, ScratchPool};

/// Executes a [`ShardedMatrix`] resident across a pool of prepared inner
/// handles (one per shard, so shards never serialize behind a shared
/// engine). Build once with [`ShardExecutor::prepare`], execute many —
/// concurrently, through `&self`.
pub struct ShardExecutor {
    /// One prepared inner handle per shard, resident on the shard's image.
    inners: Vec<Box<dyn PreparedSpmm + Send + Sync>>,
    /// Global rows owned by each shard (ascending; local row `i` of shard
    /// `s` is `global_rows[s][i]`).
    global_rows: Vec<Vec<u32>>,
    /// Real non-zeros per shard (for [`ShardRunStats`]).
    shard_nnz: Vec<usize>,
    /// Total rows / columns of the resident matrix.
    m: usize,
    k: usize,
    /// Build-time nnz imbalance of the shard plan.
    imbalance: f64,
    /// Aggregate build cost (shard images + inner prepares + row maps).
    cost: PrepareCost,
    /// Pool of per-call C gather block sets (one block per shard), blocks
    /// grow-only across calls — hot-path allocation stays zero after
    /// warm-up, and concurrent executions each check out their own set.
    locals: ScratchPool<Vec<Vec<f32>>>,
}

impl std::fmt::Debug for ShardExecutor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ShardExecutor({} x ", self.inners.len())?;
        match self.inners.first() {
            Some(b) => write!(f, "{})", b.backend_name()),
            None => write!(f, "none)"),
        }
    }
}

impl ShardExecutor {
    /// Prepare every shard of `sm` on an inner registry spec (`"native"`,
    /// `"native:2"`, `"functional"`, ...): the build path, paid once per
    /// matrix. A bare auto-threaded spec is first divided by the shard
    /// count through [`backend::apply_thread_budget`] so the pool as a
    /// whole never oversubscribes the machine. Nested `"sharded"` inners
    /// are refused.
    pub fn prepare(sm: &ShardedMatrix, inner_spec: &str) -> Result<ShardExecutor, BackendError> {
        let s = sm.num_shards();
        if s == 0 {
            return Err(BackendError::InvalidSpec("shard count must be >= 1".into()));
        }
        if inner_spec == "sharded" || inner_spec.starts_with("sharded:") {
            return Err(BackendError::InvalidSpec(
                "sharded cannot nest inside sharded".into(),
            ));
        }
        let t0 = Instant::now();
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let spec = backend::apply_thread_budget(inner_spec, (cores / s).max(1));
        let factory = backend::create(&spec)?;
        let inners = sm
            .shards
            .iter()
            .map(|shard| factory.prepare_send(Arc::clone(&shard.image)))
            .collect::<Result<Vec<_>, _>>()?;
        let resident_bytes = sm.resident_bytes()
            + inners.iter().map(|h| h.prepare_cost().resident_bytes).sum::<u64>();
        Ok(Self::assemble(sm, inners, PrepareCost { wall: t0.elapsed(), resident_bytes }))
    }

    /// Build from explicitly prepared handles, one per shard in order
    /// (tests, heterogeneous pools). Panics if the handle count does not
    /// match the shard count.
    pub fn from_prepared(
        sm: &ShardedMatrix,
        inners: Vec<Box<dyn PreparedSpmm + Send + Sync>>,
    ) -> ShardExecutor {
        assert_eq!(
            inners.len(),
            sm.num_shards(),
            "one prepared handle per shard required"
        );
        let resident_bytes = sm.resident_bytes()
            + inners.iter().map(|h| h.prepare_cost().resident_bytes).sum::<u64>();
        Self::assemble(sm, inners, PrepareCost { wall: Default::default(), resident_bytes })
    }

    fn assemble(
        sm: &ShardedMatrix,
        inners: Vec<Box<dyn PreparedSpmm + Send + Sync>>,
        cost: PrepareCost,
    ) -> ShardExecutor {
        ShardExecutor {
            inners,
            global_rows: sm.shards.iter().map(|s| s.global_rows.clone()).collect(),
            shard_nnz: sm.shards.iter().map(|s| s.image.nnz).collect(),
            m: sm.m,
            k: sm.k,
            imbalance: sm.imbalance(),
            cost,
            locals: ScratchPool::new(),
        }
    }

    /// Number of resident shards (= prepared inner handles).
    pub fn num_shards(&self) -> usize {
        self.inners.len()
    }

    /// The prepared inner handles (cost inspection).
    pub fn prepared(&self) -> &[Box<dyn PreparedSpmm + Send + Sync>] {
        &self.inners
    }

    /// Per-call gather-block sets currently parked in the internal scratch
    /// pool — at most one per peak concurrent execution (see
    /// [`ScratchPool`]); exposed so tests can assert the bound.
    pub fn scratch_sets(&self) -> usize {
        self.locals.idle()
    }

    /// Aggregate build cost: shard images, inner prepares, row maps.
    pub fn prepare_cost(&self) -> PrepareCost {
        self.cost
    }

    /// Bytes resident *right now*: the build-time estimate with each inner
    /// handle's live footprint substituted for its prepare-time snapshot,
    /// plus the gather-block sets parked in the scratch pool (which grow
    /// with peak concurrency and are invisible to [`PrepareCost`]).
    pub fn resident_bytes_now(&self) -> u64 {
        let static_inners: u64 =
            self.inners.iter().map(|h| h.prepare_cost().resident_bytes).sum();
        let live_inners: u64 = self.inners.iter().map(|h| h.resident_bytes_now()).sum();
        let pooled = self.locals.measure(|set| {
            set.iter().map(|b| (b.len() * std::mem::size_of::<f32>()) as u64).sum()
        });
        self.cost.resident_bytes.saturating_sub(static_inners) + live_inners + pooled
    }

    /// Drop gather-block sets parked in the scratch pool for longer than
    /// `max_idle` and return the bytes reclaimed. A concurrency burst
    /// grows the pool to its peak width; this is how the pool shrinks
    /// back once the burst passes (surfaced through
    /// [`crate::backend::PreparedSpmm::trim_resident`] on the sharded
    /// composite handle).
    pub fn trim_scratch(&self, max_idle: std::time::Duration) -> u64 {
        self.locals.trim_idle(max_idle, |set| {
            set.iter().map(|b| (b.len() * std::mem::size_of::<f32>()) as u64).sum()
        })
    }

    /// Build-time nnz imbalance of the resident shard plan.
    pub fn imbalance(&self) -> f64 {
        self.imbalance
    }

    /// Global rows owned by each resident shard — the row sets shard-aware
    /// batching routes on (ascending; local row `i` of shard `s` is
    /// `shard_rows()[s][i]`).
    pub fn shard_rows(&self) -> &[Vec<u32>] {
        &self.global_rows
    }

    /// Real non-zeros per resident shard.
    pub fn shard_nnz(&self) -> &[usize] {
        &self.shard_nnz
    }

    /// Execute `C = alpha * A @ B + beta * C` across all resident shards in
    /// parallel. On success C holds every row; on failure C is untouched
    /// and the error names the failing shard.
    pub fn execute(
        &self,
        b: &[f32],
        c: &mut [f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<ShardRunStats, ShardError> {
        self.execute_masked(b, c, n, alpha, beta, false).map(|(stats, _)| stats)
    }

    /// Like [`ShardExecutor::execute`], but skip shards that own no
    /// non-zeros: no thread is spawned for them, and their rows receive
    /// the pure `beta * C` update host-side — bit-identical, because an
    /// empty shard's engine result is exactly `beta * C`. Returns the run
    /// stats (skipped shards report zero latency) plus the number of
    /// shards skipped. This is the execution half of shard-aware routing:
    /// worth it for small-N requests, where per-shard fan-out overhead is
    /// comparable to the useful work.
    pub fn execute_active(
        &self,
        b: &[f32],
        c: &mut [f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<(ShardRunStats, usize), ShardError> {
        self.execute_masked(b, c, n, alpha, beta, true)
    }

    fn execute_masked(
        &self,
        b: &[f32],
        c: &mut [f32],
        n: usize,
        alpha: f32,
        beta: f32,
        skip_empty: bool,
    ) -> Result<(ShardRunStats, usize), ShardError> {
        if b.len() != self.k * n {
            return Err(ShardError::Shape(format!(
                "B has {} elements, expected K*N = {}",
                b.len(),
                self.k * n
            )));
        }
        if c.len() != self.m * n {
            return Err(ShardError::Shape(format!(
                "C has {} elements, expected M*N = {}",
                c.len(),
                self.m * n
            )));
        }
        let active: Vec<bool> = if skip_empty {
            self.shard_nnz.iter().map(|&nnz| nnz > 0).collect()
        } else {
            vec![true; self.inners.len()]
        };
        let skipped = active.iter().filter(|a| !**a).count();

        // Per-call mutable state: check one gather-block set out of the
        // pool (concurrent executions each get their own set; the pool
        // lock covers only this checkout and the return at the end).
        let mut locals = self.locals.checkout(Vec::new);
        if locals.len() < self.global_rows.len() {
            locals.resize_with(self.global_rows.len(), Vec::new);
        }

        // Gather: seed each active shard's private C block with its global
        // rows (the beta * C_in term lives in the block). Blocks are
        // grow-only pooled scratch; every element is overwritten by the
        // gather, so stale contents from earlier calls cannot leak.
        for (i, (rows, buf)) in
            self.global_rows.iter().zip(locals.iter_mut()).enumerate()
        {
            if !active[i] {
                continue;
            }
            let need = rows.len() * n;
            if buf.len() < need {
                buf.resize(need, 0.0);
            }
            for (li, &gr) in rows.iter().enumerate() {
                let gr = gr as usize;
                buf[li * n..(li + 1) * n].copy_from_slice(&c[gr * n..(gr + 1) * n]);
            }
        }

        // Parallel shard execution: one scoped thread per active shard,
        // each driving its (shared, &self) prepared inner handle on its
        // own C block from the checked-out set.
        let inners = &self.inners;
        let global_rows = &self.global_rows;
        let active_ref = &active;
        let outcomes: Vec<(usize, Result<(), BackendError>, std::time::Duration)> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = inners
                    .iter()
                    .zip(global_rows.iter())
                    .zip(locals.iter_mut())
                    .enumerate()
                    .filter(|(i, _)| active_ref[*i])
                    .map(|(i, ((inner, rows), buf))| {
                        scope.spawn(move || {
                            let need = rows.len() * n;
                            let t0 = Instant::now();
                            let r = inner.execute(b, &mut buf[..need], n, alpha, beta);
                            (i, r, t0.elapsed())
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("shard worker panicked"))
                    .collect()
            });

        let shards_total = self.global_rows.len();
        for (shard, outcome, _) in &outcomes {
            if let Err(e) = outcome {
                return Err(ShardError::ShardFailed {
                    shard: *shard,
                    shards: shards_total,
                    message: e.to_string(),
                });
            }
        }

        // Scatter: every active shard succeeded, so write the row-disjoint
        // blocks back in shard-ascending order (the order contract the
        // bit-identical tests pin down); only now do skipped shards' rows
        // get their pure beta update (partial results never reach C).
        for (i, (rows, buf)) in
            self.global_rows.iter().zip(locals.iter()).enumerate()
        {
            if active[i] {
                for (li, &gr) in rows.iter().enumerate() {
                    let gr = gr as usize;
                    c[gr * n..(gr + 1) * n].copy_from_slice(&buf[li * n..(li + 1) * n]);
                }
            } else {
                for &gr in rows {
                    let gr = gr as usize;
                    for v in &mut c[gr * n..(gr + 1) * n] {
                        *v *= beta;
                    }
                }
            }
        }

        let mut shard_latency = vec![std::time::Duration::ZERO; shards_total];
        for (i, _, d) in outcomes {
            shard_latency[i] = d;
        }
        Ok((
            ShardRunStats {
                shards: shards_total,
                shard_nnz: self.shard_nnz.clone(),
                shard_latency,
                imbalance: self.imbalance,
            },
            skipped,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{FunctionalBackend, SpmmBackend};
    use crate::prop;
    use crate::sparse::{gen, rng::Rng, Coo};

    /// Fails every execution — for partial-failure surfacing tests.
    struct FailingPrepared;

    impl PreparedSpmm for FailingPrepared {
        fn backend_name(&self) -> &'static str {
            "failing"
        }

        fn prepare_cost(&self) -> PrepareCost {
            PrepareCost::default()
        }

        fn execute(
            &self,
            _b: &[f32],
            _c: &mut [f32],
            _n: usize,
            _alpha: f32,
            _beta: f32,
        ) -> Result<(), BackendError> {
            Err(BackendError::Execution("injected shard failure".into()))
        }
    }

    fn functional_pool(sm: &ShardedMatrix) -> ShardExecutor {
        let inners = sm
            .shards
            .iter()
            .map(|s| FunctionalBackend.prepare_send(Arc::clone(&s.image)).unwrap())
            .collect();
        ShardExecutor::from_prepared(sm, inners)
    }

    #[test]
    fn sharded_matches_reference() {
        let mut rng = Rng::new(1);
        let coo = gen::power_law_rows(150, 80, 2_000, 1.1, &mut rng);
        let n = 7;
        let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
        let mut want = c0.clone();
        coo.spmm_reference(&b, &mut want, n, 1.5, -0.5);
        for s in [1usize, 2, 5] {
            let sharded = ShardedMatrix::build(&coo, s, 4, 16, 6);
            let exec = functional_pool(&sharded);
            let mut c = c0.clone();
            let stats = exec.execute(&b, &mut c, n, 1.5, -0.5).unwrap();
            assert_eq!(stats.shards, s);
            assert_eq!(stats.shard_nnz.iter().sum::<usize>(), coo.nnz());
            prop::assert_allclose(&c, &want, 2e-4, 2e-4).unwrap();
        }
    }

    #[test]
    fn failing_shard_is_identified_and_c_untouched() {
        let mut rng = Rng::new(2);
        let coo = gen::random_uniform(40, 30, 0.2, &mut rng);
        let sharded = ShardedMatrix::build(&coo, 3, 2, 8, 4);
        let exec = ShardExecutor::from_prepared(
            &sharded,
            vec![
                FunctionalBackend.prepare_send(Arc::clone(&sharded.shards[0].image)).unwrap(),
                Box::new(FailingPrepared),
                FunctionalBackend.prepare_send(Arc::clone(&sharded.shards[2].image)).unwrap(),
            ],
        );
        let n = 3;
        let b = vec![1.0f32; coo.k * n];
        let c0: Vec<f32> = (0..coo.m * n).map(|i| i as f32).collect();
        let mut c = c0.clone();
        let err = exec.execute(&b, &mut c, n, 1.0, 0.0).unwrap_err();
        match err {
            ShardError::ShardFailed { shard, shards, ref message } => {
                assert_eq!(shard, 1);
                assert_eq!(shards, 3);
                assert!(message.contains("injected shard failure"), "{message}");
            }
            other => panic!("wrong error: {other:?}"),
        }
        // No partial scatter: C must be exactly the input.
        assert_eq!(c, c0);
    }

    #[test]
    fn shape_mismatches_are_rejected() {
        let coo = Coo::empty(4, 4);
        let sharded = ShardedMatrix::build(&coo, 2, 2, 4, 2);
        let exec = functional_pool(&sharded);
        let mut c = vec![0f32; 8];
        // Wrong B length.
        assert!(matches!(
            exec.execute(&[0.0; 7], &mut c, 2, 1.0, 0.0),
            Err(ShardError::Shape(_))
        ));
        // Wrong C length.
        assert!(matches!(
            exec.execute(&[0.0; 8], &mut c[..6], 2, 1.0, 0.0),
            Err(ShardError::Shape(_))
        ));
    }

    #[test]
    fn empty_rows_still_get_beta_scaling() {
        // Rows with no non-zeros must still compute C = beta * C.
        let coo = Coo::new(6, 4, vec![2], vec![1], vec![3.0]).unwrap();
        let sharded = ShardedMatrix::build(&coo, 3, 2, 4, 2);
        let exec = functional_pool(&sharded);
        let n = 2;
        let b = vec![1.0f32; coo.k * n];
        let mut c = vec![2.0f32; coo.m * n];
        exec.execute(&b, &mut c, n, 1.0, 0.5).unwrap();
        for (i, &v) in c.iter().enumerate() {
            let row = i / n;
            let want = if row == 2 { 3.0 + 1.0 } else { 1.0 };
            assert!((v - want).abs() < 1e-6, "row {row}: {v} != {want}");
        }
    }

    #[test]
    fn prepare_builds_budgeted_resident_pool() {
        let mut rng = Rng::new(5);
        let coo = gen::random_uniform(64, 48, 0.1, &mut rng);
        let sharded = ShardedMatrix::build(&coo, 4, 2, 16, 4);
        let exec = ShardExecutor::prepare(&sharded, "native").unwrap();
        assert_eq!(exec.num_shards(), 4);
        assert_eq!(exec.prepared().len(), 4);
        for h in exec.prepared() {
            assert_eq!(h.backend_name(), "native");
        }
        // Resident accounting covers the shard images at minimum.
        assert!(exec.prepare_cost().resident_bytes >= sharded.resident_bytes());
    }

    #[test]
    fn prepare_rejects_nesting() {
        let coo = Coo::empty(4, 4);
        let sharded = ShardedMatrix::build(&coo, 2, 2, 4, 2);
        assert!(matches!(
            ShardExecutor::prepare(&sharded, "sharded:2:native"),
            Err(BackendError::InvalidSpec(_))
        ));
    }

    #[test]
    fn execute_active_skips_empty_shards_bit_identically() {
        // 3 non-empty rows over 8 shards: 5 shards own only empty rows
        // and must be skipped, with C bit-identical to the full run.
        let coo = Coo::new(
            24,
            16,
            vec![0, 0, 5, 5, 11],
            vec![1, 7, 3, 9, 14],
            vec![1.5, -2.0, 0.25, 4.0, -1.0],
        )
        .unwrap();
        let sharded = ShardedMatrix::build(&coo, 8, 2, 8, 2);
        let empty_shards =
            sharded.shards.iter().filter(|s| s.image.nnz == 0).count();
        assert!(empty_shards >= 5, "construction must leave empty shards");
        let n = 3;
        let b: Vec<f32> = (0..coo.k * n).map(|i| (i as f32 * 0.37).sin()).collect();
        let c0: Vec<f32> = (0..coo.m * n).map(|i| (i as f32 * 0.13).cos()).collect();

        let mut full = c0.clone();
        let exec = functional_pool(&sharded);
        exec.execute(&b, &mut full, n, 1.25, -0.75).unwrap();

        let mut routed = c0.clone();
        let (stats, skipped) =
            exec.execute_active(&b, &mut routed, n, 1.25, -0.75).unwrap();
        assert_eq!(skipped, empty_shards);
        assert_eq!(routed, full, "routing must be bit-identical");
        assert_eq!(stats.shards, 8, "stats still describe the whole pool");
        // Skipped shards report zero latency; the row sets are exposed
        // for the batcher's routing decision.
        let zero_lat =
            stats.shard_latency.iter().filter(|d| d.is_zero()).count();
        assert!(zero_lat >= empty_shards);
        assert_eq!(exec.shard_rows().len(), 8);
        assert_eq!(
            exec.shard_rows().iter().map(|r| r.len()).sum::<usize>(),
            coo.m
        );
        assert_eq!(exec.shard_nnz().iter().sum::<usize>(), coo.nnz());
    }

    #[test]
    fn execute_active_runs_all_shards_when_none_empty() {
        let mut rng = Rng::new(9);
        let coo = gen::power_law_rows(60, 40, 900, 1.0, &mut rng);
        let sharded = ShardedMatrix::build(&coo, 3, 2, 8, 2);
        assert!(sharded.shards.iter().all(|s| s.image.nnz > 0));
        let exec = functional_pool(&sharded);
        let n = 2;
        let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
        let mut c: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
        let mut want = c.clone();
        coo.spmm_reference(&b, &mut want, n, 1.0, 0.5);
        let (stats, skipped) = exec.execute_active(&b, &mut c, n, 1.0, 0.5).unwrap();
        assert_eq!(skipped, 0);
        assert_eq!(stats.shards, 3);
        prop::assert_allclose(&c, &want, 2e-4, 2e-4).unwrap();
    }

    #[test]
    fn resident_bytes_now_sees_pooled_gather_blocks() {
        let mut rng = Rng::new(11);
        let coo = gen::random_uniform(48, 32, 0.2, &mut rng);
        let sharded = ShardedMatrix::build(&coo, 3, 2, 8, 4);
        let exec = functional_pool(&sharded);
        let before = exec.resident_bytes_now();
        assert_eq!(
            before,
            exec.prepare_cost().resident_bytes,
            "no pooled scratch before the first execution"
        );
        let n = 4;
        let b = vec![1.0f32; coo.k * n];
        let mut c = vec![0.0f32; coo.m * n];
        exec.execute(&b, &mut c, n, 1.0, 0.0).unwrap();
        // One gather-block set (one block per shard, m rows total) is now
        // parked in the pool and must be charged.
        let gather = (coo.m * n * std::mem::size_of::<f32>()) as u64;
        let after = exec.resident_bytes_now();
        assert!(
            after >= before + gather,
            "pooled gather blocks uncharged: {before} -> {after} (want >= +{gather})"
        );
    }

    #[test]
    fn one_pool_serves_varying_n() {
        let mut rng = Rng::new(7);
        let coo = gen::power_law_rows(90, 60, 900, 1.0, &mut rng);
        let sharded = ShardedMatrix::build(&coo, 3, 2, 16, 4);
        let exec = ShardExecutor::prepare(&sharded, "native:1").unwrap();
        for n in [5usize, 1, 9, 3] {
            let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
            let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
            let mut want = c0.clone();
            coo.spmm_reference(&b, &mut want, n, 1.25, 0.5);
            let mut c = c0;
            exec.execute(&b, &mut c, n, 1.25, 0.5).unwrap();
            prop::assert_allclose(&c, &want, 2e-4, 2e-4).unwrap();
        }
    }
}
