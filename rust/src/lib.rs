//! # Sextans — general-purpose SpMM streaming accelerator (FPGA '22 reproduction)
//!
//! This crate reproduces *Sextans: A Streaming Accelerator for General-Purpose
//! Sparse-Matrix Dense-Matrix Multiplication* (Song et al., FPGA '22) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's system contribution: matrix
//!   partitioning (Eq. 2–4), PE-aware out-of-order non-zero scheduling
//!   (§3.3), the HFlex pointer-list runtime (§3.4), a cycle-level streaming
//!   simulator of the accelerator (§3.1–3.2, §4.1), analytical and GPU
//!   baseline performance models (§3.6, §4), and the full benchmark harness
//!   regenerating every table and figure of the evaluation.
//! * **L2 (python/compile/model.py)** — the window-level SpMM compute graph
//!   in JAX, AOT-lowered to HLO text artifacts.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the PE inner loop
//!   and the Comp-C stage, executed from Rust via the PJRT CPU client
//!   ([`runtime`], behind the optional `pjrt` cargo feature).
//!
//! Execution is pluggable ([`backend`]): the default native multi-threaded
//! engine consumes scheduled images directly, so the whole serving stack
//! builds, tests, and benches with no Python artifacts present.
//!
//! Python never runs on the request path: `make artifacts` runs once, and
//! the Rust binary is self-contained afterwards.
//!
//! ## Module map
//!
//! | module | paper section | role |
//! |---|---|---|
//! | [`sparse`] | §2.1, Table 2 | COO/CSR formats, MatrixMarket I/O, synthetic matrix generators, the 200-matrix catalog |
//! | [`sched`] | §3.3, §3.4, Fig. 5 | window partitioning, OoO non-zero scheduling, 64-bit encoding, Q pointer list |
//! | [`arch`] | §3.1, §3.2, §3.5, §3.6.2 | cycle-level streaming simulator, functional simulator, resource model |
//! | [`perfmodel`] | §3.6.1, §4.1 | Eq. 6–10 closed form, GPU baselines, platform constants, energy |
//! | [`hflex`] | §3.4 | the HFlex runtime contract: one fixed accelerator, arbitrary SpMMs; [`hflex::HFlexAccelerator::load`] returns an A-resident [`hflex::LoadedMatrix`] |
//! | [`backend`] | §3.4, §4.2 | two-phase prepare/execute engines: [`backend::SpmmBackend`] factories produce matrix-resident [`backend::PreparedSpmm`] handles (prepare A once, execute many — *concurrently*: `execute` takes `&self`, per-call scratch comes from [`backend::ScratchPool`]s) — native multi-threaded CPU over condensed per-PE streams and the runtime-dispatched [`backend::simd`] kernel layer (AVX2 or bit-identical scalar fallback; plain + adaptively column-blocked), functional reference, PJRT adapter, sharded composite — selected by name |
//! | [`shard`] | §3.3 scaled up | sharded multi-accelerator execution: nnz-balanced row partitioning, resident [`shard::ShardExecutor`] pools of prepared inner handles (full or active-subset execution, `&self` with pooled gather blocks), `sharded:<S>:<inner>` composite backend |
//! | [`net`] | §3.3 scaled out | distributed worker fleet: versioned length-prefixed wire codec for scheduled images, `sextans worker` shard servers, LPT/replicated shard placement, and the `remote:<addr>[,addr...]` backend proxying execution over pooled connections with retry + re-place |
//! | [`runtime`] | — | PJRT client wrapping the AOT HLO artifacts (stubbed unless both `pjrt` and `xla` features are on) |
//! | [`serve_net`] | — | network front door: framed client protocol (chunked image registration, column-block panel streaming, typed shed frames), `sextans serve --listen`, the [`serve_net::FrontClient`] library, and the open-loop `sextans loadgen` capacity harness |
//! | [`coordinator`] | — | adaptive SpMM serving pipeline in four stages — admission (backpressure gate + per-image fairness quota), batcher (merge window + shard-aware routing), dispatch (worker pool + thread budgets + stage timings + concurrent execution over shared `Arc<dyn PreparedSpmm>` handles), residency (byte-sized cache of shared lock-free handles + re-shard-on-skew) — behind the [`coordinator::Server`] facade |
//! | [`metrics`] | §4.2 | GFLOP/s, bandwidth utilization, energy efficiency, geomean/CDF |
//! | [`telemetry`] | §4.2 methodology | observability: per-request span traces (sink threaded through the coordinator via `PipelineConfig`), fixed-memory streaming latency histograms behind `Summary`, hand-rolled JSON, and the persisted `BENCH_*.json` perf-trajectory schema with regression compare |
//! | [`report`] | §4.2, §4.3 | experiment drivers regenerating Tables 1–5 and Figures 7–10 |

pub mod arch;
pub mod backend;
pub mod bench_util;
pub mod cli;
pub mod coordinator;
pub mod hflex;
pub mod metrics;
pub mod net;
pub mod perfmodel;
pub mod prop;
pub mod report;
pub mod runtime;
pub mod sched;
pub mod serve_net;
pub mod shard;
pub mod sparse;
pub mod telemetry;
