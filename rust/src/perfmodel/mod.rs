//! Performance models: the §3.6.1 closed form, the GPU baselines, the four
//! Table 3 platforms, and the energy model.

pub mod analytical;
pub mod energy;
pub mod gpu;
pub mod platforms;

pub use gpu::{GpuModel, MatrixStats};
pub use platforms::Platform;
