//! The four evaluation platforms (paper Table 3) behind one interface.
//!
//! | platform | Tech | Freq | Bdw | On-chip | Power | Peak SpMM |
//! |---|---|---|---|---|---|---|
//! | Tesla K80  | 28 nm | 562 MHz | 480 GB/s | 24.5 MB | 130 W | 127.8 GF/s |
//! | Sextans    | 16 nm | 189 MHz | 460 GB/s | 22.7 MB |  52 W | 181.1 GF/s |
//! | Tesla V100 | 12 nm | 1.297 GHz | 900 GB/s | 33.5 MB | 287 W | 688.0 GF/s |
//! | Sextans-P  | 16 nm | 350 MHz | 900 GB/s | 24.5 MB |  96 W | 343.6 GF/s |

use crate::arch::{simulate, AcceleratorConfig, SimReport};
use crate::sched::ScheduledMatrix;

use super::gpu::{GpuModel, MatrixStats};

/// Platform identifier (Table 3 rows, in the paper's order).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Platform {
    /// NVIDIA Tesla K80 (cuSPARSE csrmm model).
    K80,
    /// Sextans U280 prototype (cycle-level simulator).
    Sextans,
    /// NVIDIA Tesla V100 (cuSPARSE csrmm model).
    V100,
    /// Sextans-P projection (simulator at 350 MHz / 900 GB/s).
    SextansP,
}

/// All four, in presentation order.
pub const ALL: [Platform; 4] = [
    Platform::K80,
    Platform::Sextans,
    Platform::V100,
    Platform::SextansP,
];

/// Static platform metadata (Table 3 columns).
#[derive(Clone, Debug)]
pub struct PlatformSpec {
    /// Display name.
    pub name: &'static str,
    /// Process node (nm).
    pub tech_nm: u32,
    /// Clock (MHz).
    pub freq_mhz: f64,
    /// Memory bandwidth (GB/s).
    pub bandwidth_gbps: f64,
    /// On-chip memory (MB).
    pub onchip_mb: f64,
    /// Power (W).
    pub power_w: f64,
    /// Peak SpMM throughput (GFLOP/s).
    pub peak_gflops: f64,
}

impl Platform {
    /// Table 3 metadata.
    pub fn spec(&self) -> PlatformSpec {
        match self {
            Platform::K80 => PlatformSpec {
                name: "Tesla K80",
                tech_nm: 28,
                freq_mhz: 562.0,
                bandwidth_gbps: 480.0,
                onchip_mb: 24.5,
                power_w: 130.0,
                peak_gflops: 127.8,
            },
            Platform::Sextans => PlatformSpec {
                name: "SEXTANS",
                tech_nm: 16,
                freq_mhz: 189.0,
                bandwidth_gbps: 460.0,
                onchip_mb: 22.7,
                power_w: 52.0,
                peak_gflops: 181.1,
            },
            Platform::V100 => PlatformSpec {
                name: "Tesla V100",
                tech_nm: 12,
                freq_mhz: 1297.0,
                bandwidth_gbps: 900.0,
                onchip_mb: 33.5,
                power_w: 287.0,
                peak_gflops: 688.0,
            },
            Platform::SextansP => PlatformSpec {
                name: "SEXTANS-P",
                tech_nm: 16,
                freq_mhz: 350.0,
                bandwidth_gbps: 900.0,
                onchip_mb: 24.5,
                power_w: 96.0,
                peak_gflops: 343.6,
            },
        }
    }

    /// Is this one of the two FPGA/simulator rows?
    pub fn is_sextans(&self) -> bool {
        matches!(self, Platform::Sextans | Platform::SextansP)
    }

    /// Accelerator config for the Sextans rows.
    pub fn accel_config(&self) -> Option<AcceleratorConfig> {
        match self {
            Platform::Sextans => Some(AcceleratorConfig::sextans_u280()),
            Platform::SextansP => Some(AcceleratorConfig::sextans_p()),
            _ => None,
        }
    }

    /// GPU model for the GPU rows.
    pub fn gpu_model(&self) -> Option<GpuModel> {
        match self {
            Platform::K80 => Some(GpuModel::k80()),
            Platform::V100 => Some(GpuModel::v100()),
            _ => None,
        }
    }

    /// Execution time of one SpMM. Sextans rows need the scheduled image;
    /// GPU rows need only the statistics.
    pub fn seconds(&self, image: Option<&ScheduledMatrix>, stats: &MatrixStats, n: usize) -> f64 {
        match (self.accel_config(), self.gpu_model()) {
            (Some(cfg), _) => {
                let sm = image.expect("Sextans platforms need a scheduled image");
                simulate(sm, &cfg, n).seconds
            }
            (_, Some(gpu)) => gpu.seconds(stats, n),
            _ => unreachable!(),
        }
    }

    /// Full simulator report (Sextans rows only).
    pub fn sim_report(&self, image: &ScheduledMatrix, n: usize) -> Option<SimReport> {
        self.accel_config().map(|cfg| simulate(image, &cfg, n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::preprocess;
    use crate::sparse::{gen, rng::Rng};

    #[test]
    fn table3_rows_are_faithful() {
        let k80 = Platform::K80.spec();
        assert_eq!((k80.tech_nm, k80.power_w as u32), (28, 130));
        let sx = Platform::Sextans.spec();
        assert_eq!((sx.freq_mhz as u32, sx.bandwidth_gbps as u32), (189, 460));
        let v100 = Platform::V100.spec();
        assert_eq!(v100.peak_gflops, 688.0);
        let sxp = Platform::SextansP.spec();
        assert_eq!((sxp.freq_mhz as u32, sxp.bandwidth_gbps as u32), (350, 900));
    }

    #[test]
    fn all_four_platforms_run_one_spmm() {
        let mut rng = Rng::new(1);
        let coo = gen::random_uniform(2048, 2048, 0.005, &mut rng);
        let cfg = AcceleratorConfig::sextans_u280();
        let image = preprocess(&coo, cfg.p(), cfg.k0, cfg.d);
        let stats = MatrixStats {
            m: coo.m,
            k: coo.k,
            nnz: coo.nnz(),
            max_row_nnz: coo.max_row_nnz(),
        };
        for p in ALL {
            let t = p.seconds(Some(&image), &stats, 64);
            assert!(t > 0.0 && t < 1.0, "{:?}: {t}", p);
        }
    }

    #[test]
    fn sextans_config_matches_spec() {
        for p in [Platform::Sextans, Platform::SextansP] {
            let cfg = p.accel_config().unwrap();
            let spec = p.spec();
            assert_eq!(cfg.freq_mhz, spec.freq_mhz);
            assert_eq!(cfg.hbm_gbps, spec.bandwidth_gbps);
            assert_eq!(cfg.power_w, spec.power_w);
        }
    }

    #[test]
    fn gpu_models_match_spec() {
        for p in [Platform::K80, Platform::V100] {
            let gpu = p.gpu_model().unwrap();
            let spec = p.spec();
            assert_eq!(gpu.peak_spmm_gflops, spec.peak_gflops);
            assert_eq!(gpu.mem_bw_gbps, spec.bandwidth_gbps);
            assert_eq!(gpu.power_w, spec.power_w);
        }
    }
}
