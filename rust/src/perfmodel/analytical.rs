//! Closed-form performance model, paper §3.6.1 (Eq. 6–10).
//!
//! ```text
//!   t_initC   = K/P                         (Eq. 6; the paper's notation —
//!                                            the C scratchpad holds M/P rows,
//!                                            see `cycles_init_c`)
//!   t_streamB = K0 / (2 F_B)                (Eq. 7)
//!   t_PE      = NNZ·K0 / (P·K)              (Eq. 8, per window average)
//!   t_compC   = M / F_C                     (Eq. 9)
//!   t         = (K/(2F_B) + NNZ/P + M/F_C) · N/N0     (Eq. 10)
//! ```
//!
//! Eq. 10 is the idealized lower bound: perfect balance, zero bubbles, no
//! fill/drain, no setup. The cycle-level simulator must never beat it by
//! more than its explicit overhead terms (asserted in simulator tests).

use crate::arch::AcceleratorConfig;

/// Eq. 6 — C-scratchpad initialization cycles. The paper prints `K/P`; the
/// scratchpad actually holds `M/P` rows per PE, and for the square matrices
/// of the evaluation the two coincide. We implement `M/P` and note the
/// discrepancy here.
pub fn cycles_init_c(cfg: &AcceleratorConfig, m: usize) -> u64 {
    (m as u64).div_ceil(cfg.p() as u64)
}

/// Eq. 7 — B window streaming cycles (on-chip port bound).
pub fn cycles_stream_b(cfg: &AcceleratorConfig) -> u64 {
    (cfg.k0 as u64).div_ceil(2 * cfg.f_b as u64)
}

/// Eq. 8 — average PE-region cycles per window.
pub fn cycles_pe_per_window(cfg: &AcceleratorConfig, k: usize, nnz: usize) -> u64 {
    let windows = (k as u64).div_ceil(cfg.k0 as u64).max(1);
    (nnz as u64).div_ceil(cfg.p() as u64 * windows)
}

/// Eq. 9 — Comp-C cycles per i-slice.
pub fn cycles_comp_c(cfg: &AcceleratorConfig, m: usize) -> u64 {
    (m as u64).div_ceil(cfg.f_c as u64)
}

/// Eq. 10 — total cycles for one SpMM.
pub fn cycles(cfg: &AcceleratorConfig, m: usize, k: usize, nnz: usize, n: usize) -> u64 {
    let slices = (n as u64).div_ceil(cfg.n0 as u64).max(1);
    let per_slice = (k as u64).div_ceil(2 * cfg.f_b as u64)
        + (nnz as u64).div_ceil(cfg.p() as u64)
        + (m as u64).div_ceil(cfg.f_c as u64);
    per_slice * slices
}

/// Eq. 10 in seconds at the config's clock.
pub fn seconds(cfg: &AcceleratorConfig, m: usize, k: usize, nnz: usize, n: usize) -> f64 {
    cfg.seconds(cycles(cfg, m, k, nnz, n))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AcceleratorConfig {
        AcceleratorConfig::sextans_u280()
    }

    #[test]
    fn eq10_is_sum_of_components_times_slices() {
        let (m, k, nnz, n) = (10_000, 20_000, 500_000, 64);
        let c = cfg();
        let per_slice = k as u64 / (2 * c.f_b as u64)
            + (nnz as u64).div_ceil(c.p() as u64)
            + (m as u64).div_ceil(c.f_c as u64);
        assert_eq!(cycles(&c, m, k, nnz, n), per_slice * 8);
    }

    #[test]
    fn paper_example_magnitudes() {
        // A 100k x 100k matrix with 1M nnz at N=512: Eq. 10 gives
        // (100000/8 + 1000000/64 + 100000/16) * 64 = (12500+15625+6250)*64.
        let c = cfg();
        assert_eq!(cycles(&c, 100_000, 100_000, 1_000_000, 512), 34_375 * 64);
    }

    #[test]
    fn component_equations() {
        let c = cfg();
        assert_eq!(cycles_init_c(&c, 640), 10);
        assert_eq!(cycles_stream_b(&c), 512); // 4096 / (2*4)
        assert_eq!(cycles_comp_c(&c, 160), 10);
        assert_eq!(cycles_pe_per_window(&c, 8192, 128_000), 1000);
    }

    #[test]
    fn n_rounds_up_to_slices() {
        let c = cfg();
        assert_eq!(
            cycles(&c, 1000, 1000, 10_000, 1),
            cycles(&c, 1000, 1000, 10_000, 8)
        );
        assert!(cycles(&c, 1000, 1000, 10_000, 9) > cycles(&c, 1000, 1000, 10_000, 8));
    }

    #[test]
    fn seconds_uses_frequency() {
        let c = cfg();
        let cyc = cycles(&c, 1000, 1000, 10_000, 8);
        assert!((seconds(&c, 1000, 1000, 10_000, 8) - cyc as f64 / 189e6).abs() < 1e-12);
    }
}
