//! Analytical GPU baseline models — cuSPARSE `csrmm` on K80 and V100.
//!
//! **Substitution note (DESIGN.md §1):** no GPUs exist in this environment;
//! the paper's comparison *shape* is driven by four published quantities we
//! encode directly: achieved SpMM peak (Table 3: 127.8 / 688.0 GFLOP/s),
//! memory bandwidth (480 / 900 GB/s), kernel-launch overhead (§2.4 measures
//! 0.15 ms per OpenCL launch; CUDA runtime launches are ~20–45 µs and the
//! paper attributes GPU losses below 10⁶ FLOP to them), and row-split load
//! imbalance (§2.2 / Fig. 1 — csrmm parallelizes over rows, so one heavy
//! row bounds a thread block).
//!
//! Model: `t = t_launch + max(t_compute, t_memory, t_hot_row)` — the same
//! stage-max streaming form the paper's own Sextans-P simulator uses.

use crate::arch::simulator::problem_flops;

/// Matrix statistics the GPU model consumes (cheap, O(nnz) once).
#[derive(Clone, Copy, Debug)]
pub struct MatrixStats {
    /// Rows.
    pub m: usize,
    /// Columns.
    pub k: usize,
    /// Non-zeros.
    pub nnz: usize,
    /// Max non-zeros in a single row (hot-row bound).
    pub max_row_nnz: usize,
}

impl MatrixStats {
    /// Mean non-zeros per row.
    pub fn mean_row_nnz(&self) -> f64 {
        self.nnz as f64 / self.m.max(1) as f64
    }
}

/// GPU platform model parameters.
#[derive(Clone, Debug)]
pub struct GpuModel {
    /// Display name.
    pub name: &'static str,
    /// Achieved SpMM compute roof in GFLOP/s (Table 3 "Peak Th." — already
    /// includes cuSPARSE's sparse inefficiency at saturation).
    pub peak_spmm_gflops: f64,
    /// Board memory bandwidth GB/s.
    pub mem_bw_gbps: f64,
    /// Effective fraction of bandwidth csrmm sustains on sparse streams
    /// (irregular B gathers through L2; calibrated so geomean speedups and
    /// bandwidth-utilization geomeans track Fig. 9).
    pub mem_efficiency: f64,
    /// CUDA runtime launch + sync overhead per SpMM, seconds.
    pub launch_s: f64,
    /// Streaming multiprocessor count (hot-row bound granularity).
    pub sms: usize,
    /// FLOP/s one SM sustains on a serial row accumulation.
    pub per_sm_gflops: f64,
    /// Half-saturation constant of the row-length efficiency curve
    /// len/(len + row_eff_half): K80's csr2-based csrmm degrades hard on
    /// short rows; V100's merge-path kernel much less so.
    pub row_eff_half: f64,
    /// C elements needed to saturate the GPU's thread pool: below this the
    /// compute roof scales down linearly (occupancy). This is what makes
    /// GPUs lose badly on small problems in the paper's Fig. 7/8 ("the two
    /// GPU platforms reach their peak throughput around 1e9 FLOP" while
    /// Sextans saturates at ~8e7).
    pub saturation_elems: f64,
    /// Board power, watts (Table 3).
    pub power_w: f64,
}

impl GpuModel {
    /// NVIDIA Tesla K80 (one GK210 die, as the paper measures).
    pub fn k80() -> Self {
        GpuModel {
            name: "K80",
            peak_spmm_gflops: 127.8,
            mem_bw_gbps: 480.0,
            mem_efficiency: 0.16,
            launch_s: 45e-6,
            sms: 13,
            // A hot row is serialized on one thread block: warp-reduction
            // rate, well under peak/SM.
            per_sm_gflops: 4.0,
            row_eff_half: 16.0,
            saturation_elems: 13.0 * 6144.0,
            power_w: 130.0,
        }
    }

    /// NVIDIA Tesla V100.
    pub fn v100() -> Self {
        GpuModel {
            name: "V100",
            peak_spmm_gflops: 688.0,
            mem_bw_gbps: 900.0,
            mem_efficiency: 0.34,
            launch_s: 20e-6,
            sms: 80,
            per_sm_gflops: 12.0,
            row_eff_half: 4.0,
            saturation_elems: 80.0 * 6144.0,
            power_w: 287.0,
        }
    }

    /// Bytes csrmm must move: CSR A (8 B/nnz + 4 B/row-ptr), B read once
    /// per column block with gather amplification folded into
    /// `mem_efficiency`, C read+write.
    pub fn traffic_bytes(&self, s: &MatrixStats, n: usize) -> u64 {
        let a = s.nnz as u64 * 8 + (s.m as u64 + 1) * 4;
        let b = (s.k * n * 4) as u64;
        let c = 2 * (s.m * n * 4) as u64;
        a + b + c
    }

    /// Occupancy factor: csrmm parallelizes over C elements (row × column
    /// tiles); small problems cannot fill the SMs.
    pub fn occupancy(&self, s: &MatrixStats, n: usize) -> f64 {
        ((s.m * n) as f64 / self.saturation_elems).min(1.0)
    }

    /// Row-length efficiency: csrmm's per-row reduction only approaches the
    /// achieved peak on long rows (short rows starve the warp of ILP and
    /// thrash the B gather). Saturating form len/(len + 16): ~0.6 at the
    /// 20-30 nnz/row typical of FEM matrices, ~1 on dense-ish rows — which
    /// is exactly why the *peak* in Table 3 comes from the densest inputs.
    pub fn row_efficiency(&self, s: &MatrixStats) -> f64 {
        let len = s.mean_row_nnz();
        len / (len + self.row_eff_half)
    }

    /// Execution time for one SpMM `C = αA×B + βC` with B width `n`.
    pub fn seconds(&self, s: &MatrixStats, n: usize) -> f64 {
        let flops = problem_flops(s.nnz, s.m, n) as f64;
        let eff = self.occupancy(s, n) * self.row_efficiency(s);
        let t_compute = flops / (self.peak_spmm_gflops * 1e9 * eff);
        let t_memory =
            self.traffic_bytes(s, n) as f64 / (self.mem_bw_gbps * 1e9 * self.mem_efficiency);
        // Row-split: the hottest row is serialized on one SM (2 FLOP per
        // nnz per column).
        let hot_row_flops = (s.max_row_nnz * n * 2) as f64;
        let t_hot_row = hot_row_flops / (self.per_sm_gflops * 1e9);
        self.launch_s + t_compute.max(t_memory).max(t_hot_row)
    }

    /// Achieved GFLOP/s.
    pub fn gflops(&self, s: &MatrixStats, n: usize) -> f64 {
        problem_flops(s.nnz, s.m, n) as f64 / self.seconds(s, n) / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(m: usize, k: usize, nnz: usize, max_row: usize) -> MatrixStats {
        MatrixStats { m, k, nnz, max_row_nnz: max_row }
    }

    #[test]
    fn v100_beats_k80_at_scale() {
        let s = stats(200_000, 200_000, 5_000_000, 60);
        let k80 = GpuModel::k80().seconds(&s, 512);
        let v100 = GpuModel::v100().seconds(&s, 512);
        assert!(v100 < k80 / 2.0, "v100 {v100} vs k80 {k80}");
    }

    #[test]
    fn launch_overhead_dominates_tiny_problems() {
        // Paper §4.2.1: below 1e6 FLOP the CUDA overhead degrades GPUs.
        let s = stats(100, 100, 500, 10);
        let m = GpuModel::v100();
        let t = m.seconds(&s, 8);
        assert!(t < m.launch_s * 2.0 && t >= m.launch_s);
        // Throughput far below peak.
        assert!(m.gflops(&s, 8) < 0.05 * m.peak_spmm_gflops);
    }

    #[test]
    fn throughput_saturates_below_peak() {
        let s = stats(500_000, 500_000, 20_000_000, 80);
        let m = GpuModel::k80();
        let g = m.gflops(&s, 512);
        assert!(g <= m.peak_spmm_gflops * 1.001);
        assert!(g > 0.3 * m.peak_spmm_gflops, "g = {g}");
    }

    #[test]
    fn hot_row_penalty_bites_powerlaw() {
        let balanced = stats(100_000, 100_000, 2_000_000, 40);
        let skewed = stats(100_000, 100_000, 2_000_000, 200_000);
        let m = GpuModel::k80();
        assert!(m.seconds(&skewed, 64) > 1.5 * m.seconds(&balanced, 64));
    }

    #[test]
    fn traffic_counts_all_three_matrices() {
        let s = stats(10, 20, 30, 5);
        let m = GpuModel::k80();
        let bytes = m.traffic_bytes(&s, 4);
        assert_eq!(bytes, 30 * 8 + 11 * 4 + 20 * 4 * 4 + 2 * 10 * 4 * 4);
    }
}
