//! Energy model (paper §4.2.4, Fig. 10): efficiency = p / (t · Power),
//! with platform powers from Table 3 (U280 measured by `xbutil`, GPUs by
//! `nvidia-smi`, Sextans-P projected by P = C·V²·f frequency scaling).

use super::platforms::Platform;

/// Energy consumed by one SpMM execution, joules.
pub fn energy_joules(platform: Platform, seconds: f64) -> f64 {
    seconds * platform.spec().power_w
}

/// Energy efficiency in FLOP/J (the paper's Fig. 10 Y-axis).
pub fn flop_per_joule(platform: Platform, flops: u64, seconds: f64) -> f64 {
    flops as f64 / energy_joules(platform, seconds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_is_time_times_power() {
        let e = energy_joules(Platform::Sextans, 2.0);
        assert!((e - 104.0).abs() < 1e-9); // 52 W * 2 s
    }

    #[test]
    fn efficiency_ordering_matches_power_ratio_at_equal_time() {
        // At equal runtime, Sextans (52 W) is 130/52 = 2.5x more efficient
        // than K80 per FLOP.
        let f = 1_000_000u64;
        let sx = flop_per_joule(Platform::Sextans, f, 1.0);
        let k80 = flop_per_joule(Platform::K80, f, 1.0);
        assert!((sx / k80 - 130.0 / 52.0).abs() < 1e-9);
    }

    #[test]
    fn sextans_p_power_projection() {
        // §4.1: measured 52 W scaled by frequency increase to 96 W.
        assert_eq!(Platform::SextansP.spec().power_w, 96.0);
    }
}
