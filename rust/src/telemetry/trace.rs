//! Per-request span tracing: monotonic timestamps, parent/child span ids,
//! and a pluggable [`TelemetrySink`].
//!
//! One served request yields one *span tree*: a root `request` span with a
//! leaf per pipeline stage (`queue`, `batch`, `prepare`, `exec`) plus an
//! `admission` span at submit time and a `backend.prepare` child under
//! `prepare` when the residency layer actually builds a handle. The leaf
//! spans are stamped from the **same** `Instant`s the coordinator uses for
//! [`RequestTiming`], so a tree's stage durations reconcile exactly with
//! the recorded timing (pinned by `tests/integration_telemetry.rs`).
//!
//! Timestamps are nanoseconds since a process-local monotonic epoch (the
//! first time any telemetry clock is read) — comparable within a process,
//! meaningless across processes; the `BENCH_*.json` trajectory carries
//! wall-clock context instead. Span and trace ids come from process-wide
//! atomic counters, so concurrent requests interleave without collisions.
//!
//! Sinks receive completed [`SpanRecord`]s only (no start events): every
//! emit site measures first, then reports, keeping the hot path to one
//! `Mutex` push in the bundled [`TraceCollector`]. A sink must be cheap
//! and must not block — it runs inside the batcher and worker loops.
//!
//! [`RequestTiming`]: crate::coordinator::metrics::RequestTiming

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use super::json::{self, Value};

/// Process-local monotonic epoch: fixed the first time any span timestamp
/// is taken, so all spans in a process share one time base.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds from the process epoch to `t`. Saturates to 0 for instants
/// taken before the epoch was initialized (possible when the first spans
/// race), keeping timestamps monotone rather than panicking.
pub fn instant_ns(t: Instant) -> u64 {
    t.checked_duration_since(epoch()).map(|d| d.as_nanos() as u64).unwrap_or(0)
}

/// Nanoseconds from the process epoch to now.
pub fn now_ns() -> u64 {
    instant_ns(Instant::now())
}

/// Allocate a fresh trace id (one per request).
pub fn next_trace_id() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// Allocate a fresh span id (unique within the process, not per trace, so
/// emit sites never need coordination).
pub fn next_span_id() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

thread_local! {
    /// The (trace id, span id) a deeper layer should parent its child
    /// spans under — set around an execution by the dispatch stage so
    /// engine internals (e.g. the remote backend's per-RPC `net.rpc`
    /// spans) land inside the request's `exec` span without the trace
    /// context being threaded through every `execute` signature.
    static CURRENT_SPAN: std::cell::Cell<Option<(u64, u64)>> =
        const { std::cell::Cell::new(None) };
}

/// Set the current span context for this thread; restored to the previous
/// value when the returned guard drops. Note the context is thread-local:
/// an engine that fans out to scoped threads must capture
/// [`current_span_context`] *before* spawning and pass it into the
/// closures.
pub fn push_span_context(trace_id: u64, span_id: u64) -> SpanContextGuard {
    let prev = CURRENT_SPAN.with(|c| c.replace(Some((trace_id, span_id))));
    SpanContextGuard { prev }
}

/// The (trace id, span id) deeper layers should parent under, if an
/// enclosing stage published one via [`push_span_context`].
pub fn current_span_context() -> Option<(u64, u64)> {
    CURRENT_SPAN.with(|c| c.get())
}

/// RAII guard from [`push_span_context`]: restores the previous context
/// (usually `None`) on drop, so nested pushes compose.
pub struct SpanContextGuard {
    prev: Option<(u64, u64)>,
}

impl Drop for SpanContextGuard {
    fn drop(&mut self) {
        CURRENT_SPAN.with(|c| c.set(self.prev));
    }
}

/// One completed span: a named interval inside a request's trace.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    /// The request this span belongs to.
    pub trace_id: u64,
    /// Unique id of this span.
    pub span_id: u64,
    /// Parent span id; `None` marks the trace root.
    pub parent_id: Option<u64>,
    /// Stage name: `request`, `admission`, `queue`, `batch`, `prepare`,
    /// `backend.prepare`, `exec`, ...
    pub name: &'static str,
    /// Start, nanoseconds since the process epoch.
    pub start_ns: u64,
    /// End, nanoseconds since the process epoch (`>= start_ns`).
    pub end_ns: u64,
    /// Free-form annotations (backend name, admission outcome, ...).
    pub tags: Vec<(&'static str, String)>,
}

impl SpanRecord {
    /// Span duration in nanoseconds.
    pub fn duration_ns(&self) -> u64 {
        self.end_ns.saturating_sub(self.start_ns)
    }

    /// Build a record from the `Instant`s an emit site already holds.
    pub fn from_instants(
        trace_id: u64,
        parent_id: Option<u64>,
        name: &'static str,
        start: Instant,
        end: Instant,
    ) -> SpanRecord {
        let start_ns = instant_ns(start);
        SpanRecord {
            trace_id,
            span_id: next_span_id(),
            parent_id,
            name,
            start_ns,
            end_ns: instant_ns(end).max(start_ns),
            tags: Vec::new(),
        }
    }

    /// Attach a tag, builder-style.
    pub fn tag(mut self, key: &'static str, value: impl Into<String>) -> SpanRecord {
        self.tags.push((key, value.into()));
        self
    }

    /// Serialize as a JSON object.
    pub fn to_value(&self) -> Value {
        let mut fields = vec![
            ("trace_id", json::num(self.trace_id as f64)),
            ("span_id", json::num(self.span_id as f64)),
        ];
        if let Some(p) = self.parent_id {
            fields.push(("parent_id", json::num(p as f64)));
        }
        fields.push(("name", json::s(self.name)));
        fields.push(("start_ns", json::num(self.start_ns as f64)));
        fields.push(("end_ns", json::num(self.end_ns as f64)));
        if !self.tags.is_empty() {
            fields.push((
                "tags",
                Value::Obj(
                    self.tags.iter().map(|(k, v)| (k.to_string(), json::s(v.clone()))).collect(),
                ),
            ));
        }
        json::obj(fields)
    }
}

/// Receiver for completed spans. Implementations must be cheap and
/// non-blocking — emit sites sit inside the batcher and worker loops.
pub trait TelemetrySink: Send + Sync {
    /// Accept one completed span.
    fn emit(&self, span: SpanRecord);
}

/// The bundled sink: collects every span in memory for later inspection,
/// tree assembly, or JSON export. Suitable for tests, `sextans trace`, and
/// `serve --trace-json`; a long-running deployment would swap in a
/// bounded/exporting sink.
#[derive(Debug, Default)]
pub struct TraceCollector {
    spans: Mutex<Vec<SpanRecord>>,
}

impl TraceCollector {
    pub fn new() -> TraceCollector {
        TraceCollector::default()
    }

    /// All spans emitted so far, in emit order.
    pub fn spans(&self) -> Vec<SpanRecord> {
        self.spans.lock().unwrap().clone()
    }

    /// Spans of one trace, in emit order.
    pub fn trace(&self, trace_id: u64) -> Vec<SpanRecord> {
        self.spans.lock().unwrap().iter().filter(|s| s.trace_id == trace_id).cloned().collect()
    }

    /// Distinct trace ids seen, ascending.
    pub fn trace_ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> =
            self.spans.lock().unwrap().iter().map(|s| s.trace_id).collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    /// Serialize every span as a JSON array (the `serve --trace-json`
    /// payload).
    pub fn to_value(&self) -> Value {
        Value::Arr(self.spans.lock().unwrap().iter().map(SpanRecord::to_value).collect())
    }
}

impl TelemetrySink for TraceCollector {
    fn emit(&self, span: SpanRecord) {
        self.spans.lock().unwrap().push(span);
    }
}

/// One node of an assembled span tree.
#[derive(Debug)]
pub struct SpanNode {
    pub span: SpanRecord,
    pub children: Vec<SpanNode>,
}

impl SpanNode {
    /// Sum of this node's leaf durations (a node with children reports
    /// its children's leaves, not its own interval).
    pub fn leaf_duration_ns(&self) -> u64 {
        if self.children.is_empty() {
            self.span.duration_ns()
        } else {
            self.children.iter().map(SpanNode::leaf_duration_ns).sum()
        }
    }
}

/// Assemble one trace's spans into root trees. Children are ordered by
/// start time; spans whose parent is missing from the slice are promoted
/// to roots so a partial trace still renders.
pub fn build_tree(spans: &[SpanRecord]) -> Vec<SpanNode> {
    let ids: std::collections::HashSet<u64> = spans.iter().map(|s| s.span_id).collect();
    let mut by_parent: std::collections::HashMap<u64, Vec<&SpanRecord>> =
        std::collections::HashMap::new();
    let mut roots: Vec<&SpanRecord> = Vec::new();
    for s in spans {
        match s.parent_id {
            Some(p) if ids.contains(&p) => by_parent.entry(p).or_default().push(s),
            _ => roots.push(s),
        }
    }
    fn attach(
        s: &SpanRecord,
        by_parent: &std::collections::HashMap<u64, Vec<&SpanRecord>>,
    ) -> SpanNode {
        let mut children: Vec<SpanNode> = by_parent
            .get(&s.span_id)
            .map(|kids| kids.iter().map(|k| attach(k, by_parent)).collect())
            .unwrap_or_default();
        children.sort_by_key(|n| n.span.start_ns);
        SpanNode { span: s.clone(), children }
    }
    roots.sort_by_key(|s| s.start_ns);
    roots.iter().map(|r| attach(r, &by_parent)).collect()
}

/// Pretty-print span trees, one line per span with indentation, duration,
/// and tags — the `sextans trace` output.
pub fn render_tree(roots: &[SpanNode]) -> String {
    fn fmt_dur(ns: u64) -> String {
        if ns >= 1_000_000_000 {
            format!("{:.3}s", ns as f64 / 1e9)
        } else if ns >= 1_000_000 {
            format!("{:.3}ms", ns as f64 / 1e6)
        } else {
            format!("{:.1}us", ns as f64 / 1e3)
        }
    }
    fn walk(node: &SpanNode, depth: usize, out: &mut String) {
        out.push_str(&"  ".repeat(depth));
        out.push_str(&format!(
            "{:<16} {:>10}  [{} .. {}]",
            node.span.name,
            fmt_dur(node.span.duration_ns()),
            node.span.start_ns,
            node.span.end_ns
        ));
        for (k, v) in &node.span.tags {
            out.push_str(&format!("  {k}={v}"));
        }
        out.push('\n');
        for child in &node.children {
            walk(child, depth + 1, out);
        }
    }
    let mut out = String::new();
    for root in roots {
        walk(root, 0, &mut out);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    fn span(trace: u64, id_hint: &'static str, parent: Option<u64>) -> SpanRecord {
        let start = Instant::now();
        SpanRecord::from_instants(trace, parent, id_hint, start, start + Duration::from_micros(5))
    }

    #[test]
    fn ids_are_unique_across_threads() {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                std::thread::spawn(|| (0..500).map(|_| next_span_id()).collect::<Vec<u64>>())
            })
            .collect();
        let mut all: Vec<u64> =
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n, "span ids collided");
    }

    #[test]
    fn timestamps_are_monotone_and_ordered() {
        let a = now_ns();
        std::thread::sleep(Duration::from_millis(1));
        let b = now_ns();
        assert!(b > a);
        let s = span(1, "x", None);
        assert!(s.end_ns >= s.start_ns);
        assert!(s.duration_ns() >= 4_000, "5us span measured {}ns", s.duration_ns());
    }

    #[test]
    fn collector_filters_by_trace() {
        let sink = TraceCollector::new();
        sink.emit(span(1, "a", None));
        sink.emit(span(2, "b", None));
        sink.emit(span(1, "c", None));
        assert_eq!(sink.spans().len(), 3);
        assert_eq!(sink.trace(1).len(), 2);
        assert_eq!(sink.trace(2).len(), 1);
        assert_eq!(sink.trace_ids(), vec![1, 2]);
    }

    #[test]
    fn tree_assembly_nests_children_under_parents() {
        let root = span(7, "request", None);
        let queue = span(7, "queue", Some(root.span_id));
        let prepare = span(7, "prepare", Some(root.span_id));
        let build = span(7, "backend.prepare", Some(prepare.span_id));
        let spans = vec![queue.clone(), build.clone(), root.clone(), prepare.clone()];
        let trees = build_tree(&spans);
        assert_eq!(trees.len(), 1);
        let t = &trees[0];
        assert_eq!(t.span.name, "request");
        assert_eq!(t.children.len(), 2);
        let prep = t.children.iter().find(|c| c.span.name == "prepare").unwrap();
        assert_eq!(prep.children.len(), 1);
        assert_eq!(prep.children[0].span.name, "backend.prepare");
        // Leaf duration of the tree sums queue + backend.prepare (prepare
        // has a child, so its own interval is not double-counted).
        let want = queue.duration_ns() + build.duration_ns();
        assert_eq!(t.leaf_duration_ns(), want);
    }

    #[test]
    fn orphan_spans_are_promoted_to_roots() {
        let s = span(3, "exec", Some(999_999_999));
        let trees = build_tree(&[s]);
        assert_eq!(trees.len(), 1);
        assert_eq!(trees[0].span.name, "exec");
    }

    #[test]
    fn render_shows_names_durations_and_tags() {
        let root = span(5, "request", None).tag("backend", "native");
        let child = span(5, "exec", Some(root.span_id));
        let text = render_tree(&build_tree(&[root, child]));
        assert!(text.contains("request"), "{text}");
        assert!(text.contains("  exec"), "{text}");
        assert!(text.contains("backend=native"), "{text}");
    }

    #[test]
    fn span_json_round_trips() {
        let s = span(9, "prepare", Some(4)).tag("backend", "native:2");
        let v = s.to_value();
        let parsed = super::super::json::parse(&v.to_json_pretty()).unwrap();
        assert_eq!(parsed.get("trace_id").and_then(Value::as_u64), Some(9));
        assert_eq!(parsed.get("parent_id").and_then(Value::as_u64), Some(4));
        assert_eq!(parsed.get("name").and_then(Value::as_str), Some("prepare"));
        assert_eq!(
            parsed.get("tags").and_then(|t| t.get("backend")).and_then(Value::as_str),
            Some("native:2")
        );
        assert_eq!(
            parsed.get("end_ns").and_then(Value::as_u64),
            Some(s.end_ns),
            "nanosecond timestamps survive the f64 JSON number path"
        );
    }

    #[test]
    fn span_context_nests_and_restores() {
        assert_eq!(current_span_context(), None);
        {
            let _outer = push_span_context(7, 100);
            assert_eq!(current_span_context(), Some((7, 100)));
            {
                let _inner = push_span_context(7, 200);
                assert_eq!(current_span_context(), Some((7, 200)));
            }
            assert_eq!(current_span_context(), Some((7, 100)), "inner pop restores outer");
        }
        assert_eq!(current_span_context(), None, "outer pop restores None");
        // The context is per-thread: a fresh thread starts clean.
        let _guard = push_span_context(9, 1);
        std::thread::spawn(|| assert_eq!(current_span_context(), None))
            .join()
            .unwrap();
    }

    #[test]
    fn sink_trait_object_is_shareable() {
        let sink: Arc<dyn TelemetrySink> = Arc::new(TraceCollector::new());
        let clone = Arc::clone(&sink);
        let t = std::thread::spawn(move || clone.emit(span(1, "a", None)));
        sink.emit(span(1, "b", None));
        t.join().unwrap();
    }
}
