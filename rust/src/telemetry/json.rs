//! Hand-rolled JSON: a tree [`Value`], a deterministic writer, and a
//! recursive-descent parser.
//!
//! The build is offline (no crates beyond the vendored `anyhow`), so the
//! telemetry subsystem carries its own JSON layer instead of depending on
//! `serde`. Scope is exactly what the telemetry formats need: objects keep
//! insertion order (stable diffs for committed `BENCH_*.json` files),
//! numbers are `f64` (every field we persist fits — counts, nanoseconds,
//! GFLOP/s), strings escape the JSON control set, and the parser accepts
//! anything the writer emits plus ordinary hand-edited whitespace. Round-
//! tripping is pinned by tests here and in `tests/integration_telemetry.rs`.

use std::fmt::Write as _;

/// A JSON value. Objects preserve insertion order so emitted files diff
/// cleanly across regenerations.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    /// All JSON numbers, parsed as `f64`. Integers up to 2^53 round-trip
    /// exactly — nanosecond timestamps and byte counts stay within that.
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Key/value pairs in insertion order (no deduplication; the writer
    /// never emits duplicates).
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The number, if this is one.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The number as u64 (must be a non-negative integer within 2^53).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 9e15 => Some(*n as u64),
            _ => None,
        }
    }

    /// The string, if this is one.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Serialize compactly (no whitespace).
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation — the format committed
    /// `BENCH_*.json` files use.
    pub fn to_json_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => write_num(out, *n),
            Value::Str(s) => write_str(out, s),
            Value::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline(out, indent, depth);
                out.push(']');
            }
            Value::Obj(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no NaN/Inf; null is the conventional stand-in.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Errors carry the byte offset of the problem.
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

/// A parse failure: what went wrong and where.
#[derive(Debug, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, message: msg.into() }
    }

    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => self.string().map(Value::Str),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Value::Num).map_err(|_| self.err("malformed number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("non-ascii \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogates are not paired up — the writer
                            // never emits them (it escapes only < 0x20).
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input is &str, so valid).
                    let rest = std::str::from_utf8(&self.bytes[self.pos..]).unwrap();
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Builder shorthand: an object from (key, value) pairs.
pub fn obj(fields: Vec<(&str, Value)>) -> Value {
    Value::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Builder shorthand: a string value.
pub fn s(v: impl Into<String>) -> Value {
    Value::Str(v.into())
}

/// Builder shorthand: a numeric value.
pub fn num(v: f64) -> Value {
    Value::Num(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_round_trips() {
        for (text, want) in [
            ("null", Value::Null),
            ("true", Value::Bool(true)),
            ("false", Value::Bool(false)),
            ("42", Value::Num(42.0)),
            ("-3.5", Value::Num(-3.5)),
            ("1e3", Value::Num(1000.0)),
            ("\"hi\"", Value::Str("hi".into())),
        ] {
            assert_eq!(parse(text).unwrap(), want, "{text}");
        }
    }

    #[test]
    fn nested_document_round_trips_compact_and_pretty() {
        let v = obj(vec![
            ("name", s("bench")),
            ("gflops", num(12.75)),
            ("tags", Value::Arr(vec![s("a"), s("b")])),
            ("inner", obj(vec![("count", num(3.0)), ("ok", Value::Bool(true))])),
            ("none", Value::Null),
        ]);
        for text in [v.to_json(), v.to_json_pretty()] {
            assert_eq!(parse(&text).unwrap(), v, "{text}");
        }
    }

    #[test]
    fn object_order_is_preserved() {
        let v = parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        match &v {
            Value::Obj(fields) => {
                let keys: Vec<&str> = fields.iter().map(|(k, _)| k.as_str()).collect();
                assert_eq!(keys, vec!["z", "a", "m"]);
            }
            other => panic!("not an object: {other:?}"),
        }
        assert_eq!(v.to_json(), r#"{"z":1,"a":2,"m":3}"#);
    }

    #[test]
    fn strings_escape_and_unescape() {
        let v = Value::Str("a\"b\\c\nd\te\u{1}".into());
        let text = v.to_json();
        assert_eq!(text, r#""a\"b\\c\nd\te""#);
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn integers_emit_without_decimal_point() {
        assert_eq!(Value::Num(1_000_000.0).to_json(), "1000000");
        assert_eq!(Value::Num(0.5).to_json(), "0.5");
    }

    #[test]
    fn malformed_documents_report_offsets() {
        for text in ["{", "[1,", "\"open", "{\"a\" 1}", "12 34", "{,}", "nul"] {
            let err = parse(text).unwrap_err();
            assert!(err.offset <= text.len(), "{text}: {err}");
        }
    }

    #[test]
    fn accessors_navigate_structure() {
        let v = parse(r#"{"a": {"b": [1, "x"]}, "n": 7}"#).unwrap();
        assert_eq!(v.get("n").and_then(Value::as_u64), Some(7));
        let arr = v.get("a").and_then(|a| a.get("b")).and_then(Value::as_arr).unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[1].as_str(), Some("x"));
        assert!(v.get("missing").is_none());
    }
}
