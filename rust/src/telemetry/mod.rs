//! End-to-end observability: span traces, streaming histograms, and the
//! persisted `BENCH_*.json` perf trajectory.
//!
//! The paper's claims are throughput numbers; this subsystem is how the
//! repo keeps its own numbers honest. Three layers, all dependency-free
//! (the build is offline — JSON is hand-rolled in [`json`], clocks are
//! `std::time`):
//!
//! * [`trace`] — per-request span trees. The serving pipeline emits one
//!   span per stage through a [`TelemetrySink`] configured on
//!   `PipelineConfig`; leaf durations reconcile exactly with the
//!   coordinator's `RequestTiming` because both are stamped from the same
//!   `Instant`s. Surfaced by `sextans trace` and `serve --trace-json`.
//! * [`histogram`] — fixed-memory log-bucketed latency histograms
//!   (± 2.2% relative quantile error) that replaced the recorder's
//!   unbounded timing `Vec`, giving per-stage / per-backend p50/p95/p99
//!   in `Summary` no matter how long the server runs.
//! * [`bench_record`] — the `BENCH_<name>.json` snapshot schema (git rev,
//!   catalog params, GFLOP/s, percentiles, scaling efficiency) written by
//!   the benches and `sextans bench`, plus [`compare`] for regression
//!   flagging. The committed repo-root baseline is the start of the
//!   trajectory each PR appends to.

pub mod bench_record;
pub mod histogram;
pub mod json;
pub mod trace;

pub use bench_record::{compare, BenchMeasurement, BenchRecord, Regression, ScalingPoint};
pub use histogram::{Histogram, Percentiles};
pub use trace::{
    build_tree, current_span_context, push_span_context, render_tree, SpanContextGuard,
    SpanNode, SpanRecord, TelemetrySink, TraceCollector,
};
