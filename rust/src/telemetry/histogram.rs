//! Fixed-memory streaming latency histogram with log-spaced buckets.
//!
//! The serving recorder used to keep every [`RequestTiming`] in an
//! unbounded `Vec` and sort it at summary time — O(requests) memory held
//! for the lifetime of the server. This histogram replaces that with a
//! fixed ~4 KB footprint: buckets grow geometrically by `2^(1/16)`
//! (≈ 4.4% per bucket), so any quantile is recovered to within ± 2.2%
//! relative error (half a bucket width, geometric), independent of how
//! many samples streamed through. Exact `count`, `sum`, `min`, and `max`
//! are tracked on the side, and quantile estimates are clamped to the
//! observed `[min, max]` so the tails never report a value outside what
//! was actually seen.
//!
//! The quantile rank convention matches the exact-sort implementation it
//! replaces (`idx = round((n-1) * q)`, nearest-rank on the sorted
//! samples), so summaries stay comparable across the transition.
//!
//! [`RequestTiming`]: crate::coordinator::metrics::RequestTiming

/// Geometric bucket growth factor: `2^(1/16)`.
const GROWTH: f64 = 1.044_273_782_427_413_8;
/// Natural log of [`GROWTH`] (ln 2 / 16).
const LN_GROWTH: f64 = std::f64::consts::LN_2 / 16.0;
/// Lower edge of the first regular bucket: 100 ns in seconds.
const MIN_EDGE: f64 = 1e-7;
/// Regular bucket count: spans 100 ns .. ~3400 s (`MIN_EDGE * GROWTH^N`),
/// comfortably past any single-request latency this stack can produce.
const BUCKETS: usize = 560;

/// Streaming histogram over non-negative `f64` samples (seconds, by
/// convention, but any unit works). Fixed memory; ± 2.2% relative
/// quantile error.
#[derive(Clone)]
pub struct Histogram {
    /// `counts[0]` is the underflow bucket (`< MIN_EDGE`), `counts[1..=BUCKETS]`
    /// are the regular log-spaced buckets, `counts[BUCKETS + 1]` is overflow.
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Histogram")
            .field("count", &self.count)
            .field("sum", &self.sum)
            .field("min", &self.min)
            .field("max", &self.max)
            .finish()
    }
}

impl Histogram {
    /// An empty histogram. Allocation happens once, here.
    pub fn new() -> Histogram {
        Histogram {
            counts: vec![0; BUCKETS + 2],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn bucket_of(v: f64) -> usize {
        if v < MIN_EDGE {
            return 0;
        }
        let idx = ((v / MIN_EDGE).ln() / LN_GROWTH).floor() as isize;
        (idx.max(0) as usize + 1).min(BUCKETS + 1)
    }

    /// Record one sample. Negative and non-finite samples are clamped to 0
    /// (they land in the underflow bucket).
    pub fn record(&mut self, v: f64) {
        let v = if v.is_finite() && v > 0.0 { v } else { 0.0 };
        self.counts[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Number of samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact sum of all samples.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact mean, or 0 when empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.sum / self.count as f64 }
    }

    /// Exact smallest sample, or 0 when empty.
    pub fn min(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.min }
    }

    /// Exact largest sample, or 0 when empty.
    pub fn max(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.max }
    }

    /// Estimate the `q`-quantile (`q` in `[0, 1]`), nearest-rank with the
    /// same rounding as the exact-sort path this histogram replaced:
    /// the returned value approximates sorted-sample index
    /// `round((count - 1) * q)`. Returns 0 when empty. The estimate is the
    /// geometric midpoint of the bucket holding that rank, clamped to the
    /// exact observed `[min, max]`.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((self.count - 1) as f64 * q.clamp(0.0, 1.0)).round() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            seen += c;
            if seen > rank {
                return self.representative(i);
            }
        }
        self.max()
    }

    /// A point estimate for bucket `i`: the geometric midpoint of its
    /// edges, clamped to the observed extrema (so single-bucket and tail
    /// estimates cannot leave the sampled range).
    fn representative(&self, i: usize) -> f64 {
        let v = if i == 0 {
            // Underflow: everything below 100 ns — call it the midpoint
            // to zero.
            MIN_EDGE / 2.0
        } else if i >= BUCKETS + 1 {
            self.max
        } else {
            let lo = MIN_EDGE * ((i - 1) as f64 * LN_GROWTH).exp();
            lo * GROWTH.sqrt()
        };
        v.clamp(self.min, self.max)
    }

    /// p50 / p95 / p99 in one call.
    pub fn percentiles(&self) -> Percentiles {
        Percentiles {
            p50: self.quantile(0.50),
            p95: self.quantile(0.95),
            p99: self.quantile(0.99),
        }
    }
}

/// The three standard latency percentiles, in the histogram's sample unit
/// (seconds for every histogram in this crate).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Percentiles {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::rng::Rng;

    /// The exact nearest-rank quantile the histogram approximates.
    fn exact_quantile(samples: &mut [f64], q: f64) -> f64 {
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((samples.len() - 1) as f64 * q).round() as usize;
        samples[idx]
    }

    #[test]
    fn empty_histogram_reports_zeroes() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum(), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.percentiles(), Percentiles::default());
    }

    #[test]
    fn count_sum_min_max_are_exact() {
        let mut h = Histogram::new();
        for v in [0.003, 0.001, 0.25, 0.007] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 0.261).abs() < 1e-12);
        assert_eq!(h.min(), 0.001);
        assert_eq!(h.max(), 0.25);
        assert!((h.mean() - 0.261 / 4.0).abs() < 1e-12);
    }

    #[test]
    fn single_sample_quantiles_are_that_sample() {
        let mut h = Histogram::new();
        h.record(0.0042);
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(h.quantile(q), 0.0042, "q={q}");
        }
    }

    #[test]
    fn quantiles_match_exact_sort_within_bucket_error_uniform() {
        let mut rng = Rng::new(11);
        // Latencies spread over 4 decades: 100 µs .. 1 s.
        let mut samples: Vec<f64> =
            (0..5000).map(|_| 1e-4 * 10f64.powf(4.0 * rng.f64())).collect();
        let mut h = Histogram::new();
        for &v in &samples {
            h.record(v);
        }
        for q in [0.01, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0] {
            let exact = exact_quantile(&mut samples, q);
            let est = h.quantile(q);
            let rel = (est - exact).abs() / exact;
            assert!(rel < 0.045, "q={q}: exact {exact} vs est {est} (rel {rel})");
        }
    }

    #[test]
    fn quantiles_match_exact_sort_on_skewed_samples() {
        let mut rng = Rng::new(7);
        // Heavy-tailed: mostly ~1 ms with a 100x tail, like a latency trace
        // with occasional cold prepares.
        let mut samples: Vec<f64> = (0..2000)
            .map(|i| {
                let base = 1e-3 * (1.0 + rng.f64());
                if i % 50 == 0 { base * 100.0 } else { base }
            })
            .collect();
        let mut h = Histogram::new();
        for &v in &samples {
            h.record(v);
        }
        for q in [0.5, 0.95, 0.99] {
            let exact = exact_quantile(&mut samples, q);
            let est = h.quantile(q);
            let rel = (est - exact).abs() / exact;
            assert!(rel < 0.045, "q={q}: exact {exact} vs est {est} (rel {rel})");
        }
    }

    #[test]
    fn out_of_range_samples_clamp_not_panic() {
        let mut h = Histogram::new();
        h.record(1e-9); // below first edge -> underflow bucket
        h.record(1e6); // beyond last edge -> overflow bucket
        h.record(-3.0); // negative -> clamped to 0
        h.record(f64::NAN); // non-finite -> clamped to 0
        assert_eq!(h.count(), 4);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 1e6);
        // Quantiles stay inside the observed range.
        for q in [0.0, 0.5, 1.0] {
            let v = h.quantile(q);
            assert!((0.0..=1e6).contains(&v), "q={q}: {v}");
        }
        assert_eq!(h.quantile(1.0), 1e6, "overflow estimate is the exact max");
    }

    #[test]
    fn rank_rounding_matches_replaced_sort_path() {
        // The recorder's historical fixture: 1..9 ms plus one 100 ms
        // outlier. Exact sort gives p50 = 6 ms (rank round(4.5) = 5) and
        // p99 = 100 ms; the histogram must land within bucket error.
        let mut h = Histogram::new();
        for ms in 1..=9 {
            h.record(ms as f64 * 1e-3);
        }
        h.record(0.1);
        let p = h.percentiles();
        assert!((p.p50 - 0.006).abs() / 0.006 < 0.045, "p50 {}", p.p50);
        assert!((p.p99 - 0.1).abs() / 0.1 < 0.045, "p99 {}", p.p99);
    }
}
