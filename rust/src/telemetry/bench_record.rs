//! The persisted perf trajectory: `BENCH_<name>.json` snapshots.
//!
//! The paper's evaluation is a ranked throughput table (PAPER.md §6:
//! 1,400 SpMMs by GFLOP/s); this module gives the repo the machine-readable
//! equivalent so the trajectory survives across PRs. Every snapshot records
//! enough to re-run it (git rev, matrix catalog parameters, thread count)
//! plus the measurements (GFLOP/s, latency percentiles, scaling
//! efficiency). `bench_backend`/`bench_concurrency`/`bench_prepare` and the
//! `sextans bench` subcommand all emit this schema; [`compare`] flags
//! regressions between two snapshots, and CI validates a smoke-sized file
//! every run (the full sweep stays manual).
//!
//! Schema (all JSON, written pretty for diffable commits):
//!
//! ```json
//! {
//!   "schema": 1,
//!   "name": "baseline",
//!   "git_rev": "b487bad...",
//!   "timestamp": "2026-08-08",
//!   "host_threads": 8,
//!   "matrices": [ {"name", "family", "m", "k", "nnz", "seed"} ],
//!   "results":  [ {"bench", "matrix", "n", "gflops", "median_ns",
//!                  "p50_ns", "p95_ns", "p99_ns"} ],
//!   "scaling":  [ {"bench", "workers", "gflops", "efficiency"} ]
//! }
//! ```
//!
//! `timestamp` is a caller-supplied string (the build is offline and the
//! harness avoids ambient wall-clock reads — pass `--timestamp` to the CLI
//! or set `BENCH_TIMESTAMP` for the benches; unset, it records `unknown`).

use std::path::Path;

use super::json::{self, Value};
use crate::sparse::catalog::{Family, MatrixSpec};

/// Current schema version, bumped on breaking layout changes.
pub const SCHEMA_VERSION: u64 = 1;

/// One `BENCH_*.json` snapshot.
#[derive(Clone, Debug, Default)]
pub struct BenchRecord {
    /// Snapshot name; the file is conventionally `BENCH_<name>.json`.
    pub name: String,
    /// Git revision the numbers were taken at.
    pub git_rev: String,
    /// Caller-supplied timestamp string.
    pub timestamp: String,
    /// `available_parallelism` on the measuring host.
    pub host_threads: usize,
    /// Catalog parameters of every matrix measured (re-buildable via
    /// [`MatrixSpec::build`]).
    pub matrices: Vec<MatrixSpec>,
    /// Throughput/latency measurements.
    pub results: Vec<BenchMeasurement>,
    /// Concurrency scaling points.
    pub scaling: Vec<ScalingPoint>,
}

/// One throughput measurement: a (bench, matrix, N) cell.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchMeasurement {
    /// Which bench produced it (`backend/native:4`, `prepare/sharded`, ...).
    pub bench: String,
    /// Catalog name of the matrix.
    pub matrix: String,
    /// Dense column count.
    pub n: usize,
    /// Sustained throughput.
    pub gflops: f64,
    /// Median iteration latency, nanoseconds.
    pub median_ns: f64,
    /// Iteration latency percentiles, nanoseconds.
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
}

/// One concurrency scaling point: aggregate throughput at `workers`
/// concurrent callers, and efficiency relative to `workers` × the
/// single-caller rate (1.0 = perfect scaling).
#[derive(Clone, Debug, PartialEq)]
pub struct ScalingPoint {
    pub bench: String,
    pub workers: usize,
    pub gflops: f64,
    pub efficiency: f64,
}

fn family_name(f: Family) -> &'static str {
    match f {
        Family::SnapRmat => "snap_rmat",
        Family::SsBanded => "ss_banded",
        Family::SsCircuit => "ss_circuit",
        Family::SsUniform => "ss_uniform",
        Family::SsBlock => "ss_block",
        Family::SsPowerRows => "ss_power_rows",
    }
}

fn family_from(name: &str) -> Option<Family> {
    Some(match name {
        "snap_rmat" => Family::SnapRmat,
        "ss_banded" => Family::SsBanded,
        "ss_circuit" => Family::SsCircuit,
        "ss_uniform" => Family::SsUniform,
        "ss_block" => Family::SsBlock,
        "ss_power_rows" => Family::SsPowerRows,
        _ => return None,
    })
}

impl BenchRecord {
    /// Serialize to the schema above.
    pub fn to_value(&self) -> Value {
        json::obj(vec![
            ("schema", json::num(SCHEMA_VERSION as f64)),
            ("name", json::s(self.name.clone())),
            ("git_rev", json::s(self.git_rev.clone())),
            ("timestamp", json::s(self.timestamp.clone())),
            ("host_threads", json::num(self.host_threads as f64)),
            (
                "matrices",
                Value::Arr(
                    self.matrices
                        .iter()
                        .map(|m| {
                            json::obj(vec![
                                ("name", json::s(m.name.clone())),
                                ("family", json::s(family_name(m.family))),
                                ("m", json::num(m.m as f64)),
                                ("k", json::num(m.k as f64)),
                                ("nnz", json::num(m.nnz as f64)),
                                ("seed", json::num(m.seed as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "results",
                Value::Arr(
                    self.results
                        .iter()
                        .map(|r| {
                            json::obj(vec![
                                ("bench", json::s(r.bench.clone())),
                                ("matrix", json::s(r.matrix.clone())),
                                ("n", json::num(r.n as f64)),
                                ("gflops", json::num(r.gflops)),
                                ("median_ns", json::num(r.median_ns)),
                                ("p50_ns", json::num(r.p50_ns)),
                                ("p95_ns", json::num(r.p95_ns)),
                                ("p99_ns", json::num(r.p99_ns)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "scaling",
                Value::Arr(
                    self.scaling
                        .iter()
                        .map(|s| {
                            json::obj(vec![
                                ("bench", json::s(s.bench.clone())),
                                ("workers", json::num(s.workers as f64)),
                                ("gflops", json::num(s.gflops)),
                                ("efficiency", json::num(s.efficiency)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Deserialize; errors name the offending field.
    pub fn from_value(v: &Value) -> Result<BenchRecord, String> {
        fn str_field(v: &Value, key: &str) -> Result<String, String> {
            v.get(key)
                .and_then(Value::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("missing or non-string field '{key}'"))
        }
        fn num_field(v: &Value, key: &str) -> Result<f64, String> {
            v.get(key)
                .and_then(Value::as_f64)
                .ok_or_else(|| format!("missing or non-numeric field '{key}'"))
        }
        fn usize_field(v: &Value, key: &str) -> Result<usize, String> {
            v.get(key)
                .and_then(Value::as_u64)
                .map(|n| n as usize)
                .ok_or_else(|| format!("missing or non-integer field '{key}'"))
        }
        let schema = v
            .get("schema")
            .and_then(Value::as_u64)
            .ok_or_else(|| "missing 'schema' version".to_string())?;
        if schema != SCHEMA_VERSION {
            return Err(format!("unsupported schema version {schema} (want {SCHEMA_VERSION})"));
        }
        let arr_field = |key: &str| -> Result<&[Value], String> {
            v.get(key)
                .and_then(Value::as_arr)
                .ok_or_else(|| format!("missing or non-array field '{key}'"))
        };
        let mut matrices = Vec::new();
        for m in arr_field("matrices")? {
            let fam = str_field(m, "family")?;
            matrices.push(MatrixSpec {
                name: str_field(m, "name")?,
                family: family_from(&fam).ok_or_else(|| format!("unknown family '{fam}'"))?,
                m: usize_field(m, "m")?,
                k: usize_field(m, "k")?,
                nnz: usize_field(m, "nnz")?,
                seed: m
                    .get("seed")
                    .and_then(Value::as_u64)
                    .ok_or_else(|| "missing or non-integer field 'seed'".to_string())?,
            });
        }
        let mut results = Vec::new();
        for r in arr_field("results")? {
            results.push(BenchMeasurement {
                bench: str_field(r, "bench")?,
                matrix: str_field(r, "matrix")?,
                n: usize_field(r, "n")?,
                gflops: num_field(r, "gflops")?,
                median_ns: num_field(r, "median_ns")?,
                p50_ns: num_field(r, "p50_ns")?,
                p95_ns: num_field(r, "p95_ns")?,
                p99_ns: num_field(r, "p99_ns")?,
            });
        }
        let mut scaling = Vec::new();
        for s in arr_field("scaling")? {
            scaling.push(ScalingPoint {
                bench: str_field(s, "bench")?,
                workers: usize_field(s, "workers")?,
                gflops: num_field(s, "gflops")?,
                efficiency: num_field(s, "efficiency")?,
            });
        }
        Ok(BenchRecord {
            name: str_field(v, "name")?,
            git_rev: str_field(v, "git_rev")?,
            timestamp: str_field(v, "timestamp")?,
            host_threads: usize_field(v, "host_threads")?,
            matrices,
            results,
            scaling,
        })
    }

    /// True when the snapshot carries no real measurements: every
    /// throughput number across `results` and `scaling` is zero (or both
    /// lists are empty). The repo seeds `BENCH_baseline.json` as an
    /// all-zero placeholder so the schema is exercised before any machine
    /// has measured; comparing against such a file can only ever pass, so
    /// `sextans bench --baseline` warns (and `--strict` fails) when it
    /// sees one.
    pub fn is_zeroed(&self) -> bool {
        self.results.iter().all(|r| r.gflops == 0.0)
            && self.scaling.iter().all(|s| s.gflops == 0.0)
    }

    /// Write `BENCH_<name>.json`-style pretty JSON to `path`.
    pub fn write(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_value().to_json_pretty())
    }

    /// Read and validate a snapshot file.
    pub fn read(path: &Path) -> Result<BenchRecord, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        let v = json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
        BenchRecord::from_value(&v).map_err(|e| format!("{}: {e}", path.display()))
    }
}

/// One flagged regression between two snapshots.
#[derive(Clone, Debug)]
pub struct Regression {
    /// What regressed (`backend/native:4 on crystm03_like n=16`, ...).
    pub what: String,
    pub baseline: f64,
    pub current: f64,
}

impl std::fmt::Display for Regression {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}: {:.3} -> {:.3} ({:+.1}%)",
            self.what,
            self.baseline,
            self.current,
            (self.current / self.baseline - 1.0) * 100.0
        )
    }
}

/// Compare `current` against `baseline`: every (bench, matrix, n) cell and
/// every (bench, workers) scaling point present in both is checked, and a
/// [`Regression`] is flagged when current throughput (or efficiency) falls
/// more than `tolerance` below baseline (`tolerance` 0.15 = 15% slack;
/// single-machine benches are noisy, so comparisons should leave headroom).
/// Cells present in only one snapshot are ignored — the trajectory grows.
pub fn compare(baseline: &BenchRecord, current: &BenchRecord, tolerance: f64) -> Vec<Regression> {
    let mut out = Vec::new();
    for b in &baseline.results {
        let hit = current
            .results
            .iter()
            .find(|c| c.bench == b.bench && c.matrix == b.matrix && c.n == b.n);
        if let Some(c) = hit {
            if c.gflops < b.gflops * (1.0 - tolerance) {
                out.push(Regression {
                    what: format!("{} on {} n={} (GFLOP/s)", b.bench, b.matrix, b.n),
                    baseline: b.gflops,
                    current: c.gflops,
                });
            }
        }
    }
    for b in &baseline.scaling {
        let hit =
            current.scaling.iter().find(|c| c.bench == b.bench && c.workers == b.workers);
        if let Some(c) = hit {
            if c.efficiency < b.efficiency * (1.0 - tolerance) {
                out.push(Regression {
                    what: format!("{} at {} workers (efficiency)", b.bench, b.workers),
                    baseline: b.efficiency,
                    current: c.efficiency,
                });
            }
        }
    }
    out
}

/// The current git revision, read straight from `.git` (no subprocess —
/// the bench environment is offline and minimal). Walks up from `start`
/// to find the repository; follows one level of `ref:` indirection and
/// falls back to `packed-refs`. Returns `"unknown"` when anything is
/// missing — a bench must never fail because it ran outside a checkout.
pub fn git_rev_from(start: &Path) -> String {
    let mut dir = Some(start.to_path_buf());
    while let Some(d) = dir {
        let git = d.join(".git");
        if git.join("HEAD").is_file() {
            return read_head(&git).unwrap_or_else(|| "unknown".into());
        }
        dir = d.parent().map(Path::to_path_buf);
    }
    "unknown".into()
}

/// [`git_rev_from`] starting at the current directory.
pub fn git_rev() -> String {
    std::env::current_dir().map(|d| git_rev_from(&d)).unwrap_or_else(|_| "unknown".into())
}

fn read_head(git: &Path) -> Option<String> {
    let head = std::fs::read_to_string(git.join("HEAD")).ok()?;
    let head = head.trim();
    let reference = match head.strip_prefix("ref: ") {
        None => return Some(head.to_string()), // detached HEAD
        Some(r) => r.trim(),
    };
    if let Ok(hash) = std::fs::read_to_string(git.join(reference)) {
        return Some(hash.trim().to_string());
    }
    // Ref may live in packed-refs: lines of "<hash> <ref>".
    let packed = std::fs::read_to_string(git.join("packed-refs")).ok()?;
    for line in packed.lines() {
        if let Some((hash, name)) = line.split_once(' ') {
            if name.trim() == reference {
                return Some(hash.trim().to_string());
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::catalog::crystm03_like;

    fn sample() -> BenchRecord {
        BenchRecord {
            name: "unit".into(),
            git_rev: "abc123".into(),
            timestamp: "2026-08-08".into(),
            host_threads: 8,
            matrices: vec![crystm03_like()],
            results: vec![BenchMeasurement {
                bench: "backend/native:4".into(),
                matrix: "crystm03_like".into(),
                n: 16,
                gflops: 12.5,
                median_ns: 1_500_000.0,
                p50_ns: 1_480_000.0,
                p95_ns: 1_900_000.0,
                p99_ns: 2_400_000.0,
            }],
            scaling: vec![ScalingPoint {
                bench: "concurrency/native:1".into(),
                workers: 4,
                gflops: 40.0,
                efficiency: 0.91,
            }],
        }
    }

    #[test]
    fn record_round_trips_through_json() {
        let rec = sample();
        let text = rec.to_value().to_json_pretty();
        let back = BenchRecord::from_value(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.name, rec.name);
        assert_eq!(back.git_rev, rec.git_rev);
        assert_eq!(back.host_threads, 8);
        assert_eq!(back.results, rec.results);
        assert_eq!(back.scaling, rec.scaling);
        assert_eq!(back.matrices.len(), 1);
        let m = &back.matrices[0];
        assert_eq!(m.name, "crystm03_like");
        assert_eq!(m.family, Family::SsBanded);
        assert_eq!((m.m, m.k, m.nnz, m.seed), (24_696, 24_696, 583_770, 0xC45731));
    }

    #[test]
    fn every_family_survives_the_round_trip() {
        for fam in [
            Family::SnapRmat,
            Family::SsBanded,
            Family::SsCircuit,
            Family::SsUniform,
            Family::SsBlock,
            Family::SsPowerRows,
        ] {
            assert_eq!(family_from(family_name(fam)), Some(fam));
        }
        assert_eq!(family_from("nonsense"), None);
    }

    #[test]
    fn malformed_records_are_rejected_with_field_names() {
        let missing_rev = json::parse(r#"{"schema": 1, "name": "x"}"#).unwrap();
        let err = BenchRecord::from_value(&missing_rev).unwrap_err();
        assert!(err.contains("matrices") || err.contains("git_rev"), "{err}");

        let bad_schema = json::parse(r#"{"schema": 99}"#).unwrap();
        let err = BenchRecord::from_value(&bad_schema).unwrap_err();
        assert!(err.contains("schema version 99"), "{err}");

        let no_schema = json::parse("{}").unwrap();
        assert!(BenchRecord::from_value(&no_schema).is_err());
    }

    #[test]
    fn compare_flags_only_regressions_beyond_tolerance() {
        let base = sample();
        let mut cur = sample();
        // 4% down: inside a 15% tolerance.
        cur.results[0].gflops = 12.0;
        assert!(compare(&base, &cur, 0.15).is_empty());
        // 40% down: flagged, with the cell named.
        cur.results[0].gflops = 7.5;
        let regs = compare(&base, &cur, 0.15);
        assert_eq!(regs.len(), 1);
        assert!(regs[0].what.contains("crystm03_like n=16"), "{}", regs[0].what);
        assert!(regs[0].to_string().contains("12.5"), "{}", regs[0]);
        // Scaling efficiency collapse is flagged independently.
        cur.results[0].gflops = 12.5;
        cur.scaling[0].efficiency = 0.4;
        let regs = compare(&base, &cur, 0.15);
        assert_eq!(regs.len(), 1);
        assert!(regs[0].what.contains("workers"), "{}", regs[0].what);
    }

    #[test]
    fn zeroed_placeholder_is_detected() {
        // Empty counts as zeroed: nothing was measured.
        assert!(BenchRecord::default().is_zeroed());
        let mut rec = sample();
        assert!(!rec.is_zeroed(), "real measurements are not a placeholder");
        rec.results[0].gflops = 0.0;
        assert!(!rec.is_zeroed(), "a nonzero scaling point still counts");
        rec.scaling[0].gflops = 0.0;
        assert!(rec.is_zeroed(), "all-zero throughput is the placeholder");
    }

    #[test]
    fn compare_ignores_cells_present_on_one_side_only() {
        let base = sample();
        let mut cur = sample();
        cur.results[0].matrix = "different_matrix".into();
        cur.scaling[0].workers = 16;
        assert!(compare(&base, &cur, 0.15).is_empty());
    }

    #[test]
    fn git_rev_resolves_this_repository() {
        // The test runs inside the repo checkout, so a 40-hex rev must
        // resolve from the manifest directory upward.
        let rev = git_rev_from(Path::new(env!("CARGO_MANIFEST_DIR")));
        assert_eq!(rev.len(), 40, "unexpected rev: {rev}");
        assert!(rev.chars().all(|c| c.is_ascii_hexdigit()), "{rev}");
    }

    #[test]
    fn git_rev_outside_a_checkout_is_unknown() {
        assert_eq!(git_rev_from(Path::new("/")), "unknown");
    }

    #[test]
    fn write_and_read_file() {
        let dir = std::env::temp_dir().join("sextans_bench_record_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_unit.json");
        let rec = sample();
        rec.write(&path).unwrap();
        let back = BenchRecord::read(&path).unwrap();
        assert_eq!(back.results, rec.results);
        std::fs::remove_dir_all(&dir).ok();
    }
}
