//! PE-aware out-of-order non-zero scheduling (paper §3.3, Fig. 5).
//!
//! The floating-point accumulator on the target platform has a RAW
//! dependency distance `D` (7–10 cycles on Xilinx FPGAs; the paper's worked
//! example uses D=4): a non-zero writing row `r` must not issue within `D`
//! cycles of the previous non-zero that wrote row `r`, or HLS schedules a
//! large II. The scheduler reorders the column-major non-zero stream
//! Tomasulo-style: each non-zero issues at the **earliest free cycle ≥
//! last_issue[row] + D**, so later non-conflicting elements fill the bubbles
//! earlier conflicts created, and the pipeline runs at II=1.
//!
//! This greedy rule reproduces the paper's Fig. 5 walkthrough cycle-for-cycle
//! (see `fig5_worked_example` below) including the 15-cycle column-major and
//! 28-cycle row-major in-order baselines.

use std::collections::BTreeSet;

use super::partition::Nz;

/// A scheduled window: `slots[c]` is the non-zero issued at cycle `c`, or
/// `None` for a pipeline bubble.
#[derive(Clone, Debug, Default)]
pub struct Schedule {
    /// One slot per cycle.
    pub slots: Vec<Option<Nz>>,
    /// Real non-zeros (== input length).
    pub nnz: usize,
}

impl Schedule {
    /// Total cycles consumed by this window's PE region (slot count).
    #[inline]
    pub fn cycles(&self) -> usize {
        self.slots.len()
    }

    /// Bubble (idle) slots.
    pub fn bubbles(&self) -> usize {
        self.slots.iter().filter(|s| s.is_none()).count()
    }

    /// Effective initiation interval = cycles / nnz (1.0 == perfect II=1).
    pub fn effective_ii(&self) -> f64 {
        if self.nnz == 0 {
            return 1.0;
        }
        self.cycles() as f64 / self.nnz as f64
    }
}

/// Scratch buffers reused across windows (hot-path allocation control).
#[derive(Default)]
pub struct Scratch {
    last_issue: Vec<i64>,
    touched: Vec<u32>,
}

/// Out-of-order schedule of a column-major non-zero stream under RAW
/// distance `d`. `rows_hint` bounds the compressed row index space (pass
/// `rows_per_pe`; it sizes the per-row issue table).
pub fn schedule_ooo(nzs: &[Nz], d: usize, rows_hint: usize, scratch: &mut Scratch) -> Schedule {
    let d = d.max(1);
    if nzs.is_empty() {
        return Schedule::default();
    }
    // Per-row last issue cycle, -inf encoded as -(d as i64) so row-first
    // issues are unconstrained.
    let need = rows_hint.max(nzs.iter().map(|n| n.row as usize + 1).max().unwrap_or(1));
    if scratch.last_issue.len() < need {
        scratch.last_issue.resize(need, i64::MIN / 2);
    }
    for &r in &scratch.touched {
        scratch.last_issue[r as usize] = i64::MIN / 2;
    }
    scratch.touched.clear();

    let mut slots: Vec<Option<Nz>> = Vec::with_capacity(nzs.len() + d);
    // Free slots strictly below `slots.len()`; the tail is implicitly free.
    let mut holes: BTreeSet<usize> = BTreeSet::new();

    for &nz in nzs {
        let row = nz.row as usize;
        let earliest = scratch.last_issue[row].saturating_add(d as i64).max(0) as usize;
        // First free cycle >= earliest: a hole, or the tail.
        let cycle = match holes.range(earliest..).next().copied() {
            Some(h) => {
                holes.remove(&h);
                h
            }
            None => {
                let tail = slots.len().max(earliest);
                // Cycles between the old tail and the chosen one are bubbles
                // (eligible for later fills).
                for b in slots.len()..tail {
                    holes.insert(b);
                    slots.push(None);
                }
                slots.push(None);
                tail
            }
        };
        slots[cycle] = Some(nz);
        if scratch.last_issue[row] == i64::MIN / 2 {
            scratch.touched.push(nz.row);
        }
        scratch.last_issue[row] = cycle as i64;
    }

    Schedule { slots, nnz: nzs.len() }
}

/// Cycle count of **in-order column-major** issue (no reordering): each
/// element stalls until `max(prev_cycle + 1, last_issue[row] + d)`.
/// This is the "non-zero based parallelization without OoO" baseline the
/// paper's Fig. 5 caption quotes as 15 cycles.
pub fn cycles_inorder(nzs: &[Nz], d: usize, rows_hint: usize) -> usize {
    let d = d.max(1) as i64;
    if nzs.is_empty() {
        return 0;
    }
    let need = rows_hint.max(nzs.iter().map(|n| n.row as usize + 1).max().unwrap_or(1));
    let mut last = vec![i64::MIN / 2; need];
    let mut cycle: i64 = -1;
    for nz in nzs {
        let row = nz.row as usize;
        cycle = (cycle + 1).max(last[row] + d);
        last[row] = cycle;
    }
    (cycle + 1) as usize
}

/// Cycle count of **in-order row-major** issue (CSR streaming, the paper's
/// Table 1 baseline): same stall rule over a row-major-sorted copy. The
/// Fig. 5 caption quotes 28 cycles for the worked example.
pub fn cycles_inorder_rowmajor(nzs: &[Nz], d: usize, rows_hint: usize) -> usize {
    let mut sorted = nzs.to_vec();
    sorted.sort_by_key(|n| (n.row, n.col));
    cycles_inorder(&sorted, d, rows_hint)
}

/// Verify a schedule respects the RAW distance and is a permutation of the
/// input. Returns a human-readable violation if any. (Test/debug aid; the
/// property tests drive it.)
pub fn validate(schedule: &Schedule, input: &[Nz], d: usize) -> Result<(), String> {
    let d = d.max(1);
    // Permutation check (multiset equality on bit patterns).
    let key = |n: &Nz| (n.row, n.col, n.val.to_bits());
    let mut a: Vec<_> = input.iter().map(key).collect();
    let mut b: Vec<_> = schedule.slots.iter().flatten().map(key).collect();
    a.sort_unstable();
    b.sort_unstable();
    if a != b {
        return Err("scheduled slots are not a permutation of the input".into());
    }
    if schedule.nnz != input.len() {
        return Err(format!("nnz {} != input {}", schedule.nnz, input.len()));
    }
    // RAW check.
    let mut last: std::collections::HashMap<u32, usize> = std::collections::HashMap::new();
    for (c, slot) in schedule.slots.iter().enumerate() {
        if let Some(nz) = slot {
            if let Some(&prev) = last.get(&nz.row) {
                if c - prev < d {
                    return Err(format!(
                        "RAW violation: row {} issued at cycles {prev} and {c} (D={d})",
                        nz.row
                    ));
                }
            }
            last.insert(nz.row, c);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    fn nz(row: u32, col: u16) -> Nz {
        Nz { row, col, val: 1.0 }
    }

    /// Paper Fig. 5 (a)–(h): the worked example, D = 4.
    /// Column-major input: (0,0) (2,0) (3,0) (1,1) (2,1) (0,2) (2,2) (3,2)
    /// (0,3) (3,3). Expected issue cycles: 0 1 2 3 5 4 9 6 8 10, one bubble
    /// at cycle 7, 11 total slots.
    #[test]
    fn fig5_worked_example() {
        let input = vec![
            nz(0, 0),
            nz(2, 0),
            nz(3, 0),
            nz(1, 1),
            nz(2, 1),
            nz(0, 2),
            nz(2, 2),
            nz(3, 2),
            nz(0, 3),
            nz(3, 3),
        ];
        let s = schedule_ooo(&input, 4, 4, &mut Scratch::default());
        let cycle_of = |row: u32, col: u16| {
            s.slots
                .iter()
                .position(|x| matches!(x, Some(n) if n.row == row && n.col == col))
                .unwrap()
        };
        assert_eq!(cycle_of(0, 0), 0);
        assert_eq!(cycle_of(2, 0), 1);
        assert_eq!(cycle_of(3, 0), 2);
        assert_eq!(cycle_of(1, 1), 3);
        assert_eq!(cycle_of(2, 1), 5, "yellow (2,1) must defer to cycle 5");
        assert_eq!(cycle_of(0, 2), 4, "blue (0,2) must fill the bubble at 4");
        assert_eq!(cycle_of(2, 2), 9);
        assert_eq!(cycle_of(3, 2), 6);
        assert_eq!(cycle_of(0, 3), 8);
        assert_eq!(cycle_of(3, 3), 10);
        assert_eq!(s.cycles(), 11, "OoO schedule takes 11 cycles");
        assert_eq!(s.bubbles(), 1, "one bubble (cycle 7)");
        assert!(s.slots[7].is_none());

        // Fig. 5 caption: in-order baselines take 15 (column-major) and 28
        // (row-major) cycles.
        assert_eq!(cycles_inorder(&input, 4, 4), 15);
        assert_eq!(cycles_inorder_rowmajor(&input, 4, 4), 28);
    }

    #[test]
    fn empty_input_empty_schedule() {
        let s = schedule_ooo(&[], 8, 0, &mut Scratch::default());
        assert_eq!(s.cycles(), 0);
        assert_eq!(cycles_inorder(&[], 8, 0), 0);
    }

    #[test]
    fn single_element_single_cycle() {
        let s = schedule_ooo(&[nz(5, 3)], 8, 6, &mut Scratch::default());
        assert_eq!(s.cycles(), 1);
        assert_eq!(s.bubbles(), 0);
    }

    #[test]
    fn conflict_free_stream_is_ii1_dense() {
        // All distinct rows: no bubbles possible.
        let input: Vec<Nz> = (0..64).map(|i| nz(i, 0)).collect();
        let s = schedule_ooo(&input, 8, 64, &mut Scratch::default());
        assert_eq!(s.cycles(), 64);
        assert_eq!(s.bubbles(), 0);
        assert!((s.effective_ii() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn worst_case_same_row_spreads_by_d() {
        let input: Vec<Nz> = (0..8).map(|i| nz(0, i)).collect();
        let s = schedule_ooo(&input, 5, 1, &mut Scratch::default());
        assert_eq!(s.cycles(), 7 * 5 + 1); // issues at 0,5,10,...,35
        assert_eq!(s.bubbles(), 36 - 8);
        validate(&s, &input, 5).unwrap();
    }

    #[test]
    fn d1_means_no_constraint() {
        let input: Vec<Nz> = (0..32).map(|i| nz(i % 3, i as u16)).collect();
        let s = schedule_ooo(&input, 1, 3, &mut Scratch::default());
        assert_eq!(s.cycles(), 32);
        assert_eq!(s.bubbles(), 0);
    }

    #[test]
    fn scratch_reuse_is_clean_across_windows() {
        let mut scratch = Scratch::default();
        let a: Vec<Nz> = (0..16).map(|i| nz(i % 4, i as u16)).collect();
        let s1 = schedule_ooo(&a, 4, 4, &mut scratch);
        let s1b = schedule_ooo(&a, 4, 4, &mut scratch);
        assert_eq!(s1.slots.len(), s1b.slots.len());
        // Same input, same scratch -> identical schedule.
        for (x, y) in s1.slots.iter().zip(s1b.slots.iter()) {
            assert_eq!(x.is_none(), y.is_none());
        }
    }

    #[test]
    fn ooo_never_slower_than_inorder_property() {
        prop::check("ooo_beats_inorder", 0x000, 64, |rng| {
            let n = 1 + rng.index(256);
            let rows = 1 + rng.index(32);
            let d = 1 + rng.index(12);
            let input: Vec<Nz> = (0..n)
                .map(|i| Nz {
                    row: rng.index(rows) as u32,
                    col: (i % 1024) as u16,
                    val: rng.normal(),
                })
                .collect();
            let s = schedule_ooo(&input, d, rows, &mut Scratch::default());
            let inorder = cycles_inorder(&input, d, rows);
            if s.cycles() > inorder {
                return Err(format!("OoO {} > in-order {}", s.cycles(), inorder));
            }
            Ok(())
        });
    }

    #[test]
    fn schedule_is_valid_permutation_respecting_raw_property() {
        prop::check("ooo_valid", 0x001, 64, |rng| {
            let n = rng.index(300);
            let rows = 1 + rng.index(40);
            let d = 1 + rng.index(10);
            let input: Vec<Nz> = (0..n)
                .map(|i| Nz {
                    row: rng.index(rows) as u32,
                    col: (i % 512) as u16,
                    val: rng.normal(),
                })
                .collect();
            let s = schedule_ooo(&input, d, rows, &mut Scratch::default());
            validate(&s, &input, d)
        });
    }

    #[test]
    fn lower_bound_cycles_property() {
        // cycles >= nnz always; cycles >= (max_row_count - 1) * d + 1.
        prop::check("ooo_lower_bound", 0x002, 64, |rng| {
            let n = 1 + rng.index(300);
            let rows = 1 + rng.index(16);
            let d = 1 + rng.index(10);
            let input: Vec<Nz> = (0..n)
                .map(|i| Nz {
                    row: rng.index(rows) as u32,
                    col: (i % 512) as u16,
                    val: 1.0,
                })
                .collect();
            let s = schedule_ooo(&input, d, rows, &mut Scratch::default());
            let mut counts = vec![0usize; rows];
            for nz in &input {
                counts[nz.row as usize] += 1;
            }
            let maxc = *counts.iter().max().unwrap();
            let lb = n.max((maxc - 1) * d + 1);
            if s.cycles() < lb {
                return Err(format!("cycles {} below lower bound {lb}", s.cycles()));
            }
            Ok(())
        });
    }

    #[test]
    fn validate_catches_violations() {
        let input = vec![nz(0, 0), nz(0, 1)];
        let bad = Schedule {
            slots: vec![Some(nz(0, 0)), Some(nz(0, 1))],
            nnz: 2,
        };
        assert!(validate(&bad, &input, 4).is_err());
        let not_perm = Schedule { slots: vec![Some(nz(1, 0))], nnz: 1 };
        assert!(validate(&not_perm, &input[..1], 4).is_err());
    }
}
