//! The HFlex iteration pointer list Q (paper §3.4, Fig. 5 (k)-(l)).
//!
//! "We store the scheduled non-zero lists of all A submatrices linearly in
//! a memory space. We use an iteration pointer list Q to record the starting
//! index of each scheduled non-zero list. In the processing, entries of Q
//! serve as the loop iteration number" — so one synthesized accelerator
//! executes any SpMM: the loop bounds arrive as data, not as hardware.
//!
//! Q has `K/K0 + 1` entries; `Q[0] == 0`; window `j`'s scheduled list
//! occupies `stream[Q[j] .. Q[j+1]]`.

use anyhow::{bail, Result};

/// Pointer list over a linear scheduled-slot stream.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PointerList {
    starts: Vec<u32>,
}

impl PointerList {
    /// Build from per-window scheduled lengths.
    pub fn from_lengths(lengths: &[usize]) -> PointerList {
        let mut starts = Vec::with_capacity(lengths.len() + 1);
        let mut acc = 0u32;
        starts.push(0);
        for &l in lengths {
            acc += l as u32;
            starts.push(acc);
        }
        PointerList { starts }
    }

    /// Validate an externally supplied Q against a stream length
    /// (monotonicity, Q[0] == 0, final entry == stream length).
    pub fn validate(starts: &[u32], stream_len: usize) -> Result<PointerList> {
        if starts.is_empty() {
            bail!("Q must have at least one entry");
        }
        if starts[0] != 0 {
            bail!("Q[0] must be 0, got {}", starts[0]);
        }
        if starts.windows(2).any(|w| w[0] > w[1]) {
            bail!("Q must be monotone non-decreasing");
        }
        if *starts.last().unwrap() as usize != stream_len {
            bail!(
                "Q end {} != stream length {stream_len}",
                starts.last().unwrap()
            );
        }
        Ok(PointerList { starts: starts.to_vec() })
    }

    /// Number of windows (= len - 1).
    #[inline]
    pub fn num_windows(&self) -> usize {
        self.starts.len() - 1
    }

    /// Slot range of window `j`.
    #[inline]
    pub fn window_range(&self, j: usize) -> std::ops::Range<usize> {
        self.starts[j] as usize..self.starts[j + 1] as usize
    }

    /// Scheduled length of window `j` — the PE's loop iteration count
    /// (Algorithm 1 line 6: `for (Q_i <= r < Q_{i+1})`).
    #[inline]
    pub fn window_len(&self, j: usize) -> usize {
        (self.starts[j + 1] - self.starts[j]) as usize
    }

    /// Raw entries (what the hardware actually receives).
    pub fn entries(&self) -> &[u32] {
        &self.starts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    /// Fig. 5 (l): first window's 11 slots at 0..10, next submatrix's 6
    /// slots at 11..16, so Q = [0, 11, 17].
    #[test]
    fn fig5_pointer_example() {
        let q = PointerList::from_lengths(&[11, 6]);
        assert_eq!(q.entries(), &[0, 11, 17]);
        assert_eq!(q.window_range(0), 0..11);
        assert_eq!(q.window_range(1), 11..17);
        assert_eq!(q.num_windows(), 2);
    }

    #[test]
    fn empty_windows_allowed() {
        let q = PointerList::from_lengths(&[0, 5, 0]);
        assert_eq!(q.window_len(0), 0);
        assert_eq!(q.window_len(1), 5);
        assert_eq!(q.window_len(2), 0);
    }

    #[test]
    fn validate_accepts_good() {
        assert!(PointerList::validate(&[0, 3, 3, 7], 7).is_ok());
    }

    #[test]
    fn validate_rejects_bad() {
        assert!(PointerList::validate(&[], 0).is_err());
        assert!(PointerList::validate(&[1, 2], 2).is_err()); // Q[0] != 0
        assert!(PointerList::validate(&[0, 5, 3], 3).is_err()); // not monotone
        assert!(PointerList::validate(&[0, 3], 7).is_err()); // wrong end
    }

    #[test]
    fn from_lengths_roundtrip_property() {
        prop::check("pointer_roundtrip", 0x97, 64, |rng| {
            let n = 1 + rng.index(40);
            let lengths: Vec<usize> = (0..n).map(|_| rng.index(100)).collect();
            let q = PointerList::from_lengths(&lengths);
            let total: usize = lengths.iter().sum();
            PointerList::validate(q.entries(), total).map_err(|e| e.to_string())?;
            for (j, &l) in lengths.iter().enumerate() {
                if q.window_len(j) != l {
                    return Err(format!("window {j}: {} != {l}", q.window_len(j)));
                }
            }
            Ok(())
        });
    }
}
