//! End-to-end preprocessing: COO matrix → per-PE encoded scheduled streams
//! with pointer lists — the memory image the accelerator consumes.
//!
//! This is the host-side "C++ wrapper" of paper §3.3, run once per matrix
//! (build path, not request path). It also collects the per-window cycle
//! statistics every performance model downstream consumes, including the
//! in-order baselines needed for the Table 1 breakdown.

use super::encode::encode_slot;
use super::ooo::{self, Scratch};
use super::partition::{partition, Nz};
use super::pointer::PointerList;
use crate::sparse::Coo;

/// Scheduling discipline (Table 1 ablation axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleMode {
    /// Out-of-order PE-aware scheduling (the paper's contribution): II=1.
    OutOfOrder,
    /// In-order column-major (non-zero parallelization without OoO).
    InOrderColMajor,
    /// In-order row-major (CSR streaming — the Table 1 "Baseline").
    InOrderRowMajor,
}

/// One PE's linear memory image: encoded scheduled slots + pointer list Q.
#[derive(Clone, Debug, Default)]
pub struct PeStream {
    /// 64-bit encoded slots of all windows, concatenated (Fig. 5 (l)).
    pub encoded: Vec<u64>,
    /// Q pointer list: window j occupies `encoded[q[j]..q[j+1]]`.
    pub q: PointerList,
    /// Real non-zeros in this stream (excludes bubbles).
    pub nnz: usize,
}

/// Per-window aggregate statistics across PEs.
#[derive(Clone, Debug, Default)]
pub struct WindowStats {
    /// Max scheduled cycles over PEs (the PE-region latency for this window,
    /// Algorithm 1 lines 5–11 — PEs run in parallel, slowest dominates).
    pub max_cycles: u64,
    /// Sum of real non-zeros over PEs.
    pub nnz: u64,
    /// Sum of bubbles over PEs.
    pub bubbles: u64,
    /// Max *in-order column-major* cycles over PEs (ablation baseline).
    pub max_cycles_inorder: u64,
    /// Max *in-order row-major* cycles over PEs (ablation baseline).
    pub max_cycles_rowmajor: u64,
}

/// A fully preprocessed matrix: what the host hands the accelerator
/// (pointers + scalars — the HFlex contract of §3.4).
#[derive(Clone, Debug)]
pub struct ScheduledMatrix {
    /// Rows of A.
    pub m: usize,
    /// Cols of A.
    pub k: usize,
    /// PE count the image was scheduled for.
    pub p: usize,
    /// Window size K0.
    pub k0: usize,
    /// RAW distance D the image was scheduled for.
    pub d: usize,
    /// Number of K-windows.
    pub num_windows: usize,
    /// One stream per PE.
    pub streams: Vec<PeStream>,
    /// Per-window stats (cycle model inputs).
    pub window_stats: Vec<WindowStats>,
    /// Total real non-zeros.
    pub nnz: usize,
}

impl ScheduledMatrix {
    /// Rows per PE C-scratchpad (ceil(M / P)).
    pub fn rows_per_pe(&self) -> usize {
        self.m.div_ceil(self.p)
    }

    /// Total scheduled slots across PEs and windows (bubbles included) —
    /// the A-stream memory footprint in 8-byte words.
    pub fn total_slots(&self) -> u64 {
        self.streams.iter().map(|s| s.encoded.len() as u64).sum()
    }

    /// Total bubbles across all streams.
    pub fn total_bubbles(&self) -> u64 {
        self.window_stats.iter().map(|w| w.bubbles).sum()
    }

    /// Whole-matrix effective II: per-window slowest-PE cycles summed,
    /// normalized by perfectly balanced nnz/P (1.0 is ideal).
    pub fn effective_ii(&self) -> f64 {
        let cyc: u64 = self.window_stats.iter().map(|w| w.max_cycles).sum();
        if self.nnz == 0 {
            return 1.0;
        }
        cyc as f64 / (self.nnz as f64 / self.p as f64)
    }

    /// A-stream bytes (8 B per scheduled slot; paper §3.2).
    pub fn a_stream_bytes(&self) -> u64 {
        self.total_slots() * 8
    }
}

/// Preprocess with the paper's OoO scheduling. Skips the in-order baseline
/// cycle statistics (only the Table 1 ablation needs them — they cost ~40%
/// of preprocessing; see EXPERIMENTS.md §Perf): `max_cycles_inorder` /
/// `max_cycles_rowmajor` are 0 in the result. Use [`preprocess_mode`] when
/// baselines matter.
pub fn preprocess(coo: &Coo, p: usize, k0: usize, d: usize) -> ScheduledMatrix {
    preprocess_impl(coo, p, k0, d, ScheduleMode::OutOfOrder, false)
}

/// Preprocess under a chosen scheduling discipline (Table 1 ablations).
///
/// For in-order modes the emitted stream is the same non-zeros in (possibly
/// stalled) issue order with explicit bubbles, so the functional result is
/// identical; only cycle counts differ.
pub fn preprocess_mode(
    coo: &Coo,
    p: usize,
    k0: usize,
    d: usize,
    mode: ScheduleMode,
) -> ScheduledMatrix {
    preprocess_impl(coo, p, k0, d, mode, true)
}

fn preprocess_impl(
    coo: &Coo,
    p: usize,
    k0: usize,
    d: usize,
    mode: ScheduleMode,
    baselines: bool,
) -> ScheduledMatrix {
    let w = partition(coo, p, k0);
    let rows_hint = w.rows_per_pe();
    let mut encoded: Vec<Vec<u64>> = vec![Vec::new(); p];
    let mut lengths: Vec<Vec<usize>> = vec![Vec::with_capacity(w.num_windows); p];
    let mut stream_nnz = vec![0usize; p];
    let mut window_stats = Vec::with_capacity(w.num_windows);
    let mut scratch = Scratch::default();

    for j in 0..w.num_windows {
        let mut stats = WindowStats::default();
        for pe in 0..p {
            let bin = &w.windows[j][pe];
            // Baseline cycle counts cost a second pass (plus a clone+sort
            // for row-major), so they are opt-in (Table 1 / ablations).
            if baselines {
                let inorder = ooo::cycles_inorder(bin, d, rows_hint) as u64;
                let rowmajor = ooo::cycles_inorder_rowmajor(bin, d, rows_hint) as u64;
                stats.max_cycles_inorder = stats.max_cycles_inorder.max(inorder);
                stats.max_cycles_rowmajor = stats.max_cycles_rowmajor.max(rowmajor);
            }

            let slots: Vec<Option<Nz>> = match mode {
                ScheduleMode::OutOfOrder => {
                    ooo::schedule_ooo(bin, d, rows_hint, &mut scratch).slots
                }
                ScheduleMode::InOrderColMajor => {
                    let cycles = ooo::cycles_inorder(bin, d, rows_hint);
                    inorder_slots(bin, d, cycles)
                }
                ScheduleMode::InOrderRowMajor => {
                    let mut sorted = bin.clone();
                    sorted.sort_by_key(|n| (n.row, n.col));
                    let cycles = ooo::cycles_inorder(&sorted, d, rows_hint);
                    inorder_slots(&sorted, d, cycles)
                }
            };
            stats.max_cycles = stats.max_cycles.max(slots.len() as u64);
            stats.nnz += bin.len() as u64;
            stats.bubbles += (slots.len() - bin.len()) as u64;
            stream_nnz[pe] += bin.len();
            lengths[pe].push(slots.len());
            encoded[pe].extend(slots.into_iter().map(encode_slot));
        }
        window_stats.push(stats);
    }

    let streams = encoded
        .into_iter()
        .zip(lengths.iter())
        .zip(stream_nnz.iter())
        .map(|((enc, lens), &nnz)| PeStream {
            q: PointerList::from_lengths(lens),
            encoded: enc,
            nnz,
        })
        .collect();

    ScheduledMatrix {
        m: coo.m,
        k: coo.k,
        p,
        k0,
        d,
        num_windows: w.num_windows,
        streams,
        window_stats,
        nnz: coo.nnz(),
    }
}

/// Expand an in-order stream into explicit slots with stall bubbles.
fn inorder_slots(bin: &[Nz], d: usize, total_cycles: usize) -> Vec<Option<Nz>> {
    let d = d.max(1) as i64;
    let mut slots: Vec<Option<Nz>> = vec![None; total_cycles];
    let mut last: std::collections::HashMap<u32, i64> = std::collections::HashMap::new();
    let mut cycle: i64 = -1;
    for &nz in bin {
        let prev = last.get(&nz.row).copied().unwrap_or(i64::MIN / 2);
        cycle = (cycle + 1).max(prev + d);
        slots[cycle as usize] = Some(nz);
        last.insert(nz.row, cycle);
    }
    slots
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::sched::decode;
    use crate::sparse::{gen, rng::Rng};

    fn toy() -> Coo {
        let mut rng = Rng::new(42);
        gen::random_uniform(64, 96, 0.1, &mut rng)
    }

    #[test]
    fn streams_and_q_are_consistent() {
        let coo = toy();
        let s = preprocess(&coo, 4, 32, 6);
        assert_eq!(s.streams.len(), 4);
        assert_eq!(s.num_windows, 3);
        for stream in &s.streams {
            assert_eq!(stream.q.num_windows(), s.num_windows);
            assert_eq!(
                stream.q.entries().last().copied().unwrap() as usize,
                stream.encoded.len()
            );
        }
    }

    #[test]
    fn every_nonzero_survives_encoding() {
        let coo = toy();
        let s = preprocess(&coo, 4, 32, 6);
        let total: usize = s
            .streams
            .iter()
            .map(|st| st.encoded.iter().filter(|&&w| decode(w).val != 0.0).count())
            .sum();
        assert_eq!(total, coo.nnz());
        assert_eq!(s.nnz, coo.nnz());
    }

    #[test]
    fn raw_distance_holds_within_every_window() {
        let coo = toy();
        let d = 7;
        let s = preprocess(&coo, 4, 32, d);
        for stream in &s.streams {
            for j in 0..s.num_windows {
                let mut last: std::collections::HashMap<u32, usize> = Default::default();
                for (c, &word) in stream.encoded[stream.q.window_range(j)].iter().enumerate() {
                    let nz = decode(word);
                    if nz.val == 0.0 {
                        continue;
                    }
                    if let Some(&prev) = last.get(&nz.row) {
                        assert!(c - prev >= d, "RAW violation in window {j}");
                    }
                    last.insert(nz.row, c);
                }
            }
        }
    }

    #[test]
    fn window_stats_sum_matches_nnz() {
        let coo = toy();
        let s = preprocess(&coo, 8, 16, 5);
        let sum: u64 = s.window_stats.iter().map(|ws| ws.nnz).sum();
        assert_eq!(sum as usize, coo.nnz());
    }

    #[test]
    fn ooo_mode_never_slower_than_inorder_modes() {
        let coo = toy();
        let s = preprocess_mode(&coo, 4, 32, 8, ScheduleMode::OutOfOrder);
        for ws in &s.window_stats {
            assert!(ws.max_cycles <= ws.max_cycles_inorder);
            assert!(ws.max_cycles_inorder <= ws.max_cycles_rowmajor + ws.max_cycles_inorder);
        }
    }

    #[test]
    fn inorder_modes_produce_matching_cycle_counts() {
        let coo = toy();
        let a = preprocess_mode(&coo, 4, 32, 8, ScheduleMode::InOrderColMajor);
        for (j, ws) in a.window_stats.iter().enumerate() {
            let longest = a
                .streams
                .iter()
                .map(|st| st.q.window_len(j) as u64)
                .max()
                .unwrap();
            assert_eq!(ws.max_cycles, longest);
            assert_eq!(ws.max_cycles, ws.max_cycles_inorder);
        }
    }

    #[test]
    fn effective_ii_close_to_one_for_balanced_matrix() {
        let mut rng = Rng::new(9);
        // Dense-ish uniform matrix, few conflicts at D=1.
        let coo = gen::random_uniform(512, 512, 0.05, &mut rng);
        let s = preprocess(&coo, 8, 512, 1);
        // With D=1 there are no bubbles; II reflects only imbalance.
        assert_eq!(s.total_bubbles(), 0);
        assert!(s.effective_ii() < 1.6, "ii = {}", s.effective_ii());
    }

    #[test]
    fn preprocess_properties() {
        prop::check("preprocess_invariants", 0x9E9, 24, |rng| {
            let m = 1 + rng.index(128);
            let k = 1 + rng.index(128);
            let coo = gen::random_uniform(m, k, 0.05 + rng.f64() * 0.15, rng);
            let p = 1 + rng.index(8);
            let k0 = 1 + rng.index(64);
            let d = 1 + rng.index(10);
            let s = preprocess(&coo, p, k0, d);
            // Invariant: slot totals = nnz + bubbles.
            let slots = s.total_slots();
            let bubbles = s.total_bubbles();
            if slots != s.nnz as u64 + bubbles {
                return Err(format!("slots {slots} != nnz {} + bubbles {bubbles}", s.nnz));
            }
            // Invariant: every window's stats.max_cycles equals the longest
            // per-PE window length.
            for j in 0..s.num_windows {
                let longest = s
                    .streams
                    .iter()
                    .map(|st| st.q.window_len(j) as u64)
                    .max()
                    .unwrap_or(0);
                if longest != s.window_stats[j].max_cycles {
                    return Err(format!("window {j}: {longest} != stats"));
                }
            }
            Ok(())
        });
    }
}
