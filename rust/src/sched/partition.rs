//! Matrix partitioning, paper Eq. 2–4 and Fig. 3.
//!
//! Three nested splits reform `C_AB = A × B`:
//!
//! 1. **Eq. 2** — B columns into `N/N0` slices `B_i` (handled by the outer
//!    loop at run time; independent of A, so not materialized here).
//! 2. **Eq. 3** — A columns / B rows into `K/K0` windows (`A_j`, `B_ji`).
//!    `K0` is the window size; random access is confined to one on-chip
//!    window.
//! 3. **Eq. 4** — A rows into `P` bins by `row mod P`, one bin per PE, for
//!    statistically balanced load. PE `p` owns global rows `{r : r % P == p}`
//!    and stores them compressed as `r / P` (Fig. 3: "both row index and
//!    column index are compressed").

use crate::sparse::Coo;

/// One non-zero inside a window, indices compressed to the PE's frame:
/// `row` = global_row / P (C-scratchpad address), `col` = global_col % K0
/// (B-window address).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Nz {
    /// Compressed row index (18-bit budget, paper §3.2).
    pub row: u32,
    /// Compressed column index (14-bit budget).
    pub col: u16,
    /// FP32 value.
    pub val: f32,
}

/// A-matrix partitioned into `K/K0` windows × `P` PE bins (Eq. 3 + Eq. 4).
#[derive(Clone, Debug)]
pub struct WindowedMatrix {
    /// Rows of A (M).
    pub m: usize,
    /// Cols of A (K).
    pub k: usize,
    /// PE count P (paper: 64).
    pub p: usize,
    /// Window size K0 (paper: 4096).
    pub k0: usize,
    /// Number of K-windows = ceil(K / K0).
    pub num_windows: usize,
    /// `windows[j][p]` = non-zeros of submatrix A_pj in column-major order
    /// (the order the outer-product pipeline consumes, Eq. 5).
    pub windows: Vec<Vec<Vec<Nz>>>,
    /// Total non-zeros (== input nnz).
    pub nnz: usize,
}

impl WindowedMatrix {
    /// Rows held by one PE's C scratchpad: ceil(M / P).
    pub fn rows_per_pe(&self) -> usize {
        self.m.div_ceil(self.p)
    }

    /// Max non-zeros in any single (j, p) bin — the load-imbalance metric
    /// the mod-P interleaving is meant to flatten.
    pub fn max_bin_nnz(&self) -> usize {
        self.windows
            .iter()
            .flat_map(|w| w.iter().map(|b| b.len()))
            .max()
            .unwrap_or(0)
    }
}

/// Partition `coo` for a `p`-PE accelerator with window size `k0`.
///
/// Entries within each (j, p) bin come out in column-major order (col, then
/// row) — the input order of the OoO scheduler.
pub fn partition(coo: &Coo, p: usize, k0: usize) -> WindowedMatrix {
    assert!(p > 0 && k0 > 0);
    let num_windows = coo.k.div_ceil(k0).max(1);
    let mut windows: Vec<Vec<Vec<Nz>>> = (0..num_windows)
        .map(|_| (0..p).map(|_| Vec::new()).collect())
        .collect();

    // Bin first (one cache-friendly pass), then sort each small (j, p) bin
    // column-major. Beats a global indirect sort by ~4x: the per-bin sorts
    // work on contiguous 8-byte keys instead of chasing indices through
    // three parent arrays. (See EXPERIMENTS.md §Perf.)
    for i in 0..coo.nnz() {
        let (r, c, v) = (coo.rows[i] as usize, coo.cols[i] as usize, coo.vals[i]);
        let j = c / k0;
        let pe = r % p;
        windows[j][pe].push(Nz {
            row: (r / p) as u32,
            col: (c % k0) as u16,
            val: v,
        });
    }
    for wj in windows.iter_mut() {
        for bin in wj.iter_mut() {
            // (col, row) key packs into one u32: col <= 2^14, row < 2^18.
            bin.sort_unstable_by_key(|nz| ((nz.col as u32) << 18) | nz.row);
        }
    }

    WindowedMatrix {
        m: coo.m,
        k: coo.k,
        p,
        k0,
        num_windows,
        windows,
        nnz: coo.nnz(),
    }
}

/// Invert the compression: global row for a bin entry.
#[inline]
pub fn global_row(nz: &Nz, pe: usize, p: usize) -> usize {
    nz.row as usize * p + pe
}

/// Invert the compression: global column for a window entry.
#[inline]
pub fn global_col(nz: &Nz, j: usize, k0: usize) -> usize {
    j * k0 + nz.col as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::sparse::{gen, rng::Rng, Coo};

    /// Paper Fig. 3: 8x8 matrix, 2 PEs, window size 4. The green element
    /// (3, 5) must become (1, 1) in window j=1 for PE 1.
    #[test]
    fn fig3_compression_example() {
        let coo = Coo::new(8, 8, vec![3], vec![5], vec![1.0]).unwrap();
        let w = partition(&coo, 2, 4);
        assert_eq!(w.num_windows, 2);
        assert!(w.windows[0].iter().all(|b| b.is_empty()));
        assert!(w.windows[1][0].is_empty());
        let nz = w.windows[1][1][0];
        assert_eq!((nz.row, nz.col), (1, 1));
        assert_eq!(global_row(&nz, 1, 2), 3);
        assert_eq!(global_col(&nz, 1, 4), 5);
    }

    #[test]
    fn every_nnz_lands_exactly_once() {
        prop::check("partition_covers", 0x9A47, 48, |rng| {
            let m = 1 + rng.index(200);
            let k = 1 + rng.index(200);
            let a = gen::random_uniform(m, k, 0.1, rng);
            let p = 1 + rng.index(8);
            let k0 = 1 + rng.index(64);
            let w = partition(&a, p, k0);
            let total: usize = w.windows.iter().flatten().map(|b| b.len()).sum();
            if total != a.nnz() {
                return Err(format!("covered {total} of {} nnz", a.nnz()));
            }
            // Round-trip every entry and match against a sorted copy.
            let mut got: Vec<(usize, usize, f32)> = Vec::new();
            for (j, wj) in w.windows.iter().enumerate() {
                for (pe, bin) in wj.iter().enumerate() {
                    for nz in bin {
                        got.push((global_row(nz, pe, p), global_col(nz, j, k0), nz.val));
                    }
                }
            }
            got.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mut want: Vec<(usize, usize, f32)> = (0..a.nnz())
                .map(|i| (a.rows[i] as usize, a.cols[i] as usize, a.vals[i]))
                .collect();
            want.sort_by(|a, b| a.partial_cmp(b).unwrap());
            if got != want {
                return Err("roundtrip mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn bins_respect_mod_p() {
        let mut rng = Rng::new(3);
        let a = gen::random_uniform(64, 64, 0.2, &mut rng);
        let w = partition(&a, 4, 16);
        for wj in &w.windows {
            for (pe, bin) in wj.iter().enumerate() {
                for nz in bin {
                    assert_eq!(global_row(nz, pe, 4) % 4, pe);
                }
            }
        }
    }

    #[test]
    fn bins_are_col_major_ordered() {
        let mut rng = Rng::new(5);
        let a = gen::random_uniform(100, 100, 0.15, &mut rng);
        let w = partition(&a, 8, 32);
        for wj in &w.windows {
            for bin in wj {
                for pair in bin.windows(2) {
                    assert!(
                        (pair[0].col, pair[0].row) <= (pair[1].col, pair[1].row),
                        "not column-major"
                    );
                }
            }
        }
    }

    #[test]
    fn mod_p_flattens_skew() {
        // A power-law matrix has wildly uneven *row* loads, but mod-P
        // interleaving should keep PE bins within a reasonable factor.
        let mut rng = Rng::new(7);
        let a = gen::power_law_rows(1024, 1024, 16_384, 1.1, &mut rng);
        let w = partition(&a, 64, 1024);
        let mean = a.nnz() as f64 / 64.0;
        let max = w.max_bin_nnz() as f64;
        assert!(max < 8.0 * mean, "max bin {max}, mean {mean}");
    }

    #[test]
    fn k_smaller_than_k0_gives_one_window() {
        let coo = Coo::new(4, 4, vec![0], vec![3], vec![1.0]).unwrap();
        let w = partition(&coo, 2, 4096);
        assert_eq!(w.num_windows, 1);
    }

    #[test]
    fn rows_per_pe_ceils() {
        let coo = Coo::empty(10, 4);
        let w = partition(&coo, 4, 4);
        assert_eq!(w.rows_per_pe(), 3);
    }
}
