//! Preprocessing pipeline: partitioning (Eq. 2–4), PE-aware out-of-order
//! non-zero scheduling (§3.3, Fig. 5), 64-bit encoding (§3.2), and the
//! HFlex pointer list Q (§3.4).
//!
//! The paper ships this as "a host C++ wrapper for users"; here it is the
//! `sextans::sched` module, invoked once per matrix (build path), producing
//! a [`preprocess::ScheduledMatrix`] the accelerator (simulator or PJRT
//! engine) consumes without further host work.

pub mod encode;
pub mod ooo;
pub mod partition;
pub mod pointer;
pub mod preprocess;

pub use encode::{decode, encode, BUBBLE};
pub use ooo::{schedule_ooo, Schedule};
pub use partition::{partition, Nz, WindowedMatrix};
pub use preprocess::{preprocess, PeStream, ScheduledMatrix};
