//! 64-bit non-zero encoding (paper §3.2, Fig. 4 step 1).
//!
//! "One non-zero originally consumes 96 bits ... we encode the row index,
//! column index, and value of the non-zero in a 64-bit element a-64b":
//!
//! ```text
//!   bits [63:46] — 18-bit compressed row index  (C scratchpad depth 12,288 needs 14;
//!                                                18 leaves headroom, paper's choice)
//!   bits [45:32] — 14-bit compressed col index  (B window depth 4,096 needs 12)
//!   bits [31: 0] — FP32 value bit pattern
//! ```
//!
//! Bubbles travel in-band as [`BUBBLE`] (row 0, col 0, value +0.0): the PE
//! multiplies by 0.0 and accumulates harmlessly, exactly how a real
//! pipeline slot idles without a stall signal.

use super::partition::Nz;

/// Row field width (bits).
pub const ROW_BITS: u32 = 18;
/// Column field width (bits).
pub const COL_BITS: u32 = 14;
/// Max encodable compressed row index.
pub const MAX_ROW: u32 = (1 << ROW_BITS) - 1;
/// Max encodable compressed column index.
pub const MAX_COL: u16 = ((1u32 << COL_BITS) - 1) as u16;

/// The in-band bubble: value +0.0 at (0, 0) — a no-op accumulate.
pub const BUBBLE: u64 = 0;

/// Pack a non-zero. Panics (debug) if indices exceed field widths; the
/// partitioner guarantees they cannot for paper-config accelerators
/// (rows/PE ≤ 12,288 < 2^18, K0 = 4,096 ≤ 2^14).
#[inline]
pub fn encode(nz: Nz) -> u64 {
    debug_assert!(nz.row <= MAX_ROW, "row {} exceeds {ROW_BITS} bits", nz.row);
    debug_assert!(nz.col <= MAX_COL, "col {} exceeds {COL_BITS} bits", nz.col);
    ((nz.row as u64) << 46) | ((nz.col as u64) << 32) | nz.val.to_bits() as u64
}

/// Encode a schedule slot (bubble -> [`BUBBLE`]).
#[inline]
pub fn encode_slot(slot: Option<Nz>) -> u64 {
    match slot {
        Some(nz) => encode(nz),
        None => BUBBLE,
    }
}

/// Unpack. A [`BUBBLE`] decodes to `Nz { row: 0, col: 0, val: 0.0 }`, which
/// is also what the PE datapath wants (multiply-accumulate of zero).
#[inline]
pub fn decode(word: u64) -> Nz {
    Nz {
        row: (word >> 46) as u32 & MAX_ROW,
        col: ((word >> 32) as u16) & MAX_COL,
        val: f32::from_bits(word as u32),
    }
}

/// True if the word is an idle slot (+0.0 value — note -0.0 or a true zero
/// value is also a no-op arithmetically, so this is a fast-path hint, not a
/// semantic discriminator).
#[inline]
pub fn is_bubble(word: u64) -> bool {
    word as u32 == 0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    #[test]
    fn roundtrip_simple() {
        let nz = Nz { row: 1234, col: 567, val: -3.25 };
        assert_eq!(decode(encode(nz)), nz);
    }

    #[test]
    fn roundtrip_extremes() {
        for (row, col) in [(0, 0), (MAX_ROW, MAX_COL), (0, MAX_COL), (MAX_ROW, 0)] {
            for val in [0.0f32, -0.0, 1.0, f32::MIN_POSITIVE, f32::MAX, -f32::MAX] {
                let nz = Nz { row, col, val };
                let d = decode(encode(nz));
                assert_eq!((d.row, d.col), (row, col));
                assert_eq!(d.val.to_bits(), val.to_bits());
            }
        }
    }

    #[test]
    fn bubble_is_harmless_zero() {
        let d = decode(BUBBLE);
        assert_eq!((d.row, d.col), (0, 0));
        assert_eq!(d.val, 0.0);
        assert!(is_bubble(BUBBLE));
        assert!(!is_bubble(encode(Nz { row: 0, col: 0, val: 1.0 })));
    }

    #[test]
    fn encode_slot_maps_none_to_bubble() {
        assert_eq!(encode_slot(None), BUBBLE);
        let nz = Nz { row: 3, col: 4, val: 2.0 };
        assert_eq!(encode_slot(Some(nz)), encode(nz));
    }

    #[test]
    fn fields_do_not_overlap_property() {
        prop::check("encode_roundtrip", 0xE6C, 64, |rng| {
            let nz = Nz {
                row: rng.below(1 << 18) as u32,
                col: rng.below(1 << 14) as u16,
                val: f32::from_bits(rng.next_u64() as u32),
            };
            let d = decode(encode(nz));
            if (d.row, d.col) != (nz.row, nz.col) || d.val.to_bits() != nz.val.to_bits() {
                return Err(format!("{nz:?} -> {d:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn paper_width_budget_holds() {
        // Paper §3.2: URAM depth 12,288 (needs 14 bits < 18 available),
        // window size 4,096 (needs 12 bits < 14 available).
        assert!(12_288u32 <= MAX_ROW);
        assert!(4_096u16 <= MAX_COL + 1);
    }
}
