//! Distributed worker fleet: remote shard execution over a framed wire
//! protocol.
//!
//! The sharded composite ([`crate::shard`]) scales SpMM across prepared
//! handles *inside one process*; this module lifts the same
//! prepare-once/execute-many contract onto a fleet of `sextans worker`
//! processes so shard residencies can live on other machines:
//!
//! * [`wire`] — the versioned, length-prefixed binary framing plus
//!   payload codecs for [`crate::sched::ScheduledMatrix`] images, shard
//!   plans, prepare costs, and execute requests. Hand-rolled little-endian
//!   encoding in the spirit of [`crate::telemetry::json`]: no new
//!   dependencies, every decode bounds-checked and version-gated.
//! * [`worker`] — the server side: a process that listens on a socket,
//!   holds prepared shard residencies keyed by image id, and serves
//!   ping/prepare/execute/stats/evict/shutdown RPCs with per-request
//!   framing and read/write timeouts.
//! * [`placer`] — LPT shard placement across the fleet with R-way
//!   replication on distinct workers, plus minimal-movement rebalancing
//!   onto the current live set.
//! * [`remote`] — the client side: the `remote:<addr>[,addr...]` backend
//!   whose [`crate::backend::PreparedSpmm`] handle proxies shard
//!   executions over pooled connections, retries across replicas, and
//!   re-places shards off dead workers mid-stream. Fleet liveness is
//!   supervised by a heartbeat-fed [`remote::Membership`] table
//!   (Live → Suspect → Dead → recovered Live) with a per-worker circuit
//!   breaker.
//! * [`fault`] — seeded, deterministic fault injection (delays, drops,
//!   corrupt frames, trickle, refused accepts, failed RPCs) installable
//!   on a worker (`--fault`) or the client framing path.
//!
//! Failure semantics mirror the in-process executor: "shard i of S on
//! host h failed" with C untouched — never silently zeroed rows.

pub mod fault;
pub mod placer;
pub mod remote;
pub mod wire;
pub mod worker;

pub use fault::{FaultPlan, FaultSpec, FaultStream};
pub use placer::{place, rebalance, FleetPlan};
pub use remote::{set_telemetry_sink, Liveness, Membership, PreparedRemote, RemoteBackend};
pub use wire::{Op, WireError, WorkerStats, MAX_FRAME_BYTES, WIRE_VERSION};
pub use worker::{Worker, WorkerConfig};
