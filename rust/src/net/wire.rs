//! Versioned, length-prefixed binary framing for the distributed worker
//! protocol — the serialization surface that lets a prepared matrix cross
//! a process (and host) boundary.
//!
//! Hand-rolled like [`crate::telemetry::json`]: no external dependencies,
//! explicit little-endian layout, and every decoder validates before it
//! trusts. One frame is
//!
//! ```text
//! +------+---------+--------+----------+---------...---------+
//! | SXTN | version | opcode | len (u32)| payload (len bytes) |
//! | 4 B  |  u16 LE | u16 LE |   LE     |                     |
//! +------+---------+--------+----------+---------...---------+
//! ```
//!
//! and the payload codecs cover the three prepared-work artifacts named by
//! the HFlex contract: the [`ScheduledMatrix`] memory image
//! ([`encode_image`]/[`decode_image`]), the shard plan
//! ([`encode_plan`]/[`decode_plan`]), and the [`PrepareCost`] amortization
//! report ([`encode_cost`]/[`decode_cost`]). Truncated frames, foreign
//! magic, version skew, and malformed payloads all surface as typed
//! [`WireError`]s — a worker must never panic on hostile bytes.

use std::io::{Read, Write};
use std::time::Duration;

use crate::backend::PrepareCost;
use crate::sched::pointer::PointerList;
use crate::sched::preprocess::{PeStream, WindowStats};
use crate::sched::ScheduledMatrix;
use crate::shard::ShardPlan;

/// Frame magic: the first four bytes of every Sextans frame.
pub const MAGIC: [u8; 4] = *b"SXTN";

/// Wire protocol version. Bumped on any incompatible layout change; a
/// worker refuses frames from a different version rather than guessing.
pub const WIRE_VERSION: u16 = 1;

/// Upper bound on one frame's payload (1 GiB). Large enough for any
/// realistic B/C operand pair, small enough that a corrupt length field
/// cannot drive an allocation to the moon.
pub const MAX_FRAME_BYTES: u32 = 1 << 30;

/// Fixed frame header size: magic + version + opcode + payload length.
pub const HEADER_BYTES: usize = 12;

/// RPC opcodes carried in the frame header.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u16)]
pub enum Op {
    /// Liveness / availability probe. Empty payload both ways.
    Ping = 1,
    /// Install a prepared residency: `u64 image id` + encoded image.
    /// Reply: the worker-side [`PrepareCost`].
    Prepare = 2,
    /// Execute against a resident image: id, n, alpha, beta, B, C.
    /// Reply: the updated C block.
    Execute = 3,
    /// Worker residency statistics. Empty request payload.
    Stats = 4,
    /// Drop one residency: `u64 image id`. Reply: 1 if it was resident.
    Evict = 5,
    /// Ask the worker process to exit after replying (used by tests/CI
    /// for a clean shutdown instead of a kill).
    Shutdown = 6,
    /// Front door: open a streamed image registration. Payload: the total
    /// encoded-image byte count. Reply: a `u64` upload token.
    RegisterBegin = 10,
    /// Front door: one chunk of a streamed registration (token, offset,
    /// raw image bytes). Reply: empty.
    RegisterChunk = 11,
    /// Front door: finish a registration (token); the server decodes and
    /// registers the image. Reply: image id + M + K.
    RegisterEnd = 12,
    /// Front door: open a submit (image id, N, alpha, beta). The B/C
    /// panels follow in column-block chunks. Reply: a `u64` ticket.
    Submit = 13,
    /// Front door: one column block of the B and C panels for a pending
    /// submit. Reply: empty.
    SubmitChunk = 14,
    /// Front door: all panels uploaded — enter the serving pipeline.
    /// Reply: empty on admission; an [`Op::Shed`] frame when the
    /// admission gate refuses the request.
    SubmitEnd = 15,
    /// Front door: non-blocking completion probe for a ticket. Reply: one
    /// byte, 1 when the response is ready.
    Poll = 16,
    /// Front door: block until a ticket completes, then stream the C
    /// panel back as [`Op::Chunk`] frames followed by a closing
    /// [`Op::Ok`] frame carrying the per-stage timing.
    Await = 17,
    /// Front door: live serving-metrics snapshot. Reply: the summary as
    /// JSON bytes ([`crate::coordinator::metrics::Summary`] layout).
    Metrics = 18,
    /// Front door: stop admitting new submits; in-flight requests finish
    /// and new ones shed with a typed [`Op::Shed`] frame.
    Drain = 19,
    /// Front door: liveness/identity probe — which backend spec this
    /// front door serves, whether it is draining, and its load counters.
    FrontStatus = 20,
    /// Success reply; payload layout depends on the request opcode.
    Ok = 100,
    /// Failure reply; payload is a UTF-8 error message.
    Err = 101,
    /// Streamed-reply element: one column block of a result panel; the
    /// closing [`Op::Ok`] frame follows the last chunk.
    Chunk = 102,
    /// Typed load-shed reply: a one-byte reason code
    /// ([`crate::serve_net::ShedReason`]) plus a UTF-8 message. Distinct
    /// from [`Op::Err`] so clients can tell backpressure from failure.
    Shed = 103,
}

impl Op {
    /// Decode an opcode, rejecting unknown values.
    pub fn from_u16(v: u16) -> Result<Op, WireError> {
        Ok(match v {
            1 => Op::Ping,
            2 => Op::Prepare,
            3 => Op::Execute,
            4 => Op::Stats,
            5 => Op::Evict,
            6 => Op::Shutdown,
            10 => Op::RegisterBegin,
            11 => Op::RegisterChunk,
            12 => Op::RegisterEnd,
            13 => Op::Submit,
            14 => Op::SubmitChunk,
            15 => Op::SubmitEnd,
            16 => Op::Poll,
            17 => Op::Await,
            18 => Op::Metrics,
            19 => Op::Drain,
            20 => Op::FrontStatus,
            100 => Op::Ok,
            101 => Op::Err,
            102 => Op::Chunk,
            103 => Op::Shed,
            other => return Err(WireError::BadOpcode(other)),
        })
    }
}

/// Why a frame or payload was refused.
#[derive(Debug)]
pub enum WireError {
    /// Underlying socket/file error.
    Io(std::io::Error),
    /// The stream did not start with [`MAGIC`].
    BadMagic([u8; 4]),
    /// Peer speaks a different protocol version.
    Version {
        /// Version found in the frame header.
        got: u16,
        /// Version this build speaks ([`WIRE_VERSION`]).
        want: u16,
    },
    /// Unknown opcode value.
    BadOpcode(u16),
    /// The stream ended mid-frame, or a payload declared more content
    /// than it carries.
    Truncated {
        /// Bytes the decoder needed next.
        needed: usize,
        /// Bytes actually remaining.
        have: usize,
    },
    /// Declared payload length exceeds [`MAX_FRAME_BYTES`].
    TooLarge(u64),
    /// Payload parsed but violates an invariant (bad Q list, shard-count
    /// mismatch, trailing garbage, ...).
    Malformed(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire i/o error: {e}"),
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:?} (expected SXTN)"),
            WireError::Version { got, want } => {
                write!(f, "wire version mismatch: peer speaks v{got}, this build v{want}")
            }
            WireError::BadOpcode(v) => write!(f, "unknown opcode {v}"),
            WireError::Truncated { needed, have } => {
                write!(f, "truncated frame: needed {needed} more bytes, have {have}")
            }
            WireError::TooLarge(n) => {
                write!(f, "frame payload of {n} bytes exceeds the {MAX_FRAME_BYTES} cap")
            }
            WireError::Malformed(s) => write!(f, "malformed payload: {s}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

// ---------------------------------------------------------------------------
// Byte-level primitives
// ---------------------------------------------------------------------------

/// Append-only little-endian payload builder.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    /// Fresh empty writer.
    pub fn new() -> ByteWriter {
        ByteWriter::default()
    }

    /// Finish and take the encoded bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Append a u8.
    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a little-endian u16.
    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a little-endian u32.
    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a little-endian u64.
    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a little-endian f32.
    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a length-prefixed (u32) UTF-8 string.
    pub fn put_str(&mut self, s: &str) {
        self.put_u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Append a length-prefixed (u64 count) u32 slice.
    pub fn put_u32_slice(&mut self, vs: &[u32]) {
        self.put_u64(vs.len() as u64);
        for &v in vs {
            self.put_u32(v);
        }
    }

    /// Append a length-prefixed (u64 count) u64 slice.
    pub fn put_u64_slice(&mut self, vs: &[u64]) {
        self.put_u64(vs.len() as u64);
        for &v in vs {
            self.put_u64(v);
        }
    }

    /// Append a length-prefixed (u64 count) f32 slice.
    pub fn put_f32_slice(&mut self, vs: &[f32]) {
        self.put_u64(vs.len() as u64);
        for &v in vs {
            self.put_f32(v);
        }
    }
}

/// Bounds-checked little-endian payload reader: every read either yields a
/// value or a [`WireError::Truncated`] — no panics on short input.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    /// Read from the start of `buf`.
    pub fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Error unless every byte was consumed — catches trailing garbage.
    pub fn finish(&self) -> Result<(), WireError> {
        if self.remaining() != 0 {
            return Err(WireError::Malformed(format!(
                "{} trailing bytes after payload",
                self.remaining()
            )));
        }
        Ok(())
    }

    /// Read `n` raw bytes (bounds-checked, no copy).
    pub fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated { needed: n, have: self.remaining() });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read a u8.
    pub fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    /// Read a little-endian u16.
    pub fn u16(&mut self) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    /// Read a little-endian u32.
    pub fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Read a little-endian u64.
    pub fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read a little-endian u64 and require it to fit a usize.
    pub fn len64(&mut self) -> Result<usize, WireError> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| WireError::TooLarge(v))
    }

    /// Read a little-endian f32.
    pub fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Read a length-prefixed UTF-8 string.
    pub fn str(&mut self) -> Result<String, WireError> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| WireError::Malformed("string is not UTF-8".into()))
    }

    /// Read a length-prefixed u32 slice (count validated against the
    /// remaining bytes *before* allocating).
    pub fn u32_slice(&mut self) -> Result<Vec<u32>, WireError> {
        let n = self.len64()?;
        if self.remaining() < n * 4 {
            return Err(WireError::Truncated { needed: n * 4, have: self.remaining() });
        }
        (0..n).map(|_| self.u32()).collect()
    }

    /// Read a length-prefixed u64 slice.
    pub fn u64_slice(&mut self) -> Result<Vec<u64>, WireError> {
        let n = self.len64()?;
        if self.remaining() < n * 8 {
            return Err(WireError::Truncated { needed: n * 8, have: self.remaining() });
        }
        (0..n).map(|_| self.u64()).collect()
    }

    /// Read a length-prefixed f32 slice.
    pub fn f32_slice(&mut self) -> Result<Vec<f32>, WireError> {
        let n = self.len64()?;
        if self.remaining() < n * 4 {
            return Err(WireError::Truncated { needed: n * 4, have: self.remaining() });
        }
        (0..n).map(|_| self.f32()).collect()
    }
}

// ---------------------------------------------------------------------------
// Frame I/O
// ---------------------------------------------------------------------------

/// Write one frame: header ([`MAGIC`], [`WIRE_VERSION`], opcode, length)
/// followed by the payload, then flush.
///
/// Fault-injection hook: when a [`super::fault::FaultPlan`] is installed
/// on this thread ([`super::fault::install_client_plan`]), its
/// corrupt-frame decision may flip one header byte and its trickle
/// directive slices the payload write — the client-side counterpart of
/// wrapping a worker's sockets in [`super::fault::FaultStream`].
pub fn write_frame(w: &mut impl Write, op: Op, payload: &[u8]) -> Result<(), WireError> {
    if payload.len() as u64 > MAX_FRAME_BYTES as u64 {
        return Err(WireError::TooLarge(payload.len() as u64));
    }
    let mut header = [0u8; HEADER_BYTES];
    header[0..4].copy_from_slice(&MAGIC);
    header[4..6].copy_from_slice(&WIRE_VERSION.to_le_bytes());
    header[6..8].copy_from_slice(&(op as u16).to_le_bytes());
    header[8..12].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    if let Some(plan) = super::fault::client_plan() {
        plan.corrupt_frame_header(&mut header);
        w.write_all(&header)?;
        if let Some((piece, pause)) = plan.trickle() {
            for chunk in payload.chunks(piece.max(1)) {
                w.write_all(chunk)?;
                std::thread::sleep(pause);
            }
        } else {
            w.write_all(payload)?;
        }
    } else {
        w.write_all(&header)?;
        w.write_all(payload)?;
    }
    w.flush()?;
    Ok(())
}

/// Read one frame, returning `None` on a clean EOF *between* frames (the
/// peer closed an idle connection). EOF mid-header or mid-payload is a
/// [`WireError::Truncated`].
///
/// Fault-injection hook: a thread-installed
/// [`super::fault::FaultPlan`]'s delay-before-read and drop-connection
/// directives run before the header read.
pub fn read_frame_opt(r: &mut impl Read) -> Result<Option<(Op, Vec<u8>)>, WireError> {
    if let Some(plan) = super::fault::client_plan() {
        plan.before_read()?;
    }
    let mut header = [0u8; HEADER_BYTES];
    let mut filled = 0;
    while filled < HEADER_BYTES {
        let n = r.read(&mut header[filled..])?;
        if n == 0 {
            if filled == 0 {
                return Ok(None);
            }
            return Err(WireError::Truncated {
                needed: HEADER_BYTES - filled,
                have: filled,
            });
        }
        filled += n;
    }
    if header[0..4] != MAGIC {
        return Err(WireError::BadMagic(header[0..4].try_into().unwrap()));
    }
    let version = u16::from_le_bytes(header[4..6].try_into().unwrap());
    if version != WIRE_VERSION {
        return Err(WireError::Version { got: version, want: WIRE_VERSION });
    }
    let op = Op::from_u16(u16::from_le_bytes(header[6..8].try_into().unwrap()))?;
    let len = u32::from_le_bytes(header[8..12].try_into().unwrap());
    if len > MAX_FRAME_BYTES {
        return Err(WireError::TooLarge(len as u64));
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            WireError::Truncated { needed: len as usize, have: 0 }
        } else {
            WireError::Io(e)
        }
    })?;
    Ok(Some((op, payload)))
}

/// Read one frame; a clean EOF between frames is also an error here (use
/// [`read_frame_opt`] where idle closes are expected).
pub fn read_frame(r: &mut impl Read) -> Result<(Op, Vec<u8>), WireError> {
    read_frame_opt(r)?.ok_or(WireError::Truncated { needed: HEADER_BYTES, have: 0 })
}

// ---------------------------------------------------------------------------
// ScheduledMatrix codec
// ---------------------------------------------------------------------------

/// Encode a [`ScheduledMatrix`] memory image (scalars, per-PE encoded
/// streams with Q pointer lists, per-window stats).
pub fn encode_image(sm: &ScheduledMatrix) -> Vec<u8> {
    let mut w = ByteWriter::new();
    for v in [sm.m, sm.k, sm.p, sm.k0, sm.d, sm.num_windows, sm.nnz] {
        w.put_u64(v as u64);
    }
    w.put_u64(sm.streams.len() as u64);
    for stream in &sm.streams {
        w.put_u64(stream.nnz as u64);
        w.put_u64_slice(&stream.encoded);
        w.put_u32_slice(stream.q.entries());
    }
    w.put_u64(sm.window_stats.len() as u64);
    for ws in &sm.window_stats {
        for v in [ws.max_cycles, ws.nnz, ws.bubbles, ws.max_cycles_inorder, ws.max_cycles_rowmajor]
        {
            w.put_u64(v);
        }
    }
    w.into_bytes()
}

/// Decode a [`ScheduledMatrix`], validating structural invariants: stream
/// count equals P, each Q list is a valid pointer list over its stream
/// ([`PointerList::validate`]), and window-stat count equals the window
/// count.
pub fn decode_image(bytes: &[u8]) -> Result<ScheduledMatrix, WireError> {
    let mut r = ByteReader::new(bytes);
    let m = r.len64()?;
    let k = r.len64()?;
    let p = r.len64()?;
    let k0 = r.len64()?;
    let d = r.len64()?;
    let num_windows = r.len64()?;
    let nnz = r.len64()?;
    let nstreams = r.len64()?;
    if nstreams != p {
        return Err(WireError::Malformed(format!("{nstreams} streams for P = {p}")));
    }
    let mut streams = Vec::with_capacity(nstreams);
    for _ in 0..nstreams {
        let s_nnz = r.len64()?;
        let encoded = r.u64_slice()?;
        let q_raw = r.u32_slice()?;
        let q = PointerList::validate(&q_raw, encoded.len())
            .map_err(|e| WireError::Malformed(format!("bad Q list: {e}")))?;
        if q.num_windows() != num_windows {
            return Err(WireError::Malformed(format!(
                "stream has {} windows, image declares {num_windows}",
                q.num_windows()
            )));
        }
        streams.push(PeStream { encoded, q, nnz: s_nnz });
    }
    let nstats = r.len64()?;
    if nstats != num_windows {
        return Err(WireError::Malformed(format!(
            "{nstats} window stats for {num_windows} windows"
        )));
    }
    let mut window_stats = Vec::with_capacity(nstats);
    for _ in 0..nstats {
        window_stats.push(WindowStats {
            max_cycles: r.u64()?,
            nnz: r.u64()?,
            bubbles: r.u64()?,
            max_cycles_inorder: r.u64()?,
            max_cycles_rowmajor: r.u64()?,
        });
    }
    r.finish()?;
    Ok(ScheduledMatrix { m, k, p, k0, d, num_windows, streams, window_stats, nnz })
}

// ---------------------------------------------------------------------------
// ShardPlan codec
// ---------------------------------------------------------------------------

/// Encode a [`ShardPlan`] (shard count, row→shard assignment, per-shard
/// row lists and nnz).
pub fn encode_plan(plan: &ShardPlan) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_u64(plan.shards as u64);
    w.put_u32_slice(&plan.assignment);
    w.put_u64(plan.shard_rows.len() as u64);
    for rows in &plan.shard_rows {
        w.put_u32_slice(rows);
    }
    w.put_u64(plan.shard_nnz.len() as u64);
    for &nnz in &plan.shard_nnz {
        w.put_u64(nnz as u64);
    }
    w.into_bytes()
}

/// Decode a [`ShardPlan`], validating that the per-shard vectors agree
/// with the declared shard count.
pub fn decode_plan(bytes: &[u8]) -> Result<ShardPlan, WireError> {
    let mut r = ByteReader::new(bytes);
    let shards = r.len64()?;
    let assignment = r.u32_slice()?;
    let nrows = r.len64()?;
    if nrows != shards {
        return Err(WireError::Malformed(format!("{nrows} row lists for {shards} shards")));
    }
    let mut shard_rows = Vec::with_capacity(nrows);
    for _ in 0..nrows {
        shard_rows.push(r.u32_slice()?);
    }
    let nnnz = r.len64()?;
    if nnnz != shards {
        return Err(WireError::Malformed(format!("{nnnz} nnz entries for {shards} shards")));
    }
    let mut shard_nnz = Vec::with_capacity(nnnz);
    for _ in 0..nnnz {
        shard_nnz.push(r.len64()?);
    }
    r.finish()?;
    for (i, &s) in assignment.iter().enumerate() {
        if s as usize >= shards {
            return Err(WireError::Malformed(format!(
                "row {i} assigned to shard {s} of {shards}"
            )));
        }
    }
    Ok(ShardPlan { shards, assignment, shard_rows, shard_nnz })
}

// ---------------------------------------------------------------------------
// PrepareCost codec
// ---------------------------------------------------------------------------

/// Encode a [`PrepareCost`] (wall nanoseconds + resident bytes).
pub fn encode_cost(cost: &PrepareCost) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_u64(cost.wall.as_nanos() as u64);
    w.put_u64(cost.resident_bytes);
    w.into_bytes()
}

/// Decode a [`PrepareCost`].
pub fn decode_cost(bytes: &[u8]) -> Result<PrepareCost, WireError> {
    let mut r = ByteReader::new(bytes);
    let wall = Duration::from_nanos(r.u64()?);
    let resident_bytes = r.u64()?;
    r.finish()?;
    Ok(PrepareCost { wall, resident_bytes })
}

// ---------------------------------------------------------------------------
// RPC payload codecs (shared by worker and remote backend)
// ---------------------------------------------------------------------------

/// Encode a Prepare request: image id + encoded image.
pub fn encode_prepare_req(image_id: u64, sm: &ScheduledMatrix) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_u64(image_id);
    let img = encode_image(sm);
    w.put_u64(img.len() as u64);
    let mut bytes = w.into_bytes();
    bytes.extend_from_slice(&img);
    bytes
}

/// Decode a Prepare request into (image id, image).
pub fn decode_prepare_req(bytes: &[u8]) -> Result<(u64, ScheduledMatrix), WireError> {
    let mut r = ByteReader::new(bytes);
    let id = r.u64()?;
    let len = r.len64()?;
    let img_bytes = r.take(len)?;
    r.finish()?;
    Ok((id, decode_image(img_bytes)?))
}

/// Encode an Execute request: image id, N, scalars, B (`k×n`), C (`m×n`).
pub fn encode_execute_req(
    image_id: u64,
    n: usize,
    alpha: f32,
    beta: f32,
    b: &[f32],
    c: &[f32],
) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_u64(image_id);
    w.put_u64(n as u64);
    w.put_f32(alpha);
    w.put_f32(beta);
    w.put_f32_slice(b);
    w.put_f32_slice(c);
    w.into_bytes()
}

/// Decode an Execute request into (id, n, alpha, beta, b, c).
#[allow(clippy::type_complexity)]
pub fn decode_execute_req(
    bytes: &[u8],
) -> Result<(u64, usize, f32, f32, Vec<f32>, Vec<f32>), WireError> {
    let mut r = ByteReader::new(bytes);
    let id = r.u64()?;
    let n = r.len64()?;
    let alpha = r.f32()?;
    let beta = r.f32()?;
    let b = r.f32_slice()?;
    let c = r.f32_slice()?;
    r.finish()?;
    Ok((id, n, alpha, beta, b, c))
}

/// Encode an Execute success reply: the updated C block.
pub fn encode_execute_ok(c: &[f32]) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_f32_slice(c);
    w.into_bytes()
}

/// Decode an Execute success reply.
pub fn decode_execute_ok(bytes: &[u8]) -> Result<Vec<f32>, WireError> {
    let mut r = ByteReader::new(bytes);
    let c = r.f32_slice()?;
    r.finish()?;
    Ok(c)
}

/// Worker residency statistics carried in a Stats reply.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkerStats {
    /// Prepared images currently resident.
    pub resident: u64,
    /// Live resident bytes across those handles.
    pub resident_bytes: u64,
    /// Execute RPCs served since the worker started.
    pub executes: u64,
}

/// Encode a Stats success reply.
pub fn encode_stats_ok(stats: &WorkerStats) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_u64(stats.resident);
    w.put_u64(stats.resident_bytes);
    w.put_u64(stats.executes);
    w.into_bytes()
}

/// Decode a Stats success reply.
pub fn decode_stats_ok(bytes: &[u8]) -> Result<WorkerStats, WireError> {
    let mut r = ByteReader::new(bytes);
    let stats = WorkerStats {
        resident: r.u64()?,
        resident_bytes: r.u64()?,
        executes: r.u64()?,
    };
    r.finish()?;
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::sched::preprocess;
    use crate::shard::plan_shards;
    use crate::sparse::{gen, rng::Rng};

    fn sample_image(seed: u64) -> ScheduledMatrix {
        let mut rng = Rng::new(seed);
        let m = 8 + rng.index(56);
        let k = 8 + rng.index(72);
        let coo = gen::random_uniform(m, k, 0.05 + rng.f64() * 0.2, &mut rng);
        let p = 1 + rng.index(6);
        let k0 = 4 + rng.index(28);
        let d = 1 + rng.index(8);
        preprocess(&coo, p, k0, d)
    }

    fn assert_images_equal(a: &ScheduledMatrix, b: &ScheduledMatrix) {
        assert_eq!(a.m, b.m);
        assert_eq!(a.k, b.k);
        assert_eq!(a.p, b.p);
        assert_eq!(a.k0, b.k0);
        assert_eq!(a.d, b.d);
        assert_eq!(a.num_windows, b.num_windows);
        assert_eq!(a.nnz, b.nnz);
        assert_eq!(a.streams.len(), b.streams.len());
        for (x, y) in a.streams.iter().zip(&b.streams) {
            assert_eq!(x.encoded, y.encoded);
            assert_eq!(x.q, y.q);
            assert_eq!(x.nnz, y.nnz);
        }
        assert_eq!(a.window_stats.len(), b.window_stats.len());
        for (x, y) in a.window_stats.iter().zip(&b.window_stats) {
            assert_eq!(x.max_cycles, y.max_cycles);
            assert_eq!(x.nnz, y.nnz);
            assert_eq!(x.bubbles, y.bubbles);
        }
    }

    #[test]
    fn image_roundtrip_property() {
        prop::check("wire_image_roundtrip", 0xD15C, 16, |rng| {
            let sm = sample_image(rng.index(1 << 30) as u64);
            let bytes = encode_image(&sm);
            let back = decode_image(&bytes).map_err(|e| e.to_string())?;
            assert_images_equal(&sm, &back);
            Ok(())
        });
    }

    #[test]
    fn plan_roundtrip_property() {
        prop::check("wire_plan_roundtrip", 0x9A7, 24, |rng| {
            let m = 1 + rng.index(96);
            let k = 1 + rng.index(64);
            let coo = gen::random_uniform(m, k, 0.02 + rng.f64() * 0.2, rng);
            let s = 1 + rng.index(8);
            let plan = plan_shards(&coo, s);
            let back = decode_plan(&encode_plan(&plan)).map_err(|e| e.to_string())?;
            if back.shards != plan.shards
                || back.assignment != plan.assignment
                || back.shard_rows != plan.shard_rows
                || back.shard_nnz != plan.shard_nnz
            {
                return Err("plan did not round-trip".into());
            }
            Ok(())
        });
    }

    #[test]
    fn cost_roundtrip() {
        let cost = PrepareCost {
            wall: Duration::from_nanos(123_456_789),
            resident_bytes: 9_876_543,
        };
        let back = decode_cost(&encode_cost(&cost)).unwrap();
        assert_eq!(back.wall, cost.wall);
        assert_eq!(back.resident_bytes, cost.resident_bytes);
    }

    #[test]
    fn truncated_image_is_rejected_at_every_prefix() {
        let sm = sample_image(7);
        let bytes = encode_image(&sm);
        // Every strict prefix must fail loudly, never panic or succeed.
        for cut in [0, 1, 8, bytes.len() / 2, bytes.len() - 1] {
            let err = decode_image(&bytes[..cut]).unwrap_err();
            assert!(
                matches!(err, WireError::Truncated { .. } | WireError::Malformed(_)),
                "prefix {cut}: unexpected {err:?}"
            );
        }
    }

    #[test]
    fn corrupt_q_list_is_rejected() {
        let sm = sample_image(11);
        let mut bytes = encode_image(&sm);
        // The first stream's Q starts right after scalars + stream nnz +
        // encoded-words; flip its Q[0] (must be 0) to a nonzero value.
        let q0_offset = 8 * 8 + 8 + 8 + sm.streams[0].encoded.len() * 8 + 8;
        bytes[q0_offset] = 0xFF;
        let err = decode_image(&bytes).unwrap_err();
        assert!(matches!(err, WireError::Malformed(_)), "{err:?}");
    }

    #[test]
    fn frame_roundtrip_over_a_buffer() {
        let payload = vec![1u8, 2, 3, 4, 5];
        let mut buf = Vec::new();
        write_frame(&mut buf, Op::Execute, &payload).unwrap();
        assert_eq!(buf.len(), HEADER_BYTES + payload.len());
        let (op, got) = read_frame(&mut buf.as_slice()).unwrap();
        assert_eq!(op, Op::Execute);
        assert_eq!(got, payload);
    }

    #[test]
    fn clean_eof_between_frames_is_none() {
        let empty: &[u8] = &[];
        assert!(read_frame_opt(&mut &*empty).unwrap().is_none());
    }

    #[test]
    fn truncated_frame_header_and_payload_are_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, Op::Ping, b"abc").unwrap();
        // Mid-header cut.
        let err = read_frame(&mut &buf[..6]).unwrap_err();
        assert!(matches!(err, WireError::Truncated { .. }), "{err:?}");
        // Mid-payload cut.
        let err = read_frame(&mut &buf[..HEADER_BYTES + 1]).unwrap_err();
        assert!(matches!(err, WireError::Truncated { .. }), "{err:?}");
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, Op::Ping, b"").unwrap();
        buf[4] = (WIRE_VERSION + 1) as u8; // bump the version field
        let err = read_frame(&mut buf.as_slice()).unwrap_err();
        match err {
            WireError::Version { got, want } => {
                assert_eq!(got, WIRE_VERSION + 1);
                assert_eq!(want, WIRE_VERSION);
            }
            other => panic!("expected version mismatch, got {other:?}"),
        }
    }

    #[test]
    fn bad_magic_and_bad_opcode_are_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, Op::Ping, b"").unwrap();
        let mut spoofed = buf.clone();
        spoofed[0] = b'X';
        assert!(matches!(
            read_frame(&mut spoofed.as_slice()).unwrap_err(),
            WireError::BadMagic(_)
        ));
        let mut bad_op = buf.clone();
        bad_op[6] = 99; // not a registered opcode
        assert!(matches!(
            read_frame(&mut bad_op.as_slice()).unwrap_err(),
            WireError::BadOpcode(99)
        ));
    }

    #[test]
    fn oversized_length_field_is_rejected_without_allocating() {
        let mut buf = Vec::new();
        write_frame(&mut buf, Op::Ping, b"").unwrap();
        buf[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            read_frame(&mut buf.as_slice()).unwrap_err(),
            WireError::TooLarge(_)
        ));
    }

    #[test]
    fn execute_req_roundtrip() {
        let b = vec![1.5f32, -2.25, 0.0, 3.75];
        let c = vec![0.5f32, -0.5];
        let bytes = encode_execute_req(42, 2, 1.5, -0.25, &b, &c);
        let (id, n, alpha, beta, b2, c2) = decode_execute_req(&bytes).unwrap();
        assert_eq!((id, n, alpha, beta), (42, 2, 1.5, -0.25));
        assert_eq!(b2, b);
        assert_eq!(c2, c);
        let c3 = decode_execute_ok(&encode_execute_ok(&c)).unwrap();
        assert_eq!(c3, c);
    }

    #[test]
    fn prepare_req_roundtrip() {
        let sm = sample_image(3);
        let bytes = encode_prepare_req(7, &sm);
        let (id, back) = decode_prepare_req(&bytes).unwrap();
        assert_eq!(id, 7);
        assert_images_equal(&sm, &back);
    }

    #[test]
    fn stats_roundtrip_and_trailing_garbage_rejected() {
        let stats = WorkerStats { resident: 3, resident_bytes: 4096, executes: 17 };
        assert_eq!(decode_stats_ok(&encode_stats_ok(&stats)).unwrap(), stats);
        let mut bytes = encode_stats_ok(&stats);
        bytes.push(0);
        assert!(matches!(decode_stats_ok(&bytes).unwrap_err(), WireError::Malformed(_)));
    }

    #[test]
    fn plan_roundtrip_and_validation() {
        let mut rng = Rng::new(5);
        let coo = gen::power_law_rows(64, 48, 300, 1.2, &mut rng);
        let plan = plan_shards(&coo, 4);
        let bytes = encode_plan(&plan);
        let back = decode_plan(&bytes).unwrap();
        assert_eq!(back.shards, plan.shards);
        assert_eq!(back.assignment, plan.assignment);
        assert_eq!(back.shard_rows, plan.shard_rows);
        assert_eq!(back.shard_nnz, plan.shard_nnz);
        // A row assigned to a shard >= S is rejected.
        let mut evil = plan.clone();
        evil.assignment[0] = 99;
        assert!(matches!(
            decode_plan(&encode_plan(&evil)).unwrap_err(),
            WireError::Malformed(_)
        ));
    }
}
