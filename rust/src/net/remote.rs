//! The `remote:<addr>[,addr...]` backend: a [`PreparedSpmm`] handle whose
//! shards live in `sextans worker` processes across a fleet.
//!
//! Prepare shards the image locally ([`ShardedMatrix::from_image`], one
//! shard per worker up to M rows), spreads the shards over the fleet with
//! the LPT [`placer`] (R-way replication via `replicas=R` in the spec),
//! and ships each shard's [`crate::sched::ScheduledMatrix`] over the
//! [`super::wire`] framing. Execution is the [`crate::shard::ShardExecutor`]
//! gather → fan-out → scatter dance with RPCs in place of inner handles:
//! B is broadcast, each shard's C block is seeded from the caller's C (so
//! the worker computes the full `alpha·A_i·B + beta·C_i` expression), and
//! the scatter runs **only after every shard succeeded** — a partial
//! failure surfaces as "shard i of S on host h failed: ..." with C
//! untouched, never as silently zeroed rows.
//!
//! Failure handling per shard, in order: retry the next replica
//! (placement order), then **re-place** — re-prepare the shard on any
//! live worker that does not hold it and execute there, updating the
//! placement map for subsequent calls. Worker-side errors (an evicted
//! residency, an execution refusal) leave a worker live so a re-prepare
//! can heal it. Retry/re-place/placement counts flow out through
//! [`ExecutionReport::remote`] into the serving metrics, and every RPC
//! emits a `net.rpc` child span when a telemetry sink is installed
//! ([`set_telemetry_sink`]) and the executing thread carries a span
//! context ([`crate::telemetry::trace::push_span_context`]).
//!
//! Liveness is supervised, not inferred once and stuck: a [`Membership`]
//! table, fed by a background heartbeat thread, moves each worker
//! Live → Suspect (first failure) → Dead ([`BREAKER_THRESHOLD`]
//! consecutive failures) → back to Live when a heartbeat succeeds again.
//! A revived worker is reused directly — its placements were never
//! discarded, so images it still holds need no re-registration. Each
//! worker also carries a circuit breaker: after the failure threshold
//! the breaker opens and RPCs fail fast (no timeout burned) until the
//! [`BREAKER_COOLDOWN`] elapses and one half-open probe is admitted.
//! When membership changes, placements rebalance onto the current live
//! set ([`super::placer::rebalance`]) *before* the next execution needs
//! to fail over, restoring replica counts proactively.
//!
//! Deadlines: a dispatch worker can install an absolute deadline for the
//! current thread ([`push_call_deadline`]); the shard fan-out checks it
//! before every fleet RPC, so an expired request stops issuing executes
//! mid-flight instead of riding every retry to its timeout.
//!
//! Connections are pooled per worker (stale pooled connections fall back
//! to one fresh reconnect), and all sockets run with read/write timeouts
//! so a hung peer becomes an error, not a stuck request.

use std::cell::Cell;
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock, Weak};
use std::time::{Duration, Instant};

use super::placer::{self, FleetPlan};
use super::wire::{self, Op, WireError};
use crate::backend::{
    check_shapes, BackendError, Capability, ExecutionReport, PrepareCost, PreparedSpmm,
    RemoteStats, ScratchPool, SpmmBackend,
};
use crate::sched::ScheduledMatrix;
use crate::shard::{ShardRunStats, ShardedMatrix};
use crate::telemetry::trace::{self, SpanRecord, TelemetrySink};

/// Default per-socket read/write/connect timeout (`timeout_ms=` overrides).
const DEFAULT_TIMEOUT: Duration = Duration::from_secs(10);

/// Default heartbeat ping interval (`heartbeat_ms=` overrides).
const DEFAULT_HEARTBEAT: Duration = Duration::from_millis(250);

/// Consecutive failures that mark a worker Dead and open its circuit
/// breaker.
pub const BREAKER_THRESHOLD: u32 = 3;

/// How long an open breaker rejects RPCs outright before admitting one
/// half-open probe.
pub const BREAKER_COOLDOWN: Duration = Duration::from_millis(500);

/// Install (or clear) the process-wide sink that receives `net.rpc` spans.
/// The serving CLI points this at the same collector as
/// [`crate::coordinator::PipelineConfig::sink`] so remote RPCs nest under
/// each request's `exec` span.
pub fn set_telemetry_sink(sink: Option<Arc<dyn TelemetrySink>>) {
    *sink_cell().lock().unwrap() = sink;
}

fn sink_cell() -> &'static Mutex<Option<Arc<dyn TelemetrySink>>> {
    static SINK: OnceLock<Mutex<Option<Arc<dyn TelemetrySink>>>> = OnceLock::new();
    SINK.get_or_init(|| Mutex::new(None))
}

fn current_sink() -> Option<Arc<dyn TelemetrySink>> {
    sink_cell().lock().unwrap().clone()
}

/// Fleet-unique image ids (per client process): every shard residency a
/// handle installs gets a fresh id, so two prepared matrices never
/// collide on a worker.
fn next_image_id() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

// ---------------------------------------------------------------------------
// Deadline propagation
// ---------------------------------------------------------------------------

thread_local! {
    static CALL_DEADLINE: Cell<Option<Instant>> = const { Cell::new(None) };
}

/// Install an absolute deadline for remote executes issued from this
/// thread; the shard fan-out checks it before every fleet RPC and stops
/// retrying once it passes. Restored (to the previous value) when the
/// returned guard drops. The dispatch stage installs this around each
/// job whose segments all carry deadlines.
pub fn push_call_deadline(deadline: Instant) -> CallDeadlineGuard {
    let prev = CALL_DEADLINE.with(|c| c.replace(Some(deadline)));
    CallDeadlineGuard { prev }
}

/// The deadline installed on this thread, if any.
pub fn current_call_deadline() -> Option<Instant> {
    CALL_DEADLINE.with(|c| c.get())
}

/// RAII restore for [`push_call_deadline`].
pub struct CallDeadlineGuard {
    prev: Option<Instant>,
}

impl Drop for CallDeadlineGuard {
    fn drop(&mut self) {
        CALL_DEADLINE.with(|c| c.set(self.prev));
    }
}

// ---------------------------------------------------------------------------
// Fleet membership + circuit breaking
// ---------------------------------------------------------------------------

/// Liveness of one fleet worker as seen by the supervising heartbeat.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Liveness {
    /// Answering; RPCs flow normally.
    Live,
    /// At least one recent failure but under the death threshold —
    /// still tried, on notice.
    Suspect,
    /// [`BREAKER_THRESHOLD`] consecutive failures; skipped by placement
    /// until a heartbeat succeeds and revives it.
    Dead,
}

/// Supervision state for one worker.
struct MemberState {
    /// 0 = Live, 1 = Suspect, 2 = Dead.
    liveness: AtomicU8,
    /// Consecutive failures since the last success.
    failures: AtomicU32,
    /// `Some(until)` while the circuit breaker is open; RPCs fail fast
    /// until `until`, then one half-open probe is admitted.
    breaker_open_until: Mutex<Option<Instant>>,
}

/// The fleet liveness table: one row per worker, written by RPC
/// outcomes and by the background heartbeat thread, read by placement
/// and the per-worker circuit breaker. State machine per worker:
/// Live → Suspect on the first failure, → Dead at
/// [`BREAKER_THRESHOLD`] consecutive failures (which also opens the
/// breaker), → Live again on any success (heartbeat or RPC) — so a
/// revived worker rejoins without a handle rebuild, keeping whatever
/// residencies it still holds.
pub struct Membership {
    addrs: Vec<String>,
    states: Vec<MemberState>,
    timeout: Duration,
    /// Bumped on every liveness transition; consumers compare epochs to
    /// decide when to rebalance placements.
    epoch: AtomicU64,
    /// Total liveness transitions (any direction) since construction.
    transitions: AtomicU64,
    /// Times a worker's breaker tripped open (closed → open edges only).
    breaker_trips: AtomicU64,
}

impl Membership {
    fn new(addrs: Vec<String>, timeout: Duration) -> Arc<Membership> {
        let states = addrs
            .iter()
            .map(|_| MemberState {
                liveness: AtomicU8::new(0),
                failures: AtomicU32::new(0),
                breaker_open_until: Mutex::new(None),
            })
            .collect();
        Arc::new(Membership {
            addrs,
            states,
            timeout,
            epoch: AtomicU64::new(0),
            transitions: AtomicU64::new(0),
            breaker_trips: AtomicU64::new(0),
        })
    }

    /// Build a table and start its background heartbeat thread. The
    /// thread holds only a `Weak` reference and exits when the last
    /// owner (the prepared handle) drops.
    fn with_heartbeat(
        addrs: Vec<String>,
        timeout: Duration,
        interval: Duration,
    ) -> Arc<Membership> {
        let membership = Membership::new(addrs, timeout);
        let weak = Arc::downgrade(&membership);
        std::thread::spawn(move || heartbeat_loop(weak, interval));
        membership
    }

    /// Current liveness of worker `w`.
    pub fn liveness(&self, w: usize) -> Liveness {
        match self.states[w].liveness.load(Ordering::Relaxed) {
            0 => Liveness::Live,
            1 => Liveness::Suspect,
            _ => Liveness::Dead,
        }
    }

    /// Liveness transitions (any direction) since construction.
    pub fn transitions(&self) -> u64 {
        self.transitions.load(Ordering::Relaxed)
    }

    /// Closed → open breaker trips since construction.
    pub fn breaker_trips(&self) -> u64 {
        self.breaker_trips.load(Ordering::Relaxed)
    }

    fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Relaxed)
    }

    fn set_liveness(&self, w: usize, next: Liveness) {
        let code = match next {
            Liveness::Live => 0u8,
            Liveness::Suspect => 1,
            Liveness::Dead => 2,
        };
        let prev = self.states[w].liveness.swap(code, Ordering::Relaxed);
        if prev != code {
            self.transitions.fetch_add(1, Ordering::Relaxed);
            self.epoch.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// A successful exchange with worker `w` (RPC reply — even an error
    /// reply proves liveness — or heartbeat): reset failures, close the
    /// breaker, revive.
    fn record_ok(&self, w: usize) {
        self.states[w].failures.store(0, Ordering::Relaxed);
        *self.states[w].breaker_open_until.lock().unwrap() = None;
        self.set_liveness(w, Liveness::Live);
    }

    /// A transport failure against worker `w`: escalate liveness and,
    /// at the threshold, open (or re-arm) the breaker.
    fn record_failure(&self, w: usize) {
        let n = self.states[w].failures.fetch_add(1, Ordering::Relaxed) + 1;
        if n >= BREAKER_THRESHOLD {
            self.set_liveness(w, Liveness::Dead);
            let mut open = self.states[w].breaker_open_until.lock().unwrap();
            if open.is_none() {
                self.breaker_trips.fetch_add(1, Ordering::Relaxed);
            }
            *open = Some(Instant::now() + BREAKER_COOLDOWN);
        } else {
            self.set_liveness(w, Liveness::Suspect);
        }
    }

    /// Read-only breaker check: false while `w`'s breaker is cooling
    /// down. Used by placement loops to skip doomed workers without
    /// consuming the half-open probe.
    fn would_admit(&self, w: usize) -> bool {
        match *self.states[w].breaker_open_until.lock().unwrap() {
            Some(until) => Instant::now() >= until,
            None => true,
        }
    }

    /// Gate one RPC to worker `w`: rejected while the breaker cools;
    /// once the cooldown elapses the caller is admitted as the one
    /// half-open probe (the window is pushed out so concurrent callers
    /// keep failing fast until the probe resolves).
    fn admit_rpc(&self, w: usize) -> bool {
        let mut open = self.states[w].breaker_open_until.lock().unwrap();
        match *open {
            None => true,
            Some(until) if Instant::now() < until => false,
            Some(_) => {
                *open = Some(Instant::now() + BREAKER_COOLDOWN);
                true
            }
        }
    }

    /// One heartbeat ping: a fresh short-timeout connection and a Ping
    /// RPC. Any reply frame counts as alive.
    fn ping(&self, w: usize) {
        let timeout = self.timeout.min(Duration::from_secs(1));
        let ok = (|| -> Result<(), String> {
            let sock_addr = self.addrs[w]
                .to_socket_addrs()
                .map_err(|e| e.to_string())?
                .next()
                .ok_or_else(|| "no address".to_string())?;
            let mut stream =
                TcpStream::connect_timeout(&sock_addr, timeout).map_err(|e| e.to_string())?;
            let _ = stream.set_read_timeout(Some(timeout));
            let _ = stream.set_write_timeout(Some(timeout));
            rpc_on(&mut stream, Op::Ping, &[]).map_err(|e| e.to_string())?;
            Ok(())
        })();
        match ok {
            Ok(()) => self.record_ok(w),
            Err(_) => self.record_failure(w),
        }
    }
}

fn heartbeat_loop(weak: Weak<Membership>, interval: Duration) {
    loop {
        let Some(membership) = weak.upgrade() else { return };
        for w in 0..membership.addrs.len() {
            membership.ping(w);
        }
        drop(membership);
        std::thread::sleep(interval);
    }
}

/// Why one RPC attempt failed.
enum RpcError {
    /// Could not reach the worker or the stream broke — the worker is
    /// marked dead.
    Transport(String),
    /// The worker replied with an error — it is alive (e.g. the
    /// residency was evicted), so it stays eligible for re-prepare.
    Remote(String),
}

impl RpcError {
    fn message(&self) -> &str {
        match self {
            RpcError::Transport(m) | RpcError::Remote(m) => m,
        }
    }
}

/// One blocking request/reply exchange. Outer error = transport, inner =
/// worker-side error string.
fn rpc_on(
    stream: &mut TcpStream,
    op: Op,
    payload: &[u8],
) -> Result<Result<Vec<u8>, String>, WireError> {
    wire::write_frame(stream, op, payload)?;
    let (reply_op, reply) = wire::read_frame(stream)?;
    match reply_op {
        Op::Ok => Ok(Ok(reply)),
        Op::Err => Ok(Err(String::from_utf8_lossy(&reply).into_owned())),
        other => Err(WireError::Malformed(format!("unexpected reply opcode {other:?}"))),
    }
}

/// One worker in the fleet: its address, a pool of warm connections, and
/// its row in the shared [`Membership`] table (liveness + breaker).
struct WorkerLink {
    addr: String,
    pool: Mutex<Vec<TcpStream>>,
    member: Arc<Membership>,
    /// This worker's row in `member`.
    index: usize,
    timeout: Duration,
}

impl WorkerLink {
    /// A standalone link with its own single-row membership table and no
    /// heartbeat — used for probes and one-off RPCs outside a fleet.
    fn new(addr: String, timeout: Duration) -> WorkerLink {
        let member = Membership::new(vec![addr.clone()], timeout);
        WorkerLink { addr, pool: Mutex::new(Vec::new()), member, index: 0, timeout }
    }

    /// A link sharing a fleet-wide membership table.
    fn in_fleet(
        addr: String,
        timeout: Duration,
        member: Arc<Membership>,
        index: usize,
    ) -> WorkerLink {
        WorkerLink { addr, pool: Mutex::new(Vec::new()), member, index, timeout }
    }

    fn liveness(&self) -> Liveness {
        self.member.liveness(self.index)
    }

    fn is_dead(&self) -> bool {
        self.liveness() == Liveness::Dead
    }

    fn connect(&self) -> Result<TcpStream, String> {
        let sock_addr = self
            .addr
            .to_socket_addrs()
            .map_err(|e| format!("cannot resolve {}: {e}", self.addr))?
            .next()
            .ok_or_else(|| format!("{} resolves to no address", self.addr))?;
        let stream = TcpStream::connect_timeout(&sock_addr, self.timeout)
            .map_err(|e| format!("cannot connect to {}: {e}", self.addr))?;
        let _ = stream.set_read_timeout(Some(self.timeout));
        let _ = stream.set_write_timeout(Some(self.timeout));
        let _ = stream.set_nodelay(true);
        Ok(stream)
    }

    /// One RPC over a pooled connection; a stale pooled connection gets
    /// exactly one fresh reconnect before the failure is recorded.
    ///
    /// The call is gated by the worker's circuit breaker: while it is
    /// open (cooling down after [`BREAKER_THRESHOLD`] consecutive
    /// failures), the RPC fails fast with a typed transport error
    /// instead of burning a connect timeout. A breaker rejection does
    /// **not** count as another failure.
    fn call(&self, op: Op, payload: &[u8]) -> Result<Vec<u8>, RpcError> {
        if !self.member.admit_rpc(self.index) {
            return Err(RpcError::Transport(format!("circuit breaker open to {}", self.addr)));
        }
        if let Some(mut stream) = self.pool.lock().unwrap().pop() {
            match rpc_on(&mut stream, op, payload) {
                Ok(Ok(bytes)) => {
                    self.member.record_ok(self.index);
                    self.pool.lock().unwrap().push(stream);
                    return Ok(bytes);
                }
                Ok(Err(msg)) => {
                    // An error *reply* still proves the worker is alive.
                    self.member.record_ok(self.index);
                    self.pool.lock().unwrap().push(stream);
                    return Err(RpcError::Remote(msg));
                }
                // Stale pooled connection (worker restarted, idle close):
                // drop it and fall through to a fresh connect.
                Err(_) => {}
            }
        }
        let mut stream = self.connect().map_err(|e| {
            self.member.record_failure(self.index);
            RpcError::Transport(e)
        })?;
        match rpc_on(&mut stream, op, payload) {
            Ok(Ok(bytes)) => {
                self.member.record_ok(self.index);
                self.pool.lock().unwrap().push(stream);
                Ok(bytes)
            }
            Ok(Err(msg)) => {
                self.member.record_ok(self.index);
                self.pool.lock().unwrap().push(stream);
                Err(RpcError::Remote(msg))
            }
            Err(e) => {
                self.member.record_failure(self.index);
                Err(RpcError::Transport(format!("rpc to {} failed: {e}", self.addr)))
            }
        }
    }

    /// [`WorkerLink::call`] wrapped in a `net.rpc` span when the calling
    /// thread carries a span context and a sink is installed.
    fn call_traced(
        &self,
        op: Op,
        payload: &[u8],
        op_name: &'static str,
        shard: usize,
        ctx: Option<(u64, u64)>,
    ) -> Result<Vec<u8>, RpcError> {
        let start = Instant::now();
        let result = self.call(op, payload);
        if let (Some((trace_id, parent)), Some(sink)) = (ctx, current_sink()) {
            sink.emit(
                SpanRecord::from_instants(
                    trace_id,
                    Some(parent),
                    "net.rpc",
                    start,
                    Instant::now(),
                )
                .tag("op", op_name)
                .tag("addr", self.addr.clone())
                .tag("shard", shard.to_string())
                .tag("outcome", if result.is_ok() { "ok" } else { "error" }),
            );
        }
        result
    }
}

/// Factory for distributed execution over a `sextans worker` fleet.
/// Spec: `remote:<addr>[,addr...][,replicas=R][,timeout_ms=T][,heartbeat_ms=H]`.
pub struct RemoteBackend {
    addrs: Vec<String>,
    replicas: usize,
    timeout: Duration,
    heartbeat: Duration,
}

impl RemoteBackend {
    /// Parse the spec argument (everything after `remote:`).
    pub fn from_spec(arg: Option<&str>) -> Result<RemoteBackend, BackendError> {
        let usage = "remote:<addr>[,addr...][,replicas=R][,timeout_ms=T][,heartbeat_ms=H] \
                     needs at least one <host:port> worker address";
        let Some(arg) = arg.filter(|a| !a.is_empty()) else {
            return Err(BackendError::InvalidSpec(usage.to_string()));
        };
        let mut addrs = Vec::new();
        let mut replicas = 1usize;
        let mut timeout = DEFAULT_TIMEOUT;
        let mut heartbeat = DEFAULT_HEARTBEAT;
        for part in arg.split(',') {
            let part = part.trim();
            if part.is_empty() {
                return Err(BackendError::InvalidSpec(format!(
                    "empty element in remote spec {arg:?}"
                )));
            }
            if let Some((key, value)) = part.split_once('=') {
                match key {
                    "replicas" => {
                        replicas = value.parse::<usize>().ok().filter(|&r| r >= 1).ok_or_else(
                            || {
                                BackendError::InvalidSpec(format!(
                                    "replicas= needs an integer >= 1, got {value:?}"
                                ))
                            },
                        )?;
                    }
                    "timeout_ms" => {
                        let ms = value.parse::<u64>().map_err(|_| {
                            BackendError::InvalidSpec(format!(
                                "timeout_ms= needs an integer, got {value:?}"
                            ))
                        })?;
                        timeout = Duration::from_millis(ms.max(1));
                    }
                    "heartbeat_ms" => {
                        let ms = value.parse::<u64>().map_err(|_| {
                            BackendError::InvalidSpec(format!(
                                "heartbeat_ms= needs an integer, got {value:?}"
                            ))
                        })?;
                        heartbeat = Duration::from_millis(ms.max(1));
                    }
                    other => {
                        return Err(BackendError::InvalidSpec(format!(
                            "unknown remote option {other:?} (expected replicas=, \
                             timeout_ms=, or heartbeat_ms=)"
                        )));
                    }
                }
            } else {
                let port_ok = part
                    .rsplit_once(':')
                    .is_some_and(|(host, port)| !host.is_empty() && port.parse::<u16>().is_ok());
                if !port_ok {
                    return Err(BackendError::InvalidSpec(format!(
                        "worker address {part:?} is not <host:port>"
                    )));
                }
                addrs.push(part.to_string());
            }
        }
        if addrs.is_empty() {
            return Err(BackendError::InvalidSpec(usage.to_string()));
        }
        Ok(RemoteBackend { addrs, replicas, timeout, heartbeat })
    }

    /// The configured worker addresses.
    pub fn addrs(&self) -> &[String] {
        &self.addrs
    }

    /// Availability probe: at least one worker must answer a Ping.
    /// [`crate::backend::check_available`] routes `remote:` specs here, so
    /// `sextans backends` and server startup report fleet reachability
    /// instead of assuming it.
    pub fn probe(&self) -> Result<(), BackendError> {
        let mut last_err = String::from("fleet is empty");
        for addr in &self.addrs {
            let link = WorkerLink::new(addr.clone(), self.timeout);
            match link.call(Op::Ping, &[]) {
                Ok(_) => return Ok(()),
                Err(e) => last_err = e.message().to_string(),
            }
        }
        Err(BackendError::Unavailable(format!(
            "no reachable worker in fleet [{}]: {last_err}",
            self.addrs.join(", ")
        )))
    }

    fn build(&self, image: Arc<ScheduledMatrix>) -> Result<PreparedRemote, BackendError> {
        let t0 = Instant::now();
        let fleet_size = self.addrs.len();
        // One shard per worker, but never more shards than rows.
        let s = fleet_size.min(image.m.max(1));
        let sharded = ShardedMatrix::from_image(&image, s);
        let imbalance = sharded.imbalance();
        let resident_bytes = sharded.resident_bytes();
        let weights: Vec<u64> = sharded.shards.iter().map(|sh| sh.image.nnz as u64).collect();
        let fleet: FleetPlan = placer::place(&weights, fleet_size, self.replicas);
        let membership =
            Membership::with_heartbeat(self.addrs.clone(), self.timeout, self.heartbeat);
        let workers: Vec<Arc<WorkerLink>> = self
            .addrs
            .iter()
            .enumerate()
            .map(|(w, a)| {
                Arc::new(WorkerLink::in_fleet(
                    a.clone(),
                    self.timeout,
                    Arc::clone(&membership),
                    w,
                ))
            })
            .collect();
        let shards: Vec<RemoteShard> = sharded
            .shards
            .into_iter()
            .map(|sh| RemoteShard {
                global_rows: sh.global_rows,
                image: sh.image,
                image_id: next_image_id(),
            })
            .collect();

        // Install every placement; a worker that fails its prepare is
        // routed around (the shard lands on any live worker instead), and
        // prepare only fails outright when a shard has nowhere to live.
        let mut placements: Vec<Vec<usize>> = vec![Vec::new(); shards.len()];
        for (i, shard) in shards.iter().enumerate() {
            let payload = wire::encode_prepare_req(shard.image_id, &shard.image);
            let mut last_err = String::from("no worker assigned");
            for &w in &fleet.assignments[i] {
                if workers[w].is_dead() {
                    continue;
                }
                match workers[w].call(Op::Prepare, &payload) {
                    Ok(_) => placements[i].push(w),
                    Err(e) => last_err = e.message().to_string(),
                }
            }
            if placements[i].is_empty() {
                for (w, link) in workers.iter().enumerate() {
                    if fleet.assignments[i].contains(&w) || link.is_dead() {
                        continue;
                    }
                    match link.call(Op::Prepare, &payload) {
                        Ok(_) => {
                            placements[i].push(w);
                            break;
                        }
                        Err(e) => last_err = e.message().to_string(),
                    }
                }
            }
            if placements[i].is_empty() {
                return Err(BackendError::Unavailable(format!(
                    "shard {i} of {} has no reachable worker in fleet [{}]: {last_err}",
                    shards.len(),
                    self.addrs.join(", ")
                )));
            }
        }

        let last_epoch = membership.epoch();
        Ok(PreparedRemote {
            image,
            shards,
            workers,
            membership,
            last_epoch: AtomicU64::new(last_epoch),
            placements: Mutex::new(placements),
            replicas: fleet.replicas,
            imbalance,
            scratch: ScratchPool::new(),
            last_stats: Mutex::new(None),
            cost: PrepareCost { wall: t0.elapsed(), resident_bytes },
            retries_total: AtomicU64::new(0),
            replaced_total: AtomicU64::new(0),
            rebalanced_total: AtomicU64::new(0),
        })
    }
}

impl SpmmBackend for RemoteBackend {
    fn name(&self) -> &'static str {
        "remote"
    }

    fn capability(&self) -> Capability {
        Capability {
            threads: self.addrs.len(),
            simd_lanes: 1,
            requires_artifacts: false,
            deterministic: true,
        }
    }

    fn prepare(&self, image: Arc<ScheduledMatrix>) -> Result<Box<dyn PreparedSpmm>, BackendError> {
        Ok(Box::new(self.build(image)?))
    }

    fn prepare_send(
        &self,
        image: Arc<ScheduledMatrix>,
    ) -> Result<Box<dyn PreparedSpmm + Send + Sync>, BackendError> {
        Ok(Box::new(self.build(image)?))
    }
}

/// One shard of a remote prepared matrix: the rows it owns, the image the
/// client keeps for re-placement, and its fleet-unique residency id.
struct RemoteShard {
    global_rows: Vec<u32>,
    image: Arc<ScheduledMatrix>,
    image_id: u64,
}

/// What one shard's fan-out thread produced.
struct ShardOutcome {
    latency: Duration,
    retries: usize,
    /// Worker index the shard was re-placed onto, when failover ran out
    /// of standing replicas.
    replaced: Option<usize>,
}

/// The distributed [`PreparedSpmm`] handle: shard residencies on remote
/// workers, execute via pooled RPCs with replica failover and re-place.
pub struct PreparedRemote {
    image: Arc<ScheduledMatrix>,
    shards: Vec<RemoteShard>,
    workers: Vec<Arc<WorkerLink>>,
    /// The fleet liveness table (heartbeat-fed) shared by every link.
    membership: Arc<Membership>,
    /// The membership epoch placements were last rebalanced against.
    last_epoch: AtomicU64,
    /// `placements[shard]` = worker indices holding it, preference order.
    /// Mutated by re-placement and rebalancing; dead holders sink to the
    /// back of each list but are kept, so a revived worker is reused
    /// without re-registering images it still holds.
    placements: Mutex<Vec<Vec<usize>>>,
    replicas: usize,
    imbalance: f64,
    /// Per-call gather blocks (one `rows_i × n` C block per shard).
    scratch: ScratchPool<Vec<Vec<f32>>>,
    last_stats: Mutex<Option<ShardRunStats>>,
    cost: PrepareCost,
    retries_total: AtomicU64,
    replaced_total: AtomicU64,
    rebalanced_total: AtomicU64,
}

impl PreparedRemote {
    /// Where every shard currently lives: (residency id, worker
    /// addresses in preference order). Exposed for tests and diagnostics.
    pub fn shard_locations(&self) -> Vec<(u64, Vec<String>)> {
        let placements = self.placements.lock().unwrap();
        self.shards
            .iter()
            .zip(placements.iter())
            .map(|(shard, ws)| {
                (shard.image_id, ws.iter().map(|&w| self.workers[w].addr.clone()).collect())
            })
            .collect()
    }

    /// Current fleet view as reported in [`ExecutionReport::remote`].
    fn remote_stats(&self, retries: usize, replaced: usize) -> RemoteStats {
        let placements: usize = self.placements.lock().unwrap().iter().map(Vec::len).sum();
        RemoteStats {
            workers: self.workers.len(),
            live_workers: self
                .workers
                .iter()
                .filter(|w| w.liveness() == Liveness::Live)
                .count(),
            placements,
            replicas: self.replicas,
            retries,
            replaced,
            breaker_trips: self.membership.breaker_trips() as usize,
            transitions: self.membership.transitions() as usize,
            rebalanced: self.rebalanced_total.load(Ordering::Relaxed) as usize,
        }
    }

    /// React to membership changes since the last execution: when the
    /// liveness epoch moved, recompute placements onto the current live
    /// set ([`placer::rebalance`]) and prepare any newly assigned
    /// holders, *before* the fan-out has to fail over reactively.
    /// Returns how many shards gained a placement.
    fn maybe_rebalance(&self, ctx: Option<(u64, u64)>) -> usize {
        let epoch = self.membership.epoch();
        if self.last_epoch.swap(epoch, Ordering::Relaxed) == epoch {
            return 0;
        }
        let live: Vec<bool> = (0..self.workers.len())
            .map(|w| self.membership.liveness(w) != Liveness::Dead)
            .collect();
        if !live.iter().any(|&l| l) {
            return 0;
        }
        let weights: Vec<u64> = self.shards.iter().map(|sh| sh.image.nnz as u64).collect();
        let mut moved = 0usize;
        let mut placements = self.placements.lock().unwrap();
        let desired = placer::rebalance(&placements, &weights, &live, self.replicas);
        for (i, want) in desired.iter().enumerate() {
            for &w in want {
                if placements[i].contains(&w) {
                    continue;
                }
                let payload =
                    wire::encode_prepare_req(self.shards[i].image_id, &self.shards[i].image);
                if self.workers[w].call_traced(Op::Prepare, &payload, "prepare", i, ctx).is_ok() {
                    placements[i].insert(0, w);
                    moved += 1;
                }
            }
        }
        if moved > 0 {
            self.rebalanced_total.fetch_add(moved as u64, Ordering::Relaxed);
        }
        moved
    }

    /// Run one shard: standing replicas in placement order, then
    /// re-place onto any live worker (preferring workers that do not
    /// already hold the shard, then re-preparing on live holders — which
    /// heals an evicted residency). `deadline`, when set, is checked
    /// before every attempt so an expired request stops issuing fleet
    /// RPCs instead of riding each retry to its timeout.
    #[allow(clippy::too_many_arguments)]
    fn run_shard(
        &self,
        i: usize,
        block: &mut Vec<f32>,
        b: &[f32],
        n: usize,
        alpha: f32,
        beta: f32,
        order: &[usize],
        ctx: Option<(u64, u64)>,
        deadline: Option<Instant>,
    ) -> Result<ShardOutcome, String> {
        let t0 = Instant::now();
        let shard = &self.shards[i];
        let total = self.shards.len();
        let expired = |last_err: &str| -> Option<String> {
            match deadline {
                Some(d) if Instant::now() >= d => Some(format!(
                    "shard {i} of {total} deadline exceeded before completion \
                     (last error: {last_err})"
                )),
                _ => None,
            }
        };
        let payload = wire::encode_execute_req(shard.image_id, n, alpha, beta, b, block);
        let mut retries = 0usize;
        let mut last_err = String::from("no replica placed");
        let mut last_addr = self.workers.first().map(|w| w.addr.clone()).unwrap_or_default();

        // One execute attempt on worker `w`: Ok(rows) on success, Err with
        // the failure described otherwise. Captures only the request
        // payload and expected reply length, so `block` stays free for
        // the caller to overwrite on success.
        let expect_len = block.len();
        let attempt = |w: usize| -> Result<Vec<f32>, String> {
            let link = &self.workers[w];
            let bytes = link
                .call_traced(Op::Execute, &payload, "execute", i, ctx)
                .map_err(|e| e.message().to_string())?;
            match wire::decode_execute_ok(&bytes) {
                Ok(rows) if rows.len() == expect_len => Ok(rows),
                Ok(rows) => {
                    Err(format!("reply has {} elements, expected {expect_len}", rows.len()))
                }
                Err(e) => Err(format!("bad execute reply: {e}")),
            }
        };

        for &w in order {
            if self.workers[w].is_dead() {
                continue;
            }
            if let Some(msg) = expired(&last_err) {
                return Err(msg);
            }
            match attempt(w) {
                Ok(rows) => {
                    *block = rows;
                    return Ok(ShardOutcome { latency: t0.elapsed(), retries, replaced: None });
                }
                Err(e) => {
                    retries += 1;
                    last_err = e;
                    last_addr = self.workers[w].addr.clone();
                }
            }
        }

        // Re-place: fresh workers first, then live current holders (a
        // re-prepare on a holder heals an evicted residency). Workers
        // whose breaker is cooling down are skipped without consuming
        // the half-open probe.
        let usable = |w: &usize| !self.workers[*w].is_dead() && self.membership.would_admit(*w);
        let mut candidates: Vec<usize> =
            (0..self.workers.len()).filter(|w| !order.contains(w)).filter(usable).collect();
        candidates.extend(order.iter().copied().filter(|w| usable(w)));
        let prepare_payload = wire::encode_prepare_req(shard.image_id, &shard.image);
        for w in candidates {
            if let Some(msg) = expired(&last_err) {
                return Err(msg);
            }
            if let Err(e) =
                self.workers[w].call_traced(Op::Prepare, &prepare_payload, "prepare", i, ctx)
            {
                last_err = e.message().to_string();
                last_addr = self.workers[w].addr.clone();
                continue;
            }
            match attempt(w) {
                Ok(rows) => {
                    *block = rows;
                    return Ok(ShardOutcome {
                        latency: t0.elapsed(),
                        retries,
                        replaced: Some(w),
                    });
                }
                Err(e) => {
                    retries += 1;
                    last_err = e;
                    last_addr = self.workers[w].addr.clone();
                }
            }
        }
        Err(format!("shard {i} of {total} on host {last_addr} failed: {last_err}"))
    }

    /// The full gather → remote fan-out → scatter execution.
    fn execute_remote(
        &self,
        b: &[f32],
        c: &mut [f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<ExecutionReport, BackendError> {
        check_shapes(&self.image, b, c, n)?;
        let ctx = trace::current_span_context();
        // Scoped threads do not inherit thread-locals: read the caller's
        // deadline here and hand the Copy value to every shard thread.
        let deadline = current_call_deadline();
        if let Some(d) = deadline {
            if Instant::now() >= d {
                return Err(BackendError::Execution(
                    "deadline exceeded before remote dispatch".to_string(),
                ));
            }
        }
        self.maybe_rebalance(ctx);
        let s = self.shards.len();

        let mut blocks = self.scratch.checkout(Vec::new);
        blocks.resize_with(s, Vec::new);
        for (i, shard) in self.shards.iter().enumerate() {
            let block = &mut blocks[i];
            block.resize(shard.global_rows.len() * n, 0.0);
            for (li, &gr) in shard.global_rows.iter().enumerate() {
                block[li * n..(li + 1) * n]
                    .copy_from_slice(&c[gr as usize * n..(gr as usize + 1) * n]);
            }
        }

        let order: Vec<Vec<usize>> = self.placements.lock().unwrap().clone();
        let outcomes: Vec<Result<ShardOutcome, String>> = std::thread::scope(|scope| {
            let handles: Vec<_> = blocks
                .iter_mut()
                .enumerate()
                .map(|(i, block)| {
                    let order_i = &order[i];
                    scope.spawn(move || {
                        self.run_shard(i, block, b, n, alpha, beta, order_i, ctx, deadline)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("remote shard thread panicked"))
                .collect()
        });

        // Fail before any scatter: a partial failure leaves C untouched.
        let mut run = Vec::with_capacity(s);
        for outcome in outcomes {
            match outcome {
                Ok(o) => run.push(o),
                Err(msg) => return Err(BackendError::Execution(msg)),
            }
        }

        // All shards succeeded: scatter the disjoint row blocks back,
        // shard-ascending (deterministic, rows are disjoint by plan).
        for (shard, block) in self.shards.iter().zip(blocks.iter()) {
            for (li, &gr) in shard.global_rows.iter().enumerate() {
                c[gr as usize * n..(gr as usize + 1) * n]
                    .copy_from_slice(&block[li * n..(li + 1) * n]);
            }
        }

        // Record re-placements so subsequent calls go straight to the
        // new holders. Dead holders sink to the back of the list instead
        // of being dropped: if the worker revives, its residency is
        // reused without a re-register.
        let retries: usize = run.iter().map(|o| o.retries).sum();
        let replaced: usize = run.iter().filter(|o| o.replaced.is_some()).count();
        if replaced > 0 {
            let mut placements = self.placements.lock().unwrap();
            for (i, outcome) in run.iter().enumerate() {
                if let Some(w) = outcome.replaced {
                    placements[i].retain(|&old| old != w);
                    placements[i].insert(0, w);
                    placements[i].sort_by_key(|&old| self.workers[old].is_dead());
                }
            }
        }
        self.retries_total.fetch_add(retries as u64, Ordering::Relaxed);
        self.replaced_total.fetch_add(replaced as u64, Ordering::Relaxed);

        let stats = ShardRunStats {
            shards: s,
            shard_nnz: self.shards.iter().map(|sh| sh.image.nnz).collect(),
            shard_latency: run.iter().map(|o| o.latency).collect(),
            imbalance: self.imbalance,
        };
        *self.last_stats.lock().unwrap() = Some(stats.clone());
        Ok(ExecutionReport {
            skipped: 0,
            shard_stats: Some(stats),
            remote: Some(self.remote_stats(retries, replaced)),
        })
    }
}

impl PreparedSpmm for PreparedRemote {
    fn backend_name(&self) -> &'static str {
        "remote"
    }

    fn prepare_cost(&self) -> PrepareCost {
        self.cost
    }

    fn execute(
        &self,
        b: &[f32],
        c: &mut [f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<(), BackendError> {
        self.execute_remote(b, c, n, alpha, beta).map(|_| ())
    }

    fn execute_with_report(
        &self,
        b: &[f32],
        c: &mut [f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<ExecutionReport, BackendError> {
        self.execute_remote(b, c, n, alpha, beta)
    }

    fn execute_routed_with_report(
        &self,
        b: &[f32],
        c: &mut [f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<ExecutionReport, BackendError> {
        // No shard skipping over the wire yet: route = plain execute, but
        // keep the remote counters attached to the report.
        self.execute_remote(b, c, n, alpha, beta)
    }

    fn shard_stats(&self) -> Option<ShardRunStats> {
        self.last_stats.lock().unwrap().clone()
    }

    fn resident_shards(&self) -> Option<usize> {
        Some(self.shards.len())
    }

    fn resident_bytes_now(&self) -> u64 {
        let pooled = self.scratch.measure(|blocks| {
            blocks.iter().map(|b| b.len() as u64 * 4).sum::<u64>()
        });
        self.cost.resident_bytes + pooled
    }

    fn trim_resident(&self, max_idle: Duration) -> u64 {
        self.scratch
            .trim_idle(max_idle, |blocks| blocks.iter().map(|b| b.len() as u64 * 4).sum())
    }
}

impl Drop for PreparedRemote {
    fn drop(&mut self) {
        // Best-effort fleet hygiene: release the shard residencies so
        // workers do not accumulate images across handle rebuilds.
        let placements = self.placements.lock().unwrap();
        for (shard, ws) in self.shards.iter().zip(placements.iter()) {
            let mut payload = wire::ByteWriter::new();
            payload.put_u64(shard.image_id);
            let payload = payload.into_bytes();
            for &w in ws {
                if !self.workers[w].is_dead() {
                    let _ = self.workers[w].call(Op::Evict, &payload);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::worker::{Worker, WorkerConfig};
    use crate::prop::assert_allclose;
    use crate::sched::preprocess;
    use crate::sparse::{gen, rng::Rng};
    use crate::telemetry::trace::TraceCollector;

    fn spawn_worker(spec: &str) -> String {
        let config = WorkerConfig {
            backend_spec: spec.to_string(),
            read_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(5),
            ..WorkerConfig::default()
        };
        let worker = Worker::bind("127.0.0.1:0", &config).unwrap();
        let addr = worker.local_addr().unwrap().to_string();
        std::thread::spawn(move || worker.run(&config).unwrap());
        addr
    }

    fn fleet_spec(addrs: &[String], extra: &str) -> String {
        if extra.is_empty() {
            addrs.join(",")
        } else {
            format!("{},{extra}", addrs.join(","))
        }
    }

    #[test]
    fn spec_parsing_accepts_fleets_and_options() {
        let be = RemoteBackend::from_spec(Some("127.0.0.1:7070,127.0.0.1:7071,replicas=2"))
            .unwrap();
        assert_eq!(be.addrs().len(), 2);
        assert_eq!(be.replicas, 2);
        let be =
            RemoteBackend::from_spec(Some("h1:1,timeout_ms=250")).unwrap();
        assert_eq!(be.timeout, Duration::from_millis(250));
        let be = RemoteBackend::from_spec(Some("h1:1,heartbeat_ms=40")).unwrap();
        assert_eq!(be.heartbeat, Duration::from_millis(40));
        assert!(RemoteBackend::from_spec(Some("h1:1,heartbeat_ms=soon")).is_err());
        assert!(RemoteBackend::from_spec(None).is_err());
        assert!(RemoteBackend::from_spec(Some("")).is_err());
        assert!(RemoteBackend::from_spec(Some("replicas=2")).is_err());
        assert!(RemoteBackend::from_spec(Some("no-port")).is_err());
        assert!(RemoteBackend::from_spec(Some("h:99999")).is_err());
        assert!(RemoteBackend::from_spec(Some("h:1,bogus=3")).is_err());
    }

    #[test]
    fn remote_over_two_workers_matches_local_reference() {
        let addrs = vec![spawn_worker("functional"), spawn_worker("functional")];
        let be = RemoteBackend::from_spec(Some(&fleet_spec(&addrs, ""))).unwrap();
        be.probe().unwrap();

        let mut rng = Rng::new(40);
        let coo = gen::random_uniform(50, 36, 0.15, &mut rng);
        let image = Arc::new(preprocess(&coo, 4, 12, 4));
        let handle = be.prepare_send(Arc::clone(&image)).unwrap();
        assert_eq!(handle.resident_shards(), Some(2));

        let n = 3;
        let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
        let mut got = c0.clone();
        let report = handle.execute_with_report(&b, &mut got, n, 1.5, -0.5).unwrap();
        let mut want = c0.clone();
        coo.spmm_reference(&b, &mut want, n, 1.5, -0.5);
        assert_allclose(&got, &want, 2e-4, 2e-4).unwrap();

        let remote = report.remote.expect("remote stats attached");
        assert_eq!(remote.workers, 2);
        assert_eq!(remote.live_workers, 2);
        assert_eq!(remote.placements, 2, "2 shards x 1 replica");
        assert_eq!(remote.retries, 0);
        assert_eq!(remote.replaced, 0);
        let stats = report.shard_stats.expect("shard stats attached");
        assert_eq!(stats.shards, 2);
    }

    #[test]
    fn replicated_placement_survives_an_evicted_replica() {
        let addrs = vec![spawn_worker("functional"), spawn_worker("functional")];
        let be =
            RemoteBackend::from_spec(Some(&fleet_spec(&addrs, "replicas=2"))).unwrap();

        let mut rng = Rng::new(41);
        let coo = gen::random_uniform(30, 24, 0.2, &mut rng);
        let image = Arc::new(preprocess(&coo, 2, 8, 3));
        let boxed = be.prepare_send(Arc::clone(&image)).unwrap();
        // Concrete type needed for shard_locations; re-prepare directly.
        let handle = be.build(Arc::clone(&image)).unwrap();
        drop(boxed);
        let locations = handle.shard_locations();
        assert_eq!(locations.len(), 2);
        for (_, ws) in &locations {
            assert_eq!(ws.len(), 2, "every shard is double-placed");
        }

        // Evict shard 0's residency from its primary worker, out of band.
        let (id, ws) = &locations[0];
        let link = WorkerLink::new(ws[0].clone(), Duration::from_secs(5));
        let mut payload = wire::ByteWriter::new();
        payload.put_u64(*id);
        assert_eq!(link.call(Op::Evict, &payload.into_bytes()).unwrap(), vec![1]);

        let n = 2;
        let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
        let mut got = c0.clone();
        let report = handle.execute_with_report(&b, &mut got, n, 1.0, 0.5).unwrap();
        let mut want = c0.clone();
        coo.spmm_reference(&b, &mut want, n, 1.0, 0.5);
        assert_allclose(&got, &want, 2e-4, 2e-4).unwrap();

        let remote = report.remote.unwrap();
        assert!(remote.retries >= 1, "the evicted replica costs a retry: {remote:?}");
        assert_eq!(remote.live_workers, 2, "an evicted residency is not a dead worker");
    }

    #[test]
    fn dead_worker_triggers_replace_and_correct_answer() {
        // Worker 1 exists at prepare time, then "dies" before execution:
        // simulate by binding a listener, preparing, then dropping it.
        let live = spawn_worker("functional");
        let doomed_config = WorkerConfig {
            backend_spec: "functional".to_string(),
            read_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(5),
            ..WorkerConfig::default()
        };
        let doomed = Worker::bind("127.0.0.1:0", &doomed_config).unwrap();
        let doomed_addr = doomed.local_addr().unwrap().to_string();
        let doomed_thread = {
            let cfg = doomed_config.clone();
            std::thread::spawn(move || doomed.run(&cfg).unwrap())
        };

        // A long heartbeat keeps the test deterministic: liveness moves
        // only through the execute path's own failures, never racing the
        // background pinger.
        let spec = format!("{live},{doomed_addr},timeout_ms=2000,heartbeat_ms=60000");
        let be = RemoteBackend::from_spec(Some(&spec)).unwrap();
        let mut rng = Rng::new(42);
        let coo = gen::random_uniform(40, 30, 0.2, &mut rng);
        let image = Arc::new(preprocess(&coo, 2, 8, 3));
        let handle = be.build(Arc::clone(&image)).unwrap();

        // Kill the doomed worker: shut its listener down so fresh
        // connections fail. Its pooled prepare-time connection is also
        // torn down because shutdown stops the accept loop and the
        // connection thread exits with the RPC below.
        {
            let link = WorkerLink::new(doomed_addr.clone(), Duration::from_secs(2));
            link.call(Op::Shutdown, &[]).unwrap();
        }
        doomed_thread.join().unwrap();

        let n = 2;
        let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
        let mut got = c0.clone();
        let report = handle.execute_with_report(&b, &mut got, n, 2.0, -1.0).unwrap();
        let mut want = c0.clone();
        coo.spmm_reference(&b, &mut want, n, 2.0, -1.0);
        assert_allclose(&got, &want, 2e-4, 2e-4).unwrap();

        let remote = report.remote.unwrap();
        assert!(remote.retries >= 1, "{remote:?}");
        assert!(remote.replaced >= 1, "the dead worker's shard must re-place: {remote:?}");
        assert_eq!(remote.live_workers, 1, "{remote:?}");

        // The next call uses the updated placement: no further retries.
        let mut again = c0.clone();
        let report = handle.execute_with_report(&b, &mut again, n, 2.0, -1.0).unwrap();
        assert_eq!(again, got, "post-re-place results stay deterministic");
        let remote = report.remote.unwrap();
        assert_eq!(remote.retries, 0, "{remote:?}");
        assert_eq!(remote.replaced, 0, "{remote:?}");
    }

    #[test]
    fn partial_failure_leaves_c_untouched() {
        // A fleet whose only worker is unreachable: prepare must fail
        // (nothing to place on), so build against a live worker, kill it,
        // then execute — C must be byte-identical to its seed.
        let cfg = WorkerConfig {
            backend_spec: "functional".to_string(),
            read_timeout: Duration::from_secs(2),
            write_timeout: Duration::from_secs(2),
            ..WorkerConfig::default()
        };
        let worker = Worker::bind("127.0.0.1:0", &cfg).unwrap();
        let addr = worker.local_addr().unwrap().to_string();
        let join = {
            let cfg = cfg.clone();
            std::thread::spawn(move || worker.run(&cfg).unwrap())
        };
        let spec = format!("{addr},timeout_ms=1000,heartbeat_ms=60000");
        let be = RemoteBackend::from_spec(Some(&spec)).unwrap();
        let mut rng = Rng::new(43);
        let coo = gen::random_uniform(20, 16, 0.25, &mut rng);
        let image = Arc::new(preprocess(&coo, 2, 8, 3));
        let handle = be.build(Arc::clone(&image)).unwrap();
        {
            let link = WorkerLink::new(addr, Duration::from_secs(2));
            link.call(Op::Shutdown, &[]).unwrap();
        }
        join.join().unwrap();

        let n = 2;
        let b = vec![1.0f32; coo.k * n];
        let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
        let mut c = c0.clone();
        let err = handle.execute(&b, &mut c, n, 1.0, 0.0).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("shard 0 of 1 on host"), "{msg}");
        assert_eq!(c, c0, "failed execution must leave C untouched");
    }

    #[test]
    fn membership_tracks_liveness_and_breaker_transitions() {
        let mb = Membership::new(vec!["127.0.0.1:1".into()], Duration::from_millis(100));
        assert_eq!(mb.liveness(0), Liveness::Live);
        assert!(mb.would_admit(0));
        mb.record_failure(0);
        assert_eq!(mb.liveness(0), Liveness::Suspect);
        assert!(mb.would_admit(0), "suspect workers are still tried");
        mb.record_failure(0);
        mb.record_failure(0);
        assert_eq!(mb.liveness(0), Liveness::Dead);
        assert_eq!(mb.breaker_trips(), 1);
        assert!(!mb.would_admit(0), "an open breaker rejects while cooling down");
        assert!(!mb.admit_rpc(0));
        mb.record_failure(0);
        assert_eq!(mb.breaker_trips(), 1, "re-arming an open breaker is not a new trip");
        mb.record_ok(0);
        assert_eq!(mb.liveness(0), Liveness::Live);
        assert!(mb.would_admit(0), "success closes the breaker");
        assert_eq!(mb.transitions(), 3, "Live -> Suspect -> Dead -> Live");
        assert_eq!(mb.epoch(), 3);
    }

    #[test]
    fn breaker_fails_fast_on_an_unreachable_worker() {
        // A port that refuses connections: bind a listener, note the
        // address, drop it.
        let refused = {
            let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            listener.local_addr().unwrap().to_string()
        };
        let link = WorkerLink::new(refused, Duration::from_millis(200));
        for _ in 0..BREAKER_THRESHOLD {
            let err = link.call(Op::Ping, &[]).err().expect("unreachable worker must fail");
            assert!(
                !err.message().contains("circuit breaker"),
                "pre-threshold calls reach the socket: {}",
                err.message()
            );
        }
        assert_eq!(link.liveness(), Liveness::Dead);
        let err = link.call(Op::Ping, &[]).err().expect("breaker must reject");
        assert!(
            err.message().contains("circuit breaker open"),
            "post-threshold calls fail fast: {}",
            err.message()
        );
    }

    #[test]
    fn revived_worker_is_reused_without_re_register() {
        let addrs = vec![spawn_worker("functional"), spawn_worker("functional")];
        let be = RemoteBackend::from_spec(Some(&fleet_spec(
            &addrs,
            "timeout_ms=2000,heartbeat_ms=25",
        )))
        .unwrap();
        let mut rng = Rng::new(45);
        let coo = gen::random_uniform(30, 20, 0.2, &mut rng);
        let image = Arc::new(preprocess(&coo, 2, 8, 3));
        let handle = be.build(Arc::clone(&image)).unwrap();

        // Falsely declare worker 1 dead. The worker is in fact alive, so
        // the heartbeat must revive it — and because its placements were
        // never discarded, the next execute reuses the residency it
        // still holds with no re-prepare. (The loop guards against a
        // heartbeat success interleaving with the injected failures.)
        for _ in 0..100 {
            handle.membership.record_failure(1);
            handle.membership.record_failure(1);
            handle.membership.record_failure(1);
            if handle.membership.breaker_trips() >= 1 {
                break;
            }
        }
        assert!(handle.membership.breaker_trips() >= 1, "injected failures must trip");
        let mut revived = false;
        for _ in 0..400 {
            if handle.membership.liveness(1) == Liveness::Live {
                revived = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(revived, "heartbeat must revive a falsely-dead worker");

        let n = 2;
        let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
        let mut got = c0.clone();
        let report = handle.execute_with_report(&b, &mut got, n, 1.0, 0.0).unwrap();
        let mut want = c0.clone();
        coo.spmm_reference(&b, &mut want, n, 1.0, 0.0);
        assert_allclose(&got, &want, 2e-4, 2e-4).unwrap();

        let remote = report.remote.unwrap();
        assert_eq!(remote.retries, 0, "revived worker serves its old residency: {remote:?}");
        assert_eq!(remote.replaced, 0, "{remote:?}");
        assert_eq!(remote.rebalanced, 0, "nothing to move, nothing re-registered: {remote:?}");
        assert_eq!(remote.live_workers, 2, "{remote:?}");
        assert!(remote.transitions >= 2, "{remote:?}");
        assert!(remote.breaker_trips >= 1, "{remote:?}");
    }

    #[test]
    fn heartbeat_death_rebalances_placements_proactively() {
        let addrs = vec![spawn_worker("functional"), spawn_worker("functional")];
        let be = RemoteBackend::from_spec(Some(&fleet_spec(
            &addrs,
            "timeout_ms=2000,heartbeat_ms=25",
        )))
        .unwrap();
        let mut rng = Rng::new(46);
        let coo = gen::random_uniform(40, 30, 0.2, &mut rng);
        let image = Arc::new(preprocess(&coo, 2, 8, 3));
        let handle = be.build(Arc::clone(&image)).unwrap();
        assert_eq!(handle.shards.len(), 2, "one shard per worker");

        // Kill worker 1 and wait for the heartbeat to notice.
        {
            let link = WorkerLink::new(addrs[1].clone(), Duration::from_secs(2));
            link.call(Op::Shutdown, &[]).unwrap();
        }
        let mut dead = false;
        for _ in 0..400 {
            if handle.membership.liveness(1) == Liveness::Dead {
                dead = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(dead, "heartbeat must mark a killed worker dead");

        // The next execute rebalances the orphaned shard onto the
        // survivor *before* fan-out, so no execute-path retry is needed.
        let n = 2;
        let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
        let mut got = c0.clone();
        let report = handle.execute_with_report(&b, &mut got, n, 2.0, -1.0).unwrap();
        let mut want = c0.clone();
        coo.spmm_reference(&b, &mut want, n, 2.0, -1.0);
        assert_allclose(&got, &want, 2e-4, 2e-4).unwrap();

        let remote = report.remote.unwrap();
        assert!(remote.rebalanced >= 1, "orphaned shard re-placed proactively: {remote:?}");
        assert_eq!(remote.retries, 0, "rebalance beats reactive failover: {remote:?}");
        assert_eq!(remote.replaced, 0, "{remote:?}");
        assert_eq!(remote.live_workers, 1, "{remote:?}");
        assert!(remote.breaker_trips >= 1, "{remote:?}");
        assert!(remote.transitions >= 2, "{remote:?}");
    }

    #[test]
    fn expired_deadline_short_circuits_before_fleet_rpcs() {
        let addrs = vec![spawn_worker("functional")];
        let be =
            RemoteBackend::from_spec(Some(&fleet_spec(&addrs, "heartbeat_ms=60000"))).unwrap();
        let mut rng = Rng::new(47);
        let coo = gen::random_uniform(20, 16, 0.25, &mut rng);
        let image = Arc::new(preprocess(&coo, 2, 8, 3));
        let handle = be.build(Arc::clone(&image)).unwrap();

        let n = 2;
        let b = vec![1.0f32; coo.k * n];
        let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
        let mut c = c0.clone();
        {
            let _guard = push_call_deadline(Instant::now());
            assert!(current_call_deadline().is_some());
            let err = handle.execute(&b, &mut c, n, 1.0, 0.0).unwrap_err();
            assert!(
                err.to_string().contains("deadline exceeded"),
                "typed deadline error, got: {err}"
            );
            assert_eq!(c, c0, "expired request must leave C untouched");
        }
        // Guard dropped: the deadline is gone and the same call runs.
        assert!(current_call_deadline().is_none());
        handle.execute(&b, &mut c, n, 1.0, 0.0).unwrap();
        let mut want = c0.clone();
        coo.spmm_reference(&b, &mut want, n, 1.0, 0.0);
        assert_allclose(&c, &want, 2e-4, 2e-4).unwrap();
    }

    #[test]
    fn rpc_spans_nest_under_the_pushed_context() {
        let addrs = vec![spawn_worker("functional")];
        let be = RemoteBackend::from_spec(Some(&fleet_spec(&addrs, ""))).unwrap();
        let mut rng = Rng::new(44);
        let coo = gen::random_uniform(16, 12, 0.3, &mut rng);
        let image = Arc::new(preprocess(&coo, 2, 8, 3));
        let handle = be.build(Arc::clone(&image)).unwrap();

        let collector = Arc::new(TraceCollector::new());
        set_telemetry_sink(Some(Arc::clone(&collector) as Arc<dyn TelemetrySink>));
        let n = 2;
        let b = vec![0.5f32; coo.k * n];
        let mut c = vec![0.0f32; coo.m * n];
        {
            let _guard = trace::push_span_context(77, 500);
            handle.execute(&b, &mut c, n, 1.0, 0.0).unwrap();
        }
        set_telemetry_sink(None);

        let spans: Vec<_> = collector
            .spans()
            .into_iter()
            .filter(|s| s.name == "net.rpc" && s.trace_id == 77)
            .collect();
        assert!(!spans.is_empty(), "execute must emit net.rpc spans");
        for s in &spans {
            assert_eq!(s.parent_id, Some(500), "net.rpc parents under the pushed span");
            assert!(s.tags.iter().any(|(k, _)| *k == "addr"));
        }
    }
}
