//! Deterministic fault injection for the distributed tier.
//!
//! A [`FaultSpec`] is a tiny comma-separated grammar describing which
//! failure modes to inject and how often; a [`FaultPlan`] turns the spec
//! into concrete per-event decisions driven by counter-indexed
//! splitmix64 — the same seed always yields the same schedule of
//! delays, drops, and corruptions, so every chaos run is reproducible
//! bit for bit. Two installation points:
//!
//! * **Server side** (`sextans worker --fault <spec>`): the worker wraps
//!   every accepted connection in a [`FaultStream`], refuses a fraction
//!   of accepts, and fails every nth RPC with a typed error reply.
//! * **Client side**: [`install_client_plan`] installs a plan for the
//!   current thread; the [`super::wire`] framing functions consult it on
//!   every frame written or read. Thread-local on purpose — a fault plan
//!   in one test can never leak into concurrently running tests.
//!
//! Corruption only ever touches the first eight header bytes (magic,
//! version, opcode) of a frame, never the length field or the payload:
//! every corrupt frame is *detectably* corrupt (a typed
//! [`super::wire::WireError`]), so chaos runs can assert "no wrong
//! answers ever" — payload integrity is TCP's job, and a flipped payload
//! byte would silently produce wrong floats instead of a typed error.
//!
//! Spec grammar (`,`-separated, every directive optional but at least
//! one required):
//!
//! ```text
//! seed=<u64>              decision-stream seed (default 0xFA017)
//! delay-read=<ms>[:<p>]   sleep <ms> before a read, with probability p (default 1)
//! drop=<p>                abort the connection before a read, with probability p
//! corrupt=<p>             flip one header byte of a written frame, with probability p
//! trickle=<bytes>:<ms>    write in <bytes>-sized pieces, sleeping <ms> between them
//! refuse=<p>              close an accepted connection immediately, with probability p
//! fail-nth=<n>            server only: every nth RPC replies with an injected error
//! ```
//!
//! Example: `seed=7,corrupt=0.1,trickle=64:1` corrupts ~10% of frames
//! and slow-trickles every write in 64-byte pieces with 1 ms pauses.

use std::cell::RefCell;
use std::io::{Read, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::wire::HEADER_BYTES;

/// Default decision-stream seed when the spec does not carry `seed=`.
pub const DEFAULT_FAULT_SEED: u64 = 0xFA017;

/// Parsed fault-injection directives. See the module docs for the spec
/// grammar. All directives are optional; [`FaultSpec::parse`] rejects an
/// empty spec so a typo'd `--fault` flag cannot silently inject nothing.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultSpec {
    /// Seed for every probabilistic decision stream.
    pub seed: u64,
    /// Sleep this long before a read, with the given probability.
    pub delay_read: Option<(Duration, f64)>,
    /// Probability of aborting the connection before a read.
    pub drop_conn: Option<f64>,
    /// Probability of flipping one header byte of a written frame.
    pub corrupt: Option<f64>,
    /// Write in pieces of this many bytes, sleeping between pieces.
    pub trickle: Option<(usize, Duration)>,
    /// Probability of refusing (immediately closing) an accepted
    /// connection. Server side only.
    pub refuse_accept: Option<f64>,
    /// Fail every nth RPC served with an injected error reply. Server
    /// side only.
    pub fail_nth_rpc: Option<u64>,
}

impl FaultSpec {
    /// Parse the `--fault` spec grammar. Errors name the offending
    /// directive; an empty spec is an error.
    pub fn parse(spec: &str) -> Result<FaultSpec, String> {
        let mut out = FaultSpec { seed: DEFAULT_FAULT_SEED, ..FaultSpec::default() };
        let mut directives = 0usize;
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                return Err(format!("empty directive in fault spec {spec:?}"));
            }
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| format!("fault directive {part:?} is not key=value"))?;
            match key {
                "seed" => {
                    out.seed = value
                        .parse::<u64>()
                        .map_err(|_| format!("seed= needs a u64, got {value:?}"))?;
                    // A bare seed is not a fault; require a real directive.
                    continue;
                }
                "delay-read" => {
                    let (ms, prob) = match value.split_once(':') {
                        Some((ms, p)) => (ms, parse_prob("delay-read", p)?),
                        None => (value, 1.0),
                    };
                    let ms = ms
                        .parse::<u64>()
                        .map_err(|_| format!("delay-read= needs <ms>[:<prob>], got {value:?}"))?;
                    out.delay_read = Some((Duration::from_millis(ms), prob));
                }
                "drop" => out.drop_conn = Some(parse_prob("drop", value)?),
                "corrupt" => out.corrupt = Some(parse_prob("corrupt", value)?),
                "trickle" => {
                    let (bytes, ms) = value
                        .split_once(':')
                        .ok_or_else(|| format!("trickle= needs <bytes>:<ms>, got {value:?}"))?;
                    let bytes = bytes
                        .parse::<usize>()
                        .ok()
                        .filter(|&b| b >= 1)
                        .ok_or_else(|| format!("trickle= needs bytes >= 1, got {value:?}"))?;
                    let ms = ms
                        .parse::<u64>()
                        .map_err(|_| format!("trickle= needs <bytes>:<ms>, got {value:?}"))?;
                    out.trickle = Some((bytes, Duration::from_millis(ms)));
                }
                "refuse" => out.refuse_accept = Some(parse_prob("refuse", value)?),
                "fail-nth" => {
                    out.fail_nth_rpc = Some(
                        value
                            .parse::<u64>()
                            .ok()
                            .filter(|&n| n >= 1)
                            .ok_or_else(|| format!("fail-nth= needs n >= 1, got {value:?}"))?,
                    );
                }
                other => return Err(format!("unknown fault directive {other:?}")),
            }
            directives += 1;
        }
        if directives == 0 {
            return Err(format!("fault spec {spec:?} has no fault directive"));
        }
        Ok(out)
    }
}

fn parse_prob(key: &str, value: &str) -> Result<f64, String> {
    value
        .parse::<f64>()
        .ok()
        .filter(|p| (0.0..=1.0).contains(p))
        .ok_or_else(|| format!("{key}= needs a probability in [0, 1], got {value:?}"))
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

// Distinct decision streams per fault kind, so `corrupt=` decisions
// never shift when `drop=` is added to the same spec.
const SALT_DELAY: u64 = 0xDE1A;
const SALT_DROP: u64 = 0xD209;
const SALT_CORRUPT: u64 = 0xC022;
const SALT_REFUSE: u64 = 0x2EF5;

/// A live fault plan: the parsed spec plus the per-event counters that
/// index its decision streams. Shared (`Arc`) across the connections of
/// one worker so the event counters — and therefore the injected
/// schedule — are process-wide and reproducible from the seed.
pub struct FaultPlan {
    spec: FaultSpec,
    reads: AtomicU64,
    frames: AtomicU64,
    accepts: AtomicU64,
    rpcs: AtomicU64,
}

impl FaultPlan {
    /// Build a plan over a parsed spec with all event counters at zero.
    pub fn new(spec: FaultSpec) -> FaultPlan {
        FaultPlan {
            spec,
            reads: AtomicU64::new(0),
            frames: AtomicU64::new(0),
            accepts: AtomicU64::new(0),
            rpcs: AtomicU64::new(0),
        }
    }

    /// The spec this plan executes.
    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// Deterministic uniform sample in `[0, 1)` for event `i` of the
    /// `salt` decision stream.
    fn unit(&self, salt: u64, i: u64) -> f64 {
        let bits = splitmix64(self.spec.seed ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ i);
        (bits >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Should this accepted connection be refused (closed immediately)?
    /// Consumes one accept event.
    pub fn refuse_accept(&self) -> bool {
        let Some(prob) = self.spec.refuse_accept else { return false };
        let i = self.accepts.fetch_add(1, Ordering::Relaxed);
        self.unit(SALT_REFUSE, i) < prob
    }

    /// Should this RPC be failed with an injected error reply? Counts
    /// RPCs from 1, so `fail-nth=3` fails RPCs 3, 6, 9, ...
    pub fn fail_rpc(&self) -> bool {
        let Some(n) = self.spec.fail_nth_rpc else { return false };
        let i = self.rpcs.fetch_add(1, Ordering::Relaxed);
        (i + 1) % n == 0
    }

    /// Apply pre-read faults: delay-before-read, then drop-connection
    /// (an injected `ConnectionReset`). Consumes one read event.
    pub fn before_read(&self) -> std::io::Result<()> {
        let i = self.reads.fetch_add(1, Ordering::Relaxed);
        if let Some((delay, prob)) = self.spec.delay_read {
            if self.unit(SALT_DELAY, i) < prob {
                std::thread::sleep(delay);
            }
        }
        if let Some(prob) = self.spec.drop_conn {
            if self.unit(SALT_DROP, i) < prob {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::ConnectionReset,
                    "fault: connection dropped by plan",
                ));
            }
        }
        Ok(())
    }

    /// Corruption decision for the next frame: `Some(byte)` with the
    /// header byte index (always < 8 — magic/version/opcode, never the
    /// length field or payload, so corruption is always detectable).
    /// Consumes one frame event.
    pub fn corrupt_decision(&self) -> Option<usize> {
        let prob = self.spec.corrupt?;
        let i = self.frames.fetch_add(1, Ordering::Relaxed);
        if self.unit(SALT_CORRUPT, i) < prob {
            Some((splitmix64(self.spec.seed ^ SALT_CORRUPT ^ i.wrapping_mul(31)) % 8) as usize)
        } else {
            None
        }
    }

    /// Flip one detectable header byte of a frame about to be written,
    /// when this frame's corruption decision says so. Returns whether
    /// the header was corrupted.
    pub fn corrupt_frame_header(&self, header: &mut [u8]) -> bool {
        match self.corrupt_decision() {
            Some(at) if at < header.len() => {
                // XOR always changes the byte; 0x40 maps every valid
                // magic/version/opcode value onto an invalid one.
                header[at] ^= 0x40;
                true
            }
            _ => false,
        }
    }

    /// The slow-byte-trickle directive, if any: (piece bytes, pause).
    pub fn trickle(&self) -> Option<(usize, Duration)> {
        self.spec.trickle
    }
}

// ---------------------------------------------------------------------------
// Client-path injection hook (consulted by `wire::write_frame` /
// `wire::read_frame_opt`)
// ---------------------------------------------------------------------------

thread_local! {
    static CLIENT_PLAN: RefCell<Option<Arc<FaultPlan>>> = const { RefCell::new(None) };
}

/// Install `plan` as this thread's client-side fault plan; the wire
/// framing functions consult it on every frame until the returned guard
/// drops (restoring whatever was installed before). Thread-local so a
/// plan in one test cannot leak into concurrently running tests.
pub fn install_client_plan(plan: Arc<FaultPlan>) -> ClientPlanGuard {
    let prev = CLIENT_PLAN.with(|c| c.replace(Some(plan)));
    ClientPlanGuard { prev }
}

/// The fault plan installed on this thread, if any.
pub fn client_plan() -> Option<Arc<FaultPlan>> {
    CLIENT_PLAN.with(|c| c.borrow().clone())
}

/// RAII restore for [`install_client_plan`].
pub struct ClientPlanGuard {
    prev: Option<Arc<FaultPlan>>,
}

impl Drop for ClientPlanGuard {
    fn drop(&mut self) {
        CLIENT_PLAN.with(|c| *c.borrow_mut() = self.prev.take());
    }
}

// ---------------------------------------------------------------------------
// FaultStream
// ---------------------------------------------------------------------------

/// A `Read + Write` wrapper injecting the plan's stream-level faults:
/// delay-before-read and drop-connection on the read side; corrupt-frame
/// and slow-byte-trickle on the write side. The write side tracks frame
/// boundaries (header + declared payload length) across arbitrarily
/// segmented writes, so corruption lands on exactly one header byte per
/// corrupted frame no matter how the caller chunks its writes.
pub struct FaultStream<S> {
    inner: S,
    plan: Arc<FaultPlan>,
    /// Byte offset within the current outgoing frame.
    pos: usize,
    /// Accumulated (uncorrupted) header of the current outgoing frame.
    header: [u8; HEADER_BYTES],
    /// Payload length parsed from the header (valid once `pos` >=
    /// [`HEADER_BYTES`]).
    payload_len: usize,
    /// Header byte to flip in the current frame, when corrupting.
    corrupt_at: Option<usize>,
}

impl<S> FaultStream<S> {
    /// Wrap `inner` under `plan`.
    pub fn new(inner: S, plan: Arc<FaultPlan>) -> FaultStream<S> {
        FaultStream {
            inner,
            plan,
            pos: 0,
            header: [0u8; HEADER_BYTES],
            payload_len: 0,
            corrupt_at: None,
        }
    }

    /// Walk `out` through the frame-boundary tracker, flipping the
    /// corrupted header byte in place when this frame's decision hit.
    fn track_frames(&mut self, out: &mut [u8]) {
        for idx in 0..out.len() {
            if self.pos == 0 {
                self.corrupt_at = self.plan.corrupt_decision();
            }
            if self.pos < HEADER_BYTES {
                self.header[self.pos] = out[idx];
                if self.corrupt_at == Some(self.pos) {
                    out[idx] ^= 0x40;
                }
                if self.pos == HEADER_BYTES - 1 {
                    self.payload_len =
                        u32::from_le_bytes(self.header[8..12].try_into().unwrap()) as usize;
                }
            }
            self.pos += 1;
            if self.pos >= HEADER_BYTES && self.pos == HEADER_BYTES + self.payload_len {
                self.pos = 0;
            }
        }
    }
}

impl<S: Read> Read for FaultStream<S> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        self.plan.before_read()?;
        self.inner.read(buf)
    }
}

impl<S: Write> Write for FaultStream<S> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let mut out = buf.to_vec();
        self.track_frames(&mut out);
        match self.plan.trickle() {
            Some((piece, pause)) => {
                for chunk in out.chunks(piece.max(1)) {
                    self.inner.write_all(chunk)?;
                    std::thread::sleep(pause);
                }
            }
            None => self.inner.write_all(&out)?,
        }
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::wire::{self, Op, WireError};

    #[test]
    fn spec_parsing_accepts_the_grammar_and_rejects_garbage() {
        let spec =
            FaultSpec::parse("seed=7,delay-read=5:0.5,drop=0.25,corrupt=0.1,trickle=64:1")
                .unwrap();
        assert_eq!(spec.seed, 7);
        assert_eq!(spec.delay_read, Some((Duration::from_millis(5), 0.5)));
        assert_eq!(spec.drop_conn, Some(0.25));
        assert_eq!(spec.corrupt, Some(0.1));
        assert_eq!(spec.trickle, Some((64, Duration::from_millis(1))));

        let spec = FaultSpec::parse("refuse=1,fail-nth=3").unwrap();
        assert_eq!(spec.refuse_accept, Some(1.0));
        assert_eq!(spec.fail_nth_rpc, Some(3));
        assert_eq!(spec.seed, DEFAULT_FAULT_SEED);

        for bad in [
            "", "seed=7", "bogus=1", "drop=1.5", "drop=x", "trickle=64", "trickle=0:1",
            "fail-nth=0", "delay-read=abc", "corrupt",
        ] {
            assert!(FaultSpec::parse(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn decisions_are_deterministic_from_the_seed() {
        let mk = || FaultPlan::new(FaultSpec::parse("seed=11,refuse=0.5,corrupt=0.5").unwrap());
        let (a, b) = (mk(), mk());
        let seq_a: Vec<(bool, Option<usize>)> =
            (0..64).map(|_| (a.refuse_accept(), a.corrupt_decision())).collect();
        let seq_b: Vec<(bool, Option<usize>)> =
            (0..64).map(|_| (b.refuse_accept(), b.corrupt_decision())).collect();
        assert_eq!(seq_a, seq_b, "same seed, same schedule");
        assert!(seq_a.iter().any(|(r, _)| *r), "p=0.5 over 64 events must fire");
        assert!(seq_a.iter().any(|(r, _)| !*r), "p=0.5 over 64 events must also pass");
    }

    #[test]
    fn fail_nth_fails_exactly_every_nth_rpc() {
        let plan = FaultPlan::new(FaultSpec::parse("fail-nth=3").unwrap());
        let got: Vec<bool> = (0..9).map(|_| plan.fail_rpc()).collect();
        assert_eq!(got, vec![false, false, true, false, false, true, false, false, true]);
    }

    #[test]
    fn corrupted_frames_are_always_detected_never_misparsed() {
        // corrupt=1: every frame written through the stream is corrupted,
        // and every one must surface as a typed WireError — header-only
        // corruption can never silently alter a payload.
        let plan = Arc::new(FaultPlan::new(FaultSpec::parse("seed=3,corrupt=1").unwrap()));
        for round in 0u8..16 {
            let mut fs = FaultStream::new(Vec::new(), Arc::clone(&plan));
            let payload = vec![round; 5];
            wire::write_frame(&mut fs, Op::Execute, &payload).unwrap();
            let buf = fs.inner;
            let err = wire::read_frame(&mut buf.as_slice()).unwrap_err();
            assert!(
                matches!(
                    err,
                    WireError::BadMagic(_) | WireError::Version { .. } | WireError::BadOpcode(_)
                ),
                "round {round}: corrupt frame must be typed-rejected, got {err:?}"
            );
            // The payload bytes themselves are untouched.
            assert_eq!(&buf[HEADER_BYTES..], &payload[..]);
        }
    }

    #[test]
    fn trickled_frames_roundtrip_bit_identical() {
        let plan = Arc::new(FaultPlan::new(FaultSpec::parse("trickle=3:0").unwrap()));
        let mut fs = FaultStream::new(Vec::new(), Arc::clone(&plan));
        let payload: Vec<u8> = (0..37).collect();
        wire::write_frame(&mut fs, Op::Stats, &payload).unwrap();
        wire::write_frame(&mut fs, Op::Ping, &[]).unwrap();
        let buf = fs.inner;
        let mut r = buf.as_slice();
        let (op, got) = wire::read_frame(&mut r).unwrap();
        assert_eq!((op, got), (Op::Stats, payload));
        let (op, got) = wire::read_frame(&mut r).unwrap();
        assert_eq!((op, got), (Op::Ping, Vec::new()));
    }

    #[test]
    fn frame_tracking_survives_byte_at_a_time_writes() {
        // Write two frames one byte per write() call: corruption must
        // still land on exactly one header byte of each frame.
        let plan = Arc::new(FaultPlan::new(FaultSpec::parse("seed=5,corrupt=1").unwrap()));
        let mut encoded = Vec::new();
        wire::write_frame(&mut encoded, Op::Ping, b"abc").unwrap();
        wire::write_frame(&mut encoded, Op::Stats, b"").unwrap();
        let mut fs = FaultStream::new(Vec::new(), Arc::clone(&plan));
        for &b in &encoded {
            fs.write_all(std::slice::from_ref(&b)).unwrap();
        }
        let buf = fs.inner;
        assert_eq!(buf.len(), encoded.len());
        let flipped: Vec<usize> =
            (0..buf.len()).filter(|&i| buf[i] != encoded[i]).collect();
        assert_eq!(flipped.len(), 2, "one flipped byte per frame: {flipped:?}");
        let frame2 = HEADER_BYTES + 3;
        assert!(flipped[0] < 8, "first flip inside frame 1 header: {flipped:?}");
        assert!(
            (frame2..frame2 + 8).contains(&flipped[1]),
            "second flip inside frame 2 header: {flipped:?}"
        );
    }

    #[test]
    fn drop_connection_surfaces_as_a_read_error() {
        let plan = Arc::new(FaultPlan::new(FaultSpec::parse("drop=1").unwrap()));
        let mut encoded = Vec::new();
        wire::write_frame(&mut encoded, Op::Ping, b"").unwrap();
        let mut fs = FaultStream::new(encoded.as_slice(), plan);
        let err = wire::read_frame(&mut fs).unwrap_err();
        assert!(matches!(err, WireError::Io(_)), "{err:?}");
    }

    #[test]
    fn client_hook_injects_on_this_thread_only_and_restores() {
        let plan = Arc::new(FaultPlan::new(FaultSpec::parse("seed=9,corrupt=1").unwrap()));
        {
            let _guard = install_client_plan(Arc::clone(&plan));
            assert!(client_plan().is_some());
            let mut buf = Vec::new();
            wire::write_frame(&mut buf, Op::Ping, b"x").unwrap();
            assert!(wire::read_frame(&mut buf.as_slice()).is_err(), "hook must corrupt");
            // Another thread sees no plan.
            std::thread::spawn(|| assert!(client_plan().is_none())).join().unwrap();
        }
        assert!(client_plan().is_none(), "guard drop restores");
        let mut buf = Vec::new();
        wire::write_frame(&mut buf, Op::Ping, b"x").unwrap();
        assert!(wire::read_frame(&mut buf.as_slice()).is_ok(), "no hook, clean frame");
    }
}
