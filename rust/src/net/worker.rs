//! The `sextans worker` process: a socket server holding prepared shard
//! residencies and serving prepare/execute/stats/evict RPCs.
//!
//! One worker is one address space of the distributed fleet. A client
//! (the `remote:<addr>` backend) ships [`crate::sched::ScheduledMatrix`]
//! images over the [`super::wire`] framing; the worker prepares them
//! through its own local backend spec (any registry spec — `native:2`,
//! `functional`, even `sharded:2:native`) and keeps the resulting
//! [`PreparedSpmm`] handles resident under client-assigned image ids.
//! Execute RPCs then carry only the dense operands.
//!
//! Concurrency model: one thread per connection, handles shared as
//! `Arc<dyn PreparedSpmm + Send + Sync>` — the PR 5 `&self` execution
//! contract means two connections executing against the same resident
//! image run concurrently, exactly like in-process workers. Per-request
//! framing plus read/write timeouts bound how long a dead or stalled peer
//! can pin a connection thread.
//!
//! Every reply is a frame: [`Op::Ok`] with an op-specific payload, or
//! [`Op::Err`] carrying the error message — a worker failure becomes a
//! typed error on the client, never a hung socket.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::fault::{FaultPlan, FaultSpec, FaultStream};
use super::wire::{
    self, decode_execute_req, decode_prepare_req, encode_cost, encode_execute_ok,
    encode_stats_ok, ByteReader, ByteWriter, Op, WireError, WorkerStats,
};
use crate::backend::{self, PreparedSpmm};
use crate::coordinator::ResidencyPolicy;

/// Worker process configuration.
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    /// Registry spec the worker prepares images through.
    pub backend_spec: String,
    /// Per-connection socket read timeout (a blocked peer, not an idle
    /// one, is the failure this bounds; an idle close is handled cleanly).
    pub read_timeout: Duration,
    /// Per-connection socket write timeout.
    pub write_timeout: Duration,
    /// Optional residency byte budget, sharing [`ResidencyPolicy`] with
    /// the coordinator's in-process cache (`sextans worker
    /// --max-resident-mb`). Enforced twice: before `prepare`, against a
    /// conservative estimate from the decoded image's stream footprint
    /// (so the prepare transient itself cannot spike far past the
    /// budget), and after `prepare`, against the handle's exact retained
    /// bytes. Either refusal is a typed error — the client sees a
    /// [`WireError`], never an OOM-killed worker. `None` (the default)
    /// leaves residency unbounded.
    pub residency: Option<ResidencyPolicy>,
    /// Optional seeded fault plan (`sextans worker --fault <spec>`):
    /// refused accepts, delayed/dropped reads, corrupted reply headers,
    /// trickled replies, and injected per-RPC failures — all
    /// deterministic from the spec's seed so chaos runs reproduce.
    pub fault: Option<FaultSpec>,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        WorkerConfig {
            backend_spec: "native".to_string(),
            read_timeout: Duration::from_secs(30),
            write_timeout: Duration::from_secs(30),
            residency: None,
            fault: None,
        }
    }
}

/// One resident prepared image.
struct Resident {
    handle: Arc<dyn PreparedSpmm + Send + Sync>,
}

/// Shared state across connection threads.
struct WorkerState {
    spec: String,
    resident: Mutex<HashMap<u64, Resident>>,
    executes: AtomicU64,
    shutdown: AtomicBool,
    /// Residency byte budget ([`WorkerConfig::residency`]), if bounded.
    max_resident_bytes: Option<u64>,
}

impl WorkerState {
    /// Resident bytes across all images except `id` — re-preparing an id
    /// replaces its old residency, so its bytes don't count against the
    /// incoming prepare.
    fn resident_bytes_excluding(&self, id: u64) -> u64 {
        self.resident
            .lock()
            .unwrap()
            .iter()
            .filter(|(rid, _)| **rid != id)
            .map(|(_, r)| r.handle.resident_bytes_now())
            .sum()
    }

    fn stats(&self) -> WorkerStats {
        let resident = self.resident.lock().unwrap();
        WorkerStats {
            resident: resident.len() as u64,
            resident_bytes: resident.values().map(|r| r.handle.resident_bytes_now()).sum(),
            executes: self.executes.load(Ordering::Relaxed),
        }
    }
}

/// A running worker: the bound listener plus its shared state. Produced
/// by [`Worker::bind`]; [`Worker::run`] serves until a Shutdown RPC.
pub struct Worker {
    listener: TcpListener,
    state: Arc<WorkerState>,
}

impl Worker {
    /// Bind to `addr` (`host:port`; port 0 picks a free port — the actual
    /// address is available via [`Worker::local_addr`]).
    pub fn bind(addr: &str, config: &WorkerConfig) -> std::io::Result<Worker> {
        backend::create(&config.backend_spec).map_err(|e| {
            std::io::Error::new(std::io::ErrorKind::InvalidInput, e.to_string())
        })?;
        let listener = TcpListener::bind(addr)?;
        Ok(Worker {
            listener,
            state: Arc::new(WorkerState {
                spec: config.backend_spec.clone(),
                resident: Mutex::new(HashMap::new()),
                executes: AtomicU64::new(0),
                shutdown: AtomicBool::new(false),
                max_resident_bytes: config.residency.as_ref().map(|r| r.max_resident_bytes),
            }),
        })
    }

    /// The actually bound address (resolves port 0).
    pub fn local_addr(&self) -> std::io::Result<std::net::SocketAddr> {
        self.listener.local_addr()
    }

    /// Accept and serve connections until a Shutdown RPC arrives. Each
    /// connection gets its own thread; a connection-level protocol error
    /// closes that connection only. A configured fault plan wraps every
    /// accepted stream (and may refuse the accept outright).
    pub fn run(self, config: &WorkerConfig) -> std::io::Result<()> {
        let plan = config.fault.as_ref().map(|spec| Arc::new(FaultPlan::new(spec.clone())));
        for conn in self.listener.incoming() {
            if self.state.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let stream = match conn {
                Ok(s) => s,
                Err(_) => continue,
            };
            if let Some(plan) = &plan {
                if plan.refuse_accept() {
                    // Injected refusal: close the connection before any
                    // frame flows — the client sees a clean reset.
                    drop(stream);
                    continue;
                }
            }
            let _ = stream.set_read_timeout(Some(config.read_timeout));
            let _ = stream.set_write_timeout(Some(config.write_timeout));
            let _ = stream.set_nodelay(true);
            let state = Arc::clone(&self.state);
            let plan = plan.clone();
            std::thread::spawn(move || {
                // The shutdown self-connect needs the raw address, which
                // a wrapped stream no longer exposes: capture it first.
                let self_addr = stream.local_addr().ok();
                match plan {
                    Some(p) => {
                        let faulty = FaultStream::new(stream, Arc::clone(&p));
                        serve_connection(faulty, &state, Some(&p), self_addr)
                    }
                    None => serve_connection(stream, &state, None, self_addr),
                }
            });
            if self.state.shutdown.load(Ordering::SeqCst) {
                break;
            }
        }
        Ok(())
    }
}

/// Serve one connection's request loop until EOF, error, or shutdown.
/// Generic over the stream so a [`FaultStream`]-wrapped connection runs
/// the exact same protocol loop as a clean [`TcpStream`].
fn serve_connection<S: Read + Write>(
    mut stream: S,
    state: &Arc<WorkerState>,
    plan: Option<&FaultPlan>,
    self_addr: Option<SocketAddr>,
) {
    loop {
        let (op, payload) = match wire::read_frame_opt(&mut stream) {
            Ok(Some(frame)) => frame,
            // Clean close between frames, or a broken/timed-out peer:
            // either way this connection is done.
            Ok(None) | Err(_) => return,
        };
        // A shut-down worker stops serving standing connections too —
        // the peer sees the close and fails over exactly as it would to
        // a killed process.
        if state.shutdown.load(Ordering::SeqCst) {
            return;
        }
        // Injected per-RPC failure: the request is decoded fine but the
        // worker answers with a typed error instead of doing the work.
        let reply = match plan {
            Some(p) if p.fail_rpc() => {
                Err(format!("injected fault: {op:?} failed by plan"))
            }
            _ => handle_request(op, &payload, state),
        };
        let (reply_op, reply_payload) = match &reply {
            Ok(bytes) => (Op::Ok, bytes.as_slice()),
            Err(msg) => (Op::Err, msg.as_bytes()),
        };
        if wire::write_frame(&mut stream, reply_op, reply_payload).is_err() {
            return;
        }
        if op == Op::Shutdown {
            let _ = stream.flush();
            // Unblock the accept loop so `run` observes the flag.
            if let Some(addr) = self_addr {
                let _ = TcpStream::connect(addr);
            }
            return;
        }
    }
}

/// Dispatch one RPC. `Ok` carries the success payload, `Err` the message
/// for an [`Op::Err`] reply.
fn handle_request(op: Op, payload: &[u8], state: &Arc<WorkerState>) -> Result<Vec<u8>, String> {
    match op {
        Op::Ping => Ok(Vec::new()),
        Op::Prepare => {
            let (id, image) =
                decode_prepare_req(payload).map_err(|e| format!("prepare: {e}"))?;
            // Refuse before materializing when the image's own stream
            // footprint already busts the budget: prepare pins at least
            // the decoded streams, so checking only after prepare_send
            // would let peak memory spike far past --max-resident-mb
            // before the typed refusal. The exact retained-bytes check
            // below still decides the final residency.
            if let Some(max) = state.max_resident_bytes {
                let estimate = image.a_stream_bytes();
                let in_use = state.resident_bytes_excluding(id);
                if in_use.saturating_add(estimate) > max {
                    return Err(format!(
                        "prepare: residency budget exceeded: image {id} streams \
                         {estimate} B before prepare, {in_use} of {max} B in use"
                    ));
                }
            }
            let handle = backend::prepare_send(&state.spec, Arc::new(image))
                .map_err(|e| format!("prepare: {e}"))?;
            let cost = handle.prepare_cost();
            // Budget check and insert under one lock so two concurrent
            // prepares cannot both squeeze past the limit. Re-preparing
            // an id replaces the old residency, so its bytes don't count
            // against the new handle.
            let mut resident = state.resident.lock().unwrap();
            if let Some(max) = state.max_resident_bytes {
                let in_use: u64 = resident
                    .iter()
                    .filter(|(rid, _)| **rid != id)
                    .map(|(_, r)| r.handle.resident_bytes_now())
                    .sum();
                if in_use + cost.resident_bytes > max {
                    return Err(format!(
                        "prepare: residency budget exceeded: image {id} needs {} B, \
                         {in_use} of {max} B in use",
                        cost.resident_bytes
                    ));
                }
            }
            resident.insert(id, Resident { handle: Arc::from(handle) });
            Ok(encode_cost(&cost))
        }
        Op::Execute => {
            let (id, n, alpha, beta, b, mut c) =
                decode_execute_req(payload).map_err(|e| format!("execute: {e}"))?;
            // Clone the Arc out so the residency lock never covers the
            // multiply — concurrent connections execute in parallel.
            let handle = {
                let resident = state.resident.lock().unwrap();
                match resident.get(&id) {
                    Some(r) => Arc::clone(&r.handle),
                    None => return Err(format!("execute: image {id} is not resident")),
                }
            };
            handle.execute(&b, &mut c, n, alpha, beta).map_err(|e| e.to_string())?;
            state.executes.fetch_add(1, Ordering::Relaxed);
            Ok(encode_execute_ok(&c))
        }
        Op::Stats => Ok(encode_stats_ok(&state.stats())),
        Op::Evict => {
            let mut r = ByteReader::new(payload);
            let id = r.u64().map_err(|e| format!("evict: {e}"))?;
            r.finish().map_err(|e| format!("evict: {e}"))?;
            let found = state.resident.lock().unwrap().remove(&id).is_some();
            let mut w = ByteWriter::new();
            w.put_u8(found as u8);
            Ok(w.into_bytes())
        }
        Op::Shutdown => {
            state.shutdown.store(true, Ordering::SeqCst);
            Ok(Vec::new())
        }
        Op::Ok | Op::Err | Op::Chunk | Op::Shed => {
            Err("reply opcode sent as a request".to_string())
        }
        // Front-door opcodes (RegisterBegin..FrontStatus) belong to
        // `serve_net`, not the worker tier.
        other => Err(format!("{other:?} is a front-door opcode; this is a worker")),
    }
}

/// Client-side helper: one blocking RPC over an existing stream — write
/// the request frame, read the reply frame, unwrap `Ok`/`Err`.
pub fn rpc(stream: &mut TcpStream, op: Op, payload: &[u8]) -> Result<Vec<u8>, WireError> {
    wire::write_frame(stream, op, payload)?;
    let (reply_op, reply) = wire::read_frame(stream)?;
    match reply_op {
        Op::Ok => Ok(reply),
        Op::Err => Err(WireError::Malformed(
            String::from_utf8_lossy(&reply).into_owned(),
        )),
        other => Err(WireError::Malformed(format!("unexpected reply opcode {other:?}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::preprocess;
    use crate::sparse::{gen, rng::Rng};

    fn spawn_worker(spec: &str) -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
        let config = WorkerConfig {
            backend_spec: spec.to_string(),
            read_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(5),
            ..WorkerConfig::default()
        };
        let worker = Worker::bind("127.0.0.1:0", &config).unwrap();
        let addr = worker.local_addr().unwrap();
        let handle = std::thread::spawn(move || worker.run(&config).unwrap());
        (addr, handle)
    }

    fn connect(addr: std::net::SocketAddr) -> TcpStream {
        let s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.set_write_timeout(Some(Duration::from_secs(5))).unwrap();
        s
    }

    #[test]
    fn worker_serves_prepare_execute_stats_evict() {
        let (addr, join) = spawn_worker("functional");
        let mut conn = connect(addr);

        assert!(rpc(&mut conn, Op::Ping, &[]).unwrap().is_empty());

        let mut rng = Rng::new(21);
        let coo = gen::random_uniform(24, 18, 0.2, &mut rng);
        let sm = preprocess(&coo, 2, 8, 3);
        let cost_bytes =
            rpc(&mut conn, Op::Prepare, &wire::encode_prepare_req(5, &sm)).unwrap();
        let _cost = wire::decode_cost(&cost_bytes).unwrap();

        let n = 3;
        let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
        let c0: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
        let reply = rpc(
            &mut conn,
            Op::Execute,
            &wire::encode_execute_req(5, n, 1.5, -0.5, &b, &c0),
        )
        .unwrap();
        let got = wire::decode_execute_ok(&reply).unwrap();
        let mut want = c0.clone();
        crate::backend::create("functional")
            .unwrap()
            .execute_once(&Arc::new(sm), &b, &mut want, n, 1.5, -0.5)
            .unwrap();
        assert_eq!(got, want, "remote execute must match local functional execute");

        let stats =
            wire::decode_stats_ok(&rpc(&mut conn, Op::Stats, &[]).unwrap()).unwrap();
        assert_eq!(stats.resident, 1);
        assert_eq!(stats.executes, 1);

        let mut w = ByteWriter::new();
        w.put_u64(5);
        let evicted = rpc(&mut conn, Op::Evict, &w.into_bytes()).unwrap();
        assert_eq!(evicted, vec![1]);
        let err = rpc(
            &mut conn,
            Op::Execute,
            &wire::encode_execute_req(5, n, 1.0, 0.0, &b, &c0),
        )
        .unwrap_err();
        assert!(err.to_string().contains("not resident"), "{err}");

        rpc(&mut conn, Op::Shutdown, &[]).unwrap();
        join.join().unwrap();
    }

    #[test]
    fn worker_rejects_bad_backend_spec_at_bind() {
        let config = WorkerConfig {
            backend_spec: "warpdrive".to_string(),
            ..WorkerConfig::default()
        };
        assert!(Worker::bind("127.0.0.1:0", &config).is_err());
    }

    #[test]
    fn prepare_beyond_residency_budget_is_a_typed_error() {
        // Native keeps decoded streams resident (>= 12 B/nnz), so any
        // real matrix busts a 1-byte budget. (Functional would not: it
        // holds nothing beyond the shared image, resident_bytes = 0.)
        let config = WorkerConfig {
            backend_spec: "native:1".to_string(),
            read_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(5),
            residency: Some(ResidencyPolicy { max_resident_bytes: 1, scratch_idle: None }),
            ..WorkerConfig::default()
        };
        let worker = Worker::bind("127.0.0.1:0", &config).unwrap();
        let addr = worker.local_addr().unwrap();
        let run_config = config.clone();
        let join = std::thread::spawn(move || worker.run(&run_config).unwrap());
        let mut conn = connect(addr);

        let mut rng = Rng::new(5);
        let coo = gen::random_uniform(16, 16, 0.2, &mut rng);
        let sm = preprocess(&coo, 2, 8, 3);
        let err =
            rpc(&mut conn, Op::Prepare, &wire::encode_prepare_req(1, &sm)).unwrap_err();
        assert!(err.to_string().contains("residency budget exceeded"), "{err}");
        // The refusal is a reply, not a crash: the worker keeps serving.
        assert!(rpc(&mut conn, Op::Ping, &[]).unwrap().is_empty());
        rpc(&mut conn, Op::Shutdown, &[]).unwrap();
        join.join().unwrap();
    }

    #[test]
    fn prepare_estimate_refuses_before_materializing() {
        // Functional retains nothing after prepare (resident_bytes = 0),
        // so only the pre-prepare stream-footprint estimate can refuse
        // here — pinning that the budget also bounds the prepare
        // transient, not just retained bytes.
        let config = WorkerConfig {
            backend_spec: "functional".to_string(),
            read_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(5),
            residency: Some(ResidencyPolicy { max_resident_bytes: 1, scratch_idle: None }),
            ..WorkerConfig::default()
        };
        let worker = Worker::bind("127.0.0.1:0", &config).unwrap();
        let addr = worker.local_addr().unwrap();
        let run_config = config.clone();
        let join = std::thread::spawn(move || worker.run(&run_config).unwrap());
        let mut conn = connect(addr);

        let mut rng = Rng::new(6);
        let coo = gen::random_uniform(16, 16, 0.2, &mut rng);
        let sm = preprocess(&coo, 2, 8, 3);
        let err =
            rpc(&mut conn, Op::Prepare, &wire::encode_prepare_req(1, &sm)).unwrap_err();
        assert!(err.to_string().contains("residency budget exceeded"), "{err}");
        assert!(err.to_string().contains("before prepare"), "{err}");
        // The refusal is a reply, not a crash: the worker keeps serving.
        assert!(rpc(&mut conn, Op::Ping, &[]).unwrap().is_empty());
        rpc(&mut conn, Op::Shutdown, &[]).unwrap();
        join.join().unwrap();
    }

    #[test]
    fn injected_fail_nth_fails_exactly_every_nth_rpc() {
        let config = WorkerConfig {
            backend_spec: "functional".to_string(),
            read_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(5),
            fault: Some(FaultSpec::parse("seed=7,fail-nth=2").unwrap()),
            ..WorkerConfig::default()
        };
        let worker = Worker::bind("127.0.0.1:0", &config).unwrap();
        let addr = worker.local_addr().unwrap();
        let run_config = config.clone();
        let join = std::thread::spawn(move || worker.run(&run_config).unwrap());
        let mut conn = connect(addr);

        assert!(rpc(&mut conn, Op::Ping, &[]).is_ok(), "rpc 1 passes");
        let err = rpc(&mut conn, Op::Ping, &[]).unwrap_err();
        assert!(err.to_string().contains("injected fault"), "rpc 2 injected: {err}");
        assert!(rpc(&mut conn, Op::Ping, &[]).is_ok(), "rpc 3 passes");
        let err = rpc(&mut conn, Op::Ping, &[]).unwrap_err();
        assert!(err.to_string().contains("injected fault"), "rpc 4 injected: {err}");

        rpc(&mut conn, Op::Shutdown, &[]).unwrap();
        join.join().unwrap();
    }

    #[test]
    fn corrupting_worker_replies_surfaces_as_typed_wire_errors() {
        // corrupt=1 flips a header byte in every reply frame the worker
        // writes; the client must always get a typed WireError, never a
        // misparsed payload.
        let config = WorkerConfig {
            backend_spec: "functional".to_string(),
            read_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(5),
            fault: Some(FaultSpec::parse("seed=9,corrupt=1").unwrap()),
            ..WorkerConfig::default()
        };
        let worker = Worker::bind("127.0.0.1:0", &config).unwrap();
        let addr = worker.local_addr().unwrap();
        let run_config = config.clone();
        std::thread::spawn(move || worker.run(&run_config).unwrap());
        let mut conn = connect(addr);

        let err = rpc(&mut conn, Op::Ping, &[]).unwrap_err();
        assert!(
            matches!(
                err,
                WireError::BadMagic(_) | WireError::Version { .. } | WireError::BadOpcode(_)
            ),
            "corrupted header must decode to a typed frame error, got {err:?}"
        );
    }

    #[test]
    fn execute_against_unknown_image_is_a_typed_error() {
        let (addr, join) = spawn_worker("functional");
        let mut conn = connect(addr);
        let err = rpc(
            &mut conn,
            Op::Execute,
            &wire::encode_execute_req(99, 1, 1.0, 0.0, &[0.0], &[0.0]),
        )
        .unwrap_err();
        assert!(err.to_string().contains("image 99"), "{err}");
        rpc(&mut conn, Op::Shutdown, &[]).unwrap();
        join.join().unwrap();
    }
}
