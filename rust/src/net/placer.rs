//! Fleet shard placement: spread a shard plan across worker processes
//! with R-way replication.
//!
//! The same LPT greedy that balances non-zeros across shards
//! ([`crate::shard::plan_shards`]) is applied one level up — shards →
//! workers, the Sextans/Serpens channel-balancing story lifted across the
//! process boundary. Heaviest shard first, each copy onto the currently
//! lightest worker that does not already hold one; a worker's load is the
//! nnz of everything placed on it. Replication (R ≥ 2) is what lets one
//! hot matrix survive a worker death: the executor fails over to the next
//! replica before it has to re-place and re-prepare.
//!
//! Placement is deterministic (stable weight sort, index tie-break), so a
//! fleet of identical prepares lands identically — the property tests pin
//! that.

/// Where every shard of one prepared matrix lives in the fleet.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FleetPlan {
    /// `assignments[shard]` = worker indices holding a replica of that
    /// shard, preference order (first = primary). Each list holds
    /// `replicas` distinct workers.
    pub assignments: Vec<Vec<usize>>,
    /// Effective replication factor (requested R clamped to the fleet
    /// size).
    pub replicas: usize,
}

impl FleetPlan {
    /// Total shard placements across the fleet (shards × replicas).
    pub fn placements(&self) -> usize {
        self.assignments.iter().map(Vec::len).sum()
    }

    /// Placements beyond the one required copy per shard.
    pub fn replica_placements(&self) -> usize {
        self.placements() - self.assignments.len()
    }
}

/// Place `weights.len()` shards (weight = shard nnz) onto `workers`
/// workers with `replicas`-way replication. `replicas` is clamped to
/// `[1, workers]`; `workers` must be ≥ 1.
///
/// Greedy LPT: shards descend by weight, each replica goes to the least
/// loaded worker not already holding that shard. Ties break on the lower
/// worker index, so placement is a pure function of its inputs.
pub fn place(weights: &[u64], workers: usize, replicas: usize) -> FleetPlan {
    assert!(workers >= 1, "placement needs at least one worker");
    let replicas = replicas.clamp(1, workers);
    let mut order: Vec<usize> = (0..weights.len()).collect();
    // Stable sort: equal weights keep ascending shard order.
    order.sort_by_key(|&i| std::cmp::Reverse(weights[i]));
    let mut load = vec![0u64; workers];
    let mut assignments = vec![Vec::new(); weights.len()];
    for &shard in &order {
        let mut chosen: Vec<usize> = Vec::with_capacity(replicas);
        for _ in 0..replicas {
            let w = (0..workers)
                .filter(|w| !chosen.contains(w))
                .min_by_key(|&w| (load[w], w))
                .expect("replicas clamped to fleet size");
            load[w] += weights[shard];
            chosen.push(w);
        }
        assignments[shard] = chosen;
    }
    FleetPlan { assignments, replicas }
}

/// Rebalance an existing assignment onto the current live set with
/// minimal movement: every still-live holder of a shard keeps it, and
/// only shards whose live replica count fell below
/// `min(replicas, live workers)` gain new placements — on the least
/// loaded live workers not already holding them (heaviest shard first,
/// index tie-break, so rebalancing is as deterministic as [`place`]).
///
/// A shard that never lost a live replica comes back *identical*
/// (same workers, same order), which is what makes recovery cheap:
/// re-adding a worker to the live set moves nothing, and removing one
/// relocates only the shards it held.
pub fn rebalance(
    prev: &[Vec<usize>],
    weights: &[u64],
    live: &[bool],
    replicas: usize,
) -> Vec<Vec<usize>> {
    assert_eq!(prev.len(), weights.len(), "one weight per shard");
    let live_count = live.iter().filter(|&&l| l).count();
    if live_count == 0 {
        // Nowhere to move anything; keep the old map for when workers
        // come back.
        return prev.to_vec();
    }
    let want = replicas.clamp(1, live_count);
    let mut assignments: Vec<Vec<usize>> = prev
        .iter()
        .map(|ws| ws.iter().copied().filter(|&w| live.get(w) == Some(&true)).collect())
        .collect();
    let mut load = vec![0u64; live.len()];
    for (shard, ws) in assignments.iter().enumerate() {
        for &w in ws {
            load[w] += weights[shard];
        }
    }
    let mut order: Vec<usize> = (0..weights.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(weights[i]));
    for &shard in &order {
        while assignments[shard].len() < want {
            let Some(w) = (0..live.len())
                .filter(|&w| live[w] && !assignments[shard].contains(&w))
                .min_by_key(|&w| (load[w], w))
            else {
                break;
            };
            load[w] += weights[shard];
            assignments[shard].push(w);
        }
    }
    assignments
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    #[test]
    fn single_worker_takes_everything() {
        let plan = place(&[5, 1, 3], 1, 1);
        assert_eq!(plan.assignments, vec![vec![0], vec![0], vec![0]]);
        assert_eq!(plan.placements(), 3);
        assert_eq!(plan.replica_placements(), 0);
    }

    #[test]
    fn replicas_land_on_distinct_workers() {
        let plan = place(&[10, 8, 6, 4], 3, 2);
        assert_eq!(plan.replicas, 2);
        for (shard, workers) in plan.assignments.iter().enumerate() {
            assert_eq!(workers.len(), 2, "shard {shard}");
            assert_ne!(workers[0], workers[1], "shard {shard} replicated onto itself");
        }
        assert_eq!(plan.replica_placements(), 4);
    }

    #[test]
    fn replication_clamps_to_fleet_size() {
        let plan = place(&[7, 7], 2, 5);
        assert_eq!(plan.replicas, 2);
        assert!(plan.assignments.iter().all(|a| a.len() == 2));
    }

    #[test]
    fn lpt_balances_unreplicated_load() {
        // Weights 9,7,6,5,4 over 2 workers: LPT lands loads 14 and 17,
        // within one smallest-item of balance.
        let plan = place(&[9, 7, 6, 5, 4], 2, 1);
        let mut load = [0u64; 2];
        for (shard, a) in plan.assignments.iter().enumerate() {
            load[a[0]] += [9u64, 7, 6, 5, 4][shard];
        }
        let (lo, hi) = (load.iter().min().unwrap(), load.iter().max().unwrap());
        assert!(hi - lo <= 4, "loads {load:?}");
    }

    #[test]
    fn rebalance_moves_minimum_and_preserves_replication() {
        prop::check("placer_rebalance", 0xBA1A, 48, |rng| {
            let shards = 1 + rng.index(10);
            let workers = 2 + rng.index(5);
            let replicas = 1 + rng.index(3);
            let weights: Vec<u64> = (0..shards).map(|_| 1 + rng.index(1000) as u64).collect();
            let plan = place(&weights, workers, replicas);
            let dead = rng.index(workers);
            let mut live = vec![true; workers];
            live[dead] = false;
            let next = rebalance(&plan.assignments, &weights, &live, plan.replicas);
            let want_r = plan.replicas.min(workers - 1);
            for (shard, (old, new)) in plan.assignments.iter().zip(&next).enumerate() {
                if new.iter().any(|&w| w == dead) {
                    return Err(format!("shard {shard} still placed on dead worker {dead}"));
                }
                let mut sorted = new.clone();
                sorted.sort_unstable();
                sorted.dedup();
                if sorted.len() != new.len() {
                    return Err(format!("shard {shard}: duplicate worker in {new:?}"));
                }
                if new.len() != want_r {
                    return Err(format!(
                        "shard {shard}: {} replicas after rebalance, want {want_r}",
                        new.len()
                    ));
                }
                // Minimal movement: survivors keep every placement, and a
                // shard that never touched the dead worker is unchanged.
                let survivors: Vec<usize> =
                    old.iter().copied().filter(|&w| w != dead).collect();
                if !survivors.iter().all(|w| new.contains(w)) {
                    return Err(format!(
                        "shard {shard}: surviving placement dropped ({old:?} -> {new:?})"
                    ));
                }
                if !old.contains(&dead) && new != old {
                    return Err(format!(
                        "shard {shard} moved without losing a replica ({old:?} -> {new:?})"
                    ));
                }
            }
            // Re-adding the worker moves nothing: every shard already has
            // its full live replica count.
            let restored = rebalance(&next, &weights, &vec![true; workers], want_r);
            if restored != next {
                return Err("re-adding a worker must not relocate shards".into());
            }
            Ok(())
        });
    }

    #[test]
    fn rebalance_with_no_live_workers_keeps_the_old_map() {
        let prev = vec![vec![0usize], vec![1]];
        let got = rebalance(&prev, &[3, 4], &[false, false], 1);
        assert_eq!(got, prev);
    }

    #[test]
    fn placement_is_deterministic_and_balanced() {
        prop::check("placer_properties", 0xF1EE7, 32, |rng| {
            let shards = 1 + rng.index(12);
            let workers = 1 + rng.index(6);
            let replicas = 1 + rng.index(3);
            let weights: Vec<u64> = (0..shards).map(|_| rng.index(1000) as u64).collect();
            let a = place(&weights, workers, replicas);
            let b = place(&weights, workers, replicas);
            if a != b {
                return Err("placement is not deterministic".into());
            }
            let want_r = replicas.min(workers);
            for (shard, ws) in a.assignments.iter().enumerate() {
                if ws.len() != want_r {
                    return Err(format!("shard {shard}: {} replicas, want {want_r}", ws.len()));
                }
                let mut sorted = ws.clone();
                sorted.sort_unstable();
                sorted.dedup();
                if sorted.len() != ws.len() {
                    return Err(format!("shard {shard}: duplicate worker in {ws:?}"));
                }
                if ws.iter().any(|&w| w >= workers) {
                    return Err(format!("shard {shard}: worker out of range in {ws:?}"));
                }
            }
            Ok(())
        });
    }
}
