//! Minimal property-testing helper (offline substitute for `proptest`).
//!
//! Runs a property over `n` randomly generated cases from a deterministic
//! base seed. On failure it retries the failing case once to confirm, then
//! panics with the case seed so the exact input can be replayed:
//!
//! ```text
//! property failed (case seed = 0x1234abcd): <your message>
//! replay with: PROP_SEED=0x1234abcd cargo test <test name>
//! ```
//!
//! Generators receive an [`crate::sparse::rng::Rng`] forked per case. No
//! shrinking — cases are kept small by construction instead (the standard
//! trade-off when vendoring is impossible).

use crate::sparse::rng::Rng;

/// Default number of cases per property.
pub const DEFAULT_CASES: usize = 64;

/// Run `prop` over `cases` random cases derived from `base_seed`.
///
/// If the env var `PROP_SEED` is set (hex or decimal), only that single case
/// seed is run — the replay path.
pub fn check<F>(name: &str, base_seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    if let Ok(replay) = std::env::var("PROP_SEED") {
        let seed = parse_seed(&replay).expect("PROP_SEED must be hex (0x..) or decimal");
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property `{name}` failed on replay (case seed = {seed:#x}): {msg}");
        }
        return;
    }
    let mut meta = Rng::new(base_seed);
    for case in 0..cases {
        let case_seed = meta.next_u64();
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property `{name}` failed (case {case}, case seed = {case_seed:#x}): {msg}\n\
                 replay with: PROP_SEED={case_seed:#x} cargo test"
            );
        }
    }
}

fn parse_seed(s: &str) -> Option<u64> {
    let s = s.trim();
    if let Some(hex) = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).ok()
    } else {
        s.parse().ok()
    }
}

/// Assert two f32 slices are element-wise close (relative + absolute tol).
pub fn assert_allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let diff = (x - y).abs();
        let tol = atol + rtol * x.abs().max(y.abs());
        if !(diff <= tol) {
            // NaN-aware: NaN != NaN fails here too.
            return Err(format!(
                "element {i}: {x} vs {y} (|diff| = {diff}, tol = {tol})"
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", 1, 32, |rng| {
            let x = rng.below(100);
            if x < 100 { Ok(()) } else { Err(format!("{x}")) }
        });
    }

    #[test]
    #[should_panic(expected = "property `always_fails` failed")]
    fn check_reports_failures() {
        check("always_fails", 2, 4, |_| Err("nope".into()));
    }

    #[test]
    fn allclose_accepts_equal() {
        assert_allclose(&[1.0, 2.0], &[1.0, 2.0], 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn allclose_rejects_distant() {
        assert!(assert_allclose(&[1.0], &[2.0], 1e-6, 1e-6).is_err());
    }

    #[test]
    fn allclose_rejects_nan() {
        assert!(assert_allclose(&[f32::NAN], &[f32::NAN], 1e-3, 1e-3).is_err());
    }

    #[test]
    fn allclose_rejects_len_mismatch() {
        assert!(assert_allclose(&[1.0], &[1.0, 2.0], 1e-3, 1e-3).is_err());
    }

    #[test]
    fn parse_seed_formats() {
        assert_eq!(parse_seed("0x10"), Some(16));
        assert_eq!(parse_seed("42"), Some(42));
        assert_eq!(parse_seed("zz"), None);
    }
}
