//! HFlex — hardware flexibility (paper §3.4): one "synthesized" accelerator
//! executes arbitrary SpMMs, with only memory pointers and scalars varying
//! per problem.
//!
//! The contract is enforced by construction:
//!
//! * [`HFlexAccelerator::synthesize`] consumes an [`AcceleratorConfig`] —
//!   after that the configuration is immutable (no public mutators), like a
//!   bitstream after place-and-route.
//! * [`HFlexAccelerator::load`] preprocesses a matrix *and* prepares it on
//!   the accelerator's execution backend, returning a [`LoadedMatrix`] —
//!   the A-resident handle of the serving shape (one sparse A, many dense
//!   B). Loading is the only per-matrix cost; it happens once.
//! * [`HFlexAccelerator::invoke`] accepts any [`SpmmProblem`] against a
//!   loaded matrix; the only inputs that change between invocations are
//!   the Algorithm 1 parameters: matrix pointers (the loaded image, B, C)
//!   and the scalars N, α, β.
//! * An image preprocessed for a *different* configuration is rejected at
//!   load with [`HFlexError::WrongConfiguration`] — the analogue of needing
//!   a new synthesis/place/route run, which HFlex exists to avoid.

use std::sync::Arc;

use crate::arch::{simulate, AcceleratorConfig, SimReport};
use crate::backend::{self, BackendError, PrepareCost, PreparedSpmm, SpmmBackend};
use crate::sched::{preprocess, ScheduledMatrix};
use crate::sparse::Coo;

/// Why a load or an invocation was refused.
#[derive(Debug, PartialEq)]
pub enum HFlexError {
    /// Image was scheduled for a different accelerator configuration.
    WrongConfiguration {
        /// What the image was built for (p, k0, d).
        image: (usize, usize, usize),
        /// What this accelerator is (p, k0, d).
        accel: (usize, usize, usize),
    },
    /// Matrix exceeds the C-scratchpad capacity (M > c_depth × P): the
    /// paper's 5 GB memory-budget exclusion analogue.
    ScratchpadOverflow {
        /// Rows required per PE.
        rows_per_pe: usize,
        /// URAM depth available per PE.
        c_depth: usize,
    },
    /// B/C buffer shape mismatch with (M, K, N).
    ShapeMismatch(String),
    /// The execution backend refused or failed the prepare or the run.
    Backend(String),
}

impl std::fmt::Display for HFlexError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HFlexError::WrongConfiguration { image, accel } => write!(
                f,
                "image scheduled for (P, K0, D) = {image:?} but accelerator is {accel:?}; \
                 HFlex avoids re-synthesis only for matching preprocessing"
            ),
            HFlexError::ScratchpadOverflow { rows_per_pe, c_depth } => write!(
                f,
                "C scratchpad overflow: {rows_per_pe} rows/PE > URAM depth {c_depth}"
            ),
            HFlexError::ShapeMismatch(s) => write!(f, "shape mismatch: {s}"),
            HFlexError::Backend(s) => write!(f, "backend error: {s}"),
        }
    }
}

impl std::error::Error for HFlexError {}

/// Backend failures flow through unchanged — shape errors stay shape
/// errors, everything else keeps the backend's own message — so HFlex and
/// the serving coordinator report identical error text for the same
/// failure.
impl From<BackendError> for HFlexError {
    fn from(e: BackendError) -> HFlexError {
        match e {
            BackendError::Shape(s) => HFlexError::ShapeMismatch(s),
            other => HFlexError::Backend(other.to_string()),
        }
    }
}

/// One SpMM problem against a loaded matrix: `C = alpha * A @ B + beta * C`.
/// The HFlex parameter set of Algorithm 1 — pointers + scalars, nothing
/// hardware-shaped.
#[derive(Debug)]
pub struct SpmmProblem<'a> {
    /// The loaded (preprocessed + prepared) A.
    pub a: &'a LoadedMatrix,
    /// Dense B, row-major K × N.
    pub b: &'a [f32],
    /// Dense C in/out, row-major M × N.
    pub c: &'a mut [f32],
    /// Columns of B / C.
    pub n: usize,
    /// Scalar α.
    pub alpha: f32,
    /// Scalar β.
    pub beta: f32,
}

/// Result of one invocation.
#[derive(Clone, Debug)]
pub struct InvokeReport {
    /// Cycle-level timing of the run.
    pub sim: SimReport,
    /// Name of the backend that produced the functional result.
    pub backend: &'static str,
}

/// A matrix loaded onto an accelerator: the scheduled image plus the
/// backend's matrix-resident [`PreparedSpmm`] handle. Invocations against
/// it never re-submit or re-shard the image — the HFlex serving shape.
///
/// `Send + Sync` with **lock-free invocation**: the prepared handle
/// executes through `&self` (per-call scratch comes from its internal
/// pool), so request threads sharing one loaded matrix invoke
/// concurrently — one resident copy of A, W simultaneous streams against
/// it, exactly the paper's one-A-many-B serving shape.
///
/// Thread composition is the caller's to budget on this direct API: W
/// concurrent invocations each use the backend's full thread count, so an
/// auto-threaded engine (`native` = all cores) driven from W request
/// threads schedules up to W × cores workers. Synthesize with an explicit
/// share (e.g. `backend::create("native:2")`) when fanning in requests —
/// the serving coordinator does this automatically via its per-worker
/// core budget.
pub struct LoadedMatrix {
    image: Arc<ScheduledMatrix>,
    prepared: Box<dyn PreparedSpmm + Send + Sync>,
    cost: PrepareCost,
}

impl std::fmt::Debug for LoadedMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LoadedMatrix")
            .field("m", &self.image.m)
            .field("k", &self.image.k)
            .field("nnz", &self.image.nnz)
            .field("backend", &self.backend_name())
            .finish()
    }
}

impl LoadedMatrix {
    /// The scheduled image this matrix is resident as.
    pub fn image(&self) -> &Arc<ScheduledMatrix> {
        &self.image
    }

    /// What loading cost and what the backend keeps resident.
    pub fn prepare_cost(&self) -> PrepareCost {
        self.cost
    }

    /// Name of the backend holding the residency.
    pub fn backend_name(&self) -> &'static str {
        self.prepared.backend_name()
    }
}

/// A "synthesized" Sextans accelerator: an immutable configuration plus the
/// execution backend that stands in for the silicon. Backends are stateless
/// `Send + Sync` factories, so the accelerator itself is freely shareable;
/// per-matrix state lives in the [`LoadedMatrix`] handles it loads.
pub struct HFlexAccelerator {
    cfg: AcceleratorConfig,
    backend: Box<dyn SpmmBackend>,
}

impl std::fmt::Debug for HFlexAccelerator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("HFlexAccelerator")
            .field("cfg", &self.cfg)
            .field("backend", &self.backend_name())
            .finish()
    }
}

impl HFlexAccelerator {
    /// One-time synthesis (the hours-long place-and-route the paper's flow
    /// replaces with... this constructor). Executes on the default
    /// [`backend::default_backend`] (native, auto-threaded).
    pub fn synthesize(cfg: AcceleratorConfig) -> Self {
        Self::synthesize_with_backend(cfg, backend::default_backend())
    }

    /// Synthesis with an explicit execution backend (see
    /// [`backend::create`] for name-based construction).
    pub fn synthesize_with_backend(cfg: AcceleratorConfig, backend: Box<dyn SpmmBackend>) -> Self {
        HFlexAccelerator { cfg, backend }
    }

    /// The immutable configuration.
    pub fn config(&self) -> &AcceleratorConfig {
        &self.cfg
    }

    /// Name of the execution backend.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Host-side preprocessing (§3.3's "C++ wrapper"): partition + OoO
    /// schedule + encode for THIS accelerator's (P, K0, D). Most callers
    /// want [`load`], which also makes the image backend-resident.
    ///
    /// [`load`]: HFlexAccelerator::load
    pub fn preprocess(&self, a: &Coo) -> Result<ScheduledMatrix, HFlexError> {
        let sm = preprocess(a, self.cfg.p(), self.cfg.k0, self.cfg.d);
        if sm.rows_per_pe() > self.cfg.c_depth {
            return Err(HFlexError::ScratchpadOverflow {
                rows_per_pe: sm.rows_per_pe(),
                c_depth: self.cfg.c_depth,
            });
        }
        Ok(sm)
    }

    /// Load a matrix onto the accelerator: preprocess for this (P, K0, D)
    /// and prepare it on the execution backend. The per-matrix cost, paid
    /// once; every subsequent [`invoke`] runs against the resident handle.
    ///
    /// [`invoke`]: HFlexAccelerator::invoke
    pub fn load(&self, a: &Coo) -> Result<LoadedMatrix, HFlexError> {
        let image = Arc::new(self.preprocess(a)?);
        self.load_image(image)
    }

    /// Load an already-preprocessed image (it must match this
    /// accelerator's configuration and fit the C scratchpad).
    pub fn load_image(&self, image: Arc<ScheduledMatrix>) -> Result<LoadedMatrix, HFlexError> {
        let accel = (self.cfg.p(), self.cfg.k0, self.cfg.d);
        let img = (image.p, image.k0, image.d);
        if accel != img {
            return Err(HFlexError::WrongConfiguration { image: img, accel });
        }
        if image.rows_per_pe() > self.cfg.c_depth {
            return Err(HFlexError::ScratchpadOverflow {
                rows_per_pe: image.rows_per_pe(),
                c_depth: self.cfg.c_depth,
            });
        }
        let prepared = self.backend.prepare_send(Arc::clone(&image))?;
        let cost = prepared.prepare_cost();
        Ok(LoadedMatrix { image, prepared, cost })
    }

    /// Execute one SpMM against a loaded matrix: the functional result is
    /// written into `problem.c`, cycle-accurate timing of what the silicon
    /// would do is returned. No re-synthesis, no re-preparation, ever.
    pub fn invoke(&self, problem: SpmmProblem<'_>) -> Result<InvokeReport, HFlexError> {
        let sm: &ScheduledMatrix = problem.a.image();
        // A LoadedMatrix from a different accelerator generation is still a
        // foreign image (loads are accelerator-specific).
        let accel = (self.cfg.p(), self.cfg.k0, self.cfg.d);
        let image = (sm.p, sm.k0, sm.d);
        if accel != image {
            return Err(HFlexError::WrongConfiguration { image, accel });
        }
        // Same (P, K0, D) does not imply the same URAM depth: a matrix
        // loaded on a deeper-scratchpad generation must still be refused
        // here.
        if sm.rows_per_pe() > self.cfg.c_depth {
            return Err(HFlexError::ScratchpadOverflow {
                rows_per_pe: sm.rows_per_pe(),
                c_depth: self.cfg.c_depth,
            });
        }
        if problem.b.len() != sm.k * problem.n {
            return Err(HFlexError::ShapeMismatch(format!(
                "B has {} elements, expected K*N = {}",
                problem.b.len(),
                sm.k * problem.n
            )));
        }
        if problem.c.len() != sm.m * problem.n {
            return Err(HFlexError::ShapeMismatch(format!(
                "C has {} elements, expected M*N = {}",
                problem.c.len(),
                sm.m * problem.n
            )));
        }
        // Lock-free: the handle executes through &self, so concurrent
        // invocations against one loaded matrix proceed in parallel.
        let prepared = &problem.a.prepared;
        let backend_name = prepared.backend_name();
        prepared.execute(problem.b, problem.c, problem.n, problem.alpha, problem.beta)?;
        let sim = simulate(sm, &self.cfg, problem.n);
        Ok(InvokeReport { sim, backend: backend_name })
    }
}

/// A matrix too tall for the C scratchpad, split into sequential row
/// blocks (extension over the paper, which *excludes* such matrices from
/// its evaluation: each block fits `c_depth × P` rows and is loaded as an
/// independent resident SpMM over the same B — correctness is exact because
/// C rows partition cleanly across blocks).
#[derive(Debug)]
pub struct TiledImage {
    /// (first global row, loaded block) per block.
    pub blocks: Vec<(usize, LoadedMatrix)>,
    /// Total rows (M).
    pub m: usize,
    /// Columns (K).
    pub k: usize,
}

impl TiledImage {
    /// Total prepare cost across blocks.
    pub fn prepare_cost(&self) -> PrepareCost {
        let mut total = PrepareCost::default();
        for (_, block) in &self.blocks {
            let c = block.prepare_cost();
            total.wall += c.wall;
            total.resident_bytes += c.resident_bytes;
        }
        total
    }
}

impl HFlexAccelerator {
    /// Load with automatic row-block tiling: always succeeds shape-wise,
    /// even for M > c_depth × P (the paper's 5 GB/scratchpad exclusions).
    /// Every block is preprocessed *and* prepared, so the tiled invoke path
    /// is as resident as the plain one.
    pub fn load_tiled(&self, a: &Coo) -> Result<TiledImage, HFlexError> {
        let block_rows = self.cfg.c_depth * self.cfg.p();
        if a.m <= block_rows {
            let image =
                Arc::new(preprocess(a, self.cfg.p(), self.cfg.k0, self.cfg.d));
            return Ok(TiledImage {
                blocks: vec![(0, self.load_image(image)?)],
                m: a.m,
                k: a.k,
            });
        }
        let nblocks = a.m.div_ceil(block_rows);
        // Bucket non-zeros by row block, shifting rows to block-local.
        let mut rows: Vec<Vec<u32>> = vec![Vec::new(); nblocks];
        let mut cols: Vec<Vec<u32>> = vec![Vec::new(); nblocks];
        let mut vals: Vec<Vec<f32>> = vec![Vec::new(); nblocks];
        for i in 0..a.nnz() {
            let blk = a.rows[i] as usize / block_rows;
            rows[blk].push(a.rows[i] - (blk * block_rows) as u32);
            cols[blk].push(a.cols[i]);
            vals[blk].push(a.vals[i]);
        }
        let mut blocks = Vec::with_capacity(nblocks);
        for blk in 0..nblocks {
            let off = blk * block_rows;
            let m_blk = block_rows.min(a.m - off);
            let coo = Coo {
                m: m_blk,
                k: a.k,
                rows: std::mem::take(&mut rows[blk]),
                cols: std::mem::take(&mut cols[blk]),
                vals: std::mem::take(&mut vals[blk]),
            };
            let image = Arc::new(preprocess(&coo, self.cfg.p(), self.cfg.k0, self.cfg.d));
            blocks.push((off, self.load_image(image)?));
        }
        Ok(TiledImage { blocks, m: a.m, k: a.k })
    }

    /// Execute a tiled SpMM: blocks run sequentially on the accelerator
    /// (B is re-streamed per block, exactly what the hardware would do);
    /// cycle counts accumulate across blocks.
    pub fn invoke_tiled(
        &self,
        image: &TiledImage,
        b: &[f32],
        c: &mut [f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<u64, HFlexError> {
        if b.len() != image.k * n {
            return Err(HFlexError::ShapeMismatch("B".into()));
        }
        if c.len() != image.m * n {
            return Err(HFlexError::ShapeMismatch("C".into()));
        }
        let mut total_cycles = 0u64;
        for (off, block) in &image.blocks {
            // C rows of this block are contiguous in row-major C.
            let c_block = &mut c[off * n..(off + block.image().m) * n];
            let report = self.invoke(SpmmProblem {
                a: block,
                b,
                c: c_block,
                n,
                alpha,
                beta,
            })?;
            total_cycles += report.sim.cycles;
        }
        Ok(total_cycles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::sparse::{gen, rng::Rng};

    fn accel() -> HFlexAccelerator {
        HFlexAccelerator::synthesize(AcceleratorConfig::sextans_u280())
    }

    fn problem_data(k: usize, m: usize, n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let b = (0..k * n).map(|_| rng.normal()).collect();
        let c = (0..m * n).map(|_| rng.normal()).collect();
        (b, c)
    }

    #[test]
    fn one_accelerator_many_problem_shapes() {
        // The HFlex headline: the SAME synthesized accelerator runs SpMMs of
        // wildly different (M, K, N, nnz) with zero reconfiguration.
        let acc = accel();
        let mut rng = Rng::new(1);
        for (m, k, n) in [(64, 64, 8), (1000, 300, 16), (77, 4100, 64), (5, 5, 8)] {
            let a = gen::random_uniform(m, k, 0.1, &mut rng);
            let loaded = acc.load(&a).unwrap();
            let (b, mut c) = problem_data(k, m, n, 2);
            let mut want = c.clone();
            a.spmm_reference(&b, &mut want, n, 2.0, 0.5);
            let report = acc
                .invoke(SpmmProblem { a: &loaded, b: &b, c: &mut c, n, alpha: 2.0, beta: 0.5 })
                .unwrap();
            prop::assert_allclose(&c, &want, 2e-4, 2e-4).unwrap();
            assert!(report.sim.cycles > 0);
        }
    }

    #[test]
    fn loaded_matrix_serves_many_invocations() {
        // One load, many (B, n, alpha, beta): the A-resident serving shape.
        let acc = accel();
        let mut rng = Rng::new(31);
        let a = gen::power_law_rows(120, 100, 1_500, 1.0, &mut rng);
        let loaded = acc.load(&a).unwrap();
        assert!(loaded.prepare_cost().resident_bytes > 0);
        for (n, alpha, beta) in [(4usize, 1.0f32, 0.0f32), (9, 2.0, -0.5), (1, 0.5, 1.0)] {
            let (b, mut c) = problem_data(a.k, a.m, n, 32 + n as u64);
            let mut want = c.clone();
            a.spmm_reference(&b, &mut want, n, alpha, beta);
            acc.invoke(SpmmProblem { a: &loaded, b: &b, c: &mut c, n, alpha, beta }).unwrap();
            prop::assert_allclose(&c, &want, 2e-4, 2e-4).unwrap();
        }
    }

    #[test]
    fn accelerator_and_loaded_matrix_are_send_and_sync() {
        // Shareable across request threads: the accelerator (stateless
        // factory) and the loaded handle (&self execution over pooled
        // scratch — no lock).
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<HFlexAccelerator>();
        assert_send_sync::<LoadedMatrix>();
    }

    #[test]
    fn concurrent_invocations_share_one_loaded_matrix() {
        // W request threads invoking one LoadedMatrix simultaneously must
        // all match the serial result bitwise — the lock removal must not
        // cost determinism.
        let acc = accel();
        let mut rng = Rng::new(51);
        let a = gen::power_law_rows(100, 80, 1_200, 1.0, &mut rng);
        let loaded = acc.load(&a).unwrap();
        let n = 4;
        let (b, c0) = problem_data(a.k, a.m, n, 52);
        let mut serial = c0.clone();
        acc.invoke(SpmmProblem {
            a: &loaded,
            b: &b,
            c: &mut serial,
            n,
            alpha: 1.5,
            beta: -0.5,
        })
        .unwrap();
        let results: Vec<Vec<f32>> = std::thread::scope(|s| {
            (0..4)
                .map(|_| {
                    s.spawn(|| {
                        let mut c = c0.clone();
                        acc.invoke(SpmmProblem {
                            a: &loaded,
                            b: &b,
                            c: &mut c,
                            n,
                            alpha: 1.5,
                            beta: -0.5,
                        })
                        .unwrap();
                        c
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        for c in &results {
            assert_eq!(c, &serial, "concurrent invoke diverged from serial");
        }
    }

    #[test]
    fn default_backend_is_native_and_reported() {
        let acc = accel();
        assert_eq!(acc.backend_name(), "native");
        let mut rng = Rng::new(21);
        let a = gen::random_uniform(32, 32, 0.2, &mut rng);
        let loaded = acc.load(&a).unwrap();
        assert_eq!(loaded.backend_name(), "native");
        let (b, mut c) = problem_data(32, 32, 4, 22);
        let report = acc
            .invoke(SpmmProblem { a: &loaded, b: &b, c: &mut c, n: 4, alpha: 1.0, beta: 0.0 })
            .unwrap();
        assert_eq!(report.backend, "native");
    }

    #[test]
    fn explicit_backend_selection() {
        let acc = HFlexAccelerator::synthesize_with_backend(
            AcceleratorConfig::sextans_u280(),
            crate::backend::create("functional").unwrap(),
        );
        assert_eq!(acc.backend_name(), "functional");
        let mut rng = Rng::new(23);
        let a = gen::random_uniform(40, 30, 0.15, &mut rng);
        let loaded = acc.load(&a).unwrap();
        let (b, mut c) = problem_data(30, 40, 3, 24);
        let mut want = c.clone();
        a.spmm_reference(&b, &mut want, 3, 1.0, 1.0);
        let report = acc
            .invoke(SpmmProblem { a: &loaded, b: &b, c: &mut c, n: 3, alpha: 1.0, beta: 1.0 })
            .unwrap();
        assert_eq!(report.backend, "functional");
        prop::assert_allclose(&c, &want, 2e-4, 2e-4).unwrap();
    }

    #[test]
    fn sharded_backend_loads_and_invokes() {
        let acc = HFlexAccelerator::synthesize_with_backend(
            AcceleratorConfig::sextans_u280(),
            crate::backend::create("sharded:2:native:1").unwrap(),
        );
        let mut rng = Rng::new(25);
        let a = gen::random_uniform(64, 48, 0.1, &mut rng);
        let loaded = acc.load(&a).unwrap();
        assert_eq!(loaded.backend_name(), "sharded");
        let (b, mut c) = problem_data(48, 64, 5, 26);
        let mut want = c.clone();
        a.spmm_reference(&b, &mut want, 5, 1.0, 0.0);
        let report = acc
            .invoke(SpmmProblem { a: &loaded, b: &b, c: &mut c, n: 5, alpha: 1.0, beta: 0.0 })
            .unwrap();
        assert_eq!(report.backend, "sharded");
        prop::assert_allclose(&c, &want, 2e-4, 2e-4).unwrap();
    }

    #[test]
    fn rejects_image_from_other_configuration() {
        let acc = accel();
        let mut rng = Rng::new(3);
        let a = gen::random_uniform(64, 64, 0.1, &mut rng);
        // Preprocess for a DIFFERENT window size: refused at load.
        let foreign = Arc::new(preprocess(&a, acc.config().p(), 1024, acc.config().d));
        let err = acc.load_image(foreign).map(|_| ()).unwrap_err();
        assert!(matches!(err, HFlexError::WrongConfiguration { .. }));
        assert!(err.to_string().contains("re-synthesis"));
    }

    #[test]
    fn rejects_loaded_matrix_from_other_accelerator() {
        // A LoadedMatrix prepared for one generation is foreign to another.
        let acc = accel();
        let mut other_cfg = AcceleratorConfig::sextans_u280();
        other_cfg.k0 = 1024;
        let other = HFlexAccelerator::synthesize(other_cfg);
        let mut rng = Rng::new(33);
        let a = gen::random_uniform(32, 32, 0.2, &mut rng);
        let loaded = other.load(&a).unwrap();
        let (b, mut c) = problem_data(32, 32, 4, 34);
        let err = acc
            .invoke(SpmmProblem { a: &loaded, b: &b, c: &mut c, n: 4, alpha: 1.0, beta: 0.0 })
            .unwrap_err();
        assert!(matches!(err, HFlexError::WrongConfiguration { .. }));
    }

    #[test]
    fn invoke_rejects_overflow_from_deeper_scratchpad_generation() {
        // Same (P, K0, D), larger c_depth: a matrix loaded there must not
        // slip past a smaller-scratchpad accelerator at invoke time.
        let small = tiny_accel(); // c_depth = 16
        let mut big_cfg = AcceleratorConfig::sextans_u280();
        big_cfg.pegs = 2;
        big_cfg.pes_per_peg = 2;
        big_cfg.c_depth = 64; // block = 256 rows
        big_cfg.k0 = 32;
        let big = HFlexAccelerator::synthesize(big_cfg);
        let mut rng = Rng::new(15);
        let a = gen::random_uniform(200, 30, 0.1, &mut rng); // fits big, not small
        let loaded = big.load(&a).unwrap();
        let (b, mut c) = problem_data(30, 200, 2, 16);
        let err = small
            .invoke(SpmmProblem { a: &loaded, b: &b, c: &mut c, n: 2, alpha: 1.0, beta: 0.0 })
            .unwrap_err();
        assert!(matches!(err, HFlexError::ScratchpadOverflow { .. }));
    }

    #[test]
    fn rejects_scratchpad_overflow() {
        // M > c_depth * P: 64 PEs * 12,288 = 786,432 rows max.
        let acc = accel();
        let huge = Coo::empty(800_000, 16);
        let err = acc.load(&huge).map(|_| ()).unwrap_err();
        assert!(matches!(err, HFlexError::ScratchpadOverflow { .. }));
    }

    #[test]
    fn rejects_shape_mismatch() {
        let acc = accel();
        let mut rng = Rng::new(5);
        let a = gen::random_uniform(16, 16, 0.2, &mut rng);
        let loaded = acc.load(&a).unwrap();
        let (b, mut c) = problem_data(16, 16, 8, 6);
        let err = acc
            .invoke(SpmmProblem { a: &loaded, b: &b[..10], c: &mut c, n: 8, alpha: 1.0, beta: 0.0 })
            .unwrap_err();
        assert!(matches!(err, HFlexError::ShapeMismatch(_)));
    }

    #[test]
    fn backend_errors_convert_without_restringifying() {
        let shape = BackendError::Shape("B has 3 elements".into());
        assert_eq!(
            HFlexError::from(shape),
            HFlexError::ShapeMismatch("B has 3 elements".into())
        );
        let exec = BackendError::Execution("boom".into());
        let converted = HFlexError::from(exec);
        // The inner text is exactly the BackendError display, once.
        assert_eq!(
            converted,
            HFlexError::Backend(BackendError::Execution("boom".into()).to_string())
        );
    }

    use crate::sparse::Coo;

    fn tiny_accel() -> HFlexAccelerator {
        // Shrunken scratchpad to exercise tiling with small matrices.
        let mut cfg = AcceleratorConfig::sextans_u280();
        cfg.pegs = 2;
        cfg.pes_per_peg = 2; // P = 4
        cfg.c_depth = 16; // block = 64 rows
        cfg.k0 = 32;
        HFlexAccelerator::synthesize(cfg)
    }

    #[test]
    fn tiled_matches_reference_over_blocks() {
        let acc = tiny_accel();
        let mut rng = Rng::new(7);
        let a = gen::random_uniform(200, 70, 0.1, &mut rng); // 4 blocks
        let image = acc.load_tiled(&a).unwrap();
        assert_eq!(image.blocks.len(), 4);
        let n = 5;
        let (b, mut c) = problem_data(70, 200, n, 8);
        let mut want = c.clone();
        a.spmm_reference(&b, &mut want, n, 1.5, -0.5);
        let cycles = acc
            .invoke_tiled(&image, &b, &mut c, n, 1.5, -0.5)
            .unwrap();
        prop::assert_allclose(&c, &want, 2e-4, 2e-4).unwrap();
        assert!(cycles > 0);
    }

    #[test]
    fn tiled_single_block_when_it_fits() {
        let acc = tiny_accel();
        let mut rng = Rng::new(9);
        let a = gen::random_uniform(60, 40, 0.1, &mut rng);
        let image = acc.load_tiled(&a).unwrap();
        assert_eq!(image.blocks.len(), 1);
    }

    #[test]
    fn tiled_every_block_fits_scratchpad() {
        let acc = tiny_accel();
        let mut rng = Rng::new(11);
        let a = gen::random_uniform(300, 50, 0.05, &mut rng);
        let image = acc.load_tiled(&a).unwrap();
        for (_, block) in &image.blocks {
            assert!(block.image().rows_per_pe() <= acc.config().c_depth);
        }
        // Every non-zero lands in exactly one block.
        let total: usize = image.blocks.iter().map(|(_, b)| b.image().nnz).sum();
        assert_eq!(total, a.nnz());
        // Prepare cost aggregates across blocks.
        assert!(image.prepare_cost().resident_bytes > 0);
    }

    #[test]
    fn tiled_beats_plain_load_rejection() {
        // The plain path refuses what the tiled path handles.
        let acc = tiny_accel();
        let mut rng = Rng::new(13);
        let a = gen::random_uniform(200, 30, 0.08, &mut rng);
        assert!(matches!(
            acc.load(&a).map(|_| ()),
            Err(HFlexError::ScratchpadOverflow { .. })
        ));
        let image = acc.load_tiled(&a).unwrap();
        let n = 2;
        let (b, mut c) = problem_data(30, 200, n, 14);
        acc.invoke_tiled(&image, &b, &mut c, n, 1.0, 0.0).unwrap();
    }
}
