//! HFlex — hardware flexibility (paper §3.4): one "synthesized" accelerator
//! executes arbitrary SpMMs, with only memory pointers and scalars varying
//! per problem.
//!
//! The contract is enforced by construction:
//!
//! * [`HFlexAccelerator::synthesize`] consumes an [`AcceleratorConfig`] —
//!   after that the configuration is immutable (no public mutators), like a
//!   bitstream after place-and-route.
//! * [`HFlexAccelerator::invoke`] accepts any [`SpmmProblem`]; the only
//!   inputs that change between invocations are the Algorithm 1 parameters:
//!   matrix pointers (A's scheduled image, B, C), the Q pointer lists
//!   (inside the image), and the scalars M, K, N, α, β.
//! * An image preprocessed for a *different* configuration is rejected with
//!   [`HFlexError::WrongConfiguration`] — the analogue of needing a new
//!   synthesis/place/route run, which HFlex exists to avoid.

use std::sync::Mutex;

use crate::arch::{simulate, AcceleratorConfig, SimReport};
use crate::backend::{self, SpmmBackend};
use crate::sched::{preprocess, ScheduledMatrix};
use crate::sparse::Coo;

/// Why an invocation was refused.
#[derive(Debug, PartialEq)]
pub enum HFlexError {
    /// Image was scheduled for a different accelerator configuration.
    WrongConfiguration {
        /// What the image was built for (p, k0, d).
        image: (usize, usize, usize),
        /// What this accelerator is (p, k0, d).
        accel: (usize, usize, usize),
    },
    /// Matrix exceeds the C-scratchpad capacity (M > c_depth × P): the
    /// paper's 5 GB memory-budget exclusion analogue.
    ScratchpadOverflow {
        /// Rows required per PE.
        rows_per_pe: usize,
        /// URAM depth available per PE.
        c_depth: usize,
    },
    /// B/C buffer shape mismatch with (M, K, N).
    ShapeMismatch(String),
    /// The execution backend refused or failed the run.
    Backend(String),
}

impl std::fmt::Display for HFlexError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HFlexError::WrongConfiguration { image, accel } => write!(
                f,
                "image scheduled for (P, K0, D) = {image:?} but accelerator is {accel:?}; \
                 HFlex avoids re-synthesis only for matching preprocessing"
            ),
            HFlexError::ScratchpadOverflow { rows_per_pe, c_depth } => write!(
                f,
                "C scratchpad overflow: {rows_per_pe} rows/PE > URAM depth {c_depth}"
            ),
            HFlexError::ShapeMismatch(s) => write!(f, "shape mismatch: {s}"),
            HFlexError::Backend(s) => write!(f, "backend error: {s}"),
        }
    }
}

impl std::error::Error for HFlexError {}

/// One SpMM problem: `C = alpha * A @ B + beta * C`. The HFlex parameter
/// set of Algorithm 1 — pointers + scalars, nothing hardware-shaped.
#[derive(Debug)]
pub struct SpmmProblem<'a> {
    /// Preprocessed A (carries M, K, Q and the scheduled non-zeros).
    pub a: &'a ScheduledMatrix,
    /// Dense B, row-major K × N.
    pub b: &'a [f32],
    /// Dense C in/out, row-major M × N.
    pub c: &'a mut [f32],
    /// Columns of B / C.
    pub n: usize,
    /// Scalar α.
    pub alpha: f32,
    /// Scalar β.
    pub beta: f32,
}

/// Result of one invocation.
#[derive(Clone, Debug)]
pub struct InvokeReport {
    /// Cycle-level timing of the run.
    pub sim: SimReport,
    /// Name of the backend that produced the functional result.
    pub backend: &'static str,
}

/// A "synthesized" Sextans accelerator: an immutable configuration plus the
/// execution backend that stands in for the silicon.
pub struct HFlexAccelerator {
    cfg: AcceleratorConfig,
    // `+ Send` keeps the accelerator itself Send + Sync (shareable across
    // threads like the seed's plain-config struct); executions serialize
    // through the lock, matching one physical accelerator.
    backend: Mutex<Box<dyn SpmmBackend + Send>>,
}

impl std::fmt::Debug for HFlexAccelerator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("HFlexAccelerator")
            .field("cfg", &self.cfg)
            .field("backend", &self.backend_name())
            .finish()
    }
}

impl HFlexAccelerator {
    /// One-time synthesis (the hours-long place-and-route the paper's flow
    /// replaces with... this constructor). Executes on the default
    /// [`backend::default_backend`] (native, auto-threaded).
    pub fn synthesize(cfg: AcceleratorConfig) -> Self {
        Self::synthesize_with_backend(cfg, backend::default_backend())
    }

    /// Synthesis with an explicit execution backend (see
    /// [`backend::create_send`] for name-based construction).
    pub fn synthesize_with_backend(
        cfg: AcceleratorConfig,
        backend: Box<dyn SpmmBackend + Send>,
    ) -> Self {
        HFlexAccelerator { cfg, backend: Mutex::new(backend) }
    }

    /// The immutable configuration.
    pub fn config(&self) -> &AcceleratorConfig {
        &self.cfg
    }

    /// Name of the execution backend.
    pub fn backend_name(&self) -> &'static str {
        self.backend.lock().unwrap().name()
    }

    /// Host-side preprocessing (§3.3's "C++ wrapper"): partition + OoO
    /// schedule + encode for THIS accelerator's (P, K0, D).
    pub fn preprocess(&self, a: &Coo) -> Result<ScheduledMatrix, HFlexError> {
        let sm = preprocess(a, self.cfg.p(), self.cfg.k0, self.cfg.d);
        if sm.rows_per_pe() > self.cfg.c_depth {
            return Err(HFlexError::ScratchpadOverflow {
                rows_per_pe: sm.rows_per_pe(),
                c_depth: self.cfg.c_depth,
            });
        }
        Ok(sm)
    }

    /// Execute one SpMM through the configured backend: the functional
    /// result is written into `problem.c`, cycle-accurate timing of what
    /// the silicon would do is returned. No re-synthesis, ever.
    pub fn invoke(&self, problem: SpmmProblem<'_>) -> Result<InvokeReport, HFlexError> {
        let sm = problem.a;
        let accel = (self.cfg.p(), self.cfg.k0, self.cfg.d);
        let image = (sm.p, sm.k0, sm.d);
        if accel != image {
            return Err(HFlexError::WrongConfiguration { image, accel });
        }
        if sm.rows_per_pe() > self.cfg.c_depth {
            return Err(HFlexError::ScratchpadOverflow {
                rows_per_pe: sm.rows_per_pe(),
                c_depth: self.cfg.c_depth,
            });
        }
        if problem.b.len() != sm.k * problem.n {
            return Err(HFlexError::ShapeMismatch(format!(
                "B has {} elements, expected K*N = {}",
                problem.b.len(),
                sm.k * problem.n
            )));
        }
        if problem.c.len() != sm.m * problem.n {
            return Err(HFlexError::ShapeMismatch(format!(
                "C has {} elements, expected M*N = {}",
                problem.c.len(),
                sm.m * problem.n
            )));
        }
        let backend_name = {
            let mut be = self.backend.lock().unwrap();
            let name = be.name();
            be.execute(sm, problem.b, problem.c, problem.n, problem.alpha, problem.beta)
                .map_err(|e| HFlexError::Backend(e.to_string()))?;
            name
        };
        let sim = simulate(sm, &self.cfg, problem.n);
        Ok(InvokeReport { sim, backend: backend_name })
    }
}

/// A matrix too tall for the C scratchpad, split into sequential row
/// blocks (extension over the paper, which *excludes* such matrices from
/// its evaluation: each block fits `c_depth × P` rows and is processed as
/// an independent SpMM over the same B — correctness is exact because C
/// rows partition cleanly across blocks).
#[derive(Clone, Debug)]
pub struct TiledImage {
    /// (first global row, scheduled image of the block) per block.
    pub blocks: Vec<(usize, ScheduledMatrix)>,
    /// Total rows (M).
    pub m: usize,
    /// Columns (K).
    pub k: usize,
}

impl HFlexAccelerator {
    /// Preprocess with automatic row-block tiling: always succeeds, even
    /// for M > c_depth × P (the paper's 5 GB/scratchpad exclusions).
    pub fn preprocess_tiled(&self, a: &Coo) -> TiledImage {
        let block_rows = self.cfg.c_depth * self.cfg.p();
        if a.m <= block_rows {
            return TiledImage {
                blocks: vec![(0, preprocess(a, self.cfg.p(), self.cfg.k0, self.cfg.d))],
                m: a.m,
                k: a.k,
            };
        }
        let nblocks = a.m.div_ceil(block_rows);
        // Bucket non-zeros by row block, shifting rows to block-local.
        let mut rows: Vec<Vec<u32>> = vec![Vec::new(); nblocks];
        let mut cols: Vec<Vec<u32>> = vec![Vec::new(); nblocks];
        let mut vals: Vec<Vec<f32>> = vec![Vec::new(); nblocks];
        for i in 0..a.nnz() {
            let blk = a.rows[i] as usize / block_rows;
            rows[blk].push(a.rows[i] - (blk * block_rows) as u32);
            cols[blk].push(a.cols[i]);
            vals[blk].push(a.vals[i]);
        }
        let blocks = (0..nblocks)
            .map(|blk| {
                let off = blk * block_rows;
                let m_blk = block_rows.min(a.m - off);
                let coo = Coo {
                    m: m_blk,
                    k: a.k,
                    rows: std::mem::take(&mut rows[blk]),
                    cols: std::mem::take(&mut cols[blk]),
                    vals: std::mem::take(&mut vals[blk]),
                };
                (off, preprocess(&coo, self.cfg.p(), self.cfg.k0, self.cfg.d))
            })
            .collect();
        TiledImage { blocks, m: a.m, k: a.k }
    }

    /// Execute a tiled SpMM: blocks run sequentially on the accelerator
    /// (B is re-streamed per block, exactly what the hardware would do);
    /// cycle counts accumulate across blocks.
    pub fn invoke_tiled(
        &self,
        image: &TiledImage,
        b: &[f32],
        c: &mut [f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<u64, HFlexError> {
        if b.len() != image.k * n {
            return Err(HFlexError::ShapeMismatch("B".into()));
        }
        if c.len() != image.m * n {
            return Err(HFlexError::ShapeMismatch("C".into()));
        }
        let mut total_cycles = 0u64;
        for (off, sm) in &image.blocks {
            // C rows of this block are contiguous in row-major C.
            let c_block = &mut c[off * n..(off + sm.m) * n];
            let report = self.invoke(SpmmProblem {
                a: sm,
                b,
                c: c_block,
                n,
                alpha,
                beta,
            })?;
            total_cycles += report.sim.cycles;
        }
        Ok(total_cycles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;
    use crate::sparse::{gen, rng::Rng};

    fn accel() -> HFlexAccelerator {
        HFlexAccelerator::synthesize(AcceleratorConfig::sextans_u280())
    }

    fn problem_data(k: usize, m: usize, n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let b = (0..k * n).map(|_| rng.normal()).collect();
        let c = (0..m * n).map(|_| rng.normal()).collect();
        (b, c)
    }

    #[test]
    fn one_accelerator_many_problem_shapes() {
        // The HFlex headline: the SAME synthesized accelerator runs SpMMs of
        // wildly different (M, K, N, nnz) with zero reconfiguration.
        let acc = accel();
        let mut rng = Rng::new(1);
        for (m, k, n) in [(64, 64, 8), (1000, 300, 16), (77, 4100, 64), (5, 5, 8)] {
            let a = gen::random_uniform(m, k, 0.1, &mut rng);
            let sm = acc.preprocess(&a).unwrap();
            let (b, mut c) = problem_data(k, m, n, 2);
            let mut want = c.clone();
            a.spmm_reference(&b, &mut want, n, 2.0, 0.5);
            let report = acc
                .invoke(SpmmProblem { a: &sm, b: &b, c: &mut c, n, alpha: 2.0, beta: 0.5 })
                .unwrap();
            prop::assert_allclose(&c, &want, 2e-4, 2e-4).unwrap();
            assert!(report.sim.cycles > 0);
        }
    }

    #[test]
    fn accelerator_is_send_and_sync() {
        // The accelerator must stay shareable across threads (pre-backend
        // behavior): Mutex<Box<dyn SpmmBackend + Send>> keeps Send + Sync.
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<HFlexAccelerator>();
    }

    #[test]
    fn default_backend_is_native_and_reported() {
        let acc = accel();
        assert_eq!(acc.backend_name(), "native");
        let mut rng = Rng::new(21);
        let a = gen::random_uniform(32, 32, 0.2, &mut rng);
        let sm = acc.preprocess(&a).unwrap();
        let (b, mut c) = problem_data(32, 32, 4, 22);
        let report = acc
            .invoke(SpmmProblem { a: &sm, b: &b, c: &mut c, n: 4, alpha: 1.0, beta: 0.0 })
            .unwrap();
        assert_eq!(report.backend, "native");
    }

    #[test]
    fn explicit_backend_selection() {
        let acc = HFlexAccelerator::synthesize_with_backend(
            AcceleratorConfig::sextans_u280(),
            crate::backend::create_send("functional").unwrap(),
        );
        assert_eq!(acc.backend_name(), "functional");
        let mut rng = Rng::new(23);
        let a = gen::random_uniform(40, 30, 0.15, &mut rng);
        let sm = acc.preprocess(&a).unwrap();
        let (b, mut c) = problem_data(30, 40, 3, 24);
        let mut want = c.clone();
        a.spmm_reference(&b, &mut want, 3, 1.0, 1.0);
        let report = acc
            .invoke(SpmmProblem { a: &sm, b: &b, c: &mut c, n: 3, alpha: 1.0, beta: 1.0 })
            .unwrap();
        assert_eq!(report.backend, "functional");
        prop::assert_allclose(&c, &want, 2e-4, 2e-4).unwrap();
    }

    #[test]
    fn rejects_image_from_other_configuration() {
        let acc = accel();
        let mut rng = Rng::new(3);
        let a = gen::random_uniform(64, 64, 0.1, &mut rng);
        // Preprocess for a DIFFERENT window size.
        let foreign = preprocess(&a, acc.config().p(), 1024, acc.config().d);
        let (b, mut c) = problem_data(64, 64, 8, 4);
        let err = acc
            .invoke(SpmmProblem { a: &foreign, b: &b, c: &mut c, n: 8, alpha: 1.0, beta: 0.0 })
            .unwrap_err();
        assert!(matches!(err, HFlexError::WrongConfiguration { .. }));
        assert!(err.to_string().contains("re-synthesis"));
    }

    #[test]
    fn rejects_scratchpad_overflow() {
        // M > c_depth * P: 64 PEs * 12,288 = 786,432 rows max.
        let acc = accel();
        let huge = Coo::empty(800_000, 16);
        let err = acc.preprocess(&huge).unwrap_err();
        assert!(matches!(err, HFlexError::ScratchpadOverflow { .. }));
    }

    #[test]
    fn rejects_shape_mismatch() {
        let acc = accel();
        let mut rng = Rng::new(5);
        let a = gen::random_uniform(16, 16, 0.2, &mut rng);
        let sm = acc.preprocess(&a).unwrap();
        let (b, mut c) = problem_data(16, 16, 8, 6);
        let err = acc
            .invoke(SpmmProblem { a: &sm, b: &b[..10], c: &mut c, n: 8, alpha: 1.0, beta: 0.0 })
            .unwrap_err();
        assert!(matches!(err, HFlexError::ShapeMismatch(_)));
    }

    use crate::sparse::Coo;

    fn tiny_accel() -> HFlexAccelerator {
        // Shrunken scratchpad to exercise tiling with small matrices.
        let mut cfg = AcceleratorConfig::sextans_u280();
        cfg.pegs = 2;
        cfg.pes_per_peg = 2; // P = 4
        cfg.c_depth = 16; // block = 64 rows
        cfg.k0 = 32;
        HFlexAccelerator::synthesize(cfg)
    }

    #[test]
    fn tiled_matches_reference_over_blocks() {
        let acc = tiny_accel();
        let mut rng = Rng::new(7);
        let a = gen::random_uniform(200, 70, 0.1, &mut rng); // 4 blocks
        let image = acc.preprocess_tiled(&a);
        assert_eq!(image.blocks.len(), 4);
        let n = 5;
        let (b, mut c) = problem_data(70, 200, n, 8);
        let mut want = c.clone();
        a.spmm_reference(&b, &mut want, n, 1.5, -0.5);
        let cycles = acc
            .invoke_tiled(&image, &b, &mut c, n, 1.5, -0.5)
            .unwrap();
        prop::assert_allclose(&c, &want, 2e-4, 2e-4).unwrap();
        assert!(cycles > 0);
    }

    #[test]
    fn tiled_single_block_when_it_fits() {
        let acc = tiny_accel();
        let mut rng = Rng::new(9);
        let a = gen::random_uniform(60, 40, 0.1, &mut rng);
        let image = acc.preprocess_tiled(&a);
        assert_eq!(image.blocks.len(), 1);
    }

    #[test]
    fn tiled_every_block_fits_scratchpad() {
        let acc = tiny_accel();
        let mut rng = Rng::new(11);
        let a = gen::random_uniform(300, 50, 0.05, &mut rng);
        let image = acc.preprocess_tiled(&a);
        for (_, sm) in &image.blocks {
            assert!(sm.rows_per_pe() <= acc.config().c_depth);
        }
        // Every non-zero lands in exactly one block.
        let total: usize = image.blocks.iter().map(|(_, sm)| sm.nnz).sum();
        assert_eq!(total, a.nnz());
    }

    #[test]
    fn tiled_beats_plain_preprocess_rejection() {
        // The plain path refuses what the tiled path handles.
        let acc = tiny_accel();
        let mut rng = Rng::new(13);
        let a = gen::random_uniform(200, 30, 0.08, &mut rng);
        assert!(matches!(
            acc.preprocess(&a),
            Err(HFlexError::ScratchpadOverflow { .. })
        ));
        let image = acc.preprocess_tiled(&a);
        let n = 2;
        let (b, mut c) = problem_data(30, 200, n, 14);
        acc.invoke_tiled(&image, &b, &mut c, n, 1.0, 0.0).unwrap();
    }
}
