//! The 1,400-SpMM evaluation sweep (paper §4.1): 200 matrices × 7 N values
//! × 4 platforms, producing the [`SweepPoint`]s every figure consumes.

use crate::arch::AcceleratorConfig;
use crate::metrics::{bandwidth_utilization, SweepPoint};
use crate::perfmodel::energy::flop_per_joule;
use crate::perfmodel::MatrixStats;
use crate::sched::preprocess;
use crate::sparse::catalog::{self, Scale, N_VALUES};

use crate::arch::simulator::problem_flops;

/// Sweep configuration.
#[derive(Clone, Debug)]
pub struct SweepOptions {
    /// Catalog scale (Ci caps matrix sizes; Full is the headline run).
    pub scale: Scale,
    /// N values to sweep (default: the paper's 8..512).
    pub n_values: Vec<usize>,
    /// Optional cap on matrix count (smoke tests).
    pub max_matrices: Option<usize>,
    /// Take every `stride`-th matrix (1 = all): keeps reduced sweeps
    /// representative across families instead of SNAP-heavy prefixes.
    pub stride: usize,
    /// Print progress to stderr.
    pub verbose: bool,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            scale: Scale::Ci,
            n_values: N_VALUES.to_vec(),
            max_matrices: None,
            stride: 1,
            verbose: false,
        }
    }
}

/// Run the full sweep. The A image is preprocessed ONCE per matrix (the
/// U280 and Sextans-P rows share P/K0/D, and GPUs only need statistics).
pub fn run_sweep(opts: &SweepOptions) -> Vec<SweepPoint> {
    let specs = catalog::catalog(opts.scale);
    let stride = opts.stride.max(1);
    let strided: Vec<&catalog::MatrixSpec> = specs.iter().step_by(stride).collect();
    let count = opts.max_matrices.unwrap_or(strided.len()).min(strided.len());
    let cfg = AcceleratorConfig::sextans_u280();
    let mut points = Vec::with_capacity(count * opts.n_values.len() * 4);

    for (idx, &spec) in strided.iter().take(count).enumerate() {
        let coo = spec.build();
        if opts.verbose && idx % 20 == 0 {
            eprintln!(
                "[sweep] {idx}/{count} {} ({}x{}, nnz {})",
                spec.name,
                coo.m,
                coo.k,
                coo.nnz()
            );
        }
        let stats = MatrixStats {
            m: coo.m,
            k: coo.k,
            nnz: coo.nnz(),
            max_row_nnz: coo.max_row_nnz(),
        };
        let image = preprocess(&coo, cfg.p(), cfg.k0, cfg.d);
        for &n in &opts.n_values {
            let flops = problem_flops(stats.nnz, stats.m, n);
            for platform in crate::perfmodel::platforms::ALL {
                let seconds = platform.seconds(Some(&image), &stats, n);
                let spec_p = platform.spec();
                points.push(SweepPoint {
                    matrix: spec.name.clone(),
                    platform,
                    n,
                    flops,
                    seconds,
                    gflops: flops as f64 / seconds / 1e9,
                    bw_util: bandwidth_utilization(
                        stats.nnz,
                        stats.m,
                        stats.k,
                        n,
                        seconds,
                        spec_p.bandwidth_gbps,
                    ),
                    flop_per_joule: flop_per_joule(platform, flops, seconds),
                });
            }
        }
    }
    points
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::Platform;

    fn small_sweep() -> Vec<SweepPoint> {
        run_sweep(&SweepOptions {
            scale: Scale::Ci,
            n_values: vec![8, 64],
            max_matrices: Some(6),
            ..Default::default()
        })
    }

    #[test]
    fn sweep_covers_all_cells() {
        let pts = small_sweep();
        assert_eq!(pts.len(), 6 * 2 * 4);
    }

    #[test]
    fn all_points_have_positive_time_and_throughput() {
        for p in small_sweep() {
            assert!(p.seconds > 0.0, "{p:?}");
            assert!(p.gflops > 0.0, "{p:?}");
            assert!(p.bw_util > 0.0 && p.bw_util < 1.0, "{p:?}");
            assert!(p.flop_per_joule > 0.0);
        }
    }

    #[test]
    fn flops_scale_linearly_with_n() {
        let pts = small_sweep();
        let a = pts
            .iter()
            .find(|p| p.n == 8 && p.platform == Platform::Sextans)
            .unwrap();
        let b = pts
            .iter()
            .find(|p| p.matrix == a.matrix && p.n == 64 && p.platform == Platform::Sextans)
            .unwrap();
        assert_eq!(b.flops, a.flops * 8);
    }
}
