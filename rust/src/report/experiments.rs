//! Experiment drivers: one function per paper table/figure (see DESIGN.md
//! §4 for the index). Each returns the formatted report text; `run_all`
//! also writes `results/<id>.txt` (+ CSV series for the figures).

use std::path::Path;

use anyhow::Result;

use super::sweep::{run_sweep, SweepOptions};
use super::{format_table, write_file};
use crate::arch::{resources, simulate_unchecked, AcceleratorConfig};
use crate::metrics::{
    self, cdf, geomean_speedup, running_peak, summarize, SweepPoint,
};
use crate::perfmodel::platforms::ALL;
use crate::perfmodel::Platform;
use crate::sched::preprocess::{preprocess_mode, ScheduleMode};
use crate::sched::preprocess;
use crate::sparse::catalog::{self, Scale};

/// Table 1 — incremental/accumulative speedups on crystm03 as optimizations
/// stack: Baseline (CSR in-order, 1 PU, 1 PE) → +OoO → +8 PUs → +64 PEs.
/// Paper: 9.97× / 7.97× / 45.3× incremental (3608× accumulated).
pub fn table1() -> String {
    let coo = catalog::crystm03_like().build();
    let n = 512;
    let base_cfg = {
        let mut c = AcceleratorConfig::sextans_u280();
        c.pegs = 1;
        c.pes_per_peg = 1;
        c.n0 = 1;
        c
    };
    let pu_cfg = {
        let mut c = base_cfg.clone();
        c.n0 = 8;
        c
    };
    let full_cfg = AcceleratorConfig::sextans_u280();

    // Baseline: row-major (CSR) in-order streaming, no sharing, 1 PE.
    let img_base = preprocess_mode(&coo, 1, base_cfg.k0, base_cfg.d, ScheduleMode::InOrderRowMajor);
    // +OoO scheduling.
    let img_ooo = preprocess(&coo, 1, base_cfg.k0, base_cfg.d);
    // +64 PEs.
    let img_full = preprocess(&coo, full_cfg.p(), full_cfg.k0, full_cfg.d);

    let t = [
        simulate_unchecked(&img_base, &base_cfg, n).seconds,
        simulate_unchecked(&img_ooo, &base_cfg, n).seconds,
        simulate_unchecked(&img_ooo, &pu_cfg, n).seconds,
        simulate_unchecked(&img_full, &full_cfg, n).seconds,
    ];
    let incr: Vec<f64> = (0..4)
        .map(|i| if i == 0 { 1.0 } else { t[i - 1] / t[i] })
        .collect();
    let accum: Vec<f64> = (0..4).map(|i| t[0] / t[i]).collect();

    let mut s = String::new();
    s.push_str("Table 1: incremental and accumulative speedups on crystm03 (N=512)\n");
    s.push_str(&format_table(
        &["", "Baseline", "OoO Scheduling", "8 PUs", "64 PEs"],
        &[
            vec![
                "Incr.".into(),
                format!("{:.2}x", incr[0]),
                format!("{:.2}x", incr[1]),
                format!("{:.2}x", incr[2]),
                format!("{:.2}x", incr[3]),
            ],
            vec![
                "Accum.".into(),
                format!("{:.0}x", accum[0]),
                format!("{:.0}x", accum[1]),
                format!("{:.0}x", accum[2]),
                format!("{:.0}x", accum[3]),
            ],
            vec![
                "Paper".into(),
                "1x".into(),
                "9.97x".into(),
                "7.97x".into(),
                "45.3x".into(),
            ],
        ],
    ));
    s
}

/// Table 2 — evaluated-workload specification (catalog statistics).
pub fn table2(scale: Scale) -> String {
    let specs = catalog::catalog(scale);
    let st = catalog::stats(&specs);
    let mut s = String::new();
    s.push_str("Table 2: the specification of SpMM evaluation\n");
    s.push_str(&format_table(
        &["Property", "Value", "Paper"],
        &[
            vec!["Number of SpMMs".into(), st.spmms.to_string(), "1,400".into()],
            vec!["Number of Matrices".into(), st.matrices.to_string(), "200".into()],
            vec![
                "Row/column".into(),
                format!("{} - {}", st.dim_range.0, st.dim_range.1),
                "5 - 513,351".into(),
            ],
            vec![
                "NNZ".into(),
                format!("{} - {}", st.nnz_range.0, st.nnz_range.1),
                "10 - 37,464,962".into(),
            ],
            vec![
                "Density".into(),
                format!("{:.2E} - {:.2E}", st.density_range.0, st.density_range.1),
                "5.97E-6 - 4.00E-1".into(),
            ],
            vec![
                "N".into(),
                format!("{:?}", catalog::N_VALUES),
                "8..512".into(),
            ],
        ],
    ));
    s
}

/// Table 3 — platform specs + achieved peak SpMM throughput from the sweep.
pub fn table3(points: &[SweepPoint]) -> String {
    let mut rows = Vec::new();
    let paper_peak = [127.8, 181.1, 688.0, 343.6];
    for (i, p) in ALL.iter().enumerate() {
        let spec = p.spec();
        let sum = summarize(*p, points);
        rows.push(vec![
            spec.name.to_string(),
            format!("{} nm", spec.tech_nm),
            format!("{:.0} MHz", spec.freq_mhz),
            format!("{:.0} GB/s", spec.bandwidth_gbps),
            format!("{:.1} MB", spec.onchip_mb),
            format!("{:.0} W", spec.power_w),
            format!("{:.1} GF/s", sum.peak_gflops),
            format!("{:.1} GF/s", paper_peak[i]),
        ]);
    }
    let mut s = String::new();
    s.push_str("Table 3: platform specs and achieved peak SpMM throughput\n");
    s.push_str(&format_table(
        &["Platform", "Tech", "Freq", "Bdw", "On-chip", "Power", "Peak (ours)", "Peak (paper)"],
        &rows,
    ));
    s
}

/// Table 4 — U280 resource utilization from the component model.
pub fn table4() -> String {
    let cfg = AcceleratorConfig::sextans_u280();
    let r = resources::estimate(&cfg);
    let paper = [(3086u64, 76u64), (3316, 36), (690_255, 26), (379_649, 29), (768, 80)];
    let mut rows = Vec::new();
    for ((name, used, avail, pct), (p_used, p_pct)) in
        r.utilization(&resources::U280).into_iter().zip(paper)
    {
        rows.push(vec![
            name,
            used.to_string(),
            avail.to_string(),
            format!("{pct:.0}%"),
            format!("{p_used} ({p_pct}%)"),
        ]);
    }
    let mut s = String::new();
    s.push_str("Table 4: resource utilization of Sextans on a Xilinx U280\n");
    s.push_str(&format_table(&["", "Used", "Available", "Util", "Paper"], &rows));
    s
}

/// Table 5 — comparison with related accelerators (published rows are
/// static; our Sextans rows are measured from the sweep).
pub fn table5(points: &[SweepPoint]) -> String {
    let sx = summarize(Platform::Sextans, points);
    let sxp = summarize(Platform::SextansP, points);
    let max_size = points.iter().map(|p| p.flops).max().unwrap_or(0);
    let rows: Vec<Vec<String>> = vec![
        vec!["T2S-Tensor".into(), "Dense MM,MV".into(), "2e3".into(), "-".into(), "738 GF/s".into(), "Yes/No".into()],
        vec!["AutoSA".into(), "Dense MM".into(), "4e6".into(), "7e9".into(), "950 GF/s".into(), "Yes/No".into()],
        vec!["Tensaurus".into(), "SpMV,SpMM".into(), "4.2e6".into(), "-".into(), "512 GF/s".into(), "No/No".into()],
        vec!["Fowers et al.".into(), "SpMV".into(), "5e6".into(), "<1e7".into(), "3.9 GF/s".into(), "Yes/No".into()],
        vec!["Spaghetti".into(), "SpGEMM".into(), "1.6e7".into(), "-".into(), "27 GF/s".into(), "Yes/No".into()],
        vec!["ExTensor".into(), "SpMM,SpGEMM".into(), "6e6".into(), "-".into(), "64 GF/s".into(), "No/No".into()],
        vec!["SpArch".into(), "SpGEMM".into(), "1.65e7".into(), "-".into(), "10.4 GF/s".into(), "No/No".into()],
        vec!["OuterSPACE".into(), "SpGEMM".into(), "1.65e7".into(), "-".into(), "2.9 GF/s".into(), "No/No".into()],
        vec![
            "Sextans (ours)".into(),
            "SpMM".into(),
            format!("{:.1e}", points.iter().map(|p| p.flops / (2 * p.n as u64).max(1)).max().unwrap_or(0) as f64),
            format!("{max_size:.1e}"),
            format!("{:.1} GF/s", sx.peak_gflops),
            "Yes/HFlex".into(),
        ],
        vec![
            "Sextans-P (ours)".into(),
            "SpMM".into(),
            "-".into(),
            format!("{max_size:.1e}"),
            format!("{:.1} GF/s", sxp.peak_gflops),
            "Sim/HFlex".into(),
        ],
    ];
    let mut s = String::new();
    s.push_str("Table 5: comparison with related accelerators\n");
    s.push_str(&format_table(
        &["Accelerator", "Kernels", "Mat NNZ", "Prob. size", "Throughput", "Real-exe/HFlex"],
        &rows,
    ));
    s
}

/// Fig. 6 — accelerator floorplan (qualitative ASCII rendition).
pub fn fig6() -> String {
    let mut s = String::from("Figure 6: layout of the Sextans prototype on a U280\n");
    s.push_str(&resources::floorplan(&AcceleratorConfig::sextans_u280()));
    s
}

/// Fig. 7 — throughput and execution time vs problem size (summary + the
/// full per-point series lands in the CSV).
pub fn fig7(points: &[SweepPoint]) -> String {
    let mut s = String::from(
        "Figure 7: throughput (a) and execution time (b) vs problem size\n\
         (full series in fig7_points.csv; decile summary below)\n",
    );
    for p in ALL {
        let mut pts: Vec<(f64, f64, f64)> = points
            .iter()
            .filter(|x| x.platform == p)
            .map(|x| (x.flops as f64, x.gflops, x.seconds))
            .collect();
        pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        s.push_str(&format!("\n  {} ({} points)\n", p.spec().name, pts.len()));
        let deciles = 5;
        for d in 0..deciles {
            let lo = d * pts.len() / deciles;
            let hi = ((d + 1) * pts.len() / deciles).max(lo + 1).min(pts.len());
            let bucket = &pts[lo..hi];
            let size = metrics::geomean(&bucket.iter().map(|x| x.0).collect::<Vec<_>>());
            let gf = metrics::geomean(&bucket.iter().map(|x| x.1).collect::<Vec<_>>());
            let t = metrics::geomean(&bucket.iter().map(|x| x.2).collect::<Vec<_>>());
            s.push_str(&format!(
                "    size ~{size:>10.3e} FLOP   {gf:>8.2} GF/s   {t:>10.3e} s\n"
            ));
        }
    }
    s.push('\n');
    s.push_str(&headline(points));
    s
}

/// Headline geomean speedups normalized to K80 (paper: 1.00 / 2.50 / 4.32 /
/// 4.94) plus Sextans-P vs V100 (paper: 1.14).
pub fn headline(points: &[SweepPoint]) -> String {
    let paper = [1.00, 2.50, 4.32, 4.94];
    let mut s = String::from("Headline geomean speedups (normalized to K80):\n");
    for (i, p) in ALL.iter().enumerate() {
        let sp = geomean_speedup(points, *p, Platform::K80);
        s.push_str(&format!(
            "  {:<12} {:>6.2}x   (paper {:>5.2}x)\n",
            p.spec().name,
            sp,
            paper[i]
        ));
    }
    let pv = geomean_speedup(points, Platform::SextansP, Platform::V100);
    let sk = geomean_speedup(points, Platform::Sextans, Platform::K80);
    s.push_str(&format!("  Sextans-P over V100: {pv:.2}x (paper 1.14x)\n"));
    s.push_str(&format!("  Sextans over K80:    {sk:.2}x (paper 2.50x)\n"));
    s
}

/// Fig. 8 — peak throughput vs problem size + CDF throughput.
pub fn fig8(points: &[SweepPoint]) -> String {
    let mut s = String::from(
        "Figure 8: (a) peak throughput growth with problem size, (b) CDF\n",
    );
    for p in ALL {
        let series: Vec<(f64, f64)> = points
            .iter()
            .filter(|x| x.platform == p)
            .map(|x| (x.flops as f64, x.gflops))
            .collect();
        let peaks = running_peak(&series);
        let final_peak = peaks.last().map(|x| x.1).unwrap_or(0.0);
        // Size at which the platform first reaches 90% of its final peak —
        // the paper's "Sextans saturates earliest (~8e7 FLOP)" observation.
        let sat = peaks
            .iter()
            .find(|(_, v)| *v >= 0.9 * final_peak)
            .map(|(sz, _)| *sz)
            .unwrap_or(0.0);
        let gfs: Vec<f64> = series.iter().map(|x| x.1).collect();
        let c = cdf(&gfs);
        let median = c
            .iter()
            .find(|(_, f)| *f >= 0.5)
            .map(|(v, _)| *v)
            .unwrap_or(0.0);
        s.push_str(&format!(
            "  {:<12} peak {:>8.2} GF/s, reaches 90% of peak at ~{:.2e} FLOP, median {:.2} GF/s\n",
            p.spec().name,
            final_peak,
            sat,
            median
        ));
    }
    s
}

/// Fig. 9 — memory bandwidth utilization (geomean + max per platform).
pub fn fig9(points: &[SweepPoint]) -> String {
    let paper_geo = [1.47, 3.85, 3.39, 3.88];
    let paper_max = [19.00, 14.92, 59.96, 14.96];
    let mut s = String::from("Figure 9: memory bandwidth utilization\n");
    s.push_str(&format_table(
        &["Platform", "Geomean", "Paper", "Max", "Paper max"],
        &ALL
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let sum = summarize(*p, points);
                vec![
                    p.spec().name.to_string(),
                    format!("{:.2}%", 100.0 * sum.geomean_bw_util),
                    format!("{:.2}%", paper_geo[i]),
                    format!("{:.2}%", 100.0 * sum.max_bw_util),
                    format!("{:.2}%", paper_max[i]),
                ]
            })
            .collect::<Vec<_>>(),
    ));
    s
}

/// Fig. 10 — energy efficiency (geomean + max, normalized to K80).
pub fn fig10(points: &[SweepPoint]) -> String {
    let paper_geo = [1.06e8, 6.63e8, 2.07e8, 7.10e8];
    let mut s = String::from("Figure 10: energy efficiency\n");
    let k80 = summarize(Platform::K80, points).geomean_flop_per_joule;
    s.push_str(&format_table(
        &["Platform", "Geomean FLOP/J", "Paper", "vs K80", "Paper vs K80"],
        &ALL
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let sum = summarize(*p, points);
                vec![
                    p.spec().name.to_string(),
                    format!("{:.2e}", sum.geomean_flop_per_joule),
                    format!("{:.2e}", paper_geo[i]),
                    format!("{:.2}x", sum.geomean_flop_per_joule / k80),
                    format!("{:.2}x", paper_geo[i] / paper_geo[0]),
                ]
            })
            .collect::<Vec<_>>(),
    ));
    s
}

/// §2.4 motivation experiment — the cost of the *alternative* to HFlex:
/// decompose each SpMM into fixed-size 4096x4096 dense-MM kernels (the
/// AutoSA-style accelerator) and pay 0.15 ms OpenCL launch overhead per
/// kernel. Paper: the 50 SNAP matrices average 1,793 kernels = 269 ms of
/// pure launch overhead, vs 5.85 ms average K80 execution.
pub fn motivation_decompose(scale: Scale) -> String {
    const TILE: usize = 4096;
    const LAUNCH_S: f64 = 0.15e-3;
    let specs = catalog::catalog(scale);
    let snap: Vec<_> = specs
        .iter()
        .filter(|s| s.family.source() == "SNAP")
        .collect();
    let mut kernel_counts = Vec::new();
    for s in &snap {
        // Dense-MM tiling of C = A x B at N = 512: every (M, K, N) tile.
        let tiles = s.m.div_ceil(TILE) * s.k.div_ceil(TILE) * 512usize.div_ceil(TILE);
        kernel_counts.push(tiles as f64);
    }
    let avg = kernel_counts.iter().sum::<f64>() / kernel_counts.len() as f64;
    let max = kernel_counts.iter().cloned().fold(0.0, f64::max);
    let overhead_ms = avg * LAUNCH_S * 1e3;

    let mut s = String::from(
        "Motivation (paper S2.4): fixed-size-kernel decomposition vs HFlex\n",
    );
    s.push_str(&format_table(
        &["Quantity", "Measured", "Paper"],
        &[
            vec![
                "SNAP matrices".into(),
                snap.len().to_string(),
                "50".into(),
            ],
            vec![
                "Avg decomposed 4096^2 kernels".into(),
                format!("{avg:.0}"),
                "1793".into(),
            ],
            vec!["Max kernels".into(), format!("{max:.0}"), "-".into()],
            vec![
                "Avg launch overhead (0.15 ms/kernel)".into(),
                format!("{overhead_ms:.0} ms"),
                "269 ms".into(),
            ],
            vec![
                "HFlex invocations per SpMM".into(),
                "1".into(),
                "1".into(),
            ],
        ],
    ));
    s.push_str(
        "\nWith HFlex the same SpMMs are a single invocation each: the loop\n\
         bounds travel in the Q pointer list, not in the hardware.\n",
    );
    s
}

/// Extension ablation: effective II and bubble rate vs RAW distance D.
pub fn ablation_d() -> String {
    let coo = catalog::crystm03_like().build();
    let cfg = AcceleratorConfig::sextans_u280();
    let mut s = String::from("Ablation: RAW distance D vs effective II (crystm03)\n");
    let mut rows = Vec::new();
    for d in [1usize, 2, 4, 6, 8, 10, 12, 16] {
        let sm = preprocess(&coo, cfg.p(), cfg.k0, d);
        rows.push(vec![
            d.to_string(),
            format!("{:.4}", sm.effective_ii()),
            format!(
                "{:.2}%",
                100.0 * sm.total_bubbles() as f64 / sm.total_slots() as f64
            ),
        ]);
    }
    s.push_str(&format_table(&["D", "Effective II", "Bubble rate"], &rows));
    s
}

/// Extension ablation: window size K0 sweep.
pub fn ablation_window() -> String {
    let coo = catalog::crystm03_like().build();
    let cfg = AcceleratorConfig::sextans_u280();
    let mut s = String::from("Ablation: window size K0 (crystm03, N=512)\n");
    let mut rows = Vec::new();
    for k0 in [512usize, 1024, 2048, 4096, 8192, 16384] {
        let sm = preprocess(&coo, cfg.p(), k0, cfg.d);
        let mut c = cfg.clone();
        c.k0 = k0;
        let r = simulate_unchecked(&sm, &c, 512);
        rows.push(vec![
            k0.to_string(),
            sm.num_windows.to_string(),
            r.cycles.to_string(),
            format!("{:.2}", r.gflops),
        ]);
    }
    s.push_str(&format_table(&["K0", "Windows", "Cycles", "GF/s"], &rows));
    s
}

/// Write the per-point CSV consumed by external plotting.
pub fn points_csv(points: &[SweepPoint]) -> String {
    let mut s = String::from("matrix,platform,n,flops,seconds,gflops,bw_util,flop_per_joule\n");
    for p in points {
        s.push_str(&format!(
            "{},{},{},{},{:.6e},{:.4},{:.6},{:.4e}\n",
            p.matrix,
            p.platform.spec().name,
            p.n,
            p.flops,
            p.seconds,
            p.gflops,
            p.bw_util,
            p.flop_per_joule
        ));
    }
    s
}

/// Run everything and write `results/`. Returns the combined text.
pub fn run_all(out_dir: &Path, scale: Scale, max_matrices: Option<usize>) -> Result<String> {
    std::fs::create_dir_all(out_dir)?;
    let mut combined = String::new();
    let mut emit = |name: &str, text: String| -> Result<()> {
        write_file(out_dir, &format!("{name}.txt"), &text)?;
        combined.push_str(&text);
        combined.push('\n');
        Ok(())
    };

    emit("table1", table1())?;
    emit("table2", table2(scale))?;
    emit("table4", table4())?;
    emit("fig6", fig6())?;
    // Motivation only reads spec *dimensions* (no matrix is built), so it
    // always uses the Full-scale dims the paper's SNAP set has.
    emit("motivation", motivation_decompose(Scale::Full))?;
    emit("ablation_d", ablation_d())?;
    emit("ablation_window", ablation_window())?;

    let points = run_sweep(&SweepOptions {
        scale,
        max_matrices,
        verbose: true,
        ..Default::default()
    });
    write_file(out_dir, "fig7_points.csv", &points_csv(&points))?;
    emit("table3", table3(&points))?;
    emit("table5", table5(&points))?;
    emit("fig7", fig7(&points))?;
    emit("fig8", fig8(&points))?;
    emit("fig9", fig9(&points))?;
    emit("fig10", fig10(&points))?;
    emit("headline", headline(&points))?;
    Ok(combined)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_points() -> Vec<SweepPoint> {
        run_sweep(&SweepOptions {
            scale: Scale::Ci,
            n_values: vec![8, 64],
            max_matrices: Some(5),
            ..Default::default()
        })
    }

    #[test]
    fn table1_reports_all_columns() {
        let t = table1();
        assert!(t.contains("Baseline"));
        assert!(t.contains("OoO"));
        assert!(t.contains("64 PEs"));
        assert!(t.contains("Paper"));
    }

    #[test]
    fn table2_matches_catalog() {
        let t = table2(Scale::Ci);
        assert!(t.contains("1400"));
        assert!(t.contains("200"));
    }

    #[test]
    fn figures_render_from_points() {
        let pts = tiny_points();
        for text in [table3(&pts), table5(&pts), fig7(&pts), fig8(&pts), fig9(&pts), fig10(&pts)] {
            assert!(text.len() > 100);
            assert!(text.contains("SEXTANS") || text.contains("Sextans"));
        }
    }

    #[test]
    fn csv_has_header_and_rows() {
        let pts = tiny_points();
        let csv = points_csv(&pts);
        assert!(csv.starts_with("matrix,platform"));
        assert_eq!(csv.lines().count(), pts.len() + 1);
    }

    #[test]
    fn ablations_render() {
        assert!(ablation_d().contains("Effective II"));
        assert!(ablation_window().contains("Windows"));
    }
}
