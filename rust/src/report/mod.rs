//! Report generation: the evaluation sweep and per-table/figure drivers.

pub mod experiments;
pub mod sweep;

use std::path::Path;

use anyhow::{Context, Result};

pub use sweep::{run_sweep, SweepOptions};

/// Render an aligned text table.
pub fn format_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncols = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncols) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut s = String::new();
    let sep: String = widths
        .iter()
        .map(|w| "-".repeat(w + 2))
        .collect::<Vec<_>>()
        .join("+");
    s.push_str(&format!("+{sep}+\n"));
    let hdr: Vec<String> = headers
        .iter()
        .enumerate()
        .map(|(i, h)| format!(" {:<width$} ", h, width = widths[i]))
        .collect();
    s.push_str(&format!("|{}|\n", hdr.join("|")));
    s.push_str(&format!("+{sep}+\n"));
    for row in rows {
        let cells: Vec<String> = (0..ncols)
            .map(|i| {
                let empty = String::new();
                let c = row.get(i).unwrap_or(&empty);
                format!(" {:<width$} ", c, width = widths[i])
            })
            .collect();
        s.push_str(&format!("|{}|\n", cells.join("|")));
    }
    s.push_str(&format!("+{sep}+\n"));
    s
}

/// Write a report file.
pub fn write_file(dir: &Path, name: &str, content: &str) -> Result<()> {
    let path = dir.join(name);
    std::fs::write(&path, content).with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_aligns_columns() {
        let t = format_table(
            &["A", "Bee"],
            &[vec!["xx".into(), "y".into()], vec!["1".into(), "22222".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(t.contains("Bee"));
    }

    #[test]
    fn short_rows_are_padded() {
        let t = format_table(&["A", "B"], &[vec!["only".into()]]);
        assert!(t.contains("only"));
    }
}
