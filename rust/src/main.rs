//! Sextans CLI — the leader entrypoint.
//!
//! ```text
//! sextans repro [--all | <exp-id>] [--out DIR] [--full] [--max-matrices N]
//! sextans run   --m M --k K [--n N] [--density D] [--alpha A] [--beta B]
//!               [--backend NAME] [--shards S] [--xla]
//! sextans gen   --m M --k K --density D --out file.mtx [--seed S]
//! sextans serve [--requests R] [--workers W] [--backend NAME] [--shards S]
//!               [--trace-json FILE] [--metrics-json FILE]
//!               [--listen HOST:PORT] [--max-connections C]
//! sextans loadgen [--addr HOST:PORT] [--rate R] [--duration S]
//!               [--mix power-law|banded|uniform] [--images I] [--hot F]
//!               [--name NAME] [--out DIR] [--metrics-json FILE]
//!               [--baseline FILE] [--tolerance T] [--strict] [--drain-server]
//! sextans bench [--full] [--name NAME] [--out DIR] [--timestamp TS]
//!               [--backend NAME] [--baseline FILE] [--tolerance T] [--strict]
//!               [--write-baseline]
//! sextans trace [<catalog-matrix>] [--requests R] [--workers W]
//!               [--backend NAME] [--out FILE]
//! sextans worker [--addr HOST:PORT] [--backend NAME]
//!                [--read-timeout-ms T] [--write-timeout-ms T]
//!                [--max-resident-mb MB] [--fault SPEC]
//! sextans chaos [--workers N] [--duration S] [--senders T] [--seed S]
//!               [--name NAME] [--out DIR] [--timestamp TS]
//! sextans backends [--probe HOST:PORT]
//! sextans info
//! ```
//!
//! `--backend` picks the execution engine by registry name (default:
//! `native`, the multi-threaded host engine; `sextans backends` lists every
//! registered engine with its capability and availability in this build).
//! `--shards S` (S > 1) spreads each SpMM across S parallel
//! accelerator instances of that backend — `run` drives the
//! [`sextans::shard`] API directly and prints per-shard load and latency;
//! `serve` wraps the spec as `sharded:<S>:<backend>` so the coordinator
//! picks it up from the registry.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use sextans::arch::simulator::problem_flops;
use sextans::arch::{resources, simulate, AcceleratorConfig};
use sextans::backend::{self, SpmmBackend};
use sextans::bench_util;
use sextans::cli::Cli;
use sextans::coordinator::{
    AdmissionPolicy, BatchPolicy, PipelineConfig, ReshardPolicy, ResidencyPolicy, Server,
    SpmmRequest,
};
use sextans::hflex::{HFlexAccelerator, SpmmProblem};
use sextans::net::{self, FaultSpec, WorkerConfig};
use sextans::perfmodel::Platform;
use sextans::report::{self, experiments};
use sextans::sched::preprocess;
use sextans::serve_net::{
    proto, ClientError, FrontClient, FrontDoor, FrontDoorConfig, LoadgenOptions, Mix,
    ShedReason,
};
use sextans::shard::{ShardExecutor, ShardedMatrix};
use sextans::sparse::catalog::{self, Scale};
use sextans::sparse::{gen, mm_io, rng::Rng, Coo};
use sextans::telemetry::bench_record::{compare, BenchMeasurement, BenchRecord, ScalingPoint};
use sextans::telemetry::trace::{build_tree, render_tree, TelemetrySink, TraceCollector};

fn main() {
    let cli = Cli::from_env();
    let result = match cli.command.as_str() {
        "repro" => cmd_repro(&cli),
        "run" => cmd_run(&cli),
        "gen" => cmd_gen(&cli),
        "serve" => cmd_serve(&cli),
        "loadgen" => cmd_loadgen(&cli),
        "bench" => cmd_bench(&cli),
        "trace" => cmd_trace(&cli),
        "worker" => cmd_worker(&cli),
        "chaos" => cmd_chaos(&cli),
        "backends" => cmd_backends(&cli),
        "info" | "" => cmd_info(),
        other => {
            eprintln!("unknown command {other:?}");
            eprintln!(
                "commands: repro, run, gen, serve, loadgen, bench, trace, worker, chaos, \
                 backends, info"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// `repro`: regenerate paper tables/figures into --out (default `results`).
fn cmd_repro(cli: &Cli) -> Result<()> {
    let out = PathBuf::from(cli.get("out").unwrap_or("results"));
    let scale = if cli.flag("full") { Scale::Full } else { Scale::Ci };
    let max_matrices = cli.get("max-matrices").and_then(|s| s.parse().ok());

    if cli.flag("all") || cli.positional.is_empty() {
        let text = experiments::run_all(&out, scale, max_matrices)?;
        println!("{text}");
        println!("[repro] reports written to {}", out.display());
        return Ok(());
    }
    for exp in &cli.positional {
        let text = match exp.as_str() {
            "table1" => experiments::table1(),
            "table2" => experiments::table2(scale),
            "table4" => experiments::table4(),
            "fig6" => experiments::fig6(),
            "motivation" => experiments::motivation_decompose(Scale::Full),
            "ablation-d" => experiments::ablation_d(),
            "ablation-window" => experiments::ablation_window(),
            "table3" | "table5" | "fig7" | "fig8" | "fig9" | "fig10" | "headline" => {
                let points = report::run_sweep(&report::SweepOptions {
                    scale,
                    max_matrices,
                    verbose: true,
                    ..Default::default()
                });
                match exp.as_str() {
                    "table3" => experiments::table3(&points),
                    "table5" => experiments::table5(&points),
                    "fig7" => experiments::fig7(&points),
                    "fig8" => experiments::fig8(&points),
                    "fig9" => experiments::fig9(&points),
                    "fig10" => experiments::fig10(&points),
                    _ => experiments::headline(&points),
                }
            }
            other => bail!("unknown experiment {other:?} (see DESIGN.md §4)"),
        };
        println!("{text}");
    }
    Ok(())
}

/// `run`: one SpMM end to end (random or .mtx matrix) on the HFlex
/// accelerator; `--xla` additionally cross-checks through the PJRT engine.
fn cmd_run(cli: &Cli) -> Result<()> {
    let m = cli.get_usize("m", 4096);
    let k = cli.get_usize("k", 4096);
    let n = cli.get_usize("n", 64);
    let density = cli.get_f32("density", 0.002) as f64;
    let alpha = cli.get_f32("alpha", 1.0);
    let beta = cli.get_f32("beta", 0.0);
    let seed = cli.get_u64("seed", 7);

    let coo = match cli.get("matrix") {
        Some(path) => mm_io::read_matrix_market(Path::new(path))?,
        None => gen::random_uniform(m, k, density, &mut Rng::new(seed)),
    };
    println!(
        "matrix: {}x{}, nnz {}, density {:.3e}",
        coo.m,
        coo.k,
        coo.nnz(),
        coo.density()
    );

    let backend_spec = cli.get("backend").unwrap_or("native");
    let shards = cli.get_usize("shards", 1);
    let cfg = AcceleratorConfig::sextans_u280();

    let mut rng = Rng::new(seed ^ 0xB0B);
    let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
    let mut c: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();

    if shards > 1 {
        if cli.flag("xla") {
            bail!("--xla cross-checks the single-accelerator engine; run it without --shards");
        }
        // Sharded path: S parallel accelerator instances, row-partitioned.
        // Prepare once (plan + per-shard images + resident inner handles),
        // then execute against the resident pool.
        let t0 = std::time::Instant::now();
        let sharded = ShardedMatrix::build(&coo, shards, cfg.p(), cfg.k0, cfg.d);
        println!(
            "sharded: {} shards in {:.2} ms, nnz imbalance {:.3}",
            sharded.num_shards(),
            t0.elapsed().as_secs_f64() * 1e3,
            sharded.imbalance()
        );
        let exec = ShardExecutor::prepare(&sharded, backend_spec)?;
        let pcost = exec.prepare_cost();
        println!(
            "backend: {shards} x {backend_spec:?} (thread-budgeted); prepared in {:.2} ms, \
             {:.2} MiB resident",
            pcost.wall.as_secs_f64() * 1e3,
            pcost.resident_bytes as f64 / (1024.0 * 1024.0)
        );
        let stats = exec.execute(&b, &mut c, n, alpha, beta)?;
        // Per-shard simulated cycles: the pool's makespan is the slowest
        // shard (shards run on independent accelerators).
        let mut makespan_cycles = 0u64;
        for (i, shard) in sharded.shards.iter().enumerate() {
            let rep = simulate(&shard.image, &cfg, n);
            makespan_cycles = makespan_cycles.max(rep.cycles);
            println!(
                "  shard {i}: {} rows, {} nnz, host {:.3} ms, simulated {} cycles",
                shard.global_rows.len(),
                shard.image.nnz,
                stats.shard_latency[i].as_secs_f64() * 1e3,
                rep.cycles
            );
        }
        let pool_seconds = makespan_cycles as f64 / (cfg.freq_mhz * 1e6);
        println!(
            "pool makespan: {} cycles = {:.3} ms @ {} MHz (slowest shard); host makespan {:.3} ms",
            makespan_cycles,
            pool_seconds * 1e3,
            cfg.freq_mhz,
            stats.slowest().as_secs_f64() * 1e3
        );
        let mstats = sextans::perfmodel::MatrixStats {
            m: coo.m,
            k: coo.k,
            nnz: coo.nnz(),
            max_row_nnz: coo.max_row_nnz(),
        };
        for p in [Platform::K80, Platform::V100] {
            let t = p.gpu_model().unwrap().seconds(&mstats, n);
            println!(
                "baseline {}: {:.3} ms ({:.2}x vs {}-shard Sextans pool)",
                p.spec().name,
                t * 1e3,
                t / pool_seconds,
                shards
            );
        }
        return Ok(());
    }

    let c_in = c.clone();
    let accel = HFlexAccelerator::synthesize_with_backend(
        cfg,
        backend::create(backend_spec)?,
    );
    println!("backend: {} (spec {backend_spec:?})", accel.backend_name());
    // Load = preprocess + make backend-resident, paid once per matrix.
    let loaded = accel.load(&coo)?;
    let image = loaded.image();
    println!(
        "preprocessed: {} windows, {} slots ({} bubbles), effective II {:.4}",
        image.num_windows,
        image.total_slots(),
        image.total_bubbles(),
        image.effective_ii()
    );
    let pcost = loaded.prepare_cost();
    println!(
        "loaded: prepared on {:?} in {:.2} ms, {:.2} MiB resident",
        loaded.backend_name(),
        pcost.wall.as_secs_f64() * 1e3,
        pcost.resident_bytes as f64 / (1024.0 * 1024.0)
    );

    let report = accel.invoke(SpmmProblem { a: &loaded, b: &b, c: &mut c, n, alpha, beta })?;
    let sim = &report.sim;
    println!(
        "simulated: {} cycles = {:.3} ms @ {} MHz -> {:.2} GFLOP/s",
        sim.cycles,
        sim.seconds * 1e3,
        accel.config().freq_mhz,
        sim.gflops
    );

    // GPU baselines for context.
    let stats = sextans::perfmodel::MatrixStats {
        m: coo.m,
        k: coo.k,
        nnz: coo.nnz(),
        max_row_nnz: coo.max_row_nnz(),
    };
    for p in [Platform::K80, Platform::V100] {
        let t = p.gpu_model().unwrap().seconds(&stats, n);
        println!(
            "baseline {}: {:.3} ms ({:.2}x vs Sextans)",
            p.spec().name,
            t * 1e3,
            t / sim.seconds
        );
    }

    if cli.flag("xla") {
        let engine = sextans::runtime::Engine::load_default()?;
        let p = cli.get_usize("xla-pes", 8);
        let (variant, xla_image) = engine.plan(&coo, p, accel.config().d)?;
        let got = engine.spmm(variant, &xla_image, &b, &c_in, n, alpha, beta)?;
        let max_err = got
            .iter()
            .zip(c.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        println!("xla cross-check (variant k0={}, {} PEs): max |err| = {max_err:.3e}",
            variant.k0, p);
        if !(max_err < 1e-2) {
            bail!("XLA path diverged from functional simulator");
        }
    }
    Ok(())
}

/// `gen`: write a synthetic matrix as MatrixMarket.
fn cmd_gen(cli: &Cli) -> Result<()> {
    let m = cli.get_usize("m", 1024);
    let k = cli.get_usize("k", 1024);
    let density = cli.get_f32("density", 0.01) as f64;
    let seed = cli.get_u64("seed", 1);
    let out = cli.get("out").unwrap_or("matrix.mtx");
    let kind = cli.get("kind").unwrap_or("uniform");
    let mut rng = Rng::new(seed);
    let coo: Coo = match kind {
        "uniform" => gen::random_uniform(m, k, density, &mut rng),
        "rmat" => gen::rmat(m, (m as f64 * k as f64 * density) as usize, 0.57, 0.19, 0.19, &mut rng),
        "banded" => gen::banded(m, 16, ((k as f64 * density) as usize).max(1), &mut rng),
        other => bail!("unknown kind {other:?} (uniform|rmat|banded)"),
    };
    mm_io::write_matrix_market(Path::new(out), &coo)?;
    println!("wrote {} ({}x{}, nnz {})", out, coo.m, coo.k, coo.nnz());
    Ok(())
}

/// `serve`: demo serving loop on a registry-selected backend; `--shards S`
/// wraps the backend as a `sharded:<S>:<inner>` composite. Pipeline policy
/// flags: `--queue-depth` (admission bound), `--image-quota` (per-image
/// in-flight fairness quota, 0 = off), `--max-columns`/`--window-ms`
/// (batching), `--route-columns` (shard-aware routing threshold),
/// `--resident-mb` (residency byte budget), `--scratch-idle-ms` (trim
/// pooled scratch idle past this high-water timeout; 0 = off),
/// `--reshard-threshold` / `--reshard-window` (re-shard-on-skew
/// trigger). A `--backend remote:<addr>[,addr...]` spec proxies
/// execution to `sextans worker` processes and prints fleet counters on
/// shutdown. Telemetry:
/// `--trace-json FILE` attaches a span collector and writes every
/// request's span tree as JSON; `--metrics-json FILE` writes the shutdown
/// summary (per-stage/per-backend/per-image p50/p95/p99 included).
fn cmd_serve(cli: &Cli) -> Result<()> {
    let workers = cli.get_usize("workers", 2);
    let shards = cli.get_usize("shards", 1);
    let base_spec = cli.get("backend").unwrap_or("native").to_string();
    let backend_spec = if shards > 1 {
        format!("sharded:{shards}:{base_spec}")
    } else {
        base_spec
    };
    let backend_spec = backend_spec.as_str();

    let collector = cli.get("trace-json").map(|_| Arc::new(TraceCollector::new()));
    let defaults = PipelineConfig::default();
    let config = PipelineConfig {
        admission: AdmissionPolicy {
            max_in_flight: cli.get_usize("queue-depth", defaults.admission.max_in_flight),
            per_image_quota: cli
                .get_usize("image-quota", defaults.admission.per_image_quota),
        },
        batch: BatchPolicy {
            max_columns: cli.get_usize("max-columns", defaults.batch.max_columns),
            window: std::time::Duration::from_millis(
                cli.get_u64("window-ms", defaults.batch.window.as_millis() as u64),
            ),
            route_columns: cli.get_usize("route-columns", defaults.batch.route_columns),
        },
        residency: ResidencyPolicy {
            max_resident_bytes: cli.get_u64(
                "resident-mb",
                defaults.residency.max_resident_bytes / (1024 * 1024),
            ) * 1024
                * 1024,
            scratch_idle: match cli.get_u64("scratch-idle-ms", 0) {
                0 => None,
                ms => Some(std::time::Duration::from_millis(ms)),
            },
        },
        reshard: ReshardPolicy {
            imbalance_threshold: cli.get_f32("reshard-threshold", f32::INFINITY) as f64,
            window: cli.get_usize("reshard-window", defaults.reshard.window),
        },
        sink: collector
            .as_ref()
            .map(|c| Arc::clone(c) as Arc<dyn TelemetrySink>),
    };

    // The remote backend emits net.rpc spans through a process-global
    // sink; point it at the same collector so per-shard RPCs nest under
    // each request's exec span in the trace output.
    if let Some(c) = &collector {
        net::set_telemetry_sink(Some(Arc::clone(c) as Arc<dyn TelemetrySink>));
    }

    // Network mode: bind the front door and serve until a Shutdown frame.
    if let Some(listen) = cli.get("listen") {
        use std::io::Write as _;
        let fd_config = FrontDoorConfig {
            backend_spec: backend_spec.to_string(),
            workers,
            pipeline: config,
            read_timeout: std::time::Duration::from_millis(
                cli.get_u64("read-timeout-ms", 30_000),
            ),
            write_timeout: std::time::Duration::from_millis(
                cli.get_u64("write-timeout-ms", 30_000),
            ),
            max_connections: cli.get_usize("max-connections", 256),
            // Default below --read-timeout-ms so an Await answers
            // ("still running") before a default client read times out
            // and abandons the connection mid-reply.
            await_timeout: std::time::Duration::from_millis(
                cli.get_u64("await-timeout-ms", 15_000),
            ),
        };
        let door = FrontDoor::bind(listen, &fd_config)?;
        // The "listening on" line is the readiness handshake: tests and
        // the CI smoke leg parse the port out of it, so flush it.
        println!(
            "serve listening on {} (backend {:?})",
            door.local_addr()?,
            fd_config.backend_spec
        );
        std::io::stdout().flush()?;
        let s = door.run(&fd_config)?;
        net::set_telemetry_sink(None);
        println!("front door shut down");
        print_serve_summary(cli, &s, &collector)?;
        return Ok(());
    }

    // Demo mode: self-generated requests against one R-MAT matrix.
    let requests = cli.get_usize("requests", 64);
    let mut rng = Rng::new(cli.get_u64("seed", 3));
    let coo = gen::rmat(4096, 40_000, 0.57, 0.19, 0.19, &mut rng);
    let cfg = AcceleratorConfig::sextans_u280();
    let image = Arc::new(preprocess(&coo, cfg.p(), cfg.k0, cfg.d));
    println!(
        "serving matrix {}x{} nnz {} on backend {backend_spec:?}",
        coo.m,
        coo.k,
        coo.nnz()
    );
    let server = Server::start_backend_with(workers, config, backend_spec)?;
    let handle = server.register(image);
    let mut rxs = Vec::new();
    for i in 0..requests {
        let n = [4usize, 8, 16][i % 3];
        let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
        rxs.push(server.submit(SpmmRequest {
            image: handle.clone(),
            b,
            c: vec![0.0; coo.m * n],
            n,
            alpha: 1.0,
            beta: 0.0,
            deadline: None,
        }));
    }
    for rx in rxs {
        let _ = rx.recv();
    }
    let s = server.shutdown();
    net::set_telemetry_sink(None);
    print_serve_summary(cli, &s, &collector)
}

/// Print one serving [`Summary`] (shared by `serve` demo and `--listen`
/// modes) and honor `--metrics-json` / `--trace-json`.
fn print_serve_summary(
    cli: &Cli,
    s: &sextans::coordinator::metrics::Summary,
    collector: &Option<Arc<TraceCollector>>,
) -> Result<()> {
    println!(
        "served {} requests in {} batches (mean batch {:.1}); p50 {:.2} ms, p95 {:.2} ms, p99 {:.2} ms",
        s.requests,
        s.batches,
        s.mean_batch,
        s.p50_s * 1e3,
        s.p95_s * 1e3,
        s.p99_s * 1e3
    );
    println!(
        "  stages (mean/request): queue {:.3} ms | batch {:.3} ms | prepare {:.3} ms | \
         execute {:.3} ms; exec concurrency peak {}",
        s.stage_queue_s * 1e3,
        s.stage_batch_s * 1e3,
        s.stage_prepare_s * 1e3,
        s.stage_exec_s * 1e3,
        s.exec_concurrency_peak
    );
    if s.rejected > 0 {
        println!("  admission: {} requests shed at the gate", s.rejected);
        for (image, count) in &s.image_sheds {
            println!("    image {image}: {count} shed by the per-image quota");
        }
    }
    for (name, count) in &s.backends {
        println!("  backend {name}: {count} requests");
    }
    println!(
        "  prepares: {} ({} cache hits, hit rate {:.0}%), mean prepare {:.2} ms, \
         {:.2} MiB made resident, {} evicted",
        s.prepares,
        s.prepare_hits,
        s.prepare_hit_rate * 100.0,
        s.mean_prepare_s * 1e3,
        s.prepared_bytes as f64 / (1024.0 * 1024.0),
        s.evictions
    );
    if s.routed_jobs > 0 {
        println!(
            "  routing: {} small-N jobs routed, {} shards skipped",
            s.routed_jobs, s.shards_skipped
        );
    }
    if s.reshards > 0 {
        let (from, to) = s.last_reshard.unwrap_or((0, 0));
        println!("  re-shard-on-skew: {} rebuilds (last {from} -> {to} shards)", s.reshards);
    }
    if s.shard_execs > 0 {
        println!(
            "  shards: {} sharded executions, mean {:.1} shards, nnz imbalance mean {:.3} / \
             max {:.3}, mean shard makespan {:.2} ms",
            s.shard_execs,
            s.mean_shards,
            s.mean_shard_imbalance,
            s.max_shard_imbalance,
            s.mean_shard_makespan_s * 1e3
        );
    }
    if s.remote_execs > 0 {
        println!(
            "  remote: {} fleet executions over {} workers ({} live), {} placements \
             x{} replication; {} retries, {} shards re-placed",
            s.remote_execs,
            s.remote_workers,
            s.remote_live_workers,
            s.remote_placements,
            s.remote_replicas,
            s.remote_retries,
            s.remote_replaced
        );
    }
    if s.remote_transitions + s.remote_breaker_trips + s.remote_rebalanced > 0 {
        println!(
            "  supervision: {} liveness transitions, {} breaker trips, {} placements \
             rebalanced onto the live set",
            s.remote_transitions, s.remote_breaker_trips, s.remote_rebalanced
        );
    }
    if s.deadline_admission + s.deadline_batch + s.deadline_dispatch > 0 {
        println!(
            "  deadlines: {} expired at admission, {} in the batch queue, {} at dispatch \
             pickup (typed DeadlineExceeded, not counted as load sheds)",
            s.deadline_admission, s.deadline_batch, s.deadline_dispatch
        );
    }
    if let Some(path) = cli.get("metrics-json") {
        std::fs::write(path, s.to_value().to_json_pretty())?;
        println!("  metrics summary written to {path}");
    }
    if let (Some(path), Some(collector)) = (cli.get("trace-json"), collector.as_ref()) {
        std::fs::write(path, collector.to_value().to_json_pretty())?;
        println!(
            "  {} spans across {} traces written to {path}",
            collector.spans().len(),
            collector.trace_ids().len()
        );
    }
    Ok(())
}

/// `loadgen`: open-loop load generator against a front door started with
/// `serve --listen`. Arrivals are scheduled on the clock at `--rate`
/// req/s for `--duration` seconds — never gated on responses, so an
/// overloaded server shows up as sheds and latency, not a slower
/// generator. Requests spread over `--images` matrices drawn from
/// `--mix` (`power-law`, `banded`, `uniform`); `--hot F` aims an extra
/// fraction F of requests at image 0 to model one hot tenant tripping
/// the per-image quota. Reports server-side per-stage p50/p95/p99
/// (queue/batch/prepare/exec) plus client end-to-end, typed shed counts,
/// and the client-side concurrency peak, and persists
/// `BENCH_serve_<name>.json` in the schema-v1 perf trajectory.
/// `--metrics-json FILE` fetches the server's live summary after the
/// run; `--baseline`/`--tolerance`/`--strict` gate against a previous
/// snapshot; `--drain-server` drains the server, verifies post-drain
/// work sheds with a typed `Draining` frame, and shuts it down.
fn cmd_loadgen(cli: &Cli) -> Result<()> {
    let mix_name = cli.get("mix").unwrap_or("power-law");
    let mix = Mix::parse(mix_name)
        .ok_or_else(|| anyhow!("unknown mix {mix_name:?} (power-law|banded|uniform)"))?;
    let opts = LoadgenOptions {
        addr: cli.get("addr").unwrap_or("127.0.0.1:7700").to_string(),
        rate: f64::from(cli.get_f32("rate", 50.0)),
        duration: std::time::Duration::from_secs_f64(f64::from(cli.get_f32("duration", 2.0))),
        mix,
        images: cli.get_usize("images", 4).max(1),
        hot: f64::from(cli.get_f32("hot", 0.0)),
        m: cli.get_usize("m", 256),
        k: cli.get_usize("k", 256),
        n: cli.get_usize("n", 16),
        nnz: cli.get_usize("nnz", 4096),
        seed: cli.get_u64("seed", 0x5EED),
        col_block: cli.get_usize("col-block", 0),
        senders: cli.get_usize("senders", 8).max(1),
        timeout: std::time::Duration::from_millis(cli.get_u64("timeout-ms", 30_000)),
    };
    println!(
        "loadgen: {} req/s for {:.1}s against {} ({} {} image(s), hot fraction {:.2})",
        opts.rate,
        opts.duration.as_secs_f64(),
        opts.addr,
        opts.images,
        mix.name(),
        opts.hot
    );
    let report = sextans::serve_net::loadgen::run(&opts).map_err(|e| anyhow!("loadgen: {e}"))?;
    print!("{}", report.render());

    let name = cli.get("name").unwrap_or("smoke").to_string();
    let timestamp = cli.get("timestamp").unwrap_or("unknown");
    let out_dir = PathBuf::from(cli.get("out").unwrap_or("."));
    let record = report.to_bench_record(&format!("serve_{name}"), timestamp);
    let path = out_dir.join(format!("BENCH_serve_{name}.json"));
    record.write(&path)?;
    println!("wrote {}", path.display());

    if let Some(path) = cli.get("metrics-json") {
        let mut client = FrontClient::connect(&opts.addr, opts.timeout)
            .map_err(|e| anyhow!("metrics fetch: {e}"))?;
        let json = client.metrics_json().map_err(|e| anyhow!("metrics fetch: {e}"))?;
        std::fs::write(path, json)?;
        println!("server metrics written to {path}");
    }

    if let Some(base_path) = cli.get("baseline") {
        let baseline = BenchRecord::read(Path::new(base_path)).map_err(|e| anyhow!(e))?;
        if baseline.is_zeroed() {
            eprintln!(
                "WARNING: baseline {base_path} is a zeroed placeholder — comparisons \
                 against it can only ever pass."
            );
            if cli.flag("strict") {
                bail!("--strict refuses the zeroed placeholder baseline {base_path}");
            }
        }
        let tolerance = f64::from(cli.get_f32("tolerance", 0.15));
        let regressions = compare(&baseline, &record, tolerance);
        if regressions.is_empty() {
            println!("no regressions vs {base_path} (tolerance {:.0}%)", tolerance * 100.0);
        } else {
            for r in &regressions {
                println!("regression: {r}");
            }
            if cli.flag("strict") {
                bail!("{} regression(s) vs {base_path}", regressions.len());
            }
        }
    }

    if cli.flag("drain-server") {
        let mut client = FrontClient::connect(&opts.addr, opts.timeout)
            .map_err(|e| anyhow!("drain: {e}"))?;
        client.drain().map_err(|e| anyhow!("drain: {e}"))?;
        // A draining front door must shed new work with a typed frame,
        // not accept it and not hang — verify before shutting down.
        let coo = gen::random_uniform(16, 16, 0.1, &mut Rng::new(1));
        let image = sextans::serve_net::loadgen::schedule_default(&coo);
        match client.register_image(&image, 1 << 16) {
            Err(ClientError::Shed { reason: ShedReason::Draining, .. }) => {
                println!("drain verified: post-drain register shed with a typed Draining frame");
            }
            Ok(_) => bail!("drain verification failed: post-drain register was accepted"),
            Err(e) => bail!("drain verification failed: expected a Draining shed, got {e}"),
        }
        client.shutdown_server().map_err(|e| anyhow!("shutdown: {e}"))?;
        println!("server drained and shut down");
    }
    Ok(())
}

/// `bench`: measure SpMM throughput/latency on catalog matrices and write a
/// machine-readable `BENCH_<name>.json` snapshot (schema in
/// [`sextans::telemetry::bench_record`]). The default is a CI-sized smoke
/// run; `--full` measures one representative matrix per catalog family plus
/// the Table 1 workload. `--baseline FILE` compares against a previous
/// snapshot and (with `--strict`) fails on regressions beyond
/// `--tolerance` (default 0.15).
fn cmd_bench(cli: &Cli) -> Result<()> {
    let full = cli.flag("full");
    let name = cli
        .get("name")
        .unwrap_or(if full { "full" } else { "smoke" })
        .to_string();
    let timestamp = cli.get("timestamp").unwrap_or("unknown").to_string();
    let out_dir = PathBuf::from(cli.get("out").unwrap_or("."));
    let base_spec = cli.get("backend").unwrap_or("native").to_string();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let backend_spec = backend::apply_thread_budget(&base_spec, cores);

    let specs: Vec<catalog::MatrixSpec> = if full {
        let cat = catalog::catalog(Scale::Ci);
        let mut picks: Vec<catalog::MatrixSpec> = [
            "snap_rmat_10",
            "ss_banded_10",
            "ss_circuit_10",
            "ss_uniform_10",
            "ss_block_10",
            "ss_powrows_10",
        ]
        .iter()
        .filter_map(|name| cat.iter().find(|s| s.name == *name).cloned())
        .collect();
        picks.push(catalog::crystm03_like());
        picks
    } else {
        vec![
            catalog::MatrixSpec {
                name: "smoke_banded".into(),
                family: catalog::Family::SsBanded,
                m: 2048,
                k: 2048,
                nnz: 32_768,
                seed: 0xBE9C01,
            },
            catalog::MatrixSpec {
                name: "smoke_rmat".into(),
                family: catalog::Family::SnapRmat,
                m: 2048,
                k: 2048,
                nnz: 20_000,
                seed: 0xBE9C02,
            },
        ]
    };
    let n_values: &[usize] = if full { &[8, 64, 256] } else { &[8, 32] };
    let min_time = std::time::Duration::from_millis(if full { 200 } else { 50 });

    let cfg = AcceleratorConfig::sextans_u280();
    let mut record = BenchRecord {
        name: name.clone(),
        git_rev: sextans::telemetry::bench_record::git_rev(),
        timestamp,
        host_threads: cores,
        matrices: specs.clone(),
        results: Vec::new(),
        scaling: Vec::new(),
    };

    bench_util::section(&format!("bench {name} on {backend_spec}"));
    for spec in &specs {
        let coo = spec.build();
        let image = Arc::new(preprocess(&coo, cfg.p(), cfg.k0, cfg.d));
        let be = backend::create(&backend_spec)?;
        let prepared = be.prepare(Arc::clone(&image))?;
        for &n in n_values {
            let mut rng = Rng::new(spec.seed ^ 0xB0B);
            let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
            let mut c = vec![0f32; coo.m * n];
            let flops = problem_flops(coo.nnz(), coo.m, n) as f64;
            let r = bench_util::bench(
                &format!("{}/{} n={n}", backend_spec, spec.name),
                1,
                5,
                min_time,
                || {
                    prepared.execute(&b, &mut c, n, 1.0, 0.0).expect("bench execute");
                },
            );
            record.results.push(BenchMeasurement {
                bench: format!("backend/{backend_spec}"),
                matrix: spec.name.clone(),
                n,
                // flops per nanosecond is numerically GFLOP/s.
                gflops: flops / r.median_ns,
                median_ns: r.median_ns,
                p50_ns: r.p50_ns,
                p95_ns: r.p95_ns,
                p99_ns: r.p99_ns,
            });
        }
    }

    // Concurrency scaling on the first (smallest) matrix: W independent
    // callers, each with its own thread-budgeted backend instance, hammer
    // the same matrix; prepare happens before the barrier so the timed
    // region is pure execution.
    bench_util::section("concurrency scaling");
    let scale_spec = &specs[0];
    let coo = scale_spec.build();
    let image = Arc::new(preprocess(&coo, cfg.p(), cfg.k0, cfg.d));
    let n = 16usize;
    let iters = if full { 20usize } else { 8 };
    let flops = problem_flops(coo.nnz(), coo.m, n) as f64;
    let worker_counts: &[usize] = if full { &[1, 2, 4] } else { &[1, 2] };
    let mut single_gflops = 0.0f64;
    for &workers in worker_counts {
        let per_worker = backend::apply_thread_budget(&base_spec, (cores / workers).max(1));
        let barrier = std::sync::Barrier::new(workers + 1);
        let mut t0 = std::time::Instant::now();
        std::thread::scope(|scope| {
            for w in 0..workers {
                let image = Arc::clone(&image);
                let spec = per_worker.clone();
                let barrier = &barrier;
                let (m, k) = (coo.m, coo.k);
                scope.spawn(move || {
                    let be = backend::create(&spec).expect("scaling backend");
                    let prepared = be.prepare(image).expect("scaling prepare");
                    let mut rng = Rng::new(0xD15B + w as u64);
                    let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
                    let mut c = vec![0f32; m * n];
                    barrier.wait();
                    for _ in 0..iters {
                        prepared.execute(&b, &mut c, n, 1.0, 0.0).expect("scaling execute");
                    }
                });
            }
            barrier.wait();
            t0 = std::time::Instant::now();
        });
        let elapsed_ns = (t0.elapsed().as_nanos() as f64).max(1.0);
        let gflops = (workers * iters) as f64 * flops / elapsed_ns;
        if workers == 1 {
            single_gflops = gflops;
        }
        let efficiency = if single_gflops > 0.0 {
            gflops / (workers as f64 * single_gflops)
        } else {
            0.0
        };
        println!(
            "{workers} worker(s) on {}: {gflops:.2} GFLOP/s aggregate, efficiency {efficiency:.2}",
            scale_spec.name
        );
        record.scaling.push(ScalingPoint {
            bench: format!("concurrency/{base_spec}"),
            workers,
            gflops,
            efficiency,
        });
    }

    let path = out_dir.join(format!("BENCH_{name}.json"));
    record.write(&path)?;
    println!("\nwrote {}", path.display());

    if cli.flag("write-baseline") {
        // Write-then-rename so a crash mid-write can never leave a
        // truncated baseline gating future runs.
        let baseline_path = out_dir.join("BENCH_baseline.json");
        let tmp = out_dir.join("BENCH_baseline.json.tmp");
        record.write(&tmp)?;
        std::fs::rename(&tmp, &baseline_path)?;
        println!(
            "baseline {} replaced from this run (anchored at git rev {})",
            baseline_path.display(),
            record.git_rev
        );
    }

    if let Some(base_path) = cli.get("baseline") {
        let baseline = BenchRecord::read(Path::new(base_path)).map_err(|e| anyhow!(e))?;
        if baseline.is_zeroed() {
            eprintln!(
                "WARNING: baseline {base_path} is the zeroed placeholder (every \
                 measurement is 0 GFLOP/s) — comparisons against it can only ever \
                 pass. Re-measure it with `sextans bench --name baseline` on a \
                 quiet machine before trusting this gate."
            );
            if cli.flag("strict") {
                bail!("--strict refuses the zeroed placeholder baseline {base_path}");
            }
        }
        let tolerance = cli.get_f32("tolerance", 0.15) as f64;
        let regressions = compare(&baseline, &record, tolerance);
        if regressions.is_empty() {
            println!(
                "no regressions vs {base_path} (tolerance {:.0}%)",
                tolerance * 100.0
            );
        } else {
            for r in &regressions {
                println!("regression: {r}");
            }
            if cli.flag("strict") {
                bail!("{} regression(s) vs {base_path}", regressions.len());
            }
        }
    }
    Ok(())
}

/// `trace`: run a few requests through the serving pipeline with a span
/// collector attached and pretty-print each request's span tree —
/// `admission`, `queue`, `batch`, `prepare` (with `backend.prepare` on
/// residency misses), `exec`, under a `request` root. The positional
/// argument picks a catalog matrix by name (e.g. `crystm03_like`);
/// without one a small R-MAT graph is generated.
fn cmd_trace(cli: &Cli) -> Result<()> {
    let requests = cli.get_usize("requests", 3);
    let workers = cli.get_usize("workers", 2);
    let backend_spec = cli.get("backend").unwrap_or("native");
    let cfg = AcceleratorConfig::sextans_u280();
    let coo = match cli.positional.first() {
        Some(name) => {
            let cat = catalog::catalog(Scale::Ci);
            let spec = cat.iter().find(|s| s.name == *name).ok_or_else(|| {
                anyhow!("unknown catalog matrix {name:?} (try e.g. crystm03_like)")
            })?;
            println!("matrix {} ({:?})", spec.name, spec.family);
            spec.build()
        }
        None => gen::rmat(2048, 20_000, 0.57, 0.19, 0.19, &mut Rng::new(11)),
    };
    let image = Arc::new(preprocess(&coo, cfg.p(), cfg.k0, cfg.d));
    let collector = Arc::new(TraceCollector::new());
    let config = PipelineConfig {
        sink: Some(Arc::clone(&collector) as Arc<dyn TelemetrySink>),
        ..PipelineConfig::default()
    };
    let server = Server::start_backend_with(workers, config, backend_spec)?;
    let handle = server.register(image);
    let mut rng = Rng::new(0x7A3CE);
    let mut rxs = Vec::new();
    for i in 0..requests {
        let n = [4usize, 8, 16][i % 3];
        let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
        rxs.push(server.submit(SpmmRequest {
            image: handle.clone(),
            b,
            c: vec![0.0; coo.m * n],
            n,
            alpha: 1.0,
            beta: 0.0,
            deadline: None,
        }));
    }
    for rx in rxs {
        let _ = rx.recv();
    }
    let _ = server.shutdown();
    for tid in collector.trace_ids() {
        println!("trace {tid}:");
        let spans = collector.trace(tid);
        print!("{}", render_tree(&build_tree(&spans)));
    }
    if let Some(path) = cli.get("out") {
        std::fs::write(path, collector.to_value().to_json_pretty())?;
        println!("wrote {} spans to {path}", collector.spans().len());
    }
    Ok(())
}

/// `worker`: a follower process for the distributed fleet. Binds
/// `--addr` (default `127.0.0.1:0` — port 0 picks a free port), prints
/// `worker listening on <addr>` so a parent process can scrape the bound
/// port, then serves prepare/execute/stats/evict RPCs over the framed
/// wire protocol until a shutdown RPC arrives. `--backend` picks the
/// local engine images are prepared through (default `native`);
/// `--read-timeout-ms`/`--write-timeout-ms` bound how long one stalled
/// peer can pin a connection thread (default 10000);
/// `--max-resident-mb` caps prepared-image residency (prepares over the
/// budget are refused with a typed error; 0 = unbounded).
/// `--fault SPEC` installs a seeded fault plan (e.g.
/// `seed=7,trickle=256:2,corrupt=0.05,refuse=0.1`) so chaos runs can
/// inject reproducible failures; see [`sextans::net::FaultSpec`].
fn cmd_worker(cli: &Cli) -> Result<()> {
    use std::io::Write as _;
    let addr = cli.get("addr").unwrap_or("127.0.0.1:0");
    let config = WorkerConfig {
        backend_spec: cli.get("backend").unwrap_or("native").to_string(),
        read_timeout: std::time::Duration::from_millis(cli.get_u64("read-timeout-ms", 10_000)),
        write_timeout: std::time::Duration::from_millis(cli.get_u64("write-timeout-ms", 10_000)),
        // `--max-resident-mb` bounds prepared-image residency with the
        // same policy struct the coordinator's cache uses; prepares over
        // budget come back as typed errors (0 = unbounded).
        residency: match cli.get_u64("max-resident-mb", 0) {
            0 => None,
            mb => Some(ResidencyPolicy {
                max_resident_bytes: mb * 1024 * 1024,
                scratch_idle: None,
            }),
        },
        fault: cli
            .get("fault")
            .map(FaultSpec::parse)
            .transpose()
            .map_err(|e| anyhow!("--fault: {e}"))?,
    };
    let worker = net::Worker::bind(addr, &config)?;
    // The "listening on" line is the readiness handshake: tests and the
    // CI smoke leg parse the port out of it, so flush before serving.
    println!(
        "worker listening on {} (backend {:?})",
        worker.local_addr()?,
        config.backend_spec
    );
    std::io::stdout().flush()?;
    worker.run(&config)?;
    println!("worker shut down");
    Ok(())
}

/// Bounded scrape of a child process's readiness line: read stdout until
/// a line starting with `prefix` appears, return the first whitespace
/// token after it, and leave a drain thread on the rest of the stream.
/// On timeout or child exit the child is killed and an error returned —
/// a wedged spawn can never hang the chaos harness.
fn scrape_readiness(
    child: &mut std::process::Child,
    prefix: &str,
    timeout: std::time::Duration,
) -> Result<String> {
    use std::io::{BufRead as _, BufReader};
    let stdout = child
        .stdout
        .take()
        .ok_or_else(|| anyhow!("child stdout is not piped"))?;
    let (tx, rx) = std::sync::mpsc::channel::<String>();
    std::thread::spawn(move || {
        for line in BufReader::new(stdout).lines() {
            let Ok(line) = line else { break };
            if tx.send(line).is_err() {
                break;
            }
        }
    });
    let deadline = std::time::Instant::now() + timeout;
    loop {
        let left = deadline.saturating_duration_since(std::time::Instant::now());
        match rx.recv_timeout(left) {
            Ok(line) => {
                if let Some(rest) = line.strip_prefix(prefix) {
                    let token = rest
                        .split_whitespace()
                        .next()
                        .unwrap_or_default()
                        .to_string();
                    // Keep draining stdout so the child can never block
                    // on a full pipe.
                    std::thread::spawn(move || for _line in rx {});
                    return Ok(token);
                }
            }
            Err(e) => {
                let _ = child.kill();
                let _ = child.wait();
                bail!("child never printed a {prefix:?} readiness line ({e})");
            }
        }
    }
}

/// Spawn one `sextans worker` child (this same binary) for the chaos
/// harness and scrape its bound address from the readiness line.
fn spawn_chaos_worker(addr: &str, fault: Option<&str>) -> Result<(std::process::Child, String)> {
    let exe = std::env::current_exe()?;
    let mut cmd = std::process::Command::new(exe);
    cmd.args(["worker", "--addr", addr, "--backend", "functional"])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null());
    if let Some(fault) = fault {
        cmd.args(["--fault", fault]);
    }
    let mut child = cmd.spawn()?;
    let bound = scrape_readiness(
        &mut child,
        "worker listening on ",
        std::time::Duration::from_secs(10),
    )?;
    Ok((child, bound))
}

/// Raw-frame deadline probe: submit with a 1 ms budget, let it expire
/// while the panels are still uploading (upload time counts against the
/// deadline), and require the typed `Shed(DeadlineExceeded)` answer at
/// SubmitEnd — the request must die at admission, never reach a fleet
/// execute, and never come back as an untyped error string.
fn chaos_deadline_probe(
    addr: &str,
    image_id: u64,
    n: usize,
    b: &[f32],
    c0: &[f32],
) -> Result<()> {
    use sextans::net::{wire, Op};
    let mut s = std::net::TcpStream::connect(addr)?;
    s.set_read_timeout(Some(std::time::Duration::from_secs(10)))?;
    s.set_write_timeout(Some(std::time::Duration::from_secs(10)))?;
    wire::write_frame(&mut s, Op::Submit, &proto::encode_submit(image_id, n, 1.0, 0.5, 1))?;
    let (op, payload) = wire::read_frame(&mut s)?;
    if op != Op::Ok {
        bail!("deadline probe: Submit answered {op:?}, expected a ticket");
    }
    let ticket = proto::decode_u64(&payload)?;
    std::thread::sleep(std::time::Duration::from_millis(50));
    wire::write_frame(
        &mut s,
        Op::SubmitChunk,
        &proto::encode_submit_chunk(ticket, 0, n as u64, b, c0),
    )?;
    let (op, _) = wire::read_frame(&mut s)?;
    if op != Op::Ok {
        bail!("deadline probe: SubmitChunk answered {op:?}");
    }
    wire::write_frame(&mut s, Op::SubmitEnd, &proto::encode_u64(ticket))?;
    let (op, payload) = wire::read_frame(&mut s)?;
    if op != Op::Shed {
        bail!("deadline probe: expired submit answered {op:?}, expected a typed Shed frame");
    }
    let (reason, msg) = proto::decode_shed(&payload)?;
    if reason != ShedReason::DeadlineExceeded {
        bail!("deadline probe: shed reason {reason:?} ({msg}), expected DeadlineExceeded");
    }
    println!("deadline probe: typed DeadlineExceeded at admission ({msg})");
    Ok(())
}

/// Cumulative request outcomes across the chaos run's sender threads.
#[derive(Default)]
struct ChaosCounters {
    offered: std::sync::atomic::AtomicUsize,
    done: std::sync::atomic::AtomicUsize,
    shed: std::sync::atomic::AtomicUsize,
    errors: std::sync::atomic::AtomicUsize,
    wrong: std::sync::atomic::AtomicUsize,
}

/// `chaos`: a seeded fault-injection soak against a self-spawned fleet.
/// Spawns `--workers` `sextans worker` processes (the last one under a
/// seeded `--fault` plan: trickled and corrupted replies, refused
/// accepts, delayed reads), binds an in-process front door over
/// `remote:<fleet>` with a fast heartbeat, and drives verifying load for
/// `--duration` seconds while a scripted schedule hard-kills the clean
/// worker at 25% and revives it on the same port at 50%. Every completed
/// answer is compared bitwise against the local `functional` reference.
/// Afterwards a 1 ms-deadline probe must come back as a typed
/// `DeadlineExceeded` shed, and the run fails unless: zero wrong
/// answers, every request accounted (offered = done + shed + errors),
/// liveness transitions ≥ 1, breaker trips ≥ 1, and a post-recovery
/// call succeeds. Writes a schema-v1 `BENCH_chaos_<name>.json`
/// degradation report.
fn cmd_chaos(cli: &Cli) -> Result<()> {
    use std::sync::atomic::Ordering;
    use std::time::{Duration, Instant};

    let workers = cli.get_usize("workers", 2).max(2);
    let duration =
        Duration::from_secs_f64(f64::from(cli.get_f32("duration", 6.0)).max(1.0));
    let senders = cli.get_usize("senders", 4).max(1);
    let seed = cli.get_u64("seed", 0xC4A05);
    let name = cli.get("name").unwrap_or("smoke").to_string();
    let out_dir = PathBuf::from(cli.get("out").unwrap_or("."));
    let timestamp = cli.get("timestamp").unwrap_or("unknown").to_string();

    // A schedule-invariant matrix (exactly one non-zero per row per K0
    // window) accumulates each row in the same floating-point order no
    // matter how shards, retries, or re-placements shuffle execution —
    // so every fleet answer is bitwise-comparable to the local
    // functional reference, and "no wrong answers" is exact, not
    // approximate.
    let (m, k, k0, n) = (48usize, 32usize, 8usize, 5usize);
    let mut rng = Rng::new(seed);
    let windows = k.div_ceil(k0);
    let (mut rows, mut cols, mut vals) = (Vec::new(), Vec::new(), Vec::new());
    for r in 0..m {
        for w in 0..windows {
            let lo = w * k0;
            let hi = k.min(lo + k0);
            rows.push(r as u32);
            cols.push((lo + rng.index(hi - lo)) as u32);
            vals.push(rng.normal());
        }
    }
    let coo = Coo::new(m, k, rows, cols, vals)?;
    let image = Arc::new(preprocess(&coo, 4, k0, 4));
    let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
    let c0: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
    let (alpha, beta) = (1.0f32, 0.5f32);
    let functional = backend::create("functional")?.prepare(Arc::clone(&image))?;
    let mut want = c0.clone();
    functional.execute(&b, &mut want, n, alpha, beta)?;

    // Fleet: the last worker runs under a seeded fault plan; the first
    // is clean and will be hard-killed and revived by the schedule.
    let fault_spec = format!("seed={seed},trickle=256:2,corrupt=0.05,refuse=0.1,delay-read=5:0.2");
    let mut children = Vec::new();
    let mut addrs = Vec::new();
    for w in 0..workers {
        let fault = (w == workers - 1).then_some(fault_spec.as_str());
        let (child, bound) = spawn_chaos_worker("127.0.0.1:0", fault)?;
        println!(
            "chaos: worker {w} on {bound}{}",
            if fault.is_some() { " (faulty)" } else { "" }
        );
        children.push(child);
        addrs.push(bound);
    }
    let victim_addr = addrs[0].clone();

    // Fast heartbeat so Live -> Suspect -> Dead transitions and the
    // breaker trip land well inside the kill window.
    let fleet_spec =
        format!("remote:{},timeout_ms=2000,heartbeat_ms=100", addrs.join(","));
    let fd_config = FrontDoorConfig {
        backend_spec: fleet_spec.clone(),
        workers: 2,
        ..FrontDoorConfig::default()
    };
    let door = FrontDoor::bind("127.0.0.1:0", &fd_config)?;
    let door_addr = door.local_addr()?.to_string();
    let door_thread = std::thread::spawn(move || door.run(&fd_config));
    println!("chaos: front door on {door_addr} over {fleet_spec}");

    let timeout = Duration::from_secs(10);
    let mut control = FrontClient::connect(&door_addr, timeout)
        .map_err(|e| anyhow!("connect front door: {e}"))?;
    let info = control
        .register_image(&image, 1 << 16)
        .map_err(|e| anyhow!("register image: {e}"))?;

    let counters = ChaosCounters::default();
    let e2e_ns = std::sync::Mutex::new(Vec::<u64>::new());
    let t_end = Instant::now() + duration;
    let mut revived = false;

    std::thread::scope(|scope| -> Result<()> {
        for _ in 0..senders {
            let (door_addr, info) = (door_addr.clone(), info.clone());
            let (counters, e2e_ns) = (&counters, &e2e_ns);
            let (b, c0, want) = (&b, &c0, &want);
            scope.spawn(move || {
                let mut client: Option<FrontClient> = None;
                while Instant::now() < t_end {
                    if client.is_none() {
                        client = FrontClient::connect(&door_addr, timeout).ok();
                    }
                    let Some(conn) = client.as_mut() else {
                        counters.offered.fetch_add(1, Ordering::Relaxed);
                        counters.errors.fetch_add(1, Ordering::Relaxed);
                        std::thread::sleep(Duration::from_millis(50));
                        continue;
                    };
                    counters.offered.fetch_add(1, Ordering::Relaxed);
                    let t0 = Instant::now();
                    match conn.call(&info, n, alpha, beta, b, c0, 0) {
                        Ok(resp) if resp.timing.error.is_none() => {
                            if resp.c == *want {
                                counters.done.fetch_add(1, Ordering::Relaxed);
                                e2e_ns
                                    .lock()
                                    .unwrap()
                                    .push(t0.elapsed().as_nanos() as u64);
                            } else {
                                counters.wrong.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        Ok(_) => {
                            // Pipeline-level failure (e.g. the whole
                            // fleet briefly unreachable) — typed error
                            // text, never a silent wrong answer.
                            counters.errors.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(ClientError::Shed { .. }) => {
                            counters.shed.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(e) => {
                            counters.errors.fetch_add(1, Ordering::Relaxed);
                            if matches!(e.terminal(), ClientError::Wire(_)) {
                                // Transport state unknowable: reconnect.
                                client = None;
                            }
                        }
                    }
                }
            });
        }

        // The scripted fault schedule, on this thread: hard-kill the
        // clean worker a quarter in, revive it on the same port at the
        // halfway mark.
        std::thread::sleep(duration.mul_f64(0.25));
        println!("chaos: killing worker 0 ({victim_addr})");
        let _ = children[0].kill();
        let _ = children[0].wait();
        std::thread::sleep(duration.mul_f64(0.25));
        // The freed port can linger in TIME_WAIT briefly; retry the
        // rebind until the revival succeeds.
        for attempt in 0..40 {
            match spawn_chaos_worker(&victim_addr, None) {
                Ok((child, bound)) => {
                    println!("chaos: revived worker 0 on {bound} (attempt {attempt})");
                    children[0] = child;
                    revived = true;
                    break;
                }
                Err(_) => std::thread::sleep(Duration::from_millis(250)),
            }
        }
        Ok(())
    })?;
    if !revived {
        bail!("chaos: could not revive worker 0 on {victim_addr}");
    }

    // Let the heartbeat rediscover the revived worker (Dead -> Live) and
    // the breaker close, then require a verified post-recovery answer.
    std::thread::sleep(Duration::from_secs(1));
    let mut recovered = false;
    for _ in 0..5 {
        let Ok(mut conn) = FrontClient::connect(&door_addr, timeout) else {
            std::thread::sleep(Duration::from_millis(200));
            continue;
        };
        match conn.call(&info, n, alpha, beta, &b, &c0, 0) {
            Ok(resp) if resp.timing.error.is_none() && resp.c == want => {
                recovered = true;
                break;
            }
            _ => std::thread::sleep(Duration::from_millis(200)),
        }
    }
    if !recovered {
        bail!("chaos: no bitwise-correct answer after reviving worker 0");
    }
    println!("chaos: post-recovery call verified bitwise");

    chaos_deadline_probe(&door_addr, info.id, n, &b, &c0)?;

    control.shutdown_server().map_err(|e| anyhow!("shutdown: {e}"))?;
    let summary = door_thread
        .join()
        .map_err(|_| anyhow!("front door thread panicked"))??;
    for mut child in children {
        let _ = child.kill();
        let _ = child.wait();
    }

    let (offered, done, shed, errors, wrong) = (
        counters.offered.load(Ordering::Relaxed),
        counters.done.load(Ordering::Relaxed),
        counters.shed.load(Ordering::Relaxed),
        counters.errors.load(Ordering::Relaxed),
        counters.wrong.load(Ordering::Relaxed),
    );
    println!(
        "chaos: offered {offered} | verified {done} | shed {shed} | errors {errors} | \
         wrong {wrong}"
    );
    print_serve_summary(cli, &summary, &None)?;

    // Degradation report: schema-v1 bench record, e2e latency as the
    // measurement row, outcome and supervision counters riding in the
    // scaling rows' gflops column (the same idiom `serve/sheds` uses).
    let mut samples = e2e_ns.lock().unwrap().clone();
    samples.sort_unstable();
    let pct = |q: f64| -> f64 {
        if samples.is_empty() {
            0.0
        } else {
            samples[((q * (samples.len() - 1) as f64).round() as usize).min(samples.len() - 1)]
                as f64
        }
    };
    let flops = problem_flops(coo.nnz(), coo.m, n) as f64;
    let record = BenchRecord {
        name: format!("chaos_{name}"),
        git_rev: sextans::telemetry::bench_record::git_rev(),
        timestamp,
        host_threads: std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1),
        matrices: vec![catalog::MatrixSpec {
            name: "chaos_invariant".into(),
            family: catalog::Family::SsUniform,
            m,
            k,
            nnz: coo.nnz(),
            seed,
        }],
        results: vec![BenchMeasurement {
            bench: "chaos/e2e".into(),
            matrix: "chaos_invariant".into(),
            n,
            gflops: flops / pct(0.5).max(1.0),
            median_ns: pct(0.5),
            p50_ns: pct(0.5),
            p95_ns: pct(0.95),
            p99_ns: pct(0.99),
        }],
        scaling: [
            ("chaos/offered", offered),
            ("chaos/verified", done),
            ("chaos/shed", shed),
            ("chaos/errors", errors),
            ("chaos/wrong", wrong),
            ("chaos/retries", summary.remote_retries),
            ("chaos/replaced", summary.remote_replaced),
            ("chaos/rebalanced", summary.remote_rebalanced),
            ("chaos/breaker_trips", summary.remote_breaker_trips),
            ("chaos/transitions", summary.remote_transitions),
            ("chaos/deadline_sheds", summary.deadline_admission),
        ]
        .into_iter()
        .map(|(bench, count)| ScalingPoint {
            bench: bench.into(),
            workers,
            gflops: count as f64,
            efficiency: 0.0,
        })
        .collect(),
    };
    let path = out_dir.join(format!("BENCH_chaos_{name}.json"));
    record.write(&path)?;
    println!("wrote {}", path.display());

    // The invariants: wrong answers are forbidden outright, every offered
    // request must be accounted for, the supervisor must have observed
    // the kill (transitions + breaker), and the probe's deadline shed
    // must be visible in the server's own counters.
    if wrong > 0 {
        bail!("chaos: {wrong} wrong answer(s) — transport or failover corrupted a result");
    }
    if offered != done + shed + errors + wrong {
        bail!("chaos: lost tickets — offered {offered} != {done} + {shed} + {errors} + {wrong}");
    }
    if done == 0 {
        bail!("chaos: no request completed — the fleet never served");
    }
    if summary.remote_transitions == 0 {
        bail!("chaos: the supervisor never observed a liveness transition");
    }
    if summary.remote_breaker_trips == 0 {
        bail!("chaos: the killed worker never tripped its circuit breaker");
    }
    if summary.deadline_admission == 0 {
        bail!("chaos: the deadline probe's shed is missing from the admission counters");
    }
    println!(
        "chaos: invariants hold — 0 wrong answers, {} transitions, {} breaker trips, \
         {} admission deadline shed(s)",
        summary.remote_transitions, summary.remote_breaker_trips, summary.deadline_admission
    );
    Ok(())
}

/// `backends`: every registry name with its capability, availability in
/// this build, and the effective thread budget its auto-sized spec
/// resolves to on this machine ([`backend::apply_thread_budget`] with all
/// cores). For the sharded composite the resolved inner engine is printed
/// too, since that is what actually executes. `--probe HOST:PORT`
/// additionally probes a running front door over loopback and reports
/// whether a backend spec is reachable through it (which spec it serves,
/// drain state, load counters).
fn cmd_backends(cli: &Cli) -> Result<()> {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "{:<15} {:<12} {:>7} {:>6}  {:<13} {:<10} {:<22} description",
        "name",
        "status",
        "threads",
        "lanes",
        "deterministic",
        "artifacts",
        format!("budgeted@{cores}c")
    );
    for info in backend::registry() {
        let status = if info.available { "available" } else { "unavailable" };
        if info.name == "remote" {
            // The remote composite needs a fleet address to instantiate,
            // and its availability is a live ping probe of that fleet —
            // not a property of the build, so no capability row here.
            println!(
                "{:<15} {:<12} {:>7} {:>6}  {:<13} {:<10} {:<22} {}",
                info.name,
                "probed",
                "fleet",
                1,
                "yes",
                "no",
                "remote:<addr>[,...]",
                info.description
            );
            continue;
        }
        let budgeted = backend::apply_thread_budget(info.name, cores);
        match backend::create(&budgeted) {
            Ok(be) => {
                let cap = be.capability();
                println!(
                    "{:<15} {:<12} {:>7} {:>6}  {:<13} {:<10} {:<22} {}",
                    info.name,
                    status,
                    cap.threads,
                    cap.simd_lanes,
                    if cap.deterministic { "yes" } else { "no" },
                    if cap.requires_artifacts { "required" } else { "no" },
                    budgeted,
                    info.description
                );
                if let Some((s, inner)) = backend::sharded_parts(&budgeted) {
                    let engine = backend::create(&inner)
                        .map(|b| b.name())
                        .unwrap_or("?");
                    println!(
                        "{:<15} {:<12} {:>7} {:>6}  {:<13} {:<10} {:<22} resolved inner: \
                         {s} x {inner:?} (engine {engine})",
                        "", "", "", "", "", "", ""
                    );
                }
            }
            Err(e) => println!("{:<15} {:<12} {e}", info.name, status),
        }
    }
    println!(
        "\nspecs: native:<threads>, native-blocked:<threads>, sharded:<S>:<inner>, \
         remote:<addr>[,addr...][,replicas=R][,timeout_ms=T]; select with --backend \
         on `run`/`serve`. Auto-sized specs are shown after thread budgeting for \
         this machine's {cores} cores; `serve` further divides the budget across \
         its workers. The remote fleet is `sextans worker` processes; its \
         availability probe pings the listed addresses."
    );
    if let Some(addr) = cli.get("probe") {
        let timeout = std::time::Duration::from_millis(cli.get_u64("probe-timeout-ms", 2_000));
        match FrontClient::connect(addr, timeout).and_then(|mut c| c.status()) {
            Ok(st) => {
                println!(
                    "\nfront door {addr}: reachable — serving backend {:?}{}, {} image(s) \
                     registered, {} ticket(s) open, {} request(s) completed",
                    st.backend_spec,
                    if st.draining { " (draining)" } else { "" },
                    st.images,
                    st.open_tickets,
                    st.completed
                );
            }
            Err(e) => {
                println!("\nfront door {addr}: unreachable ({e})");
            }
        }
    }
    Ok(())
}

/// `info`: platform and configuration summary.
fn cmd_info() -> Result<()> {
    let cfg = AcceleratorConfig::sextans_u280();
    println!("Sextans reproduction — FPGA '22 (Song et al.)");
    println!(
        "U280 config: {} PEGs x {} PEs x {} PUs, K0={}, C depth={}, D={}, {} MHz, {} GB/s",
        cfg.pegs, cfg.pes_per_peg, cfg.n0, cfg.k0, cfg.c_depth, cfg.d, cfg.freq_mhz, cfg.hbm_gbps
    );
    println!("datapath roof: {:.1} GFLOP/s", cfg.datapath_roof_gflops());
    let r = resources::estimate(&cfg);
    println!("estimated resources: BRAM {}, DSP {}, URAM {}", r.bram, r.dsp, r.uram);
    println!("execution backends (select with --backend):");
    for info in backend::registry() {
        let avail = if info.available { "available" } else { "unavailable in this build" };
        println!("  {:<12} {} [{avail}]", info.name, info.description);
    }
    let mut demo_rng = Rng::new(1);
    let coo = gen::random_uniform(1024, 1024, 0.01, &mut demo_rng);
    let sm = preprocess(&coo, cfg.p(), cfg.k0, cfg.d);
    let rep = simulate(&sm, &cfg, 64);
    println!(
        "demo SpMM (1024^2, 1% dense, N=64): {} cycles, {:.2} GFLOP/s",
        rep.cycles, rep.gflops
    );
    println!("run `sextans repro --all` to regenerate the paper's tables and figures");
    Ok(())
}
