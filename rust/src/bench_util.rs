//! Wall-clock benchmark harness (offline substitute for `criterion`).
//!
//! Warmup + timed iterations, reporting median and MAD. Benches are
//! `[[bench]] harness = false` binaries that call [`bench`] and print
//! criterion-style lines; `cargo bench` runs them.

use std::time::{Duration, Instant};

/// One benchmark's statistics (nanoseconds).
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Benchmark id.
    pub name: String,
    /// Timed iterations.
    pub iters: usize,
    /// Median per-iteration time.
    pub median_ns: f64,
    /// Median absolute deviation.
    pub mad_ns: f64,
    /// Mean per-iteration time.
    pub mean_ns: f64,
    /// Nearest-rank latency percentiles over the timed iterations — the
    /// tail shape the `BENCH_*.json` trajectory records.
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    /// items/second for a per-iteration item count.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.median_ns / 1e9)
    }
}

/// Nearest-rank percentile of an ascending-sorted sample set: rank
/// `round((len-1) * q)` — the same rule the coordinator's streaming
/// histograms use, so bench files and serve metrics agree on definition.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// Format nanoseconds human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Run `f` with `warmup` untimed then at least `min_iters` timed iterations
/// (or until `min_time` elapses), and print a summary line.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, min_iters: usize, min_time: Duration, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || start.elapsed() < min_time {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
        if samples.len() >= 10_000 {
            break; // enough statistics for anything
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let mut devs: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = devs[devs.len() / 2];
    let result = BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        median_ns: median,
        mad_ns: mad,
        mean_ns: mean,
        p50_ns: percentile_sorted(&samples, 0.50),
        p95_ns: percentile_sorted(&samples, 0.95),
        p99_ns: percentile_sorted(&samples, 0.99),
    };
    println!(
        "{:<48} median {:>12}  (±{:>10}, mean {:>12}, {} iters)",
        result.name,
        fmt_ns(result.median_ns),
        fmt_ns(result.mad_ns),
        fmt_ns(result.mean_ns),
        result.iters
    );
    result
}

/// Convenience wrapper with crate defaults (3 warmups, 10 iters, 300 ms).
pub fn bench_default<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench(name, 3, 10, Duration::from_millis(300), f)
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Prevent the optimizer from discarding a value (std::hint wrapper).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let r = bench("noop", 1, 5, Duration::from_millis(1), || {
            black_box(1 + 1);
        });
        assert!(r.iters >= 5);
        assert!(r.median_ns >= 0.0);
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p95_ns && r.p95_ns <= r.p99_ns);
    }

    #[test]
    fn throughput_computes_items_per_second() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            median_ns: 1e6, // 1 ms
            mad_ns: 0.0,
            mean_ns: 1e6,
            p50_ns: 1e6,
            p95_ns: 1e6,
            p99_ns: 1e6,
        };
        assert!((r.throughput(1000.0) - 1e6).abs() < 1.0);
    }

    #[test]
    fn percentile_sorted_uses_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile_sorted(&v, 0.0), 1.0);
        assert_eq!(percentile_sorted(&v, 1.0), 100.0);
        // rank round(99 * 0.5) = 50 -> value 51.
        assert_eq!(percentile_sorted(&v, 0.5), 51.0);
        assert_eq!(percentile_sorted(&v, 0.95), 95.0);
        assert_eq!(percentile_sorted(&[], 0.5), 0.0);
        assert_eq!(percentile_sorted(&[7.0], 0.99), 7.0);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(5.0).contains("ns"));
        assert!(fmt_ns(5e3).contains("µs"));
        assert!(fmt_ns(5e6).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }
}
