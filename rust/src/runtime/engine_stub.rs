//! Stub engine, compiled unless both the `pjrt` and `xla` cargo features
//! are on (the real engine needs the `xla` bindings crate).
//!
//! Keeps the full [`Engine`] API surface so every consumer (the `pjrt`
//! execution backend, `sextans run --xla`, examples, benches) type-checks
//! without the `xla` crate; `load` always fails, and because [`Engine`] is
//! uninhabited the remaining methods are statically unreachable.

use std::path::Path;

use anyhow::{bail, Result};

use super::Variant;
use crate::sched::ScheduledMatrix;
use crate::sparse::Coo;

/// Uninhabited stand-in for the PJRT engine.
#[derive(Debug)]
pub enum Engine {}

impl Engine {
    /// Always fails: the build has no PJRT support.
    pub fn load_default() -> Result<Engine> {
        Self::load(Path::new("artifacts"))
    }

    /// Always fails: the build has no PJRT support.
    pub fn load(_dir: &Path) -> Result<Engine> {
        bail!(
            "PJRT engine unavailable: built without the `pjrt`+`xla` cargo features \
             (enable both, add the `xla` dependency, and run `make artifacts`)"
        )
    }

    /// Unreachable (no `Engine` value can exist).
    pub fn variants(&self) -> Vec<Variant> {
        match *self {}
    }

    /// Unreachable (no `Engine` value can exist).
    pub fn select_variant(&self, _rows_per_pe: usize) -> Result<Variant> {
        match *self {}
    }

    /// Unreachable (no `Engine` value can exist).
    pub fn plan(&self, _a: &Coo, _p: usize, _d: usize) -> Result<(Variant, ScheduledMatrix)> {
        match *self {}
    }

    /// Unreachable (no `Engine` value can exist).
    pub fn run_window(
        &self,
        _v: Variant,
        _rows: &[i32],
        _cols: &[i32],
        _vals: &[f32],
        _b_win: &[f32],
        _c_acc: &[f32],
    ) -> Result<Vec<f32>> {
        match *self {}
    }

    /// Unreachable (no `Engine` value can exist).
    pub fn run_comp(
        &self,
        _m_tile: usize,
        _n0: usize,
        _c_ab: &[f32],
        _c_in: &[f32],
        _alpha: f32,
        _beta: f32,
    ) -> Result<Vec<f32>> {
        match *self {}
    }

    /// Unreachable (no `Engine` value can exist).
    pub fn fused_variant(&self) -> Option<(Variant, usize)> {
        match *self {}
    }

    /// Unreachable (no `Engine` value can exist).
    #[allow(clippy::too_many_arguments)]
    pub fn run_fused(
        &self,
        _rows: &[i32],
        _cols: &[i32],
        _vals: &[f32],
        _b_wins: &[f32],
        _c_in: &[f32],
        _alpha: f32,
        _beta: f32,
    ) -> Result<Vec<f32>> {
        match *self {}
    }

    /// Unreachable (no `Engine` value can exist).
    pub fn run_dense(&self, _a: &[f32], _b: &[f32]) -> Result<Vec<f32>> {
        match *self {}
    }

    /// Unreachable (no `Engine` value can exist).
    #[allow(clippy::too_many_arguments)]
    pub fn spmm(
        &self,
        _v: Variant,
        _sm: &ScheduledMatrix,
        _b: &[f32],
        _c_in: &[f32],
        _n: usize,
        _alpha: f32,
        _beta: f32,
    ) -> Result<Vec<f32>> {
        match *self {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_missing_feature() {
        let err = Engine::load_default().unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err:#}");
        let err = Engine::load(Path::new("/nonexistent")).unwrap_err();
        assert!(err.to_string().contains("unavailable"), "{err:#}");
    }
}
