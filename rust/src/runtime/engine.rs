//! PJRT execution engine: loads the AOT HLO artifacts and runs SpMM through
//! the L1 Pallas kernels on the CPU PJRT client.
//!
//! Compilation happens once per artifact at [`Engine::load`] — the runtime
//! analogue of place-and-route. After that, every SpMM is served by the
//! fixed executables (HFlex: only buffer contents change). HLO *text* is the
//! interchange format (see `python/compile/aot.py` and /opt/xla-example).
//!
//! Only compiled with the `pjrt` + `xla` cargo features (needs the `xla`
//! bindings crate); see `engine_stub.rs` for every other build.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{self, ArtifactSpec};
use super::Variant;
use crate::sched::{decode, preprocess, ScheduledMatrix};
use crate::sparse::Coo;

struct Compiled {
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// The engine: PJRT client + compiled executables, keyed by artifact name.
pub struct Engine {
    #[allow(dead_code)] // owns the PJRT runtime the executables run on
    client: xla::PjRtClient,
    windows: Vec<(Variant, Compiled)>,
    comps: HashMap<usize, Compiled>, // m_tile -> comp_c executable
    fused: Option<(Variant, usize, Compiled)>,
    dense: Option<Compiled>,
}

impl Engine {
    /// Load from the default artifacts dir (`$SEXTANS_ARTIFACTS` or
    /// `./artifacts`).
    pub fn load_default() -> Result<Engine> {
        Self::load(&manifest::default_dir())
    }

    /// Load and compile every artifact in `dir`.
    pub fn load(dir: &Path) -> Result<Engine> {
        let specs = manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(wrap_xla)?;
        let mut windows = Vec::new();
        let mut comps = HashMap::new();
        let mut fused = None;
        let mut dense = None;
        for spec in specs {
            let path = dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .map_err(wrap_xla)
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(wrap_xla)?;
            let compiled = Compiled { spec: spec.clone(), exe };
            match spec.kind.as_str() {
                "spmm_window" => {
                    let v = Variant {
                        nnz_cap: spec.param("nnz_cap")?,
                        k0: spec.param("k0")?,
                        m_tile: spec.param("m_tile")?,
                        n0: spec.param("n0")?,
                    };
                    windows.push((v, compiled));
                }
                "comp_c" => {
                    comps.insert(spec.param("m_tile")?, compiled);
                }
                "spmm_fused" => {
                    let v = Variant {
                        nnz_cap: spec.param("nnz_cap")?,
                        k0: spec.param("k0")?,
                        m_tile: spec.param("m_tile")?,
                        n0: spec.param("n0")?,
                    };
                    let nwin = spec.param("nwin")?;
                    fused = Some((v, nwin, compiled));
                }
                "dense_tile" => dense = Some(compiled),
                other => bail!("unknown artifact kind {other:?}"),
            }
        }
        if windows.is_empty() {
            bail!("no spmm_window artifacts in manifest");
        }
        // Smallest-capacity-first ordering for variant selection.
        windows.sort_by_key(|(v, _)| (v.m_tile, v.nnz_cap));
        Ok(Engine { client, windows, comps, fused, dense })
    }

    /// Available window variants (capacity-sorted).
    pub fn variants(&self) -> Vec<Variant> {
        self.windows.iter().map(|(v, _)| *v).collect()
    }

    /// Pick the smallest variant able to hold `rows_per_pe` C rows. The
    /// image must then be preprocessed with the variant's `k0`.
    pub fn select_variant(&self, rows_per_pe: usize) -> Result<Variant> {
        self.windows
            .iter()
            .map(|(v, _)| *v)
            .find(|v| v.m_tile >= rows_per_pe)
            .ok_or_else(|| {
                anyhow!(
                    "no variant fits {rows_per_pe} rows/PE (largest m_tile = {})",
                    self.windows.last().map(|(v, _)| v.m_tile).unwrap_or(0)
                )
            })
    }

    /// Preprocess a matrix for execution on this engine with `p` PEs and
    /// RAW distance `d`: selects a variant and schedules for its K0.
    pub fn plan(&self, a: &Coo, p: usize, d: usize) -> Result<(Variant, ScheduledMatrix)> {
        let rows_per_pe = a.m.div_ceil(p);
        let v = self.select_variant(rows_per_pe)?;
        Ok((v, preprocess(a, p, v.k0, d)))
    }

    fn window_exe(&self, v: Variant) -> Result<&Compiled> {
        self.windows
            .iter()
            .find(|(w, _)| *w == v)
            .map(|(_, c)| c)
            .ok_or_else(|| anyhow!("variant {v:?} not loaded"))
    }

    /// Execute one window kernel call: C tile += scheduled slots × B window.
    /// All buffers must match the variant's shapes exactly.
    pub fn run_window(
        &self,
        v: Variant,
        rows: &[i32],
        cols: &[i32],
        vals: &[f32],
        b_win: &[f32],
        c_acc: &[f32],
    ) -> Result<Vec<f32>> {
        debug_assert_eq!(rows.len(), v.nnz_cap);
        debug_assert_eq!(b_win.len(), v.k0 * v.n0);
        debug_assert_eq!(c_acc.len(), v.m_tile * v.n0);
        let compiled = self.window_exe(v)?;
        let args = [
            xla::Literal::vec1(rows),
            xla::Literal::vec1(cols),
            xla::Literal::vec1(vals),
            xla::Literal::vec1(b_win)
                .reshape(&[v.k0 as i64, v.n0 as i64])
                .map_err(wrap_xla)?,
            xla::Literal::vec1(c_acc)
                .reshape(&[v.m_tile as i64, v.n0 as i64])
                .map_err(wrap_xla)?,
        ];
        run1(&compiled.exe, &args)
    }

    /// Execute the Comp-C kernel: `alpha * c_ab + beta * c_in`.
    pub fn run_comp(
        &self,
        m_tile: usize,
        n0: usize,
        c_ab: &[f32],
        c_in: &[f32],
        alpha: f32,
        beta: f32,
    ) -> Result<Vec<f32>> {
        let compiled = self
            .comps
            .get(&m_tile)
            .ok_or_else(|| anyhow!("no comp_c artifact for m_tile={m_tile}"))?;
        let args = [
            xla::Literal::vec1(c_ab)
                .reshape(&[m_tile as i64, n0 as i64])
                .map_err(wrap_xla)?,
            xla::Literal::vec1(c_in)
                .reshape(&[m_tile as i64, n0 as i64])
                .map_err(wrap_xla)?,
            xla::Literal::vec1(&[alpha]).reshape(&[1, 1]).map_err(wrap_xla)?,
            xla::Literal::vec1(&[beta]).reshape(&[1, 1]).map_err(wrap_xla)?,
        ];
        run1(&compiled.exe, &args)
    }

    /// Fused-tile variant, if loaded: (variant, nwin).
    pub fn fused_variant(&self) -> Option<(Variant, usize)> {
        self.fused.as_ref().map(|(v, nwin, _)| (*v, *nwin))
    }

    /// Execute the fused tile artifact (scan over nwin windows + Comp-C).
    #[allow(clippy::too_many_arguments)]
    pub fn run_fused(
        &self,
        rows: &[i32],
        cols: &[i32],
        vals: &[f32],
        b_wins: &[f32],
        c_in: &[f32],
        alpha: f32,
        beta: f32,
    ) -> Result<Vec<f32>> {
        let (v, nwin, compiled) = self
            .fused
            .as_ref()
            .ok_or_else(|| anyhow!("no fused artifact loaded"))?;
        let (v, nwin) = (*v, *nwin);
        debug_assert_eq!(rows.len(), nwin * v.nnz_cap);
        let args = [
            xla::Literal::vec1(rows)
                .reshape(&[nwin as i64, v.nnz_cap as i64])
                .map_err(wrap_xla)?,
            xla::Literal::vec1(cols)
                .reshape(&[nwin as i64, v.nnz_cap as i64])
                .map_err(wrap_xla)?,
            xla::Literal::vec1(vals)
                .reshape(&[nwin as i64, v.nnz_cap as i64])
                .map_err(wrap_xla)?,
            xla::Literal::vec1(b_wins)
                .reshape(&[nwin as i64, v.k0 as i64, v.n0 as i64])
                .map_err(wrap_xla)?,
            xla::Literal::vec1(c_in)
                .reshape(&[v.m_tile as i64, v.n0 as i64])
                .map_err(wrap_xla)?,
            xla::Literal::vec1(&[alpha]).reshape(&[1, 1]).map_err(wrap_xla)?,
            xla::Literal::vec1(&[beta]).reshape(&[1, 1]).map_err(wrap_xla)?,
        ];
        run1(&compiled.exe, &args)
    }

    /// Execute the dense tile matmul artifact (MXU path), if loaded.
    pub fn run_dense(&self, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let compiled = self.dense.as_ref().ok_or_else(|| anyhow!("no dense artifact"))?;
        let m_t = compiled.spec.param("m_t")?;
        let k_t = compiled.spec.param("k_t")?;
        let n_t = compiled.spec.param("n_t")?;
        debug_assert_eq!(a.len(), m_t * k_t);
        debug_assert_eq!(b.len(), k_t * n_t);
        let args = [
            xla::Literal::vec1(a)
                .reshape(&[m_t as i64, k_t as i64])
                .map_err(wrap_xla)?,
            xla::Literal::vec1(b)
                .reshape(&[k_t as i64, n_t as i64])
                .map_err(wrap_xla)?,
        ];
        run1(&compiled.exe, &args)
    }

    /// Full SpMM `C = alpha*A@B + beta*C` through the PJRT kernels: the
    /// whole request-path compute runs inside XLA executables; rust only
    /// marshals windows — exactly the L3/L1 split of the architecture.
    ///
    /// The image must have been produced by [`Engine::plan`] (its `k0` must
    /// equal the chosen variant's and every PE tile must fit `m_tile`).
    pub fn spmm(
        &self,
        v: Variant,
        sm: &ScheduledMatrix,
        b: &[f32],
        c_in: &[f32],
        n: usize,
        alpha: f32,
        beta: f32,
    ) -> Result<Vec<f32>> {
        if sm.k0 != v.k0 {
            bail!("image k0 {} != variant k0 {} (use Engine::plan)", sm.k0, v.k0);
        }
        let rows_per_pe = sm.rows_per_pe();
        if rows_per_pe > v.m_tile {
            bail!("{rows_per_pe} rows/PE exceeds variant m_tile {}", v.m_tile);
        }
        if b.len() != sm.k * n || c_in.len() != sm.m * n {
            bail!("B/C shape mismatch");
        }
        let n_slices = n.div_ceil(v.n0);
        let mut c_out = vec![0f32; sm.m * n];

        // Reusable padded buffers.
        let mut rows_buf = vec![0i32; v.nnz_cap];
        let mut cols_buf = vec![0i32; v.nnz_cap];
        let mut vals_buf = vec![0f32; v.nnz_cap];
        let mut b_win = vec![0f32; v.k0 * v.n0];

        for slice in 0..n_slices {
            let q0 = slice * v.n0;
            let qw = v.n0.min(n - q0);
            for (pe, stream) in sm.streams.iter().enumerate() {
                let mut c_tile = vec![0f32; v.m_tile * v.n0];
                for j in 0..sm.num_windows {
                    // Stream the B window for (j, slice) with zero padding.
                    b_win.iter_mut().for_each(|x| *x = 0.0);
                    let kbase = j * v.k0;
                    let kw = v.k0.min(sm.k - kbase.min(sm.k));
                    for kk in 0..kw {
                        let src = &b[(kbase + kk) * n + q0..(kbase + kk) * n + q0 + qw];
                        b_win[kk * v.n0..kk * v.n0 + qw].copy_from_slice(src);
                    }
                    // Feed scheduled slots in nnz_cap chunks (fixed shape).
                    let slots = &stream.encoded[stream.q.window_range(j)];
                    for chunk in slots.chunks(v.nnz_cap) {
                        rows_buf.iter_mut().for_each(|x| *x = 0);
                        cols_buf.iter_mut().for_each(|x| *x = 0);
                        vals_buf.iter_mut().for_each(|x| *x = 0.0);
                        for (t, &word) in chunk.iter().enumerate() {
                            let nz = decode(word);
                            rows_buf[t] = nz.row as i32;
                            cols_buf[t] = nz.col as i32;
                            vals_buf[t] = nz.val;
                        }
                        c_tile = self.run_window(
                            v, &rows_buf, &cols_buf, &vals_buf, &b_win, &c_tile,
                        )?;
                    }
                }
                // Comp-C for this PE's rows, then scatter to C_out.
                let mut c_in_tile = vec![0f32; v.m_tile * v.n0];
                for t in 0..rows_per_pe {
                    let gr = t * sm.p + pe;
                    if gr >= sm.m {
                        break;
                    }
                    c_in_tile[t * v.n0..t * v.n0 + qw]
                        .copy_from_slice(&c_in[gr * n + q0..gr * n + q0 + qw]);
                }
                let combined =
                    self.run_comp(v.m_tile, v.n0, &c_tile, &c_in_tile, alpha, beta)?;
                for t in 0..rows_per_pe {
                    let gr = t * sm.p + pe;
                    if gr >= sm.m {
                        break;
                    }
                    c_out[gr * n + q0..gr * n + q0 + qw]
                        .copy_from_slice(&combined[t * v.n0..t * v.n0 + qw]);
                }
            }
        }
        Ok(c_out)
    }
}

fn run1(exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<Vec<f32>> {
    let result = exe.execute::<xla::Literal>(args).map_err(wrap_xla)?;
    let lit = result[0][0].to_literal_sync().map_err(wrap_xla)?;
    // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
    let out = lit.to_tuple1().map_err(wrap_xla)?;
    out.to_vec::<f32>().map_err(wrap_xla)
}

fn wrap_xla(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}
