//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute the L1
//! Pallas kernels from the rust request path. Python never runs here.
//!
//! The real engine needs the `xla` bindings crate and is therefore gated
//! behind the `pjrt` cargo feature. Without it (the default in artifact-free
//! environments), [`Engine`] is an API-identical stub whose `load` reports
//! unavailability — callers (the `pjrt` execution backend, examples, tests)
//! degrade gracefully instead of failing to build.

pub mod manifest;

#[cfg(feature = "pjrt")]
pub mod engine;

#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
pub mod engine;

pub use engine::Engine;
pub use manifest::ArtifactSpec;

/// A fixed-capacity window variant ("bitstream") the engine can execute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Variant {
    /// Scheduled-slot capacity per kernel call.
    pub nnz_cap: usize,
    /// B window depth.
    pub k0: usize,
    /// C tile rows.
    pub m_tile: usize,
    /// Lane count.
    pub n0: usize,
}
