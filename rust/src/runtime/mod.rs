//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute the L1
//! Pallas kernels from the rust request path. Python never runs here.
//!
//! The real engine needs the `xla` bindings crate and is therefore gated
//! behind **both** the `pjrt` and `xla` cargo features (`xla` marks the
//! bindings dependency as actually wired into the manifest). With `pjrt`
//! alone — the configuration CI's feature matrix builds — [`Engine`] is
//! still the API-identical stub whose `load` reports unavailability, so the
//! feature-gated API surface compiles in artifact-free environments and
//! callers (the `pjrt` execution backend, examples, tests) degrade
//! gracefully instead of failing to build.

pub mod manifest;

#[cfg(all(feature = "pjrt", feature = "xla"))]
pub mod engine;

#[cfg(not(all(feature = "pjrt", feature = "xla")))]
#[path = "engine_stub.rs"]
pub mod engine;

pub use engine::Engine;
pub use manifest::ArtifactSpec;

/// A fixed-capacity window variant ("bitstream") the engine can execute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Variant {
    /// Scheduled-slot capacity per kernel call.
    pub nnz_cap: usize,
    /// B window depth.
    pub k0: usize,
    /// C tile rows.
    pub m_tile: usize,
    /// Lane count.
    pub n0: usize,
}
