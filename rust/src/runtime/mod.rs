//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute the L1
//! Pallas kernels from the rust request path. Python never runs here.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, Variant};
pub use manifest::ArtifactSpec;
