//! Artifact manifest parsing — the contract with `python/compile/aot.py`.
//!
//! `artifacts/manifest.tsv` has one line per AOT artifact:
//! `kind \t name \t file \t key=value \t key=value ...`

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One artifact entry.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    /// Artifact kind: `spmm_window`, `comp_c`, `spmm_fused`, `dense_tile`.
    pub kind: String,
    /// Unique name (e.g. `win_m`).
    pub name: String,
    /// HLO text filename, relative to the artifacts dir.
    pub file: String,
    /// Integer parameters (nnz_cap, k0, m_tile, n0, nwin, ...).
    pub params: HashMap<String, usize>,
}

impl ArtifactSpec {
    /// Required parameter lookup.
    pub fn param(&self, key: &str) -> Result<usize> {
        self.params
            .get(key)
            .copied()
            .with_context(|| format!("artifact {} missing param {key}", self.name))
    }
}

/// Parse manifest text.
pub fn parse(text: &str) -> Result<Vec<ArtifactSpec>> {
    let mut specs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split('\t').collect();
        if fields.len() < 3 {
            bail!("manifest line {}: expected >= 3 tab fields", lineno + 1);
        }
        let mut params = HashMap::new();
        for kv in &fields[3..] {
            let (k, v) = kv
                .split_once('=')
                .with_context(|| format!("manifest line {}: bad param {kv:?}", lineno + 1))?;
            params.insert(
                k.to_string(),
                v.parse::<usize>()
                    .with_context(|| format!("manifest line {}: non-integer {kv:?}", lineno + 1))?,
            );
        }
        specs.push(ArtifactSpec {
            kind: fields[0].to_string(),
            name: fields[1].to_string(),
            file: fields[2].to_string(),
            params,
        });
    }
    if specs.is_empty() {
        bail!("empty manifest");
    }
    Ok(specs)
}

/// Load and parse `<dir>/manifest.tsv`.
pub fn load(dir: &Path) -> Result<Vec<ArtifactSpec>> {
    let path = dir.join("manifest.tsv");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
    parse(&text)
}

/// Artifacts directory: `$SEXTANS_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var_os("SEXTANS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "spmm_window\twin_s\twin_s.hlo.txt\tk0=128\tm_tile=128\tn0=8\tnnz_cap=256\n\
comp_c\tcomp_win_s\tcomp_win_s.hlo.txt\tm_tile=128\tn0=8\n";

    #[test]
    fn parses_kinds_names_params() {
        let specs = parse(SAMPLE).unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].kind, "spmm_window");
        assert_eq!(specs[0].param("nnz_cap").unwrap(), 256);
        assert_eq!(specs[1].param("m_tile").unwrap(), 128);
        assert!(specs[1].param("nnz_cap").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("").is_err());
        assert!(parse("just-one-field\n").is_err());
        assert!(parse("a\tb\tc\tnot_kv\n").is_err());
        assert!(parse("a\tb\tc\tk=notnum\n").is_err());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let text = format!("# header\n\n{SAMPLE}");
        assert_eq!(parse(&text).unwrap().len(), 2);
    }
}
