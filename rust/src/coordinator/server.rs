//! The SpMM serving coordinator: request queue → dynamic batcher → worker
//! pool, in the style of an inference router (vLLM-like), specialized to
//! the HFlex contract.
//!
//! **Dynamic batching** exploits SpMM's structure: two requests against the
//! same preprocessed A image with matching (α, β) are *column-concatenated*
//! into a single SpMM with N = N₁ + N₂ — the accelerator's per-window costs
//! (B stream, C init, pointers) amortize across the batch exactly as the
//! paper's N/N0 loop amortizes them across columns. The batcher groups by
//! image identity within a bounded window, dispatches merged jobs to
//! workers, and splits C back per request.
//!
//! **Prepared-handle caching**: each worker keys a small MRU cache of
//! [`PreparedSpmm`] handles on the registered [`ImageHandle`] id, so N
//! requests against one matrix prepare it once *per worker* — the
//! prepare/execute contract's amortization, measured: prepare counts, wall
//! time, resident bytes, and the cache hit rate all flow into
//! [`Summary`].
//!
//! Workers are std::thread; the backend factory is called once per worker
//! and handles are prepared inside the worker thread (the real PJRT
//! engine's handles are thread-local, which is exactly what the per-worker
//! cache respects). [`Server::start_backend`] builds the factory from a
//! registry spec string (`"native"`, `"native:4"`, `"functional"`,
//! `"pjrt"`, `"sharded:4:native"`), so deployments pick engines by name;
//! every request records which backend executed it.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::metrics::{Recorder, RequestTiming, Summary};
use crate::arch::simulator::problem_flops;
use crate::backend::{self, BackendError, PreparedSpmm, SpmmBackend};
use crate::sched::ScheduledMatrix;

/// Prepared handles kept per worker, most recently used first. Sized for a
/// worker serving a handful of registered matrices; beyond this the oldest
/// residency is dropped and rebuilt on next use.
pub const PREPARED_CACHE_ENTRIES: usize = 8;

/// A preprocessed matrix registered with the server (shared across
/// requests — the "model weights" of the serving analogy). The `id` is
/// what workers key their prepared-handle caches on.
#[derive(Clone)]
pub struct ImageHandle {
    /// Unique id assigned at registration.
    pub id: u64,
    /// The scheduled image.
    pub image: Arc<ScheduledMatrix>,
}

/// One SpMM request: `C = alpha * A @ B + beta * C`.
pub struct SpmmRequest {
    /// Which registered matrix.
    pub image: ImageHandle,
    /// Dense B, row-major K × n.
    pub b: Vec<f32>,
    /// Dense C_in, row-major M × n.
    pub c: Vec<f32>,
    /// Columns.
    pub n: usize,
    /// Scalar α.
    pub alpha: f32,
    /// Scalar β.
    pub beta: f32,
}

/// Completed response.
pub struct SpmmResponse {
    /// C_out, row-major M × n (zero-filled when `error` is set).
    pub c: Vec<f32>,
    /// Timing.
    pub timing: RequestTiming,
    /// Why the backend failed, if it did; `c` is then not a result.
    pub error: Option<String>,
}

/// A batch-merged job handed to workers.
pub struct MergedJob {
    image: ImageHandle,
    alpha: f32,
    beta: f32,
    b_cat: Vec<f32>,
    c_cat: Vec<f32>,
    n_total: usize,
    segments: Vec<Segment>,
}

struct Segment {
    n: usize,
    col_off: usize,
    submitted: Instant,
    respond: Sender<SpmmResponse>,
}

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max total columns per merged job (paper sweeps N up to 512).
    pub max_columns: usize,
    /// How long the batcher waits to fill a batch.
    pub window: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_columns: 512, window: Duration::from_millis(2) }
    }
}

enum Msg {
    Request(SpmmRequest, Sender<SpmmResponse>, Instant),
    Shutdown,
}

/// The serving coordinator.
pub struct Server {
    tx: Sender<Msg>,
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    recorder: Arc<Mutex<Recorder>>,
    next_image_id: AtomicU64,
}

impl Server {
    /// Start with `n_workers` threads, a backend factory (called once per
    /// worker thread), and a batching policy.
    pub fn start<F>(n_workers: usize, policy: BatchPolicy, factory: F) -> Server
    where
        F: Fn(usize) -> Box<dyn SpmmBackend> + Send + Sync + 'static,
    {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (job_tx, job_rx) = mpsc::channel::<MergedJob>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let recorder = Arc::new(Mutex::new(Recorder::default()));

        let batcher = {
            let recorder = Arc::clone(&recorder);
            std::thread::spawn(move || batcher_loop(rx, job_tx, policy, recorder))
        };

        let factory = Arc::new(factory);
        let workers = (0..n_workers.max(1))
            .map(|w| {
                let job_rx = Arc::clone(&job_rx);
                let recorder = Arc::clone(&recorder);
                let factory = Arc::clone(&factory);
                std::thread::spawn(move || {
                    let exec = factory(w);
                    worker_loop(&*exec, job_rx, recorder);
                })
            })
            .collect();

        Server {
            tx,
            batcher: Some(batcher),
            workers,
            recorder,
            next_image_id: AtomicU64::new(1),
        }
    }

    /// Start with backends built by name from the [`crate::backend`]
    /// registry (`"native"`, `"native:<threads>"`, `"native-blocked"`,
    /// `"functional"`, `"pjrt"`, `"sharded:<S>:<inner>"`). The spec is
    /// parsed and its availability in this build is checked eagerly (an
    /// unavailable backend — e.g. `pjrt` without the real engine — is
    /// refused here rather than failing every request); each worker thread
    /// then constructs its own factory and prepares handles inside the
    /// thread. Auto-threaded specs are rewritten through
    /// [`backend::apply_thread_budget`] with this machine's cores divided
    /// across the worker threads, so workers × shards × engine threads
    /// never oversubscribes the CPU.
    pub fn start_backend(
        n_workers: usize,
        policy: BatchPolicy,
        spec: &str,
    ) -> Result<Server, BackendError> {
        backend::create(spec)?; // parse + argument validation
        backend::check_available(spec)?; // sees through sharded:<S>:<inner>
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let spec =
            backend::apply_thread_budget(spec, cores.div_ceil(n_workers.max(1)).max(1));
        Ok(Server::start(n_workers, policy, move |_| {
            backend::create(&spec).expect("backend spec validated at startup")
        }))
    }

    /// Register a preprocessed matrix for serving.
    pub fn register(&self, image: Arc<ScheduledMatrix>) -> ImageHandle {
        ImageHandle { id: self.next_image_id.fetch_add(1, Ordering::Relaxed), image }
    }

    /// Submit a request; returns the response channel.
    pub fn submit(&self, req: SpmmRequest) -> Receiver<SpmmResponse> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Request(req, tx, Instant::now()))
            .expect("server stopped");
        rx
    }

    /// Convenience: submit and wait.
    pub fn call(&self, req: SpmmRequest) -> SpmmResponse {
        self.submit(req).recv().expect("worker dropped response")
    }

    /// Drain and stop; returns the serving summary.
    pub fn shutdown(mut self) -> Summary {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        let summary = self.recorder.lock().unwrap().summary();
        summary
    }
}

fn batcher_loop(
    rx: Receiver<Msg>,
    job_tx: Sender<MergedJob>,
    policy: BatchPolicy,
    recorder: Arc<Mutex<Recorder>>,
) {
    // Pending requests grouped by (image id, alpha bits, beta bits).
    type Key = (u64, u32, u32);
    let mut pending: HashMap<Key, Vec<(SpmmRequest, Sender<SpmmResponse>, Instant)>> =
        HashMap::new();
    let mut deadline: Option<Instant> = None;

    let flush = |group: Vec<(SpmmRequest, Sender<SpmmResponse>, Instant)>,
                 job_tx: &Sender<MergedJob>,
                 recorder: &Arc<Mutex<Recorder>>| {
        if group.is_empty() {
            return;
        }
        recorder.lock().unwrap().record_batch(group.len());
        let image = group[0].0.image.clone();
        let (alpha, beta) = (group[0].0.alpha, group[0].0.beta);
        let m = image.image.m;
        let k = image.image.k;
        let n_total: usize = group.iter().map(|(r, _, _)| r.n).sum();
        // Column-concatenate B and C (row-major interleave).
        let mut b_cat = vec![0f32; k * n_total];
        let mut c_cat = vec![0f32; m * n_total];
        let mut col = 0usize;
        let mut segments = Vec::with_capacity(group.len());
        for (req, respond, submitted) in group {
            for row in 0..k {
                b_cat[row * n_total + col..row * n_total + col + req.n]
                    .copy_from_slice(&req.b[row * req.n..(row + 1) * req.n]);
            }
            for row in 0..m {
                c_cat[row * n_total + col..row * n_total + col + req.n]
                    .copy_from_slice(&req.c[row * req.n..(row + 1) * req.n]);
            }
            segments.push(Segment { n: req.n, col_off: col, submitted, respond });
            col += req.n;
        }
        let _ = job_tx.send(MergedJob {
            image,
            alpha,
            beta,
            b_cat,
            c_cat,
            n_total,
            segments,
        });
    };

    loop {
        let timeout = deadline
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(Msg::Request(req, respond, submitted)) => {
                let key = (req.image.id, req.alpha.to_bits(), req.beta.to_bits());
                let group = pending.entry(key).or_default();
                group.push((req, respond, submitted));
                let cols: usize = group.iter().map(|(r, _, _)| r.n).sum();
                if cols >= policy.max_columns {
                    let group = pending.remove(&key).unwrap();
                    flush(group, &job_tx, &recorder);
                }
                if deadline.is_none() && !pending.is_empty() {
                    deadline = Some(Instant::now() + policy.window);
                }
            }
            Ok(Msg::Shutdown) => {
                for (_, group) in pending.drain() {
                    flush(group, &job_tx, &recorder);
                }
                break; // dropping job_tx stops workers
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                for (_, group) in pending.drain() {
                    flush(group, &job_tx, &recorder);
                }
                deadline = None;
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                for (_, group) in pending.drain() {
                    flush(group, &job_tx, &recorder);
                }
                break;
            }
        }
    }
}

fn worker_loop(
    backend: &dyn SpmmBackend,
    job_rx: Arc<Mutex<Receiver<MergedJob>>>,
    recorder: Arc<Mutex<Recorder>>,
) {
    let backend_name = backend.name();
    // Per-worker prepared-handle cache, MRU-first, keyed on ImageHandle id.
    // Handles never leave this thread (PJRT-compatible by construction).
    let mut prepared: Vec<(u64, Box<dyn PreparedSpmm>)> = Vec::new();
    loop {
        let job = {
            let rx = job_rx.lock().unwrap();
            rx.recv()
        };
        let Ok(mut job) = job else { break };
        let start = Instant::now();
        // Resolve the resident handle: cache hit bubbles to the front,
        // miss pays the backend's build path exactly once per worker.
        let resolved: Result<(), String> =
            match prepared.iter().position(|(id, _)| *id == job.image.id) {
                Some(0) => {
                    recorder.lock().unwrap().record_prepare_hit();
                    Ok(())
                }
                Some(i) => {
                    let entry = prepared.remove(i);
                    prepared.insert(0, entry);
                    recorder.lock().unwrap().record_prepare_hit();
                    Ok(())
                }
                None => match backend.prepare(Arc::clone(&job.image.image)) {
                    Ok(handle) => {
                        recorder.lock().unwrap().record_prepare(&handle.prepare_cost());
                        prepared.insert(0, (job.image.id, handle));
                        prepared.truncate(PREPARED_CACHE_ENTRIES);
                        Ok(())
                    }
                    Err(e) => Err(e.to_string()),
                },
            };
        let error = match resolved {
            Ok(()) => {
                let handle = &mut prepared[0].1;
                handle
                    .execute(&job.b_cat, &mut job.c_cat, job.n_total, job.alpha, job.beta)
                    .err()
                    .map(|e| e.to_string())
            }
            Err(e) => Some(e),
        };
        let exec_time = start.elapsed();
        // Sharded backends expose per-shard stats for the job just run;
        // fold them into the serving summary (imbalance, makespan).
        if error.is_none() {
            if let Some(stats) = prepared[0].1.shard_stats() {
                recorder.lock().unwrap().record_shards(&stats);
            }
        }
        let m = job.image.image.m;
        let nnz = job.image.image.nnz;
        for seg in job.segments {
            let mut c = vec![0f32; m * seg.n];
            if error.is_none() {
                for row in 0..m {
                    c[row * seg.n..(row + 1) * seg.n].copy_from_slice(
                        &job.c_cat
                            [row * job.n_total + seg.col_off..row * job.n_total + seg.col_off + seg.n],
                    );
                }
            }
            let timing = RequestTiming {
                queue: start.duration_since(seg.submitted),
                exec: exec_time,
                flops: problem_flops(nnz, m, seg.n),
                backend: backend_name,
            };
            recorder.lock().unwrap().record(timing);
            let _ = seg.respond.send(SpmmResponse { c, timing, error: error.clone() });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{Capability, FunctionalBackend, PrepareCost};
    use crate::prop;
    use crate::sched::preprocess;
    use crate::shard::{PreparedSharded, ShardExecutor, ShardedMatrix};
    use crate::sparse::{gen, rng::Rng};

    /// Injects an execution failure on every request (prepare succeeds —
    /// residency is not the failure under test).
    struct FailingBackend;

    struct FailingPrepared;

    impl PreparedSpmm for FailingPrepared {
        fn backend_name(&self) -> &'static str {
            "failing"
        }

        fn prepare_cost(&self) -> PrepareCost {
            PrepareCost::default()
        }

        fn execute(
            &mut self,
            _b: &[f32],
            _c: &mut [f32],
            _n: usize,
            _alpha: f32,
            _beta: f32,
        ) -> Result<(), BackendError> {
            Err(BackendError::Execution("injected failure".into()))
        }
    }

    impl SpmmBackend for FailingBackend {
        fn name(&self) -> &'static str {
            "failing"
        }

        fn capability(&self) -> Capability {
            Capability {
                threads: 1,
                simd_lanes: 1,
                requires_artifacts: false,
                deterministic: true,
            }
        }

        fn prepare(
            &self,
            _image: Arc<ScheduledMatrix>,
        ) -> Result<Box<dyn PreparedSpmm>, BackendError> {
            Ok(Box::new(FailingPrepared))
        }
    }

    fn make_image(seed: u64) -> (crate::sparse::Coo, Arc<ScheduledMatrix>) {
        let mut rng = Rng::new(seed);
        let coo = gen::random_uniform(48, 40, 0.15, &mut rng);
        let sm = Arc::new(preprocess(&coo, 4, 16, 8));
        (coo, sm)
    }

    fn start_functional(workers: usize) -> Server {
        Server::start(workers, BatchPolicy::default(), |_| Box::new(FunctionalBackend))
    }

    #[test]
    fn single_request_roundtrip() {
        let (coo, sm) = make_image(1);
        let server = start_functional(1);
        let handle = server.register(sm);
        let mut rng = Rng::new(2);
        let n = 4;
        let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
        let c: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
        let mut want = c.clone();
        coo.spmm_reference(&b, &mut want, n, 1.5, 0.5);
        let resp = server.call(SpmmRequest {
            image: handle,
            b,
            c,
            n,
            alpha: 1.5,
            beta: 0.5,
        });
        assert!(resp.error.is_none());
        prop::assert_allclose(&resp.c, &want, 1e-4, 1e-4).unwrap();
        let summary = server.shutdown();
        assert_eq!(summary.requests, 1);
        assert_eq!(summary.prepares, 1);
    }

    #[test]
    fn repeated_matrix_prepares_once_per_worker() {
        // The amortization headline: sequential requests against one image
        // on one worker — exactly one prepare, everything else cache hits.
        let (coo, sm) = make_image(41);
        let server = Server::start_backend(1, BatchPolicy::default(), "native:1").unwrap();
        let handle = server.register(sm);
        let mut rng = Rng::new(42);
        let n = 3;
        for _ in 0..5 {
            let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
            let c: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
            let mut want = c.clone();
            coo.spmm_reference(&b, &mut want, n, 1.0, 0.5);
            let resp = server.call(SpmmRequest {
                image: handle.clone(),
                b,
                c,
                n,
                alpha: 1.0,
                beta: 0.5,
            });
            assert!(resp.error.is_none());
            prop::assert_allclose(&resp.c, &want, 1e-4, 1e-4).unwrap();
        }
        let summary = server.shutdown();
        assert_eq!(summary.requests, 5);
        assert_eq!(summary.prepares, 1, "one matrix, one worker: one prepare");
        assert_eq!(summary.prepare_hits, 4);
        assert!(summary.prepare_hit_rate > 0.7, "{}", summary.prepare_hit_rate);
        assert!(summary.prepared_bytes > 0);
    }

    #[test]
    fn multiple_images_each_get_residency() {
        let (coo1, sm1) = make_image(43);
        let (coo2, sm2) = make_image(44);
        let server = Server::start_backend(1, BatchPolicy::default(), "native:1").unwrap();
        let h1 = server.register(sm1);
        let h2 = server.register(sm2);
        let n = 2;
        for (h, coo) in [(&h1, &coo1), (&h2, &coo2), (&h1, &coo1), (&h2, &coo2)] {
            let resp = server.call(SpmmRequest {
                image: h.clone(),
                b: vec![1.0; coo.k * n],
                c: vec![0.0; coo.m * n],
                n,
                alpha: 1.0,
                beta: 0.0,
            });
            assert!(resp.error.is_none());
        }
        let summary = server.shutdown();
        assert_eq!(summary.prepares, 2, "two matrices: two prepares");
        assert_eq!(summary.prepare_hits, 2, "revisits hit the cache");
    }

    #[test]
    fn backend_failure_is_reported_not_silent() {
        let (_, sm) = make_image(9);
        let server = Server::start(1, BatchPolicy::default(), |_| Box::new(FailingBackend));
        let handle = server.register(sm.clone());
        let resp = server.call(SpmmRequest {
            image: handle,
            b: vec![0.0; sm.k * 2],
            c: vec![0.0; sm.m * 2],
            n: 2,
            alpha: 1.0,
            beta: 0.0,
        });
        let err = resp.error.expect("failure must be surfaced");
        assert!(err.contains("injected failure"), "{err}");
        assert_eq!(resp.timing.backend, "failing");
        server.shutdown();
    }

    #[test]
    fn unavailable_prepare_is_reported_per_request() {
        // A backend whose prepare fails (pjrt without artifacts) must fail
        // each request with the prepare error, not panic the worker.
        struct NoPrepare;
        impl SpmmBackend for NoPrepare {
            fn name(&self) -> &'static str {
                "no-prepare"
            }
            fn capability(&self) -> Capability {
                Capability {
                    threads: 1,
                    simd_lanes: 1,
                    requires_artifacts: true,
                    deterministic: true,
                }
            }
            fn prepare(
                &self,
                _image: Arc<ScheduledMatrix>,
            ) -> Result<Box<dyn PreparedSpmm>, BackendError> {
                Err(BackendError::Unavailable("no artifacts here".into()))
            }
        }
        let (_, sm) = make_image(11);
        let server = Server::start(1, BatchPolicy::default(), |_| Box::new(NoPrepare));
        let handle = server.register(sm.clone());
        let resp = server.call(SpmmRequest {
            image: handle,
            b: vec![0.0; sm.k * 2],
            c: vec![0.0; sm.m * 2],
            n: 2,
            alpha: 1.0,
            beta: 0.0,
        });
        let err = resp.error.expect("prepare failure must be surfaced");
        assert!(err.contains("no artifacts here"), "{err}");
        let summary = server.shutdown();
        assert_eq!(summary.prepares, 0, "failed prepares must not count as residency");
    }

    #[test]
    fn batched_requests_are_column_exact() {
        let (coo, sm) = make_image(3);
        let server = Server::start(
            1,
            BatchPolicy { max_columns: 64, window: Duration::from_millis(20) },
            |_| Box::new(FunctionalBackend),
        );
        let handle = server.register(sm);
        let mut rng = Rng::new(4);
        let mut rxs = Vec::new();
        let mut wants = Vec::new();
        for _ in 0..5 {
            let n = 1 + rng.index(4);
            let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
            let c: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
            let mut want = c.clone();
            coo.spmm_reference(&b, &mut want, n, 2.0, -1.0);
            wants.push(want);
            rxs.push(server.submit(SpmmRequest {
                image: handle.clone(),
                b,
                c,
                n,
                alpha: 2.0,
                beta: -1.0,
            }));
        }
        for (rx, want) in rxs.into_iter().zip(wants) {
            let resp = rx.recv().unwrap();
            prop::assert_allclose(&resp.c, &want, 1e-4, 1e-4).unwrap();
        }
        let summary = server.shutdown();
        assert_eq!(summary.requests, 5);
        // The 20 ms window should have merged several requests per batch.
        assert!(summary.batches < 5, "batches = {}", summary.batches);
        assert!(summary.mean_batch > 1.0);
    }

    #[test]
    fn different_alpha_beta_never_merge() {
        let (_, sm) = make_image(5);
        let server = Server::start(
            1,
            BatchPolicy { max_columns: 512, window: Duration::from_millis(10) },
            |_| Box::new(FunctionalBackend),
        );
        let handle = server.register(sm.clone());
        let k = sm.k;
        let m = sm.m;
        let mk = |alpha: f32| SpmmRequest {
            image: handle.clone(),
            b: vec![1.0; k * 2],
            c: vec![0.0; m * 2],
            n: 2,
            alpha,
            beta: 0.0,
        };
        let r1 = server.submit(mk(1.0));
        let r2 = server.submit(mk(2.0));
        let a = r1.recv().unwrap();
        let b = r2.recv().unwrap();
        // alpha=2 result must be exactly 2x alpha=1 result.
        for (x, y) in a.c.iter().zip(b.c.iter()) {
            assert!((2.0 * x - y).abs() < 1e-4);
        }
        let summary = server.shutdown();
        assert_eq!(summary.batches, 2, "distinct scalars must not merge");
    }

    #[test]
    fn sharded_backend_serves_and_reports_shard_metrics() {
        let (coo, sm) = make_image(21);
        let server = Server::start_backend(1, BatchPolicy::default(), "sharded:3:native:1")
            .unwrap();
        let handle = server.register(sm);
        let mut rng = Rng::new(22);
        let n = 3;
        let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
        let c: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
        let mut want = c.clone();
        coo.spmm_reference(&b, &mut want, n, 1.5, 0.5);
        let resp = server.call(SpmmRequest { image: handle, b, c, n, alpha: 1.5, beta: 0.5 });
        assert!(resp.error.is_none(), "{:?}", resp.error);
        prop::assert_allclose(&resp.c, &want, 2e-4, 2e-4).unwrap();
        assert_eq!(resp.timing.backend, "sharded");
        let summary = server.shutdown();
        assert_eq!(summary.requests, 1);
        assert_eq!(summary.shard_execs, 1);
        assert!((summary.mean_shards - 3.0).abs() < 1e-12);
        assert!(summary.mean_shard_imbalance >= 1.0);
        assert_eq!(summary.prepares, 1, "the shard plan is built once, at prepare");
    }

    #[test]
    fn failing_shard_surfaces_with_shard_identified() {
        // A composite whose shard 1 of 2 always fails at execute; the
        // response must name it, never silently zero its rows.
        struct HalfBrokenSharded;
        impl SpmmBackend for HalfBrokenSharded {
            fn name(&self) -> &'static str {
                "sharded"
            }
            fn capability(&self) -> Capability {
                Capability {
                    threads: 2,
                    simd_lanes: 1,
                    requires_artifacts: false,
                    deterministic: true,
                }
            }
            fn prepare(
                &self,
                image: Arc<ScheduledMatrix>,
            ) -> Result<Box<dyn PreparedSpmm>, BackendError> {
                let sharded = ShardedMatrix::from_image(&image, 2);
                let ok = FunctionalBackend
                    .prepare_send(Arc::clone(&sharded.shards[0].image))?;
                let exec = ShardExecutor::from_prepared(
                    &sharded,
                    vec![ok, Box::new(FailingPrepared)],
                );
                Ok(Box::new(PreparedSharded::from_executor(image, exec)))
            }
        }
        let (_, sm) = make_image(23);
        let server = Server::start(1, BatchPolicy::default(), |_| Box::new(HalfBrokenSharded));
        let handle = server.register(sm.clone());
        let resp = server.call(SpmmRequest {
            image: handle,
            b: vec![0.5; sm.k * 2],
            c: vec![0.5; sm.m * 2],
            n: 2,
            alpha: 1.0,
            beta: 0.0,
        });
        let err = resp.error.expect("shard failure must surface");
        assert!(err.contains("shard 1 of 2"), "{err}");
        assert!(err.contains("injected failure"), "{err}");
        assert_eq!(resp.timing.backend, "sharded");
        let summary = server.shutdown();
        assert_eq!(summary.shard_execs, 0, "failed runs must not count as sharded execs");
    }

    #[test]
    fn multi_worker_many_requests() {
        let (coo, sm) = make_image(7);
        let server = start_functional(3);
        let handle = server.register(sm);
        let mut rng = Rng::new(8);
        let n = 2;
        let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
        let mut want = vec![0f32; coo.m * n];
        coo.spmm_reference(&b, &mut want, n, 1.0, 0.0);
        let rxs: Vec<_> = (0..20)
            .map(|_| {
                server.submit(SpmmRequest {
                    image: handle.clone(),
                    b: b.clone(),
                    c: vec![0.0; coo.m * n],
                    n,
                    alpha: 1.0,
                    beta: 0.0,
                })
            })
            .collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            prop::assert_allclose(&resp.c, &want, 1e-4, 1e-4).unwrap();
        }
        let s = server.shutdown();
        assert_eq!(s.requests, 20);
        assert!(s.p50_s >= 0.0);
        // At most one prepare per worker for the single registered image.
        assert!(s.prepares <= 3, "prepares = {}", s.prepares);
    }
}
