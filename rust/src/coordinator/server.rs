//! The public serving facade over the four-stage pipeline: [`Server`]
//! wires **admission → batching → dispatch → residency** together and
//! exposes the stable request surface (`start`, `start_backend`,
//! `register`, `submit`, `call`, `shutdown`).
//!
//! Policy for every stage lives in [`PipelineConfig`]; the two classic
//! constructors keep their signatures and default the rest. Servers
//! started from a registry spec ([`Server::start_backend`] /
//! [`Server::start_backend_with`]) additionally get re-shard-on-skew
//! wiring: the raw `sharded:<S>:<inner>` parts and the per-worker core
//! budget are handed to the residency stage so a skew-triggered rebuild
//! re-derives its thread budget for the new S.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::admission::{Admit, AdmissionGate, AdmissionPolicy};
use super::batcher::{batcher_loop, Msg};
use super::dispatch;
use super::metrics::{ConcurrencyGauge, DeadlineStage, Recorder, RequestTiming, Summary};
use super::residency::{ReshardContext, ReshardPolicy, ResidencyManager, ResidencyPolicy};
use crate::backend::{self, BackendError, SpmmBackend};
use crate::sched::ScheduledMatrix;
use crate::telemetry::trace::{
    current_span_context, next_span_id, next_trace_id, SpanRecord, TelemetrySink,
};

pub use super::batcher::BatchPolicy;
pub use super::residency::PREPARED_CACHE_ENTRIES;

/// A preprocessed matrix registered with the server (shared across
/// requests — the "model weights" of the serving analogy). The `id` is
/// what the residency stage keys prepared handles on.
#[derive(Clone)]
pub struct ImageHandle {
    /// Unique id assigned at registration.
    pub id: u64,
    /// The scheduled image.
    pub image: Arc<ScheduledMatrix>,
}

/// One SpMM request: `C = alpha * A @ B + beta * C`.
pub struct SpmmRequest {
    /// Which registered matrix.
    pub image: ImageHandle,
    /// Dense B, row-major K × n.
    pub b: Vec<f32>,
    /// Dense C_in, row-major M × n.
    pub c: Vec<f32>,
    /// Columns.
    pub n: usize,
    /// Scalar α.
    pub alpha: f32,
    /// Scalar β.
    pub beta: f32,
    /// Absolute deadline stamped at the front door (`None` = no
    /// deadline). Checked at admission, batcher dequeue, and dispatch
    /// pickup; an expired request gets a typed
    /// [`RejectKind::DeadlineExceeded`] response instead of an execute,
    /// and its admission slot is released immediately.
    pub deadline: Option<Instant>,
}

/// Why a submit was refused before entering the pipeline. Carried on
/// [`SpmmResponse::rejected`] so callers (the network front door above
/// all) can classify refusals without matching error-message text.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectKind {
    /// B/C buffer lengths do not match the image shape and `n` — a bad
    /// request, not load.
    ShapeMismatch,
    /// The admission gate's global in-flight bound is full.
    QueueFull,
    /// The target image is at its per-image fairness quota.
    ImageQuota,
    /// The request's absolute deadline passed before an execute could
    /// run — shed at admission, batch dequeue, or dispatch pickup.
    DeadlineExceeded,
}

/// Completed response.
pub struct SpmmResponse {
    /// C_out, row-major M × n. Zero-filled when the pipeline failed
    /// mid-execution; **empty** when the request was shed at admission
    /// (rejection must not pay an M × n allocation) — check `error`
    /// before reading.
    pub c: Vec<f32>,
    /// Per-stage timing.
    pub timing: RequestTiming,
    /// Why the pipeline failed, if it did; `c` is then not a result.
    pub error: Option<String>,
    /// Set when the request was refused before entering the pipeline
    /// (`error` then carries the human-readable detail); `None` for
    /// served requests and mid-pipeline failures.
    pub rejected: Option<RejectKind>,
}

/// Every pipeline stage's policy in one place. `Default` matches the
/// classic constructors: generous admission, 2 ms merge window, 512 MiB
/// residency, re-shard-on-skew off, no telemetry sink.
#[derive(Clone, Default)]
pub struct PipelineConfig {
    /// Stage 1 — admission backpressure.
    pub admission: AdmissionPolicy,
    /// Stage 2 — merge window, batch size, shard-aware routing threshold.
    pub batch: BatchPolicy,
    /// Stage 4 — prepared-handle byte budget.
    pub residency: ResidencyPolicy,
    /// Stage 4 — re-shard-on-skew trigger (needs a registry-spec server).
    pub reshard: ReshardPolicy,
    /// Telemetry sink receiving one [`SpanRecord`] per completed pipeline
    /// stage of every request (admission, queue, batch, prepare, exec,
    /// plus a `request` root and `backend.prepare` on residency misses).
    /// `None` (the default) disables tracing; emission is a few atomic
    /// increments and one sink call per span, off the lock-held paths.
    pub sink: Option<Arc<dyn TelemetrySink>>,
}

impl std::fmt::Debug for PipelineConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PipelineConfig")
            .field("admission", &self.admission)
            .field("batch", &self.batch)
            .field("residency", &self.residency)
            .field("reshard", &self.reshard)
            .field("sink", &self.sink.as_ref().map(|_| "<dyn TelemetrySink>"))
            .finish()
    }
}

/// Pre-allocated trace ids carried alongside one request through every
/// pipeline stage. The root `request` span id is reserved up front so
/// stage spans can reference their parent before it is emitted (the root
/// itself is written by dispatch when the response is sent). When the
/// submitting thread carries a span context (the network front door's
/// `net.frontend` span), the request joins that trace and the root span
/// parents under it instead of starting a fresh trace.
#[derive(Clone, Copy, Debug)]
pub(crate) struct TraceCtx {
    pub(crate) trace_id: u64,
    pub(crate) root_id: u64,
    pub(crate) root_parent: Option<u64>,
}

/// The serving coordinator facade.
pub struct Server {
    tx: Sender<Msg>,
    gate: Arc<AdmissionGate>,
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    recorder: Arc<Mutex<Recorder>>,
    exec_gauge: Arc<ConcurrencyGauge>,
    next_image_id: AtomicU64,
    sink: Option<Arc<dyn TelemetrySink>>,
}

impl Server {
    /// Start with `n_workers` threads, a backend factory (called once per
    /// worker thread), and a batching policy; every other stage runs its
    /// default policy.
    pub fn start<F>(n_workers: usize, policy: BatchPolicy, factory: F) -> Server
    where
        F: Fn(usize) -> Box<dyn SpmmBackend> + Send + Sync + 'static,
    {
        let config = PipelineConfig { batch: policy, ..PipelineConfig::default() };
        Server::start_with(n_workers, config, factory)
    }

    /// Start with every stage policy explicit. Re-shard-on-skew stays off
    /// for closure factories — there is no registry spec to rebuild from;
    /// use [`Server::start_backend_with`] for that.
    pub fn start_with<F>(n_workers: usize, config: PipelineConfig, factory: F) -> Server
    where
        F: Fn(usize) -> Box<dyn SpmmBackend> + Send + Sync + 'static,
    {
        Server::start_pipeline(n_workers, config, factory, None)
    }

    /// Start with backends built by name from the [`crate::backend`]
    /// registry (`"native"`, `"native:<threads>"`, `"native-blocked"`,
    /// `"functional"`, `"pjrt"`, `"sharded:<S>:<inner>"`). The spec is
    /// parsed and its availability in this build is checked eagerly (an
    /// unavailable backend — e.g. `pjrt` without the real engine — is
    /// refused here rather than failing every request). Auto-threaded
    /// specs are rewritten through [`backend::apply_thread_budget`] with
    /// this machine's cores divided across the worker threads, so
    /// workers × shards × engine threads never oversubscribes the CPU.
    pub fn start_backend(
        n_workers: usize,
        policy: BatchPolicy,
        spec: &str,
    ) -> Result<Server, BackendError> {
        let config = PipelineConfig { batch: policy, ..PipelineConfig::default() };
        Server::start_backend_with(n_workers, config, spec)
    }

    /// [`Server::start_backend`] with every stage policy explicit. When
    /// the spec is a `sharded:<S>:<inner>` composite, the residency stage
    /// is additionally wired for re-shard-on-skew: it keeps the raw inner
    /// spec and the per-worker core budget, so a skew-triggered rebuild at
    /// a new S re-applies [`backend::apply_thread_budget`] instead of
    /// inheriting the old S's stale thread shares.
    pub fn start_backend_with(
        n_workers: usize,
        config: PipelineConfig,
        spec: &str,
    ) -> Result<Server, BackendError> {
        backend::create(spec)?; // parse + argument validation
        backend::check_available(spec)?; // sees through sharded:<S>:<inner>
        let budget = dispatch::per_worker_budget(n_workers);
        let budgeted = backend::apply_thread_budget(spec, budget);
        let ctx = backend::sharded_parts(spec)
            .map(|(_, inner)| ReshardContext { inner_spec: inner, budget });
        Ok(Server::start_pipeline(
            n_workers,
            config,
            move |_| backend::create(&budgeted).expect("backend spec validated at startup"),
            ctx,
        ))
    }

    /// Assemble the four stages.
    fn start_pipeline<F>(
        n_workers: usize,
        config: PipelineConfig,
        factory: F,
        reshard_ctx: Option<ReshardContext>,
    ) -> Server
    where
        F: Fn(usize) -> Box<dyn SpmmBackend> + Send + Sync + 'static,
    {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (job_tx, job_rx) = mpsc::channel();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let recorder = Arc::new(Mutex::new(Recorder::default()));
        let gate = Arc::new(AdmissionGate::new(config.admission));
        let exec_gauge = Arc::new(ConcurrencyGauge::new());
        let sink = config.sink.clone();
        let residency = Arc::new(ResidencyManager::new(
            config.residency,
            config.reshard,
            reshard_ctx,
            sink.clone(),
        ));

        let batcher = {
            let recorder = Arc::clone(&recorder);
            let policy = config.batch;
            let sink = sink.clone();
            let gate = Arc::clone(&gate);
            std::thread::spawn(move || batcher_loop(rx, job_tx, policy, recorder, gate, sink))
        };
        let workers = dispatch::spawn_workers(
            n_workers,
            Arc::new(factory),
            job_rx,
            Arc::clone(&recorder),
            residency,
            Arc::clone(&gate),
            Arc::clone(&exec_gauge),
            sink.clone(),
        );

        Server {
            tx,
            gate,
            batcher: Some(batcher),
            workers,
            recorder,
            exec_gauge,
            next_image_id: AtomicU64::new(1),
            sink,
        }
    }

    /// Register a preprocessed matrix for serving.
    pub fn register(&self, image: Arc<ScheduledMatrix>) -> ImageHandle {
        ImageHandle { id: self.next_image_id.fetch_add(1, Ordering::Relaxed), image }
    }

    /// Submit a request; returns the response channel. A request whose
    /// B/C buffers do not match the image and `n` is refused here with an
    /// error response (it would otherwise poison the batcher's column
    /// concatenation), and a request beyond the admission bound is
    /// rejected immediately: the response arrives at once with
    /// [`SpmmResponse::error`] set (the latter counted in
    /// [`Summary::rejected`]).
    pub fn submit(&self, req: SpmmRequest) -> Receiver<SpmmResponse> {
        let submitted = Instant::now();
        let trace = self.sink.as_ref().map(|_| match current_span_context() {
            Some((trace_id, parent)) => TraceCtx {
                trace_id,
                root_id: next_span_id(),
                root_parent: Some(parent),
            },
            None => TraceCtx {
                trace_id: next_trace_id(),
                root_id: next_span_id(),
                root_parent: None,
            },
        });
        let (tx, rx) = mpsc::channel();
        let sm = &req.image.image;
        if req.b.len() != sm.k * req.n || req.c.len() != sm.m * req.n {
            self.emit_admission(trace, submitted, req.image.id, "shape_mismatch");
            let _ = tx.send(SpmmResponse {
                c: Vec::new(),
                timing: Self::rejected_timing(),
                error: Some(format!(
                    "shape mismatch: B has {} elements (expected K*N = {}), C has {} \
                     (expected M*N = {})",
                    req.b.len(),
                    sm.k * req.n,
                    req.c.len(),
                    sm.m * req.n
                )),
                rejected: Some(RejectKind::ShapeMismatch),
            });
            return rx;
        }
        if let Some(deadline) = req.deadline {
            if Instant::now() >= deadline {
                self.recorder.lock().unwrap().record_deadline(DeadlineStage::Admission);
                self.emit_admission(trace, submitted, req.image.id, "deadline_exceeded");
                let _ = tx.send(SpmmResponse {
                    c: Vec::new(),
                    timing: Self::rejected_timing(),
                    error: Some("deadline exceeded before admission".to_string()),
                    rejected: Some(RejectKind::DeadlineExceeded),
                });
                return rx;
            }
        }
        match self.gate.try_admit(req.image.id) {
            Admit::Admitted => {}
            Admit::Full => {
                self.recorder.lock().unwrap().record_reject();
                self.emit_admission(trace, submitted, req.image.id, "shed_full");
                let _ = tx.send(SpmmResponse {
                    c: Vec::new(),
                    timing: Self::rejected_timing(),
                    error: Some(format!(
                        "admission rejected: {} requests in flight (max {})",
                        self.gate.in_flight(),
                        self.gate.policy().max_in_flight
                    )),
                    rejected: Some(RejectKind::QueueFull),
                });
                return rx;
            }
            Admit::ImageQuota => {
                let mut recorder = self.recorder.lock().unwrap();
                recorder.record_reject();
                recorder.record_image_shed(req.image.id);
                drop(recorder);
                self.emit_admission(trace, submitted, req.image.id, "shed_image_quota");
                let _ = tx.send(SpmmResponse {
                    c: Vec::new(),
                    timing: Self::rejected_timing(),
                    error: Some(format!(
                        "admission rejected: image {} at its per-image quota ({})",
                        req.image.id,
                        self.gate.policy().per_image_quota
                    )),
                    rejected: Some(RejectKind::ImageQuota),
                });
                return rx;
            }
        }
        self.emit_admission(trace, submitted, req.image.id, "admitted");
        self.tx
            .send(Msg::Request(req, tx, submitted, trace))
            .expect("server stopped");
        rx
    }

    /// Emit the stage-1 span: the admission decision for one request.
    /// Rejected requests never get a `request` root span, so their lone
    /// `admission` span becomes the trace root when the tree is rebuilt.
    fn emit_admission(
        &self,
        trace: Option<TraceCtx>,
        submitted: Instant,
        image: u64,
        outcome: &'static str,
    ) {
        if let (Some(sink), Some(ctx)) = (self.sink.as_ref(), trace) {
            let span = SpanRecord::from_instants(
                ctx.trace_id,
                Some(ctx.root_id),
                "admission",
                submitted,
                Instant::now(),
            )
            .tag("image", image.to_string())
            .tag("outcome", outcome.to_string());
            sink.emit(span);
        }
    }

    /// Zeroed timing for requests refused before entering the pipeline.
    fn rejected_timing() -> RequestTiming {
        RequestTiming {
            queue: Duration::ZERO,
            batch: Duration::ZERO,
            prepare: Duration::ZERO,
            exec: Duration::ZERO,
            flops: 0,
            backend: "rejected",
            image: 0,
        }
    }

    /// Convenience: submit and wait.
    pub fn call(&self, req: SpmmRequest) -> SpmmResponse {
        self.submit(req).recv().expect("worker dropped response")
    }

    /// Live metrics snapshot without stopping the pipeline: the
    /// recorder's summary as of now, with the execution-concurrency
    /// high-water mark folded in from the live gauge (it is otherwise
    /// only recorded at shutdown).
    pub fn snapshot(&self) -> Summary {
        let mut s = self.recorder.lock().unwrap().summary();
        s.exec_concurrency_peak = s.exec_concurrency_peak.max(self.exec_gauge.peak());
        s
    }

    /// Drain and stop; returns the serving summary.
    pub fn shutdown(mut self) -> Summary {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // All workers have joined: the gauge's high-water mark is final.
        let mut recorder = self.recorder.lock().unwrap();
        recorder.record_exec_concurrency(self.exec_gauge.peak());
        recorder.summary()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{
        Capability, FunctionalBackend, PrepareCost, PreparedSpmm,
    };
    use crate::prop;
    use crate::sched::preprocess;
    use crate::shard::{PreparedSharded, ShardExecutor, ShardedMatrix};
    use crate::sparse::{gen, rng::Rng};

    /// Injects an execution failure on every request (prepare succeeds —
    /// residency is not the failure under test).
    struct FailingBackend;

    struct FailingPrepared;

    impl PreparedSpmm for FailingPrepared {
        fn backend_name(&self) -> &'static str {
            "failing"
        }

        fn prepare_cost(&self) -> PrepareCost {
            PrepareCost::default()
        }

        fn execute(
            &self,
            _b: &[f32],
            _c: &mut [f32],
            _n: usize,
            _alpha: f32,
            _beta: f32,
        ) -> Result<(), BackendError> {
            Err(BackendError::Execution("injected failure".into()))
        }
    }

    impl SpmmBackend for FailingBackend {
        fn name(&self) -> &'static str {
            "failing"
        }

        fn capability(&self) -> Capability {
            Capability {
                threads: 1,
                simd_lanes: 1,
                requires_artifacts: false,
                deterministic: true,
            }
        }

        fn prepare(
            &self,
            _image: Arc<ScheduledMatrix>,
        ) -> Result<Box<dyn PreparedSpmm>, BackendError> {
            Ok(Box::new(FailingPrepared))
        }
    }

    fn make_image(seed: u64) -> (crate::sparse::Coo, Arc<ScheduledMatrix>) {
        let mut rng = Rng::new(seed);
        let coo = gen::random_uniform(48, 40, 0.15, &mut rng);
        let sm = Arc::new(preprocess(&coo, 4, 16, 8));
        (coo, sm)
    }

    fn start_functional(workers: usize) -> Server {
        Server::start(workers, BatchPolicy::default(), |_| Box::new(FunctionalBackend))
    }

    #[test]
    fn single_request_roundtrip() {
        let (coo, sm) = make_image(1);
        let server = start_functional(1);
        let handle = server.register(sm);
        let mut rng = Rng::new(2);
        let n = 4;
        let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
        let c: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
        let mut want = c.clone();
        coo.spmm_reference(&b, &mut want, n, 1.5, 0.5);
        let resp = server.call(SpmmRequest {
            image: handle,
            b,
            c,
            n,
            alpha: 1.5,
            beta: 0.5,
            deadline: None,
        });
        assert!(resp.error.is_none());
        prop::assert_allclose(&resp.c, &want, 1e-4, 1e-4).unwrap();
        let summary = server.shutdown();
        assert_eq!(summary.requests, 1);
        assert_eq!(summary.prepares, 1);
    }

    #[test]
    fn repeated_matrix_prepares_once() {
        // The amortization headline: sequential requests against one image
        // — exactly one prepare, everything else shared-cache hits.
        let (coo, sm) = make_image(41);
        let server = Server::start_backend(1, BatchPolicy::default(), "native:1").unwrap();
        let handle = server.register(sm);
        let mut rng = Rng::new(42);
        let n = 3;
        for _ in 0..5 {
            let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
            let c: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
            let mut want = c.clone();
            coo.spmm_reference(&b, &mut want, n, 1.0, 0.5);
            let resp = server.call(SpmmRequest {
                image: handle.clone(),
                b,
                c,
                n,
                alpha: 1.0,
                beta: 0.5,
                deadline: None,
            });
            assert!(resp.error.is_none());
            prop::assert_allclose(&resp.c, &want, 1e-4, 1e-4).unwrap();
        }
        let summary = server.shutdown();
        assert_eq!(summary.requests, 5);
        assert_eq!(summary.prepares, 1, "one matrix: one prepare");
        assert_eq!(summary.prepare_hits, 4);
        assert!(summary.prepare_hit_rate > 0.7, "{}", summary.prepare_hit_rate);
        assert!(summary.prepared_bytes > 0);
    }

    #[test]
    fn multiple_images_each_get_residency() {
        let (coo1, sm1) = make_image(43);
        let (coo2, sm2) = make_image(44);
        let server = Server::start_backend(1, BatchPolicy::default(), "native:1").unwrap();
        let h1 = server.register(sm1);
        let h2 = server.register(sm2);
        let n = 2;
        for (h, coo) in [(&h1, &coo1), (&h2, &coo2), (&h1, &coo1), (&h2, &coo2)] {
            let resp = server.call(SpmmRequest {
                image: h.clone(),
                b: vec![1.0; coo.k * n],
                c: vec![0.0; coo.m * n],
                n,
                alpha: 1.0,
                beta: 0.0,
                deadline: None,
            });
            assert!(resp.error.is_none());
        }
        let summary = server.shutdown();
        assert_eq!(summary.prepares, 2, "two matrices: two prepares");
        assert_eq!(summary.prepare_hits, 2, "revisits hit the cache");
    }

    #[test]
    fn workers_share_one_residency_per_image() {
        // The PR 3 follow-up made real: N workers serving one matrix hold
        // one shared prepared handle, not N duplicates.
        let (coo, sm) = make_image(45);
        let server = Server::start_backend(3, BatchPolicy::default(), "native:1").unwrap();
        let handle = server.register(sm);
        let n = 2;
        let rxs: Vec<_> = (0..12)
            .map(|_| {
                server.submit(SpmmRequest {
                    image: handle.clone(),
                    b: vec![1.0; coo.k * n],
                    c: vec![0.0; coo.m * n],
                    n,
                    alpha: 1.0,
                    beta: 0.0,
                    deadline: None,
                })
            })
            .collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().error.is_none());
        }
        let summary = server.shutdown();
        assert_eq!(
            summary.prepares, 1,
            "three workers, one image: one shared residency"
        );
    }

    #[test]
    fn admission_gate_sheds_load_with_error_responses() {
        let (_, sm) = make_image(46);
        let config = PipelineConfig {
            admission: AdmissionPolicy { max_in_flight: 0, ..AdmissionPolicy::default() },
            ..PipelineConfig::default()
        };
        let server =
            Server::start_with(1, config, |_| Box::new(FunctionalBackend));
        let handle = server.register(sm.clone());
        let resp = server.call(SpmmRequest {
            image: handle,
            b: vec![0.0; sm.k * 2],
            c: vec![0.0; sm.m * 2],
            n: 2,
            alpha: 1.0,
            beta: 0.0,
            deadline: None,
        });
        let err = resp.error.expect("shed requests must carry an error");
        assert!(err.contains("admission rejected"), "{err}");
        assert_eq!(resp.timing.backend, "rejected");
        assert_eq!(resp.rejected, Some(RejectKind::QueueFull));
        let summary = server.shutdown();
        assert_eq!(summary.rejected, 1);
        assert_eq!(summary.requests, 0, "rejected requests are never served");
    }

    #[test]
    fn per_image_quota_sheds_and_attributes_to_the_image() {
        // Quota 1, one image, a burst of back-to-back submits: the first
        // admitted request holds the image's only slot at least for the
        // batcher's 2 ms merge window, so the burst (microseconds) trips
        // the quota while the global gate still has room.
        let (coo, sm) = make_image(61);
        let config = PipelineConfig {
            admission: AdmissionPolicy { max_in_flight: 64, per_image_quota: 1 },
            ..PipelineConfig::default()
        };
        let server = Server::start_with(1, config, |_| Box::new(FunctionalBackend));
        let handle = server.register(sm);
        let n = 2;
        let mk = || SpmmRequest {
            image: handle.clone(),
            b: vec![1.0; coo.k * n],
            c: vec![0.0; coo.m * n],
            n,
            alpha: 1.0,
            beta: 0.0,
            deadline: None,
        };
        let rxs: Vec<_> = (0..8).map(|_| server.submit(mk())).collect();
        let mut served = 0usize;
        let mut shed = 0usize;
        for rx in rxs {
            let resp = rx.recv().unwrap();
            match resp.error {
                None => {
                    assert_eq!(resp.rejected, None, "served requests carry no reject kind");
                    served += 1;
                }
                Some(e) => {
                    assert!(e.contains("per-image quota"), "{e}");
                    assert_eq!(resp.rejected, Some(RejectKind::ImageQuota));
                    shed += 1;
                }
            }
        }
        assert!(shed >= 1, "a burst over quota 1 must shed");
        assert!(served >= 1, "the quota holder itself is served");
        // After the pipeline drained, the image admits again.
        let resp = server.call(mk());
        assert!(resp.error.is_none(), "{:?}", resp.error);
        let summary = server.shutdown();
        assert_eq!(summary.rejected, shed);
        assert_eq!(summary.image_sheds, vec![(handle.id, shed)]);
        assert_eq!(summary.requests, served + 1);
    }

    #[test]
    fn summary_reports_exec_concurrency_peak() {
        let (coo, sm) = make_image(62);
        let server = start_functional(4);
        let handle = server.register(sm);
        let n = 2;
        let rxs: Vec<_> = (0..32)
            .map(|_| {
                server.submit(SpmmRequest {
                    image: handle.clone(),
                    b: vec![1.0; coo.k * n],
                    c: vec![0.0; coo.m * n],
                    n,
                    alpha: 1.0,
                    beta: 0.0,
                    deadline: None,
                })
            })
            .collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().error.is_none());
        }
        let summary = server.shutdown();
        assert_eq!(summary.requests, 32);
        // Every request executed, so at least one execution was observed
        // live; with 4 workers and one shared &self handle the peak may
        // reach 4, but timing makes >1 unassertable here (the dedicated
        // stress test covers true overlap).
        assert!(
            (1..=4).contains(&summary.exec_concurrency_peak),
            "peak = {}",
            summary.exec_concurrency_peak
        );
    }

    #[test]
    fn malformed_shapes_are_refused_without_poisoning_the_server() {
        let (coo, sm) = make_image(47);
        let server = start_functional(1);
        let handle = server.register(sm);
        // B one element short: refused at submit, never reaches the
        // batcher's column concatenation.
        let resp = server.call(SpmmRequest {
            image: handle.clone(),
            b: vec![0.0; coo.k * 2 - 1],
            c: vec![0.0; coo.m * 2],
            n: 2,
            alpha: 1.0,
            beta: 0.0,
            deadline: None,
        });
        let err = resp.error.expect("bad shapes must be refused");
        assert!(err.contains("shape mismatch"), "{err}");
        // The pipeline is still healthy for well-formed requests.
        let n = 2;
        let b = vec![1.0; coo.k * n];
        let c = vec![0.0; coo.m * n];
        let mut want = c.clone();
        coo.spmm_reference(&b, &mut want, n, 1.0, 0.0);
        let resp = server.call(SpmmRequest {
            image: handle,
            b,
            c,
            n,
            alpha: 1.0,
            beta: 0.0,
            deadline: None,
        });
        assert!(resp.error.is_none());
        prop::assert_allclose(&resp.c, &want, 1e-4, 1e-4).unwrap();
        let summary = server.shutdown();
        assert_eq!(summary.requests, 1, "only the valid request is served");
    }

    #[test]
    fn backend_failure_is_reported_not_silent() {
        let (_, sm) = make_image(9);
        let server = Server::start(1, BatchPolicy::default(), |_| Box::new(FailingBackend));
        let handle = server.register(sm.clone());
        let resp = server.call(SpmmRequest {
            image: handle,
            b: vec![0.0; sm.k * 2],
            c: vec![0.0; sm.m * 2],
            n: 2,
            alpha: 1.0,
            beta: 0.0,
            deadline: None,
        });
        let err = resp.error.expect("failure must be surfaced");
        assert!(err.contains("injected failure"), "{err}");
        assert_eq!(resp.timing.backend, "failing");
        server.shutdown();
    }

    #[test]
    fn unavailable_prepare_is_reported_per_request() {
        // A backend whose prepare fails (pjrt without artifacts) must fail
        // each request with the prepare error, not panic the worker.
        struct NoPrepare;
        impl SpmmBackend for NoPrepare {
            fn name(&self) -> &'static str {
                "no-prepare"
            }
            fn capability(&self) -> Capability {
                Capability {
                    threads: 1,
                    simd_lanes: 1,
                    requires_artifacts: true,
                    deterministic: true,
                }
            }
            fn prepare(
                &self,
                _image: Arc<ScheduledMatrix>,
            ) -> Result<Box<dyn PreparedSpmm>, BackendError> {
                Err(BackendError::Unavailable("no artifacts here".into()))
            }
        }
        let (_, sm) = make_image(11);
        let server = Server::start(1, BatchPolicy::default(), |_| Box::new(NoPrepare));
        let handle = server.register(sm.clone());
        let resp = server.call(SpmmRequest {
            image: handle,
            b: vec![0.0; sm.k * 2],
            c: vec![0.0; sm.m * 2],
            n: 2,
            alpha: 1.0,
            beta: 0.0,
            deadline: None,
        });
        let err = resp.error.expect("prepare failure must be surfaced");
        assert!(err.contains("no artifacts here"), "{err}");
        let summary = server.shutdown();
        assert_eq!(summary.prepares, 0, "failed prepares must not count as residency");
    }

    #[test]
    fn batched_requests_are_column_exact() {
        let (coo, sm) = make_image(3);
        let server = Server::start(
            1,
            BatchPolicy {
                max_columns: 64,
                window: Duration::from_millis(20),
                route_columns: 8,
            },
            |_| Box::new(FunctionalBackend),
        );
        let handle = server.register(sm);
        let mut rng = Rng::new(4);
        let mut rxs = Vec::new();
        let mut wants = Vec::new();
        for _ in 0..5 {
            let n = 1 + rng.index(4);
            let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
            let c: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
            let mut want = c.clone();
            coo.spmm_reference(&b, &mut want, n, 2.0, -1.0);
            wants.push(want);
            rxs.push(server.submit(SpmmRequest {
                image: handle.clone(),
                b,
                c,
                n,
                alpha: 2.0,
                beta: -1.0,
                deadline: None,
            }));
        }
        for (rx, want) in rxs.into_iter().zip(wants) {
            let resp = rx.recv().unwrap();
            prop::assert_allclose(&resp.c, &want, 1e-4, 1e-4).unwrap();
        }
        let summary = server.shutdown();
        assert_eq!(summary.requests, 5);
        // The 20 ms window should have merged several requests per batch.
        assert!(summary.batches < 5, "batches = {}", summary.batches);
        assert!(summary.mean_batch > 1.0);
    }

    #[test]
    fn different_alpha_beta_never_merge() {
        let (_, sm) = make_image(5);
        let server = Server::start(
            1,
            BatchPolicy {
                max_columns: 512,
                window: Duration::from_millis(10),
                route_columns: 8,
            },
            |_| Box::new(FunctionalBackend),
        );
        let handle = server.register(sm.clone());
        let k = sm.k;
        let m = sm.m;
        let mk = |alpha: f32| SpmmRequest {
            image: handle.clone(),
            b: vec![1.0; k * 2],
            c: vec![0.0; m * 2],
            n: 2,
            alpha,
            beta: 0.0,
            deadline: None,
        };
        let r1 = server.submit(mk(1.0));
        let r2 = server.submit(mk(2.0));
        let a = r1.recv().unwrap();
        let b = r2.recv().unwrap();
        // alpha=2 result must be exactly 2x alpha=1 result.
        for (x, y) in a.c.iter().zip(b.c.iter()) {
            assert!((2.0 * x - y).abs() < 1e-4);
        }
        let summary = server.shutdown();
        assert_eq!(summary.batches, 2, "distinct scalars must not merge");
    }

    #[test]
    fn sharded_backend_serves_and_reports_shard_metrics() {
        let (coo, sm) = make_image(21);
        let server = Server::start_backend(1, BatchPolicy::default(), "sharded:3:native:1")
            .unwrap();
        let handle = server.register(sm);
        let mut rng = Rng::new(22);
        let n = 3;
        let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
        let c: Vec<f32> = (0..coo.m * n).map(|_| rng.normal()).collect();
        let mut want = c.clone();
        coo.spmm_reference(&b, &mut want, n, 1.5, 0.5);
        let resp = server.call(SpmmRequest {
            image: handle,
            b,
            c,
            n,
            alpha: 1.5,
            beta: 0.5,
            deadline: None,
        });
        assert!(resp.error.is_none(), "{:?}", resp.error);
        prop::assert_allclose(&resp.c, &want, 2e-4, 2e-4).unwrap();
        assert_eq!(resp.timing.backend, "sharded");
        let summary = server.shutdown();
        assert_eq!(summary.requests, 1);
        assert_eq!(summary.shard_execs, 1);
        assert!((summary.mean_shards - 3.0).abs() < 1e-12);
        assert!(summary.mean_shard_imbalance >= 1.0);
        assert_eq!(summary.prepares, 1, "the shard plan is built once, at prepare");
    }

    #[test]
    fn failing_shard_surfaces_with_shard_identified() {
        // A composite whose shard 1 of 2 always fails at execute; the
        // response must name it, never silently zero its rows.
        struct HalfBrokenSharded;
        impl SpmmBackend for HalfBrokenSharded {
            fn name(&self) -> &'static str {
                "sharded"
            }
            fn capability(&self) -> Capability {
                Capability {
                    threads: 2,
                    simd_lanes: 1,
                    requires_artifacts: false,
                    deterministic: true,
                }
            }
            fn prepare(
                &self,
                image: Arc<ScheduledMatrix>,
            ) -> Result<Box<dyn PreparedSpmm>, BackendError> {
                let sharded = ShardedMatrix::from_image(&image, 2);
                let ok = FunctionalBackend
                    .prepare_send(Arc::clone(&sharded.shards[0].image))?;
                let exec = ShardExecutor::from_prepared(
                    &sharded,
                    vec![ok, Box::new(FailingPrepared)],
                );
                Ok(Box::new(PreparedSharded::from_executor(image, exec)))
            }
        }
        let (_, sm) = make_image(23);
        let server = Server::start(1, BatchPolicy::default(), |_| Box::new(HalfBrokenSharded));
        let handle = server.register(sm.clone());
        let resp = server.call(SpmmRequest {
            image: handle,
            b: vec![0.5; sm.k * 2],
            c: vec![0.5; sm.m * 2],
            n: 2,
            alpha: 1.0,
            beta: 0.0,
            deadline: None,
        });
        let err = resp.error.expect("shard failure must surface");
        assert!(err.contains("shard 1 of 2"), "{err}");
        assert!(err.contains("injected failure"), "{err}");
        assert_eq!(resp.timing.backend, "sharded");
        let summary = server.shutdown();
        assert_eq!(summary.shard_execs, 0, "failed runs must not count as sharded execs");
    }

    #[test]
    fn multi_worker_many_requests() {
        let (coo, sm) = make_image(7);
        let server = start_functional(3);
        let handle = server.register(sm);
        let mut rng = Rng::new(8);
        let n = 2;
        let b: Vec<f32> = (0..coo.k * n).map(|_| rng.normal()).collect();
        let mut want = vec![0f32; coo.m * n];
        coo.spmm_reference(&b, &mut want, n, 1.0, 0.0);
        let rxs: Vec<_> = (0..20)
            .map(|_| {
                server.submit(SpmmRequest {
                    image: handle.clone(),
                    b: b.clone(),
                    c: vec![0.0; coo.m * n],
                    n,
                    alpha: 1.0,
                    beta: 0.0,
                    deadline: None,
                })
            })
            .collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            prop::assert_allclose(&resp.c, &want, 1e-4, 1e-4).unwrap();
        }
        let s = server.shutdown();
        assert_eq!(s.requests, 20);
        assert!(s.p50_s >= 0.0);
        // The single registered image is shared: at most one prepare.
        assert!(s.prepares <= 1, "prepares = {}", s.prepares);
    }
}
